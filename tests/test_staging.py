"""Device-resident staging engine: bulk ≡ scalar heap I/O, stale-padding
regression, result ownership, per-SQE dynamic offsets, prologue flush.

Regression background: the old ``write_inputs_bulk`` mirrored the whole
heap through host memory and wrote ONLY the logical elements of each
chunk, so pad positions kept whatever the heap held before — stale data
from a prior step leaked into the padded slices the daemon circulates
(the scalar ``write_input`` always zero-filled its staging buffer).  The
old read paths returned numpy views aliasing the heap snapshot, and
per-SQE ``in_off``/``out_off`` overrides were honored by the daemon but
silently ignored by the host I/O paths.  The staging engine closes all
three: pads are part of every fused scatter, reads return owned copies,
and offset overrides are scalar adds on the precomputed index maps.

These deterministic cases double as the fallback for the hypothesis
sweep in test_staging_props.py (which skips without hypothesis).
"""
import numpy as np
import pytest

from repro.core import CollKind, OcclConfig, OcclRuntime, ReduceOp


def _cfg(**kw):
    base = dict(n_ranks=4, max_colls=4, max_comms=1, slice_elems=8,
                conn_depth=4, heap_elems=1 << 13)
    base.update(kw)
    return OcclConfig(**base)


def _ragged_sizes(n, R):
    """Per-distance live counts with real capacity drops at odd n."""
    cl = -(-n // R)
    return tuple(max(0, cl - 2 * d) for d in range(R))


def _register(rt, kind, comm, n, **kw):
    """Kind-aware registration: the a2a family has contracts the original
    five kinds don't (exactly-divisible totals; the ragged variant takes
    explicit per-distance live sizes)."""
    R = len(comm.members)
    if kind == CollKind.ALL_TO_ALL:
        return rt.register(kind, comm, n_elems=n - n % R, **kw)
    if kind == CollKind.ALL_TO_ALL_RAGGED:
        return rt.register(kind, comm, n_elems=n,
                           chunk_sizes=_ragged_sizes(n, R), **kw)
    return rt.register(kind, comm, n_elems=n, **kw)


def _inputs(kind, n, R, seed=0):
    rng = np.random.RandomState(seed)
    chunk = -(-n // R)
    if kind == CollKind.ALL_GATHER:
        return [rng.randn(chunk).astype(np.float32) for _ in range(R)]
    if kind == CollKind.ALL_TO_ALL:
        n = n - n % R
    elif kind == CollKind.ALL_TO_ALL_RAGGED:
        n = sum(_ragged_sizes(n, R))
    return [rng.randn(n).astype(np.float32) for _ in range(R)]


def _pollute(rt, fill=7.5):
    """Overwrite the input heap with garbage, simulating stale data from a
    prior step that reused the region (e.g. via dynamic offsets)."""
    import jax.numpy as jnp
    rt._ensure_built()
    rt._state = rt._state._replace(
        heap_in=jnp.full_like(rt._state.heap_in, fill))


@pytest.mark.parametrize("kind", list(CollKind))
def test_bulk_write_matches_scalar_on_polluted_heap(kind):
    """THE stale-padding regression: over a garbage-filled heap, the bulk
    write must leave the heap bit-identical to the scalar path — in
    particular, pad positions must be ZERO, not stale garbage.  The old
    write_inputs_bulk fails this (it wrote only logical elements)."""
    R, n = 4, 53                                   # odd: real pad tails
    xs = _inputs(kind, n, R)

    rts = []
    for _ in range(2):
        rt = OcclRuntime(_cfg())
        comm = rt.communicator(list(range(R)))
        cid = _register(rt, kind, comm, n)
        _pollute(rt)
        rts.append((rt, cid))

    (rt_scalar, cid), (rt_bulk, _) = rts
    for r in range(R):
        rt_scalar.write_input(r, cid, xs[r])
    rt_bulk.write_inputs_bulk({(r, cid): xs[r] for r in range(R)})

    h_scalar = np.asarray(rt_scalar.state.heap_in)
    h_bulk = np.asarray(rt_bulk.state.heap_in)
    np.testing.assert_array_equal(h_bulk, h_scalar)

    # Explicit pad check: inside the written span, every non-logical
    # position is zero (write_input's zero-fill guarantee).
    t = rt_bulk._tables
    spec = rt_bulk.specs[cid]
    span = int(t.in_span[cid])
    # Pad positions derived independently of the engine's mask: every
    # in-span offset the logical map does not cover.
    pad_rel = np.setdiff1d(np.arange(span, dtype=np.int32),
                           t.stage_in_map[cid])
    assert span > int(t.in_log[cid]), "test needs a real pad tail"
    for r in range(R):
        region = h_bulk[r, spec.in_off:spec.in_off + span]
        np.testing.assert_array_equal(region[pad_rel], 0.0)


@pytest.mark.parametrize("kind", list(CollKind))
def test_bulk_roundtrip_equals_scalar_roundtrip(kind):
    """write_inputs_bulk -> drive -> read_outputs_bulk ≡ the scalar
    write_input -> drive -> read_output pipeline, for every CollKind at an
    odd size, over THREE reuses of the same heap (stale-state regression)."""
    R, n = 4, 37
    rt_s = OcclRuntime(_cfg())
    rt_b = OcclRuntime(_cfg())
    comms = [rt.communicator(list(range(R))) for rt in (rt_s, rt_b)]
    cids = [_register(rt, kind, comm, n)
            for rt, comm in zip((rt_s, rt_b), comms)]

    for step in range(3):
        xs = _inputs(kind, n, R, seed=step)
        for r in range(R):
            data = xs[0] if kind == CollKind.BROADCAST else xs[r]
            rt_s.write_input(r, cids[0], data)
            rt_s.submit(r, cids[0])
        rt_b.write_inputs_bulk({
            (r, cids[1]): (xs[0] if kind == CollKind.BROADCAST else xs[r])
            for r in range(R)})
        for r in range(R):
            rt_b.submit(r, cids[1])
        rt_s.drive()
        rt_b.drive()
        bulk = rt_b.read_outputs_bulk([(r, cids[1]) for r in range(R)])
        for r in range(R):
            np.testing.assert_array_equal(bulk[(r, cids[1])],
                                          rt_s.read_output(r, cids[0]))


def test_read_results_are_owned_and_mutation_safe():
    """Aliasing regression: results are writable owned copies; in-place
    mutation (the grad-sync ``/= n_ranks``) cannot corrupt sibling reads
    or re-reads.  The old non-chunked read paths returned views of the
    heap snapshot."""
    R, n = 2, 24
    rt = OcclRuntime(_cfg(n_ranks=R))
    comm = rt.communicator([0, 1])
    cid = rt.register(CollKind.REDUCE_SCATTER, comm, n_elems=n)  # non-chunked out
    xs = _inputs(CollKind.REDUCE_SCATTER, n, R)
    for r in range(R):
        rt.submit(r, cid, data=xs[r])
    rt.drive()

    o1 = rt.read_output(0, cid)
    assert o1.flags.writeable and o1.flags.owndata
    ref = o1.copy()
    o1 /= R                                        # must not corrupt anything
    np.testing.assert_array_equal(rt.read_output(0, cid), ref)

    bulk = rt.read_outputs_bulk([(r, cid) for r in range(R)])
    keep = bulk[(1, cid)].copy()
    bulk[(0, cid)][:] = -1.0
    np.testing.assert_array_equal(bulk[(1, cid)], keep)
    np.testing.assert_array_equal(rt.read_output(0, cid), ref)


def test_sqe_dynamic_offsets_honored_end_to_end():
    """A submission overriding in_off/out_off runs entirely in the
    override region: staged payloads land there, the daemon reads/writes
    there, and the registered default region stays untouched.  The old
    host paths silently ignored the override (daemon read zeros)."""
    R, n = 2, 33
    rt = OcclRuntime(_cfg(n_ranks=R))
    comm = rt.communicator([0, 1])
    a = rt.register(CollKind.ALL_REDUCE, comm, n_elems=n)
    b = rt.register(CollKind.ALL_REDUCE, comm, n_elems=n)  # reserves a twin region
    alt = rt.specs[b]
    xs = _inputs(CollKind.ALL_REDUCE, n, R)
    for r in range(R):
        rt.submit(r, a, data=xs[r], in_off=alt.in_off, out_off=alt.out_off)
    rt.drive()
    want = xs[0] + xs[1]
    for r in range(R):
        np.testing.assert_allclose(
            rt.read_output(r, a, out_off=alt.out_off), want,
            rtol=1e-5, atol=1e-6)
        assert not rt.read_output(r, a).any()      # default region untouched
    # bulk variants accept the same overrides
    rt.write_inputs_bulk({(0, a): (xs[0], alt.in_off)})
    got = rt.read_outputs_bulk([(0, a, alt.out_off)])
    np.testing.assert_allclose(got[(0, a)], want, rtol=1e-5, atol=1e-6)


def test_out_of_range_offset_rejected():
    rt = OcclRuntime(_cfg(n_ranks=2))
    comm = rt.communicator([0, 1])
    cid = rt.register(CollKind.ALL_REDUCE, comm, n_elems=16)
    with pytest.raises(ValueError, match="in_off override"):
        rt.submit(0, cid, data=np.zeros(16, np.float32),
                  in_off=rt.cfg.heap_elems - 1)
    with pytest.raises(ValueError, match="out_off override"):
        rt.read_output(0, cid, out_off=rt.cfg.heap_elems - 1)


def test_wrong_payload_size_rejected():
    """The bulk path now carries the size validation write_input had —
    as ValueError, so it survives python -O."""
    rt = OcclRuntime(_cfg(n_ranks=2))
    comm = rt.communicator([0, 1])
    cid = rt.register(CollKind.ALL_REDUCE, comm, n_elems=16)
    with pytest.raises(ValueError, match="logical size"):
        rt.write_inputs_bulk({(0, cid): np.zeros(15, np.float32)})
    with pytest.raises(ValueError, match="logical size"):
        rt.submit(0, cid, data=np.zeros(17, np.float32))


def test_submit_payloads_flush_in_launch_prologue():
    """submit(data=...) must NOT touch the device at call time: payloads
    park in the staging queue and flush as one batched scatter in the
    launch prologue; an explicit write_input supersedes the staged entry."""
    R, n = 2, 16
    rt = OcclRuntime(_cfg(n_ranks=R))
    comm = rt.communicator([0, 1])
    cid = rt.register(CollKind.ALL_REDUCE, comm, n_elems=n)
    xs = _inputs(CollKind.ALL_REDUCE, n, R)
    for r in range(R):
        rt.submit(r, cid, data=xs[r])
    assert len(rt.queues.staged) == R
    assert not np.asarray(rt.state.heap_in).any()  # nothing written yet

    # A later direct write supersedes rank 0's staged payload (last write
    # at the same buffer wins, matching the old immediate-write semantics).
    override = 2 * xs[0]
    rt.write_input(0, cid, override)
    assert (0, cid, rt.specs[cid].in_off) not in rt.queues.staged

    rt.drive()
    assert len(rt.queues.staged) == 0
    want = override + xs[1]
    for r in range(R):
        np.testing.assert_allclose(rt.read_output(r, cid), want,
                                   rtol=1e-5, atol=1e-6)


def test_staged_payload_is_snapshotted_at_submit_time():
    """Mutating the caller's buffer between submit(data=...) and drive()
    must not change what lands in the heap (the pre-PR immediate-write
    path captured the value at call time; the staging queue must too)."""
    rt = OcclRuntime(_cfg(n_ranks=2))
    comm = rt.communicator([0, 1])
    cid = rt.register(CollKind.ALL_REDUCE, comm, n_elems=16)
    x = np.ones(16, np.float32)
    rt.submit(0, cid, data=x)
    rt.submit(1, cid, data=np.ones(16, np.float32))
    x *= 100.0                                     # reused caller buffer
    rt.drive()
    np.testing.assert_allclose(rt.read_output(0, cid),
                               2 * np.ones(16), rtol=1e-6)


def test_restaging_same_collective_last_write_wins():
    rt = OcclRuntime(_cfg(n_ranks=2))
    comm = rt.communicator([0, 1])
    cid = rt.register(CollKind.ALL_REDUCE, comm, n_elems=8)
    rt.submit(0, cid, data=np.ones(8, np.float32))
    rt.queues.pending[0].pop()                     # drop the duplicate SQE
    rt.queues.submitted[0] -= 1
    rt.submit(0, cid, data=3 * np.ones(8, np.float32))
    rt.submit(1, cid, data=np.ones(8, np.float32))
    rt.drive()
    np.testing.assert_allclose(rt.read_output(0, cid),
                               4 * np.ones(8, np.float32), rtol=1e-6)


def test_two_staged_submissions_at_distinct_offsets_both_land():
    """Pre-flush submissions of the SAME collective at different dynamic
    offsets are distinct executions: both payloads must survive staging
    (the queue is keyed by offset, not just (rank, collective)) and both
    results must be readable at their own offsets."""
    R, n = 2, 17
    rt = OcclRuntime(_cfg(n_ranks=R))
    comm = rt.communicator([0, 1])
    a = rt.register(CollKind.ALL_REDUCE, comm, n_elems=n)
    b = rt.register(CollKind.ALL_REDUCE, comm, n_elems=n)  # twin region
    alt = rt.specs[b]
    xs = _inputs(CollKind.ALL_REDUCE, n, R, seed=1)
    ys = _inputs(CollKind.ALL_REDUCE, n, R, seed=2)
    for r in range(R):
        rt.submit(r, a, data=xs[r])                       # default buffers
        rt.submit(r, a, data=ys[r], in_off=alt.in_off,
                  out_off=alt.out_off)                    # override buffers
    assert len(rt.queues.staged) == 2 * R                 # nothing dropped
    rt.drive()
    for r in range(R):
        np.testing.assert_allclose(rt.read_output(r, a), xs[0] + xs[1],
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(
            rt.read_output(r, a, out_off=alt.out_off), ys[0] + ys[1],
            rtol=1e-5, atol=1e-6)
    with pytest.raises(ValueError, match="conflicting out_off"):
        rt.read_outputs_bulk([(0, a), (0, a, alt.out_off)])
    # identical repeats still dedup silently (pre-PR dict semantics)
    dup = rt.read_outputs_bulk([(0, a), (0, a)])
    assert set(dup) == {(0, a)}


@pytest.mark.parametrize("kind", [CollKind.ALL_REDUCE, CollKind.ALL_GATHER,
                                  CollKind.REDUCE_SCATTER])
def test_device_read_plan_matches_host_fast_path(kind, monkeypatch):
    """The compiled segment-gather read plan (the accelerator branch the
    CPU zero-copy fast path short-circuits) must return the same owned
    results — covered here by disabling the fast path, in every caller
    order (permutation-independent plan cache)."""
    from repro.core import staging as staging_mod
    R, n = 4, 53                                   # odd: padded layouts
    rt = OcclRuntime(_cfg())
    comm = rt.communicator(list(range(R)))
    cid = rt.register(kind, comm, n_elems=n)
    xs = _inputs(kind, n, R)
    for r in range(R):
        rt.submit(r, cid, data=xs[r])
    rt.drive()
    want = rt.read_outputs_bulk([(r, cid) for r in range(R)])
    monkeypatch.setattr(staging_mod, "_host_is_device", lambda: False)
    for order in ([0, 1, 2, 3], [3, 1, 0, 2]):
        got = rt.read_outputs_bulk([(r, cid) for r in order])
        for r in range(R):
            np.testing.assert_array_equal(got[(r, cid)], want[(r, cid)])
            assert got[(r, cid)].flags.writeable
    assert len(rt._staging._read_plans) == 1       # permutations share one


def test_reduce_op_with_staged_inputs():
    """Staged path composes with non-SUM ops (MAX over negatives would
    expose any zero-pad leak into logical positions)."""
    rt = OcclRuntime(_cfg(n_ranks=2))
    comm = rt.communicator([0, 1])
    cid = rt.register(CollKind.ALL_REDUCE, comm, n_elems=21,
                      op=ReduceOp.MAX)
    xs = [-1 - np.arange(21, dtype=np.float32),
          -2 - np.arange(21, dtype=np.float32)]
    for r in range(2):
        rt.submit(r, cid, data=xs[r])
    rt.drive()
    for r in range(2):
        np.testing.assert_allclose(rt.read_output(r, cid),
                                   np.maximum(xs[0], xs[1]), rtol=1e-6)
