"""The statically-sequenced baseline and its deadlock analysis (paper Fig. 1).

``StaticOrderExecutor`` models the single-FIFO-queue programming model of
deadlock-prone GPU collectives (Fig. 1(a)): each rank enqueues collectives
in some order; a collective can only start when it reaches the queue head
on EVERY member rank simultaneously (gang start), and a rank's queue head
cannot be bypassed (no preemption, resource holding).  With inconsistent
orders the wait-for graph acquires a cycle and the system deadlocks — which
this module *detects and reports* instead of hanging.

This is both the correctness foil for the deadlock-freedom property tests
(any order set that deadlocks here must complete under OCCL) and the
"statically sequenced NCCL" comparator of the paper's Sec. 5 benchmarks
(when orders are consistent it completes with zero scheduling overhead).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class StaticResult:
    deadlocked: bool
    completed: list[int]                 # collective ids, completion order
    blocked_at: dict[int, int] | None    # rank -> queue-head collective
    cycle: list[int] | None              # ranks forming a wait-for cycle


def run_static_order(
    orders: dict[int, list[int]],
    members_of: dict[int, list[int]],
) -> StaticResult:
    """Simulate single-FIFO-queue execution.

    orders: rank -> list of collective ids in issue order.
    members_of: collective id -> member ranks.
    """
    heads = {r: 0 for r in orders}
    completed: list[int] = []
    while True:
        progressed = False
        # A collective fires when it is at the head of every member rank.
        ready: list[int] = []
        for r, order in orders.items():
            if heads[r] >= len(order):
                continue
            c = order[heads[r]]
            if all(
                heads[m] < len(orders[m]) and orders[m][heads[m]] == c
                for m in members_of[c]
            ):
                if c not in ready:
                    ready.append(c)
        for c in ready:
            for m in members_of[c]:
                heads[m] += 1
            completed.append(c)
            progressed = True
        if not progressed:
            break

    blocked = {
        r: orders[r][heads[r]] for r in orders if heads[r] < len(orders[r])
    }
    if not blocked:
        return StaticResult(False, completed, None, None)
    cycle = _find_cycle(blocked, members_of, orders, heads)
    return StaticResult(True, completed, blocked, cycle)


def _find_cycle(blocked, members_of, orders, heads):
    """Wait-for graph: rank r (head collective c) waits on every member of
    c whose head is a different collective.  Returns one cycle if any."""
    graph: dict[int, list[int]] = {}
    for r, c in blocked.items():
        graph[r] = [
            m for m in members_of[c]
            if m != r and blocked.get(m) is not None and blocked[m] != c
        ]
    # DFS cycle detection.
    WHITE, GREY, BLACK = 0, 1, 2
    color = {r: WHITE for r in graph}
    stack: list[int] = []

    def dfs(u):
        color[u] = GREY
        stack.append(u)
        for v in graph.get(u, []):
            if color.get(v, WHITE) == GREY:
                return stack[stack.index(v):]
            if color.get(v, WHITE) == WHITE:
                got = dfs(v)
                if got:
                    return got
        stack.pop()
        color[u] = BLACK
        return None

    for r in graph:
        if color[r] == WHITE:
            got = dfs(r)
            if got:
                return got
    return None


def consistent_order_exists(orders: dict[int, list[int]],
                            members_of: dict[int, list[int]]) -> bool:
    """Whether the per-rank orders admit a deadlock-free static schedule
    (i.e. run_static_order drains everything)."""
    return not run_static_order(orders, members_of).deadlocked
