"""Hypothesis property sweep: cross-rank program consistency of every
registered ring-program builder (the invariant the composite-collective
algorithm registry must preserve PER SUB-COLLECTIVE, core/algos.py).

For every kind x group size x root the per-rank primitive programs must be
mutually consistent along the ring:

* **flow matching** — the sequence of chunks rank m sends equals, in FIFO
  order, the sequence of chunks rank (m+1) % R receives (connectors are
  FIFO ring buffers, so a chunk mismatch would silently combine unrelated
  slices);
* **drain** — executing the programs dataflow-style with unbounded
  connectors terminates with every program complete and no dangling
  sends (a structural wedge here would deadlock the daemon regardless of
  scheduling);
* **flow conservation** — every chunk reaches its destination with
  exactly the right contribution set (all ranks for reductions, the
  originator for gathers/broadcast, and for the all-to-all kinds the
  right ORIGIN GRANULE per destination — the personalized-exchange
  property a bare origin-set check cannot see);
* **ragged capacity drops** — ALL_TO_ALL_RAGGED end-to-end through the
  runtime for arbitrary per-distance keep fractions, zeros included.

Skipped when hypothesis is absent (tier-1 containers);
``pip install -r requirements-dev.txt`` restores the sweep.
"""
import collections

import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.primitives import (_FLAGS, CollKind, Prim, build_program)


def _simulate(kind: CollKind, R: int, root: int):
    """Dataflow-execute the R per-rank programs over unbounded FIFO
    connectors, tracking each output chunk's contribution set: the set of
    ``(origin_rank, granule)`` atoms combined into it, where ``granule``
    is the chunk operand at the step that READ the origin's input.  Atoms
    (not bare ranks) are what make the all-to-all checkable — a
    personalized exchange and an all-gather have identical origin SETS
    per output chunk and differ only in WHICH granule each origin
    contributed.

    Wire-id policy: for every kind except the flat ALL_TO_ALL the FIFO
    hands each receiver exactly the chunk id its program names
    (``wk == k``), relays included.  The flat all-to-all names
    DESTINATION granules on the wire (SEND and the inert RECV_SEND relay
    operands both carry the destination id) but ORIGIN granules at the
    terminal RECV, so there the check is semantic instead: only chunks
    destined for this very rank are terminally received (``wk == m``)
    and the payload is exactly the named origin's granule for this
    destination."""
    progs = [build_program(kind, m, R, root) for m in range(R)]
    pc = [0] * R
    fifo = [collections.deque() for _ in range(R)]  # edge m -> (m+1) % R
    out: list[dict] = [dict() for _ in range(R)]
    progress = True
    while progress:
        progress = False
        for m in range(R):
            while pc[m] < len(progs[m]):
                prim, k = progs[m][pc[m]]
                recv, send, _reduce, copy, reads = _FLAGS[Prim(prim)]
                src = (m - 1) % R
                if recv and not fifo[src]:
                    break                      # wait for the upstream send
                val: set = set()
                if recv:
                    wk, wv = fifo[src].popleft()
                    if kind == CollKind.ALL_TO_ALL and not send:
                        assert wk == m, (
                            f"{kind.name} R={R}: rank {m} terminally "
                            f"receives a chunk destined for {wk}")
                        assert wv == frozenset({(k, wk)}), (
                            f"{kind.name} R={R}: rank {m} RECV {k} "
                            f"carries {wv}, wants origin {k}'s granule "
                            f"for {wk}")
                    else:
                        # Flow matching: the FIFO hands this rank exactly
                        # the chunk its program expects next.
                        assert wk == k, (
                            f"{kind.name} R={R} root={root}: rank {m} "
                            f"step {pc[m]} expects chunk {k}, wire has "
                            f"{wk}")
                    val |= wv
                if reads:
                    val.add((m, k))
                if copy:
                    out[m][k] = frozenset(val)
                if send:
                    fifo[m].append((k, frozenset(val)))
                pc[m] += 1
                progress = True
    assert all(pc[m] == len(progs[m]) for m in range(R)), (
        f"{kind.name} R={R} root={root}: programs wedge at {pc}")
    assert all(not f for f in fifo), "dangling sends after completion"
    return out


@settings(max_examples=120, deadline=None)
@given(data=st.data())
def test_flow_conservation(data):
    kind = data.draw(st.sampled_from(list(CollKind)), label="kind")
    R = data.draw(st.integers(1, 9), label="group_size")
    root = data.draw(st.integers(0, R - 1), label="root")
    out = _simulate(kind, R, root)

    def every(k):
        return frozenset((r, k) for r in range(R))

    if R == 1:
        # Degenerate single-member group: local copy of the own input.
        assert out[0] == {0: frozenset({(0, 0)})}
        return
    if kind == CollKind.ALL_REDUCE:
        for m in range(R):
            assert out[m] == {k: every(k) for k in range(R)}
    elif kind == CollKind.ALL_GATHER:
        for m in range(R):
            assert out[m] == {k: frozenset({(k, k)}) for k in range(R)}
    elif kind == CollKind.REDUCE_SCATTER:
        for m in range(R):
            # Rank m finalizes exactly its own chunk, fully reduced.
            assert out[m] == {m: every(m)}
    elif kind == CollKind.BROADCAST:
        for m in range(R):
            assert out[m] == {k: frozenset({(root, k)}) for k in range(R)}
    elif kind == CollKind.REDUCE:
        assert out[root] == {k: every(k) for k in range(R)}
        for m in range(R):
            if m != root:
                assert out[m] == {}   # non-roots copy nothing
    elif kind == CollKind.ALL_TO_ALL:
        # Personalized exchange, absolute granules: output granule o at
        # rank m is EXACTLY origin o's input granule destined for m —
        # same origin set as all-gather, different granule per origin,
        # which is precisely what the (origin, granule) atoms resolve.
        for m in range(R):
            assert out[m] == {o: frozenset({(o, m)}) for o in range(R)}
    else:
        assert kind == CollKind.ALL_TO_ALL_RAGGED
        # Distance-keyed granules: rank m's distance-s granule comes
        # from origin (m - s) % R, which names it by the SAME distance s
        # (the rank-independent program contract the shared per-
        # collective stage maps rely on).
        for m in range(R):
            assert out[m] == {s: frozenset({((m - s) % R, s)})
                              for s in range(R)}


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_alltoall_ragged_capacity_drops_end_to_end(data):
    """ALL_TO_ALL_RAGGED through the real runtime for arbitrary
    per-distance capacity-drop fractions: each distance s keeps
    ``sizes[s]`` of ``cap`` elements (any fraction from keep-all to
    drop-all).  Rank m's distance-s output segment must be element-
    identical to origin ``(m - s) % R``'s distance-s input segment, and
    dropped capacity must never resurface in any output."""
    import numpy as np

    from repro.core import CollKind as K, OcclConfig, OcclRuntime

    R = data.draw(st.integers(2, 4), label="ranks")
    cap = data.draw(st.integers(1, 4), label="capacity")
    sizes = [data.draw(st.integers(0, cap), label=f"size{s}")
             for s in range(R)]
    if sum(sizes) == 0:
        sizes[0] = 1            # registration requires >= 1 live element

    cfg = OcclConfig(n_ranks=R, max_colls=1, max_comms=1, slice_elems=4,
                     conn_depth=4, heap_elems=1 << 12)
    rt = OcclRuntime(cfg)
    comm = rt.communicator(list(range(R)))
    cid = rt.register(K.ALL_TO_ALL_RAGGED, comm, n_elems=R * cap,
                      chunk_sizes=tuple(sizes))

    # Element values encode (origin, distance, index) so any misrouted or
    # resurfaced element is unambiguously identifiable.
    def seg(origin, s):
        return origin * 10000 + s * 100 + np.arange(sizes[s])

    for m in range(R):
        x = np.concatenate([seg(m, s) for s in range(R)]).astype(np.float32)
        rt.write_input(m, cid, x)
        rt.submit(m, cid)
    rt.drive()
    for m in range(R):
        want = np.concatenate([seg((m - s) % R, s)
                               for s in range(R)]).astype(np.float32)
        np.testing.assert_array_equal(rt.read_output(m, cid), want)


# ---------------------------------------------------------------------------
# composite-plan flow conservation (the algorithm zoo, core/algos.py)
# ---------------------------------------------------------------------------

def _eval_stage(stage, state):
    """Semantically evaluate one CompositePlan stage over per-rank logical
    contribution vectors.

    ``state[rank]`` is a list of frozensets of ``(origin_rank, elem)``
    atoms — the provenance of each logical element the rank currently
    holds — or None where the previous stage left the rank's buffer
    undefined (reduce non-roots).  Atoms carry the ORIGINAL input
    identity, so chunk-offset bugs anywhere in the chain (reduce-scatter
    ownership, all-gather placement, inter-ring chunk arithmetic) show up
    as misaligned atoms in the final state, not just wrong counts."""
    from repro.core.primitives import CollKind as K

    ns, P = stage.n_elems, stage.ring_size
    cl = -(-ns // P)
    rings = [stage.members[i:i + P]
             for i in range(0, len(stage.members), P)]
    new = dict(state)
    for ring in rings:
        assert len(ring) == P
        if stage.kind in (K.ALL_REDUCE, K.REDUCE):
            for r in ring:
                assert state[r] is not None and len(state[r]) == ns, (
                    f"{stage.kind.name}: rank {r} hands stage a "
                    f"{state[r] and len(state[r])}-elem buffer, wants {ns}")
            red = [frozenset().union(*(state[r][e] for r in ring))
                   for e in range(ns)]
            if stage.kind == K.ALL_REDUCE:
                for r in ring:
                    new[r] = list(red)
            else:
                for p, r in enumerate(ring):
                    new[r] = list(red) if p == stage.root else None
        elif stage.kind == K.REDUCE_SCATTER:
            for r in ring:
                assert state[r] is not None and len(state[r]) == ns
            for p, r in enumerate(ring):
                new[r] = [frozenset().union(
                              *(state[q][p * cl + j] for q in ring))
                          if p * cl + j < ns else frozenset()
                          for j in range(cl)]
        elif stage.kind == K.ALL_GATHER:
            for r in ring:
                assert state[r] is not None and len(state[r]) == cl
            full = [state[ring[e // cl]][e % cl] for e in range(ns)]
            for r in ring:
                new[r] = list(full)
        elif stage.kind == K.BROADCAST:
            src = ring[stage.root]
            assert state[src] is not None and len(state[src]) == ns
            for r in ring:
                new[r] = list(state[src])
        else:
            raise AssertionError(f"unexpected stage kind {stage.kind}")
    return new


@settings(max_examples=100, deadline=None)
@given(data=st.data())
def test_composite_plan_flow_conservation(data):
    """Every plan in the algorithm zoo, for every grid shape, root and
    ragged payload: chain edges agree on buffer lengths (the relink span
    contract) and the final state carries exactly the right contribution
    atoms at exactly the right logical positions."""
    from repro.core.algos import build_plan

    algo, kind = data.draw(st.sampled_from([
        ("two_level", CollKind.ALL_REDUCE),
        ("torus", CollKind.ALL_REDUCE),
        ("hybrid", CollKind.ALL_REDUCE),
        ("tree", CollKind.BROADCAST),
        ("tree", CollKind.REDUCE),
    ]), label="algo_kind")
    G = data.draw(st.integers(2, 4), label="G")
    N = data.draw(st.integers(2, 4), label="N")
    R = G * N
    root = data.draw(st.integers(0, R - 1), label="root")
    n = data.draw(st.integers(1, 64), label="n_elems")
    members = tuple(range(100, 100 + R))       # non-contiguous global ids
    plan = build_plan(algo, kind, members, (G, N), n, root)
    for stage in plan.stages:
        assert set(stage.members) <= set(members)
        assert len(stage.members) % stage.ring_size == 0
        assert len(set(stage.members)) == len(stage.members)
    state = {r: [frozenset({(r, e)}) for e in range(n)] for r in members}
    for stage in plan.stages:
        state = _eval_stage(stage, state)
    want_all = [frozenset((r, e) for r in members) for e in range(n)]
    if kind == CollKind.ALL_REDUCE:
        for r in members:
            assert state[r] == want_all, f"rank {r} mis-reduced ({algo})"
    elif kind == CollKind.BROADCAST:
        src = members[root]
        want = [frozenset({(src, e)}) for e in range(n)]
        for r in members:
            assert state[r] == want, f"rank {r} got non-root data"
    else:                                      # REDUCE: defined at root
        assert state[members[root]] == want_all


@settings(max_examples=60, deadline=None)
@given(data=st.data())
def test_send_recv_counts_balance(data):
    """Per ring edge, #sends == #recvs (no chunk is ever dropped on the
    wire) — the counting form of flow conservation."""
    from repro.core.primitives import PRIM_RECV, PRIM_SEND

    kind = data.draw(st.sampled_from(list(CollKind)), label="kind")
    R = data.draw(st.integers(2, 9), label="group_size")
    root = data.draw(st.integers(0, R - 1), label="root")
    progs = [build_program(kind, m, R, root) for m in range(R)]
    for m in range(R):
        sends = sum(int(PRIM_SEND[p]) for p, _ in progs[m])
        recvs = sum(int(PRIM_RECV[p]) for p, _ in progs[(m + 1) % R])
        assert sends == recvs, (kind, R, root, m)
