"""ALL_TO_ALL as a first-class collective kind, end to end.

Covers the tentpole acceptance criteria:
* the flat relay-ring all-to-all matches the direct-indexing reference
  for every (R, n) shape, and the ragged variant for capacity-dropped
  per-distance sizes (zeros included);
* the composite two-level all-to-all (intra-group exchange -> inter-
  group exchange with the granule-transpose input permutations) lands
  bit-identically to the flat ring for every grid;
* ``algo="auto"`` resolves over {ring, two_level} and drops the
  two-level candidate when the payload is not exactly divisible;
* registration validates the a2a contracts loudly (divisibility,
  ragged size vectors, kind-registry lookups — the ValueError-naming
  satellite);
* chained conflicting a2a submission orders wedge a statically-
  sequenced executor but complete under OCCL (the paper's deadlock
  scenario, instantiated on the new kind).
"""
import numpy as np
import pytest

from repro.core import (CollKind, OcclConfig, OcclRuntime,
                        plan_two_level_alltoall, run_static_order,
                        select_algo)
from repro.core.algos import build_ring_program
from repro.core.primitives import Prim, io_chunked, program_len


def _runtime(R, max_colls=8, max_comms=4, slice_elems=8, conn_depth=8,
             heap_elems=1 << 16, **kw):
    cfg = OcclConfig(n_ranks=R, max_colls=max_colls, max_comms=max_comms,
                     slice_elems=slice_elems, conn_depth=conn_depth,
                     heap_elems=heap_elems, superstep_budget=1 << 15, **kw)
    rt = OcclRuntime(cfg)
    return rt, rt.communicator(list(range(R)))


def _inputs(R, n, seed=0):
    """Per-rank payloads whose values encode (origin, position)."""
    rng = np.random.RandomState(seed)
    return [np.asarray(o * 1000 + rng.randn(n), np.float32)
            for o in range(R)]


def _a2a_ref(ins, R):
    """Personalized exchange: out[m] = concat over origins o of o's
    granule destined for m (origin-major output, granule c = n/R)."""
    c = ins[0].size // R
    return [np.concatenate([ins[o][m * c:(m + 1) * c] for o in range(R)])
            for m in range(R)]


# ---------------------------------------------------------------------------
# program structure
# ---------------------------------------------------------------------------

def test_program_len_counts_relay_hops():
    # 1 local copy + per phase s: 1 send + (s-1) relays + 1 recv.
    for R in range(2, 10):
        want = 1 + sum(1 + (s - 1) + 1 for s in range(1, R))
        assert program_len(CollKind.ALL_TO_ALL, R) == want
        assert program_len(CollKind.ALL_TO_ALL_RAGGED, R) == want
    assert program_len(CollKind.ALL_TO_ALL, 1) == 1
    assert io_chunked(CollKind.ALL_TO_ALL) == (True, True)
    assert io_chunked(CollKind.ALL_TO_ALL_RAGGED) == (True, True)


def test_ragged_program_is_rank_independent():
    """The distance-keyed program must be identical across members —
    the contract that lets every member share one stage map."""
    R = 5
    progs = [build_ring_program(CollKind.ALL_TO_ALL_RAGGED, m, R)
             for m in range(R)]
    assert all(p == progs[0] for p in progs)
    assert progs[0][0] == (Prim.COPY, 0)


def test_unregistered_kind_lookups_name_the_registry():
    with pytest.raises(ValueError, match="registered kinds"):
        program_len(99, 4)
    with pytest.raises(ValueError, match="registered kinds"):
        io_chunked(99)
    with pytest.raises(ValueError, match="ALL_TO_ALL"):
        build_ring_program(CollKind.ALL_TO_ALL, 0, 4, algo="nope")


# ---------------------------------------------------------------------------
# flat ring vs reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("R,n", [(2, 8), (3, 12), (4, 16), (4, 32),
                                 (5, 20), (8, 64)])
def test_flat_ring_matches_reference(R, n):
    rt, world = _runtime(R)
    cid = rt.register(CollKind.ALL_TO_ALL, world, n_elems=n)
    xs = _inputs(R, n)
    for r in range(R):
        rt.submit(r, cid, data=xs[r])
    rt.drive()
    want = _a2a_ref(xs, R)
    for m in range(R):
        np.testing.assert_array_equal(rt.read_output(m, cid), want[m])


@pytest.mark.parametrize("R,sizes", [(3, (1, 0, 2)), (4, (3, 8, 0, 5)),
                                     (4, (2, 2, 2, 2)), (5, (4, 0, 0, 1, 3))])
def test_ragged_matches_reference(R, sizes):
    """Distance-keyed ragged exchange: rank m's distance-s segment is
    origin (m - s) % R's distance-s segment, capacity drops and all."""
    n = R * max(sizes)
    rt, world = _runtime(R)
    cid = rt.register(CollKind.ALL_TO_ALL_RAGGED, world, n_elems=n,
                      chunk_sizes=sizes)
    seg = lambda o, s: np.asarray(o * 1000 + s * 10 + np.arange(sizes[s]),
                                  np.float32)
    for r in range(R):
        rt.submit(r, cid, data=np.concatenate(
            [seg(r, s) for s in range(R)]))
    rt.drive()
    for m in range(R):
        want = np.concatenate([seg((m - s) % R, s) for s in range(R)])
        np.testing.assert_array_equal(rt.read_output(m, cid), want)


# ---------------------------------------------------------------------------
# composite two-level plan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("R,hier,n", [(4, (2, 2), 16), (8, (2, 4), 32),
                                      (8, (4, 2), 64), (9, (3, 3), 36)])
def test_two_level_matches_flat(R, hier, n):
    rt, world = _runtime(R, max_colls=12, heap_elems=1 << 17)
    flat = rt.register(CollKind.ALL_TO_ALL, world, n_elems=n)
    two = rt.register(CollKind.ALL_TO_ALL, world, n_elems=n,
                      algo="two_level", hierarchy=hier)
    xs = _inputs(R, n)
    for r in range(R):
        rt.submit(r, flat, data=xs[r])
        rt.submit(r, two, data=xs[r])
    rt.drive()
    want = _a2a_ref(xs, R)
    for m in range(R):
        np.testing.assert_array_equal(rt.read_output(m, flat), want[m])
        # Identical OUTPUT LAYOUT is part of the plan contract: callers
        # may swap algorithms without re-deriving granule offsets.
        np.testing.assert_array_equal(rt.read_output(m, two), want[m])


def test_two_level_plan_validates():
    with pytest.raises(ValueError, match="divisible"):
        plan_two_level_alltoall(CollKind.ALL_TO_ALL, range(4), (2, 2), 10)
    with pytest.raises(ValueError, match="RAGGED"):
        plan_two_level_alltoall(CollKind.ALL_TO_ALL_RAGGED, range(4),
                                (2, 2), 16)
    with pytest.raises(ValueError, match="does not tile"):
        plan_two_level_alltoall(CollKind.ALL_TO_ALL, range(8), (3, 2), 24)


def test_auto_resolves_and_runs():
    # Exact divisibility: both candidates rankable; selection resolves.
    got = select_algo("auto", CollKind.ALL_TO_ALL, 64, 8)
    assert got in ("ring", "two_level")
    # Indivisible payload: the two-level candidate is unconstructible
    # and must be DROPPED, not crash selection.
    assert select_algo("auto", CollKind.ALL_TO_ALL, 60, 8) == "ring"
    assert select_algo("auto", CollKind.ALL_TO_ALL_RAGGED, 64, 8) == "ring"

    rt, world = _runtime(8, max_colls=12, heap_elems=1 << 17)
    cid = rt.register(CollKind.ALL_TO_ALL, world, n_elems=64, algo="auto")
    xs = _inputs(8, 64)
    for r in range(8):
        rt.submit(r, cid, data=xs[r])
    rt.drive()
    want = _a2a_ref(xs, 8)
    for m in range(8):
        np.testing.assert_array_equal(rt.read_output(m, cid), want[m])


# ---------------------------------------------------------------------------
# registration validation
# ---------------------------------------------------------------------------

def test_registration_validates_contracts():
    rt, world = _runtime(4)
    with pytest.raises(ValueError, match="divisible"):
        rt.register(CollKind.ALL_TO_ALL, world, n_elems=10)
    with pytest.raises(ValueError, match="ALL_TO_ALL_RAGGED"):
        rt.register(CollKind.ALL_REDUCE, world, n_elems=8,
                    chunk_sizes=(2, 2, 2, 2))
    with pytest.raises(ValueError, match="chunk_sizes"):
        rt.register(CollKind.ALL_TO_ALL_RAGGED, world, n_elems=8,
                    chunk_sizes=(2, 2))          # wrong length
    with pytest.raises(ValueError):
        rt.register(CollKind.ALL_TO_ALL_RAGGED, world, n_elems=8,
                    chunk_sizes=(9, 0, 0, 0))    # beyond capacity
    with pytest.raises(ValueError):
        rt.register(CollKind.ALL_TO_ALL_RAGGED, world, n_elems=8,
                    chunk_sizes=(0, 0, 0, 0))    # nothing live
    with pytest.raises(ValueError, match="composite"):
        rt.register(CollKind.ALL_TO_ALL_RAGGED, world, n_elems=8,
                    chunk_sizes=(2, 1, 1, 2), algo="two_level",
                    hierarchy=(2, 2))


# ---------------------------------------------------------------------------
# deadlock scenario on the new kind
# ---------------------------------------------------------------------------

def test_chained_conflicting_a2a_orders_complete():
    """Two all-to-alls submitted in opposite orders by even/odd ranks:
    the single-FIFO-queue static executor provably wedges on a wait-for
    cycle, while OCCL drains both with correct personalized payloads."""
    R, n = 4, 16
    orders = {r: [0, 1] if r % 2 == 0 else [1, 0] for r in range(R)}
    static = run_static_order(orders, {c: list(range(R)) for c in (0, 1)})
    assert static.deadlocked and static.cycle

    rt, world = _runtime(R)
    ids = [rt.register(CollKind.ALL_TO_ALL, world, n_elems=n)
           for _ in range(2)]
    xs = {c: _inputs(R, n, seed=c) for c in (0, 1)}
    for r in range(R):
        for c in orders[r]:
            rt.submit(r, ids[c], data=xs[c][r])
    rt.drive()
    for c in (0, 1):
        want = _a2a_ref(xs[c], R)
        for m in range(R):
            np.testing.assert_array_equal(rt.read_output(m, ids[c]),
                                          want[m])


def test_chained_a2a_across_algorithms_and_allreduce():
    """The MoE shape: a dispatch/combine a2a PAIR interleaved with an
    all-reduce, submitted in rank-dependent conflicting orders (no
    consistent static schedule exists), one a2a flat and one two-level —
    all complete and all land reference-exact."""
    R, n = 8, 32
    orders = {r: list(np.random.RandomState(r).permutation(3))
              for r in range(R)}
    static = run_static_order(orders, {c: list(range(R)) for c in range(3)})
    assert static.deadlocked

    rt, world = _runtime(R, max_colls=16, heap_elems=1 << 17)
    disp = rt.register(CollKind.ALL_TO_ALL, world, n_elems=n)
    comb = rt.register(CollKind.ALL_TO_ALL, world, n_elems=n,
                       algo="two_level", hierarchy=(2, 4))
    ar = rt.register(CollKind.ALL_REDUCE, world, n_elems=n)
    ids = [disp, comb, ar]
    xs = {c: _inputs(R, n, seed=10 + c) for c in range(3)}
    for r in range(R):
        for c in orders[r]:
            rt.submit(r, ids[c], data=xs[c][r])
    rt.drive()
    for c, cid in ((0, disp), (1, comb)):
        want = _a2a_ref(xs[c], R)
        for m in range(R):
            np.testing.assert_array_equal(rt.read_output(m, cid), want[m])
    want = np.sum(xs[2], axis=0)
    for m in range(R):
        np.testing.assert_allclose(rt.read_output(m, ar), want, rtol=1e-5)
