"""Launch-epoch scheduler clock: the superstep budget is PER LAUNCH, queue
keys are bounded per launch (no i32 class bleed at any runtime age), spin
advances by stalled slices, and the conn_depth burst guard fires.

Regression background: the seed compared the cumulative ``supersteps``
clock against ``superstep_budget``, so once the runtime had executed the
budget's worth of supersteps across its lifetime, EVERY later launch
exited after one superstep and ``drive()`` raised spurious
``DeadlockTimeout`` — fatal for long-lived serving.  The same unbounded
clock fed the task-queue arrival keys, whose priority stride is only
``1 << 20``.
"""
import warnings

import numpy as np
import pytest

from repro.core import (CollKind, ConnDepthWarning, OcclConfig, OcclRuntime,
                        OrderPolicy)
from repro.core.config import QUEUE_KEY_DEMAND_STRIDE
from repro.core.scheduler import rebase_arrivals
from repro.core.state import init_state


# ---------------------------------------------------------------------------
# per-launch superstep budget (the tentpole regression)
# ---------------------------------------------------------------------------

def test_budget_is_per_launch_across_many_launches():
    """drive() keeps completing work after the CUMULATIVE superstep count
    exceeds superstep_budget: each launch gets a fresh budget, launches
    stay multi-superstep, and no spurious DeadlockTimeout fires."""
    budget = 64
    cfg = OcclConfig(n_ranks=4, max_colls=4, max_comms=1, slice_elems=4,
                     conn_depth=4, heap_elems=1 << 13,
                     superstep_budget=budget, quit_threshold=budget)
    rt = OcclRuntime(cfg)
    comm = rt.communicator(list(range(4)))
    # ~126 supersteps per iteration (7 prims x 3 slices x 6 rounds) — each
    # drive() needs >= 2 launches at budget 64.
    cid = rt.register(CollKind.ALL_REDUCE, comm, n_elems=256)
    rng = np.random.RandomState(0)
    for it in range(3):
        xs = [rng.randn(256).astype(np.float32) for _ in range(4)]
        for r in range(4):
            rt.submit(r, cid, data=xs[r])
        rt.drive()                      # must NOT raise DeadlockTimeout
        want = np.sum(xs, axis=0)
        for r in range(4):
            np.testing.assert_allclose(rt.read_output(r, cid), want,
                                       rtol=1e-4)
    st = rt.stats()
    total = int(st["supersteps"].max())
    assert total > 2 * budget           # cumulative clock far past budget
    assert rt.launches >= 3
    # The regression signature was one-superstep launches once the
    # cumulative clock passed the budget: every launch would then consume
    # a relaunch, needing ~total supersteps worth of launches.  With the
    # per-launch clock a handful of full-budget launches suffice.
    assert rt.launches <= 3 * (total // budget + 2)
    for rec in rt.stats()["launch_history"]:
        assert rec["launch_steps"] <= budget
    # Device-side launch counter mirrors the host's.
    assert int(st["epoch"].max()) == rt.launches


def test_launch_clock_resets_while_epoch_clock_accumulates():
    cfg = OcclConfig(n_ranks=2, max_colls=2, max_comms=1, slice_elems=4,
                     conn_depth=2, heap_elems=512)
    rt = OcclRuntime(cfg)
    cm = rt.communicator([0, 1])
    ar = rt.register(CollKind.ALL_REDUCE, cm, n_elems=8)
    steps_seen = []
    for it in range(3):
        for r in range(2):
            rt.submit(r, ar, data=np.ones(8, np.float32))
        rt.drive()
        st = rt.stats()
        steps_seen.append(int(st["supersteps"].max()))
        # launch_steps is the LAST launch's clock — bounded by the budget,
        # not by the runtime's age.
        assert int(st["launch_steps"].max()) <= cfg.superstep_budget
    assert steps_seen == sorted(steps_seen)      # cumulative, monotonic
    assert steps_seen[-1] > steps_seen[0]


# ---------------------------------------------------------------------------
# bounded queue keys / arrival rebase
# ---------------------------------------------------------------------------

def test_rebase_arrivals_bounds_and_preserves_order():
    cfg = OcclConfig(n_ranks=1, max_colls=8, max_comms=1)
    st = init_state(cfg, per_rank=False)
    active = np.zeros(8, bool)
    arrival = np.zeros(8, np.int32)
    # Huge arrivals (>= 1 << 20) as an aged runtime would have produced.
    for c, a in [(2, (1 << 20) + 5), (5, 3), (7, (1 << 30) + 1)]:
        active[c] = True
        arrival[c] = a
    st = st._replace(tq_active=np.asarray(active),
                     arrival=np.asarray(arrival))
    got = np.asarray(rebase_arrivals(st).arrival)
    assert got[5] == 0 and got[2] == 1 and got[7] == 2   # order kept
    assert got.max() < cfg.max_colls                     # bounded
    assert all(got[c] == 0 for c in range(8) if not active[c])


def test_arrivals_stay_bounded_over_many_launches():
    budget = 64
    cfg = OcclConfig(n_ranks=2, max_colls=4, max_comms=1, slice_elems=4,
                     conn_depth=4, heap_elems=1 << 13,
                     superstep_budget=budget)
    rt = OcclRuntime(cfg)
    cm = rt.communicator([0, 1])
    cid = rt.register(CollKind.ALL_REDUCE, cm, n_elems=128)
    for _ in range(4):
        for r in range(2):
            rt.submit(r, cid, data=np.ones(128, np.float32))
        rt.drive()
    arr = np.asarray(rt.state.arrival)
    assert arr.max() < cfg.max_colls + budget + 2
    assert arr.max() < QUEUE_KEY_DEMAND_STRIDE           # no class bleed


def test_priority_and_demand_survive_huge_legacy_arrivals():
    """Queue-key classes survive arrival values >= 1 << 20: after the
    prologue rebase, a poisoned carryover arrival can neither demote a
    collective out of its priority class (stride 1 << 20) nor defeat the
    demand-steering bonus (1 << 18) — both of which the unbounded epoch
    clock silently corrupted."""
    import jax
    from repro.core.daemon import local_tables, shared_tables
    from repro.core.scheduler import _lane_keys

    cfg = OcclConfig(n_ranks=2, max_colls=4, max_comms=1, slice_elems=4,
                     conn_depth=2, heap_elems=1 << 13,
                     order_policy=OrderPolicy.PRIORITY, quit_threshold=8)
    rt = OcclRuntime(cfg)
    cm = rt.communicator([0, 1])
    lo = rt.register(CollKind.ALL_REDUCE, cm, n_elems=32)
    hi = rt.register(CollKind.ALL_REDUCE, cm, n_elems=32)
    # Strand both on rank 0 (peer missing) so they become carryover queue
    # entries, then poison hi's arrival as if it had been queued for ~6M
    # cumulative supersteps (6 full priority strides).
    rt.submit(0, lo, prio=0, data=np.ones(32, np.float32))
    rt.submit(0, hi, prio=5, data=np.ones(32, np.float32))
    assert rt.launch_once() == 0
    assert bool(np.asarray(rt.state.tq_active)[0, hi])
    rt._state = rt.state._replace(
        arrival=rt.state.arrival.at[0, hi].set(6 << 20))

    def rank0_front(st):
        st0 = jax.tree_util.tree_map(lambda a: a[0], st)
        lt0 = jax.tree_util.tree_map(lambda a: a[0],
                                     local_tables(rt._tables))
        eligible, key = _lane_keys(cfg, st0, shared_tables(rt._tables), lt0)
        assert bool(eligible[0, lo]) and bool(eligible[0, hi])
        return int(np.argmin(np.asarray(key)[0]))

    # PRIORITY: hi (prio 5) must outrank lo despite the poisoned arrival.
    st = rebase_arrivals(rt.state)
    assert rank0_front(st) == hi

    # Demand steering: with equal priorities, queued recv-connector data
    # must steer the lane toward the demanded collective even when its
    # raw arrival was poisoned 6 strides past the bonus.
    st = rt.state._replace(
        arrival=rt.state.arrival.at[0, lo].set(6 << 20)
                                .at[0, hi].set(0),
        prio=rt.state.prio.at[0, hi].set(0),
        head_mirror=rt.state.head_mirror.at[0, lo].set(1))
    assert rank0_front(rebase_arrivals(st)) == lo

    # End-to-end: the poisoned runtime still drains once the peer submits.
    rt.submit(1, lo, prio=0, data=np.ones(32, np.float32))
    rt.submit(1, hi, prio=5, data=np.ones(32, np.float32))
    rt.drive()
    assert rt.queues.outstanding() == 0
    np.testing.assert_allclose(rt.read_output(0, lo), 2 * np.ones(32),
                               rtol=1e-5)


def test_budget_validation_rejects_key_overflow():
    with pytest.raises(AssertionError, match="superstep_budget"):
        OcclConfig(superstep_budget=1 << 18)


# ---------------------------------------------------------------------------
# burst-aware stall accounting + conn_depth guard
# ---------------------------------------------------------------------------

def _adversarial_contention(burst: int):
    """8 ranks, 8 all-reduces, one lane, pairwise-different orders — the
    EXACT workload builder the contention benchmark records, so this test
    guards the benchmarked regime (smaller slices for test speed)."""
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]
                           / "benchmarks"))
    from bench_collectives import build_contention_runtime
    rt = build_contention_runtime(burst, n=256, slice_elems=8)
    rt.drive(max_launches=128)
    return rt.stats()


def test_contention_burst8_beats_burst1():
    """The ROADMAP-measured gap: with superstep-counting spin, adversarial
    contention at B=8 ran at B=1 superstep parity.  Burst-aware stall
    accounting (spin += denied slices) must recover a real superstep win,
    and the stall counters must be observable."""
    s1 = _adversarial_contention(1)
    s8 = _adversarial_contention(8)
    assert int(s1["slices_moved"].sum()) == int(s8["slices_moved"].sum())
    assert int(s8["supersteps"].max()) < 0.7 * int(s1["supersteps"].max())
    assert int(s8["stall_slices"].sum()) > 0
    assert int(s8["preempts"].sum()) > 0
    assert s8["stall_slices"].shape == s8["preempts"].shape  # [R, C]


def test_stall_accounting_is_superstep_counting_at_burst1():
    """At B=1 a stalled superstep denies exactly one slice, so the stall
    counter equals what the seed's +1-per-superstep spin would have
    accumulated; sanity: stalls happen and stay per-collective."""
    st = _adversarial_contention(1)
    assert int(st["stall_slices"].sum()) > 0


def _solo_skewed(queue_conditional_stall: bool) -> tuple:
    """The solo-stall regime: rank 0 submits its all-reduce and launches
    BEFORE its ring peer arrives, so its only queued collective fully
    stalls on recv every superstep.  With unconditional denied-slice spin
    it reaches the threshold ~B× per launch and preempts a collective
    that has no competitor — pure churn (boost resets, preempt noise)."""
    import warnings as w
    cfg = OcclConfig(n_ranks=2, max_colls=2, max_comms=1, slice_elems=8,
                     conn_depth=16, burst_slices=8, heap_elems=1 << 13,
                     superstep_budget=1 << 15,
                     queue_conditional_stall=queue_conditional_stall)
    rt = OcclRuntime(cfg)
    comm = rt.communicator([0, 1])
    cid = rt.register(CollKind.ALL_REDUCE, comm, n_elems=512)
    rng = np.random.RandomState(3)
    xs = [rng.randn(512).astype(np.float32) for _ in range(2)]
    with w.catch_warnings():
        w.simplefilter("ignore", ConnDepthWarning)
        rt.submit(0, cid, data=xs[0])
        rt.launch_once()          # rank 0 alone until the voluntary quit
        rt.submit(1, cid, data=xs[1])
        rt.drive()
    for r in range(2):
        np.testing.assert_allclose(rt.read_output(r, cid), xs[0] + xs[1],
                                   rtol=1e-4, atol=1e-5)
    return rt.stats()


def test_solo_stall_weight_stops_preempt_churn():
    """Queue-length-conditional stall weight (ROADMAP follow-up): a
    burst-denied SOLO collective advances spin by 1 per stalled superstep
    (seed cadence) instead of by denied slices, so it no longer preempts
    B× too eagerly while blocked waiting for its peers.  The ablation
    switch restores the old eager behavior for comparison."""
    cond = _solo_skewed(queue_conditional_stall=True)
    eager = _solo_skewed(queue_conditional_stall=False)
    # Same work either way; solo preemption is a no-op for throughput...
    assert int(cond["slices_moved"].sum()) == int(eager["slices_moved"].sum())
    # ...but the eager accounting preempts a contender-less collective
    # many times over; patience must cut that churn by a lot.
    assert int(cond["preempts"].sum()) > 0      # still preemptible
    assert int(eager["preempts"].sum()) > 4 * int(cond["preempts"].sum())
    # Starvation stays observable either way: stall_slices records raw
    # denied slices independently of the spin weight.
    assert int(cond["stall_slices"].sum()) > 0
    assert int(cond["stall_slices"].sum()) == int(eager["stall_slices"].sum())


def test_contended_lanes_keep_burst_scaled_preemption():
    """The other regime: under adversarial contention every lane has
    queued contenders, so the conditional weight must leave the fast
    B-scaled preemption (and its superstep win over B=1) intact."""
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]
                           / "benchmarks"))
    from bench_collectives import build_contention_runtime

    def run(flag):
        rt = build_contention_runtime(8, n=256, slice_elems=8,
                                      queue_conditional_stall=flag)
        rt.drive(max_launches=128)
        return rt.stats()

    cond, eager = run(True), run(False)
    assert int(cond["slices_moved"].sum()) == int(eager["slices_moved"].sum())
    # Contended-phase behavior is identical; only the drain tail (queue
    # length 1) may differ slightly, so supersteps stay within a whisker.
    assert (int(cond["supersteps"].max())
            <= 1.15 * int(eager["supersteps"].max()))
    # And the PR-2 headline stands with the conditional weight on: B=8
    # still beats B=1 by a wide margin (test_contention_burst8_beats_burst1
    # covers the default path; this guards the explicit flag).
    rt1 = build_contention_runtime(1, n=256, slice_elems=8)
    rt1.drive(max_launches=128)
    assert (int(cond["supersteps"].max())
            < 0.7 * int(rt1.stats()["supersteps"].max()))


def test_conn_depth_guard_warns_and_auto_derives():
    cfg = OcclConfig(n_ranks=2, max_colls=2, max_comms=1, slice_elems=4,
                     conn_depth=4, burst_slices=8, heap_elems=512)
    rt = OcclRuntime(cfg)
    cm = rt.communicator([0, 1])
    rt.register(CollKind.ALL_REDUCE, cm, n_elems=8)
    with pytest.warns(ConnDepthWarning):
        rt._ensure_built()

    auto = OcclConfig(conn_depth=4, burst_slices=8, auto_conn_depth=True)
    assert auto.conn_depth == 24                 # max(conn_depth, 3B)
    deep = OcclConfig(conn_depth=32, burst_slices=8, auto_conn_depth=True)
    assert deep.conn_depth == 32                 # never shrinks

    rt2 = OcclRuntime(OcclConfig(n_ranks=2, max_colls=2, max_comms=1,
                                 slice_elems=4, conn_depth=4, burst_slices=8,
                                 auto_conn_depth=True, heap_elems=512))
    cm2 = rt2.communicator([0, 1])
    cid = rt2.register(CollKind.ALL_REDUCE, cm2, n_elems=8)
    with warnings.catch_warnings():
        warnings.simplefilter("error", ConnDepthWarning)
        for r in range(2):
            rt2.submit(r, cid, data=np.ones(8, np.float32))
        rt2.drive()                              # no warning: depth derived
    np.testing.assert_allclose(rt2.read_output(0, cid), 2 * np.ones(8),
                               rtol=1e-5)
