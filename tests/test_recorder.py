"""Flight recorder: event/counter reconciliation + hang diagnosis.

The wrap-proof per-kind counters (``fr_kinds``) must reconcile EXACTLY
with the scheduler's own counters on every collective kind — including
chained composites and the ragged all-to-all — and ``diagnose()`` must
name the correct wedged rank in scenarios ``run_static_order`` proves
statically deadlocked (bench_deadlock's adversarial-order setup).
"""
import numpy as np
import pytest

from repro.core import (CollKind, OcclConfig, OcclRuntime, ReduceOp,
                        run_static_order)
from repro.core.errors import DeadlockTimeout
from repro.core.recorder import (EV_CHAIN_HANDOFF, EV_CQE, EV_PREEMPT,
                                 EV_STAGE_DONE, EV_SUBMIT, events)


def _reconcile(rt):
    """Assert the recorder's per-kind cumulative counters against the
    scheduler counters, per rank (recorder.py module docstring)."""
    st = rt.state
    kinds = np.asarray(st.fr_kinds)                    # [R, NK]
    stage = np.asarray(st.stage_completions).sum(axis=1)
    comp = np.asarray(st.completed).sum(axis=1)
    pre = np.asarray(st.preempts).sum(axis=1)
    rtc = np.asarray(st.rtc_events).sum(axis=1)
    np.testing.assert_array_equal(kinds[:, EV_STAGE_DONE], stage)
    np.testing.assert_array_equal(kinds[:, EV_STAGE_DONE], rtc)
    np.testing.assert_array_equal(kinds[:, EV_CQE], comp)
    np.testing.assert_array_equal(
        kinds[:, EV_STAGE_DONE],
        kinds[:, EV_CHAIN_HANDOFF] + kinds[:, EV_CQE])
    np.testing.assert_array_equal(kinds[:, EV_PREEMPT], pre)
    # Ring totals match the counters: fr_count sums every kind.
    np.testing.assert_array_equal(np.asarray(st.fr_count),
                                  kinds.sum(axis=1))


def _cfg(R, **kw):
    kw.setdefault("max_colls", 12)
    kw.setdefault("max_comms", 4)
    kw.setdefault("slice_elems", 8)
    kw.setdefault("heap_elems", 1 << 13)
    return OcclConfig(n_ranks=R, **kw)


KINDS = [
    (CollKind.ALL_REDUCE, dict()),
    (CollKind.ALL_GATHER, dict()),
    (CollKind.REDUCE_SCATTER, dict()),
    (CollKind.BROADCAST, dict(root=1)),
    (CollKind.REDUCE, dict(root=2, op=ReduceOp.MAX)),
    (CollKind.ALL_TO_ALL, dict()),
]


@pytest.mark.parametrize("kind,extra",
                         KINDS, ids=[k.name for k, _ in KINDS])
def test_counts_reconcile_every_kind(kind, extra):
    R = 4
    rt = OcclRuntime(_cfg(R))
    h = rt.register(kind, rt.communicator(range(R)), n_elems=32, **extra)
    # ALL_GATHER's logical input is the per-rank contribution (one chunk).
    n_in = 32 // R if kind is CollKind.ALL_GATHER else 32
    rng = np.random.RandomState(0)
    for _ in range(3):
        for r in range(R):
            h.submit(r, data=rng.rand(n_in).astype(np.float32))
        rt.drive()
    _reconcile(rt)
    rec = rt.stats()["flight_recorder"]
    assert rec["enabled"]
    # Every rank saw 3 SUBMIT fetches and 3 CQEs for the one collective.
    np.testing.assert_array_equal(rec["kind_counts"][:, EV_SUBMIT], 3)
    np.testing.assert_array_equal(rec["kind_counts"][:, EV_CQE], 3)


def test_ragged_alltoall_reconciles():
    R = 4
    rt = OcclRuntime(_cfg(R))
    sizes = (3, 0, 2, 1)
    h = rt.register(CollKind.ALL_TO_ALL_RAGGED, rt.communicator(range(R)),
                    n_elems=16, chunk_sizes=sizes)
    n = sum(sizes)
    for r in range(R):
        h.submit(r, data=np.arange(n, dtype=np.float32) + 10 * r)
    rt.drive()
    _reconcile(rt)


def test_composite_chain_events():
    """Two-level chain: intermediates emit CHAIN_HANDOFF, the tail CQE;
    the per-kind identity STAGE_DONE == HANDOFF + CQE pins the split."""
    R = 8
    rt = OcclRuntime(_cfg(R))
    h = rt.register(CollKind.ALL_REDUCE,
                    rt.logical_communicator(range(R)),
                    n_elems=64, algo="two_level", hierarchy=(2, 4))
    for r in range(R):
        h.submit(r, data=np.full(64, float(r), np.float32))
    rt.drive()
    _reconcile(rt)
    rec = rt.export_flight_record()
    # 3-stage chain, every rank in every stage: 2 handoffs + 1 CQE each.
    np.testing.assert_array_equal(rec["kind_counts"][:, EV_CHAIN_HANDOFF],
                                  2)
    np.testing.assert_array_equal(rec["kind_counts"][:, EV_CQE], 1)
    # The decoded per-rank streams are clock-ordered and end at the tail.
    for r in range(R):
        evs = events(rec, rank=r)
        assert [e.step for e in evs] == sorted(e.step for e in evs)
        assert evs[-1].kind == EV_CQE


def test_ring_wrap_keeps_counters_exact():
    """A recorder ring far smaller than the event stream: the ring keeps
    only the newest events but the per-kind counters stay exact."""
    R = 4
    rt = OcclRuntime(_cfg(R, recorder_len=8))
    h = rt.register(CollKind.ALL_REDUCE, rt.communicator(range(R)),
                    n_elems=32)
    iters = 10
    for _ in range(iters):
        for r in range(R):
            h.submit(r, data=np.ones(32, np.float32))
        rt.drive()
    _reconcile(rt)
    rec = rt.export_flight_record()
    assert int(rec["count"][0]) > 8          # the ring wrapped
    assert len(events(rec, rank=0)) == 8     # only the newest 8 retained
    np.testing.assert_array_equal(rec["kind_counts"][:, EV_CQE], iters)


def test_tiny_ring_batched_overflow_stays_deterministic():
    """One superstep can emit more valid events than the ring has slots
    (two collectives completing together: 2 STAGE_DONE + 2 CQE in one
    batched scatter vs recorder_len=2).  The scheduler pre-drops the
    oldest events of the batch, so slots never collide within a scatter:
    identical runs leave bit-identical rings and the wrap-proof counters
    stay exact."""
    def run():
        R = 2
        rt = OcclRuntime(_cfg(R, max_comms=2, recorder_len=2))
        ha = rt.register(CollKind.ALL_REDUCE, rt.communicator(range(R)),
                         n_elems=16)
        hb = rt.register(CollKind.ALL_REDUCE, rt.communicator(range(R)),
                         n_elems=16)
        for r in range(R):
            ha.submit(r, data=np.ones(16, np.float32))
            hb.submit(r, data=np.full(16, 2.0, np.float32))
        rt.drive()
        _reconcile(rt)
        return rt.export_flight_record()
    a, b = run(), run()
    for key in ("kind", "coll", "step", "count", "kind_counts"):
        np.testing.assert_array_equal(a[key], b[key])
    for r in range(2):
        evs = events(a, rank=r)
        assert len(evs) == 2                 # newest 2 retained
        assert all(e.kind >= 0 for e in evs)  # real events, no stale -1


def test_recorder_disabled_records_nothing():
    R = 4
    rt = OcclRuntime(_cfg(R, flight_recorder=False))
    h = rt.register(CollKind.ALL_REDUCE, rt.communicator(range(R)),
                    n_elems=32)
    for r in range(R):
        h.submit(r, data=np.ones(32, np.float32))
    rt.drive()
    rec = rt.stats()["flight_recorder"]
    assert not rec["enabled"]
    np.testing.assert_array_equal(rec["count"], 0)
    np.testing.assert_array_equal(rec["kind_counts"], 0)
    assert events(rec) == []


def test_diagnose_names_withheld_rank():
    """bench_deadlock's adversarial setup: conflicting static orders that
    run_static_order proves wedge a single-queue library.  OCCL completes
    them — until rank 2 withholds one collective entirely; the diagnosis
    must name exactly that rank and collective."""
    R, C = 4, 4
    rng = np.random.RandomState(0)
    orders = {r: list(rng.permutation(C)) for r in range(R)}
    static = run_static_order(orders, {c: list(range(R)) for c in range(C)})
    assert static.deadlocked      # proven static deadlock scenario
    rt = OcclRuntime(_cfg(R))
    comm = rt.communicator(range(R))
    hs = [rt.register(CollKind.ALL_REDUCE, comm, n_elems=16)
          for _ in range(C)]
    withheld = 2                  # collective rank 2 never submits
    for r in range(R):
        for c in orders[r]:
            if r == 2 and c == withheld:
                continue
            hs[c].submit(r, data=np.full(16, float(r), np.float32))
    with pytest.raises(DeadlockTimeout) as ei:
        rt.drive(max_launches=4)
    e = ei.value
    assert e.flight_record is not None and e.flight_record["enabled"]
    diag = e.diagnosis
    assert diag is not None
    stalled_ids = {s.coll_id for s in diag.stalled}
    assert int(hs[withheld]) in stalled_ids
    blocked = {s.coll_id: s for s in diag.stalled}[int(hs[withheld])]
    assert blocked.holding_ranks == [2]
    assert "never submitted" in blocked.reason
    assert 2 in diag.holders
    assert str(diag)              # human-readable rendering exists


def test_diagnose_attaches_to_timeout_message():
    R = 4
    rt = OcclRuntime(_cfg(R))
    h = rt.register(CollKind.ALL_REDUCE, rt.communicator(range(R)),
                    n_elems=16)
    for r in range(R - 1):        # rank 3 never submits
        h.submit(r, data=np.ones(16, np.float32))
    with pytest.raises(DeadlockTimeout) as ei:
        rt.drive(max_launches=3)
    assert "held by rank(s) 3" in str(ei.value)
