"""Unified error taxonomy for the OCCL reproduction.

Every failure the runtime or the fault-tolerance layer can surface lives
here so callers catch one module's names regardless of which layer threw:

- :class:`RegistrationClosed` — topology mutation after the first build.
- :class:`DeadlockTimeout` — the daemon relaunched repeatedly with zero
  progress; carries the flight-recorder export and host diagnosis so the
  failure names its holder (see ``core/recorder.py``).
- :class:`EvictionError` — ``runtime.evict(rank)`` could not rebuild a
  registration for the shrunk communicator (e.g. ragged all-to-all
  ``chunk_sizes`` no longer match the group size).
- :class:`ConnDepthWarning` — connector rings too shallow for the
  registered burst width (progress still guaranteed, just slower).
- :class:`StepTimeout` — the fabric-level training watchdog fired.

``repro.core.runtime`` and ``repro.fabric.ft`` re-export their historic
names from here, so pre-existing ``from repro.core.runtime import
DeadlockTimeout`` imports keep working.
"""
from __future__ import annotations


class RegistrationClosed(RuntimeError):
    """Raised when communicators/collectives are added after first launch."""


class DeadlockTimeout(RuntimeError):
    """The daemon made no forward progress across repeated relaunches.

    Attributes
    ----------
    flight_record : dict | None
        The on-device flight-recorder export (``runtime.stats()
        ["flight_recorder"]`` schema) captured at timeout.
    diagnosis : repro.core.recorder.Diagnosis | None
        Host-side analysis naming the rank + collective holding each
        stalled chain.
    """

    def __init__(self, message: str, flight_record=None, diagnosis=None):
        super().__init__(message)
        self.flight_record = flight_record
        self.diagnosis = diagnosis


class EvictionError(RuntimeError):
    """``evict(rank)`` could not rebuild a registration at R-1."""


class ConnDepthWarning(UserWarning):
    """conn_depth is too shallow for the configured burst width."""


class StepTimeout(RuntimeError):
    """A training step exceeded the fault-tolerance watchdog deadline."""
