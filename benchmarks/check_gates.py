"""CI perf gates over BENCH_collectives.json (called from ci.yml).

Replaces the inline workflow heredoc with a versioned, testable script.
Gates (thresholds deliberately looser than local best-of-N numbers —
shared CI runners are noisy; the gate catches REGRESSIONS, not jitter):

* **staging** — the device-resident staging engine must stay >= 3x the
  pre-PR bulk path (local best-of-N shows >= 5x; see ROADMAP "Device-
  resident staging").
* **contention** — burst-aware stall accounting must keep the adversarial
  8x8 all-reduce at B=8 at no more than 0.5x the supersteps of B=1 (the
  PR-2 record shows ~3x fewer; parity was the pre-PR failure mode).
* **mesh pack** — packed 16-bit heaps must ride exactly 2 ppermutes per
  ``_mesh_exchange`` superstep, same as 32-bit (3 means the packing
  regressed to the separate header/payload exchange).
* **hierarchy** — the composite two-level all-reduce at R=16 must
  complete in FEWER supersteps than the flat ring (the chain's latency
  term is N + (2G - 1) + N = 15 steps vs the ring's 2R - 1 = 31; parity
  or worse means the device-side chain advance regressed to host round
  trips or the stages stopped overlapping their slice bursts).  Under
  the bandwidth-skew lane model the two-level chain must also win on
  WALL-CLOCK (its bulk stages ride intra lanes at the full burst while
  the flat ring pays the inter cap every hop).
* **alltoall** — the flat relay-ring all-to-all at R=16 pays its
  O(R^2) program (136 steps, relay hops included) while the two-level
  composite runs two short exchanges (20 steps at (4, 4)), so the chain
  must complete in strictly FEWER supersteps; the calibrated model's
  ``auto`` pick must land on the measured wall-clock winner (within the
  same 1.15x near-tie tolerance as the algos gate); and the adversarial
  a2a x all-reduce contention scenario must record a PROVEN static
  deadlock that OCCL drained.
* **algos** — the algorithm zoo at R=16 under bandwidth skew: at the
  large payload at least one NEW chained plan (torus/hybrid/two_level)
  must beat the flat ring on wall-clock, and the calibrated cost model's
  ``auto`` picks (benchmarks/calibrate.py) must land on the measured
  winner's side of the crossover at BOTH payload sizes — small stays
  single-stage-cheap (flat ring family), large goes hierarchical —
  enforced only when the measured winner itself sits in that family
  (runner noise can hand the chain an outright small-payload win, and
  following the measurement is not a regression), and each pick's
  measured wall must be within 1.15x of the measured best (the model
  may break near-ties either way; picking a genuinely slow algorithm
  is the regression).

* **training** — the tick-contract overlap record
  (bench_training.run_training_bench): the overlapped dense grad-sync
  step must beat the barrier-mode step on modeled step time under the
  bandwidth-skew lane model — equivalently, it must EXPOSE strictly
  fewer supersteps (hidden supersteps ride behind backward compute);
  and the MoE stream-sharded path must put strictly fewer supersteps on
  the per-layer critical path than the full-barrier forward.  Exposed
  counts are structural (deterministic per config), so these gates are
  noise-immune.

* **reliability** — elastic shrink + flight recorder: the R=8
  kill-one-rank eviction must complete the survivors' grad-sync round in
  NO MORE supersteps than a fresh R-1 runtime driving the identical
  workload, bit-identically (the replay is the same schedule — more
  supersteps means the rebuild is leaking work, and any float diff means
  the replay changed the op order); and the always-on flight recorder
  must cost <= 5% supersteps/sec on the burst-sweep workload (best-of-N
  on both sides — the recorder is a handful of in-jit scatters per
  superstep, an order of magnitude under the gate).

* **serving** — the QoS traffic replay (bench_serving.py): with mixed
  tenants sharing one lane under adversarial background bursts,
  priority preemption must yield STRICTLY lower p99 decode latency
  (supersteps — structural, deterministic per seed) than the
  no-preemption FIFO baseline, the preempt counter must actually
  advance (a "win" with zero preemptions means the contention
  disappeared and the scenario stopped testing anything), and the
  background tenant must degrade gracefully rather than starve: every
  admitted burst drains once arrivals stop, and its contention-window-
  normalized throughput stays >= 0.15x the baseline's.

A missing or partial record FAILS (validate_record): a stale
BENCH_collectives.json silently skipping a gate was the failure mode
that motivated this script.

Usage: ``python benchmarks/check_gates.py [path/to/BENCH_collectives.json]``
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))


def check(doc: dict) -> list[str]:
    """Returns a list of human-readable gate failures (empty == pass)."""
    failures = []

    s = doc["staging"]
    speedup = s["speedup_vs_legacy"]
    print(f"staging speedup vs legacy bulk: {speedup:.1f}x "
          f"(vs scalar: {s['speedup_vs_legacy_scalar']:.0f}x)")
    if speedup < 3.0:
        failures.append(
            f"staging engine regressed: {speedup:.2f}x vs legacy bulk "
            "(gate: >= 3x)")

    c = doc["contention"]["bursts"]
    if "1" not in c or "8" not in c:
        failures.append(
            f"contention sweep lacks bursts 1 and 8 (got {sorted(c)}) — "
            "rerun benchmarks/run.py")
    else:
        b1, b8 = c["1"]["supersteps"], c["8"]["supersteps"]
        ratio = b8 / max(b1, 1)
        print(f"contention supersteps: B=1 {b1}, B=8 {b8} "
              f"(ratio {ratio:.2f})")
        if ratio > 0.5:
            failures.append(
                f"burst-aware stall accounting regressed: B=8 ran "
                f"{ratio:.2f}x the supersteps of B=1 (gate: <= 0.5x)")

    pp = doc["mesh"]["ppermutes_per_superstep"]
    print(f"mesh ppermutes/superstep: {pp}")
    for key in ("float32", "bfloat16_packed", "float16_packed"):
        if pp.get(key) != 2:
            failures.append(
                f"mesh exchange {key} pays {pp.get(key)} ppermutes per "
                "superstep (gate: exactly 2 — packed 16-bit must match "
                "32-bit)")
    if pp.get("bfloat16_unpacked") != 3:
        failures.append(
            "unpacked-bf16 baseline no longer pays 3 ppermutes "
            f"(got {pp.get('bfloat16_unpacked')}) — the escape-hatch "
            "baseline the packed path is measured against has drifted")

    h = doc["hierarchy"]
    flat_steps = h["flat"]["supersteps"]
    two_steps = h["two_level"]["supersteps"]
    print(f"hierarchy supersteps at R={h['config']['n_ranks']}: "
          f"flat {flat_steps:.0f}, two_level {two_steps:.0f} "
          f"(ratio {h['superstep_ratio']:.2f})")
    if not two_steps < flat_steps:
        failures.append(
            f"two-level all-reduce regressed: {two_steps:.0f} supersteps "
            f"vs flat ring's {flat_steps:.0f} (gate: strictly fewer)")
    sk = h["skew"]
    print(f"hierarchy skew wall: flat {sk['flat']['latency_s']*1e3:.1f}ms, "
          f"two_level {sk['two_level']['latency_s']*1e3:.1f}ms "
          f"(ratio {sk['wall_ratio']:.2f})")
    if not sk["two_level"]["latency_s"] < sk["flat"]["latency_s"]:
        failures.append(
            "two-level all-reduce lost its WALL-CLOCK win under bandwidth "
            f"skew: {sk['two_level']['latency_s']*1e3:.1f}ms vs flat "
            f"{sk['flat']['latency_s']*1e3:.1f}ms (gate: strictly faster)")

    at = doc["alltoall"]
    a2a_flat = at["flat"]["supersteps"]
    a2a_two = at["two_level"]["supersteps"]
    print(f"alltoall supersteps at R={at['config']['n_ranks']}: "
          f"flat {a2a_flat:.0f}, two_level {a2a_two:.0f} "
          f"(ratio {at['superstep_ratio']:.2f})")
    if not a2a_two < a2a_flat:
        failures.append(
            f"two-level all-to-all regressed: {a2a_two:.0f} supersteps "
            f"vs flat relay ring's {a2a_flat:.0f} (gate: strictly fewer)")
    ap = at["auto"]
    print(f"auto[alltoall]: pick {ap['pick']} "
          f"(measured best {ap['best_algo']})")
    if (ap.get("pick_wall_s") is not None
            and ap["pick_wall_s"] > 1.15 * ap["best_wall_s"]):
        failures.append(
            f"auto pick for alltoall ({ap['pick']}) measured "
            f"{ap['pick_wall_s']*1e3:.1f}ms, >1.15x the best "
            f"({ap['best_algo']} {ap['best_wall_s']*1e3:.1f}ms)")
    cont = at["contention"]
    print(f"alltoall contention: static_deadlocks="
          f"{cont['static_deadlocks']}, "
          f"supersteps {cont['supersteps']:.0f}")
    if not cont["static_deadlocks"]:
        failures.append(
            "adversarial a2a x all-reduce orders no longer wedge the "
            "static baseline — the contention scenario stopped being "
            "adversarial (check the order generation)")

    a = doc["algos"]
    large = a["sweep"]["all_reduce"]["large"]
    flat_wall = large["ring"]["latency_s"]
    new_walls = {algo: rec["latency_s"] for algo, rec in large.items()
                 if algo not in ("ring", "n_elems")
                 and isinstance(rec, dict)}
    best_new = min(new_walls, key=new_walls.get)
    print(f"algos large all-reduce wall: ring {flat_wall*1e3:.1f}ms, "
          + ", ".join(f"{k} {v*1e3:.1f}ms" for k, v in new_walls.items()))
    if not new_walls[best_new] < flat_wall:
        failures.append(
            "no chained all-reduce plan beats the flat ring on wall-clock "
            f"at the large payload (best: {best_new} "
            f"{new_walls[best_new]*1e3:.1f}ms vs ring {flat_wall*1e3:.1f}ms)")
    # Auto picks: the calibrated model must land on the measured winner's
    # SIDE of the all-reduce crossover, and never pick something
    # measurably slow.  The crossover families apply to ALL-REDUCE only:
    # a hierarchical broadcast ships the full payload over the capped
    # leader lanes, so the flat ring legitimately stays the measured
    # winner at every size there — for broadcast the wall-tolerance
    # check below is the whole gate.
    AR_SMALL_FAMILY = {"ring"}                   # single-stage plans
    AR_LARGE_FAMILY = {"two_level", "torus", "hybrid"}
    picks = a["auto"]["picks"]
    for label, sizes in picks.items():
        for size_label, p in sizes.items():
            print(f"auto[{label}/{size_label}]: pick {p['pick']} "
                  f"(measured best {p['best_algo']})")
            if label == "all_reduce":
                family = (AR_SMALL_FAMILY if size_label == "small"
                          else AR_LARGE_FAMILY)
                # Enforce the family only when the MEASUREMENT agrees
                # with it: on a noisy runner the chain can win outright
                # even at the small payload (dispatch overhead dwarfs
                # the per-stage term), and a model that follows the
                # measured winner is correct, not regressed — the wall
                # tolerance below still catches measurably slow picks.
                if p["pick"] not in family and p["best_algo"] in family:
                    failures.append(
                        f"auto pick for {label}/{size_label} is "
                        f"{p['pick']!r} — outside the expected "
                        f"{sorted(family)} family even though the "
                        f"measured winner ({p['best_algo']}) is in it")
            if (p.get("pick_wall_s") is not None
                    and p["pick_wall_s"] > 1.15 * p["best_wall_s"]):
                failures.append(
                    f"auto pick for {label}/{size_label} ({p['pick']}) "
                    f"measured {p['pick_wall_s']*1e3:.1f}ms, "
                    f">1.15x the best ({p['best_algo']} "
                    f"{p['best_wall_s']*1e3:.1f}ms) — the calibrated "
                    "model is selecting a measurably slow algorithm")

    tr = doc["training"]
    for label, unit in (("dense", "grad-sync"), ("moe", "MoE")):
        rec = tr[label]
        bar, ovl = rec["barrier"], rec["overlap"]
        print(f"training {label}: exposed supersteps barrier "
              f"{bar['exposed_supersteps']}, overlap "
              f"{ovl['exposed_supersteps']} (hidden "
              f"{ovl['hidden_supersteps']}); modeled tokens/s "
              f"{bar['tokens_per_s_modeled']:.1f} -> "
              f"{ovl['tokens_per_s_modeled']:.1f} "
              f"({rec['modeled_speedup']:.2f}x)")
        if not ovl["exposed_supersteps"] < bar["exposed_supersteps"]:
            failures.append(
                f"{unit} overlap no longer shortens the critical path: "
                f"{ovl['exposed_supersteps']} exposed supersteps vs "
                f"barrier-mode {bar['exposed_supersteps']} (gate: "
                "strictly fewer)")
        if not (ovl["tokens_per_s_modeled"]
                > bar["tokens_per_s_modeled"]):
            failures.append(
                f"{unit} overlapped step is not faster than barrier "
                f"mode under the lane model: "
                f"{ovl['tokens_per_s_modeled']:.1f} vs "
                f"{bar['tokens_per_s_modeled']:.1f} modeled tokens/s")
    if not tr["moe"].get("bitwise_vs_barrier", False):
        failures.append(
            "MoE overlapped forward diverged from the barrier forward "
            "(transport must be bit-exact — a routing bug, not numerics)")

    rel = doc["reliability"]
    ev = rel["evict"]
    print(f"reliability evict R={ev['config']['n_ranks']}->"
          f"{ev['config']['n_ranks'] - 1}: supersteps evicted "
          f"{ev['evicted_supersteps']} vs fresh {ev['fresh_supersteps']}; "
          f"bit_equal={ev['bit_equal']} (replayed {ev['replayed']}, "
          f"dropped {ev['dropped']})")
    if ev["evicted_supersteps"] > ev["fresh_supersteps"]:
        failures.append(
            f"eviction replay is leaking work: {ev['evicted_supersteps']} "
            f"supersteps to finish the survivors' round vs a fresh "
            f"R-1 runtime's {ev['fresh_supersteps']} (gate: no more)")
    if not ev["bit_equal"]:
        failures.append(
            "post-evict grad-sync outputs diverged from a fresh R-1 "
            "runtime (the replayed schedule must be bit-identical)")
    fr = rel["recorder"]
    print(f"reliability recorder overhead: "
          f"{fr['supersteps_per_sec_off']:.0f} -> "
          f"{fr['supersteps_per_sec_on']:.0f} supersteps/s "
          f"({fr['overhead_frac'] * 100:.1f}%)")
    if fr["overhead_frac"] > 0.05:
        failures.append(
            f"flight recorder costs {fr['overhead_frac'] * 100:.1f}% "
            "supersteps/sec on the burst sweep (gate: <= 5%)")

    sv = doc["serving"]
    on, off = sv["preempt_on"], sv["preempt_off"]
    print(f"serving decode p99 (supersteps): preempt on "
          f"{on['decode']['p99']:.0f}, off {off['decode']['p99']:.0f} "
          f"(ratio {sv['p99_ratio']:.2f}, preempts {on['preempts']}); "
          f"background/kstep on {on['background_per_kstep']:.2f}, "
          f"off {off['background_per_kstep']:.2f} "
          f"(ratio {sv['background_ratio']:.2f})")
    if not on["decode"]["p99"] < off["decode"]["p99"]:
        failures.append(
            f"QoS preemption no longer improves decode p99: "
            f"{on['decode']['p99']:.0f} supersteps with preemption vs "
            f"{off['decode']['p99']:.0f} without (gate: strictly lower)")
    if not on["preempts"] > 0:
        failures.append(
            "serving replay recorded zero preemptions with preemption on "
            "— the adversarial background load stopped contending and the "
            "p99 comparison is vacuous")
    for label, rec in (("on", on), ("off", off)):
        if not rec["background_drained"]:
            failures.append(
                f"background tenant failed to drain after arrivals "
                f"stopped (preemption {label}): "
                f"{rec['background']['completed']}/"
                f"{rec['background_admitted']} bursts completed — "
                "bounded starvation is violated")
    if sv["background_ratio"] < 0.15:
        failures.append(
            f"background tenant is starved under preemption: "
            f"{sv['background_ratio']:.2f}x the no-preemption throughput "
            "per busy superstep (gate: >= 0.15x — degrade gracefully, "
            "don't starve)")
    return failures


def main(argv: list[str]) -> int:
    import bench_collectives

    path = (pathlib.Path(argv[1]) if len(argv) > 1
            else bench_collectives.BENCH_JSON)
    doc = bench_collectives.validate_record(
        required=("staging", "contention", "mesh", "hierarchy", "algos",
                  "alltoall", "training", "reliability", "serving"),
        out_path=path)
    failures = check(doc)
    for f in failures:
        print(f"GATE FAILED: {f}", file=sys.stderr)
    if not failures:
        print("all perf gates passed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
