"""Serving QoS: traffic-class tenants sharing one OCCL fabric.

The paper's bottom-level preemption exists in this repo as a deadlock-
prevention tool; this module turns it into a tail-latency optimization.
Three traffic classes map onto the scheduler's priority strides
(config.QUEUE_KEY_PRIO_STRIDE), separated by one CLASS_STRIDE each so
intra-class offsets can never bleed across classes:

* ``DECODE``   — the per-decode-step tensor-parallel all-reduce.  The
  latency-critical op: every generated token blocks on it.
* ``PREFILL``  — the prompt-ingest all-gather (larger, less critical).
* ``BACKGROUND`` — grad-sync buckets and checkpoint broadcasts: big
  throughput bursts that must not sit in front of a decode step.

With ``preemption=True`` the fabric runs ``OrderPolicy.PRIORITY`` +
``priority_preempts``: a decode submit landing mid-background-burst
preempts the in-flight bucket at slice granularity (the paper's
mechanism) instead of waiting out the whole transfer.  With
``preemption=False`` the same traffic runs FIFO at equal priority — the
no-QoS baseline the serving bench compares against.

Starvation bound: ``prio_aging_quantum`` (core/config.py) gives every
queued collective ``min(age // quantum, cap)`` extra effective priority
on the launch clock.  The cap defaults to one class stride, so an aged
BACKGROUND bucket overtakes queued PREFILLs after a bounded wait but
never outranks DECODE; DECODE itself is open-loop (arrival gaps), so
background drains in the gaps — ``drain()`` proves it after every
replay.

The fabric is driven by bounded DeviceApi ticks (``advance``): staged
submits are flushed and packed, the daemon auto-relaunches when it went
not-live with work pending, and completion callbacks stamp the replay
clock — latency is measured in SUPERSTEPS (structural, noise-immune),
with wall-clock modeled from a measured superstep cost.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.config import OcclConfig, OrderPolicy
from ..core.primitives import CollKind
from ..core.recorder import diagnose
from ..core.runtime import OcclRuntime


class TrafficClass(enum.IntEnum):
    """Serving traffic classes, low to high priority."""

    BACKGROUND = 0
    PREFILL = 1
    DECODE = 2


# Priority distance between adjacent classes.  Base priorities are
# ``cls * CLASS_STRIDE``; per-submit offsets live inside [0, CLASS_STRIDE)
# so classes cannot bleed into each other, and the default aging cap
# (2 * CLASS_STRIDE - 1 = 255) lets a starved tenant age past exactly
# ONE class boundary: BACKGROUND (base 0) tops out at 255, under
# DECODE's 256.  Worst-case effective priority stays inside the
# scheduler's +/-512 clip band (the clip re-asserts it regardless).
CLASS_STRIDE = 128
AGING_CAP = 2 * CLASS_STRIDE - 1


def class_prio(cls: TrafficClass, offset: int = 0) -> int:
    """Scheduler priority for a traffic class (+ bounded intra-class
    offset)."""
    if not 0 <= offset < CLASS_STRIDE:
        raise ValueError(
            f"intra-class offset {offset} outside [0, {CLASS_STRIDE})")
    return int(cls) * CLASS_STRIDE + offset


@dataclasses.dataclass
class TenantStats:
    """Per-class submit/complete accounting + latency samples."""

    submitted: int = 0
    completed: int = 0
    latencies: list = dataclasses.field(default_factory=list)  # supersteps

    def percentile(self, q: float) -> float:
        if not self.latencies:
            return float("nan")
        return float(np.percentile(np.asarray(self.latencies, float), q))


class ServingQos:
    """One shared fabric, three tenants, per-class submit wrappers.

    All tenants register on a SINGLE communicator lane — contention is
    the point: preemption only matters when decode and background fight
    over the same connector.  ``preemption`` toggles the whole QoS
    mechanism (PRIORITY + priority_preempts + aging vs flat FIFO) so a
    bench can compare the two regimes on identical traffic.
    """

    def __init__(self, n_ranks: int = 4, *, decode_elems: int = 256,
                 prefill_elems: int = 1024, background_elems: int = 4096,
                 background_buckets: int = 2, ckpt_elems: int = 512,
                 preemption: bool = True, max_background_inflight: int = 2,
                 prio_aging_quantum: int = 0,
                 prio_aging_cap: int = AGING_CAP,
                 tick_chunk: int = 1, slice_elems: int = 64,
                 conn_depth: int = 4, burst_slices: int = 1,
                 quit_threshold: int = 64, superstep_budget: int = 4096,
                 heap_elems: int = 1 << 17, flight_recorder: bool = True):
        self.preemption = bool(preemption)
        self.tick_chunk = int(tick_chunk)
        self.max_background_inflight = int(max_background_inflight)
        self.cfg = OcclConfig(
            n_ranks=n_ranks, max_colls=max(8, background_buckets + 6),
            max_comms=1, slice_elems=slice_elems, conn_depth=conn_depth,
            burst_slices=burst_slices, heap_elems=heap_elems,
            order_policy=(OrderPolicy.PRIORITY if self.preemption
                          else OrderPolicy.FIFO),
            priority_preempts=self.preemption,
            prio_aging_quantum=(prio_aging_quantum if self.preemption
                                else 0),
            prio_aging_cap=prio_aging_cap,
            quit_threshold=quit_threshold,
            superstep_budget=superstep_budget,
            flight_recorder=flight_recorder)
        self.runtime = OcclRuntime(self.cfg)
        comm = self.runtime.communicator(list(range(n_ranks)))
        self.decode = self.runtime.register(
            CollKind.ALL_REDUCE, comm, n_elems=decode_elems)
        self.prefill = self.runtime.register(
            CollKind.ALL_GATHER, comm, n_elems=prefill_elems)
        self.background = [
            self.runtime.register(CollKind.ALL_REDUCE, comm,
                                  n_elems=background_elems)
            for _ in range(background_buckets)]
        self.ckpt = self.runtime.register(
            CollKind.BROADCAST, comm, n_elems=ckpt_elems)
        self._class_of = {int(self.decode): TrafficClass.DECODE,
                          int(self.prefill): TrafficClass.PREFILL,
                          int(self.ckpt): TrafficClass.BACKGROUND}
        for h in self.background:
            self._class_of[int(h)] = TrafficClass.BACKGROUND
        self.tenants = {cls: TenantStats() for cls in TrafficClass}
        self._inflight = {cls: 0 for cls in TrafficClass}
        self._bg_rr = 0
        self.now = 0                    # replay superstep clock
        self._tick = None               # lazily jitted DeviceApi tick

    # ------------------------------------------------------------------
    # fabric driving (bounded DeviceApi ticks)
    # ------------------------------------------------------------------
    def _ensure_tick(self):
        if self._tick is None:
            api = self.runtime.device_api()
            self._tick = jax.jit(
                lambda st, k: api.tick(st, k, barrier=True)[0])

    def advance(self, k: Optional[int] = None) -> None:
        """Advance the shared fabric (and the replay clock) by ``k``
        supersteps.  An idle fabric fast-forwards the clock without
        ticking — open-loop arrival gaps cost no device work."""
        k = self.tick_chunk if k is None else int(k)
        rt = self.runtime
        self._ensure_tick()
        if rt.queues.outstanding() == 0:
            self.now += k
            return
        rt._flush_staged()
        st = rt.queues.pack_sq(rt._state)
        st = self._tick(st, jnp.int32(k))
        rt._state = jax.block_until_ready(st)
        rt.queues.reconcile(st)
        self.now += k

    def drain(self, patience: int = 2048) -> int:
        """Advance until every outstanding submission completed; returns
        the supersteps spent.  ``patience`` bounds consecutive no-
        completion advances so a wedged tenant raises the enriched
        DeadlockTimeout (flight record + diagnosis) instead of hanging."""
        rt = self.runtime
        start, idle = self.now, 0
        while rt.queues.outstanding():
            before = int(rt.queues.completed.sum())
            self.advance()
            idle = idle + 1 if int(rt.queues.completed.sum()) == before \
                else 0
            if idle >= patience:
                raise rt._deadlock_error(
                    f"{rt.queues.outstanding()} serving submissions "
                    f"outstanding after {idle} advances without a "
                    "completion — a tenant is wedged")
        return self.now - start

    # ------------------------------------------------------------------
    # per-class submit wrappers
    # ------------------------------------------------------------------
    def _submit(self, cls: TrafficClass, handle, data=None,
                offset: int = 0) -> dict:
        """Submit one collective on all ranks under its class priority;
        returns a pending record whose ``done_at`` is stamped (replay
        clock) when the LAST rank's CQE reconciles."""
        prio = class_prio(cls, offset) if self.preemption else 0
        rec = {"class": cls, "cid": int(handle), "arrival": self.now,
               "done_at": None}
        stats = self.tenants[cls]
        stats.submitted += 1
        self._inflight[cls] += 1
        remaining = [self.cfg.n_ranks]

        def _cb(rank, cid, _rec=rec, _stats=stats, _left=remaining,
                _cls=cls):
            _left[0] -= 1
            if _left[0] == 0:
                _rec["done_at"] = self.now
                _stats.completed += 1
                _stats.latencies.append(self.now - _rec["arrival"])
                self._inflight[_cls] -= 1

        self.runtime.submit_all(handle, prio=prio, data=data, callback=_cb)
        return rec

    def submit_decode(self, data=None) -> dict:
        return self._submit(TrafficClass.DECODE, self.decode, data=data)

    def submit_prefill(self, data=None) -> dict:
        return self._submit(TrafficClass.PREFILL, self.prefill, data=data)

    def admit_background(self) -> bool:
        """Preemption-aware admission: background joins the lane only
        while its inflight bursts sit under the cap — the cheap first
        line of defense before preemption has to cut a transfer."""
        return self._inflight[TrafficClass.BACKGROUND] \
            < self.max_background_inflight

    def submit_background(self) -> Optional[dict]:
        """Admission-gated round-robin grad-sync bucket submit; None
        when the inflight cap holds the burst back."""
        if not self.admit_background():
            return None
        h = self.background[self._bg_rr % len(self.background)]
        self._bg_rr += 1
        return self._submit(TrafficClass.BACKGROUND, h)

    def submit_checkpoint(self) -> Optional[dict]:
        if not self.admit_background():
            return None
        return self._submit(TrafficClass.BACKGROUND, self.ckpt)

    def pump_background(self) -> int:
        """Adversarial background tenant: refill grad-sync bursts up to
        the admission cap.  Returns how many were admitted."""
        n = 0
        while self.submit_background() is not None:
            n += 1
        return n

    def wait(self, rec: dict, max_supersteps: int = 1 << 16) -> int:
        """Advance until ``rec`` completes; returns its latency in
        supersteps (replay clock)."""
        start = self.now
        while rec["done_at"] is None:
            if self.now - start > max_supersteps:
                raise self.runtime._deadlock_error(
                    f"{rec['class'].name} submission incomplete after "
                    f"{self.now - start} supersteps")
            self.advance()
        return rec["done_at"] - rec["arrival"]

    # ------------------------------------------------------------------
    # event hooks for ServingEngine (one decode step / one prefill)
    # ------------------------------------------------------------------
    def decode_event(self, pump: bool = True) -> int:
        """One decode step's TP all-reduce: submit, (optionally) let the
        background tenant refill its bursts, block to completion.
        Returns the step's collective latency in supersteps."""
        rec = self.submit_decode()
        if pump:
            self.pump_background()
        return self.wait(rec)

    def prefill_event(self, pump: bool = True) -> int:
        rec = self.submit_prefill()
        if pump:
            self.pump_background()
        return self.wait(rec)

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------
    def class_of(self, coll_id: int) -> Optional[str]:
        """Tenant label of a collective id (chain stages resolve to
        their logical head's class when registered here)."""
        cls = self._class_of.get(int(coll_id))
        return cls.name if cls is not None else None

    def diagnose(self) -> list[dict]:
        """Name every stalled chain WITH its tenant: the wedged-
        background story surfaces as a named traffic class instead of
        silently inflating decode p99."""
        out = []
        for s in diagnose(self.runtime).stalled:
            out.append({"coll_id": s.coll_id,
                        "tenant": self.class_of(s.coll_id),
                        "holding_ranks": list(s.holding_ranks),
                        "waiting_ranks": list(s.waiting_ranks),
                        "reason": s.reason})
        return out

    def summary(self) -> dict:
        """Per-class latency digest (supersteps) + fabric counters."""
        st = self.runtime.stats()
        out = {"preemption": self.preemption,
               "supersteps": int(np.asarray(st["supersteps"]).max()),
               "preempts": int(np.asarray(st["preempts"]).sum())}
        for cls, t in self.tenants.items():
            out[cls.name.lower()] = {
                "submitted": t.submitted, "completed": t.completed,
                "p50": t.percentile(50), "p99": t.percentile(99),
                "mean": (float(np.mean(t.latencies))
                         if t.latencies else float("nan")),
            }
        return out
