"""Roofline analysis from the dry-run artifacts (deliverable g).

Reads benchmarks/dryrun_results/*.json and derives, per (arch x cell),
the three roofline terms on TPU v5e targets:

    compute    = HLO_FLOPs_per_device / 197e12  (bf16 peak per chip)
    memory     = HLO_bytes_per_device / 819e9   (HBM bandwidth)
    collective = wire_bytes_per_device / 50e9   (ICI per link)

HLO FLOPs/bytes come from the quadratic-extrapolated unrolled probes (see
launch/dryrun.py).  Wire bytes weight each collective kind by its ring
wire factor relative to the HLO result-shape bytes the parser sums:
all-reduce moves ~2x its result per device (reduce-scatter + all-gather
phases); the others ~1x.  The dominant term is the bottleneck; the step
is ICI/HBM/MXU-overlapped at best max(terms) seconds.

Usage: python benchmarks/roofline.py [--md] [--cell arch:cell]
"""
import argparse
import json
import pathlib
import sys

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

WIRE_FACTOR = {
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-gather": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

RESULTS = pathlib.Path(__file__).resolve().parent / "dryrun_results"


def load():
    rows = []
    for p in sorted(RESULTS.glob("*__16x16.json")):
        d = json.loads(p.read_text())
        if "roofline" not in d:
            continue
        rows.append(d)
    return rows


def terms(d: dict) -> dict:
    r = d["roofline"]
    n = d["n_devices"]
    wire = sum(WIRE_FACTOR.get(k, 1.0) * v["bytes"]
               for k, v in r["collectives"].items())
    compute = r["flops"] / PEAK_FLOPS
    memory = r["bytes"] / HBM_BW
    collective = wire / ICI_BW
    dom = max(("compute", compute), ("memory", memory),
              ("collective", collective), key=lambda kv: kv[1])
    model = d["model_flops_global"] / n
    step = max(compute, memory, collective)
    return {
        "compute_s": compute, "memory_s": memory, "collective_s": collective,
        "dominant": dom[0], "step_s": step,
        "model_flops_dev": model,
        "useful_ratio": model / r["flops"] if r["flops"] else 0.0,
        "mfu": model / step / PEAK_FLOPS if step else 0.0,
        "mem_temp_gib": (d.get("mem_temp_bytes") or 0) / 2**30,
    }


SUGGEST = {
    ("compute",): "reduce recompute (remat policy) / skip masked-out "
                  "attention blocks",
    ("memory",): "cut activation traffic: fuse, bf16 intermediates, "
                 "smaller logit/score materialization",
    ("collective",): "reshard to cut all-gathers; overlap grad "
                     "reduce-scatter with backward (OCCL priority buckets)",
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--cell", default=None)
    args = ap.parse_args()

    rows = load()
    if args.cell:
        a, c = args.cell.split(":")
        rows = [d for d in rows if d["arch"] == a and d["cell"] == c]

    hdr = ("arch", "cell", "compute_s", "memory_s", "collective_s",
           "dominant", "MFU@roofline", "useful_ratio", "temp_GiB")
    if args.md:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
    for d in rows:
        t = terms(d)
        vals = (d["arch"], d["cell"], f"{t['compute_s']:.3e}",
                f"{t['memory_s']:.3e}", f"{t['collective_s']:.3e}",
                t["dominant"], f"{t['mfu']*100:.1f}%",
                f"{t['useful_ratio']:.2f}", f"{t['mem_temp_gib']:.1f}")
        if args.md:
            print("| " + " | ".join(str(v) for v in vals) + " |")
        else:
            print(",".join(str(v) for v in vals))
    return rows


if __name__ == "__main__" and "--dryrun-md" not in sys.argv:
    main()


def dryrun_md():
    """Markdown summary of ALL dry-run cells (both meshes) for §Dry-run."""
    rows = []
    for p in sorted(RESULTS.glob("*.json")):
        rows.append(json.loads(p.read_text()))
    hdr = ("arch", "cell", "mesh", "compile_s", "temp_GiB", "args_GiB",
           "collectives(rolled)")
    print("| " + " | ".join(hdr) + " |")
    print("|" + "---|" * len(hdr))
    for d in rows:
        colls = d.get("rolled", {}).get("collectives", {})
        cs = " ".join(f"{k.split('-')[0]}-{k.split('-')[1][:1]}:{v['count']}"
                      if "-" in k else f"{k}:{v['count']}"
                      for k, v in sorted(colls.items()))
        print(f"| {d['arch']} | {d['cell']} | {d['mesh']} | "
              f"{d['compile_s']:.0f} | "
              f"{(d.get('mem_temp_bytes') or 0)/2**30:.1f} | "
              f"{(d.get('mem_argument_bytes') or 0)/2**30:.1f} | {cs} |")


if __name__ == "__main__" and "--dryrun-md" in sys.argv:
    dryrun_md()
    sys.exit(0)
