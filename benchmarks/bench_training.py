"""Paper Fig. 8/10: DNN training throughput, OCCL vs statically-sequenced
gradient synchronization — plus the overlap record (``training`` section
of BENCH_collectives.json).

``run()`` is the original host-driven comparison: ViT (the paper's
Sec. 5.3.2 model) + qwen3 (LM), reduced configs, DP=4 simulated ranks on
this host, throughput = samples/sec.  The OCCL path submits per-bucket
all-reduces in backward order with priorities; the static path sums in a
fixed global order.

``run_training_bench()`` is the tick-contract record (consumed by
benchmarks/check_gates.py): end-to-end tokens/sec for

* **dense grad sync** — ``make_overlap_grads_step`` with overlap ticks
  (bucket submissions interleaved with the backward pass) vs the SAME
  in-step path with ``ticks_per_boundary=0`` (all supersteps exposed in
  the final drain — the barrier baseline), under the bandwidth-skew lane
  model (``burst_slices=8``, grouped lanes, inter cap);
* **MoE** — ``OcclMoE.forward_overlapped`` (stream-sharded dispatch /
  combine, expert FFN starting on arrived shards while later dispatch
  tails fly) vs the host-driven barrier ``forward``.

The sim backend runs everything on ONE device, so overlap cannot show up
in raw wall-clock (XLA serializes the interleaved ticks with the
compute they would hide on a real fleet).  The record therefore models
step time under the lane model's accounting — hidden supersteps are
free, exposed (barrier) supersteps pay the measured per-superstep cost:

    step_s_modeled = compute_s + exposed_supersteps * superstep_s

with ``compute_s`` the measured compute-only wall and ``superstep_s``
calibrated from the barrier run.  Exposed-superstep counts are
STRUCTURAL (deterministic for a fixed config), so the gates are stable
under runner noise; raw walls are recorded alongside for trajectory.
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from common import row
from repro.configs import get_config
from repro.configs.base import ShapeCell
from repro.data.pipeline import SyntheticPipeline
from repro.models import moe as MOE
from repro.train.occl_moe import (OcclMoE, _combine_local, _dispatch_local_t,
                                  _expert_ffn_batched)
from repro.train.occl_sync import OcclGradSync, static_all_reduce
from repro.train.state import init_state
from repro.train.step import (make_apply_step, make_grads_step,
                              make_overlap_grads_step)


def run_arch(arch: str, steps=6, dp=4, batch=8, seq=32):
    cfg = get_config(arch).reduced()
    cell = ShapeCell("b", seq, batch, "train")
    gfn = jax.jit(make_grads_step(cfg))
    afn = jax.jit(make_apply_step(cfg))

    def loop(kind):
        states = [init_state(cfg) for _ in range(dp)]
        pipes = [SyntheticPipeline(cfg, cell, shard_id=r, n_shards=dp)
                 for r in range(dp)]
        sync = None
        # warmup (compile)
        for r in range(dp):
            gfn(states[r], pipes[r].batch_at(0))
        t0 = time.perf_counter()
        for step in range(steps):
            pr = []
            for r in range(dp):
                _, g = gfn(states[r], next(pipes[r]))
                pr.append(g)
            if kind == "occl":
                nonlocal_sync = sync
                if nonlocal_sync is None:
                    tmpl = jax.tree_util.tree_map(
                        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                        pr[0])
                    sync = OcclGradSync(tmpl, dp, bucket_elems=16384,
                                        slice_elems=512)
                synced = sync.all_reduce(pr)
            else:
                synced = static_all_reduce(pr)
            states = [afn(states[r], synced[r]) for r in range(dp)]
        jax.block_until_ready(states[0].params)
        dt = time.perf_counter() - t0
        return steps * batch / dt, sync

    tput_static, _ = loop("static")
    tput_occl, sync = loop("occl")
    overhead = (tput_static - tput_occl) / tput_static * 100
    st = sync.stats() if sync else {}
    row(f"training/{arch}_dp{dp}", 1e6 / max(tput_occl, 1e-9),
        f"occl_tput={tput_occl:.1f}sps;static_tput={tput_static:.1f}sps;"
        f"overhead={overhead:.1f}%;buckets={len(sync.buckets)}")
    return tput_occl, tput_static


def run():
    out = {}
    for arch in ("vit-base", "qwen3-0.6b"):
        out[arch] = run_arch(arch)
    return out


# ---------------------------------------------------------------------------
# the ``training`` perf-record section (tick-contract overlap gates)
# ---------------------------------------------------------------------------

def _best_of(fn, iters: int) -> float:
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _superstep_deltas(stats0: dict, stats1: dict) -> dict:
    def d(key):                      # per-rank [R] counters, lockstep sim
        return int(np.max(stats1[key] - stats0[key]))
    return {
        "supersteps": d("supersteps"),
        "exposed_supersteps": d("barrier_supersteps"),
        "hidden_supersteps": d("overlap_supersteps"),
        "tick_calls": d("tick_calls"),
    }


def _modelize(recs: dict, compute_s: float, tokens: int) -> float:
    """Fill ``step_s_modeled`` / ``tokens_per_s_modeled`` in-place from
    the lane-model accounting (module docstring); returns superstep_s
    calibrated from the barrier run.  The floor keeps superstep_s
    strictly positive so the modeled ordering stays exactly the
    exposed-superstep ordering even when runner noise makes the barrier
    wall dip under the compute-only wall."""
    t_ss = max((recs["barrier"]["wall_s"] - compute_s)
               / max(recs["barrier"]["exposed_supersteps"], 1), 1e-9)
    for rec in recs.values():
        rec["step_s_modeled"] = compute_s + rec["exposed_supersteps"] * t_ss
        rec["tokens_per_s_modeled"] = tokens / rec["step_s_modeled"]
    return t_ss


def _dense_record(arch="qwen3-0.6b", dp=4, batch=4, seq=16,
                  ticks_per_boundary=8, iters=3) -> dict:
    """Dense grad-sync: in-step overlapped backward vs the same program
    with a zero overlap budget (pure barrier drain), bandwidth-skew
    lanes on the fabric."""
    cfg = get_config(arch).reduced()
    cell = ShapeCell("t", seq, batch, "train")
    states = [init_state(cfg) for _ in range(dp)]
    batches = [SyntheticPipeline(cfg, cell, shard_id=r,
                                 n_shards=dp).batch_at(0)
               for r in range(dp)]
    gfn = jax.jit(make_grads_step(cfg))
    _, gshape = jax.eval_shape(gfn, states[0], batches[0])
    skew = dict(burst_slices=8, bandwidth_groups=2,
                intra_burst_cap=8, inter_burst_cap=2)
    sync = OcclGradSync(gshape, dp, bucket_elems=16384, slice_elems=512,
                        **skew)
    step_fns = {
        "overlap": jax.jit(make_overlap_grads_step(
            cfg, sync, ticks_per_boundary=ticks_per_boundary)),
        "barrier": jax.jit(make_overlap_grads_step(
            cfg, sync, ticks_per_boundary=0)),
    }
    params_list = [s.params for s in states]

    # compute-only proxy: the per-rank backward without any sync
    for r in range(dp):
        jax.block_until_ready(gfn(states[r], batches[r]))
    compute_s = _best_of(
        lambda: jax.block_until_ready(
            [gfn(states[r], batches[r]) for r in range(dp)]), iters)

    recs = {}
    for mode, fn in step_fns.items():
        st = sync.occl.state
        s0 = sync.stats()
        st1, losses, grads = fn(st, params_list, batches)
        jax.block_until_ready(st1)
        sync.occl.adopt_state(st1)
        recs[mode] = _superstep_deltas(s0, sync.stats())
        recs[mode]["wall_s"] = _best_of(
            lambda fn=fn, st=st: jax.block_until_ready(
                fn(st, params_list, batches)), iters)
        recs[mode]["loss_mean"] = float(jnp.mean(losses))

    tokens = dp * batch * seq
    t_ss = _modelize(recs, compute_s, tokens)
    for mode in ("barrier", "overlap"):
        r = recs[mode]
        row(f"training/dense_grad_sync_{mode}", r["wall_s"] * 1e6,
            f"exposed={r['exposed_supersteps']};"
            f"hidden={r['hidden_supersteps']};"
            f"tok_per_s_modeled={r['tokens_per_s_modeled']:.1f}")
    return {
        "config": {"arch": arch, "dp": dp, "batch": batch, "seq": seq,
                   "ticks_per_boundary": ticks_per_boundary,
                   "buckets": len(sync.buckets), "iters": iters, **skew},
        "tokens_per_step": tokens,
        "compute_s": compute_s,
        "superstep_s": t_ss,
        "barrier": recs["barrier"],
        "overlap": recs["overlap"],
        "modeled_speedup": (recs["overlap"]["tokens_per_s_modeled"]
                            / recs["barrier"]["tokens_per_s_modeled"]),
    }


def _moe_record(n_streams=4, overlap_ticks=8, iters=3) -> dict:
    """MoE layer: stream-sharded overlapped dispatch/FFN/combine vs the
    host-driven full-barrier forward."""
    cfg = dataclasses.replace(get_config("deepseek-moe-16b").reduced(),
                              capacity_factor=8.0)
    params = MOE.init_moe_block(jax.random.PRNGKey(0), "t", cfg,
                                jnp.float32)
    rng = np.random.RandomState(7)
    R, Tl = 4, 8
    cap = Tl * cfg.top_k                       # no drops possible
    xs = [jnp.asarray(rng.randn(Tl, cfg.d_model) * 0.5, jnp.float32)
          for _ in range(R)]
    moe = OcclMoE(cfg, R, Tl, cap=cap, n_streams=n_streams,
                  overlap_ticks=overlap_ticks)
    epr, D, E = moe.epr, cfg.d_model, cfg.n_experts

    # compute-only proxy: the identical per-rank math with the exchanges
    # as pure transposes (what a zero-cost fabric would do)
    def compute_only(params, xs_arr):
        xe, tok_idx, w = jax.vmap(
            lambda x: _dispatch_local_t(cfg, params, x, cap))(xs_arr)
        recv = jnp.swapaxes(xe.reshape(R, R, epr, cap, D), 0, 1
                            ).reshape(R, -1)
        ys = _expert_ffn_batched(params, recv, R, epr, cap, D)
        back = jnp.swapaxes(ys.reshape(R, R, epr, cap, D), 0, 1
                            ).reshape(R, E, cap, D)
        return jax.vmap(
            lambda x, rv, ti, ww: _combine_local(
                params, x, rv.reshape(-1), ti, ww))(
            xs_arr, back, tok_idx, w)

    cfn = jax.jit(compute_only)
    params_j = jax.tree_util.tree_map(jnp.asarray, dict(params))
    xs_arr = jnp.stack(xs)
    jax.block_until_ready(cfn(params_j, xs_arr))
    compute_s = _best_of(
        lambda: jax.block_until_ready(cfn(params_j, xs_arr)), iters)

    recs, outs = {}, {}
    for mode, fwd in (("barrier", moe.forward),
                      ("overlap", moe.forward_overlapped)):
        s0 = moe.stats()
        outs[mode] = fwd(params, xs)
        jax.block_until_ready(outs[mode])
        recs[mode] = _superstep_deltas(s0, moe.stats())
        recs[mode]["wall_s"] = _best_of(
            lambda fwd=fwd: jax.block_until_ready(fwd(params, xs)), iters)
    bitwise = all(np.array_equal(np.asarray(outs["barrier"][r]),
                                 np.asarray(outs["overlap"][r]))
                  for r in range(R))

    tokens = R * Tl
    t_ss = _modelize(recs, compute_s, tokens)
    for mode in ("barrier", "overlap"):
        r = recs[mode]
        row(f"training/moe_{mode}", r["wall_s"] * 1e6,
            f"exposed={r['exposed_supersteps']};"
            f"hidden={r['hidden_supersteps']};"
            f"tok_per_s_modeled={r['tokens_per_s_modeled']:.1f}")
    return {
        "config": {"arch": "deepseek-moe-16b", "n_ranks": R,
                   "tokens_per_rank": Tl, "cap": cap,
                   "n_streams": n_streams, "overlap_ticks": overlap_ticks,
                   "iters": iters},
        "tokens_per_step": tokens,
        "compute_s": compute_s,
        "superstep_s": t_ss,
        "bitwise_vs_barrier": bool(bitwise),
        "barrier": recs["barrier"],
        "overlap": recs["overlap"],
        "modeled_speedup": (recs["overlap"]["tokens_per_s_modeled"]
                            / recs["barrier"]["tokens_per_s_modeled"]),
    }


def run_training_bench(iters=3, out_path=None) -> dict:
    """Write the ``training`` section of BENCH_collectives.json (the
    overlap perf gates of benchmarks/check_gates.py)."""
    import bench_collectives as BC
    out_path = out_path or BC.BENCH_JSON
    record = {
        "config": {
            "backend": "sim",
            "model": "step_s_modeled = compute_s + exposed_supersteps * "
                     "superstep_s (hidden supersteps overlap compute; "
                     "superstep_s calibrated from the barrier run)",
        },
        "dense": _dense_record(iters=iters),
        "moe": _moe_record(iters=iters),
    }
    doc = BC._read_record(out_path)
    doc["training"] = record
    BC._write_record(out_path, doc)
    print(f"# wrote {out_path} (training)")
    return record


if __name__ == "__main__":
    run()
    run_training_bench()
