"""Config registry: ``get_config(arch_id)`` for every assigned arch."""
from __future__ import annotations

import importlib

from .base import ArchConfig, ShapeCell, SHAPES

_REGISTRY = {
    "mamba2-2.7b": "mamba2_2p7b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "paligemma-3b": "paligemma_3b",
    "zamba2-1.2b": "zamba2_1p2b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "llama3-8b": "llama3_8b",
    "qwen3-0.6b": "qwen3_0p6b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "stablelm-3b": "stablelm_3b",
    "vit-base": "vit_base",
}

ASSIGNED_ARCHS = [k for k in _REGISTRY if k != "vit-base"]


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    mod = importlib.import_module(f".{_REGISTRY[name]}", __package__)
    return mod.CONFIG


def all_cells() -> list[tuple[str, str]]:
    """Every (arch, shape-cell) pair of the assigned grid, skips excluded."""
    out = []
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for cell in cfg.cells():
            out.append((arch, cell))
    return out


__all__ = ["ArchConfig", "ShapeCell", "SHAPES", "get_config",
           "ASSIGNED_ARCHS", "all_cells"]
