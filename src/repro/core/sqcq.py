"""Host-side SQ/CQ handling (paper Sec. 3.1.2).

Submission-queue entries carry the collective id, user priority and live
buffer addresses (heap offsets) — the dynamic part of the static context.
The completion queue is drained by a poller that dispatches user callbacks
registered in the callback map at submission time.

On a GPU these rings live in page-locked host memory and are polled
concurrently; a TPU device cannot observe host writes mid-program, so the
rings cross the host/device boundary at daemon (re)launches — the paper's
voluntary-quit / event-driven-restart cycle (Sec. 3.1.3) supplies exactly
the needed boundary.  See DESIGN.md Sec. 2.1.

The same boundary carries the submit-time STAGING queue: payloads passed
to ``OcclRuntime.submit(..., data=...)`` are parked here host-side (one
entry per (rank, collective); a re-submission before the flush supersedes
the earlier payload, matching the old immediate-write semantics) and
drained by the launch prologue into one batched device scatter
(staging.StagingEngine) instead of a per-call device round trip.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from .config import OcclConfig
from .state import DaemonState


@dataclasses.dataclass
class SQE:
    coll_id: int
    prio: int = 0
    in_off: int = -1    # -1 = keep the registered default
    out_off: int = -1
    callback: Optional[Callable[[int, int], None]] = None  # (rank, coll_id)


class HostQueues:
    """Per-rank pending submissions + callback map + completion counters."""

    def __init__(self, cfg: OcclConfig):
        self.cfg = cfg
        self.pending: list[collections.deque[SQE]] = [
            collections.deque() for _ in range(cfg.n_ranks)
        ]
        self.callbacks: list[dict[int, collections.deque]] = [
            collections.defaultdict(collections.deque)
            for _ in range(cfg.n_ranks)
        ]
        self.submitted = np.zeros(cfg.n_ranks, np.int64)
        self.completed = np.zeros(cfg.n_ranks, np.int64)
        # Submit-time staged payloads: {(rank, coll_id, in_off): data},
        # drained once per daemon launch by OcclRuntime._flush_staged.
        # The offset is part of the key: two pre-flush submissions of the
        # same collective at DIFFERENT dynamic offsets are distinct
        # executions and both payloads must reach the heap; only a
        # re-submission at the same offset supersedes (the old
        # immediate-write last-write-wins semantics).
        self.staged: dict = {}
        # Relaunch bookkeeping: reconcile() is called once per daemon
        # launch; ``launch_completions`` holds the completions each recent
        # launch contributed (bounded window — long-lived runtimes
        # relaunch indefinitely) and ``reconciles`` the total launch count
        # (host-side mirror of the device's epoch counter, useful for
        # spotting one-superstep launches).
        self.reconciles = 0
        self.launch_completions: collections.deque = collections.deque(
            maxlen=1024)
        # Last-seen snapshot of the device's cumulative per-(rank, coll)
        # completion counters; reconcile() consumes the delta, so every
        # completion is accounted even when the CQ ring wraps more than
        # once within a single launch.
        self._completed_seen = np.zeros(
            (cfg.n_ranks, cfg.max_colls), np.int64)

    def submit(self, rank: int, sqe: SQE, cb_coll: Optional[int] = None
               ) -> None:
        """``cb_coll`` keys the callback under a different collective id
        than the submitted SQE — the runtime passes a composite chain's
        TAIL here, because that is the id the device CQE will carry."""
        self.pending[rank].append(sqe)
        if sqe.callback is not None:
            self.callbacks[rank][
                sqe.coll_id if cb_coll is None else cb_coll
            ].append(sqe.callback)
        self.submitted[rank] += 1

    # -- submit-time payload staging --------------------------------------
    def stage(self, rank: int, coll_id: int, data, in_off: int) -> None:
        """Park a payload for the next launch-prologue flush (last write
        per (rank, collective, offset) wins, like the old immediate-write
        path; distinct offsets are distinct buffers and coexist)."""
        self.staged[(rank, coll_id, in_off)] = data

    def take_staged(self) -> list:
        """Drain the staging queue as ``(rank, coll_id, data, in_off)``
        items for one batched StagingEngine.write."""
        items = [(rank, cid, data, off)
                 for (rank, cid, off), data in self.staged.items()]
        self.staged.clear()
        return items

    # -- device-bound packing ---------------------------------------------
    def pack_sq(self, st: DaemonState) -> DaemonState:
        """Load up to sq_len pending SQEs per rank into the state's SQ and
        reset the cursors (the previous launch's consumed entries were
        already popped by :meth:`reconcile`)."""
        cfg = self.cfg
        sq_coll = np.full((cfg.n_ranks, cfg.sq_len), -1, np.int32)
        sq_prio = np.zeros((cfg.n_ranks, cfg.sq_len), np.int32)
        sq_in = np.full((cfg.n_ranks, cfg.sq_len), -1, np.int32)
        sq_out = np.full((cfg.n_ranks, cfg.sq_len), -1, np.int32)
        sq_size = np.zeros((cfg.n_ranks,), np.int32)
        for r in range(cfg.n_ranks):
            n = min(len(self.pending[r]), cfg.sq_len)
            for i in range(n):
                e = self.pending[r][i]
                sq_coll[r, i] = e.coll_id
                sq_prio[r, i] = e.prio
                sq_in[r, i] = e.in_off
                sq_out[r, i] = e.out_off
            sq_size[r] = n
        return st._replace(
            sq_coll=jnp.asarray(sq_coll), sq_prio=jnp.asarray(sq_prio),
            sq_in=jnp.asarray(sq_in), sq_out=jnp.asarray(sq_out),
            sq_size=jnp.asarray(sq_size),
            sq_read=jnp.zeros((cfg.n_ranks,), jnp.int32),
            cq_coll=jnp.full((cfg.n_ranks, cfg.cq_len), -1, jnp.int32),
            cq_count=jnp.zeros((cfg.n_ranks,), jnp.int32),
        )

    # -- post-launch reconciliation ----------------------------------------
    def reconcile(self, st: DaemonState) -> int:
        """Pop consumed SQEs, account completions, fire callbacks.

        Completion accounting is driven by the device's cumulative
        ``completed`` matrix rather than by walking CQEs: the device CQ is
        a RING (slots wrap modulo ``cq_len``), so with more than ``cq_len``
        completions per launch early CQEs are rotated out — the counter
        delta still reconciles every one of them exactly.  Returns the
        number of completions accounted this call.
        """
        cfg = self.cfg
        sq_read = np.asarray(st.sq_read)
        comp = np.asarray(st.completed, dtype=np.int64)   # [R, C] cumulative
        cq_count = np.asarray(st.cq_count)
        cq_coll = np.asarray(st.cq_coll)
        fired = 0
        for r in range(cfg.n_ranks):
            for _ in range(int(sq_read[r])):
                self.pending[r].popleft()
            delta = comp[r] - self._completed_seen[r]
            # Surviving ring entries, oldest first (completion order).
            cqc = int(cq_count[r])
            ring = [int(cq_coll[r, i % cfg.cq_len])
                    for i in range(max(0, cqc - cfg.cq_len), cqc)]
            # Completions rotated out of a wrapped ring: exact counts from
            # the counter delta, completion order unrecoverable.
            lost = delta.copy()
            for c in ring:
                lost[c] -= 1
            seq = list(np.repeat(np.arange(cfg.max_colls),
                                 np.maximum(lost, 0))) + ring
            for c in seq:
                self.completed[r] += 1
                fired += 1
                cbs = self.callbacks[r].get(int(c))
                if cbs:
                    cbs.popleft()(r, int(c))
            self._completed_seen[r] = comp[r]
        self.reconciles += 1
        self.launch_completions.append(fired)
        return fired

    def outstanding(self) -> int:
        """#SQEs submitted whose CQE has not been seen (drives relaunch)."""
        return int(self.submitted.sum() - self.completed.sum())
