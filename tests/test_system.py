"""End-to-end system behaviour: the paper's headline claims on this repo.

1. Deadlock stress (Sec. 5.2): N ranks invoke the same set of collectives
   in pairwise-different orders, repeatedly — everything completes, with
   preemptions doing the work the consistent global order used to do.
2. The statically-sequenced baseline provably deadlocks on those orders.
3. DP training with OCCL grad-sync produces the same training curve as
   statically-sequenced synchronization.
"""
import pytest

# Heavyweight end-to-end system tests: excluded from tier-1; run with `pytest -m ""`.
pytestmark = pytest.mark.slow
import jax
import numpy as np

from repro.core import (CollKind, OcclConfig, OcclRuntime, OrderPolicy,
                        run_static_order)


def test_stress_pairwise_opposite_orders_iterated():
    R, C, ITERS = 4, 4, 3
    cfg = OcclConfig(n_ranks=R, max_colls=C, max_comms=1, slice_elems=8,
                     conn_depth=3, heap_elems=1 << 14,
                     superstep_budget=1 << 14)
    rt = OcclRuntime(cfg)
    comm = rt.communicator(list(range(R)))
    sizes = [256, 64, 512, 128]
    ids = [rt.register(CollKind.ALL_REDUCE, comm, n_elems=s) for s in sizes]
    rng = np.random.RandomState(0)

    orders = {r: list(rng.permutation(C)) for r in range(R)}
    static = run_static_order(
        orders, {i: list(range(R)) for i in range(C)})

    for it in range(ITERS):
        data = {i: [rng.randn(sizes[i]).astype(np.float32)
                    for _ in range(R)] for i in range(C)}
        for r in range(R):
            for slot in orders[r]:
                rt.submit(r, ids[slot], data=data[slot][r])
        rt.drive()
        for i in range(C):
            want = sum(data[i])
            for r in range(R):
                np.testing.assert_allclose(
                    rt.read_output(r, ids[i]), want, rtol=1e-5)
    st = rt.stats()
    assert int(st["completed"].sum()) == R * C * ITERS
    if static.deadlocked:
        assert int(st["preempts"].sum()) > 0


def test_training_curves_identical_occl_vs_static():
    from repro.configs import get_config
    from repro.configs.base import ShapeCell
    from repro.data.pipeline import SyntheticPipeline
    from repro.train.occl_sync import OcclGradSync, static_all_reduce
    from repro.train.state import init_state
    from repro.train.step import make_apply_step, make_grads_step

    cfg = get_config("qwen3-0.6b").reduced()
    cell = ShapeCell("t", 16, 2, "train")
    dp = 2

    def run(sync_kind):
        states = [init_state(cfg) for _ in range(dp)]
        pipes = [SyntheticPipeline(cfg, cell, shard_id=r, n_shards=dp)
                 for r in range(dp)]
        gfn = jax.jit(make_grads_step(cfg))
        afn = jax.jit(make_apply_step(cfg))
        sync = None
        losses = []
        for step in range(4):
            pr = []
            ls = []
            for r in range(dp):
                loss, g = gfn(states[r], next(pipes[r]))
                pr.append(g)
                ls.append(float(loss))
            if sync_kind == "occl":
                if sync is None:
                    tmpl = jax.tree_util.tree_map(
                        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                        pr[0])
                    sync = OcclGradSync(tmpl, dp, bucket_elems=4096)
                synced = sync.all_reduce(pr)
            else:
                synced = static_all_reduce(pr)
            states = [afn(states[r], synced[r]) for r in range(dp)]
            losses.append(np.mean(ls))
        return losses

    occl = run("occl")
    static = run("static")
    np.testing.assert_allclose(occl, static, rtol=1e-4)
