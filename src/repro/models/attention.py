"""Attention: GQA/MQA/MHA with RoPE, qk-norm, sliding windows, prefix-LM,
cross-attention, blockwise (flash-style) training path, and KV-cache decode.

Shapes: q [B, S, Hq, dh]; k/v [B, Skv, Hkv, dh]; Hq = Hkv * q_per_kv.
The blockwise path streams KV in blocks with a running (max, sum, acc)
accumulator — memory O(S * block) instead of O(S^2) — and is used whenever
S exceeds ``BLOCKWISE_THRESHOLD`` (all 32k+ cells).
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .layers import u_scan

def _blockwise_threshold() -> int:
    """Sequence length above which attention streams KV blockwise instead
    of materializing the S^2 score matrix.  §Perf finding: the f32 score
    materialization dominates HBM traffic already at 4k, so the default
    is 2048 (flash-style everywhere in training); env-overridable for
    baseline comparison."""
    return int(os.environ.get("REPRO_BLOCKWISE_THRESHOLD", "8192"))


def _kv_block() -> int:
    """KV block size, env-overridable for §Perf sweeps."""
    return int(os.environ.get("REPRO_KV_BLOCK", "1024"))


def _p_dtype():
    """Dtype for storing attention probabilities/scores between the
    softmax and the PV matmul.  §Perf: REPRO_ATTN_BF16=1 halves the
    dominant HBM traffic of long-sequence training (softmax statistics
    stay f32; only the stored P matrix is bf16)."""
    return jnp.bfloat16 if os.environ.get("REPRO_ATTN_BF16") == "1" \
        else jnp.float32

NEG_INF = -1e30


def _mask(qpos, kpos, mode: str, window: int, prefix_len: int):
    """Additive mask [..., Sq, Skv] from position vectors."""
    d = qpos[..., :, None] - kpos[..., None, :]
    if mode == "causal":
        ok = d >= 0
    elif mode == "bidir":
        ok = jnp.ones_like(d, dtype=bool)
    elif mode == "prefix":
        ok = (d >= 0) | (kpos[..., None, :] < prefix_len)
    else:  # pragma: no cover
        raise ValueError(mode)
    if window > 0:
        ok = ok & (d < window)
    return jnp.where(ok, 0.0, NEG_INF)


def attention(q, k, v, *, mode: str = "causal", window: int = 0,
              prefix_len: int = 0, q_offset: int | jax.Array = 0,
              kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Plain O(S^2)-memory attention (short sequences / decode).

    kv_len: optional valid KV length (decode against a partially filled
    cache); positions >= kv_len are masked out.
    """
    B, Sq, Hq, dh = q.shape
    _, Skv, Hkv, _ = k.shape
    g = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, g, dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) / np.sqrt(dh)
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Skv)
    m = _mask(qpos, kpos, mode, window, prefix_len)
    if kv_len is not None:
        m = m + jnp.where(kpos[None, :] < kv_len, 0.0, NEG_INF)
    scores = scores + m[None, None, None]
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(_p_dtype()),
                     vf.astype(_p_dtype()),
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, Hq, dh).astype(q.dtype)


def blockwise_attention(q, k, v, *, mode: str = "causal", window: int = 0,
                        prefix_len: int = 0) -> jax.Array:
    """Flash-style streaming attention over KV blocks (training path).

    Scans KV in blocks of KV_BLOCK with running (m, l, acc) per query.
    Causal/SWA masking is applied per block; blocks entirely masked out
    still stream (a static schedule keeps XLA happy) — the §Perf pass
    measures and then removes that waste for causal via block skipping.
    """
    B, S, Hq, dh = q.shape
    _, Skv, Hkv, _ = k.shape
    g = Hq // Hkv
    KB = _kv_block()
    nb = Skv // KB
    assert Skv % KB == 0, "pad sequences to a multiple of the KV block"

    qf = q.astype(jnp.float32).reshape(B, S, Hkv, g, dh) / np.sqrt(dh)
    kb = k.astype(jnp.float32).reshape(B, nb, KB, Hkv, dh)
    vb = v.astype(jnp.float32).reshape(B, nb, KB, Hkv, dh)
    qpos = jnp.arange(S)

    def body(carry, blk):
        m_run, l_run, acc = carry
        kblk, vblk, j = blk
        kpos = j * KB + jnp.arange(KB)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kblk)
        s = s + _mask(qpos, kpos, mode, window, prefix_len)[None, None, None]
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale = jnp.exp(m_run - m_new)
        l_new = l_run * scale + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(_p_dtype()),
                        vblk.astype(_p_dtype()),
                        preferred_element_type=jnp.float32)
        acc = acc * scale[..., None] + pv
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Hkv, g, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, g, S), jnp.float32)
    a0 = jnp.zeros((B, Hkv, g, S, dh), jnp.float32)
    kb_t = jnp.moveaxis(kb, 1, 0)   # [nb, B, KV_BLOCK, Hkv, dh]
    vb_t = jnp.moveaxis(vb, 1, 0)
    (m_f, l_f, acc), _ = u_scan(
        body, (m0, l0, a0), (kb_t, vb_t, jnp.arange(nb)))
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(B, S, Hq, dh)
    return out.astype(q.dtype)


def _blockwise_stats(q, k, v, *, mode, window, prefix_len, q_offset=0,
                     k_offset=0):
    """Blockwise attention returning the running (m, l, acc) statistics
    (pre-normalization) so partial attentions can be merged flash-style."""
    B, S, Hq, dh = q.shape
    _, Skv, Hkv, _ = k.shape
    g = Hq // Hkv
    kb_sz = min(_kv_block(), Skv)
    nb = Skv // kb_sz
    assert Skv % kb_sz == 0

    qf = q.astype(jnp.float32).reshape(B, S, Hkv, g, dh) / np.sqrt(dh)
    kb = k.astype(jnp.float32).reshape(B, nb, kb_sz, Hkv, dh)
    vb = v.astype(jnp.float32).reshape(B, nb, kb_sz, Hkv, dh)
    qpos = jnp.arange(S) + q_offset

    def body(carry, blk):
        m_run, l_run, acc = carry
        kblk, vblk, j = blk
        kpos = k_offset + j * kb_sz + jnp.arange(kb_sz)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kblk)
        s = s + _mask(qpos, kpos, mode, window, prefix_len)[None, None, None]
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale = jnp.exp(m_run - m_new)
        l_new = l_run * scale + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(_p_dtype()),
                        vblk.astype(_p_dtype()),
                        preferred_element_type=jnp.float32)
        acc = acc * scale[..., None] + pv
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Hkv, g, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, g, S), jnp.float32)
    a0 = jnp.zeros((B, Hkv, g, S, dh), jnp.float32)
    (m_f, l_f, acc), _ = u_scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nb)))
    return m_f, l_f, acc


def _merge_stats(a, b):
    """Merge two flash partials over disjoint KV ranges."""
    ma, la, xa = a
    mb, lb, xb = b
    m = jnp.maximum(ma, mb)
    sa = jnp.exp(ma - m)
    sb = jnp.exp(mb - m)
    return m, la * sa + lb * sb, xa * sa[..., None] + xb * sb[..., None]


def _finish_stats(stats, B, S, Hq, dh, dtype):
    m, l, acc = stats
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 3, 1).reshape(B, S, Hq, dh).astype(dtype)


def causal_rec_stats(q, k, v, levels: int, q_offset=0, k_offset=0):
    """Recursive-halving causal attention (beyond-paper §Perf):

    causal(S) = [causal(top half)] and [full(bottom->top) merged with
    causal(bottom half)].  Each level skips the strictly-upper quarter of
    the score matrix with STATIC shapes (no ragged work), approaching the
    true 2x causal FLOPs saving as levels grow: 1 level saves 25%, 2
    levels 37.5%, 3 levels 43.75%."""
    B, S, Hq, dh = q.shape
    if levels <= 0 or S < 2 * _kv_block() or S % 2:
        return _blockwise_stats(q, k, v, mode="causal", window=0,
                                prefix_len=0, q_offset=q_offset,
                                k_offset=k_offset)
    h = S // 2
    top = causal_rec_stats(q[:, :h], k[:, :h], v[:, :h], levels - 1,
                           q_offset, k_offset)
    bot_full = _blockwise_stats(q[:, h:], k[:, :h], v[:, :h], mode="bidir",
                                window=0, prefix_len=0,
                                q_offset=q_offset + h, k_offset=k_offset)
    bot_diag = causal_rec_stats(q[:, h:], k[:, h:], v[:, h:], levels - 1,
                                q_offset + h, k_offset + h)
    bot = _merge_stats(bot_full, bot_diag)
    return tuple(jnp.concatenate([t, b], axis=3)
                 for t, b in zip(top, bot))


def causal_rec_attention(q, k, v, levels: int = 2):
    B, S, Hq, dh = q.shape
    stats = causal_rec_stats(q, k, v, levels)
    return _finish_stats(stats, B, S, Hq, dh, q.dtype)


def full_or_blockwise(q, k, v, **kw):
    if q.shape[1] > _blockwise_threshold():
        levels = int(os.environ.get("REPRO_CAUSAL_REC", "0"))
        if (levels > 0 and kw.get("mode", "causal") == "causal"
                and not kw.get("window") and q.shape[1] == k.shape[1]):
            return causal_rec_attention(q, k, v, levels)
        return blockwise_attention(q, k, v, **kw)
    return attention(q, k, v, **kw)


def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0):
    """Single-token decode vs a cache [B, Smax, Hkv, dh]; pos = #valid."""
    kv_len = pos + 1
    out = attention(q, k_cache, v_cache, mode="bidir", window=0,
                    q_offset=pos, kv_len=kv_len)
    if window > 0:
        # SWA decode: restrict to the trailing window (mask via positions).
        kpos = jnp.arange(k_cache.shape[1])
        keep = (kpos >= kv_len - window) & (kpos < kv_len)
        # Re-run with explicit mask: cheaper path — attention() above with
        # kv_len handles validity; window needs the lower bound too.
        B, Sq, Hq, dh = q.shape
        _, Skv, Hkv, _ = k_cache.shape
        g = Hq // Hkv
        qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, g, dh)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf,
                       k_cache.astype(jnp.float32)) / np.sqrt(dh)
        s = s + jnp.where(keep, 0.0, NEG_INF)[None, None, None, None, :]
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", p,
                         v_cache.astype(jnp.float32))
        out = out.reshape(B, Sq, Hq, dh).astype(q.dtype)
    return out
