"""Expert-parallel MoE dispatch/combine routed through OCCL all-to-all.

The expert-parallel layout shards the routed experts across ranks
(rank d owns the ``E/R`` contiguous experts ``d*E/R .. (d+1)*E/R - 1``)
while tokens stay data-parallel.  Each MoE layer then needs TWO
personalized exchanges per step — dispatch (token slots travel to their
experts' owners) and combine (expert outputs travel back) — and those
are exactly the chained, differently-ordered all-to-alls that wedge a
statically-sequenced executor when two layers (or dispatch and combine
of adjacent microbatches) interleave across ranks.  Routing them through
OCCL makes the pair order-free: ranks submit in ANY order and the daemon
resolves the schedule (paper Sec. 3; tests/test_alltoall.py holds the
adversarial chained-order case).

Layout contract (what makes a PLAIN :class:`CollKind.ALL_TO_ALL` fit):
every (source rank, expert) pair gets the same ``cap`` token slots, so
the per-destination granule is a fixed ``E/R * cap * D`` elements and
the wire payload is fully dense — dropped slots travel as zeros, which
the bias-free SwiGLU experts map back to zeros, so padding never leaks
into the combine.  The dispatch math itself is the sort-based capacity
dispatch of :mod:`repro.models.moe`, restricted to the rank-local token
set.

:func:`ep_forward_ref` runs the IDENTICAL per-rank stages with direct
numpy indexing as the transport, so ``OcclMoE.forward`` must match it
bit for bit in float32 (the all-to-all moves bits, no arithmetic);
``ep_forward_ref`` in turn matches ``moe_forward_dense_ref`` to
float tolerance whenever capacity admits no drops.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core import CollKind, OcclConfig, OcclRuntime


def _capacity(tokens_per_rank: int, top_k: int, n_experts: int,
              capacity_factor: float) -> int:
    """Uniform per-(source rank, expert) slot count — the moe.py formula
    applied to the rank-local token count."""
    cap = int(np.ceil(tokens_per_rank * top_k / n_experts * capacity_factor))
    return max(4, -(-cap // 4) * 4)


# ---------------------------------------------------------------------------
# the three per-rank stages (shared verbatim by OCCL path and reference)
# ---------------------------------------------------------------------------

def _dispatch_local_t(cfg, params, x, cap: int):
    """Traced core of :func:`_dispatch_local`: returns the [E, cap, D]
    dispatch buffer plus (tok_idx, weight) slot metadata, all jnp."""
    E, k = cfg.n_experts, cfg.top_k
    Tl = x.shape[0]
    xt = x.astype(jnp.float32)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    flat_e = topi.reshape(-1)
    order = jnp.argsort(flat_e)                 # stable
    sorted_e = flat_e[order]
    sorted_tok = order // k
    sorted_w = topv.reshape(-1)[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))
    slot = starts[:, None] + jnp.arange(cap)[None, :]
    slot_c = jnp.clip(slot, 0, Tl * k - 1)
    valid = (sorted_e[slot_c] == jnp.arange(E)[:, None]) & (slot < Tl * k)
    tok_idx = jnp.where(valid, sorted_tok[slot_c], 0)      # [E, cap]
    w = jnp.where(valid, sorted_w[slot_c], 0.0)            # [E, cap]
    xe = jnp.where(valid[..., None], xt[tok_idx], 0.0)     # [E, cap, D]
    return xe, tok_idx, w


def _dispatch_local(cfg, params, x, cap: int):
    """Sort-based capacity dispatch of one rank's tokens: returns the
    destination-major dispatch buffer ``[E, cap, D]`` (expert-major IS
    destination-rank-major under the contiguous expert sharding; invalid
    slots zeroed) plus the (tok_idx, weight) slot metadata the combine
    needs back at this rank."""
    xe, tok_idx, w = _dispatch_local_t(cfg, params, x, cap)
    return np.asarray(xe, np.float32).reshape(-1), tok_idx, w


def _expert_ffn(params, rank: int, n_ranks: int, recv, epr: int, cap: int,
                d_model: int) -> np.ndarray:
    """This rank's expert shard over the received origin-major dispatch
    buffer; returns the origin-major combine payload (granule o = the
    outputs of origin o's slots, headed back to o)."""
    xe = jnp.asarray(recv, jnp.float32).reshape(n_ranks, epr, cap, d_model)
    xe = xe.transpose(1, 0, 2, 3).reshape(epr, n_ranks * cap, d_model)
    sl = slice(rank * epr, (rank + 1) * epr)
    wg = params["wg"][sl].astype(jnp.float32)
    wu = params["wu"][sl].astype(jnp.float32)
    wd = params["wd"][sl].astype(jnp.float32)
    h = jnp.einsum("ecd,edf->ecf", xe, wg)
    u = jnp.einsum("ecd,edf->ecf", xe, wu)
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, wd)
    ye = ye.reshape(epr, n_ranks, cap, d_model).transpose(1, 0, 2, 3)
    return np.asarray(ye, np.float32).reshape(-1)


def _expert_ffn_batched(params, recv, n_ranks: int, epr: int, cap: int,
                        d_model: int) -> jnp.ndarray:
    """All owner ranks' expert shards in ONE batched einsum over the
    received origin-major buffers ``recv`` [R, R * epr * cap * D] —
    the traced analogue of vmapping :func:`_expert_ffn` over owners.
    Returns the [R, n] origin-major combine payloads."""
    R = n_ranks
    xe = recv.astype(jnp.float32).reshape(R, R, epr, cap, d_model)
    xe = xe.transpose(0, 2, 1, 3, 4).reshape(R, epr, R * cap, d_model)
    wg = params["wg"].astype(jnp.float32).reshape(R, epr, d_model, -1)
    wu = params["wu"].astype(jnp.float32).reshape(R, epr, d_model, -1)
    wd = params["wd"].astype(jnp.float32).reshape(R, epr, -1, d_model)
    h = jnp.einsum("recd,redf->recf", xe, wg)
    u = jnp.einsum("recd,redf->recf", xe, wu)
    ye = jnp.einsum("recf,refd->recd", jax.nn.silu(h) * u, wd)
    ye = ye.reshape(R, epr, R, cap, d_model).transpose(0, 2, 1, 3, 4)
    return ye.reshape(R, -1)


def _combine_local(params, x, recv, tok_idx, w) -> jnp.ndarray:
    """Weighted scatter-add of the returned expert outputs onto the local
    tokens (+ the replicated shared-expert path).  ``recv`` arrives
    expert-owner-major = expert-major, i.e. aligned with ``tok_idx``."""
    Tl, D = x.shape
    ye = jnp.asarray(recv, jnp.float32).reshape(-1, D)
    y = jnp.zeros((Tl, D), jnp.float32)
    y = y.at[tok_idx.reshape(-1)].add(ye * w.reshape(-1)[:, None])
    if "shared_wg" in params:
        xt = x.astype(jnp.float32)
        hs = jax.nn.silu(xt @ params["shared_wg"].astype(jnp.float32)) * (
            xt @ params["shared_wu"].astype(jnp.float32))
        y = y + hs @ params["shared_wd"].astype(jnp.float32)
    return y


# ---------------------------------------------------------------------------
# transports
# ---------------------------------------------------------------------------

def a2a_exchange_ref(payloads: Sequence[np.ndarray]) -> list:
    """Direct-indexing personalized exchange — the transport oracle the
    OCCL path must match bit for bit."""
    R = len(payloads)
    c = payloads[0].size // R
    return [np.concatenate([np.asarray(payloads[o][m * c:(m + 1) * c])
                            for o in range(R)]) for m in range(R)]


def ep_forward_ref(cfg, params, xs: Sequence, cap: Optional[int] = None):
    """Expert-parallel reference: the same three per-rank stages with
    numpy indexing as the transport.  Returns one [T_l, D] output per
    rank."""
    R = len(xs)
    epr = cfg.n_experts // R
    cap = cap or _capacity(xs[0].shape[0], cfg.top_k, cfg.n_experts,
                           cfg.capacity_factor)
    disp, meta = [], []
    for r in range(R):
        xe, tok_idx, wts = _dispatch_local(cfg, params, xs[r], cap)
        disp.append(xe)
        meta.append((tok_idx, wts))
    recv = a2a_exchange_ref(disp)
    comb = [_expert_ffn(params, d, R, recv[d], epr, cap, cfg.d_model)
            for d in range(R)]
    back = a2a_exchange_ref(comb)
    return [_combine_local(params, xs[r], back[r], *meta[r])
            for r in range(R)]


class OcclMoE:
    """MoE dispatch + combine over two registered OCCL ALL_TO_ALLs.

    The runtime is self-sized from the layer shape (the OcclGradSync
    idiom): one communicator, two collectives (dispatch, combine), heap
    scaled to the ``E * cap * D`` payload.  ``hierarchy=(G, N)`` routes
    both exchanges through the composite two-level all-to-all (intra-
    group exchange -> inter-group exchange over the G x N rank grid)
    instead of the flat relay ring; ``algo="auto"`` lets the fitted cost
    model pick.
    """

    def __init__(self, cfg, n_ranks: int, tokens_per_rank: int,
                 cap: Optional[int] = None, algo: str = "ring",
                 hierarchy: Optional[tuple] = None, slice_elems: int = 128,
                 n_streams: int = 1, overlap_ticks: int = 4):
        """``n_streams=S`` additionally registers S stream-sharded
        dispatch and S combine all-to-alls (the capacity axis split into
        S independent exchanges of ``E * cap/S * D`` elements — each
        shard is itself a legal personalized exchange because expert-
        major stays destination-rank-major) for
        :meth:`forward_overlapped`: expert FFN compute on shard s starts
        while the dispatch tails of shards > s are still in flight, and
        shard s's combine is submitted as its outputs finish rather than
        behind a full-layer barrier.  Stream shards always ride the flat
        ring (``algo`` applies to the barrier-path pair).
        ``overlap_ticks`` is the overlap budget spent after each in-step
        submission."""
        E, D = cfg.n_experts, cfg.d_model
        assert E % n_ranks == 0, (
            f"expert-parallel layout needs n_experts % n_ranks == 0 "
            f"(E={E}, R={n_ranks})")
        self.cfg = cfg
        self.R = n_ranks
        self.epr = E // n_ranks
        self.cap = cap or _capacity(tokens_per_rank, cfg.top_k, E,
                                    cfg.capacity_factor)
        assert n_streams >= 1 and self.cap % n_streams == 0, (
            f"n_streams={n_streams} must divide the capacity "
            f"(cap={self.cap}; it is always a multiple of 4)")
        self.n_streams = n_streams
        self.overlap_ticks = overlap_ticks
        n = E * self.cap * D
        self.n_elems = n
        composite = hierarchy is not None or algo == "auto"
        self.occl = OcclRuntime(OcclConfig(
            n_ranks=n_ranks,
            max_colls=max(8, 2 * (1 + n_streams) + (8 if composite else 0)),
            max_comms=4 if composite else 1,
            slice_elems=slice_elems,
            conn_depth=8,
            heap_elems=max(1 << 14, 10 * n) * (2 if composite else 1),
            superstep_budget=1 << 16,
        ))
        comm = self.occl.communicator(list(range(n_ranks)))
        self.disp_id = self.occl.register(
            CollKind.ALL_TO_ALL, comm, n_elems=n, algo=algo,
            hierarchy=hierarchy)
        self.comb_id = self.occl.register(
            CollKind.ALL_TO_ALL, comm, n_elems=n, algo=algo,
            hierarchy=hierarchy)
        cap_s = self.cap // n_streams
        self.disp_stream_ids = [
            self.occl.register(CollKind.ALL_TO_ALL, comm,
                               n_elems=E * cap_s * D, algo="ring")
            for _ in range(n_streams)]
        self.comb_stream_ids = [
            self.occl.register(CollKind.ALL_TO_ALL, comm,
                               n_elems=E * cap_s * D, algo="ring")
            for _ in range(n_streams)]
        self._overlap_jit = None

    def forward(self, params, xs: Sequence) -> list:
        """xs: one [T_l, D] local token matrix per rank -> one [T_l, D]
        output per rank, bit-comparable to :func:`ep_forward_ref`.

        Payloads go through staged submits (one batched heap flush per
        exchange); submission order across ranks is free — the runtime
        is deadlock-free by construction."""
        assert len(xs) == self.R
        meta = []
        for r in range(self.R):
            xe, tok_idx, wts = _dispatch_local(self.cfg, params, xs[r],
                                               self.cap)
            meta.append((tok_idx, wts))
            self.occl.submit(r, self.disp_id, data=xe)
        self.occl.drive()
        recv = self.occl.read_outputs_bulk(
            [(r, self.disp_id) for r in range(self.R)])
        for d in range(self.R):
            self.occl.submit(d, self.comb_id, data=_expert_ffn(
                params, d, self.R, recv[(d, self.disp_id)], self.epr,
                self.cap, self.cfg.d_model))
        self.occl.drive()
        back = self.occl.read_outputs_bulk(
            [(r, self.comb_id) for r in range(self.R)])
        return [_combine_local(params, xs[r], back[(r, self.comb_id)],
                               *meta[r]) for r in range(self.R)]

    # ------------------------------------------------------------------
    # overlapped path: stream-sharded dispatch/combine inside ONE jitted
    # program (tick contract; core/daemon.py and core/device_api.py)
    # ------------------------------------------------------------------
    def _build_overlap_core(self):
        api = self.occl.device_api()
        cfg, R, S = self.cfg, self.R, self.n_streams
        cap, epr, D = self.cap, self.epr, cfg.d_model
        cap_s = cap // S
        E = cfg.n_experts
        disp_ids, comb_ids = self.disp_stream_ids, self.comb_stream_ids
        k_over = self.overlap_ticks

        def core(st, params, xs):          # xs: [R, T_l, D]
            st = api.step_prologue(st)
            base = [api.completed(st, c) for c in disp_ids]
            xe, tok_idx, w = jax.vmap(
                lambda x: _dispatch_local_t(cfg, params, x, cap))(xs)
            # Submit every dispatch shard up front (rising stream
            # priority), spending a bounded overlap tick after each —
            # later shards' staging hides earlier shards' supersteps.
            for s in range(S):
                shard = xe[:, :, s * cap_s:(s + 1) * cap_s, :].reshape(R, -1)
                st = api.submit_all(st, disp_ids[s], shard, prio=s)
                st, _ = api.tick(st, jnp.int32(k_over), barrier=False)
            for s in range(S):
                # Exposed wait: only until THIS shard's granules arrived
                # — the dispatch tails of shards > s keep flying while
                # shard s's expert FFN runs below.
                cid, tgt = disp_ids[s], base[s] + 1
                st = api.tick_until(
                    st, lambda t: jnp.all(api.completed(t, cid) >= tgt),
                    chunk=8, barrier=True)
                recv = api.read_all(st, disp_ids[s])
                ys = _expert_ffn_batched(params, recv, R, epr, cap_s, D)
                # Combine submitted per shard as its outputs finish (no
                # full-layer barrier), then another hidden tick.
                st = api.submit_all(st, comb_ids[s], ys, prio=S + s)
                st, _ = api.tick(st, jnp.int32(k_over), barrier=False)
            st = api.drain(st)
            back = jnp.concatenate(
                [api.read_all(st, comb_ids[s]).reshape(R, E, cap_s, D)
                 for s in range(S)], axis=2)    # [R, E, cap, D]
            y = jax.vmap(
                lambda x, rv, ti, ww: _combine_local(
                    params, x, rv.reshape(-1), ti, ww))(
                xs, back, tok_idx, w)
            return st, y

        return jax.jit(core, donate_argnums=0)

    def forward_overlapped(self, params, xs: Sequence) -> list:
        """The overlap-mode :meth:`forward`: one jitted program doing
        dispatch -> per-shard (wait, FFN, combine-submit) -> drain, with
        daemon ticks interleaved so only the per-shard arrival waits and
        the final drain are EXPOSED supersteps (``stats()``'s
        barrier/overlap split measures it).  Matches
        :func:`ep_forward_ref` numerically; with ``n_streams=1`` the
        exchanges are the same full-capacity payloads bit for bit."""
        assert len(xs) == self.R
        if self._overlap_jit is None:
            self._overlap_jit = self._build_overlap_core()
        params_j = jax.tree_util.tree_map(jnp.asarray, dict(params))
        xs_arr = jnp.stack([jnp.asarray(x) for x in xs])
        st, y = self._overlap_jit(self.occl.state, params_j, xs_arr)
        self.occl.adopt_state(st)
        return [y[r] for r in range(self.R)]

    def stats(self):
        return self.occl.stats()
