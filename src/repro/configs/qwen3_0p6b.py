"""qwen3-0.6b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-0.6B].

Note: Qwen3 decouples head_dim (128) from d_model/n_heads.
"""
from .base import ArchConfig, _FULL_ATTN_500K_SKIP

CONFIG = ArchConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, d_head=128,
    d_ff=3072, vocab=151936, qk_norm=True, rope_theta=1_000_000.0,
    skip_cells=(_FULL_ATTN_500K_SKIP,),
)
