"""Composite-collective demo: hierarchical two-level all-reduce via
device-chained sub-collectives.

Three acts:

1. **Flat vs two-level registration** — the same logical all-reduce over a
   4x4 rank grid registered both ways; the two-level lowering (intra-group
   reduce-scatter -> inter-group all-reduce over chunk owners ->
   intra-group all-gather, "The Big Send-off" decomposition) completes in
   ~half the supersteps because its latency term is N + (2G - 1) + N = 15
   primitive steps instead of the ring's 2R - 1 = 31.  The chain advances
   ON DEVICE: one daemon launch runs all three stages, observable in the
   stats() chain/stage counters.

2. **Auto selection** — ``algo="auto"`` ranks the registered candidate
   plans (ring / two_level / torus / hybrid for all-reduce) with the
   measured α-β-γ cost model: at a small payload the per-stage overhead
   term keeps the flat ring, under inter-island bandwidth skew at a
   large payload a hierarchical chain wins (core/costmodel.py; calibrate
   with ``python benchmarks/calibrate.py``).

3. **The adversarial chained-order scenario** — two chains share the
   derived intra/inter lanes and the ranks submit them in conflicting
   orders.  The static single-FIFO-queue baseline deadlocks on this order
   set; OCCL's preemption completes both chains — composed collectives
   stay deadlock-free, not just independently submitted ones.

    PYTHONPATH=src python examples/hierarchical_allreduce.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import (CollKind, OcclConfig, OcclRuntime,
                        run_static_order)

R, HIER, N_ELEMS = 16, (4, 4), 2048
rng = np.random.RandomState(42)


def make_runtime():
    cfg = OcclConfig(n_ranks=R, max_colls=8, max_comms=3, slice_elems=64,
                     conn_depth=24, burst_slices=8, heap_elems=1 << 17,
                     superstep_budget=1 << 15)
    rt = OcclRuntime(cfg)
    return rt, rt.communicator(list(range(R)))


def drive_once(rt, cid, xs):
    for r in range(R):
        rt.submit(r, cid, data=xs[r])
    s0 = int(np.asarray(rt.stats()["supersteps"]).max())
    rt.drive()
    return int(np.asarray(rt.stats()["supersteps"]).max()) - s0


# --- 1. flat ring vs two-level chain -----------------------------------
xs = [rng.randn(N_ELEMS).astype(np.float32) for _ in range(R)]
want = np.sum(xs, axis=0)
steps = {}
for algo in ("ring", "two_level"):
    rt, world = make_runtime()
    cid = rt.register(CollKind.ALL_REDUCE, world, n_elems=N_ELEMS,
                      algo=algo, hierarchy=HIER)
    drive_once(rt, cid, xs)                    # warmup: compile + converge
    steps[algo] = drive_once(rt, cid, xs)
    for r in range(R):
        np.testing.assert_allclose(rt.read_output(r, cid), want,
                                   rtol=1e-4, atol=1e-4)
    st = rt.stats()
    if algo == "two_level":
        chain = st["chains"][cid]
        print(f"two-level chain stages {chain}: per-stage completions "
              f"{st['stage_completions'][:, chain].sum(axis=0).tolist()}, "
              f"logical CQEs only at the tail "
              f"{st['completed'][:, chain].sum(axis=0).tolist()}")
print(f"supersteps per all-reduce at R={R}: flat ring {steps['ring']}, "
      f"two-level {steps['two_level']} "
      f"({steps['ring'] / steps['two_level']:.1f}x fewer)")
assert steps["two_level"] < steps["ring"]

# --- 2. cost-model auto selection --------------------------------------
# Under the bandwidth-skew lane model (4 islands, inter lanes capped at
# 2 slices/superstep) the flat ring pays the inter cap on EVERY hop, so
# the model's latency term flips the selection at large payloads while
# the per-stage overhead term keeps small payloads on the single-stage
# ring.
skew_cfg = OcclConfig(n_ranks=R, max_colls=8, max_comms=3, slice_elems=64,
                      conn_depth=24, burst_slices=8, heap_elems=1 << 17,
                      superstep_budget=1 << 15,
                      bandwidth_groups=4, inter_burst_cap=2)
rt = OcclRuntime(skew_cfg)
world = rt.communicator(list(range(R)))
small = rt.register(CollKind.ALL_REDUCE, world, n_elems=64, algo="auto")
big = rt.register(CollKind.ALL_REDUCE, world, n_elems=1 << 16, algo="auto")
algos = rt.stats()["algos"]
print(f"auto selection under bandwidth skew: 64 elems -> "
      f"{algos.get(small, 'ring')}, {1 << 16} elems -> "
      f"{algos.get(big, 'ring')}")

# --- 3. adversarial chained submission orders --------------------------
orders = {r: [0, 1] if r % 2 == 0 else [1, 0] for r in range(R)}
static = run_static_order(orders, {c: list(range(R)) for c in range(2)})
print("static single-FIFO-queue baseline on the conflicting orders:",
      "DEADLOCK" if static.deadlocked else "ok",
      f"(wait-for cycle over ranks {static.cycle})")
assert static.deadlocked

rt, world = make_runtime()
a = rt.register(CollKind.ALL_REDUCE, world, n_elems=512,
                algo="two_level", hierarchy=HIER)
b = rt.register(CollKind.ALL_REDUCE, world, n_elems=384,
                algo="two_level", hierarchy=HIER)
data = {c: [rng.randn(n).astype(np.float32) for _ in range(R)]
        for c, n in [(a, 512), (b, 384)]}
for r in range(R):
    for slot in orders[r]:
        cid = [a, b][slot]
        rt.submit(r, cid, data=data[cid][r])
rt.drive(max_launches=128)
for cid in (a, b):
    w = np.sum(data[cid], axis=0)
    for r in range(R):
        np.testing.assert_allclose(rt.read_output(r, cid), w,
                                   rtol=1e-4, atol=1e-4)
st = rt.stats()
print(f"OCCL: both chains complete under conflicting orders — "
      f"{int(st['preempts'].sum())} preemptions, "
      f"{rt.launches} daemon launches")
print("OK — composed collectives are deadlock-free, not just "
      "independently submitted ones.")
