"""stablelm-3b [dense] — MHA (kv = n_heads) [hf:stabilityai/stablelm-*]."""
from .base import ArchConfig, _FULL_ATTN_500K_SKIP

CONFIG = ArchConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, d_head=80,
    d_ff=6912, vocab=50304,
    skip_cells=(_FULL_ATTN_500K_SKIP,),
)
