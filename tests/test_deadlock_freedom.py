"""THE property of the paper: any per-rank submission order of any mix of
collectives completes deadlock-free under OCCL with correct results —
including order-sets that provably deadlock the statically sequenced
baseline (Fig. 1a).

This module holds the deterministic scenario plus the shared ``_run_occl``
driver; the hypothesis property sweeps live in
test_deadlock_freedom_props.py (skipped when hypothesis is absent)."""
import numpy as np
import pytest

from repro.core import (CollKind, OcclConfig, OcclRuntime, OrderPolicy,
                        run_static_order)

KINDS = [CollKind.ALL_REDUCE, CollKind.ALL_GATHER, CollKind.REDUCE_SCATTER,
         CollKind.BROADCAST, CollKind.REDUCE]


def _run_occl(R, colls, orders, policy, stickiness, seed, burst_slices=1):
    cfg = OcclConfig(
        n_ranks=R, max_colls=max(4, len(colls)), max_comms=1,
        slice_elems=4, conn_depth=3, heap_elems=1 << 13,
        order_policy=policy, stickiness=stickiness,
        burst_slices=burst_slices,
        superstep_budget=1 << 14, quit_threshold=64)
    rt = OcclRuntime(cfg)
    comm = rt.communicator(list(range(R)))
    rng = np.random.RandomState(seed)
    ids, inputs, roots = [], {}, {}
    for kind, n_elems, root in colls:
        cid = rt.register(kind, comm, n_elems=n_elems, root=root)
        ids.append(cid)
        roots[cid] = root
        if kind == CollKind.ALL_GATHER:
            chunk = -(-n_elems // R)
            inputs[cid] = [rng.randn(chunk).astype(np.float32)
                           for _ in range(R)]
        else:
            inputs[cid] = [rng.randn(n_elems).astype(np.float32)
                           for _ in range(R)]
    for r in range(R):
        for slot in orders[r]:
            cid = ids[slot]
            kind = colls[slot][0]
            if kind == CollKind.BROADCAST:
                if r == comm.members[roots[cid]]:
                    rt.write_input(r, cid, inputs[cid][0])
            else:
                rt.write_input(r, cid, inputs[cid][r])
            rt.submit(r, cid)
    rt.drive(max_launches=128)
    return rt, ids, inputs, roots


def _run_occl_chained(R, hierarchy, n_chained, n_flat, orders, seed,
                      policy=OrderPolicy.FIFO):
    """Chained-composite variant of the driver: ``n_chained`` two-level
    all-reduces (device-chained sub-collectives sharing the derived
    intra/inter lanes) plus ``n_flat`` flat all-reduces, submitted in the
    given per-rank orders.  Returns (runtime, logical ids, inputs)."""
    n_coll = n_chained + n_flat
    cfg = OcclConfig(
        n_ranks=R, max_colls=max(4, 3 * n_chained + n_flat), max_comms=3,
        slice_elems=4, conn_depth=3, heap_elems=1 << 14,
        order_policy=policy, superstep_budget=1 << 14, quit_threshold=64)
    rt = OcclRuntime(cfg)
    comm = rt.communicator(list(range(R)))
    rng = np.random.RandomState(seed)
    ids = []
    for i in range(n_coll):
        n_elems = int(rng.randint(4, 40))
        if i < n_chained:
            ids.append(rt.register(CollKind.ALL_REDUCE, comm,
                                   n_elems=n_elems, algo="two_level",
                                   hierarchy=hierarchy))
        else:
            ids.append(rt.register(CollKind.ALL_REDUCE, comm,
                                   n_elems=n_elems))
    inputs = {cid: [rng.randn(rt.specs[cid].n_elems).astype(np.float32)
                    for _ in range(R)] for cid in ids}
    for r in range(R):
        for slot in orders[r]:
            rt.submit(r, ids[slot], data=inputs[ids[slot]][r])
    rt.drive(max_launches=128)
    return rt, ids, inputs


def test_pairwise_opposite_orders_deadlock_baseline_not_occl():
    """The canonical Fig. 1(a) two-collective inversion."""
    orders = {0: [0, 1], 1: [1, 0]}
    members = {0: [0, 1], 1: [0, 1]}
    res = run_static_order(orders, members)
    assert res.deadlocked and res.cycle

    colls = [(CollKind.ALL_REDUCE, 12, 0), (CollKind.ALL_REDUCE, 12, 0)]
    rt, ids, inputs, _ = _run_occl(
        2, colls, [[0, 1], [1, 0]], OrderPolicy.FIFO, True, seed=2)
    for cid in ids:
        np.testing.assert_allclose(
            rt.read_output(0, cid), sum(inputs[cid]), rtol=1e-4, atol=1e-6)
    assert rt.stats()["preempts"].sum() > 0   # preemption did the work
