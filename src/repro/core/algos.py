"""Collective algorithm registry + the composite-collective IR.

Two layers live here:

* **Algorithm registry** — the per-kind ring program builders that used to
  be inlined in :func:`repro.core.primitives.build_program` are registered
  under ``("ring", kind)`` keys, so alternative single-communicator
  algorithms (tree, bucket, ...) can be added without touching the
  builder dispatch.  ``build_ring_program`` is the registry-backed
  entrypoint; ``primitives.build_program`` delegates here.

* **CompositePlan IR** — a logical collective over a ``G x N`` rank grid
  lowered into a CHAIN of ring sub-collectives over derived
  sub-communicators.  The canonical plan is the two-level all-reduce of
  "The Big Send-off" (PAPERS.md): intra-group reduce-scatter -> inter-group
  all-reduce over chunk owners -> intra-group all-gather, which replaces
  the flat ring's ``2R - 1`` latency steps with ``N + (2G - 1) + N``.
  Each stage is an ordinary registered collective; the chain edges become
  the registration-time successor tables that let the daemon advance a
  chain ON DEVICE (scheduler.lanes_step enqueues the successor SQE in the
  same superstep its predecessor completes).

Chained sub-collectives are exactly the inter-collective dependencies the
source paper warns about (circular collective dependency, Sec. 1): stage
k+1 on one rank waits for stage k on OTHER ranks.  The OCCL scheduler's
preemption keeps composed chains deadlock-free the same way it keeps
independently submitted collectives deadlock-free — the deadlock-freedom
property sweep covers chains submitted in conflicting orders.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence

from .primitives import CollKind, Prim

# ---------------------------------------------------------------------------
# algorithm registry (single-communicator program builders)
# ---------------------------------------------------------------------------

# (algo_name, kind) -> builder(member_idx, group_size, root_idx) -> program.
ALGO_BUILDERS: dict = {}


def register_algo(algo: str, kind: CollKind):
    """Decorator: register a per-rank program builder for (algo, kind)."""

    def deco(fn: Callable[[int, int, int], list]):
        ALGO_BUILDERS[(algo, kind)] = fn
        return fn

    return deco


@register_algo("ring", CollKind.ALL_REDUCE)
def _ring_all_reduce(m: int, R: int, root: int) -> list:
    # Phase 1 (reduce-scatter): chunk c starts at rank c; at step s rank r
    # handles chunk (r - s) mod R; partial completes at step R-1.
    prog = [(Prim.SEND, m)]
    for s in range(1, R - 1):
        prog.append((Prim.RECV_REDUCE_SEND, (m - s) % R))
    prog.append((Prim.RECV_REDUCE_COPY_SEND, (m - (R - 1)) % R))
    # Phase 2 (all-gather): fully-reduced chunks circulate once more.
    for s in range(R, 2 * R - 2):
        prog.append((Prim.RECV_COPY_SEND, (m - s) % R))
    prog.append((Prim.RECV, (m + 2) % R))
    return prog


@register_algo("ring", CollKind.ALL_GATHER)
def _ring_all_gather(m: int, R: int, root: int) -> list:
    prog = [(Prim.COPY_SEND, m)]
    for s in range(1, R - 1):
        prog.append((Prim.RECV_COPY_SEND, (m - s) % R))
    prog.append((Prim.RECV, (m + 1) % R))
    return prog


@register_algo("ring", CollKind.REDUCE_SCATTER)
def _ring_reduce_scatter(m: int, R: int, root: int) -> list:
    # Chunk c finalizes at rank c after R-1 hops, so it starts at c+1.
    prog = [(Prim.SEND, (m - 1) % R)]
    for s in range(1, R - 1):
        prog.append((Prim.RECV_REDUCE_SEND, (m - s - 1) % R))
    prog.append((Prim.RECV_REDUCE_COPY, m))
    return prog


@register_algo("ring", CollKind.BROADCAST)
def _ring_broadcast(m: int, R: int, root: int) -> list:
    d = (m - root) % R
    prog = []
    for k in range(R):  # pipeline the R chunks down the chain
        if d == 0:
            prog.append((Prim.COPY_SEND, k))
        elif d == R - 1:
            prog.append((Prim.RECV, k))
        else:
            prog.append((Prim.RECV_COPY_SEND, k))
    return prog


@register_algo("ring", CollKind.REDUCE)
def _ring_reduce(m: int, R: int, root: int) -> list:
    # R >= 2 here: single-member groups early-return a COPY in
    # build_ring_program, so the chain roles below are total.
    d = (m - root) % R
    prog = []
    for k in range(R):
        if d == 1:
            prog.append((Prim.SEND, k))
        elif d == 0:
            prog.append((Prim.RECV_REDUCE_COPY, k))
        else:
            prog.append((Prim.RECV_REDUCE_SEND, k))
    return prog


def build_ring_program(
    kind: CollKind, member_idx: int, group_size: int, root_idx: int = 0,
    algo: str = "ring",
) -> list:
    """Per-rank primitive sequence ``[(prim, chunk_idx), ...]`` from the
    algorithm registry.  Ring algorithm, Simple protocol (paper Sec. 5)."""
    if group_size == 1:
        # Degenerate single-member group: a local copy (broadcast/reduce/
        # all_* all collapse to in -> out).
        return [(Prim.COPY, 0)]
    try:
        builder = ALGO_BUILDERS[(algo, CollKind(kind))]
    except KeyError:  # pragma: no cover
        raise ValueError(f"no registered builder for algo={algo!r}, "
                         f"kind={CollKind(kind)!r}")
    return builder(member_idx, group_size, root_idx)


# ---------------------------------------------------------------------------
# composite plans (multi-communicator chained sub-collectives)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SubCollective:
    """One stage of a composite plan: an ordinary ring collective over a
    PARTITIONED sub-communicator (disjoint rings sharing one lane)."""

    kind: CollKind
    members: tuple          # flat rank tuple; consecutive ``ring_size``
                            # runs are the disjoint rings of this stage
    ring_size: int
    n_elems: int            # logical element count of this stage
    root: int = 0


@dataclasses.dataclass(frozen=True)
class CompositePlan:
    """A logical collective lowered to a chain of sub-collectives.

    ``stages[k+1]`` consumes ``stages[k]``'s logical output; the tables
    layer turns each edge into a registration-time heap relink map and a
    ``next_coll`` successor entry, so the daemon advances the whole chain
    on device.  Logical I/O addresses only the endpoints: payloads stage
    into ``stages[0]``'s input region, results read from ``stages[-1]``'s
    output region.
    """

    kind: CollKind          # the logical collective the chain implements
    n_elems: int
    hierarchy: tuple        # (G groups, N ranks per group)
    stages: tuple           # tuple[SubCollective, ...]


def default_hierarchy(R: int) -> tuple:
    """(G, N) with G * N == R and N the largest divisor <= sqrt(R) —
    the most square grid, which minimizes the two-level latency term
    N + (2G - 1) + N.  Primes fall back to (R, 1)."""
    best = 1
    for n in range(2, int(math.isqrt(R)) + 1):
        if R % n == 0:
            best = n
    return (R // best, best)


def plan_two_level(kind: CollKind, members: Sequence[int],
                   hierarchy: tuple, n_elems: int) -> CompositePlan:
    """Lower a logical all-reduce over a ``G x N`` rank grid into the
    two-level chain (The Big Send-off, PAPERS.md):

      1. intra-group REDUCE_SCATTER over each group's N-ring: member m of
         group g ends up owning chunk m of the group-local sum;
      2. inter-group ALL_REDUCE over the G chunk owners of each position m
         (one G-ring per chunk position): chunk m becomes globally reduced
         everywhere;
      3. intra-group ALL_GATHER over the N-rings: every rank reassembles
         the full globally-reduced payload.

    ``members`` is the logical communicator's ring order, reshaped
    row-major: group g = members[g*N : (g+1)*N].
    """
    G, N = hierarchy
    R = len(members)
    if G * N != R:
        raise ValueError(f"hierarchy {hierarchy} does not tile the "
                         f"{R}-member communicator (G * N != {R})")
    if kind != CollKind.ALL_REDUCE:
        raise ValueError(
            f"two_level lowering is defined for ALL_REDUCE only, got "
            f"{CollKind(kind)!r} (register other kinds with algo='ring')")
    members = tuple(members)
    groups = [members[g * N:(g + 1) * N] for g in range(G)]
    # Inter-group rings: position m's chunk owners across all groups.
    owners = [tuple(groups[g][m] for g in range(G)) for m in range(N)]
    intra = tuple(r for grp in groups for r in grp)          # == members
    inter = tuple(r for ring in owners for r in ring)
    chunk = -(-n_elems // N)                                 # ceil
    return CompositePlan(
        kind=kind, n_elems=n_elems, hierarchy=(G, N),
        stages=(
            SubCollective(CollKind.REDUCE_SCATTER, intra, N, n_elems),
            SubCollective(CollKind.ALL_REDUCE, inter, G, chunk),
            SubCollective(CollKind.ALL_GATHER, intra, N, n_elems),
        ))


def select_algo(algo: str, kind: CollKind, n_elems: int, group_size: int,
                hierarchy: Optional[tuple], threshold: int) -> str:
    """Resolve ``"auto"`` to a concrete algorithm.

    Flat ring below the payload threshold, two-level at/above it: with
    slice bursts the superstep cost of a collective is dominated by its
    primitive-step (latency) term, which grows as ``2R - 1`` for the flat
    ring but only ``2N + 2G - 1`` for the two-level chain — the larger
    the payload the longer a flat ring's per-step slice train, so the
    decomposition pays off once the payload amortizes the chain's two
    stage hand-offs.  Explicit ``"ring"`` / ``"two_level"`` pass through
    unchanged; auto falls back to ring when the kind has no two-level
    lowering or the grid is degenerate (prime group, G or N == 1).
    """
    if algo != "auto":
        return algo
    if kind != CollKind.ALL_REDUCE or n_elems < threshold:
        return "ring"
    if hierarchy is not None:
        G, N = hierarchy
        # A caller-provided grid that does not tile the group is a bug,
        # not a selection hint: silently downgrading to the flat ring
        # would hide the typo (the explicit two_level path raises the
        # same error via plan_two_level).
        if G * N != group_size:
            raise ValueError(
                f"hierarchy {hierarchy} does not tile the "
                f"{group_size}-member communicator (G * N != {group_size})")
    else:
        G, N = default_hierarchy(group_size)
    if G <= 1 or N <= 1:
        return "ring"                          # degenerate grid (primes)
    return "two_level"
