"""OcclRuntime: the public host API of the deadlock-free collective library.

Mirrors the paper's integration contract (Sec. 4): register communicators
and collectives once, then ``submit`` from any rank in ANY order with an
optional completion callback; the runtime launches the daemon event-driven
and guarantees every submitted collective completes (assuming every member
rank eventually submits it — the same contract NCCL imposes, minus the
ordering requirement).

The runtime also exposes the observability used in the paper's Fig. 9 case
study: per-collective preemption (context-switch) counts and task-queue
lengths at fetch time.

Heap I/O is device-resident (staging.StagingEngine): the padded chunk
layout of every collective is precomputed at registration
(tables.build_tables), so ``write_input``/``write_inputs_bulk`` are one
host->device transfer of concatenated logical payloads plus one fused
scatter into ``heap_in`` (pad positions zero-filled in the same scatter),
and ``read_output``/``read_outputs_bulk`` are the mirror gather out of
``heap_out`` returning owned copies.  ``submit(..., data=...)`` does NOT
touch the device at call time: the payload is enqueued host-side
(HostQueues.stage) and the whole batch is flushed in the ``launch_once``
prologue — one staging transfer per daemon launch, so per-step grad-sync
cost scales with payload bytes instead of Python-loop iterations.  Per-SQE
dynamic buffer offsets (``in_off``/``out_off``) are honored end to end:
the staging engine adds the override to its relative index maps, and the
daemon applies the same override at SQE fetch.
"""
from __future__ import annotations

import collections
import dataclasses
import warnings
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .config import OcclConfig, ReduceOp
from .daemon import build_sim_daemon
from .primitives import (
    CollKind,
    CollectiveSpec,
    Communicator,
    derive_slicing,
    io_chunked,
)
from .sqcq import SQE, HostQueues
from .staging import StagingEngine
from .state import DaemonState, init_state
from .tables import StaticTables, build_tables


class RegistrationClosed(RuntimeError):
    pass


class DeadlockTimeout(RuntimeError):
    """drive() saw ``max_launches`` consecutive launches with NO progress
    (no completions reconciled and no slices moved) while work was still
    outstanding.

    With OCCL this means some member rank never submitted a matching
    collective (an application bug), NOT an ordering deadlock — inconsistent
    orders are handled by preemption.  Launches that make progress do not
    consume the budget: a long-lived workload may relaunch the daemon an
    unbounded number of times (the superstep budget is per launch)."""


class ConnDepthWarning(UserWarning):
    """conn_depth is too shallow to sustain the configured slice burst."""


class OcclRuntime:
    def __init__(self, cfg: OcclConfig, mesh=None, mesh_axis: str = "rank"):
        """mesh=None: sim backend (vmapped ranks on one device).
        mesh: a jax Mesh whose ``mesh_axis`` has cfg.n_ranks devices —
        the shard_map backend (ppermute connector fabric)."""
        self.cfg = cfg
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        self.comms: list[Communicator] = []
        self.specs: list[CollectiveSpec] = []
        # Separate allocation arenas for input and output buffers: in_off
        # indexes heap_in and out_off indexes heap_out — two DIFFERENT
        # arrays — so a shared pointer only interleaved dead holes into
        # both address spaces.  Independent pointers pack each heap's live
        # regions contiguously (the staging engine coalesces adjacent
        # regions into single stacked device ops) and double the usable
        # capacity per cfg.heap_elems.
        self._in_ptr = 0
        self._out_ptr = 0
        self._tables: Optional[StaticTables] = None
        self._staging: Optional[StagingEngine] = None
        self._daemon = None
        self._state: Optional[DaemonState] = None
        self.queues = HostQueues(cfg)
        self.launches = 0
        # Per-launch bookkeeping (relaunch observability): one record per
        # launch_once with the device epoch, the supersteps the launch ran,
        # the slices it moved and the completions it reconciled.  Bounded:
        # a long-lived runtime relaunches indefinitely, so only the most
        # recent window is kept (aggregates live in the device counters).
        self.launch_history: collections.deque = collections.deque(
            maxlen=1024)

    # ------------------------------------------------------------------
    # registration (paper Sec. 3.1.1)
    # ------------------------------------------------------------------
    def communicator(self, members: Sequence[int]) -> Communicator:
        if self._tables is not None:
            raise RegistrationClosed("register communicators before first launch")
        comm = Communicator(
            comm_id=len(self.comms), members=tuple(members),
            lane=len(self.comms))
        assert comm.lane < self.cfg.max_comms, "raise cfg.max_comms"
        self.comms.append(comm)
        return comm

    def _alloc_in(self, elems: int) -> int:
        off = self._in_ptr
        self._in_ptr += elems
        assert self._in_ptr <= self.cfg.heap_elems, "raise cfg.heap_elems"
        return off

    def _alloc_out(self, elems: int) -> int:
        off = self._out_ptr
        self._out_ptr += elems
        assert self._out_ptr <= self.cfg.heap_elems, "raise cfg.heap_elems"
        return off

    def register(self, kind: CollKind, comm: Communicator, n_elems: int,
                 op: ReduceOp = ReduceOp.SUM, root: int = 0) -> int:
        """Register a collective; returns its unique id (paper Sec. 3.1.1)."""
        if self._tables is not None:
            raise RegistrationClosed("register collectives before first launch")
        cid = len(self.specs)
        assert cid < self.cfg.max_colls, "raise cfg.max_colls"
        ns, rounds = derive_slicing(
            n_elems, comm.size, self.cfg.slice_elems, self.cfg.conn_depth)
        chunk = rounds * ns * self.cfg.slice_elems
        padded = comm.size * chunk
        inc, outc = io_chunked(kind)
        in_off = self._alloc_in(padded if inc else chunk)
        out_off = self._alloc_out(padded if outc else chunk)
        spec = CollectiveSpec(
            coll_id=cid, kind=kind, comm=comm, n_elems=n_elems, op=int(op),
            root=root, in_off=in_off, out_off=out_off, n_slices=ns,
            n_rounds=rounds)
        self.specs.append(spec)
        return cid

    # ------------------------------------------------------------------
    # lazy build (first launch closes registration)
    # ------------------------------------------------------------------
    def _ensure_built(self):
        if self._tables is None:
            if (self.cfg.burst_slices > 1
                    and self.cfg.conn_depth < 3 * self.cfg.burst_slices):
                warnings.warn(
                    f"conn_depth={self.cfg.conn_depth} < 3 * burst_slices="
                    f"{3 * self.cfg.burst_slices}: the connector cannot "
                    "cover the burst credit round trip, so sustained "
                    "throughput relaxes to the 1-slice/superstep "
                    "equilibrium (no faster than burst_slices=1).  Set "
                    "conn_depth >= 3 * burst_slices or auto_conn_depth=True.",
                    ConnDepthWarning, stacklevel=3)
            self._tables = build_tables(self.cfg, self.comms, self.specs)
            sharding = None
            if self.mesh is None:
                self._daemon = build_sim_daemon(self.cfg, self._tables)
            else:
                from jax.sharding import NamedSharding
                from jax.sharding import PartitionSpec as P

                from .daemon import build_shardmap_daemon
                # The [R, ...] state sharding: rank axis on the mesh axis.
                # Plumbed into the staging engine (per-device flush
                # placements skip the sim-style gathered commit) and into
                # init_state (state is born sharded).
                sharding = NamedSharding(self.mesh, P(self.mesh_axis))
                self._daemon = build_shardmap_daemon(
                    self.cfg, self._tables, self.mesh, self.mesh_axis)
            self._staging = StagingEngine(self.cfg, self._tables,
                                          sharding=sharding)
            self._state = init_state(self.cfg, per_rank=True,
                                     sharding=sharding)

    @property
    def state(self) -> DaemonState:
        self._ensure_built()
        return self._state

    # ------------------------------------------------------------------
    # data movement (send/recv buffers live in the per-rank heap)
    # ------------------------------------------------------------------
    def _spec(self, coll_id: int) -> CollectiveSpec:
        return self.specs[coll_id]

    def _resolve_off(self, coll_id: int, off: Optional[int], default: int,
                     span: int, name: str) -> int:
        """Default (None / -1 sentinel) or per-SQE-override base offset;
        overrides are bounds-checked and negatives other than the -1
        sentinel are rejected (an underflowed offset silently landing on
        the registered default is the silent-ignore bug class this layer
        exists to close)."""
        if off is None or off == -1:
            return default
        if off < 0 or off + span > self.cfg.heap_elems:
            raise ValueError(
                f"collective {coll_id}: {name} override {off} + padded "
                f"span {span} outside [0, heap_elems={self.cfg.heap_elems})")
        return off

    def _resolve_in_off(self, coll_id: int, off: Optional[int]) -> int:
        return self._resolve_off(coll_id, off, self._spec(coll_id).in_off,
                                 int(self._tables.in_span[coll_id]),
                                 "in_off")

    def _resolve_out_off(self, coll_id: int, off: Optional[int]) -> int:
        return self._resolve_off(coll_id, off, self._spec(coll_id).out_off,
                                 int(self._tables.out_span[coll_id]),
                                 "out_off")

    def write_input(self, rank: int, coll_id: int, data: np.ndarray,
                    in_off: Optional[int] = None) -> None:
        """Place logical input data into the rank's heap (padded layout,
        pad positions zero-filled).  Supersedes any payload staged at the
        same buffer by an earlier ``submit(..., data=...)``."""
        self._ensure_built()
        off = self._resolve_in_off(coll_id, in_off)
        self.queues.staged.pop((rank, coll_id, off), None)
        self._state = self._staging.write(
            self._state, [(rank, coll_id, data, off)])

    def write_inputs_bulk(self, writes: dict) -> None:
        """Batch heap writes: ``{(rank, coll_id): data}`` in ONE
        host->device transfer + one fused scatter.  To override the
        registered offset, pass the value as an ``(ndarray, in_off)``
        pair — the payload must be an ``np.ndarray`` in that form, so a
        plain tuple/list of numbers is always treated as data."""
        self._ensure_built()
        specs = self.specs
        staged = self.queues.staged
        items = []
        for (rank, coll_id), v in writes.items():
            if (isinstance(v, tuple) and len(v) == 2
                    and isinstance(v[0], np.ndarray)
                    and isinstance(v[1], (int, np.integer))):
                data, off = v[0], self._resolve_in_off(coll_id, v[1])
            else:                       # registered default: pre-validated
                data, off = v, specs[coll_id].in_off
            if staged:
                staged.pop((rank, coll_id, off), None)
            items.append((rank, coll_id, data, off))
        self._state = self._staging.write(self._state, items)

    def read_outputs_bulk(self, reads: list) -> dict:
        """Batch heap reads: ``[(rank, coll_id), ...]`` (or ``(rank,
        coll_id, out_off)``) with ONE fused gather + device->host transfer.
        Returns ``{(rank, coll_id): logical output}`` as owned copies."""
        self._ensure_built()
        specs = self.specs
        # Identical repeats dedup (pre-PR dict semantics); only CONFLICTING
        # offsets for one (rank, coll_id) are ambiguous — the result dict
        # could hold just one of them — and must be rejected.
        resolved: dict = {}
        for e in reads:
            off = (self._resolve_out_off(e[1], e[2]) if len(e) > 2
                   else specs[e[1]].out_off)
            prev = resolved.setdefault((e[0], e[1]), off)
            if prev != off:
                raise ValueError(
                    f"conflicting out_off reads for (rank={e[0]}, "
                    f"coll={e[1]}): {prev} vs {off}; read each "
                    "dynamic-offset result with its own read_output call")
        keys = [(r, c, off) for (r, c), off in resolved.items()]
        return self._staging.read(self._state, keys)

    def read_output(self, rank: int, coll_id: int,
                    out_off: Optional[int] = None) -> np.ndarray:
        """Gather logical output data from the rank's heap (un-pad);
        returns an owned copy (callers may mutate it in place)."""
        self._ensure_built()
        return self._staging.read(
            self._state,
            [(rank, coll_id, self._resolve_out_off(coll_id, out_off))]
        )[(rank, coll_id)]

    # ------------------------------------------------------------------
    # submission + event-driven execution (paper Sec. 3.1.2 / 3.1.3)
    # ------------------------------------------------------------------
    def submit(self, rank: int, coll_id: int, prio: int = 0,
               data: Optional[np.ndarray] = None,
               callback: Optional[Callable[[int, int], None]] = None,
               in_off: int = -1, out_off: int = -1) -> None:
        """Enqueue one SQE.  A payload passed via ``data`` is STAGED
        host-side and flushed to the device in the next ``launch_once``
        prologue (one batched transfer per launch), not written at call
        time.  ``in_off``/``out_off`` override the registered heap offsets
        for this submission (-1 keeps the defaults); the override is
        honored both by the daemon (SQE fetch) and by the staged write."""
        self._ensure_built()
        in_off = self._resolve_in_off(coll_id, in_off)
        out_off = self._resolve_out_off(coll_id, out_off)
        if data is not None:
            # snapshot() validates and COPIES: the flush happens at the
            # next launch prologue, and the pre-PR immediate-write
            # semantics captured the value at call time — a caller
            # reusing its buffer between submit and drive must not leak
            # the mutation in.
            self.queues.stage(rank, coll_id,
                              self._staging.snapshot(coll_id, data), in_off)
        self.queues.submit(rank, SQE(coll_id=coll_id, prio=prio,
                                     in_off=in_off, out_off=out_off,
                                     callback=callback))

    def submit_all(self, coll_id: int, prio: int = 0) -> None:
        spec = self._spec(coll_id)
        for r in spec.comm.members:
            self.submit(r, coll_id, prio=prio)

    def _flush_staged(self) -> None:
        """Launch prologue: drain the submit-time staging queue into the
        device heap — one batched scatter for every payload submitted
        since the previous launch."""
        staged = self.queues.take_staged()
        if staged:
            self._state = self._staging.write(self._state, staged,
                                              owned=True)

    def launch_once(self) -> int:
        """One daemon launch; returns #CQEs drained (may be 0)."""
        self._ensure_built()
        self._flush_staged()
        prev_slices = int(np.asarray(self._state.slices_moved).sum())
        st = self.queues.pack_sq(self._state)
        st = self._daemon(st)
        st = jax.block_until_ready(st)
        self.launches += 1
        self._state = st
        fired = self.queues.reconcile(st)
        self.launch_history.append({
            "epoch": int(np.asarray(st.epoch).max()),
            "launch_steps": int(np.asarray(st.launch_steps).max()),
            "slices_moved": int(np.asarray(st.slices_moved).sum())
                            - prev_slices,
            "completions": fired,
        })
        return fired

    def drive(self, max_launches: int = 64) -> None:
        """Event-driven daemon restarting: run while #CQE < #SQE (Sec. 3.1.3).

        ``max_launches`` bounds CONSECUTIVE launches without progress (no
        completions reconciled and no slices moved), not total launches: a
        workload whose span exceeds ``superstep_budget`` legitimately needs
        many launches, and each one that advances work resets the patience.
        """
        idle = 0
        while self.queues.outstanding() != 0:
            self.launch_once()
            rec = self.launch_history[-1]
            if rec["completions"] == 0 and rec["slices_moved"] == 0:
                idle += 1
            else:
                idle = 0
            if idle >= max_launches:
                raise DeadlockTimeout(
                    f"{self.queues.outstanding()} collectives outstanding "
                    f"after {idle} consecutive daemon launches without "
                    f"progress ({self.launches} total) — a member rank "
                    f"never submitted a matching collective")

    # ------------------------------------------------------------------
    # observability (paper Fig. 9)
    # ------------------------------------------------------------------
    def stats(self) -> dict:
        self._ensure_built()
        st = self._state
        return {
            "preempts": np.asarray(st.preempts),          # [R, C]
            "stall_slices": np.asarray(st.stall_slices),  # [R, C] — burst
                                                          # slices denied by
                                                          # the credit gate
            "qlen_at_fetch": np.asarray(st.qlen_at_fetch),
            "completed": np.asarray(st.completed),
            "supersteps": np.asarray(st.supersteps),      # cumulative epoch
                                                          # clock (never
                                                          # reset)
            "launch_steps": np.asarray(st.launch_steps),  # last launch only
            "epoch": np.asarray(st.epoch),                # device launch
                                                          # counter
            "slices_moved": np.asarray(st.slices_moved),
            "cq_count": np.asarray(st.cq_count),          # [R] — may exceed
                                                          # cq_len (ring CQ)
            "burst_slices": self.cfg.burst_slices,
            "launches": self.launches,
            "launch_history": list(self.launch_history),
            # Staging-flush accounting (mesh fast path observability):
            # payload bytes shipped by StagingEngine.write and how many of
            # those writes took the per-device sharded placement path.
            "staging_flush_writes": self._staging.flush_writes,
            "staging_flush_bytes": self._staging.flush_bytes,
            "staging_sharded_flushes": self._staging.sharded_flushes,
        }
