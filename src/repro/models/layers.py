"""Shared neural-net building blocks (pure JAX, no framework deps)."""
from __future__ import annotations

import os
import zlib

import jax
import jax.numpy as jnp
import numpy as np


def u_scan(body, carry, xs, length=None):
    """lax.scan that fully unrolls when REPRO_SCAN_UNROLL=1 (dry-run mode).

    XLA's cost_analysis counts while-loop bodies once, not x trip count;
    the dry-run unrolls layer/KV-block scans so HLO FLOPs/bytes and
    per-layer collectives are multiplied correctly.  Training/serving use
    the rolled scan (small HLO, fast compiles)."""
    unroll = os.environ.get("REPRO_SCAN_UNROLL") == "1"
    return jax.lax.scan(body, carry, xs, length=length,
                        unroll=True if unroll else 1)


def key_for(root: jax.Array, path: str) -> jax.Array:
    """Deterministic per-parameter RNG key (stable across processes)."""
    return jax.random.fold_in(root, zlib.crc32(path.encode()) & 0x7FFFFFFF)


def ninit(root, path, shape, scale, dtype):
    return (jax.random.normal(key_for(root, path), shape, jnp.float32)
            * scale).astype(dtype)


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(dt)


def layer_norm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: [..., S, H, dh]; positions: [..., S]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]   # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


def swiglu(x, wg, wu, wd):
    h = jax.nn.silu(x @ wg) * (x @ wu)
    return h @ wd


def geglu(x, wg, wu, wd):
    h = jax.nn.gelu(x @ wg, approximate=True) * (x @ wu)
    return h @ wd


def gelu_mlp(x, w1, b1, w2, b2):
    return jax.nn.gelu(x @ w1 + b1, approximate=True) @ w2 + b2


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  ignore_id: int = -1) -> jax.Array:
    """Mean token CE in f32.  logits [..., V]; targets [...] int32."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(
        lf, jnp.clip(targets, 0, lf.shape[-1] - 1)[..., None], axis=-1
    )[..., 0]
    nll = lse - gold
    mask = (targets != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
