"""Input specs and synthetic batches for every (arch x shape-cell).

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation) — the dry-run lowers from these.
``make_concrete`` materializes random arrays of the same specs for smoke
tests and real training on reduced configs.

Modality frontends are STUBS per assignment: ``[audio]`` seamless gets
precomputed frame embeddings, ``[vlm]``/``[vit]`` get patch embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, ShapeCell


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), jnp.dtype(dtype))


def train_specs(cfg: ArchConfig, cell: ShapeCell) -> dict:
    B, S = cell.global_batch, cell.seq_len
    cdt = cfg.compute_dtype
    if cfg.family == "vit":
        return {
            "patches": _sds((B, cfg.vis_tokens, cfg.d_model), cdt),
            "labels": _sds((B,), jnp.int32),
        }
    out = {}
    text = S
    if cfg.family == "vlm":
        text = S - cfg.vis_tokens
        out["patches"] = _sds((B, cfg.vis_tokens, cfg.d_model), cdt)
    if cfg.family == "encdec":
        out["frames"] = _sds((B, cfg.enc_frames, cfg.d_model), cdt)
    out["tokens"] = _sds((B, text), jnp.int32)
    out["targets"] = _sds((B, text), jnp.int32)
    return out


def prefill_specs(cfg: ArchConfig, cell: ShapeCell) -> dict:
    specs = train_specs(cfg, cell)
    specs.pop("targets", None)
    specs.pop("labels", None)
    return specs


def cache_specs(cfg: ArchConfig, cell: ShapeCell) -> dict:
    """Cache ShapeDtypeStructs for a decode cell (cache length = seq_len)."""
    B, Smax = cell.global_batch, cell.seq_len
    cdt = cfg.compute_dtype
    L = cfg.n_layers
    fam = cfg.family
    out = {"pos": _sds((), jnp.int32)}
    if fam in ("dense", "vlm", "moe", "encdec"):
        kv, dh = cfg.n_kv_heads, cfg.d_head
        out["k"] = _sds((L, B, Smax, kv, dh), cdt)
        out["v"] = _sds((L, B, Smax, kv, dh), cdt)
        if fam == "encdec":
            out["enc_out"] = _sds((B, cfg.enc_frames, cfg.d_model), cdt)
    elif fam in ("ssm", "hybrid"):
        H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
        GN = cfg.ssm_groups * N
        K1 = cfg.ssm_dconv - 1
        out["state"] = _sds((L, B, H, N, P), jnp.float32)
        out["conv_x"] = _sds((L, B, K1, cfg.d_inner), cdt)
        out["conv_B"] = _sds((L, B, K1, GN), cdt)
        out["conv_C"] = _sds((L, B, K1, GN), cdt)
        if fam == "hybrid":
            n_inv = cfg.n_layers // cfg.shared_attn_period
            kv, dh = cfg.n_kv_heads, cfg.d_head
            out["shared_k"] = _sds((n_inv, B, Smax, kv, dh), cdt)
            out["shared_v"] = _sds((n_inv, B, Smax, kv, dh), cdt)
    else:  # pragma: no cover
        raise ValueError(fam)
    return out


def decode_specs(cfg: ArchConfig, cell: ShapeCell) -> dict:
    return {"cache": cache_specs(cfg, cell),
            "tokens": _sds((cell.global_batch,), jnp.int32)}


def input_specs(cfg: ArchConfig, cell: ShapeCell) -> dict:
    if cell.kind == "train":
        return train_specs(cfg, cell)
    if cell.kind == "prefill":
        return prefill_specs(cfg, cell)
    return decode_specs(cfg, cell)


def make_concrete(specs, seed: int = 0, vocab: int = 1 << 30):
    """Random arrays matching a spec tree (smoke tests / CPU training)."""
    rng = np.random.RandomState(seed)

    def gen(s):
        if jnp.issubdtype(s.dtype, jnp.integer):
            hi = min(vocab, 1 << 15)
            return jnp.asarray(
                rng.randint(0, max(hi, 2), size=s.shape), s.dtype)
        return jnp.asarray(rng.randn(*s.shape) * 0.02, s.dtype)

    return jax.tree_util.tree_map(gen, specs)
