"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 routed top-8 + 1 shared
[arXiv:2501.kimi2 per assignment sheet].

Head dim is not on the sheet; we use 128 (MXU-aligned).  Moments are bf16
and ZeRO-1 is forced: 1T params do not fit 512 x 16 GB otherwise (see
EXPERIMENTS.md Dry-run notes).
"""
from .base import ArchConfig, _FULL_ATTN_500K_SKIP

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=2048, vocab=163840,
    n_experts=384, top_k=8, n_shared_experts=1, d_ff_expert=2048,
    param_dtype="bfloat16", moment_dtype="bfloat16", zero1=True,
    skip_cells=(_FULL_ATTN_500K_SKIP,),
)
