"""llama3-8b [dense] — GQA, 128k vocab [arXiv:2407.21783]."""
from .base import ArchConfig, _FULL_ATTN_500K_SKIP

CONFIG = ArchConfig(
    name="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab=128256, rope_theta=500_000.0,
    skip_cells=(_FULL_ATTN_500K_SKIP,),
)
