"""Serve a small model with batched requests (prefill + step-locked
decode over recycled batch slots).

    PYTHONPATH=src python examples/serve_batched.py --arch llama3-8b
"""
import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.configs import get_config
from repro.serving.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    eng = ServingEngine(cfg, batch_size=4, prompt_len=16)
    rng = np.random.RandomState(0)
    for i in range(args.requests):
        eng.submit(Request(
            rid=i,
            prompt=rng.randint(0, cfg.vocab, size=rng.randint(4, 16)),
            max_new_tokens=args.max_new))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    for r in done[:4]:
        print(f"req {r.rid}: {len(r.out_tokens)} tokens -> "
              f"{r.out_tokens[:8]}...")
    tok = eng.stats["tokens"]
    print(f"{len(done)} requests, {tok} tokens in {dt:.2f}s "
          f"({tok/dt:.1f} tok/s, {eng.stats['prefills']} prefills, "
          f"{eng.stats['decode_steps']} decode steps)")
    print("OK")


if __name__ == "__main__":
    main()
