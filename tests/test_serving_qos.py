"""Serving QoS layer: priority classes, preemption, aging, diagnosis.

Tier-1 (fabric-only, no model): the QoS layer maps serving traffic
classes onto the scheduler's priority strides, so these tests drive
real OCCL fabrics — a high-priority decode submit landing behind an
in-flight background burst must complete FIRST (CQE order) with the
preempt counter advancing, admission must cap background in-flight
bursts, priority aging must bound starvation, and a wedged background
chain must be diagnosed BY TENANT NAME.
"""
import numpy as np
import pytest

from repro.core.config import OcclConfig, OrderPolicy
from repro.core.primitives import CollKind
from repro.core.runtime import OcclRuntime
from repro.fabric.ft import ReliabilityController
from repro.serving.qos import (
    AGING_CAP, CLASS_STRIDE, ServingQos, TrafficClass, class_prio)


def _qos(**kw):
    kw.setdefault("n_ranks", 2)
    kw.setdefault("decode_elems", 64)
    kw.setdefault("prefill_elems", 128)
    kw.setdefault("background_elems", 1024)
    kw.setdefault("background_buckets", 1)
    return ServingQos(**kw)


def test_class_prio_ladder():
    assert class_prio(TrafficClass.BACKGROUND) == 0
    assert class_prio(TrafficClass.PREFILL) == CLASS_STRIDE
    assert class_prio(TrafficClass.DECODE) == 2 * CLASS_STRIDE
    # Intra-class offsets stay inside the stride: classes cannot bleed.
    assert class_prio(TrafficClass.PREFILL, CLASS_STRIDE - 1) \
        < class_prio(TrafficClass.DECODE)
    with pytest.raises(ValueError):
        class_prio(TrafficClass.DECODE, CLASS_STRIDE)
    # The default aging cap crosses exactly ONE class boundary:
    # BACKGROUND tops out just under DECODE.
    assert AGING_CAP == 2 * CLASS_STRIDE - 1
    assert class_prio(TrafficClass.BACKGROUND) + AGING_CAP \
        < class_prio(TrafficClass.DECODE)


def test_high_priority_cqe_first_and_preempts():
    """Interleaved high/low-priority collectives on ONE lane: the decode
    submit lands while the big background burst holds the connector, yet
    its CQE reconciles first and the preempt counter advances."""
    qos = _qos(preemption=True)
    bg = qos.submit_background()
    qos.advance(2)                      # background burst is mid-flight
    dec = qos.submit_decode()
    qos.wait(dec)
    assert dec["done_at"] is not None
    assert bg["done_at"] is None        # decode overtook the burst
    qos.drain()
    assert bg["done_at"] > dec["done_at"]
    assert qos.summary()["preempts"] > 0


def test_fifo_baseline_decode_waits_out_background():
    """Same interleaving, preemption off: FIFO order holds, so decode
    pays the whole background transfer — the contrast that makes the
    preemption win above meaningful (and a mini p99 comparison)."""
    def decode_latency(preemption):
        qos = _qos(preemption=preemption)
        qos.submit_background()
        qos.advance(2)
        lat = qos.wait(qos.submit_decode())
        qos.drain()
        return lat, qos.summary()

    lat_on, s_on = decode_latency(True)
    lat_off, s_off = decode_latency(False)
    assert lat_on < lat_off
    assert s_on["preempts"] > 0
    assert s_off["preempts"] == 0


def test_background_admission_cap():
    qos = _qos(preemption=True, background_buckets=3,
               max_background_inflight=2)
    assert qos.pump_background() == 2   # cap, not bucket count
    assert qos.submit_background() is None
    qos.drain()
    # Completions release admission slots.
    assert qos.admit_background()
    assert qos.pump_background() == 2
    qos.drain()
    bg = qos.tenants[TrafficClass.BACKGROUND]
    assert bg.completed == bg.submitted == 4


def test_priority_aging_bounds_starvation():
    """A continuous high-priority stream starves a low-priority burst
    under pure PRIORITY order; with aging the burst's effective priority
    climbs one step per quantum queued supersteps until it wins the lane.
    Run the identical schedule with aging on and off and compare the
    low-priority collective's fate at the same horizon."""
    def run(quantum):
        cfg = OcclConfig(
            n_ranks=2, max_colls=8, max_comms=1, slice_elems=32,
            conn_depth=2, order_policy=OrderPolicy.PRIORITY,
            priority_preempts=True, prio_aging_quantum=quantum,
            prio_aging_cap=511, quit_threshold=64)
        rt = OcclRuntime(cfg)
        comm = rt.communicator([0, 1])
        lo = rt.register(CollKind.ALL_REDUCE, comm, n_elems=256)
        hi = rt.register(CollKind.ALL_REDUCE, comm, n_elems=32)
        done = {"lo": None, "hi": 0}
        hi_cqes = [0]                   # per-rank completion events

        def lo_cb(rank, cid):
            done["lo"] = True

        def hi_cb(rank, cid):
            hi_cqes[0] += 1
            if hi_cqes[0] == cfg.n_ranks:
                hi_cqes[0] = 0
                done["hi"] += 1

        hi_subs = [0]
        rt.submit_all(lo, prio=0, callback=lo_cb)
        api = rt.device_api()
        import jax
        import jax.numpy as jnp
        tick = jax.jit(lambda st, k: api.tick(st, k, barrier=True)[0])
        for _ in range(120):
            # Adversary: a fresh high-priority op is queued before EVERY
            # tick that does not already have one in flight, so the
            # low-priority burst never sees an uncontended superstep.
            if done["hi"] == hi_subs[0]:
                rt.submit_all(hi, prio=8, callback=hi_cb)
                hi_subs[0] += 1
            rt._flush_staged()
            st = rt.queues.pack_sq(rt._state)
            st = jax.block_until_ready(tick(st, jnp.int32(1)))
            rt._state = st
            rt.queues.reconcile(st)
        return done

    aged = run(quantum=2)
    starved = run(quantum=0)
    assert aged["lo"] is True           # aging let the burst through
    assert starved["lo"] is None        # pure priority starved it
    assert aged["hi"] > 0 and starved["hi"] > 0


def test_diagnose_names_wedged_tenant():
    """Background submits on rank 0 only: the chain wedges, and both the
    QoS diagnosis and the serving-bound ReliabilityController name the
    BACKGROUND tenant (not a bare collective id) with the lagging rank
    as holder."""
    qos = _qos(preemption=True)
    bgh = qos.background[0]
    bgh.submit(0, data=np.ones(1024, np.float32))   # rank 1 never submits
    qos.advance(4)
    diag = qos.diagnose()
    assert len(diag) == 1
    assert diag[0]["tenant"] == "BACKGROUND"
    assert diag[0]["holding_ranks"] == [1]
    assert "never submitted" in diag[0]["reason"]

    ctrl = ReliabilityController.for_serving(qos)
    named = ctrl.diagnose_tenants()
    assert named and named[0]["tenant"] == "BACKGROUND"
    assert named[0]["coll_id"] == int(bgh)


def test_straggler_detector_observes_serving_tenant():
    """Decode traffic feeds the detector's collective EWMA through the
    SAME channel training collectives use — observe_step on a serving
    fabric is enough to seed the rtc-latency signal."""
    qos = _qos(preemption=True)
    ctrl = ReliabilityController.for_serving(qos)
    for _ in range(3):
        qos.wait(qos.submit_decode())
    ctrl.observe_step()
    assert ctrl.detector.coll_seen.any()
    assert not ctrl.detector.suspect.any()
    assert ctrl.detector.healthy_ranks() == list(range(2))


def test_replay_determinism():
    """Identical traffic on identical configs produces identical
    superstep latencies — the property the bench gates lean on."""
    def run():
        qos = _qos(preemption=True, prio_aging_quantum=8)
        lats = []
        for _ in range(3):
            qos.pump_background()
            lats.append(qos.wait(qos.submit_decode()))
        qos.drain()
        return lats, qos.summary()["preempts"]

    assert run() == run()


def test_summary_counts_reconcile():
    qos = _qos(preemption=True)
    recs = [qos.submit_decode(), qos.submit_prefill(),
            qos.submit_background()]
    qos.drain()
    assert all(r["done_at"] is not None for r in recs)
    s = qos.summary()
    for cls in TrafficClass:
        t = qos.tenants[cls]
        assert t.completed == t.submitted
        assert s[cls.name.lower()]["completed"] == t.completed
        assert len(t.latencies) == t.completed
