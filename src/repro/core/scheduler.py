"""Per-rank daemon superstep: the core of the DFCE-framework (paper Sec. 3.1).

One superstep, per rank:
  A. apply arriving connector messages (slice-burst commits + credit counts);
  B. maybe fetch one SQE (order policy controls eagerness, Sec. 3.2);
  C. all lanes at once: select each lane's current collective (two-phase
     blocking), gate a *burst* of up to ``cfg.burst_slices`` slice moves of
     its current primitive on connector credit, execute or spin/preempt
     (spin thresholds + stickiness, Sec. 3.2);
  D. bookkeeping for voluntary quit (Sec. 3.1.3).

Vectorized/burst execution (perf tentpole)
------------------------------------------
Phase C is *batched across lanes*: selection, gating and context advance are
[L, ...] array ops instead of a sequential Python loop of per-lane steps.
This is semantically faithful because eligibility is lane-partitioned
(``shared.lane[c] == lane``), so concurrent lanes never touch the same
collective's counters; the only shared sinks — the output heap, the CQ ring
and the scalar work counters — are combined with masked scatters
(``mode='drop'``) and cumulative-sum slot assignment.  The per-superstep
cost drops from L serialized full-heap ``dynamic_update_slice`` +
``lax.select`` copies (O(L * H)) to one [L, B * SLICE] windowed scatter, and
the O(C^2) queue-position comparison matrix is replaced by one batched
stable double-argsort shared by all lanes (O(L * C log C)).

A *burst* moves up to B contiguous slices of the lane's current primitive in
one superstep, where B = ``cfg.burst_slices``.  The burst is gated by
:func:`repro.core.primitives.burst_quota`: it never crosses a primitive-step
boundary and never exceeds the connector credit visible in the lagging
``head/tail`` mirrors, which now admit *counts* rather than booleans.  Why
deadlock freedom survives bursts: every slice of a burst is individually
credit-accounted, so the ring-capacity invariant from ``derive_slicing`` —
``sum(sent - consumed) <= R * (K - 1)`` around any communicator ring — still
guarantees an edge with both data and capacity; and a collective remains
preemptible *between* bursts (spin thresholds are evaluated every superstep,
B only bounds the atomic quantum, which is itself bounded by the per-round
slice cap K - 1).  With B = 1 the schedule is exactly the seed single-slice
semantics.

Sizing note: sustained burst throughput needs the connector depth to cover
the burst bandwidth-delay product — credits complete a ~3-superstep round
trip (commit, consume, credit-return), so K should be >= ~3B.  With a
shallower connector the ring saturates (in-flight == K) and relaxes into
the 1-slice/superstep credit-return equilibrium: still correct and
deadlock-free, just no faster than B = 1 (benchmarks/bench_collectives.py
uses conn_depth=32 for the B in {1, 4, 8} sweep; ``cfg.auto_conn_depth``
derives the bound automatically, and the runtime warns at registration
time when it is not met).

Launch-epoch clock + burst-aware stall accounting
-------------------------------------------------
Scheduling decisions are measured against the PER-LAUNCH clock
``st.launch_steps`` (zeroed in the daemon prologue), never the cumulative
``st.supersteps`` epoch clock:

* **Queue age.**  :func:`rebase_arrivals` (called from the prologue)
  compresses every active collective's ``arrival`` to its queue rank, a
  value < C; fetches and rotations during the launch stamp
  ``C + launch_steps``.  Arrival keys are therefore bounded by
  ``C + superstep_budget + 2`` per launch — validated in config to sit
  below ``QUEUE_KEY_DEMAND_STRIDE`` so the demand bonus and the PRIORITY
  class stride (``QUEUE_KEY_PRIO_STRIDE``) cannot bleed into the FIFO age
  no matter how many cumulative supersteps the runtime has executed.

* **Stall units.**  On a zero-progress superstep ``spin`` advances by the
  slices the credit gate DENIED (``min(B, room) - quota``, floored at 1),
  not by 1 per superstep; any partial grant still resets ``spin`` to 0
  (progress), exactly like the seed.  At B = 1 the two accountings are
  identical; at B > 1 a fully-stalled lane reaches its spin threshold up
  to B× sooner, so under contention the lane multiplexes between
  collectives at the same *slice* cadence it executes them, instead of
  wasting B-wide supersteps spinning.  The stall weight is QUEUE-LENGTH
  CONDITIONAL (``cfg.queue_conditional_stall``): a lane whose task queue
  holds no other eligible collective advances by 1 per stalled superstep
  instead — preempting a solo collective frees nothing, so B×-eager
  rotation during the ~3-superstep credit round trip would be pure churn
  (preempt-counter noise, boost resets).  Denied slices — including
  partial denials on supersteps that did move some slices — always
  accumulate unweighted in ``st.stall_slices`` (per collective) for
  Fig. 9-style observability.

Everything is branch-free fixed-shape array code so the loop compiles into
a single long-running XLA program — the daemon-kernel analogue.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import (
    QUEUE_KEY_DEMAND_STRIDE,
    QUEUE_KEY_PRIO_STRIDE,
    OcclConfig,
    OrderPolicy,
    ReduceOp,
)
from . import primitives as P
from .primitives import Prim
from .recorder import (
    EV_CHAIN_HANDOFF,
    EV_CQE,
    EV_PREEMPT,
    EV_STAGE_DONE,
    EV_SUBMIT,
    N_EVENT_KINDS,
)
from .state import DaemonState

# Queue-key stride between priority classes (per-launch arrival + demand
# bonus stay below this; see config.py for the class-separation proof).
_BIG = jnp.int32(QUEUE_KEY_PRIO_STRIDE)
_DEMAND = jnp.int32(QUEUE_KEY_DEMAND_STRIDE)

# Primitive action-flag lookups as device arrays (indexable by tracers).
PRIM_RECV = jnp.asarray(P.PRIM_RECV)
PRIM_SEND = jnp.asarray(P.PRIM_SEND)
PRIM_REDUCE = jnp.asarray(P.PRIM_REDUCE)
PRIM_COPY = jnp.asarray(P.PRIM_COPY)
PRIM_READS_IN = jnp.asarray(P.PRIM_READS_IN)


class SharedTables(NamedTuple):
    """Rank-independent static context (vmap in_axes=None)."""

    registered: jnp.ndarray   # [C] bool
    kind: jnp.ndarray         # [C]
    op: jnp.ndarray           # [C]
    lane: jnp.ndarray         # [C]
    lane_caps: jnp.ndarray    # [L] — per-lane slice burst cap (uniform
                              #   burst_slices unless the bandwidth-skew
                              #   model classifies the lane; <= B always,
                              #   so mailbox payload width is unchanged)
    n_steps: jnp.ndarray      # [C]
    n_slices: jnp.ndarray     # [C]
    n_rounds: jnp.ndarray     # [C]
    in_chunked: jnp.ndarray   # [C]
    out_chunked: jnp.ndarray  # [C]
    base_in_off: jnp.ndarray  # [C]
    base_out_off: jnp.ndarray # [C]
    # Composite-chain tables (tables.StaticTables; all-identity /
    # all-sentinel when no composite collectives are registered).
    next_coll: jnp.ndarray    # [C] — device-enqueued successor (-1 none)
    chain_tail: jnp.ndarray   # [C] — tail stage of c's chain (self: flat)
    chain_prio_inherit: jnp.ndarray  # [C] bool
    chain_mask: jnp.ndarray   # [C, C] bool — stages sharing c's chain
    chain_src: jnp.ndarray    # [C, M] — heap relink gather map (M == 0
                              #   when chain-free: the relink scatter is
                              #   not traced at all)
    chain_dst: jnp.ndarray    # [C, M]


class LocalTables(NamedTuple):
    """Per-rank static context (vmap in_axes=0)."""

    member: jnp.ndarray       # [C] bool
    prog_kind: jnp.ndarray    # [C, S]
    prog_chunk: jnp.ndarray   # [C, S]
    # Per-rank composite-chain maps (tables._build_rank_chain_maps): a
    # chain stage may cover only a subset of the logical members, so each
    # rank advances to ITS next participating stage and completes
    # logically at ITS last one.  Equal to the shared next_coll /
    # chain_tail rows for full-membership chains; -1 / self for flat.
    chain_next: jnp.ndarray   # [C] — rank's successor stage (-1 = tail)
    chain_tail_r: jnp.ndarray # [C] — rank's chain tail (self for flat)


class Mailbox(NamedTuple):
    """Per-lane connector traffic for one superstep (fwd burst + rev credit).

    ``fwd_count`` / ``rev_count`` are slice/credit *counts* (0..B), not
    validity bools: one superstep may commit a whole burst.
    """

    fwd_count: jnp.ndarray    # [L] i32 — slices committed this superstep
    fwd_coll: jnp.ndarray     # [L] i32
    fwd_payload: jnp.ndarray  # [L, B, SLICE]
    rev_count: jnp.ndarray    # [L] i32 — credits returned this superstep
    rev_coll: jnp.ndarray     # [L] i32


def _combine_by_op(op: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray
                   ) -> jnp.ndarray:
    """Per-lane reduction select: ``op`` is [L], a/b are [L, ...].

    A where-chain over the four ReduceOps is bit-identical to the seed's
    per-lane ``lax.switch`` (same elementwise ops, same operand order).
    """
    opc = jnp.clip(op, 0, 3).reshape(op.shape + (1,) * (a.ndim - 1))
    return jnp.where(
        opc == 0, a + b,
        jnp.where(opc == 1, jnp.maximum(a, b),
                  jnp.where(opc == 2, jnp.minimum(a, b), a * b)))


def _effective_prio(cfg, st):
    """Live priority including the bounded queue-age bump ([C]).

    With ``cfg.prio_aging_quantum`` set, a queued collective earns
    ``min(age // quantum, cap)`` extra priority where age is its launch-
    clock queue residency (``max_colls + launch_steps - arrival``) — the
    QoS starvation bound: a low class overtakes the class above it after
    a config-bounded wait, but the cap (<= one class stride in
    serving/qos.py) keeps it below the top class.  Clipped to the same
    +/-512 band as user priority so the queue-key magnitude proof in
    config.py is unchanged.  Quantum 0 returns ``st.prio`` untouched —
    bit-identical to the pre-aging scheduler.
    """
    if cfg.prio_aging_quantum <= 0:
        return st.prio
    age = jnp.maximum(
        jnp.int32(cfg.max_colls) + st.launch_steps - st.arrival, 0)
    bump = jnp.minimum(age // jnp.int32(cfg.prio_aging_quantum),
                       jnp.int32(cfg.prio_aging_cap))
    return jnp.clip(st.prio + bump, -512, 512)


def _lane_keys(cfg, st, shared, local):
    """Ascending queue-order key per collective for every lane at once.

    Returns (eligible [L, C], key [L, C]); front of lane l's queue is
    ``argmin(key[l])`` (ties broken by lowest collective id, matching the
    seed's comparison-matrix tie-break).
    """
    L = cfg.max_comms
    lanes = jnp.arange(L, dtype=jnp.int32)
    eligible = (st.tq_active & local.member)[None, :] \
        & (shared.lane[None, :] == lanes[:, None])
    key = jnp.broadcast_to(st.arrival[None, :], eligible.shape)
    if cfg.demand_steering:
        # Data already waiting in the recv connector => ring peers are on
        # this collective; steering toward it is the fastest decentralized
        # gang-convergence signal available (beyond-paper policy).
        demand = (st.tail < st.head_mirror).astype(jnp.int32)
        key = key - demand[None, :] * _DEMAND
    if cfg.order_policy == OrderPolicy.PRIORITY:
        # Higher priority first; FIFO (+demand) within equal priority.
        # Aging (if configured) bumps the effective class of long-queued
        # collectives — the serving QoS starvation bound.
        key = (-_effective_prio(cfg, st)[None, :]) * _BIG + key
    key = jnp.where(eligible, key, jnp.iinfo(jnp.int32).max)
    return eligible, key


def _lane_positions(key):
    """Task-queue position per (lane, collective) — batched stable ranks.

    ``argsort(argsort(key))`` along the collective axis yields each entry's
    rank in ascending key order with ties broken by index (jnp.argsort is
    stable), replacing the seed's O(C^2) pairwise comparison matrix.
    """
    order = jnp.argsort(key, axis=1)
    return jnp.argsort(order, axis=1).astype(jnp.int32)


def _thresholds(cfg, st, pos):
    """Effective spin thresholds (stickiness scheme, Sec. 3.2); [L, C]."""
    if cfg.stickiness:
        base = cfg.spin_base - pos * cfg.spin_decr + st.boost[None, :]
    else:
        base = jnp.full_like(pos, cfg.spin_base)
    return jnp.clip(base, cfg.spin_min, cfg.spin_max)


def rebase_arrivals(st: DaemonState) -> DaemonState:
    """Launch prologue: re-express queue age on the fresh launch clock.

    Active collectives keep their relative order but their ``arrival``
    values are compressed to queue ranks (< C, ties broken by lowest
    collective id exactly like the key argmin); inactive slots reset to 0.
    New fetches/rotations during the launch stamp ``C + launch_steps``, so
    carryover work always sorts ahead of work that arrives later — the
    same order the unbounded epoch clock produced, now bounded per launch.

    Operates on the last axis, so it works on both the per-rank [C] state
    (mesh backend) and the batched [R, C] state (sim backend).
    """
    key = jnp.where(st.tq_active, st.arrival, jnp.iinfo(jnp.int32).max)
    order = jnp.argsort(key, axis=-1)
    ranks = jnp.argsort(order, axis=-1).astype(jnp.int32)
    return st._replace(arrival=jnp.where(st.tq_active, ranks, 0))


@functools.lru_cache(maxsize=None)
def _burst_offsets(L: int, B: int) -> np.ndarray:
    """Precomputed [L, B] burst-offset table for the inbox scatter (the
    static part of the row/slot index grid; a cached HOST constant — a
    device array built here would be a tracer inside the daemon trace)."""
    return np.ascontiguousarray(
        np.broadcast_to(np.arange(B, dtype=np.int32)[None, :], (L, B)))


def apply_inbox(cfg: OcclConfig, st: DaemonState, inbox: Mailbox
                ) -> DaemonState:
    """Phase A: commit arriving slice bursts into the recv-connector mirror
    and arriving credit counts into the send-side tail mirror — one batched
    scatter over all lanes.

    With ``cfg.vectorized_inbox`` the (coll, slot) scatter grid is
    flattened through the precomputed [L, B] burst-offset table into ONE
    single-axis scatter over the [C*K, SLICE] payload view (the inbox
    analogue of the heap-window trick: one index dimension instead of a
    two-axis scatter; masked entries route to the dropped row C*K).  The
    written slots and values are identical either way — bit-identical
    results, guarded by the fast-path equivalence tests.
    """
    K, B, C = cfg.conn_depth, cfg.burst_slices, cfg.max_colls
    L = cfg.max_comms
    bidx = _burst_offsets(L, B)                             # [L, B]

    c = jnp.clip(inbox.fwd_coll, 0, C - 1)                  # [L]
    cnt = jnp.clip(inbox.fwd_count, 0, B)                   # [L]
    take = bidx < cnt[:, None]                              # [L, B]
    slot = (st.head_mirror[c][:, None] + bidx) % K
    vals = inbox.fwd_payload.astype(st.payload.dtype)
    # Lanes are coll-disjoint (a collective is bound to one lane); masked
    # entries are routed to a dropped target.
    if cfg.vectorized_inbox:
        flat = jnp.where(take, c[:, None] * K + slot, C * K)
        payload = (st.payload.reshape(C * K, -1)
                   .at[flat].set(vals, mode="drop")
                   .reshape(st.payload.shape))
    else:
        row = jnp.where(take, c[:, None], C)
        payload = st.payload.at[row, slot].set(vals, mode="drop")
    head_mirror = st.head_mirror.at[c].add(cnt)

    rc = jnp.clip(inbox.rev_coll, 0, C - 1)
    tail_mirror = st.tail_mirror.at[rc].add(
        jnp.maximum(inbox.rev_count, 0))
    return st._replace(
        head_mirror=head_mirror, tail_mirror=tail_mirror, payload=payload
    )


def _record_events(cfg: OcclConfig, st: DaemonState, kinds: jnp.ndarray,
                   colls: jnp.ndarray, valid: jnp.ndarray) -> DaemonState:
    """Append masked events to the rank's flight-recorder ring.

    Same masked-scatter ring-append pattern as the CQ ring (lanes_step):
    exclusive-cumsum slot assignment over the valid mask, invalid entries
    routed to a dropped target.  A batch larger than the ring would map
    two events onto one slot WITHIN a single scatter (nondeterministic
    winner), so all but the newest ``recorder_len`` events of the batch
    are pre-dropped — ring semantics ("keep the newest events") are
    unchanged and the write stays collision-free for any
    ``recorder_len >= 1``.  ``fr_step`` stamps the cumulative epoch
    clock; ``fr_kinds`` keeps wrap-proof per-kind cumulative counters
    (dropped events still count).  Compiled out entirely when
    ``cfg.flight_recorder`` is off.
    """
    if not cfg.flight_recorder:
        return st
    FR = cfg.recorder_len
    n = valid.astype(jnp.int32)
    off = jnp.cumsum(n) - n                                 # exclusive scan
    total = jnp.sum(n)
    keep = valid & (off >= total - FR)
    slot = (st.fr_count + off) % FR
    tgt = jnp.where(keep, slot, FR)
    ktgt = jnp.where(valid, kinds, N_EVENT_KINDS)
    return st._replace(
        fr_kind=st.fr_kind.at[tgt].set(kinds, mode="drop"),
        fr_coll=st.fr_coll.at[tgt].set(colls, mode="drop"),
        fr_step=st.fr_step.at[tgt].set(st.supersteps, mode="drop"),
        fr_count=st.fr_count + total,
        fr_kinds=st.fr_kinds.at[ktgt].add(1, mode="drop"),
    )


def fetch_sqe(cfg: OcclConfig, st: DaemonState, shared: SharedTables,
              local: LocalTables) -> tuple[DaemonState, jnp.ndarray]:
    """Phase B: pop at most one SQE into the task queue (paper Sec. 3.1.2).

    FIFO policy fetches lazily (queue empty or stuck); PRIORITY fetches
    eagerly every superstep (paper: "checking the SQ more frequently").
    """
    has_sqe = st.sq_read < st.sq_size
    if cfg.order_policy == OrderPolicy.PRIORITY:
        want = has_sqe
    else:
        stuck_or_empty = (~st.made_prog_prev) | (~jnp.any(st.tq_active))
        want = has_sqe & stuck_or_empty
    slot = jnp.clip(st.sq_read, 0, cfg.sq_len - 1)
    c = st.sq_coll[slot]
    # Head-of-line wait: a re-submission of an in-flight collective waits
    # (the runtime never has two executions of one collective concurrently).
    # For a composite chain the head's inflight bit covers the WHOLE chain
    # (set below via chain_mask, cleared when the tail completes), so a
    # re-submitted chain head also waits for its predecessor's device-
    # enqueued stages to drain.
    ok = want & (c >= 0) & ~st.inflight[c] & local.member[c] & shared.registered[c]
    qlen = jnp.sum(st.tq_active).astype(jnp.int32)
    one = jnp.where(ok, 1, 0)
    # Per-SQE out_off overrides resolve END-TO-END: the override (or the
    # tail's registered default) lands on THIS RANK'S chain tail — its
    # logical output endpoint — while a chained head keeps its registered
    # intermediate output region.  Flat collectives have tail == c, so
    # the second write is a no-op and the behavior is exactly the seed's.
    # On a partial-membership chain a rank whose own tail is NOT the
    # logical tail (e.g. tree-reduce non-leaders) ignores the override:
    # it was sized for the logical endpoint's span, and this rank's
    # output is not part of the logical result.
    tail = local.chain_tail_r[c]
    use_ovr = (st.sq_out[slot] >= 0) & (tail == shared.chain_tail[c])
    resolved_out = jnp.where(use_ovr, st.sq_out[slot],
                             shared.base_out_off[tail])
    out_off = st.out_off.at[tail].set(
        jnp.where(ok, resolved_out, st.out_off[tail]))
    out_off = out_off.at[c].set(
        jnp.where(ok & (tail != c), shared.base_out_off[c], out_off[c]))
    st = st._replace(
        tq_active=st.tq_active.at[c].set(jnp.where(ok, True, st.tq_active[c])),
        inflight=st.inflight | (shared.chain_mask[c] & ok),
        # Launch-clock queue age: behind every rebased carryover (< C).
        arrival=st.arrival.at[c].set(
            jnp.where(ok, cfg.max_colls + st.launch_steps, st.arrival[c])),
        prio=st.prio.at[c].set(jnp.where(
            ok, jnp.clip(st.sq_prio[slot], -512, 512), st.prio[c])),
        in_off=st.in_off.at[c].set(jnp.where(
            ok,
            jnp.where(st.sq_in[slot] >= 0, st.sq_in[slot], shared.base_in_off[c]),
            st.in_off[c])),
        out_off=out_off,
        ctx_step=st.ctx_step.at[c].set(jnp.where(ok, 0, st.ctx_step[c])),
        ctx_slice=st.ctx_slice.at[c].set(jnp.where(ok, 0, st.ctx_slice[c])),
        ctx_round=st.ctx_round.at[c].set(jnp.where(ok, 0, st.ctx_round[c])),
        spin=st.spin.at[c].set(jnp.where(ok, 0, st.spin[c])),
        boost=st.boost.at[c].set(jnp.where(ok, 0, st.boost[c])),
        qlen_at_fetch=st.qlen_at_fetch.at[c].set(
            jnp.where(ok, qlen, st.qlen_at_fetch[c])),
        # Ready-to-complete clock: stamp queue entry on the cumulative
        # supersteps clock (monotonic across launches, so a collective
        # carried over a relaunch keeps accruing latency).
        fetch_step=st.fetch_step.at[c].set(
            jnp.where(ok, st.supersteps, st.fetch_step[c])),
        sq_read=st.sq_read + one,
    )
    st = _record_events(
        cfg, st,
        kinds=jnp.full((1,), EV_SUBMIT, jnp.int32),
        colls=jnp.reshape(c, (1,)),
        valid=jnp.reshape(ok, (1,)))
    return st, ok


def lanes_step(cfg: OcclConfig, st: DaemonState, shared: SharedTables,
               local: LocalTables, cond_relink: bool = False,
               defer_relink: bool = False
               ) -> tuple[DaemonState, jnp.ndarray, Mailbox]:
    """Phase C for ALL lanes: two-phase-blocking selection + one credit-gated
    slice burst per lane, fully vectorized over the lane axis.

    ``cond_relink`` wraps the chain-relink scatter in a ``lax.cond`` on
    "any chained stage completed this superstep" (mesh backend; each
    device's predicate is a scalar, so the branch is real and chain-free
    supersteps skip the gather entirely).

    ``defer_relink`` skips the in-step relink altogether: the caller is
    responsible for applying it after the step from the
    ``stage_completions`` delta (sim backend — under vmap the per-rank
    cond predicate is batched and would lower to a select that executes
    the O(M)-element gather EVERY superstep; the sim driver instead
    reduces the predicate over ranks outside the vmap, where the cond
    stays a real branch).

    Returns (state, moved_any, outbox).
    """
    K, SL, B = cfg.conn_depth, cfg.slice_elems, cfg.burst_slices
    C, L = cfg.max_colls, cfg.max_comms
    lanes = jnp.arange(L, dtype=jnp.int32)
    bidx = jnp.arange(B, dtype=jnp.int32)

    eligible, key = _lane_keys(cfg, st, shared, local)
    pos = _lane_positions(key)
    thr = _thresholds(cfg, st, pos)

    cur = st.cur                                            # [L]
    cur_c = jnp.clip(cur, 0, C - 1)
    cur_ok = (cur >= 0) & eligible[lanes, cur_c]
    overspun = cur_ok & (st.spin[cur_c] > thr[lanes, cur_c])
    if cfg.priority_preempts:
        # Same effective priority (aging included) as the queue key, so
        # an aged-up collective both sorts ahead AND preempts — one
        # consistent class ladder.
        ep = _effective_prio(cfg, st)
        higher = jnp.any(
            eligible & (ep[None, :] > ep[cur_c][:, None]), axis=1)
        overspun = overspun | (cur_ok & higher)

    # Preempt: context switch — dynamic context stays in the context buffer
    # (it already lives in ctx_* arrays: the lazy-saving optimization of
    # Sec. 4 is structural here), rotate to the back of the queue.  Overspun
    # lanes own disjoint collectives, so the scatter-add mask is exact.
    rot = jnp.zeros((C,), jnp.int32).at[cur_c].add(
        overspun.astype(jnp.int32)) > 0
    st = st._replace(
        preempts=st.preempts + rot.astype(st.preempts.dtype),
        arrival=jnp.where(rot, cfg.max_colls + st.launch_steps + 1,
                          st.arrival),
        spin=jnp.where(rot, 0, st.spin),
        boost=jnp.where(rot, 0, st.boost),
    )
    keep = cur_ok & ~overspun

    # Queue front after a possible rotation (only `arrival` changed).
    eligible, key = _lane_keys(cfg, st, shared, local)
    front = jnp.argmin(key, axis=1).astype(jnp.int32)       # [L]
    any_eligible = jnp.any(eligible, axis=1)
    cand = jnp.where(keep, cur, jnp.where(any_eligible, front, -1))
    c = jnp.clip(cand, 0, C - 1)                            # [L]
    valid = cand >= 0
    # Valid lanes select distinct collectives (lane-partitioned
    # eligibility); invalid lanes are routed to dropped scatter targets.
    cv = jnp.where(valid, c, C)                             # valid-gated tgt

    # --- gate a slice burst of the current primitive ---------------------
    step = jnp.clip(st.ctx_step[c], 0, local.prog_kind.shape[1] - 1)
    prim = local.prog_kind[c, step]                         # [L]
    chunk = local.prog_chunk[c, step]
    sl = st.ctx_slice[c]
    needs_recv = PRIM_RECV[prim] > 0
    needs_send = PRIM_SEND[prim] > 0
    does_reduce = PRIM_REDUCE[prim] > 0
    does_copy = PRIM_COPY[prim] > 0
    reads_in = PRIM_READS_IN[prim] > 0

    nsl = shared.n_slices[c]
    recv_avail = st.head_mirror[c] - st.tail[c]
    send_free = K - (st.head[c] - st.tail_mirror[c])
    # Per-lane burst width: the uniform cfg.burst_slices unless the
    # bandwidth-skew model capped this lane's class (lane_caps <= B, so
    # mailbox geometry is untouched; with the model off this is a [L]
    # array of B and every value below matches the scalar-B math).
    Bl = shared.lane_caps                                   # [L]
    quota = P.burst_quota(Bl, nsl - sl, recv_avail, send_free,
                          needs_recv, needs_send)
    gate = valid & (prim != Prim.NULL) & (quota > 0)
    n = jnp.where(gate, quota, 0)                           # [L] burst size
    # Burst-aware stall accounting: the slices this lane WANTED (a full
    # burst, capped by the primitive step) minus the slices the credit
    # gate granted, floored at one so a stalled B = 1 superstep advances
    # spin by exactly 1 — bit-identical to the seed superstep counting.
    want = jnp.minimum(Bl, jnp.maximum(nsl - sl, 1))
    denied = jnp.maximum(want - n, 1)                       # [L] denied
    # Queue-length-conditional stall weight: preempting a SOLO collective
    # (no other eligible collective queued on its lane) frees nothing, so
    # a lane briefly blocked on the burst credit round trip should not
    # reach its spin threshold B× sooner — it advances by 1 per stalled
    # superstep (the seed cadence).  Contended lanes keep the fast
    # B-scaled denied-slice accounting that closed the PR-2 contention
    # gap.  ``eligible`` includes the current collective, so solo means
    # queue length <= 1.
    if cfg.queue_conditional_stall:
        solo = jnp.sum(eligible, axis=1) <= 1               # [L]
        stalled = jnp.where(solo, 1, denied)
    else:
        stalled = denied

    # --- execute the fused actions on the burst (paper Fig. 3) -----------
    slots = (st.tail[c][:, None] + bidx[None, :]) % K       # [L, B] ring read
    recv_val = st.payload[c[:, None], slots]                # [L, B, SL]
    rnd = st.ctx_round[c]
    chunk_stride = shared.n_rounds[c] * nsl * SL   # padded chunk extent
    within = (rnd * nsl + sl) * SL                 # (round, slice) offset
    in_base = (st.in_off[c]
               + jnp.where(shared.in_chunked[c] > 0, chunk, 0) * chunk_stride
               + within)
    out_base = (st.out_off[c]
                + jnp.where(shared.out_chunked[c] > 0, chunk, 0) * chunk_stride
                + within)
    # Per-lane contiguous [B*SL] windows (bursts never straddle a step
    # boundary, so the slice range is contiguous in the heap).  L is a
    # small static constant; dynamic_slice stays a memcpy where a batched
    # elementwise gather/scatter would serialize on CPU/TPU backends.
    span = jnp.arange(B * SL, dtype=jnp.int32)
    in_val = jnp.stack([
        jax.lax.dynamic_slice(st.heap_in, (in_base[l],), (B * SL,))
        for l in range(L)
    ]).reshape(L, B, SL)

    opv = shared.op[c]
    if cfg.use_pallas:
        from ..kernels import ops as kops
        flags = jnp.stack([
            needs_recv.astype(jnp.int32), does_reduce.astype(jnp.int32),
            reads_in.astype(jnp.int32), opv.astype(jnp.int32),
        ], axis=1)                                          # [L, 4]
        flags_lb = jnp.broadcast_to(
            flags[:, None, :], (L, B, 4)).reshape(L * B, 4)
        value = kops.fused_primitive_batch(
            recv_val.reshape(L * B, SL), in_val.reshape(L * B, SL),
            flags_lb).reshape(L, B, SL)
    else:
        reduced = _combine_by_op(opv, recv_val, in_val)
        sel = lambda m: m[:, None, None]
        value = jnp.where(
            sel(does_reduce), reduced,
            jnp.where(sel(needs_recv), recv_val,
                      jnp.where(sel(reads_in), in_val,
                                jnp.zeros_like(in_val))))

    # Per-lane [B*SL] read-modify-write windows replace the seed's L
    # serialized full-heap dynamic_update_slice + lax.select copies
    # (O(L * B * SLICE) moved instead of O(L * H)).  The heap carries
    # B*SLICE scratch padding (state.init_state) so windows at the top of
    # the allocated region never clamp-shift.
    write_out = gate & does_copy
    out_limit = jnp.where(write_out, n, 0) * SL             # elems to write
    vals = value.reshape(L, B * SL).astype(st.heap_out.dtype)
    heap_out = st.heap_out
    for l in range(L):
        window = jax.lax.dynamic_slice(heap_out, (out_base[l],), (B * SL,))
        blend = jnp.where(span < out_limit[l], vals[l], window)
        heap_out = jax.lax.dynamic_update_slice(heap_out, blend,
                                                (out_base[l],))

    n_recv = jnp.where(gate & needs_recv, n, 0)
    n_send = jnp.where(gate & needs_send, n, 0)

    # --- advance the dynamic context (round, primitive, slice) -----------
    new_slice = sl + n
    step_done = gate & (new_slice >= nsl)
    seq_done = step_done & (st.ctx_step[c] + 1 >= shared.n_steps[c])
    next_step = jnp.where(
        seq_done, 0,
        jnp.where(step_done, st.ctx_step[c] + 1, st.ctx_step[c]))
    next_slice = jnp.where(step_done, 0, new_slice)
    next_round = jnp.where(seq_done, rnd + 1, rnd)
    coll_done = seq_done & (next_round >= shared.n_rounds[c])

    cg = jnp.where(gate, c, C)                              # gate-gated tgt
    st = st._replace(
        heap_out=heap_out,
        tail=st.tail.at[c].add(n_recv),
        head=st.head.at[c].add(n_send),
        ctx_step=st.ctx_step.at[cg].set(next_step, mode="drop"),
        ctx_slice=st.ctx_slice.at[cg].set(next_slice, mode="drop"),
        ctx_round=st.ctx_round.at[cg].set(next_round, mode="drop"),
        spin=st.spin.at[cv].set(
            jnp.where(gate, 0, st.spin[c] + stalled), mode="drop"),
        # The observability counter always records DENIED SLICES (partial
        # denials included), independent of the queue-conditional spin
        # weight: a persistently credit-starved lane shows its true
        # starvation even when solo patience keeps it from preempting.
        stall_slices=st.stall_slices.at[cv].add(
            jnp.where(gate, jnp.maximum(want - n, 0), denied),
            mode="drop"),
        # Stickiness: a successful primitive boosts its successors' spin
        # thresholds (gang-convergence pressure, Sec. 3.2).
        boost=st.boost.at[c].add(
            jnp.where(step_done & ~coll_done & jnp.bool_(cfg.stickiness),
                      cfg.spin_boost, 0)),
        slices_moved=st.slices_moved + jnp.sum(n),
    )

    # --- completion + chain advance (Sec. 3.1.2 / composite layer) --------
    # A completing stage with a registered successor (tables.next_coll)
    # enqueues the successor SQE ON DEVICE in the same superstep: the
    # whole chain advances inside one launch with no host round trip per
    # stage.  Only LOGICAL completions (chain tails and flat collectives)
    # write a CQE / advance `completed` — the host sees one completion
    # per submitted logical collective; per-stage progress is tracked
    # separately in `stage_completions`.  With no chains registered,
    # next_coll is all -1, chain_mask is the identity and every branch
    # below reduces bit-exactly to the seed completion semantics.
    #
    # The CQ is a RING: slots wrap modulo cq_len so completions past cq_len
    # per launch rotate through the buffer instead of silently overwriting
    # the last CQE (host reconciliation counts completions exactly via the
    # cumulative `completed` matrix, sqcq.HostQueues.reconcile).
    # Successors are PER RANK (local.chain_next): on a partial-membership
    # chain a rank advances to its own next participating stage (skipping
    # stages it is not a member of) and completes logically at its own
    # tail.  For full-membership chains chain_next == next_coll row-wise
    # and this is exactly the global-successor semantics.
    succ = local.chain_next[c]                              # [L]
    succ_c = jnp.clip(succ, 0, C - 1)
    chain_adv = coll_done & (succ >= 0)                     # enqueue next
    logical_done = coll_done & (succ < 0)                   # tail or flat
    done_i = logical_done.astype(jnp.int32)
    slot_off = jnp.cumsum(done_i) - done_i                  # exclusive scan
    cq_slot = (st.cq_count + slot_off) % cfg.cq_len
    cq_tgt = jnp.where(logical_done, cq_slot, cfg.cq_len)
    cd = jnp.where(coll_done, c, C)
    # Inflight clears CHAIN-WIDE at logical completion (set chain-wide at
    # head fetch), so a re-submitted head waits for the full chain.
    clear = jnp.any(shared.chain_mask[c] & logical_done[:, None], axis=0)
    # Successor context: fresh dynamic context, inherited priority (when
    # the chain's inherit flag is set), arrival stamped on the launch
    # clock like any rotation — the successor joins the BACK of its
    # lane's queue and competes under the normal preemption rules.
    sc = jnp.where(chain_adv, succ_c, C)                    # drop-gated tgt
    succ_prio = jnp.where(shared.chain_prio_inherit[succ_c],
                          st.prio[c], 0)
    # Intermediate successors run at their registered output region; the
    # rank's TAIL successor keeps the out_off pre-resolved at head fetch
    # (the per-SQE override's logical endpoint).
    sc_mid = jnp.where(chain_adv & (local.chain_next[succ_c] >= 0),
                       succ_c, C)
    st = st._replace(
        tq_active=st.tq_active.at[cd].set(False, mode="drop")
                             .at[sc].set(True, mode="drop"),
        inflight=st.inflight & ~clear,
        completed=st.completed.at[c].add(done_i),
        stage_completions=st.stage_completions.at[c].add(
            coll_done.astype(jnp.int32)),
        # Ready-to-complete latency on the cumulative supersteps clock:
        # each completing stage accrues (now - queue-entry stamp); the
        # event counter reconciles against stage_completions (every
        # completion is latency-accounted exactly once).  Device-enqueued
        # chain successors are stamped at THIS superstep — their wait
        # starts when the predecessor hands off, not at host submit.
        rtc_latency=st.rtc_latency.at[cd].add(
            st.supersteps - st.fetch_step[c], mode="drop"),
        rtc_events=st.rtc_events.at[cd].add(1, mode="drop"),
        fetch_step=st.fetch_step.at[sc].set(st.supersteps, mode="drop"),
        arrival=st.arrival.at[sc].set(
            cfg.max_colls + st.launch_steps + 1, mode="drop"),
        prio=st.prio.at[sc].set(succ_prio, mode="drop"),
        ctx_step=st.ctx_step.at[sc].set(0, mode="drop"),
        ctx_slice=st.ctx_slice.at[sc].set(0, mode="drop"),
        ctx_round=st.ctx_round.at[sc].set(0, mode="drop"),
        spin=st.spin.at[sc].set(0, mode="drop"),
        boost=st.boost.at[sc].set(0, mode="drop"),
        in_off=st.in_off.at[sc].set(shared.base_in_off[succ_c],
                                    mode="drop"),
        out_off=st.out_off.at[sc_mid].set(shared.base_out_off[succ_c],
                                          mode="drop"),
        cq_coll=st.cq_coll.at[cq_tgt].set(c, mode="drop"),
        cq_count=st.cq_count + jnp.sum(done_i),
        cur=jnp.where(coll_done | ~valid, -1, cand),
    )

    # Flight recorder: one batched ring append for this superstep's
    # transitions — preemptions (pre-rotation lane owner), stage
    # completions, on-device chain hand-offs and host-visible CQEs.
    if cfg.flight_recorder:
        st = _record_events(
            cfg, st,
            kinds=jnp.concatenate([
                jnp.full((L,), EV_PREEMPT, jnp.int32),
                jnp.full((L,), EV_STAGE_DONE, jnp.int32),
                jnp.full((L,), EV_CHAIN_HANDOFF, jnp.int32),
                jnp.full((L,), EV_CQE, jnp.int32),
            ]),
            colls=jnp.concatenate([cur_c, c, c, c]),
            valid=jnp.concatenate(
                [overspun, coll_done, chain_adv, logical_done]))

    # Chain hand-off relink: rewrite the successor's padded input span in
    # heap_in from the predecessor's just-finalized heap_out region via
    # the registration-time composed stage maps (pads zero-filled).  The
    # gather/scatter pair is only TRACED when the registration actually
    # contains chains (M > 0) — chain-free daemons pay nothing.  The
    # relink map of row c describes the GLOBAL edge c -> next_coll[c], so
    # it fires only when this rank's successor IS that stage: a rank
    # skipping intermediate stages (partial membership) has nothing to
    # hand off — its skipped successor's input is produced elsewhere or
    # never read (broadcast non-roots).
    if shared.chain_src.shape[1] > 0 and not defer_relink:
        relink_adv = chain_adv & (succ == shared.next_coll[c])
        heap_out = st.heap_out

        def _relink(heap_in):
            src = shared.chain_src[c]                       # [L, M]
            vals = jnp.where(src >= 0, heap_out[jnp.maximum(src, 0)],
                             0).astype(heap_in.dtype)
            dstg = jnp.where(relink_adv[:, None], shared.chain_dst[c],
                             jnp.int32(1 << 30))
            return heap_in.at[dstg].set(vals, mode="drop")

        if cond_relink:
            # Mesh backend: supersteps that complete no chained stage
            # skip the relink gather/scatter entirely (a real branch on
            # a device; under vmap this would degenerate to a select).
            heap_in = jax.lax.cond(jnp.any(relink_adv), _relink,
                                   lambda h: h, st.heap_in)
        else:
            heap_in = _relink(st.heap_in)
        st = st._replace(heap_in=heap_in)

    outbox = Mailbox(
        fwd_count=n_send,
        fwd_coll=c,
        fwd_payload=value.astype(st.payload.dtype),
        rev_count=n_recv,
        rev_coll=c,
    )
    return st, jnp.any(gate), outbox


def chain_relink_fired(shared: SharedTables, local: LocalTables,
                       prev_stage_completions: jnp.ndarray,
                       stage_completions: jnp.ndarray) -> jnp.ndarray:
    """[C] mask of chained stages whose hand-off relink must fire on this
    rank this superstep, recovered from the ``stage_completions`` delta.

    Matches the in-step ``relink_adv`` gating of :func:`lanes_step`: the
    stage completed here this superstep AND this rank's chain successor is
    the stage's GLOBAL next stage (a partial-membership rank that skips the
    successor has nothing to hand off — its skipped successor's input is
    produced elsewhere or never read)."""
    return ((stage_completions > prev_stage_completions)
            & (local.chain_next == shared.next_coll)
            & (shared.next_coll >= 0))


def rank_superstep(cfg: OcclConfig, shared: SharedTables, local: LocalTables,
                   st: DaemonState, inbox: Mailbox,
                   cond_relink: bool = False, defer_relink: bool = False
                   ) -> tuple[DaemonState, Mailbox]:
    """One full superstep for one rank."""
    st = apply_inbox(cfg, st, inbox)
    st, fetched = fetch_sqe(cfg, st, shared, local)
    st, moved_any, outbox = lanes_step(cfg, st, shared, local,
                                       cond_relink=cond_relink,
                                       defer_relink=defer_relink)

    progress = moved_any | fetched
    st = st._replace(
        supersteps=st.supersteps + 1,
        launch_steps=st.launch_steps + 1,
        no_prog=jnp.where(progress, 0, st.no_prog + 1),
        made_prog_prev=moved_any,
    )
    return st, outbox
