"""paligemma-3b [vlm] — SigLIP frontend (STUB: precomputed patch
embeddings) + gemma backbone, MQA (kv=1), prefix-LM attention over the
image+prefix tokens [arXiv:2407.07726]."""
from .base import ArchConfig, _FULL_ATTN_500K_SKIP

CONFIG = ArchConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_head=256,
    d_ff=16384, vocab=257216,
    vis_tokens=256,
    skip_cells=(_FULL_ATTN_500K_SKIP,),
)
