"""zamba2-1.2b [hybrid] — Mamba2 trunk + ONE weight-shared attention block
applied every 6 SSM layers [arXiv:2411.15242].  The shared block consumes
concat(hidden, embedding residual) through a 2D->D projector, as in Zamba.
long_500k runs (SSM trunk is linear; the shared attention decodes against
its KV cache, linear per token)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=8192, vocab=32000,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_groups=2,
    shared_attn_period=6,
)
