import os
import pathlib
import sys

# Tests see ONE device (the dry-run alone forces 512 in its own process).
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
