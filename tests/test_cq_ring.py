"""The completion queue is a ring: more completions than ``cq_len`` in a
single daemon launch must all reconcile (the seed clamped ``cq_slot`` to
``cq_len - 1``, silently overwriting the last CQE and losing completions).
"""
import numpy as np
import pytest

from repro.core import CollKind, OcclConfig, OcclRuntime


def _runtime(cq_len: int, n_colls: int):
    cfg = OcclConfig(n_ranks=1, max_colls=max(n_colls, 4), max_comms=1,
                     slice_elems=4, conn_depth=2, heap_elems=1 << 12,
                     cq_len=cq_len, superstep_budget=1 << 12)
    rt = OcclRuntime(cfg)
    comm = rt.communicator([0])        # 1-member group: COPY program
    ids = [rt.register(CollKind.ALL_REDUCE, comm, n_elems=4)
           for _ in range(n_colls)]
    return rt, ids


def test_completions_past_cq_len_all_reconcile():
    rt, ids = _runtime(cq_len=4, n_colls=8)
    fired = []
    data = {}
    for i, cid in enumerate(ids):
        data[cid] = np.full(4, float(i + 1), np.float32)
        rt.submit(0, cid, data=data[cid],
                  callback=lambda r, c: fired.append(c))
    rt.drive()
    st = rt.stats()
    # All 8 completed in-device and every one was reconciled on the host.
    assert int(st["cq_count"][0]) == 8        # ring wrapped (8 > cq_len=4)
    assert rt.queues.outstanding() == 0
    assert sorted(fired) == sorted(ids)
    for cid in ids:
        np.testing.assert_array_equal(rt.read_output(0, cid), data[cid])


def test_ring_holds_most_recent_completions():
    rt, ids = _runtime(cq_len=4, n_colls=8)
    for i, cid in enumerate(ids):
        rt.submit(0, cid, data=np.full(4, float(i), np.float32))
    assert rt.launch_once() == 8
    cq = np.asarray(rt.state.cq_coll)[0]
    # FIFO completion order 0..7 wraps twice: slots hold the last four.
    assert sorted(int(c) for c in cq) == ids[4:]


def test_wrapped_ring_multiple_callbacks_per_collective_fifo():
    """One launch, cq_len=2, three collectives each submitted three times:
    the ring wraps several times, so most completions reconcile as
    counter-only "lost" entries — yet every submission's callback fires
    exactly once and, PER COLLECTIVE, in submission (FIFO) order.  (Order
    ACROSS collectives is unrecoverable for lost completions; per-coll
    FIFO is the contract the callback deques guarantee.)"""
    repeats = 3
    rt, ids = _runtime(cq_len=2, n_colls=3)
    fired = {cid: [] for cid in ids}
    data = {}
    for i in range(repeats):
        for cid in ids:
            data[(cid, i)] = np.full(4, float(10 * cid + i + 1), np.float32)
            rt.submit(0, cid, data=data[(cid, i)],
                      callback=lambda r, c, i=i: fired[c].append(i))
    # All 9 completions in ONE launch (head-of-line resubmission works
    # within a launch: a finished collective is refetched from the SQ).
    assert rt.launch_once() == repeats * len(ids)
    assert int(np.asarray(rt.state.cq_count)[0]) == repeats * len(ids)
    for cid in ids:
        assert fired[cid] == list(range(repeats))
        # Last submission's buffer won the heap (FIFO re-execution).
        np.testing.assert_array_equal(rt.read_output(0, cid),
                                      data[(cid, repeats - 1)])
    assert rt.queues.outstanding() == 0
    # Relaunch bookkeeping: one reconcile, accounting all 9 completions.
    assert rt.queues.reconciles == 1
    assert list(rt.queues.launch_completions) == [repeats * len(ids)]


def test_wrap_across_multiple_launches():
    """Cumulative-counter reconciliation survives repeated wrapping."""
    rt, ids = _runtime(cq_len=2, n_colls=6)
    total = 0
    for round_ in range(3):
        for cid in ids:
            rt.submit(0, cid, data=np.ones(4, np.float32))
        rt.drive()
        total += len(ids)
        assert rt.queues.outstanding() == 0
        assert int(rt.queues.completed.sum()) == total
