"""Paper Fig. 6/7: latency & algorithm bandwidth of the 5 collectives,
OCCL vs the statically-sequenced baseline.

Two metrics per (collective, size):
  * wall-clock per iteration on this host (CPU; both systems pay XLA
    dispatch, so the RELATIVE gap is the signal — paper Fig. 6);
  * protocol supersteps vs the pipeline-optimal minimum (the structural
    analogue of "core execution time", paper Fig. 7 — OCCL's long-running
    daemon reaches the minimum once gang convergence kicks in).

The static baseline is the same ring algorithm executed in a consistent
global order with no scheduling layer (direct jnp reduction) — the
"statically sequenced NCCL" of Sec. 5.
"""
import numpy as np
import jax
import jax.numpy as jnp

from common import row, timeit
from repro.core import CollKind, OcclConfig, OcclRuntime

KINDS = {
    "all_reduce": CollKind.ALL_REDUCE,
    "all_gather": CollKind.ALL_GATHER,
    "reduce_scatter": CollKind.REDUCE_SCATTER,
    "broadcast": CollKind.BROADCAST,
    "reduce": CollKind.REDUCE,
}


def _static_baseline(kind: CollKind, xs: list[np.ndarray], R: int):
    """Consistent-order direct execution (jit'd once)."""
    stack = jnp.stack([jnp.asarray(x) for x in xs])

    @jax.jit
    def run(stack):
        if kind == CollKind.ALL_REDUCE:
            return jnp.broadcast_to(stack.sum(0), stack.shape)
        if kind == CollKind.ALL_GATHER:
            return jnp.broadcast_to(stack.reshape(-1), (R, stack.size))
        if kind == CollKind.REDUCE_SCATTER:
            s = stack.sum(0)
            return s.reshape(R, -1)
        if kind == CollKind.BROADCAST:
            return jnp.broadcast_to(stack[0], stack.shape)
        return stack.sum(0)

    return run, stack


def run(sizes=(64, 1024, 16384, 262144), R=8, iters=3):
    results = []
    for name, kind in KINDS.items():
        for n in sizes:
            cfg = OcclConfig(n_ranks=R, max_colls=2, max_comms=1,
                             slice_elems=min(4096, max(64, n // 16)),
                             conn_depth=8,
                             heap_elems=max(1 << 13, 8 * n),
                             superstep_budget=1 << 15)
            rt = OcclRuntime(cfg)
            comm = rt.communicator(list(range(R)))
            cid = rt.register(kind, comm, n_elems=n)
            rng = np.random.RandomState(0)
            if kind == CollKind.ALL_GATHER:
                xs = [rng.randn(-(-n // R)).astype(np.float32)
                      for _ in range(R)]
            else:
                xs = [rng.randn(n).astype(np.float32) for _ in range(R)]

            def occl_once():
                for r in range(R):
                    if kind == CollKind.BROADCAST and r != 0:
                        rt.submit(r, cid)
                    else:
                        rt.submit(r, cid, data=xs[r if kind !=
                                  CollKind.BROADCAST else 0])
                rt.drive()

            t_occl = timeit(occl_once, iters=iters, warmup=1)
            st = rt.stats()
            steps_per_iter = int(st["supersteps"].max()) / rt.launches
            spec = rt.specs[cid]
            prims = {CollKind.ALL_REDUCE: 2 * R - 1}.get(kind, R)
            min_steps = (prims * spec.n_slices * spec.n_rounds
                         + 2 * (R - 1))

            static_fn, stack = _static_baseline(kind, xs, R)
            t_static = timeit(lambda: jax.block_until_ready(static_fn(stack)),
                              iters=iters, warmup=1)

            bytes_alg = 4 * n
            results.append((name, n, t_occl, t_static, steps_per_iter,
                            min_steps))
            row(f"collectives/{name}_n{n}", t_occl * 1e6,
                f"static_us={t_static*1e6:.1f};"
                f"steps={steps_per_iter:.0f};proto_min={min_steps};"
                f"algbw_model={bytes_alg/max(steps_per_iter,1):.0f}B/step")
    return results


if __name__ == "__main__":
    run()
