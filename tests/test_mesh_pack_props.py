"""Hypothesis property sweep for the packed 16-bit mesh exchange: for
random lane counts, odd/even pairings (payload widths) and 16-bit dtypes,
packing adjacent element pairs into i32 lanes, riding a ring permutation
and unpacking is BITWISE identical to permuting the raw 16-bit rows —
i.e. the packed exchange is a lossless transport, including NaN payloads
and every other bit pattern.

Skipped entirely when hypothesis is not installed (tier-1 containers);
``pip install -r requirements-dev.txt`` restores the sweep.  The
deterministic fallback lives in test_daemon_fastpath.py.
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.daemon import _pack16_to_i32, _unpack16_from_i32

DTYPES = ["bfloat16", "float16"]


def _payload(data, rows, width, dtype):
    """Random 16-bit BIT PATTERNS (not floats): exactness must hold for
    NaNs, infs, subnormals — every pattern the wire can carry."""
    bits = data.draw(st.lists(st.integers(0, (1 << 16) - 1),
                              min_size=rows * width, max_size=rows * width))
    return (np.array(bits, np.uint16)
            .view(np.dtype(jnp.dtype(dtype)))
            .reshape(rows, width))


@settings(deadline=None, max_examples=60)
@given(lanes=st.integers(1, 6), width=st.integers(1, 48),
       dtype=st.sampled_from(DTYPES), data=st.data())
def test_pack_unpack_roundtrip_bitexact(lanes, width, dtype, data):
    pay = _payload(data, lanes, width, dtype)
    pad = width % 2
    packed = _pack16_to_i32(jnp.asarray(pay), pad)
    assert packed.shape == (lanes, (width + pad) // 2)
    assert packed.dtype == jnp.int32
    out = _unpack16_from_i32(packed, jnp.dtype(dtype), width)
    assert np.asarray(out).tobytes() == pay.tobytes()


@settings(deadline=None, max_examples=40)
@given(ring=st.integers(2, 8), width=st.integers(1, 32), shift=st.integers(1, 7),
       dtype=st.sampled_from(DTYPES), data=st.data())
def test_packed_exchange_equals_unpacked_exchange(ring, width, shift, dtype,
                                                  data):
    # A ppermute is a pure row permutation over ring members: the packed
    # exchange (pack -> permute i32 rows -> unpack) must deliver the same
    # bits as the unpacked exchange (permute the raw 16-bit rows).
    pay = _payload(data, ring, width, dtype)
    perm = np.roll(np.arange(ring), shift % ring)
    packed = np.asarray(_pack16_to_i32(jnp.asarray(pay), width % 2))
    got = _unpack16_from_i32(jnp.asarray(packed[perm]), jnp.dtype(dtype),
                             width)
    assert np.asarray(got).tobytes() == pay[perm].tobytes()
