"""Pallas TPU kernel: fused primitive slice application.

The daemon's compute hot-spot is the fused action of a primitive on a
slice (paper Sec. 2.3): ``recvReduceCopySend`` reads the recv-connector
payload and the local send buffer once, combines them, and feeds both the
recv-buffer write and the send-connector push from the same value — one
pass through VMEM instead of separate reduce + copy kernels.

Layout: payload/local are [N, S], where the scheduler batches the FULL
superstep burst into N = L * burst_slices rows (every lane's contiguous
slice burst) and S = slice_elems — one kernel call per superstep instead of
one per lane per slice.  Grid is (N, S // TS); each program instance owns a
(1, TS) VMEM tile.  The per-row opcode (recv, reduce, reads_in, op) rides
in SMEM via a scalar BlockSpec.  TS is a multiple of 128 to keep tiles
lane-aligned for the VPU (small-S test shapes fall back to S itself).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _tile(s: int) -> int:
    # Largest power-of-two tile <= 512 dividing S, floor 8 (interp-friendly).
    for ts in (512, 256, 128, 64, 32, 16, 8):
        if s % ts == 0:
            return ts
    return s


def _kernel(flags_ref, payload_ref, local_ref, out_ref):
    recv = flags_ref[0, 0] > 0
    reduce = flags_ref[0, 1] > 0
    reads = flags_ref[0, 2] > 0
    op = flags_ref[0, 3]

    p = payload_ref[...]
    l = local_ref[...]
    # bf16 combines accumulate in f32 (matches ref oracle).
    pf = p.astype(jnp.float32)
    lf = l.astype(jnp.float32)
    combined = jax.lax.switch(
        jnp.clip(op, 0, 3),
        [lambda x, y: x + y, jnp.maximum, jnp.minimum, lambda x, y: x * y],
        pf, lf,
    )
    val = jnp.where(
        reduce, combined,
        jnp.where(recv, pf, jnp.where(reads, lf, jnp.zeros_like(lf))))
    out_ref[...] = val.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_primitive_pallas(payload: jnp.ndarray, local: jnp.ndarray,
                           flags: jnp.ndarray, *,
                           interpret: bool = True) -> jnp.ndarray:
    """payload, local: [B, S]; flags: [B, 4] i32 -> value [B, S]."""
    B, S = payload.shape
    TS = _tile(S)
    grid = (B, S // TS)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            # Per-row opcode in SMEM: one (1, 4) block per row program.
            pl.BlockSpec((1, 4), lambda b, s: (b, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, TS), lambda b, s: (b, s)),
            pl.BlockSpec((1, TS), lambda b, s: (b, s)),
        ],
        out_specs=pl.BlockSpec((1, TS), lambda b, s: (b, s)),
        out_shape=jax.ShapeDtypeStruct((B, S), payload.dtype),
        interpret=interpret,
    )(flags, payload, local)
