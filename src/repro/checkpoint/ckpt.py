"""Checkpointing: sharded save/restore with manifest, async writer,
atomic commit, and elastic resharding.

Layout:  <dir>/step_<N>/
             manifest.json        {path -> {shape, dtype, crc32}}
             <flat-key>.npy       one file per leaf
             extras.json          data-pipeline cursor, RNG, metadata
Commit is atomic: everything is written into step_<N>.tmp and renamed.
Restore validates CRCs and re-shards onto whatever mesh the current
process has (elastic scaling: checkpoints are mesh-agnostic).
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save(directory, step: int, tree, extras: Optional[dict] = None,
         keep: int = 3) -> pathlib.Path:
    directory = pathlib.Path(directory)
    tmp = directory / f"step_{step}.tmp"
    final = directory / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    manifest = {}
    for key, leaf in _flatten(tree).items():
        arr = np.asarray(jax.device_get(leaf))
        fn = key.replace("/", "__") + ".npy"
        np.save(tmp / fn, arr)
        manifest[key] = {
            "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "crc32": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
        }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    (tmp / "extras.json").write_text(json.dumps(extras or {}, indent=1))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                    # atomic commit

    # retention
    steps = sorted((int(p.name.split("_")[1]) for p in directory.glob("step_*")
                    if not p.name.endswith(".tmp")))
    for old in steps[:-keep]:
        shutil.rmtree(directory / f"step_{old}", ignore_errors=True)
    return final


def latest_step(directory) -> Optional[int]:
    directory = pathlib.Path(directory)
    steps = [int(p.name.split("_")[1]) for p in directory.glob("step_*")
             if not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def restore(directory, step: int, template, *, shardings=None,
            validate: bool = True):
    """Rebuild `template`-shaped tree from disk; place onto `shardings`
    (NamedSharding tree) if given — this is the elastic-resharding path:
    the checkpoint has no memory of the mesh it was saved from."""
    d = pathlib.Path(directory) / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())

    flat_shards = _flatten(shardings) if shardings is not None else {}
    loaded = {}
    for key, meta in manifest.items():
        arr = np.load(d / meta["file"])
        if validate:
            crc = zlib.crc32(arr.tobytes()) & 0xFFFFFFFF
            if crc != meta["crc32"]:
                raise IOError(f"checkpoint corruption in {key}")
        if key in flat_shards:
            loaded[key] = jax.device_put(arr, flat_shards[key])
        else:
            loaded[key] = jnp.asarray(arr)

    leaves_with_path = jax.tree_util.tree_leaves_with_path(template)
    treedef = jax.tree_util.tree_structure(template)
    ordered = []
    for path, leaf in leaves_with_path:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in loaded:
            raise KeyError(f"checkpoint missing {key}")
        got = loaded[key]
        if tuple(got.shape) != tuple(leaf.shape):
            raise ValueError(
                f"{key}: ckpt shape {got.shape} != template {leaf.shape}")
        ordered.append(got.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, ordered)
    extras = json.loads((d / "extras.json").read_text())
    return tree, extras


class AsyncCheckpointer:
    """Background-thread writer: training never blocks on disk."""

    def __init__(self, directory, keep: int = 3):
        self.directory = pathlib.Path(directory)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_saved: Optional[int] = None
        self.error: Optional[BaseException] = None

    def save_async(self, step: int, tree, extras: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree_util.tree_map(
            lambda a: np.asarray(jax.device_get(a)), tree)

        def work():
            try:
                save(self.directory, step, host_tree, extras, self.keep)
                self.last_saved = step
            except BaseException as e:  # surfaced on next wait()
                self.error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.error is not None:
            err, self.error = self.error, None
            raise err
