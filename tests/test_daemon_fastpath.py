"""Mesh-backend fast-path guarantees (packed 16-bit exchange, vectorized
inbox apply, sharded staging flushes).

Tier-1 (fast, no multi-device flags needed):
  * ppermute budget: 16-bit heap dtypes ride exactly TWO ppermutes per
    superstep through ``_mesh_exchange`` — same as 32-bit — asserted by
    counting ppermute ops in the traced jaxpr; disabling the packing
    (``cfg.packed_16bit=False``) restores the third (separate payload)
    ppermute.
  * the pack16 transform is bitwise lossless for odd and even widths;
  * ``cfg.vectorized_inbox`` is a pure scatter-shape change: outputs and
    superstep counts are BIT-IDENTICAL to the two-axis scatter path.

The ``slow``-marked subprocess test drives the packed exchange end to end
on 8 simulated devices and proves packed == unpacked bit-identically
(the mesh-backend CI job runs it on every PR).
"""
import pathlib
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import CollKind, OcclConfig, OcclRuntime
from repro.core.daemon import (
    _pack16_to_i32,
    _unpack16_from_i32,
    count_exchange_ppermutes,
)

ROOT = pathlib.Path(__file__).resolve().parents[1]

# Shallow connectors on purpose in the equivalence workloads (semantics
# under test, not throughput).
pytestmark = pytest.mark.filterwarnings(
    "ignore::repro.core.runtime.ConnDepthWarning")


# ---------------------------------------------------------------------------
# ppermute budget (acceptance criterion: 16-bit == 2 ppermutes/superstep)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", ["bfloat16", "float16"])
def test_packed_16bit_exchange_uses_two_ppermutes(dtype):
    cfg = OcclConfig(n_ranks=8, max_comms=1, slice_elems=8, burst_slices=4,
                     dtype=dtype, packed_16bit=True)
    assert count_exchange_ppermutes(cfg) == 2


@pytest.mark.parametrize("dtype", ["bfloat16", "float16"])
def test_unpacked_16bit_exchange_pays_third_ppermute(dtype):
    cfg = OcclConfig(n_ranks=8, max_comms=1, slice_elems=8, burst_slices=4,
                     dtype=dtype, packed_16bit=False)
    assert count_exchange_ppermutes(cfg) == 3


def test_32bit_exchange_stays_at_two_ppermutes():
    cfg = OcclConfig(n_ranks=8, max_comms=1, slice_elems=8, burst_slices=4)
    assert count_exchange_ppermutes(cfg) == 2


def test_odd_slice_width_packs_with_pad_lane():
    # Odd B*SL: the odd lane is zero-padded, the budget is still 2.
    cfg = OcclConfig(n_ranks=8, max_comms=1, slice_elems=7, burst_slices=1,
                     dtype="bfloat16")
    assert count_exchange_ppermutes(cfg) == 2


def test_lanes_sharing_a_ring_fuse_into_two_ppermutes():
    # Two lanes whose communicators share one ring permutation are FUSED:
    # their stacked 16-bit traffic still rides a single packed fwd
    # ppermute plus one rev credit ppermute.
    cfg = OcclConfig(n_ranks=8, max_comms=2, slice_elems=8, burst_slices=2,
                     dtype="bfloat16")
    assert count_exchange_ppermutes(cfg, n_comms=2) == 2


# ---------------------------------------------------------------------------
# pack16 transform: bitwise lossless (deterministic fallback; the
# hypothesis sweep lives in test_mesh_pack_props.py)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", ["bfloat16", "float16"])
@pytest.mark.parametrize("width", [1, 2, 7, 8, 31, 64])
def test_pack16_roundtrip_bitexact(dtype, width):
    rng = np.random.RandomState(width)
    bits = rng.randint(0, 1 << 16, size=(3, width), dtype=np.uint16)
    pay = bits.view(np.dtype(jnp.dtype(dtype)))
    pad = width % 2
    packed = _pack16_to_i32(jnp.asarray(pay), pad)
    assert packed.shape == (3, (width + pad) // 2)
    assert packed.dtype == jnp.int32
    out = _unpack16_from_i32(packed, jnp.dtype(dtype), width)
    assert np.asarray(out).tobytes() == pay.tobytes()


def test_pack16_commutes_with_ring_permutation():
    # A ppermute is a pure row permutation over ring members: packing,
    # permuting the i32 rows and unpacking must equal permuting the raw
    # 16-bit rows (this is the single fact the fused fwd exchange relies
    # on for correctness).
    rng = np.random.RandomState(0)
    ring, width = 8, 33                                    # odd -> pad lane
    bits = rng.randint(0, 1 << 16, size=(ring, width), dtype=np.uint16)
    pay = bits.view(np.dtype(jnp.dtype("bfloat16")))
    perm = np.roll(np.arange(ring), 3)
    packed = np.asarray(_pack16_to_i32(jnp.asarray(pay), width % 2))
    got = _unpack16_from_i32(jnp.asarray(packed[perm]),
                             jnp.dtype("bfloat16"), width)
    assert np.asarray(got).tobytes() == pay[perm].tobytes()


# ---------------------------------------------------------------------------
# vectorized inbox apply: bit-identical to the two-axis scatter
# ---------------------------------------------------------------------------
def _run_adversarial_inbox(vectorized: bool):
    R, C = 4, 4
    rng = np.random.RandomState(42)
    orders = {r: list(rng.permutation(C)) for r in range(R)}
    cfg = OcclConfig(n_ranks=R, max_colls=C, max_comms=1, slice_elems=8,
                     conn_depth=4, burst_slices=4, heap_elems=1 << 14,
                     superstep_budget=1 << 14, vectorized_inbox=vectorized)
    rt = OcclRuntime(cfg)
    world = rt.communicator(list(range(R)))
    sizes = [24 << (i % 2) for i in range(C)]
    ids = [rt.register(CollKind.ALL_REDUCE, world, n_elems=s) for s in sizes]
    data = {i: [rng.randn(sizes[i]).astype(np.float32) for _ in range(R)]
            for i in range(C)}
    for r in range(R):
        for slot in orders[r]:
            rt.submit(r, ids[slot], data=data[slot][r])
    rt.drive(max_launches=128)
    outs = {i: {r: rt.read_output(r, ids[i]) for r in range(R)}
            for i in range(C)}
    return outs, rt.stats()


def test_vectorized_inbox_bit_identical():
    base_outs, base_st = _run_adversarial_inbox(vectorized=False)
    got_outs, got_st = _run_adversarial_inbox(vectorized=True)
    for i in base_outs:
        for r in base_outs[i]:
            np.testing.assert_array_equal(base_outs[i][r], got_outs[i][r],
                                          err_msg=f"coll={i} rank={r}")
    # Same schedule, not just same numerics: every scatter landed in the
    # same slot, so the superstep/preempt trajectory is identical too.
    np.testing.assert_array_equal(base_st["supersteps"], got_st["supersteps"])
    np.testing.assert_array_equal(base_st["preempts"], got_st["preempts"])


# ---------------------------------------------------------------------------
# end-to-end mesh equivalence on 8 simulated devices (mesh-backend CI job)
# ---------------------------------------------------------------------------
_PACKED_EQUIV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "@SRC@")
    import numpy as np, jax
    from repro.core import OcclConfig, CollKind, OcclRuntime

    def run(packed):
        mesh = jax.make_mesh((8,), ("rank",))
        cfg = OcclConfig(n_ranks=8, max_colls=4, max_comms=1, slice_elems=8,
                         conn_depth=12, burst_slices=4, dtype="bfloat16",
                         heap_elems=1 << 13, packed_16bit=packed)
        rt = OcclRuntime(cfg, mesh=mesh)
        world = rt.communicator(list(range(8)))
        a = rt.register(CollKind.ALL_REDUCE, world, n_elems=96)
        g = rt.register(CollKind.ALL_GATHER, world, n_elems=64)
        rng = np.random.RandomState(0)
        xa = [rng.randn(96).astype(np.float32) for _ in range(8)]
        xg = [rng.randn(8).astype(np.float32) for _ in range(8)]
        for r in range(8):
            order = [a, g] if r % 2 == 0 else [g, a]
            for cid in order:
                rt.submit(r, cid, data=(xa[r] if cid == a else xg[r]))
        rt.drive()
        st = rt.stats()
        # All-ranks staged submits must take the sharded flush placement.
        assert st["staging_sharded_flushes"] >= 1, st
        return {(r, c): np.asarray(rt.read_output(r, c))
                for r in range(8) for c in (a, g)}

    base = run(packed=False)
    got = run(packed=True)
    for k in base:
        assert base[k].tobytes() == got[k].tobytes(), k
    print("PACKED_EQUIV_OK")
""").replace("@SRC@", str(ROOT / "src"))


@pytest.mark.slow
def test_mesh_packed_bf16_bit_identical_to_unpacked():
    r = subprocess.run([sys.executable, "-c", _PACKED_EQUIV],
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "PACKED_EQUIV_OK" in r.stdout
