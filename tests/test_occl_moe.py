"""Expert-parallel MoE through OCCL all-to-all (train/occl_moe.py).

The transport claim is exact: the OCCL path and the direct-indexing
expert-parallel reference share the per-rank dispatch/FFN/combine stages
verbatim, so their outputs must be BIT-IDENTICAL in float32 — any
discrepancy is an all-to-all routing bug, not numerics.  The reference
itself must meet the dense O(T*E) oracle of models/moe.py to float
tolerance whenever capacity admits no drops.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe as M
from repro.train.occl_moe import OcclMoE, a2a_exchange_ref, ep_forward_ref


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("deepseek-moe-16b").reduced()
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = M.init_moe_block(jax.random.PRNGKey(0), "t", cfg, jnp.float32)
    rng = np.random.RandomState(2)
    R, Tl = 4, 8
    xs = [jnp.asarray(rng.randn(Tl, cfg.d_model) * 0.5, jnp.float32)
          for _ in range(R)]
    return cfg, params, R, Tl, xs


def test_a2a_exchange_ref_is_personalized():
    R, c = 4, 3
    ins = [np.arange(R * c, dtype=np.float32) + 100 * o for o in range(R)]
    out = a2a_exchange_ref(ins)
    for m in range(R):
        want = np.concatenate([ins[o][m * c:(m + 1) * c] for o in range(R)])
        np.testing.assert_array_equal(out[m], want)


def test_occl_moe_bitwise_matches_ep_ref(setup):
    cfg, params, R, Tl, xs = setup
    cap = Tl * cfg.top_k                       # no drops possible
    ref = ep_forward_ref(cfg, params, xs, cap=cap)
    moe = OcclMoE(cfg, R, Tl, cap=cap)
    ys = moe.forward(params, xs)
    for r in range(R):
        np.testing.assert_array_equal(np.asarray(ys[r]),
                                      np.asarray(ref[r]))


def test_occl_moe_bitwise_under_capacity_drops(setup):
    """Real drops (cap=4 < worst-case load): transport equality must
    hold regardless — dropped slots travel as zeros on both paths."""
    cfg, params, R, Tl, xs = setup
    ref = ep_forward_ref(cfg, params, xs, cap=4)
    moe = OcclMoE(cfg, R, Tl, cap=4)
    ys = moe.forward(params, xs)
    for r in range(R):
        np.testing.assert_array_equal(np.asarray(ys[r]),
                                      np.asarray(ref[r]))


@pytest.mark.parametrize("algo,hier", [("two_level", (2, 2)),
                                       ("auto", None)])
def test_occl_moe_composite_variants(setup, algo, hier):
    cfg, params, R, Tl, xs = setup
    cap = Tl * cfg.top_k
    ref = ep_forward_ref(cfg, params, xs, cap=cap)
    moe = OcclMoE(cfg, R, Tl, cap=cap, algo=algo, hierarchy=hier)
    ys = moe.forward(params, xs)
    for r in range(R):
        np.testing.assert_array_equal(np.asarray(ys[r]),
                                      np.asarray(ref[r]))


def test_ep_ref_matches_dense_oracle(setup):
    """With no-drop capacity the expert-parallel decomposition equals
    the dense every-expert-on-every-token oracle to float tolerance."""
    cfg, params, R, Tl, xs = setup
    ys = ep_forward_ref(cfg, params, xs, cap=Tl * cfg.top_k)
    xg = jnp.stack(xs).reshape(1, R * Tl, cfg.d_model)
    dense = np.asarray(M.moe_forward_dense_ref(cfg, params, xg))
    dense = dense.reshape(R, Tl, cfg.d_model)
    for r in range(R):
        np.testing.assert_allclose(np.asarray(ys[r]), dense[r],
                                   rtol=2e-4, atol=2e-5)


def test_forward_reuses_registrations(setup):
    """Steps resubmit the same two collectives — no re-registration, and
    payload changes flow through (the training-loop usage)."""
    cfg, params, R, Tl, xs = setup
    cap = Tl * cfg.top_k
    moe = OcclMoE(cfg, R, Tl, cap=cap)
    first = moe.forward(params, xs)
    xs2 = [x + 1.0 for x in xs]
    second = moe.forward(params, xs2)
    ref2 = ep_forward_ref(cfg, params, xs2, cap=cap)
    for r in range(R):
        np.testing.assert_array_equal(np.asarray(second[r]),
                                      np.asarray(ref2[r]))
    assert not np.array_equal(np.asarray(first[0]), np.asarray(second[0]))


def test_expert_shard_divisibility_enforced(setup):
    cfg, params, R, Tl, xs = setup
    with pytest.raises(AssertionError, match="n_experts"):
        OcclMoE(cfg, 3, Tl)                    # 8 experts % 3 != 0


def test_forward_overlapped_bitwise_matches_ref(setup):
    """The stream-sharded overlap path moves the same bits: splitting the
    capacity axis into S independent exchanges and interleaving FFN with
    the dispatch tails must not change a single float32 — and the jitted
    core + registrations are reused across steps."""
    cfg, params, R, Tl, xs = setup
    cap = Tl * cfg.top_k
    moe = OcclMoE(cfg, R, Tl, cap=cap, n_streams=2, overlap_ticks=4)
    s0 = moe.stats()
    ys = moe.forward_overlapped(params, xs)
    s1 = moe.stats()
    ref = ep_forward_ref(cfg, params, xs, cap=cap)
    for r in range(R):
        np.testing.assert_array_equal(np.asarray(ys[r]),
                                      np.asarray(ref[r]))
    # some supersteps genuinely ran inside the hidden overlap ticks
    assert int(np.max(s1["overlap_supersteps"]
                      - s0["overlap_supersteps"])) > 0
    xs2 = [x + 1.0 for x in xs]
    ys2 = moe.forward_overlapped(params, xs2)
    ref2 = ep_forward_ref(cfg, params, xs2, cap=cap)
    for r in range(R):
        np.testing.assert_array_equal(np.asarray(ys2[r]),
                                      np.asarray(ref2[r]))


def test_forward_overlapped_shortens_critical_path(setup):
    """The dispatch-tail overlap claim on one instance: the overlapped
    step must EXPOSE strictly fewer supersteps (barrier ticks) than the
    full-barrier forward — supersteps hidden behind expert compute drop
    off the per-layer critical path."""
    cfg, params, R, Tl, xs = setup
    cap = Tl * cfg.top_k
    moe = OcclMoE(cfg, R, Tl, cap=cap, n_streams=4, overlap_ticks=8)

    def exposed(fwd):
        s0 = moe.stats()
        fwd(params, xs)
        s1 = moe.stats()
        return (int(np.max(s1["barrier_supersteps"]
                           - s0["barrier_supersteps"])),
                int(np.max(s1["overlap_supersteps"]
                           - s0["overlap_supersteps"])))

    exp_barrier, hid_barrier = exposed(moe.forward)
    exp_overlap, hid_overlap = exposed(moe.forward_overlapped)
    assert hid_barrier == 0                    # drive() is all-barrier
    assert hid_overlap > 0
    assert exp_overlap < exp_barrier
