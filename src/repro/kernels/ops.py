"""jit'd dispatch layer over the Pallas kernels.

On TPU the kernels compile natively; on CPU (this container) they execute
via the Pallas interpreter, which validates the kernel bodies bit-for-bit
against the ref.py oracles.  ``use_kernels(False)`` falls back to the
oracles entirely (the scheduler's default fast path on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .chunk_combine import chunk_combine_pallas
from .fused_slice import fused_primitive_pallas

_INTERPRET = jax.default_backend() != "tpu"


def fused_primitive_batch(payload, local, flags):
    """Scheduler entry point: the whole [L*B, SLICE] superstep burst —
    every lane's slice burst, with per-row (recv, reduce, reads_in, op)
    opcodes — in ONE kernel call."""
    return fused_primitive_pallas(payload, local, flags,
                                  interpret=_INTERPRET)


def chunk_combine(a, b, op: int = 0):
    return chunk_combine_pallas(a, b, op, interpret=_INTERPRET)


# ref aliases, exported for benchmarks and tests
fused_primitive_ref = ref.fused_primitive_ref
chunk_combine_ref = ref.chunk_combine_ref
