"""Architecture + shape-cell configuration system.

Each assigned architecture is a frozen ``ArchConfig`` in its own module
(``repro/configs/<id>.py``), selectable via ``--arch <id>`` in the
launchers.  ``reduced()`` returns a tiny same-family config for CPU smoke
tests; the full configs are exercised only via the dry-run
(ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm | vit
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int

    # --- attention flavor ------------------------------------------------
    qk_norm: bool = False
    swa_window: int = 0          # 0 = full attention; >0 = sliding window
    rope_theta: float = 10_000.0
    attn_logit_softcap: float = 0.0

    # --- MoE --------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) ------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_groups: int = 1
    ssm_dconv: int = 4
    ssm_chunk: int = 256

    # --- hybrid (zamba2): one shared attn block every N ssm layers ---------
    shared_attn_period: int = 0

    # --- enc-dec (seamless) -------------------------------------------------
    enc_layers: int = 0
    enc_frames: int = 1024       # stub audio frontend: frames per sample

    # --- vlm (paligemma) -----------------------------------------------------
    vis_tokens: int = 0          # stub patch frontend: tokens per image

    # --- numerics / training -------------------------------------------------
    param_dtype: str = "float32"     # master params
    compute_dtype: str = "bfloat16"
    moment_dtype: str = "float32"    # AdamW moments (bf16 for kimi-scale)
    remat: str = "full"              # full | dots | none
    zero1: bool = True               # shard optimizer state over data axis

    # --- shape-cell applicability --------------------------------------------
    skip_cells: tuple = ()       # (cell_name, reason) pairs

    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def cells(self) -> list[str]:
        skip = {c for c, _ in self.skip_cells}
        return [c for c in SHAPES if c not in skip]

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        r = dict(
            n_layers=min(self.n_layers, 2 if self.family != "hybrid" else 5),
            d_model=64,
            n_heads=4, n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_head=16,
            d_ff=128,
            vocab=256,
        )
        if self.n_experts:
            r.update(n_experts=8, top_k=min(self.top_k, 2),
                     n_shared_experts=min(self.n_shared_experts, 1),
                     d_ff_expert=32)
        if self.ssm_state:
            r.update(ssm_state=16, ssm_headdim=16, ssm_groups=1,
                     ssm_chunk=8)
        if self.shared_attn_period:
            r.update(shared_attn_period=2)
        if self.enc_layers:
            r.update(enc_layers=2, enc_frames=24)
        if self.vis_tokens:
            r.update(vis_tokens=8)
        if self.swa_window:
            r.update(swa_window=8)
        return dataclasses.replace(self, **r)


_FULL_ATTN_500K_SKIP = (
    "long_500k",
    "pure full attention is quadratic at 512k tokens; skipped per spec "
    "(run only for SSM / hybrid / sliding-window archs)",
)
