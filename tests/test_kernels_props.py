"""Hypothesis property tests on the fused-primitive kernel semantics.

Skipped entirely when hypothesis is not installed (tier-1 containers);
``pip install -r requirements-dev.txt`` restores the property coverage.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.kernels.fused_slice import fused_primitive_pallas


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_fused_primitive_props(data):
    """Semantics: reduce==op(payload,local); recv-only==payload;
    reads-only==local; neither==0."""
    S = data.draw(st.sampled_from([8, 32, 128]))
    rng = np.random.RandomState(data.draw(st.integers(0, 999)))
    p = jnp.asarray(rng.randn(1, S), jnp.float32)
    l = jnp.asarray(rng.randn(1, S), jnp.float32)
    recv = data.draw(st.integers(0, 1))
    red = data.draw(st.integers(0, 1))
    reads = data.draw(st.integers(0, 1))
    op = data.draw(st.integers(0, 3))
    f = jnp.asarray([[recv, red, reads, op]], jnp.int32)
    got = np.asarray(fused_primitive_pallas(p, l, f, interpret=True))[0]
    pn, ln = np.asarray(p)[0], np.asarray(l)[0]
    if red:
        want = {0: pn + ln, 1: np.maximum(pn, ln),
                2: np.minimum(pn, ln), 3: pn * ln}[op]
    elif recv:
        want = pn
    elif reads:
        want = ln
    else:
        want = np.zeros(S, np.float32)
    np.testing.assert_allclose(got, want, rtol=1e-6)
