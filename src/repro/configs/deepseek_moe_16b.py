"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, fine-grained
experts (d_ff 1408) [arXiv:2401.06066].

Deviation from the HF checkpoint: the real model's layer 0 is dense; the
assigned spec sheet gives a uniform MoE stack, which we follow.
"""
from .base import ArchConfig, _FULL_ATTN_500K_SKIP

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=1408, vocab=102400,
    n_experts=64, top_k=6, n_shared_experts=2, d_ff_expert=1408,
    skip_cells=(_FULL_ATTN_500K_SKIP,),
)
