"""Hillclimb laboratory (§Perf): re-lower a cell under a named variant and
compare roofline terms against the cached baseline artifact.

    PYTHONPATH=src python benchmarks/perf_lab.py --arch mamba2-2.7b \
        --cell train_4k --variant ssm_chunk128

Variants are registered below as (env overrides, ArchConfig overrides).
Each run prints baseline vs variant terms and the percentage delta on the
dominant term — the before/after record for EXPERIMENTS.md §Perf.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

VARIANTS = {
    # name: (env vars, config overrides)
    "baseline": ({}, {}),
    "causal_rec2": ({"REPRO_CAUSAL_REC": "2"}, {}),
    "causal_rec3": ({"REPRO_CAUSAL_REC": "3"}, {}),
    "ssm_chunk128": ({}, {"ssm_chunk": 128}),
    "ssm_chunk64": ({}, {"ssm_chunk": 64}),
    "remat_dots": ({}, {"remat": "dots"}),
    "remat_none": ({}, {"remat": "none"}),
    "cap1.0": ({}, {"capacity_factor": 1.0}),
    "kvblock512": ({"REPRO_KV_BLOCK": "512"}, {}),
    "kvblock2048": ({"REPRO_KV_BLOCK": "2048"}, {}),
    "flash4k": ({"REPRO_BLOCKWISE_THRESHOLD": "2048"}, {}),
    "flash4k_rec2": ({"REPRO_BLOCKWISE_THRESHOLD": "2048",
                      "REPRO_CAUSAL_REC": "2"}, {}),
    "flash4k_kvb512": ({"REPRO_BLOCKWISE_THRESHOLD": "2048",
                        "REPRO_KV_BLOCK": "512"}, {}),
    "flash4k_chunk128": ({"REPRO_BLOCKWISE_THRESHOLD": "2048"},
                         {"ssm_chunk": 128}),
    "moe_ep": ({"REPRO_MOE_EP": "1", "REPRO_MOE_CAP_SHARD": "1"}, {}),
    "moe_ep_flash4k": ({"REPRO_MOE_EP": "1",
                        "REPRO_BLOCKWISE_THRESHOLD": "2048"}, {}),
    "ssm_heads": ({"REPRO_SSM_SHARD_HEADS": "1"}, {}),
    "ssm_heads_chunk128": ({"REPRO_SSM_SHARD_HEADS": "1"},
                           {"ssm_chunk": 128}),
    "attn_bf16": ({"REPRO_ATTN_BF16": "1"}, {}),
    "moe_ep_scatter": ({"REPRO_MOE_EP": "1", "REPRO_MOE_CAP_SHARD": "1",
                        "REPRO_MOE_COMBINE": "scatter"}, {}),
    "moe_ep_v1": ({"REPRO_MOE_EP": "1", "REPRO_MOE_COMBINE": "scatter"},
                  {}),
    "moe_ep_v1_gather": ({"REPRO_MOE_EP": "1"}, {}),
    "rec2_bf16": ({"REPRO_CAUSAL_REC": "2", "REPRO_ATTN_BF16": "1",
                   "REPRO_BLOCKWISE_THRESHOLD": "2048"}, {}),
    "rec3_bf16_dots": ({"REPRO_CAUSAL_REC": "3", "REPRO_ATTN_BF16": "1",
                        "REPRO_BLOCKWISE_THRESHOLD": "2048"},
                       {"remat": "dots"}),
}


def run_variant(arch, cell_name, variant, multi_pod=False):
    env, overrides = VARIANTS[variant]
    for k, v in env.items():
        os.environ[k] = v
    try:
        import jax
        from repro.configs import get_config
        from repro.configs.base import SHAPES
        from repro.launch.dryrun import (_cost_of, _lower_cell,
                                         _roofline_probe)
        from repro.launch.mesh import make_production_mesh

        cfg = get_config(arch)
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        cell = SHAPES[cell_name]
        mesh = make_production_mesh(multi_pod=multi_pod)
        if cfg.family == "hybrid":
            p = cfg.shared_attn_period
            probe = _roofline_probe(cfg, cell, mesh, (p, 2 * p, 3 * p))
        else:
            probe = _roofline_probe(cfg, cell, mesh, (1, 2, 4))
        # memory from the rolled production build
        os.environ["REPRO_SCAN_UNROLL"] = "0"
        lowered, _, _ = _lower_cell(cfg, cell, mesh)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        probe["mem_temp_bytes"] = int(mem.temp_size_in_bytes)
        return probe
    finally:
        for k in env:
            os.environ.pop(k, None)


def terms_of(probe):
    import roofline as R
    wire = sum(R.WIRE_FACTOR.get(k, 1.0) * v["bytes"]
               for k, v in probe["collectives"].items())
    return {
        "compute_s": probe["flops"] / R.PEAK_FLOPS,
        "memory_s": probe["bytes"] / R.HBM_BW,
        "collective_s": wire / R.ICI_BW,
        "temp_GiB": probe.get("mem_temp_bytes", 0) / 2**30,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--cell", required=True)
    ap.add_argument("--variant", required=True)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    probe = run_variant(args.arch, args.cell, args.variant)
    t = terms_of(probe)
    rec = {"arch": args.arch, "cell": args.cell, "variant": args.variant,
           "probe": probe, "terms": t}
    print(json.dumps({k: v for k, v in rec.items() if k != "probe"},
                     indent=1))
    out = args.out or (pathlib.Path(__file__).parent / "perf_results" /
                       f"{args.arch}__{args.cell}__{args.variant}.json")
    pathlib.Path(out).parent.mkdir(parents=True, exist_ok=True)
    pathlib.Path(out).write_text(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
