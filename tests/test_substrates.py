"""Substrate tests: data pipeline, checkpointing, fault tolerance,
optimizer, straggler detector."""

import pathlib
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Heavyweight substrate integration: excluded from tier-1; run with `pytest -m ""`.
pytestmark = pytest.mark.slow

from repro.configs import get_config
from repro.configs.base import ShapeCell
from repro.checkpoint.ckpt import (AsyncCheckpointer, latest_step, restore,
                                   save)
from repro.data.pipeline import SyntheticPipeline
from repro.fabric.ft import FTConfig, TrainController
from repro.fabric.straggler import StragglerDetector
from repro.optim.adamw import AdamWConfig, adamw_update, init_moments, schedule
from repro.train.state import init_state
from repro.train.step import make_train_step

CELL = ShapeCell("t", 32, 4, "train")
CFG = get_config("qwen3-0.6b").reduced()


# ---------------------------------------------------------------- data
def test_pipeline_deterministic_and_elastic():
    p2 = [SyntheticPipeline(CFG, CELL, shard_id=i, n_shards=2)
          for i in range(2)]
    p4 = [SyntheticPipeline(CFG, CELL, shard_id=i, n_shards=4)
          for i in range(4)]
    b2 = [p.batch_at(7) for p in p2]
    b4 = [p.batch_at(7) for p in p4]
    g2 = np.concatenate([b["tokens"] for b in b2])
    g4 = np.concatenate([b["tokens"] for b in b4])
    np.testing.assert_array_equal(g2, g4)   # shard count never changes data


def test_pipeline_prefetch_and_cursor():
    p = SyntheticPipeline(CFG, CELL).start()
    b0, b1 = next(p), next(p)
    sd = p.state_dict()
    b2 = next(p)
    p.load_state_dict(sd)
    b2b = next(p)
    p.stop()
    np.testing.assert_array_equal(b2["tokens"], b2b["tokens"])
    assert not np.array_equal(b0["tokens"], b1["tokens"])


# ---------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_retention():
    with tempfile.TemporaryDirectory() as d:
        state = init_state(CFG)
        for s in (0, 10, 20, 30):
            save(d, s, state, extras={"x": s}, keep=2)
        assert latest_step(d) == 30
        kept = sorted(p.name for p in pathlib.Path(d).glob("step_*"))
        assert kept == ["step_20", "step_30"]
        got, extras = restore(d, 30, state)
        assert extras["x"] == 30
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_corruption_detected():
    with tempfile.TemporaryDirectory() as d:
        state = init_state(CFG)
        path = save(d, 0, state)
        victim = next(path.glob("*.npy"))
        arr = np.load(victim)
        arr = np.asarray(arr).copy()
        arr.reshape(-1)[0] += 1.0
        np.save(victim, arr)
        with pytest.raises(IOError):
            restore(d, 0, state)


def test_async_checkpointer():
    with tempfile.TemporaryDirectory() as d:
        ck = AsyncCheckpointer(d)
        state = init_state(CFG)
        ck.save_async(5, state)
        ck.wait()
        assert latest_step(d) == 5


# ------------------------------------------------------ fault tolerance
def test_controller_recovers_from_injected_failure():
    with tempfile.TemporaryDirectory() as d:
        state = init_state(CFG)
        pipe = SyntheticPipeline(CFG, CELL)
        step_fn = jax.jit(make_train_step(CFG, AdamWConfig(lr=1e-3)))
        ctrl = TrainController(
            FTConfig(ckpt_dir=d, ckpt_period=4, max_restarts=2),
            step_fn, state, pipe, inject_failure_at=6)
        logs = ctrl.run(10)
        assert ctrl.restarts == 1
        assert int(ctrl.state.step) == 10
        steps = [m["step"] for m in logs]
        assert steps.count(5) >= 1 and steps.count(4) >= 2  # replayed 4,5
        # losses replayed from the checkpoint are bitwise identical
        by_step = {}
        replays = 0
        for m in logs:
            if m["step"] in by_step:
                assert m["loss"] == by_step[m["step"]]
                replays += 1
            by_step[m["step"]] = m["loss"]
        assert replays >= 1


def test_training_loss_decreases():
    state = init_state(CFG)
    pipe = SyntheticPipeline(CFG, ShapeCell("t", 32, 4, "train"))
    # overfit a SINGLE repeated batch: loss must drop
    batch = pipe.batch_at(0)
    step_fn = jax.jit(make_train_step(CFG, AdamWConfig(
        lr=3e-3, warmup_steps=5, total_steps=100)))
    first = None
    for i in range(30):
        state, metrics = step_fn(state, batch)
        if first is None:
            first = float(metrics["loss"])
    last = float(metrics["loss"])
    assert last < first - 0.5, (first, last)


# ----------------------------------------------------------- optimizer
def test_adamw_matches_reference_step():
    rng = np.random.RandomState(0)
    params = {"w": jnp.asarray(rng.randn(4, 4), jnp.float32)}
    grads = {"w": jnp.asarray(rng.randn(4, 4), jnp.float32)}
    m, v = init_moments(params, "float32")
    cfg = AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=10,
                      weight_decay=0.0, clip_norm=1e9)
    p2, m2, v2, gn = adamw_update(cfg, params, grads, m, v,
                                  jnp.zeros((), jnp.int32))
    g = np.asarray(grads["w"])
    mm = 0.1 * g
    vv = 0.05 * g * g
    upd = (mm / (1 - 0.9)) / (np.sqrt(vv / (1 - 0.95)) + 1e-8)
    lr = float(schedule(cfg, jnp.zeros((), jnp.int32)))
    want = np.asarray(params["w"]) - lr * upd
    np.testing.assert_allclose(np.asarray(p2["w"]), want, rtol=1e-5)


# ------------------------------------------------------------ straggler
def test_straggler_detector():
    d = StragglerDetector(n_ranks=4)
    for t in range(10):
        for r in range(4):
            d.observe(r, 1.0 if r != 2 else 5.0)
    assert d.stragglers() == [2]
    assert d.healthy_ranks() == [0, 1, 3]
