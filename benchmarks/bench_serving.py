"""Serving QoS traffic replay: decode p99 with preemption on vs off.

The scenario the QoS layer exists for: latency-critical decode traffic
(open-loop Poisson arrivals, each request a chain of per-decode-step
tensor-parallel all-reduces gated by a prefill all-gather) shares ONE
fabric lane with an adversarial background tenant that keeps grad-sync
bursts at its admission cap for the whole replay.  The identical traffic
trace runs twice:

* ``preemption=True``  — PRIORITY policy + priority_preempts + aging:
  a decode submit landing mid-burst preempts the in-flight background
  bucket at slice granularity (the paper's mechanism as a tail-latency
  optimization);
* ``preemption=False`` — FIFO at equal priority: the no-QoS baseline
  where decode waits out whatever transfer holds the lane.

Latency is measured in SUPERSTEPS on the replay clock (structural —
deterministic per seed/config, noise-immune for the CI gates), with
wall-clock modeled as ``supersteps * superstep_s`` where superstep_s is
the measured wall cost of the replay's busy loop per superstep (host
dispatch included; recorded for scale, not gated).

Gates (benchmarks/check_gates.py, ``serving`` section):
* preemption-on decode p99 strictly below preemption-off under the
  adversarial background load;
* bounded starvation: the background tenant still completes work under
  preemption (admitted bursts all drain after arrivals stop), degrading
  gracefully rather than being starved out.
"""
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.serving.qos import ServingQos, TrafficClass

# One replay workload for --quick and full runs: the gates compare
# structural superstep percentiles, which do not shrink with iters, and
# the whole replay is a few thousand jitted 1-superstep ticks.
REPLAY = {
    "seed": 0,
    "n_ranks": 4,
    "n_requests": 12,           # open-loop decode requests
    "decode_chain": 4,          # decode steps (chained all-reduces) each
    "mean_gap": 24.0,           # Poisson mean inter-arrival (supersteps)
    "decode_elems": 256,
    "prefill_elems": 1024,
    "background_elems": 4096,   # adversarial bursts, pumped to the cap
    "background_buckets": 2,
    "max_background_inflight": 2,
    "prio_aging_quantum": 8,    # starvation bound: an aged background
    "prio_aging_cap": 255,      # bucket overtakes queued prefills after
                                # ~8*129 queued supersteps, never decode
    "horizon": 1 << 15,         # hard safety bound on replay supersteps
}


def _percentiles(samples) -> dict:
    a = np.asarray(samples, float)
    return {"samples": int(a.size),
            "p50": float(np.percentile(a, 50)),
            "p99": float(np.percentile(a, 99)),
            "mean": float(a.mean())}


def replay(preemption: bool, spec: dict = REPLAY) -> dict:
    """Run the traffic trace once; returns the latency/throughput record.

    The trace is derived from ``spec['seed']`` alone, so both regimes
    see byte-identical arrivals; only the scheduler policy differs.
    """
    qos = ServingQos(
        n_ranks=spec["n_ranks"], decode_elems=spec["decode_elems"],
        prefill_elems=spec["prefill_elems"],
        background_elems=spec["background_elems"],
        background_buckets=spec["background_buckets"],
        max_background_inflight=spec["max_background_inflight"],
        preemption=preemption,
        prio_aging_quantum=spec["prio_aging_quantum"],
        prio_aging_cap=spec["prio_aging_cap"], tick_chunk=1)
    rng = np.random.RandomState(spec["seed"])
    arrivals = np.cumsum(
        rng.exponential(spec["mean_gap"], spec["n_requests"])).astype(int)
    # Request state machine: waiting -> prefill in flight -> decode
    # chain (one all-reduce at a time, the next submitted when the
    # previous completes) -> done.
    jobs = [{"arrival": int(a), "rec": None, "prefilled": False,
             "left": spec["decode_chain"], "done_at": None}
            for a in arrivals]
    decode_lat: list[int] = []
    qos.pump_background()               # bursts in flight from superstep 0
    t0 = time.perf_counter()
    while any(j["done_at"] is None for j in jobs):
        if qos.now > spec["horizon"]:
            raise RuntimeError(
                f"serving replay exceeded its {spec['horizon']}-superstep "
                f"horizon (preemption={preemption}) — decode is starving")
        for j in jobs:
            if j["done_at"] is not None or j["arrival"] > qos.now:
                continue
            if j["rec"] is None:        # arrived: issue the prefill
                j["rec"] = qos.submit_prefill()
            elif j["rec"]["done_at"] is not None:
                if j["prefilled"]:      # a decode step just completed
                    decode_lat.append(
                        j["rec"]["done_at"] - j["rec"]["arrival"])
                    j["left"] -= 1
                else:
                    j["prefilled"] = True
                if j["left"] == 0:
                    j["done_at"] = qos.now
                else:
                    j["rec"] = qos.submit_decode()
        qos.pump_background()           # adversarial: refill every step
        qos.advance()
    busy_wall = time.perf_counter() - t0
    busy_supersteps = max(qos.now, 1)
    # Arrivals stopped: the background tenant must drain — the bounded-
    # starvation proof (drain() raises the enriched DeadlockTimeout on a
    # wedge instead of hanging).
    bg = qos.tenants[TrafficClass.BACKGROUND]
    admitted_bg = bg.submitted
    drain_supersteps = qos.drain()
    s = qos.summary()
    superstep_s = busy_wall / busy_supersteps
    dec = _percentiles(decode_lat)
    return {
        "decode": dec,
        "prefill": s["prefill"],
        "background": s["background"],
        "background_admitted": admitted_bg,
        "background_drained": bg.completed == bg.submitted,
        # Contention-window-normalized throughput (completions per 1k
        # busy supersteps): the two regimes run DIFFERENT busy-window
        # lengths on the same trace, so raw completion counts are not
        # comparable — this is what "degrades gracefully" gates on.
        "background_per_kstep": 1000.0 * bg.completed / busy_supersteps,
        "drain_supersteps": int(drain_supersteps),
        "supersteps": s["supersteps"],
        "preempts": s["preempts"],
        "superstep_s_measured": superstep_s,
        "decode_p50_wall_s": dec["p50"] * superstep_s,
        "decode_p99_wall_s": dec["p99"] * superstep_s,
    }


def run_serving_bench(out_path=None) -> dict:
    """Write the ``serving`` section of BENCH_collectives.json (the QoS
    p99 + starvation gates of benchmarks/check_gates.py)."""
    import bench_collectives as BC
    out_path = out_path or BC.BENCH_JSON
    on = replay(preemption=True)
    off = replay(preemption=False)
    record = {
        "config": dict(
            REPLAY,
            model="latency in supersteps on the replay clock; wall "
                  "modeled as supersteps * measured superstep_s"),
        "preempt_on": on,
        "preempt_off": off,
        "p99_ratio": off["decode"]["p99"] / max(on["decode"]["p99"], 1e-9),
        "background_ratio": (
            on["background_per_kstep"]
            / max(off["background_per_kstep"], 1e-9)),
    }
    doc = BC._read_record(out_path)
    doc["serving"] = record
    BC._write_record(out_path, doc)
    print(f"serving/decode_p99,{on['decode_p99_wall_s']*1e6:.1f},"
          f"supersteps_on={on['decode']['p99']:.0f};"
          f"off={off['decode']['p99']:.0f};"
          f"ratio={record['p99_ratio']:.2f};"
          f"preempts={on['preempts']}")
    print(f"# wrote {out_path} (serving)")
    return record


if __name__ == "__main__":
    run_serving_bench()
