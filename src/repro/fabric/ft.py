"""Fault tolerance: checkpoint/restart training controller.

``TrainController`` wraps the train loop with the behaviors a 1000+-node
deployment needs:

* periodic async checkpoints (never blocks the step);
* a step watchdog (a step exceeding ``step_timeout_s`` marks the node
  suspect — on real fleets this triggers re-scheduling; here it raises);
* crash recovery: on any step failure the controller restores the last
  committed checkpoint (params, optimizer, data cursor) and resumes —
  losing at most ``ckpt_period`` steps;
* failure injection hooks for tests (``inject_failure_at``).

Straggler mitigation at the *collective* layer is the OCCL daemon's
voluntary-quit bound (core/daemon.py): a wedged peer cannot hold the
fabric — the daemon returns to the host, which can re-route or re-admit
work.  ``fabric/straggler.py`` adds the step-level detector.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax

from ..checkpoint.ckpt import AsyncCheckpointer, latest_step, restore
from ..data.pipeline import SyntheticPipeline


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_period: int = 20
    keep: int = 2
    step_timeout_s: float = 300.0
    max_restarts: int = 3


class StepTimeout(RuntimeError):
    pass


class TrainController:
    def __init__(self, cfg: FTConfig, step_fn: Callable, state,
                 pipeline: SyntheticPipeline,
                 inject_failure_at: Optional[int] = None):
        self.cfg = cfg
        self.step_fn = step_fn
        self.state = state
        self.pipeline = pipeline
        self.ckpt = AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.keep)
        self.inject_failure_at = inject_failure_at
        self.restarts = 0
        self.metrics_log: list[dict] = []

    def _checkpoint(self, step: int):
        self.ckpt.save_async(step, self.state,
                             extras={"pipeline": self.pipeline.state_dict()})

    def _recover(self):
        self.restarts += 1
        if self.restarts > self.cfg.max_restarts:
            raise RuntimeError("restart budget exhausted")
        last = latest_step(self.cfg.ckpt_dir)
        if last is None:
            raise RuntimeError("no checkpoint to recover from")
        self.state, extras = restore(self.cfg.ckpt_dir, last, self.state)
        self.pipeline.load_state_dict(extras["pipeline"])
        return last

    def run(self, n_steps: int) -> list[dict]:
        self._checkpoint(int(self.state.step))   # step-0 baseline
        self.ckpt.wait()
        done = int(self.state.step)
        while done < n_steps:
            try:
                if (self.inject_failure_at is not None
                        and done == self.inject_failure_at):
                    self.inject_failure_at = None   # fire once
                    raise RuntimeError("injected node failure")
                batch = next(self.pipeline)
                t0 = time.time()
                self.state, metrics = self.step_fn(self.state, batch)
                jax.block_until_ready(metrics)
                dt = time.time() - t0
                if dt > self.cfg.step_timeout_s:
                    raise StepTimeout(f"step took {dt:.1f}s")
                metrics = {k: float(v) for k, v in metrics.items()}
                metrics.update(step=done, step_time_s=dt,
                               restarts=self.restarts)
                self.metrics_log.append(metrics)
                done += 1
                if done % self.cfg.ckpt_period == 0:
                    self._checkpoint(done)
            except (RuntimeError, StepTimeout):
                recovered = self._recover()
                done = recovered
        self.ckpt.wait()
        self._checkpoint(done)
        self.ckpt.wait()
        return self.metrics_log
