"""Registration-time static tables for the daemon (paper Sec. 3.1.1).

``OCCL registers collectives to be used on each GPU and prepares their meta
information as well as collective context buffer slots before executing
them.``  Registration happens host-side in numpy; the result is a set of
dense arrays indexed by collective id, compiled into the daemon program.
Per-rank tables (primitive programs, membership) carry a leading rank axis
in the sim backend and are sliced per-device in the mesh backend.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .config import OcclConfig
from .primitives import (
    CollKind,
    CollectiveSpec,
    Communicator,
    Prim,
    build_program,
    io_chunked,
    program_len,
)


@dataclasses.dataclass
class StaticTables:
    """Dense static context for up to C collectives over Rk ranks."""

    # per-collective, rank-independent -----------------------------------
    registered: np.ndarray    # [C] bool
    kind: np.ndarray          # [C] int32
    op: np.ndarray            # [C] int32 (ReduceOp)
    lane: np.ndarray          # [C] int32
    n_steps: np.ndarray       # [C] int32 (per-rank program length; same all ranks)
    n_slices: np.ndarray      # [C] int32 (slices per chunk per round)
    n_rounds: np.ndarray      # [C] int32 (primitive-sequence repetitions)
    group_size: np.ndarray    # [C] int32
    in_chunked: np.ndarray    # [C] int32
    out_chunked: np.ndarray   # [C] int32
    base_in_off: np.ndarray   # [C] int32 (default heap offsets)
    base_out_off: np.ndarray  # [C] int32

    # per-(rank, collective) ----------------------------------------------
    member: np.ndarray        # [Rk, C] bool — rank participates
    prog_kind: np.ndarray     # [Rk, C, S] int32 (Prim)
    prog_chunk: np.ndarray    # [Rk, C, S] int32

    # per-lane ring permutations -----------------------------------------
    fwd_src: np.ndarray       # [L, Rk] int32 — fwd msg arriving at rank r
                              #   was sent by fwd_src[l, r]
    rev_src: np.ndarray       # [L, Rk] int32 — reverse (credit) exchange
    fwd_perm_pairs: list      # [L] list[(src, dst)] for lax.ppermute
    rev_perm_pairs: list
    # Lanes grouped by identical ring permutation: each group's traffic is
    # fused into ONE stacked ppermute pair per direction in the mesh
    # backend (instead of one ppermute per lane per mailbox field).
    lane_groups: list         # [(lanes: list[int], fwd_pairs, rev_pairs)]

    max_steps: int


def build_tables(
    cfg: OcclConfig,
    comms: list[Communicator],
    specs: list[CollectiveSpec],
) -> StaticTables:
    Rk, C, L = cfg.n_ranks, cfg.max_colls, cfg.max_comms
    assert len(comms) <= L, "more communicators than daemon lanes"
    assert len(specs) <= C, "more collectives than registered slots"
    for s in specs:
        assert s.coll_id < C
        assert s.comm.lane < L

    S = max(
        [program_len(CollKind(s.kind), s.group_size) for s in specs] or [1]
    )

    t = StaticTables(
        registered=np.zeros(C, bool),
        kind=np.zeros(C, np.int32),
        op=np.zeros(C, np.int32),
        lane=np.zeros(C, np.int32),
        n_steps=np.zeros(C, np.int32),
        n_slices=np.ones(C, np.int32),
        n_rounds=np.ones(C, np.int32),
        group_size=np.ones(C, np.int32),
        in_chunked=np.ones(C, np.int32),
        out_chunked=np.ones(C, np.int32),
        base_in_off=np.zeros(C, np.int32),
        base_out_off=np.zeros(C, np.int32),
        member=np.zeros((Rk, C), bool),
        prog_kind=np.full((Rk, C, S), int(Prim.NULL), np.int32),
        prog_chunk=np.zeros((Rk, C, S), np.int32),
        fwd_src=np.tile(np.arange(Rk, dtype=np.int32), (L, 1)),
        rev_src=np.tile(np.arange(Rk, dtype=np.int32), (L, 1)),
        fwd_perm_pairs=[[] for _ in range(L)],
        rev_perm_pairs=[[] for _ in range(L)],
        lane_groups=[],
        max_steps=S,
    )

    for comm in comms:
        fwd = comm.fwd_perm(Rk)   # perm[src] = dst
        rev = comm.rev_perm(Rk)
        for src in range(Rk):
            t.fwd_src[comm.lane, fwd[src]] = src
            t.rev_src[comm.lane, rev[src]] = src
        t.fwd_perm_pairs[comm.lane] = [
            (int(s), int(fwd[s])) for s in range(Rk)
        ]
        t.rev_perm_pairs[comm.lane] = [
            (int(s), int(rev[s])) for s in range(Rk)
        ]

    # Group lanes by ring-permutation signature; lanes without a
    # communicator (empty pairs) are excluded — their mailbox slots stay
    # zero, which the receiving scheduler reads as count 0.
    by_perm: dict = {}
    for lane in range(L):
        pairs = t.fwd_perm_pairs[lane]
        if not pairs:
            continue
        by_perm.setdefault(tuple(pairs), []).append(lane)
    t.lane_groups = [
        (lanes, list(sig), t.rev_perm_pairs[lanes[0]])
        for sig, lanes in by_perm.items()
    ]

    for s in specs:
        c = s.coll_id
        kind = CollKind(s.kind)
        inc, outc = io_chunked(kind)
        t.registered[c] = True
        t.kind[c] = int(kind)
        t.op[c] = int(s.op)
        t.lane[c] = s.comm.lane
        t.n_steps[c] = program_len(kind, s.group_size)
        t.n_slices[c] = s.n_slices
        t.n_rounds[c] = s.n_rounds
        t.group_size[c] = s.group_size
        t.in_chunked[c] = int(inc)
        t.out_chunked[c] = int(outc)
        t.base_in_off[c] = s.in_off
        t.base_out_off[c] = s.out_off
        for rank in s.comm.members:
            m = s.comm.member_index(rank)
            t.member[rank, c] = True
            prog = build_program(kind, m, s.group_size, s.root)
            for step, (prim, chunk) in enumerate(prog):
                t.prog_kind[rank, c, step] = int(prim)
                t.prog_chunk[rank, c, step] = chunk
    return t
