"""Collective primitives and per-rank primitive programs (paper Sec. 2.3).

Every commonly used collective is a per-rank sequence of *primitives*, each a
fusion of the four basic actions ``send / recv / reduce / copy`` over four
buffers (send/recv buffer, send/recv connector).  A rank executes its
sequence chunk-by-chunk, slice-by-slice; the (chunk, primitive, slice)
triple is the *dynamic context* that makes collectives preemptible.

This module builds the primitive program (``prim_kind[step], chunk[step]``)
for each rank of a communicator for the five collectives of the paper
(all-reduce, all-gather, reduce-scatter, broadcast, reduce), Ring algorithm /
Simple protocol, exactly the configuration benchmarked in paper Sec. 5.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Sequence

import jax.numpy as jnp
import numpy as np


class Prim(enum.IntEnum):
    """Primitive vocabulary (paper Sec. 2.3)."""

    NULL = 0                    # padding past the end of a program
    COPY = 1                    # local copy (degenerate 1-rank groups)
    SEND = 2
    RECV = 3
    COPY_SEND = 4
    RECV_COPY_SEND = 5
    RECV_REDUCE_SEND = 6
    RECV_REDUCE_COPY = 7
    RECV_REDUCE_COPY_SEND = 8
    # Pure relay: receive a slice burst and forward it WITHOUT touching
    # the output heap.  All-to-all is the first collective that needs it:
    # a ring hop carrying a chunk addressed to a rank further down the
    # ring must not deposit it locally (RECV_COPY_SEND would overwrite an
    # output chunk that belongs to a different origin).
    RECV_SEND = 9


# Action-fusion flag table: prim -> (recv, send, reduce, copy, reads_input).
# ``reads_input`` marks prims whose value involves the local send buffer.
_FLAGS = {
    Prim.NULL: (0, 0, 0, 0, 0),
    Prim.COPY: (0, 0, 0, 1, 1),
    Prim.SEND: (0, 1, 0, 0, 1),
    Prim.RECV: (1, 0, 0, 1, 0),
    Prim.COPY_SEND: (0, 1, 0, 1, 1),
    Prim.RECV_COPY_SEND: (1, 1, 0, 1, 0),
    Prim.RECV_REDUCE_SEND: (1, 1, 1, 0, 1),
    Prim.RECV_REDUCE_COPY: (1, 0, 1, 1, 1),
    Prim.RECV_REDUCE_COPY_SEND: (1, 1, 1, 1, 1),
    Prim.RECV_SEND: (1, 1, 0, 0, 0),
}

# Dense lookup arrays indexed by Prim value (used inside jitted code).
PRIM_RECV = np.array([_FLAGS[Prim(i)][0] for i in range(len(Prim))], np.int32)
PRIM_SEND = np.array([_FLAGS[Prim(i)][1] for i in range(len(Prim))], np.int32)
PRIM_REDUCE = np.array([_FLAGS[Prim(i)][2] for i in range(len(Prim))], np.int32)
PRIM_COPY = np.array([_FLAGS[Prim(i)][3] for i in range(len(Prim))], np.int32)
PRIM_READS_IN = np.array([_FLAGS[Prim(i)][4] for i in range(len(Prim))], np.int32)


class CollKind(enum.IntEnum):
    ALL_REDUCE = 0
    ALL_GATHER = 1
    REDUCE_SCATTER = 2
    BROADCAST = 3
    REDUCE = 4
    # Personalized exchange: member m's input chunk d is the payload FOR
    # member d; its output chunk o is the payload FROM member o.  The
    # first kind whose send AND recv buffers are both per-peer chunked
    # with *different* chunk indices live at each program step.
    ALL_TO_ALL = 5
    # Capacity-dropped variant: per-DISTANCE valid sizes (chunk s of the
    # padded buffer carries ``chunk_sizes[s]`` live elements for member
    # (m+s) mod R on the way in, from member (m-s) mod R on the way
    # out).  Distance keying keeps the stage maps rank-independent, so
    # one per-collective map serves every rank (see tables.py).
    ALL_TO_ALL_RAGGED = 6


def build_program(
    kind: CollKind, member_idx: int, group_size: int, root_idx: int = 0
) -> list[tuple[Prim, int]]:
    """Per-rank primitive sequence ``[(prim, chunk_idx), ...]``.

    ``member_idx`` is the rank's position in the communicator's ring order;
    data flows member m -> member (m+1) % group_size.  Ring algorithm,
    Simple protocol (paper Sec. 5 Benchmarks).  The per-kind builders
    live in the algorithm registry (:mod:`repro.core.algos`); this
    wrapper keeps the historical entrypoint.
    """
    from .algos import build_ring_program

    return build_ring_program(kind, member_idx, group_size, root_idx)


# Ring all-to-all: step 0 is the local COPY, then phase s in 1..R-1 moves
# every (origin -> origin+s) pair s hops down the ring: one SEND, s-1
# relay forwards (RECV_SEND), one final RECV — sum_{s=1}^{R-1} (s+1)
# steps after the COPY.
def _ring_a2a_len(group_size: int) -> int:
    return 1 + (group_size - 1) * (group_size + 2) // 2


# Per-kind registries.  Kinds are extensible (the a2a family was added
# after the original five), so lookups go through :func:`_registered`
# which raises a ValueError naming the kind and the registered set
# instead of a bare KeyError.
_PROGRAM_LEN: dict[CollKind, "callable"] = {
    CollKind.ALL_REDUCE: lambda R: 2 * R - 1,
    CollKind.ALL_GATHER: lambda R: R,
    CollKind.REDUCE_SCATTER: lambda R: R,
    CollKind.BROADCAST: lambda R: R,
    CollKind.REDUCE: lambda R: R,
    CollKind.ALL_TO_ALL: _ring_a2a_len,
    CollKind.ALL_TO_ALL_RAGGED: _ring_a2a_len,
}

# I/O indexing: whether the collective's send/recv *buffer* is indexed by the
# chunk id (True) or holds a single chunk addressed by slice only (False).
_IO_CHUNKED: dict[CollKind, tuple[bool, bool]] = {
    CollKind.ALL_REDUCE: (True, True),
    CollKind.ALL_GATHER: (False, True),   # in: own chunk; out: all chunks
    CollKind.REDUCE_SCATTER: (True, False),
    CollKind.BROADCAST: (True, True),
    CollKind.REDUCE: (True, True),
    CollKind.ALL_TO_ALL: (True, True),    # per-destination in, per-origin out
    CollKind.ALL_TO_ALL_RAGGED: (True, True),
}


def _registered(kind, table: dict, what: str):
    """Registry lookup with a loud, named error for unknown kinds."""
    try:
        return table[CollKind(kind)]
    except (KeyError, ValueError):
        known = sorted(CollKind(k).name for k in table)
        raise ValueError(
            f"{what} has no entry for collective kind {kind!r}; "
            f"registered kinds: {known}") from None


def program_len(kind: CollKind, group_size: int) -> int:
    if group_size == 1:
        return 1
    return _registered(kind, _PROGRAM_LEN, "program_len")(group_size)


def io_chunked(kind: CollKind) -> tuple[bool, bool]:
    return _registered(kind, _IO_CHUNKED, "io_chunked")


@dataclasses.dataclass(frozen=True)
class Communicator:
    """Rank group(s) with fixed ring order, bound to a daemon lane.

    The lane is the CUDA-block analogue (paper Sec. 4): lane ``l`` on every
    device gang-schedules with lane ``l`` on its ring peers and owns a
    private connector channel (one forward slice exchange + one reverse
    credit exchange per superstep).

    A communicator may be PARTITIONED into several disjoint rings of equal
    size sharing the one lane (``ring_size < len(members)``): consecutive
    ``ring_size``-runs of ``members`` are independent rings, each with its
    own wrap-around data flow.  Disjoint rings merge into one well-defined
    lane permutation, which is how the composite layer runs e.g. all G
    intra-group rings of a two-level decomposition on a single lane.
    ``size`` is the RING size (the group size programs are built for),
    not the member count.
    """

    comm_id: int
    members: tuple[int, ...]      # global ranks; consecutive ring_size runs
    lane: int
    ring_size: int | None = None  # None: one ring over all members

    def __post_init__(self):
        assert len(set(self.members)) == len(self.members)
        if self.ring_size is not None:
            assert self.ring_size >= 1
            assert len(self.members) % self.ring_size == 0, (
                "members must tile into equal-size rings")

    @property
    def size(self) -> int:
        return (len(self.members) if self.ring_size is None
                else self.ring_size)

    def member_index(self, rank: int) -> int:
        """Position of ``rank`` within ITS ring (ring-local index)."""
        return self.members.index(rank) % self.size

    def rings(self) -> list[tuple[int, ...]]:
        rs = self.size
        return [self.members[i:i + rs]
                for i in range(0, len(self.members), rs)]

    def fwd_perm(self, n_ranks: int) -> np.ndarray:
        """perm[src] = dst for the forward (data) exchange; identity
        off-group.  Each partitioned ring wraps independently."""
        perm = np.arange(n_ranks)
        for ring in self.rings():
            for i, r in enumerate(ring):
                perm[r] = ring[(i + 1) % len(ring)]
        return perm

    def rev_perm(self, n_ranks: int) -> np.ndarray:
        perm = np.arange(n_ranks)
        for ring in self.rings():
            for i, r in enumerate(ring):
                perm[r] = ring[(i - 1) % len(ring)]
        return perm


@dataclasses.dataclass(frozen=True)
class CollectiveSpec:
    """Static context of a registered collective (paper Sec. 3.1.1).

    Constant configuration: buffer geometry, group meta, primitive-sequence
    composition.  Buffer *addresses* (heap offsets) live here as defaults but
    may be overridden per submission by the SQE (paper Sec. 3.1.2).
    """

    coll_id: int
    kind: CollKind
    comm: Communicator
    n_elems: int                  # logical element count (all-reduce size N)
    op: ReduceOpLike = 0          # ReduceOp value
    root: int = 0                 # member index of root (broadcast/reduce)
    in_off: int = 0               # default heap offsets
    out_off: int = 0
    n_slices: int = 1             # slices per chunk PER ROUND (derived)
    n_rounds: int = 1             # primitive-sequence repetitions (derived)
    # Composite-chain linkage (core/algos.py CompositePlan): a chained
    # sub-collective names its successor, which the daemon enqueues ON
    # DEVICE when this stage completes; only the chain tail (next_coll ==
    # -1) emits a CQE for the logical collective.
    next_coll: int = -1           # successor collective id (-1: tail/flat)
    chain_stage: int = 0          # 0 = head/standalone, 1.. = later stages
    inherit_prio: bool = True     # successor inherits the live priority
    # Logical-input permutation: stage-local logical position of each
    # caller-logical element j (empty = identity).  Applied to the stage
    # INPUT map only (tables._build_stage_maps); composite a2a plans use
    # it to fold the inter-stage granule transpose into the existing
    # chain relink instead of adding a shuffle stage.
    in_perm: tuple = ()
    # ALL_TO_ALL_RAGGED only: per-distance live element counts, one per
    # ring member, each <= ceil(n_elems / group_size).  Empty = dense.
    chunk_sizes: tuple = ()

    @property
    def group_size(self) -> int:
        return self.comm.size

    def chunk_elems(self, slice_elems: int) -> int:
        return self.n_rounds * self.n_slices * slice_elems

    def padded_elems(self, slice_elems: int) -> int:
        return self.group_size * self.chunk_elems(slice_elems)


ReduceOpLike = int


def burst_quota(burst, room, recv_avail, send_free, needs_recv, needs_send):
    """Slices a lane may move this superstep (credit-aware gating math).

    Element-wise over lanes: the burst is bounded by the configured width
    ``burst``, the slices left in the current primitive step ``room`` (a
    burst never crosses a step boundary, so preemption granularity stays
    one slice between bursts), the committed-but-unconsumed writes in the
    recv connector (``recv_avail = head_mirror - tail``) when the primitive
    receives, and the free connector slots (``send_free = K - (head -
    tail_mirror)``) when it sends.  Both mirrors lag the peer's true
    counter, so the quota is conservative: per-slice credit accounting is
    unchanged and the ``sum(sent - consumed) <= R * (K - 1)`` ring-capacity
    invariant of :func:`derive_slicing` survives bursts unweakened.
    """
    q = jnp.minimum(jnp.asarray(burst, jnp.int32), room)
    q = jnp.minimum(q, jnp.where(needs_recv, recv_avail, q))
    q = jnp.minimum(q, jnp.where(needs_send, send_free, q))
    return jnp.maximum(q, 0)


def derive_slicing(n_elems: int, group_size: int, slice_elems: int,
                   conn_depth: int) -> tuple[int, int]:
    """(slices-per-chunk-per-round, rounds).

    The paper: "A GPU executes a collective by executing its primitive
    sequence a certain number of times to process all the data chunks."
    Per-round slices are capped at ``conn_depth - 1`` so the connector ring
    can never fill on every edge simultaneously: around a ring,
    sum(sent - consumed) <= R * (K - 1) < R * K, hence at least one edge
    always has both data and capacity — the fused primitives cannot wedge.
    This mirrors NCCL sizing chunks to fit the connector buffer.
    """
    assert conn_depth >= 2, "conn_depth must be >= 2 for pipelining"
    chunk = -(-n_elems // group_size)              # ceil
    total = max(1, -(-chunk // slice_elems))       # ceil: slices per chunk
    cap = conn_depth - 1
    rounds = -(-total // cap)
    per_round = -(-total // rounds)
    return per_round, rounds
