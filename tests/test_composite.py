"""Composite-collective layer: hierarchical two-level algorithms via
device-chained sub-collectives (core/algos.py + the chain tables /
scheduler successor-enqueue machinery).

Covers the acceptance criteria of the composite tentpole:
* two-level all-reduce numerically equivalent to the flat ring
  (numpy-reference tolerance) across hierarchies and ragged sizes;
* the chain advances ON DEVICE — one ``launch_once`` completes the whole
  chain when uncontended, observed via the ``stats()`` chain/stage
  counters;
* per-SQE offset overrides resolve end-to-end through the chain (head
  input, tail output);
* chained sub-collectives submitted in conflicting orders complete
  (deterministic adversarial scenario; the hypothesis sweep lives in
  test_deadlock_freedom_props.py).
"""
import numpy as np
import pytest

from repro.core import (AUTO_CANDIDATES, PLAN_BUILDERS, CollKind, CostModel,
                        OcclConfig, OcclRuntime, OrderPolicy, ReduceOp,
                        build_plan, default_hierarchy, plan_features,
                        plan_two_level, select_algo, run_static_order)


def _runtime(R, max_colls=16, max_comms=4, slice_elems=8, conn_depth=6,
             heap_elems=1 << 15, **kw):
    cfg = OcclConfig(n_ranks=R, max_colls=max_colls, max_comms=max_comms,
                     slice_elems=slice_elems, conn_depth=conn_depth,
                     heap_elems=heap_elems, superstep_budget=1 << 14, **kw)
    rt = OcclRuntime(cfg)
    return rt, rt.communicator(list(range(R)))


# ---------------------------------------------------------------------------
# planning / selection units
# ---------------------------------------------------------------------------

def test_default_hierarchy_most_square():
    assert default_hierarchy(16) == (4, 4)
    assert default_hierarchy(8) == (4, 2)
    assert default_hierarchy(12) == (4, 3)
    assert default_hierarchy(7) == (7, 1)      # prime: degenerate


def test_plan_two_level_stage_shapes():
    plan = plan_two_level(CollKind.ALL_REDUCE, range(8), (2, 4), 100)
    rs, ar, ag = plan.stages
    assert rs.kind == CollKind.REDUCE_SCATTER and rs.ring_size == 4
    assert rs.members == tuple(range(8)) and rs.n_elems == 100
    # Inter rings join the chunk owners at each intra position.
    assert ar.kind == CollKind.ALL_REDUCE and ar.ring_size == 2
    assert ar.members == (0, 4, 1, 5, 2, 6, 3, 7)
    assert ar.n_elems == 25                    # ceil(100 / 4)
    assert ag.kind == CollKind.ALL_GATHER and ag.n_elems == 100


def test_plan_two_level_rejects_bad_grids():
    with pytest.raises(ValueError, match="does not tile"):
        plan_two_level(CollKind.ALL_REDUCE, range(8), (3, 2), 10)
    with pytest.raises(ValueError, match="ALL_REDUCE only"):
        plan_two_level(CollKind.BROADCAST, range(8), (2, 4), 10)


def test_plan_registry_contents():
    """The algorithm zoo registers every (algo, kind) lowering and auto's
    candidate lists stay consistent with it."""
    assert ("two_level", CollKind.ALL_REDUCE) in PLAN_BUILDERS
    assert ("torus", CollKind.ALL_REDUCE) in PLAN_BUILDERS
    assert ("hybrid", CollKind.ALL_REDUCE) in PLAN_BUILDERS
    assert ("tree", CollKind.BROADCAST) in PLAN_BUILDERS
    assert ("tree", CollKind.REDUCE) in PLAN_BUILDERS
    for kind, cands in AUTO_CANDIDATES.items():
        assert cands[0] == "ring"
        for a in cands[1:]:
            assert (a, kind) in PLAN_BUILDERS


def test_select_algo_cost_model():
    # Explicit algorithms pass through untouched.
    assert select_algo("ring", CollKind.ALL_REDUCE, 1 << 20, 16) == "ring"
    assert select_algo("torus", CollKind.ALL_REDUCE, 4, 16) == "torus"
    # Degenerate grids (prime groups) and kinds with no composite
    # candidate fall back to the flat ring without touching the model.
    assert select_algo("auto", CollKind.ALL_REDUCE, 4096, 7) == "ring"
    assert select_algo("auto", CollKind.ALL_GATHER, 4096, 16) == "ring"
    # A per-stage-overhead-only model always keeps the flat ring: one
    # stage beats any chain.
    stagey = CostModel(alpha=0.0, beta=0.0, gamma=1.0)
    assert select_algo("auto", CollKind.ALL_REDUCE, 1 << 20, 16,
                       model=stagey) == "ring"
    assert select_algo("auto", CollKind.BROADCAST, 1 << 20, 16,
                       model=stagey) == "ring"
    # A latency-only model under inter-island bandwidth skew must drop
    # the flat ring at large payloads (its single lane crosses islands,
    # so EVERY superstep pays the inter cap) and must agree with the
    # model's own feature argmin.
    cfg = OcclConfig(n_ranks=16, burst_slices=8, conn_depth=24,
                     bandwidth_groups=4, inter_burst_cap=2,
                     max_comms=8, max_colls=8)
    lat = CostModel(alpha=1.0, beta=0.0, gamma=0.0)
    pick = select_algo("auto", CollKind.ALL_REDUCE, 1 << 16, 16,
                       cfg=cfg, model=lat)
    assert pick != "ring"
    feats = {a: plan_features(cfg, CollKind.ALL_REDUCE, 1 << 16, 16,
                              (4, 4), a)
             for a in AUTO_CANDIDATES[CollKind.ALL_REDUCE]}
    assert pick == min(feats, key=lambda a: lat.predict(feats[a]))
    # An explicitly passed grid that does not tile the group is a BUG,
    # not a hint: auto must raise, not silently downgrade to ring.
    with pytest.raises(ValueError, match="does not tile"):
        select_algo("auto", CollKind.ALL_REDUCE, 4096, 16, (4, 5))


def test_logical_communicator_claims_no_lane():
    """A logical_communicator() descriptor supports composite registration
    without spending a max_comms slot; flat registration on it is
    rejected."""
    cfg = OcclConfig(n_ranks=8, max_colls=8, max_comms=2, slice_elems=8,
                     conn_depth=6, heap_elems=1 << 15,
                     superstep_budget=1 << 14)
    rt = OcclRuntime(cfg)                     # exactly the derived lanes
    grid = rt.logical_communicator(range(8))
    cid = rt.register(CollKind.ALL_REDUCE, grid, n_elems=48,
                      algo="two_level", hierarchy=(2, 4))
    assert len(rt.comms) == 2                 # intra + inter only
    with pytest.raises(ValueError, match="lane-bound"):
        rt.register(CollKind.ALL_REDUCE, grid, n_elems=8)
    xs = [np.full(48, r + 1.0, np.float32) for r in range(8)]
    for r in range(8):
        rt.submit(r, cid, data=xs[r])
    rt.drive()
    for r in range(8):
        np.testing.assert_allclose(rt.read_output(r, cid),
                                   np.sum(xs, axis=0), rtol=1e-5)


def test_registration_chain_tables():
    rt, world = _runtime(8)
    flat = rt.register(CollKind.ALL_REDUCE, world, n_elems=32)
    head = rt.register(CollKind.ALL_REDUCE, world, n_elems=64,
                       algo="two_level", hierarchy=(2, 4))
    rt._ensure_built()
    t = rt._tables
    stages = rt.stats()["chains"][head]
    assert stages == [head, head + 1, head + 2]
    assert t.next_coll[flat] == -1 and t.chain_tail[flat] == flat
    assert list(t.next_coll[stages]) == [head + 1, head + 2, -1]
    assert list(t.chain_tail[stages]) == [head + 2] * 3
    assert list(t.chain_stage[stages]) == [0, 1, 2]
    # chain_mask: one-hot for flat, the full stage set for every stage.
    assert t.chain_mask[flat].sum() == 1
    for s in stages:
        assert sorted(np.nonzero(t.chain_mask[s])[0]) == stages
    # Relink maps cover each successor's whole padded input span; logical
    # positions point into the predecessor's output region.
    assert t.has_chains
    for c, succ in zip(stages[:-1], stages[1:]):
        span = int(t.in_span[succ])
        dst = t.chain_dst[c, :span]
        np.testing.assert_array_equal(
            dst, t.base_in_off[succ] + np.arange(span))
        src = t.chain_src[c, :span]
        logical = src[t.stage_in_map[succ]]
        assert (logical >= t.base_out_off[c]).all()
        pads = np.setdiff1d(np.arange(span), t.stage_in_map[succ])
        assert (src[pads] == -1).all()        # pads zero-fill


def test_derived_communicators_share_lanes():
    """Composite collectives over the same grid reuse the derived intra
    and inter sub-communicator partitions (one lane each)."""
    rt, world = _runtime(8)
    a = rt.register(CollKind.ALL_REDUCE, world, n_elems=64,
                    algo="two_level", hierarchy=(2, 4))
    b = rt.register(CollKind.ALL_REDUCE, world, n_elems=48,
                    algo="two_level", hierarchy=(2, 4))
    lanes_a = {rt.specs[c].comm.lane for c in rt._chain_of[a]}
    lanes_b = {rt.specs[c].comm.lane for c in rt._chain_of[b]}
    assert lanes_a == lanes_b                 # shared intra + inter lanes
    assert len(rt.comms) == 3                 # world + intra + inter


def test_lane_budget_validated():
    rt, world = _runtime(8, max_comms=2)      # world takes lane 0
    with pytest.raises(ValueError, match="max_comms"):
        rt.register(CollKind.ALL_REDUCE, world, n_elems=64,
                    algo="two_level", hierarchy=(2, 4))


# ---------------------------------------------------------------------------
# end-to-end equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("R,hier", [(4, (2, 2)), (8, (2, 4)), (8, (4, 2))])
@pytest.mark.parametrize("n", [8, 40, 100])
def test_two_level_matches_numpy_reference(R, hier, n):
    rt, world = _runtime(R)
    cid = rt.register(CollKind.ALL_REDUCE, world, n_elems=n,
                      algo="two_level", hierarchy=hier)
    rng = np.random.RandomState(n + R)
    xs = [rng.randn(n).astype(np.float32) for _ in range(R)]
    for r in range(R):
        rt.submit(r, cid, data=xs[r])
    rt.drive()
    want = np.sum(xs, axis=0)
    for r in range(R):
        np.testing.assert_allclose(rt.read_output(r, cid), want,
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("algo", ["torus", "hybrid"])
@pytest.mark.parametrize("R,hier", [(4, (2, 2)), (8, (2, 4)), (8, (4, 2))])
@pytest.mark.parametrize("n", [8, 37, 100])
def test_new_allreduce_algos_match_numpy_reference(algo, R, hier, n):
    """Every new all-reduce plan is numerically equivalent to the flat
    ring reference (numpy sum) across grids and ragged payloads."""
    rt, world = _runtime(R, max_comms=6)
    cid = rt.register(CollKind.ALL_REDUCE, world, n_elems=n,
                      algo=algo, hierarchy=hier)
    rng = np.random.RandomState(n + R)
    xs = [rng.randn(n).astype(np.float32) for _ in range(R)]
    rt.submit_all(cid, data={r: xs[r] for r in range(R)})
    rt.drive()
    want = np.sum(xs, axis=0)
    for r in range(R):
        np.testing.assert_allclose(rt.read_output(r, cid), want,
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("root", [0, 3, 7])
@pytest.mark.parametrize("n", [8, 37])
def test_tree_broadcast_matches_reference(root, n):
    """Tree broadcast (leader ring -> intra rings) delivers the root's
    payload to every rank, for roots in ANY grid position (the root's
    group leads the leader stage; its intra position roots every intra
    ring)."""
    R, hier = 8, (2, 4)
    rt, world = _runtime(R, max_comms=6)
    cid = rt.register(CollKind.BROADCAST, world, n_elems=n, root=root,
                      algo="tree", hierarchy=hier)
    rng = np.random.RandomState(root + n)
    xs = [rng.randn(n).astype(np.float32) for _ in range(R)]
    rt.submit_all(cid, data={r: xs[r] for r in range(R)})
    rt.drive()
    for r in range(R):
        np.testing.assert_allclose(rt.read_output(r, cid), xs[root],
                                   rtol=1e-5)


@pytest.mark.parametrize("root", [0, 3, 7])
def test_tree_reduce_matches_reference(root):
    """Tree reduce (intra reduce -> leader reduce) lands the full sum at
    the root for any root position."""
    R, hier, n = 8, (2, 4), 37
    rt, world = _runtime(R, max_comms=6)
    cid = rt.register(CollKind.REDUCE, world, n_elems=n, root=root,
                      algo="tree", hierarchy=hier)
    rng = np.random.RandomState(root)
    xs = [rng.randn(n).astype(np.float32) for _ in range(R)]
    rt.submit_all(cid, data={r: xs[r] for r in range(R)})
    rt.drive()
    np.testing.assert_allclose(rt.read_output(root, cid),
                               np.sum(xs, axis=0), rtol=1e-4, atol=1e-5)


def test_partial_membership_completion_routing():
    """Tree-reduce non-leaders participate ONLY in the intra stage: their
    SQE enters at the head, their CQE fires at the head (their last
    stage), and the per-rank completion counters land on each rank's own
    tail — while leaders run the full chain.  Callbacks still surface
    the logical id on every rank exactly once."""
    R, hier, n = 8, (2, 4), 24
    rt, world = _runtime(R, max_comms=6)
    cid = rt.register(CollKind.REDUCE, world, n_elems=n, root=0,
                      algo="tree", hierarchy=hier)
    chain = rt._chain_of[cid]
    head, tail = chain[0], chain[-1]
    leaders = set(rt.specs[tail].comm.members)
    assert 0 in leaders and len(leaders) == 2          # G = 2 leader ring
    # Entry routing: intra stage includes everyone -> no entry remap;
    # completion: non-leaders end at the head stage.
    assert cid not in rt._entry_of
    assert set(rt._rank_tail[cid]) == set(range(R)) - leaders
    assert all(t == head for t in rt._rank_tail[cid].values())
    fired = []
    xs = [np.full(n, float(r + 1), np.float32) for r in range(R)]
    rt.submit_all(cid, data={r: xs[r] for r in range(R)},
                  callback=lambda rk, c: fired.append((rk, c)))
    rt.drive()
    assert sorted(fired) == [(r, cid) for r in range(R)]
    np.testing.assert_allclose(rt.read_output(0, cid), np.sum(xs, axis=0),
                               rtol=1e-5)
    st = rt.stats()
    comp = st["completed"]
    for r in range(R):
        own_tail = tail if r in leaders else head
        assert comp[r, own_tail] == 1
        assert comp[r, [c for c in chain if c != own_tail]].sum() == 0
    # Per-stage counters: everyone ran the intra stage; only leaders ran
    # the leader stage.
    assert (st["stage_completions"][:, head] == 1).all()
    for r in range(R):
        assert st["stage_completions"][r, tail] == (1 if r in leaders
                                                    else 0)


def test_two_level_repeat_submissions_serialize():
    """A re-submitted chain head waits for the whole previous chain
    (chain-wide inflight), and both logical executions complete."""
    R, n = 4, 24
    rt, world = _runtime(R)
    cid = rt.register(CollKind.ALL_REDUCE, world, n_elems=n,
                      algo="two_level", hierarchy=(2, 2))
    rng = np.random.RandomState(7)
    xs1 = [rng.randn(n).astype(np.float32) for _ in range(R)]
    xs2 = [rng.randn(n).astype(np.float32) for _ in range(R)]
    done = []
    for r in range(R):
        rt.submit(r, cid, data=xs1[r], callback=lambda rk, c: done.append(1))
        rt.submit(r, cid, data=xs2[r], callback=lambda rk, c: done.append(2))
    rt.drive()
    assert len(done) == 2 * R
    # Second execution's results are live (last submission wins the heap).
    want = np.sum(xs2, axis=0)
    for r in range(R):
        np.testing.assert_allclose(rt.read_output(r, cid), want,
                                   rtol=1e-4, atol=1e-5)
    st = rt.stats()
    # Logical completions count 2 per rank, on the TAIL only; every stage
    # ran twice per rank.
    chain = st["chains"][cid]
    assert (st["completed"][:, chain[-1]] == 2).all()
    assert (st["completed"][:, chain[:-1]] == 0).all()
    assert (st["stage_completions"][:, chain] == 2).all()


def test_chain_advances_on_device_single_launch():
    """One launch_once completes the whole chain when uncontended: no
    host round trip between stages (the tentpole's scheduler criterion),
    asserted via the stats() chain/stage counters."""
    R = 8
    rt, world = _runtime(R)
    cid = rt.register(CollKind.ALL_REDUCE, world, n_elems=64,
                      algo="two_level", hierarchy=(2, 4))
    xs = [np.full(64, r + 1, np.float32) for r in range(R)]
    for r in range(R):
        rt.submit(r, cid, data=xs[r])
    fired = rt.launch_once()
    assert fired == R                          # all logical CQEs in launch 1
    assert rt.launches == 1
    assert rt.queues.outstanding() == 0
    st = rt.stats()
    chain = st["chains"][cid]
    assert (st["stage_completions"][:, chain] == 1).all()
    assert (st["completed"][:, chain[-1]] == 1).all()
    want = np.sum(xs, axis=0)
    for r in range(R):
        np.testing.assert_allclose(rt.read_output(r, cid), want, rtol=1e-5)


def test_auto_selection_registers_measured_winner():
    """auto under the cost model: below the crossover the flat ring wins
    (per-stage overhead), above it — under bandwidth skew — a chained
    plan does; both registrations execute correctly side by side."""
    rt, world = _runtime(8, heap_elems=1 << 16, max_comms=8,
                         burst_slices=8, conn_depth=24,
                         bandwidth_groups=2, inter_burst_cap=1)
    model = CostModel.default()
    rt._cost_model = model
    small = rt.register(CollKind.ALL_REDUCE, world, n_elems=64,
                        algo="auto")
    big = rt.register(CollKind.ALL_REDUCE, world, n_elems=4096,
                      algo="auto")
    assert small not in rt._chain_of           # flat ring at small n
    assert big in rt._chain_of                 # chained plan at large n
    assert rt.stats()["algos"][big] in ("two_level", "torus", "hybrid")
    # Each pick IS the model's argmin over the candidates.
    for cid, n in ((small, 64), (big, 4096)):
        feats = {a: plan_features(rt.cfg, CollKind.ALL_REDUCE, n, 8,
                                  default_hierarchy(8), a)
                 for a in AUTO_CANDIDATES[CollKind.ALL_REDUCE]}
        want = min(feats, key=lambda a: model.predict(feats[a]))
        got = rt.stats()["algos"].get(cid, "ring")
        assert got == want
    rng = np.random.RandomState(0)
    data = {c: [rng.randn(n).astype(np.float32) for _ in range(8)]
            for c, n in [(small, 64), (big, 4096)]}
    for r in range(8):
        rt.submit(r, big, data=data[big][r])
        rt.submit(r, small, data=data[small][r])
    rt.drive()
    for c in (small, big):
        want = np.sum(data[c], axis=0)
        for r in range(8):
            np.testing.assert_allclose(rt.read_output(r, c), want,
                                       rtol=1e-4, atol=1e-4)


def test_offset_overrides_end_to_end_through_chain():
    """in_off lands on the chain HEAD's read, out_off on the TAIL's
    write; intermediates stay at their registered regions."""
    R, n = 4, 32
    rt, world = _runtime(R)
    cid = rt.register(CollKind.ALL_REDUCE, world, n_elems=n,
                      algo="two_level", hierarchy=(2, 2))
    alt_in = 1 << 12
    alt_out = (1 << 12) + 512
    rng = np.random.RandomState(1)
    xs = [rng.randn(n).astype(np.float32) for _ in range(R)]
    for r in range(R):
        rt.submit(r, cid, data=xs[r], in_off=alt_in, out_off=alt_out)
    rt.drive()
    want = np.sum(xs, axis=0)
    for r in range(R):
        np.testing.assert_allclose(rt.read_output(r, cid, out_off=alt_out),
                                   want, rtol=1e-4, atol=1e-5)
    # The registered default output region was never the destination.
    default_out = np.asarray(
        rt.read_output(0, cid))                # registered tail region
    assert not np.allclose(default_out, want)
    # Out-of-range overrides are rejected against the TAIL's span.
    with pytest.raises(ValueError, match="out_off"):
        rt.submit(0, cid, out_off=rt.cfg.heap_elems)


def test_priority_inherits_down_the_chain():
    """Under PRIORITY ordering, a chain submitted with high priority keeps
    outranking a low-priority flat collective through its device-enqueued
    successor stages (inherit_prio=True default)."""
    R, n = 4, 64
    rt, world = _runtime(R, order_policy=OrderPolicy.PRIORITY)
    lo = rt.register(CollKind.ALL_REDUCE, world, n_elems=n)
    hi = rt.register(CollKind.ALL_REDUCE, world, n_elems=n,
                     algo="two_level", hierarchy=(2, 2))
    rng = np.random.RandomState(2)
    xs = {c: [rng.randn(n).astype(np.float32) for _ in range(R)]
          for c in (lo, hi)}
    for r in range(R):
        rt.submit(r, lo, prio=0, data=xs[lo][r])
        rt.submit(r, hi, prio=7, data=xs[hi][r])
    rt.drive()
    for c in (lo, hi):
        want = np.sum(xs[c], axis=0)
        for r in range(R):
            np.testing.assert_allclose(rt.read_output(r, c), want,
                                       rtol=1e-4, atol=1e-5)
    # The device propagated the submission priority to the chain stages.
    chain = rt.stats()["chains"][hi]
    prio = np.asarray(rt.state.prio)
    assert (prio[:, chain[1:]] == 7).all()


def test_submit_all_forwards_per_rank_arguments():
    """Satellite: submit_all carries per-rank prio, payloads, callbacks
    and offset overrides (scalar-or-dict forms)."""
    R, n = 4, 16
    rt, world = _runtime(R, order_policy=OrderPolicy.PRIORITY)
    cid = rt.register(CollKind.ALL_REDUCE, world, n_elems=n)
    rng = np.random.RandomState(3)
    xs = {r: rng.randn(n).astype(np.float32) for r in range(R)}
    seen = []
    rt.submit_all(cid,
                  prio={r: r for r in range(R)},
                  data=xs,
                  callback={0: lambda rk, c: seen.append((rk, c))},
                  out_off={1: 1 << 12})
    rt.drive()
    want = np.sum(list(xs.values()), axis=0)
    np.testing.assert_allclose(rt.read_output(0, cid), want, rtol=1e-5)
    # Rank 1 wrote through its per-rank out_off override...
    np.testing.assert_allclose(rt.read_output(1, cid, out_off=1 << 12),
                               want, rtol=1e-5)
    # ...and only rank 0's callback was registered.
    assert seen == [(0, cid)]


def test_bandwidth_skew_lane_caps():
    """The bandwidth-skew knob classifies derived lanes: intra rings stay
    at the full burst, island-crossing rings get the inter cap; caps are
    surfaced via stats() and the skewed run stays correct."""
    R, hier, n = 8, (2, 4), 48
    rt, world = _runtime(R, max_comms=6, burst_slices=8, conn_depth=24,
                         bandwidth_groups=2, inter_burst_cap=2)
    cid = rt.register(CollKind.ALL_REDUCE, world, n_elems=n,
                      algo="two_level", hierarchy=hier)
    xs = [np.full(n, r + 1.0, np.float32) for r in range(R)]
    rt.submit_all(cid, data={r: xs[r] for r in range(R)})
    rt.drive()
    for r in range(R):
        np.testing.assert_allclose(rt.read_output(r, cid),
                                   np.sum(xs, axis=0), rtol=1e-5)
    caps = rt.stats()["lane_caps"]
    lanes = {rt.specs[c].comm.lane for c in rt._chain_of[cid]}
    intra_lane = rt.specs[rt._chain_of[cid][0]].comm.lane
    inter_lane = rt.specs[rt._chain_of[cid][1]].comm.lane
    assert caps[0] == 2                  # flat world ring crosses islands
    assert caps[intra_lane] == 8         # intra rings: groups of 4 =
                                         # exactly one island each
    assert caps[inter_lane] == 2         # owner rings span both islands
    assert lanes == {intra_lane, inter_lane}


def test_cond_chain_relink_traced_as_branch():
    """cond_chain_relink wraps the relink scatter in a lax.cond when the
    registration has chains; the escape hatch traces the unconditional
    form (no cond primitive)."""
    import jax

    from repro.core.daemon import (_count_primitive, _load_mailbox,
                                   local_tables, shared_tables)
    from repro.core.scheduler import rank_superstep
    from repro.core.state import init_state
    from repro.core.tables import build_tables

    rt, world = _runtime(8, max_comms=6)
    rt.register(CollKind.ALL_REDUCE, world, n_elems=32,
                algo="two_level", hierarchy=(2, 4))
    t = build_tables(rt.cfg, rt.comms, rt.specs)
    sh, lt_all = shared_tables(t), local_tables(t)
    lt = jax.tree_util.tree_map(lambda a: a[0], lt_all)
    st = init_state(rt.cfg, per_rank=False)
    inbox = _load_mailbox(st)
    counts = {}
    for cond in (True, False):
        jaxpr = jax.make_jaxpr(
            lambda s, i: rank_superstep(rt.cfg, sh, lt, s, i,
                                        cond_relink=cond))(st, inbox)
        counts[cond] = _count_primitive(jaxpr.jaxpr, "cond")
    assert counts[True] >= 1
    assert counts[False] == 0


def test_mixed_chained_and_flat_conflicting_orders_complete():
    """The acceptance scenario, deterministic form: two two-level chains
    plus a flat all-reduce submitted in pairwise-conflicting orders across
    ranks.  The static single-FIFO-queue baseline deadlocks on the
    logical order set; OCCL completes every chain with correct results
    and nonzero preemption."""
    R, n = 8, 48
    orders = {r: [0, 1, 2] if r % 2 == 0 else [2, 1, 0] for r in range(R)}
    static = run_static_order(orders, {c: list(range(R)) for c in range(3)})
    assert static.deadlocked

    # Both chains use the SAME grid, so their stages CONTEND on the shared
    # derived intra/inter lanes — the conflicting submission orders below
    # force the scheduler to preempt between the two chains' stages.
    rt, world = _runtime(R, max_colls=12, max_comms=3)
    a = rt.register(CollKind.ALL_REDUCE, world, n_elems=n,
                    algo="two_level", hierarchy=(2, 4))
    b = rt.register(CollKind.ALL_REDUCE, world, n_elems=n,
                    algo="two_level", hierarchy=(2, 4))
    flat = rt.register(CollKind.ALL_REDUCE, world, n_elems=n)
    ids = [a, b, flat]
    rng = np.random.RandomState(5)
    xs = {c: [rng.randn(n).astype(np.float32) for _ in range(R)]
          for c in ids}
    for r in range(R):
        for slot in orders[r]:
            rt.submit(r, ids[slot], data=xs[ids[slot]][r])
    rt.drive(max_launches=128)
    for c in ids:
        want = np.sum(xs[c], axis=0)
        for r in range(R):
            np.testing.assert_allclose(rt.read_output(r, c), want,
                                       rtol=1e-4, atol=1e-5)
    assert rt.stats()["preempts"].sum() > 0
