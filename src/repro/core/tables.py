"""Registration-time static tables for the daemon (paper Sec. 3.1.1).

``OCCL registers collectives to be used on each GPU and prepares their meta
information as well as collective context buffer slots before executing
them.``  Registration happens host-side in numpy; the result is a set of
dense arrays indexed by collective id, compiled into the daemon program.
Per-rank tables (primitive programs, membership) carry a leading rank axis
in the sim backend and are sliced per-device in the mesh backend.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .config import OcclConfig
from .primitives import (
    CollKind,
    CollectiveSpec,
    Communicator,
    Prim,
    build_program,
    io_chunked,
    program_len,
)


@dataclasses.dataclass
class StaticTables:
    """Dense static context for up to C collectives over Rk ranks."""

    # per-collective, rank-independent -----------------------------------
    registered: np.ndarray    # [C] bool
    kind: np.ndarray          # [C] int32
    op: np.ndarray            # [C] int32 (ReduceOp)
    lane: np.ndarray          # [C] int32
    n_steps: np.ndarray       # [C] int32 (per-rank program length; same all ranks)
    n_slices: np.ndarray      # [C] int32 (slices per chunk per round)
    n_rounds: np.ndarray      # [C] int32 (primitive-sequence repetitions)
    group_size: np.ndarray    # [C] int32
    in_chunked: np.ndarray    # [C] int32
    out_chunked: np.ndarray   # [C] int32
    base_in_off: np.ndarray   # [C] int32 (default heap offsets)
    base_out_off: np.ndarray  # [C] int32

    # per-(rank, collective) ----------------------------------------------
    member: np.ndarray        # [Rk, C] bool — rank participates
    prog_kind: np.ndarray     # [Rk, C, S] int32 (Prim)
    prog_chunk: np.ndarray    # [Rk, C, S] int32

    # per-lane ring permutations -----------------------------------------
    lane_caps: np.ndarray     # [L] int32 — max slices a lane moves per
                              #   superstep: burst_slices uniformly, unless
                              #   the bandwidth-skew model
                              #   (cfg.bandwidth_groups) classifies the
                              #   lane's rings as island-crossing (inter)
                              #   or island-local (intra) and caps each
                              #   class (clamped to [1, burst_slices])
    fwd_src: np.ndarray       # [L, Rk] int32 — fwd msg arriving at rank r
                              #   was sent by fwd_src[l, r]
    rev_src: np.ndarray       # [L, Rk] int32 — reverse (credit) exchange
    fwd_perm_pairs: list      # [L] list[(src, dst)] for lax.ppermute
    rev_perm_pairs: list
    # Lanes grouped by identical ring permutation: each group's traffic is
    # fused into ONE stacked ppermute pair per direction in the mesh
    # backend (instead of one ppermute per lane per mailbox field).
    lane_groups: list         # [(lanes: list[int], fwd_pairs, rev_pairs)]
    # Per-ring-group lane-pairing metadata for the packed 16-bit exchange:
    # aligned with ``lane_groups``.  Each entry is ``(packed_cols, pad)`` —
    # the group's [G, B*SL] 16-bit payload rows are zero-padded by ``pad``
    # elements (odd lane) and adjacent element PAIRS are bitcast into
    # ``packed_cols`` i32 lanes, so the payload concatenates with the i32
    # (coll, count) header and rides ONE forward ppermute (2 ppermutes per
    # superstep, same as 32-bit dtypes).  ``None`` when the heap dtype is
    # not 16-bit or ``cfg.packed_16bit`` is off (escape hatch): the
    # exchange falls back to separate header/payload ppermutes.
    lane_group_pack16: list | None  # [(packed_cols: int, pad: int)] | None

    # staging layout (runtime I/O; consumed by staging.StagingEngine) -----
    # The padded chunk layout of every collective is resolved ONCE here, so
    # the per-step pack/unpack transform is a precomputed index map instead
    # of per-call Python chunk loops.  Maps are RELATIVE to the
    # collective's base heap offset, so a per-SQE dynamic offset override
    # is a scalar add at flush time.
    chunk_pad: np.ndarray     # [C] i32 — padded chunk extent (rounds*slices*SL)
    chunk_log: np.ndarray     # [C] i32 — logical chunk elems (ceil(n/G))
    in_log: np.ndarray        # [C] i32 — logical input elems per rank
    out_log: np.ndarray       # [C] i32 — logical output elems per rank
    in_span: np.ndarray       # [C] i32 — padded input extent in the heap
    out_span: np.ndarray      # [C] i32 — padded output extent in the heap
    stage_in_map: list        # [C] np.int32[in_log[c]]: logical j -> rel
                              #   off; every in-span offset NOT in the map
                              #   is a pad position the engine zero-fills
    stage_out_map: list       # [C] np.int32[out_log[c]]: logical j -> rel off

    # composite chains (core/algos.py CompositePlan) ----------------------
    # Successor/chain tables: when collective ``c`` completes on a rank and
    # ``next_coll[c] >= 0``, the daemon enqueues the successor SQE on
    # device in the same superstep; only the chain TAIL emits a CQE.
    next_coll: np.ndarray     # [C] i32 — successor collective id (-1 none)
    chain_stage: np.ndarray   # [C] i32 — stage index within the chain
    chain_tail: np.ndarray    # [C] i32 — tail collective of c's chain
                              #   (self for flat collectives)
    chain_prio_inherit: np.ndarray  # [C] bool — device-enqueued successor
                              #   inherits the predecessor's live priority
    chain_mask: np.ndarray    # [C, C] bool — row c marks every stage of
                              #   c's chain (one-hot for flat colls);
                              #   drives chain-wide inflight set/clear
    # Heap relink maps for the chain hand-off: when stage c completes, the
    # successor's ENTIRE padded input span (base offsets; intermediates
    # are never offset-overridden) is rewritten from c's output region —
    # logical elements gathered via the composed stage maps, pad positions
    # zero-filled (-1 source).  [C, M] with M = max successor in-span over
    # chained collectives; M == 0 when the registration has no chains, so
    # the scheduler skips tracing the relink scatter entirely.
    chain_src: np.ndarray     # [C, M] i32 — absolute heap_out offsets, -1=0
    chain_dst: np.ndarray     # [C, M] i32 — absolute heap_in offsets
                              #   (out-of-range sentinel on unused rows)
    # PER-RANK chain maps: composite stages may cover only a subset of the
    # logical members (tree/hybrid inter stages run on the group leaders),
    # so each rank walks its OWN successor chain — the next stage it
    # participates in — and emits its logical CQE at its own last
    # participating stage.  Full-membership chains (two_level/torus)
    # reduce to the global next_coll/chain_tail row-for-row.
    chain_next: np.ndarray    # [Rk, C] i32 — rank's next participating
                              #   stage after c (-1: c is the rank's tail)
    chain_tail_r: np.ndarray  # [Rk, C] i32 — rank's last participating
                              #   stage of c's chain (self for flat colls
                              #   and for non-members)

    max_steps: int

    @property
    def has_chains(self) -> bool:
        return self.chain_src.shape[1] > 0


def _wire_itemsize(dtype: str) -> int:
    """Byte width of the heap/wire dtype (ml_dtypes supplies bfloat16)."""
    import jax.numpy as jnp

    return jnp.dtype(dtype).itemsize


def build_tables(
    cfg: OcclConfig,
    comms: list[Communicator],
    specs: list[CollectiveSpec],
) -> StaticTables:
    Rk, C, L = cfg.n_ranks, cfg.max_colls, cfg.max_comms
    assert len(comms) <= L, "more communicators than daemon lanes"
    assert len(specs) <= C, "more collectives than registered slots"
    for s in specs:
        assert s.coll_id < C
        assert s.comm.lane < L

    S = max(
        [program_len(CollKind(s.kind), s.group_size) for s in specs] or [1]
    )

    t = StaticTables(
        registered=np.zeros(C, bool),
        kind=np.zeros(C, np.int32),
        op=np.zeros(C, np.int32),
        lane=np.zeros(C, np.int32),
        n_steps=np.zeros(C, np.int32),
        n_slices=np.ones(C, np.int32),
        n_rounds=np.ones(C, np.int32),
        group_size=np.ones(C, np.int32),
        in_chunked=np.ones(C, np.int32),
        out_chunked=np.ones(C, np.int32),
        base_in_off=np.zeros(C, np.int32),
        base_out_off=np.zeros(C, np.int32),
        member=np.zeros((Rk, C), bool),
        prog_kind=np.full((Rk, C, S), int(Prim.NULL), np.int32),
        prog_chunk=np.zeros((Rk, C, S), np.int32),
        lane_caps=np.full(L, cfg.burst_slices, np.int32),
        fwd_src=np.tile(np.arange(Rk, dtype=np.int32), (L, 1)),
        rev_src=np.tile(np.arange(Rk, dtype=np.int32), (L, 1)),
        fwd_perm_pairs=[[] for _ in range(L)],
        rev_perm_pairs=[[] for _ in range(L)],
        lane_groups=[],
        lane_group_pack16=None,
        chunk_pad=np.zeros(C, np.int32),
        chunk_log=np.zeros(C, np.int32),
        in_log=np.zeros(C, np.int32),
        out_log=np.zeros(C, np.int32),
        in_span=np.zeros(C, np.int32),
        out_span=np.zeros(C, np.int32),
        stage_in_map=[np.zeros(0, np.int32)] * C,
        stage_out_map=[np.zeros(0, np.int32)] * C,
        next_coll=np.full(C, -1, np.int32),
        chain_stage=np.zeros(C, np.int32),
        chain_tail=np.arange(C, dtype=np.int32),
        chain_prio_inherit=np.zeros(C, bool),
        chain_mask=np.eye(C, dtype=bool),
        chain_src=np.zeros((C, 0), np.int32),
        chain_dst=np.zeros((C, 0), np.int32),
        chain_next=np.full((Rk, C), -1, np.int32),
        chain_tail_r=np.tile(np.arange(C, dtype=np.int32), (Rk, 1)),
        max_steps=S,
    )

    for comm in comms:
        t.lane_caps[comm.lane] = _lane_cap(cfg, comm)
        fwd = comm.fwd_perm(Rk)   # perm[src] = dst
        rev = comm.rev_perm(Rk)
        for src in range(Rk):
            t.fwd_src[comm.lane, fwd[src]] = src
            t.rev_src[comm.lane, rev[src]] = src
        t.fwd_perm_pairs[comm.lane] = [
            (int(s), int(fwd[s])) for s in range(Rk)
        ]
        t.rev_perm_pairs[comm.lane] = [
            (int(s), int(rev[s])) for s in range(Rk)
        ]

    # Group lanes by ring-permutation signature; lanes without a
    # communicator (empty pairs) are excluded — their mailbox slots stay
    # zero, which the receiving scheduler reads as count 0.
    by_perm: dict = {}
    for lane in range(L):
        pairs = t.fwd_perm_pairs[lane]
        if not pairs:
            continue
        by_perm.setdefault(tuple(pairs), []).append(lane)
    t.lane_groups = [
        (lanes, list(sig), t.rev_perm_pairs[lanes[0]])
        for sig, lanes in by_perm.items()
    ]
    # Lane-pairing metadata for the packed 16-bit exchange (consumed by
    # daemon._mesh_exchange): pair adjacent 16-bit payload elements of each
    # fused [G, B*SL] group row into i32 lanes; an odd row width gets one
    # zero pad element that the receiver slices off.
    if cfg.packed_16bit and _wire_itemsize(cfg.dtype) == 2:
        width = cfg.burst_slices * cfg.slice_elems
        pad = width % 2
        t.lane_group_pack16 = [((width + pad) // 2, pad)
                               for _ in t.lane_groups]

    for s in specs:
        c = s.coll_id
        kind = CollKind(s.kind)
        inc, outc = io_chunked(kind)
        t.registered[c] = True
        t.kind[c] = int(kind)
        t.op[c] = int(s.op)
        t.lane[c] = s.comm.lane
        t.n_steps[c] = program_len(kind, s.group_size)
        t.n_slices[c] = s.n_slices
        t.n_rounds[c] = s.n_rounds
        t.group_size[c] = s.group_size
        t.in_chunked[c] = int(inc)
        t.out_chunked[c] = int(outc)
        t.base_in_off[c] = s.in_off
        t.base_out_off[c] = s.out_off
        _build_stage_maps(t, c, s, cfg.slice_elems, inc, outc)
        t.next_coll[c] = s.next_coll
        t.chain_stage[c] = s.chain_stage
        t.chain_prio_inherit[c] = bool(s.inherit_prio)
        for rank in s.comm.members:
            m = s.comm.member_index(rank)
            t.member[rank, c] = True
            prog = build_program(kind, m, s.group_size, s.root)
            assert len(prog) == int(t.n_steps[c]), (
                f"collective {c}: {kind.name} builder emitted "
                f"{len(prog)} steps for member {m}, program_len says "
                f"{int(t.n_steps[c])}")
            for step, (prim, chunk) in enumerate(prog):
                t.prog_kind[rank, c, step] = int(prim)
                t.prog_chunk[rank, c, step] = chunk
    _build_chain_tables(t, specs)
    _build_rank_chain_maps(t, specs)
    return t


def _lane_cap(cfg: OcclConfig, comm) -> int:
    """Per-superstep slice cap of a communicator's lane under the
    bandwidth-skew model: inter (any ring hop crosses an island boundary)
    vs intra class caps, clamped to [1, burst_slices]; the uniform burst
    when the model is off or the class cap is 0.  Mirrored for cost
    prediction by costmodel._lane_cap_for."""
    B = cfg.burst_slices
    if cfg.bandwidth_groups <= 1:
        return B
    isl = cfg.n_ranks // cfg.bandwidth_groups
    inter = any(
        ring[i] // isl != ring[(i + 1) % len(ring)] // isl
        for ring in comm.rings() for i in range(len(ring)))
    cap = cfg.inter_burst_cap if inter else cfg.intra_burst_cap
    return max(1, min(B, cap)) if cap > 0 else B


def _build_chain_tables(t: StaticTables, specs: list) -> None:
    """Resolve chain closure (tail ids, chain membership masks) and the
    heap relink maps of every chain edge.

    The relink map of edge ``c -> succ`` rewrites the successor's whole
    padded input span from c's output region by composing the two
    registration-time stage maps: logical element j of the hand-off lives
    at ``base_out_off[c] + stage_out_map[c][j]`` in ``heap_out`` and must
    land at ``base_in_off[succ] + stage_in_map[succ][j]`` in ``heap_in``;
    every other in-span position is a pad the relink zero-fills (source
    -1), so stale heap data can never leak into the successor's slices.
    Offsets are ABSOLUTE: chain intermediates always run at their
    registered base offsets (per-SQE overrides apply only to the logical
    endpoints — the head's input, the tail's output).
    """
    by_id = {s.coll_id: s for s in specs}
    edges = []
    for s in specs:
        c = s.coll_id
        if s.next_coll < 0:
            continue
        succ = by_id.get(s.next_coll)
        assert succ is not None, (
            f"collective {c}: successor {s.next_coll} is not registered")
        assert int(t.out_log[c]) == int(t.in_log[succ.coll_id]), (
            f"chain edge {c} -> {succ.coll_id}: logical sizes differ "
            f"({int(t.out_log[c])} vs {int(t.in_log[succ.coll_id])})")
        edges.append((c, succ.coll_id))
    # Tail closure + chain membership masks (rows identical for every
    # stage of a chain; one-hot + self-tail for flat collectives).
    for s in specs:
        members = _chain_members(by_id, s.coll_id)
        for a in members:
            t.chain_tail[a] = members[-1]
            for b in members:
                t.chain_mask[a, b] = True
    if not edges:
        return
    M = max(int(t.in_span[succ]) for _, succ in edges)
    t.chain_src = np.full((t.chain_mask.shape[0], M), -1, np.int32)
    # Unused rows point the scatter at an out-of-heap sentinel (dropped by
    # mode='drop'); they are also gated off by the completion mask.
    t.chain_dst = np.full((t.chain_mask.shape[0], M), 1 << 30, np.int32)
    for c, succ in edges:
        span = int(t.in_span[succ])
        src = np.full(span, -1, np.int32)
        n_log = int(t.in_log[succ])
        src[t.stage_in_map[succ]] = (
            t.base_out_off[c] + t.stage_out_map[c][:n_log])
        t.chain_src[c, :span] = src
        t.chain_dst[c, :span] = t.base_in_off[succ] + np.arange(
            span, dtype=np.int32)


def _build_rank_chain_maps(t: StaticTables, specs: list) -> None:
    """Per-rank successor/tail maps for partial-membership chains.

    A stage of a composite plan may cover only a subset of the logical
    members (tree broadcast's leader ring, hybrid's inter all-reduce), so
    the global ``next_coll`` chain is specialized per rank:
    ``chain_next[r, c]`` is the first stage AFTER c (following next_coll)
    that rank r participates in, and ``chain_tail_r[r, c]`` is r's last
    participating stage of c's whole chain — where r's logical CQE fires
    and where its per-SQE out_off override resolves.  For chains whose
    every stage covers every member both maps equal the global
    next_coll / chain_tail rows, and flat collectives keep the defaults
    (-1 / self), so the scheduler's chain-free semantics are unchanged.
    """
    by_id = {s.coll_id: s for s in specs}
    Rk = t.member.shape[0]
    for s in specs:
        c = s.coll_id
        chain = _chain_members(by_id, c)
        if len(chain) == 1:
            continue
        for rank in range(Rk):
            if not t.member[rank, c]:
                continue
            nxt = -1
            for cand in chain[chain.index(c) + 1:]:
                if t.member[rank, cand]:
                    nxt = cand
                    break
            t.chain_next[rank, c] = nxt
            mine = [a for a in chain if t.member[rank, a]]
            t.chain_tail_r[rank, c] = mine[-1]


def _chain_members(by_id: dict, c: int) -> list:
    """All collective ids sharing c's chain (walk to the head, then down)."""
    preds = {s.next_coll: s.coll_id for s in by_id.values()
             if s.next_coll >= 0}
    head, hops = c, 0
    while head in preds:
        head = preds[head]
        hops += 1
        assert hops <= len(by_id), "cycle in collective chain"
    members = [head]
    while by_id[members[-1]].next_coll >= 0:
        members.append(by_id[members[-1]].next_coll)
        assert len(members) <= len(by_id), "cycle in collective chain"
    return members


def _build_stage_maps(t: StaticTables, c: int, s: CollectiveSpec,
                      slice_elems: int, inc: bool, outc: bool) -> None:
    """Precompute the padded-layout scatter/gather index maps of one
    collective (the registration-time analogue of NCCL's registered user
    buffers): logical element ``j`` of a chunked buffer lives at relative
    heap offset ``(j // chunk_log) * chunk_pad + j % chunk_log``; every
    offset of the padded span NOT covered by the map is a pad position
    the staging engine zero-fills on write (so stale heap data can never
    leak into the padded slices the daemon circulates).

    Two CollectiveSpec refinements generalize the maps for the a2a
    family without touching the staging engine (maps carry ALL layout
    logic downstream):

    * ``chunk_sizes`` (ALL_TO_ALL_RAGGED) — per-distance live element
      counts.  Chunk q keeps its full padded capacity on the heap/wire
      (the daemon's slicing is static), but only its first
      ``chunk_sizes[q]`` positions are mapped: the capacity-dropped rest
      are pads the engine zero-fills on write and never reads back, so
      both logical sizes become ``sum(chunk_sizes)``.
    * ``in_perm`` — a logical-input permutation composed into the INPUT
      map only: caller-logical element j stages to the heap position of
      stage-local element ``in_perm[j]``.  Composite a2a plans use it to
      fold the inter-stage granule transpose into the existing chain
      relink (which composes stage_out_map[pred] with stage_in_map[succ]
      over logical j, so a permuted successor input IS the transpose).
    """
    cp = s.n_rounds * s.n_slices * slice_elems        # padded chunk extent
    cl = -(-s.n_elems // s.group_size)                # ceil: logical chunk
    in_log = s.n_elems if inc else cl
    out_log = s.n_elems if outc else cl
    in_span = (s.group_size if inc else 1) * cp
    out_span = (s.group_size if outc else 1) * cp

    def chunked_map(n_logical: int) -> np.ndarray:
        j = np.arange(n_logical, dtype=np.int32)
        return (j // cl) * cp + (j % cl)

    if s.chunk_sizes:
        assert inc and outc, "ragged sizes require a both-sides-chunked kind"
        sizes = np.asarray(s.chunk_sizes, np.int64)
        assert len(sizes) == s.group_size and (sizes >= 0).all() and (
            sizes <= cl).all(), (
            f"collective {c}: chunk_sizes must be {s.group_size} counts "
            f"in [0, {cl}], got {s.chunk_sizes}")
        ragged = np.concatenate([
            q * cp + np.arange(sizes[q], dtype=np.int32)
            for q in range(s.group_size)]).astype(np.int32)
        in_log = out_log = int(sizes.sum())
        in_map = out_map = ragged
    else:
        in_map = (chunked_map(in_log) if inc
                  else np.arange(in_log, dtype=np.int32))
        out_map = (chunked_map(out_log) if outc
                   else np.arange(out_log, dtype=np.int32))

    if s.in_perm:
        perm = np.asarray(s.in_perm, np.int64)
        assert perm.shape == (in_log,) and np.array_equal(
            np.sort(perm), np.arange(in_log)), (
            f"collective {c}: in_perm must be a permutation of "
            f"range({in_log})")
        in_map = in_map[perm]

    t.chunk_pad[c] = cp
    t.chunk_log[c] = cl
    t.in_log[c] = in_log
    t.out_log[c] = out_log
    t.in_span[c] = in_span
    t.out_span[c] = out_span
    t.stage_in_map[c] = in_map.astype(np.int32)
    t.stage_out_map[c] = out_map.astype(np.int32)
