"""vit-base — the paper's own benchmark model (Sec. 5.3.2): patch 16,
embed dim 768, 16 heads.  Encoder-only classifier used by the training
throughput benchmarks (Fig. 8/10 reproduction); not part of the assigned
10-arch dry-run grid (no decode shapes)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="vit-base", family="vit",
    n_layers=12, d_model=768, n_heads=16, n_kv_heads=16, d_head=48,
    d_ff=3072, vocab=1000,          # vocab = classifier classes
    vis_tokens=196,
    skip_cells=(
        ("prefill_32k", "encoder-only classifier: no serving shapes"),
        ("decode_32k", "encoder-only: no decode step"),
        ("long_500k", "encoder-only: no decode step"),
    ),
)
