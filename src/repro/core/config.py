"""Configuration for the OCCL deadlock-free collective runtime.

All sizes are static (compiled into the daemon program), mirroring the
paper's registration-time preparation of collective contexts (Sec. 3.1.1).

Launch-epoch clock invariants
-----------------------------
The daemon keeps TWO superstep clocks (state.py): a cumulative ``supersteps``
epoch counter that is never reset (observability / Fig. 9 stats) and a
per-launch ``launch_steps`` counter that the daemon prologue zeroes on every
(re)launch.  ``superstep_budget`` bounds ``launch_steps`` — it is a
*per-launch* bound, so the voluntary-quit/relaunch cycle (paper Sec. 3.1.3)
can repeat indefinitely without the budget ever going stale.

Task-queue order keys are built from the same launch clock: the scheduler
rebases every active collective's ``arrival`` to its queue rank (< max_colls)
in the launch prologue, and new fetches/rotations stamp
``max_colls + launch_steps``.  Queue age is therefore bounded by
``max_colls + superstep_budget + 2`` per launch, which MUST stay below
``QUEUE_KEY_DEMAND_STRIDE`` so the demand-steering bonus and the PRIORITY
class stride can never bleed into each other (validated in
``OcclConfig.__post_init__``).
"""
from __future__ import annotations

import dataclasses
import enum


# Queue-key class strides (scheduler._lane_keys).  Within one priority
# class the key is ``arrival - demand * QUEUE_KEY_DEMAND_STRIDE``; PRIORITY
# prepends ``-prio * QUEUE_KEY_PRIO_STRIDE``.  Keys are i32: with prio
# clipped to +/-512 (2^9) the extreme key magnitude is ~2^29 — no overflow —
# provided arrival stays below the demand stride (config validation below).
QUEUE_KEY_DEMAND_STRIDE = 1 << 18
QUEUE_KEY_PRIO_STRIDE = 1 << 20


class OrderPolicy(enum.IntEnum):
    """Order-adjusting policy of the stickiness scheme (paper Sec. 3.2)."""

    FIFO = 0      # empty the task queue ASAP; lazy SQ fetch; new at back
    PRIORITY = 1  # user priority first; eager SQ fetch; high-prio at front


class ReduceOp(enum.IntEnum):
    SUM = 0
    MAX = 1
    MIN = 2
    PROD = 3


@dataclasses.dataclass(frozen=True)
class OcclConfig:
    """Static configuration of one daemon instance.

    The daemon is compiled once per config (the analogue of launching the
    persistent daemon kernel with the max grid/block size, paper Sec. 4).
    """

    # --- geometry -------------------------------------------------------
    n_ranks: int = 8                # devices participating in the fabric
    max_colls: int = 16             # registered-collective slots (C)
    max_comms: int = 4              # communicator lanes (L); CUDA-block analogue
    slice_elems: int = 64           # elements per slice (preemption granule)
    conn_depth: int = 4             # ring-buffer slots per connector (K)
    burst_slices: int = 1           # max slices one lane moves per superstep
                                    # (B); the burst is credit-gated so the
                                    # deadlock-freedom capacity argument of
                                    # derive_slicing is unchanged, and a
                                    # collective stays preemptible between
                                    # bursts (slice granularity).  For
                                    # sustained B-slice throughput size
                                    # conn_depth >= ~3B (credit round trip;
                                    # see scheduler.py docstring)
    auto_conn_depth: bool = False   # derive conn_depth =
                                    # max(conn_depth, 3 * burst_slices) at
                                    # construction so bursts never fall into
                                    # the 1-slice/superstep credit-return
                                    # equilibrium.  Off by default: resizing
                                    # the connector changes derive_slicing
                                    # (rounds/slices), so it must be an
                                    # explicit choice; when off, the runtime
                                    # warns at registration time instead.
    heap_elems: int = 1 << 16       # per-rank data heap (send/recv buffers)

    # --- SQ / CQ --------------------------------------------------------
    sq_len: int = 64                # submission-queue slots per rank
    cq_len: int = 64                # completion-queue slots per rank

    # --- scheduling / stickiness (paper Sec. 3.2) -----------------------
    order_policy: OrderPolicy = OrderPolicy.FIFO
    stickiness: bool = True         # master switch (Fig. 9 ablation)
    priority_preempts: bool = False  # P3/PACE-style: a strictly-higher-
                                    # priority queued collective preempts the
                                    # current one (paper Sec. 3.2 / Sec. 6:
                                    # a spin-threshold adjusting policy)
    demand_steering: bool = True    # beyond-paper gang policy: prefer
                                    # collectives whose recv connector has
                                    # data waiting (local evidence that ring
                                    # peers are executing them) — same
                                    # decentralized-information constraint
                                    # as the paper's spin-threshold scheme
                                    # but converges faster under adversarial
                                    # order skew (benchmarks/bench_gang.py)
    # Spin thresholds/counts are in units of STALLED SLICES, not stalled
    # supersteps: a lane denied its whole burst advances ``spin`` by up to
    # ``burst_slices`` per superstep (scheduler.lanes_step), so at B > 1 a
    # stalled collective yields its lane in proportionally fewer wall
    # supersteps and the freed supersteps go to collectives with queued
    # demand.  At B = 1 a stalled superstep denies exactly one slice, so
    # the accounting is bit-identical to the seed superstep-counting spin.
    queue_conditional_stall: bool = True  # weight stall units by lane queue
                                    # length: a lane with NO other eligible
                                    # collective queued (solo) advances spin
                                    # by 1 per stalled superstep (preempting
                                    # it frees nothing, so B×-eager rotation
                                    # during the credit round trip is pure
                                    # churn), while contended lanes keep the
                                    # fast B-scaled denied-slice accounting.
                                    # False restores unconditional B-scaling
                                    # (the PR-2 behavior; ablation switch).
                                    # At B = 1 both settings are identical.
    spin_base: int = 16             # initial threshold of queue-front coll
    spin_decr: int = 4              # threshold decrement per queue position
    spin_boost: int = 8             # boost to successors on primitive success
    spin_min: int = 1
    spin_max: int = 256
    # Priority aging (QoS starvation bound, serving/qos.py): under
    # OrderPolicy.PRIORITY a queued collective's EFFECTIVE priority is
    # ``prio + min(queue_age // prio_aging_quantum, prio_aging_cap)``,
    # used for BOTH the queue-order key and the priority_preempts
    # comparison and clipped to the same +/-512 band as user priority —
    # the queue-key magnitude proof above is unchanged.  Queue age is
    # measured on the per-launch clock (``max_colls + launch_steps -
    # arrival``), so rebase_arrivals resets it at every relaunch: a bump
    # never outlives the launch that earned it.  0 disables aging and is
    # bit-identical to the pre-knob scheduler.
    prio_aging_quantum: int = 0     # queue-age supersteps per +1 eff. prio
    prio_aging_cap: int = 127       # max aging bump; conservative default
                                    # stays UNDER one serving class stride
                                    # (128) — aged work reorders within its
                                    # class only.  serving/qos.py passes 255
                                    # to allow exactly one class crossing.

    # --- daemon lifecycle (paper Sec. 3.1.3) ----------------------------
    quit_threshold: int = 64        # voluntary quit after this many
                                    # no-progress supersteps
    superstep_budget: int = 4096    # hard bound on launch_steps PER daemon
                                    # launch (reset in the launch prologue;
                                    # the cumulative epoch clock is separate
                                    # and unbounded)

    # --- collective algorithms (composite layer, core/algos.py) ---------
    algo: str = "ring"              # default algorithm for register():
                                    # "ring" (flat single-communicator);
                                    # the composite plans "two_level",
                                    # "torus", "hybrid" (ALL_REDUCE) and
                                    # "tree" (BROADCAST/REDUCE) over a
                                    # G x N rank grid; or "auto" — rank the
                                    # registered candidate plans with the
                                    # measured α-β-γ cost model
                                    # (core/costmodel.py, calibrated by
                                    # benchmarks/calibrate.py into
                                    # BENCH_calibration.json).
                                    # register(algo=...) overrides per
                                    # collective.

    # --- lane bandwidth skew (sim backend physical model) ---------------
    # Model a hierarchical fabric: the n_ranks are split into
    # ``bandwidth_groups`` equal islands of consecutive ranks (NVLink
    # boxes / hosts); a lane whose ring permutation has ANY hop crossing
    # an island boundary is an INTER lane, the rest are INTRA lanes.  A
    # lane moves at most its class cap slices per superstep (0 = the full
    # burst_slices; caps clamp to [1, burst_slices]).  bandwidth_groups=0
    # disables the model — every lane keeps the uniform burst, and the
    # scheduler math is value-identical to the unskewed path.  This is
    # what lets the sim backend measure WALL-CLOCK algorithm crossovers
    # (flat rings cross islands every ~N hops; hierarchical plans confine
    # the bulk to intra lanes), feeding the algos bench section and the
    # cost-model calibration.
    bandwidth_groups: int = 0
    intra_burst_cap: int = 0        # islands-local lanes (0 = burst_slices)
    inter_burst_cap: int = 0        # island-crossing lanes (0 = burst_slices)

    # --- flight recorder (fleet observability, core/recorder.py) --------
    flight_recorder: bool = True    # record per-collective scheduling
                                    # events (SUBMIT fetch, STAGE_DONE,
                                    # PREEMPT, CHAIN_HANDOFF, CQE) into a
                                    # per-rank on-device ring buffer
                                    # stamped with the epoch clock.
                                    # Exported by ``stats()
                                    # ["flight_recorder"]`` and attached
                                    # to DeadlockTimeout; False removes
                                    # every recorder op from the compiled
                                    # superstep (bit-identical schedule).
    recorder_len: int = 128         # ring-buffer slots per rank; the
                                    # per-kind cumulative counters are
                                    # wrap-proof, only the event ring
                                    # itself keeps the newest
                                    # ``recorder_len`` events.  A single
                                    # superstep can emit up to
                                    # 4*max_comms + 1 events (4 transition
                                    # kinds per lane + 1 SQE fetch);
                                    # smaller rings stay deterministic
                                    # (the scheduler pre-drops the oldest
                                    # events of an over-long batch), but
                                    # recorder_len >= 4*max_comms + 1
                                    # guarantees the decoded ring is a
                                    # gap-free suffix of the event stream

    # --- numerics / kernels ---------------------------------------------
    dtype: str = "float32"          # heap / wire dtype
    use_pallas: bool = False        # route slice math through Pallas kernels

    # --- mesh-backend fast path -----------------------------------------
    packed_16bit: bool = True       # mesh backend: bitcast PAIRS of 16-bit
                                    # payload elements into i32 lanes so
                                    # bf16/f16 heaps ride the same single
                                    # fused header++payload forward ppermute
                                    # as 32-bit dtypes (2 ppermutes per
                                    # superstep instead of 3; an odd lane is
                                    # zero-padded and sliced off on receive).
                                    # False restores the separate
                                    # header/payload ppermute pair (escape
                                    # hatch; bit-identical results).
    cond_chain_relink: bool = True  # mesh backend: wrap the chain-relink
                                    # gather/scatter in a lax.cond on "any
                                    # chained stage completed this
                                    # superstep", so workloads that
                                    # registered chains but complete none
                                    # in a given superstep skip the relink
                                    # memory traffic (it fires on the rare
                                    # completion supersteps only).  Sim
                                    # backend ignores it: under vmap a
                                    # lax.cond degenerates to a select and
                                    # both branches execute anyway.  False
                                    # restores the unconditional scatter
                                    # (escape hatch; bit-identical results).
    vectorized_inbox: bool = True   # apply_inbox: flatten the (coll, slot)
                                    # scatter grid through a precomputed
                                    # [L, B] burst-offset table into ONE
                                    # single-axis scatter over the
                                    # [C*K, SLICE] payload view.  False
                                    # restores the two-axis scatter (escape
                                    # hatch; bit-identical results).

    def __post_init__(self):
        assert self.n_ranks >= 1
        assert self.max_comms >= 1
        assert self.conn_depth >= 1
        assert self.slice_elems >= 1
        assert self.burst_slices >= 1
        assert self.spin_base >= self.spin_min
        assert self.algo in ("ring", "two_level", "torus", "hybrid",
                             "tree", "auto"), self.algo
        assert self.recorder_len >= 1
        assert self.prio_aging_quantum >= 0
        assert 0 <= self.prio_aging_cap <= 511, (
            "prio_aging_cap must stay within the +/-512 priority clip "
            "band (queue-key magnitude proof)")
        assert self.bandwidth_groups >= 0
        assert self.intra_burst_cap >= 0 and self.inter_burst_cap >= 0
        if self.bandwidth_groups > 1:
            assert self.n_ranks % self.bandwidth_groups == 0, (
                f"bandwidth_groups={self.bandwidth_groups} must divide "
                f"n_ranks={self.n_ranks} (equal islands)")
        if self.auto_conn_depth and self.conn_depth < 3 * self.burst_slices:
            # Credit round trip (commit, consume, credit-return) is ~3
            # supersteps; K >= 3B keeps the ring from saturating.
            object.__setattr__(self, "conn_depth", 3 * self.burst_slices)
        # Queue-key class separation (see module docstring): the largest
        # per-launch arrival value must stay below the demand stride.
        assert (self.superstep_budget + self.max_colls + 2
                < QUEUE_KEY_DEMAND_STRIDE), (
            "superstep_budget too large for i32 queue keys: need "
            f"superstep_budget + max_colls + 2 < {QUEUE_KEY_DEMAND_STRIDE} "
            "(split work across launches — the budget is per launch)")
