"""Flight recorder: on-device event ring + host-side hang diagnosis.

The scheduler appends one event per scheduling transition into a per-rank
fixed-size ring buffer living in :class:`~repro.core.state.DaemonState`
(``fr_*`` fields), stamped with the cumulative epoch clock — the
observability substrate arxiv 2510.00991 describes for fleet-scale
collective libraries.  Event schema (all i32):

====================  ============================================
kind                  meaning (``coll`` column / clock stamp)
====================  ============================================
``SUBMIT`` (0)        an SQE entered the task queue (entry stage id)
``STAGE_DONE`` (1)    a ring stage ran its last primitive (stage id)
``PREEMPT`` (2)       the lane rotated away from a spinning
                      collective (preempted stage id)
``CHAIN_HANDOFF`` (3) a completing stage enqueued its chain
                      successor on device (predecessor stage id)
``CQE`` (4)           a chain tail completed — host-visible CQE
                      (tail stage id)
====================  ============================================

Alongside the ring the state keeps wrap-proof per-kind cumulative
counters (``fr_kinds``), which reconcile exactly with the scheduler's
own counters: ``STAGE_DONE == stage_completions.sum == rtc_events.sum``,
``CQE == completed.sum``, ``STAGE_DONE == CHAIN_HANDOFF + CQE`` and
``PREEMPT == preempts.sum`` per rank.  Stall pressure is deliberately
NOT an event (it would flood the ring every superstep) — the
``stall_slices`` counter remains that signal.

:func:`diagnose` is the host side: on a hang it names the rank +
collective holding each stalled chain, first from host submission
bookkeeping (a member that never submitted — the common lost-rank case),
falling back to the recorder clock (the member whose chain events are
oldest).
"""
from __future__ import annotations

import dataclasses

import numpy as np

# Event kinds (i32 values in ``fr_kind``).
EV_SUBMIT = 0
EV_STAGE_DONE = 1
EV_PREEMPT = 2
EV_CHAIN_HANDOFF = 3
EV_CQE = 4
N_EVENT_KINDS = 5
EVENT_NAMES = ("SUBMIT", "STAGE_DONE", "PREEMPT", "CHAIN_HANDOFF", "CQE")


@dataclasses.dataclass
class FlightEvent:
    """One decoded recorder entry (host-side view)."""

    rank: int
    kind: int
    coll: int
    step: int  # epoch-clock superstep stamp

    @property
    def kind_name(self) -> str:
        return EVENT_NAMES[self.kind] if 0 <= self.kind < N_EVENT_KINDS \
            else f"?{self.kind}"

    def __str__(self):
        return (f"[rank {self.rank} @ step {self.step}] "
                f"{self.kind_name} coll={self.coll}")


def export_record(state, cfg) -> dict:
    """Pull the recorder arrays off the device into a plain-numpy export.

    This is the payload ``stats()["flight_recorder"]`` returns and
    :class:`~repro.core.errors.DeadlockTimeout` carries.
    """
    return {
        "enabled": bool(cfg.flight_recorder),
        "recorder_len": int(cfg.recorder_len),
        "kind": np.asarray(state.fr_kind),      # [R, FR] i32 (-1 = empty)
        "coll": np.asarray(state.fr_coll),      # [R, FR] i32
        "step": np.asarray(state.fr_step),      # [R, FR] i32 epoch stamp
        "count": np.asarray(state.fr_count),    # [R] total events appended
        "kind_counts": np.asarray(state.fr_kinds),  # [R, N_EVENT_KINDS]
    }


def events(record: dict, rank: int | None = None) -> list[FlightEvent]:
    """Decode a record's ring into events, oldest -> newest per rank."""
    out: list[FlightEvent] = []
    fr = int(record["recorder_len"])
    ranks = range(record["kind"].shape[0]) if rank is None else (rank,)
    for r in ranks:
        n = int(record["count"][r])
        kept = min(n, fr)
        start = n - kept  # absolute index of oldest retained event
        for i in range(start, n):
            s = i % fr
            out.append(FlightEvent(rank=int(r),
                                   kind=int(record["kind"][r, s]),
                                   coll=int(record["coll"][r, s]),
                                   step=int(record["step"][r, s])))
    return out


# ----------------------------------------------------------------------
# Host-side hang diagnosis
# ----------------------------------------------------------------------
@dataclasses.dataclass
class StalledChain:
    """One logical collective that cannot complete, and who holds it."""

    coll_id: int          # logical (head) collective id
    algo: str
    members: tuple        # participating ranks
    waiting_ranks: list   # ranks with an outstanding submission
    holding_ranks: list   # ranks diagnosed as holding the chain
    reason: str

    def __str__(self):
        hold = ",".join(map(str, self.holding_ranks)) or "?"
        wait = ",".join(map(str, self.waiting_ranks))
        return (f"collective {self.coll_id} ({self.algo}) held by "
                f"rank(s) {hold}: {self.reason} "
                f"(waiting ranks: {wait})")


@dataclasses.dataclass
class Diagnosis:
    stalled: list

    @property
    def holders(self) -> list:
        """All ranks named as holding at least one stalled chain."""
        out: list[int] = []
        for s in self.stalled:
            for r in s.holding_ranks:
                if r not in out:
                    out.append(r)
        return sorted(out)

    def __str__(self):
        if not self.stalled:
            return "no stalled collectives (all submissions reconciled)"
        return "\n".join(str(s) for s in self.stalled)


def diagnose(runtime) -> Diagnosis:
    """Name the rank + collective holding each stalled chain.

    Two signals, in order of strength:

    1. Host submission bookkeeping: a member whose cumulative submit
       count for the collective lags the most-submitted member never
       handed the daemon its SQE — the lost-rank / withheld-submission
       case.  This is decisive because OCCL's preemption machinery makes
       *scheduling* deadlocks impossible; only a missing participant can
       wedge a chain.
    2. The flight recorder: if every member submitted equally, the
       member whose latest event touching the chain's stages is OLDEST
       on the epoch clock made the least recent progress.
    """
    stalled: list[StalledChain] = []
    by_coll: dict[int, list[int]] = {}
    for (r, cid), dq in runtime._outstanding.items():
        if dq:
            by_coll.setdefault(cid, []).append(r)
    record = runtime.export_flight_record()
    for cid in sorted(by_coll):
        waiting = sorted(by_coll[cid])
        members = tuple(runtime._logical_members.get(
            cid, runtime.specs[cid].comm.members))
        algo = runtime._algo_of.get(cid, "ring")
        counts = {m: runtime._submit_counts.get((m, cid), 0)
                  for m in members}
        mx = max(counts.values()) if counts else 0
        holders = [m for m in members if counts[m] < mx]
        if holders:
            reason = (f"never submitted (peers at {mx} submission"
                      f"{'s' if mx != 1 else ''})")
        else:
            # Everyone submitted: fall back to recorder recency over the
            # chain's stage ids.
            stages = set(runtime._chain_of.get(cid, [cid]))
            last: dict[int, int] = {}
            for ev in events(record):
                if ev.rank in counts and ev.coll in stages:
                    last[ev.rank] = max(last.get(ev.rank, -1), ev.step)
            oldest = min((last.get(m, -1) for m in members), default=-1)
            holders = [m for m in members if last.get(m, -1) == oldest]
            reason = (f"slowest chain progress (last recorded event at "
                      f"superstep {oldest})")
        stalled.append(StalledChain(coll_id=int(cid), algo=str(algo),
                                    members=members,
                                    waiting_ranks=waiting,
                                    holding_ranks=holders,
                                    reason=reason))
    return Diagnosis(stalled=stalled)
