"""Fit the α-β-γ cost model from the measured algorithm sweep.

Reads the ``algos`` section run_algo_sweep wrote into
BENCH_collectives.json (every sample pairs a plan's structural features
with its measured wall-clock), fits the non-negative least squares of
:func:`repro.core.costmodel.fit`, persists the per-backend coefficients
to BENCH_calibration.json (what ``select_algo("auto")`` loads at
registration time), and appends the fitted model's auto-selection picks
for the sweep's own small/large configurations under ``algos.auto`` —
so benchmarks/check_gates.py can assert "auto picks the measured
winner" from the JSON record alone, without importing repro.

BENCH_calibration.json is written ATOMICALLY (CostModel.save stages a
tmp file and os.replace()s it into place) and then re-read and validated
here: a truncated or key-incomplete calibration artifact fails the run
loudly instead of silently degrading every future ``algo="auto"``
registration to the default model.

Usage: ``python benchmarks/calibrate.py`` (after ``run_algo_sweep``;
``benchmarks/run.py`` chains both).
"""
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from common import row  # noqa: E402
from bench_collectives import (BENCH_JSON, _read_record,  # noqa: E402
                               _write_record)

KIND_OF = {"all_reduce": "ALL_REDUCE", "broadcast": "BROADCAST"}

# Every fit sample must carry these; a sweep record missing any of them
# is a partial/stale measurement and must not be fitted from.
SAMPLE_KEYS = ("latency_s", "features")
FEATURE_KEYS = ("supersteps", "bytes", "stages")
# Required shape of the persisted calibration artifact, per backend.
CALIBRATION_KEYS = ("alpha", "beta", "gamma")


def collect_samples(algos_record: dict) -> list[dict]:
    """Flatten the sweep into fit() samples: one (features, wall) pair
    per (kind, size, algorithm) measurement.  Fails LOUDLY on records
    missing required keys — a partial sweep silently dropping samples
    would skew the fit without anyone noticing."""
    samples, problems = [], []
    for label, sizes in algos_record["sweep"].items():
        for size_label, entry in sizes.items():
            for algo, rec in entry.items():
                if not isinstance(rec, dict):
                    continue                   # scalar metadata (n_elems)
                tag = f"{label}/{size_label}/{algo}"
                missing = [k for k in SAMPLE_KEYS if k not in rec]
                missing += [f"features.{k}" for k in FEATURE_KEYS
                            if k not in rec.get("features", {})]
                if missing:
                    problems.append(f"{tag} lacks {missing}")
                    continue
                samples.append({
                    **rec["features"],
                    "wall": rec["latency_s"],
                    "tag": tag,
                })
    if problems:
        raise RuntimeError(
            "algos sweep record is partial — rerun run_algo_sweep "
            "(python benchmarks/run.py): " + "; ".join(problems))
    return samples


def validate_calibration(path) -> dict:
    """Re-read the just-written BENCH_calibration.json and verify every
    backend entry carries finite, non-negative (alpha, beta, gamma)."""
    try:
        with open(path) as f:
            rec = json.load(f)
    except (OSError, ValueError) as e:
        raise RuntimeError(
            f"{path} unreadable after save ({e}) — calibration write "
            "failed") from None
    problems = []
    backends = rec.get("backends")
    if not backends:
        problems.append("missing 'backends'")
    for backend, fit_rec in (backends or {}).items():
        for key in CALIBRATION_KEYS:
            v = fit_rec.get(key)
            if not isinstance(v, (int, float)) or v < 0:
                problems.append(f"backends.{backend}.{key} = {v!r} "
                                "(want a non-negative number)")
    if problems:
        raise RuntimeError(
            f"{path} failed validation: " + "; ".join(problems))
    return rec


def auto_picks(record: dict, model) -> dict:
    """The fitted model's selection for each swept (kind, size) — the
    exact configs the sweep measured, so the gate can compare pick vs
    measured winner without re-deriving features."""
    from repro.core import CollKind, OcclConfig, select_algo

    cfg_rec = record["config"]
    cfg = OcclConfig(
        n_ranks=cfg_rec["n_ranks"], max_colls=8, max_comms=3,
        slice_elems=cfg_rec["slice_elems"],
        conn_depth=cfg_rec["conn_depth"],
        burst_slices=cfg_rec["burst_slices"],
        heap_elems=1 << 18, superstep_budget=1 << 15,
        bandwidth_groups=cfg_rec["bandwidth_groups"],
        inter_burst_cap=cfg_rec["inter_burst_cap"])
    hierarchy = tuple(cfg_rec["hierarchy"])
    picks: dict = {}
    for label, sizes in record["sweep"].items():
        kind = CollKind[KIND_OF[label]]
        picks[label] = {}
        for size_label, entry in sizes.items():
            pick = select_algo("auto", kind, entry["n_elems"],
                               cfg_rec["n_ranks"], hierarchy=hierarchy,
                               cfg=cfg, model=model)
            walls = {a: r["latency_s"] for a, r in entry.items()
                     if isinstance(r, dict) and "latency_s" in r}
            picks[label][size_label] = {
                "pick": pick,
                "pick_wall_s": walls.get(pick),
                "best_algo": min(walls, key=walls.get),
                "best_wall_s": min(walls.values()),
            }
    return picks


def alltoall_auto_pick(alltoall_record: dict, model) -> dict:
    """The fitted model's flat-vs-two-level pick for the all-to-all
    bench configuration, next to the measured walls — the alltoall gate
    of check_gates.py compares pick vs measured winner."""
    from repro.core import CollKind, OcclConfig, select_algo

    cfg_rec = alltoall_record["config"]
    cfg = OcclConfig(
        n_ranks=cfg_rec["n_ranks"], max_colls=8, max_comms=3,
        slice_elems=cfg_rec["slice_elems"],
        conn_depth=cfg_rec["conn_depth"],
        burst_slices=cfg_rec["burst_slices"],
        heap_elems=1 << 18, superstep_budget=1 << 15,
        bandwidth_groups=cfg_rec["bandwidth_groups"],
        inter_burst_cap=cfg_rec["inter_burst_cap"])
    pick = select_algo("auto", CollKind.ALL_TO_ALL, cfg_rec["n_elems"],
                       cfg_rec["n_ranks"],
                       hierarchy=tuple(cfg_rec["hierarchy"]),
                       cfg=cfg, model=model)
    walls = {"ring": alltoall_record["flat"]["latency_s"],
             "two_level": alltoall_record["two_level"]["latency_s"]}
    return {
        "pick": pick,
        "pick_wall_s": walls.get(pick),
        "best_algo": min(walls, key=walls.get),
        "best_wall_s": min(walls.values()),
    }


def main(out_path=BENCH_JSON) -> dict:
    from repro.core import costmodel

    doc = _read_record(out_path)
    if "algos" not in doc or "sweep" not in doc.get("algos", {}):
        raise RuntimeError(
            f"{out_path} has no algos sweep — run "
            "benchmarks/bench_collectives.py run_algo_sweep first "
            "(python benchmarks/run.py does)")
    record = doc["algos"]
    samples = collect_samples(record)
    model = costmodel.fit(samples)
    path = model.save(backend="sim", extra={
        "n_samples": len(samples),
        "source_record": str(out_path.name),
    })
    validate_calibration(path)
    row("collectives/calibration_alpha", model.alpha * 1e6, "us/superstep")
    row("collectives/calibration_beta", model.beta * 1e9, "ns/byte")
    row("collectives/calibration_gamma", model.gamma * 1e6, "us/stage")
    picks = auto_picks(record, model)
    doc = _read_record(out_path)            # re-read: atomic append
    doc.setdefault("algos", {})["auto"] = {
        "model": {"alpha": model.alpha, "beta": model.beta,
                  "gamma": model.gamma, "source": model.source},
        "picks": picks,
    }
    if "alltoall" in doc:
        doc["alltoall"]["auto"] = alltoall_auto_pick(doc["alltoall"],
                                                     model)
        print(f"#   auto[alltoall] -> {doc['alltoall']['auto']['pick']} "
              f"(measured best: {doc['alltoall']['auto']['best_algo']})")
    else:
        print("#   (no alltoall section yet — run_alltoall_bench "
              "appends it; validate_record requires it for a full run)")
    _write_record(out_path, doc)
    print(f"# wrote {path} (calibration) + {out_path} (algos.auto)")
    for label, sizes in picks.items():
        for size_label, p in sizes.items():
            print(f"#   auto[{label}/{size_label}] -> {p['pick']} "
                  f"(measured best: {p['best_algo']})")
    return {"model": model, "picks": picks}


if __name__ == "__main__":
    main()
