"""Batched serving engine: continuous batching over recycled slots.

CPU/testbed-scale engine with the production control flow: requests are
admitted into batch slots as they free up (continuous batching — a long
request no longer stalls the whole batch behind a cohort drain), decoded
step-locked as a batch, and retired individually.  The decode step is
the same jitted ``serve_step`` the dry-run lowers at 32k/500k scale.

Admission re-prefills the whole active set (prefix replay): the KV cache
is batch-global ([B] rows sharing one position counter), so recycling a
slot means replaying every live slot's prompt + generated suffix into a
fresh cache.  That is the standard testbed continuous-batching shape
short of paged attention, and it bounds cache pressure — every admission
resets the decode position.

With a :class:`~repro.serving.qos.ServingQos` fabric attached, every
prefill issues the prompt all-gather and every decode step issues the
tensor-parallel all-reduce as staged OCCL submits on the shared fabric —
decode collectives preempt in-flight background bursts mid-superstep
(see qos.py), and the engine's stats gain the per-step collective
latency digest.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, ShapeCell
from ..models import build_model, input_specs, make_concrete


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # [S] int32
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ArchConfig, *, batch_size: int = 4,
                 prompt_len: int = 32, max_len: int = 96, seed: int = 0,
                 qos=None):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = self.model.init(seed)
        self.B, self.S, self.max_len = batch_size, prompt_len, max_len
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, pad_to=self.max_len))
        self._decode = jax.jit(self.model.decode_step)
        self.queue: collections.deque[Request] = collections.deque()
        self.qos = qos
        self.stats = {"prefills": 0, "decode_steps": 0, "tokens": 0,
                      "admissions": 0}

    def submit(self, req: Request):
        self.queue.append(req)

    def _batchify(self, slots: list) -> dict:
        """Replay each live slot's prompt + generated suffix (newest
        ``S`` tokens) into the fixed [B, S] prefill shape; free slots
        stay zero rows."""
        toks = np.zeros((self.B, self.S), np.int32)
        for i, r in enumerate(slots):
            if r is None:
                continue
            seq = np.asarray(r.prompt, np.int32)
            if r.out_tokens:
                seq = np.concatenate(
                    [seq, np.asarray(r.out_tokens, np.int32)])
            seq = seq[-self.S:]
            toks[i, :len(seq)] = seq
        batch = {"tokens": jnp.asarray(toks)}
        cfg = self.cfg
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (self.B, cfg.vis_tokens, cfg.d_model), cfg.compute_dtype)
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (self.B, cfg.enc_frames, cfg.d_model), cfg.compute_dtype)
        return batch

    def run(self) -> list[Request]:
        """Drain the queue with continuous batching; returns completed
        requests in completion order.

        Invariant (asserted in tests): ``stats["tokens"]`` equals
        ``sum(len(r.out_tokens))`` over every request ever admitted —
        each token is counted exactly once, at append time, including a
        request's final token on the step that marks it done.
        """
        finished: list[Request] = []
        active: list[Optional[Request]] = [None] * self.B
        cache = None
        toks = None
        while self.queue or any(r is not None for r in active):
            if self.queue and any(r is None for r in active):
                # Admission event: recycle every free slot, then replay
                # the whole active set through one prefill.
                for i in range(self.B):
                    if active[i] is None and self.queue:
                        req = self.queue.popleft()
                        self.stats["admissions"] += 1
                        if req.max_new_tokens <= 0:
                            req.done = True
                            finished.append(req)
                            continue
                        active[i] = req
                if not any(r is not None for r in active):
                    continue        # queue held only zero-token requests
                logits, cache = self._prefill(self.params,
                                              self._batchify(active))
                self.stats["prefills"] += 1
                if self.qos is not None:
                    self.qos.prefill_event()
                toks = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            # Append the batch's current tokens; count each token ONCE,
            # at append time, so the counter reconciles exactly with
            # out_tokens even on a request's final step.
            t_host = np.asarray(toks)
            for r, t in zip(active, t_host):
                if r is not None:
                    r.out_tokens.append(int(t))
                    self.stats["tokens"] += 1
                    if len(r.out_tokens) >= r.max_new_tokens:
                        r.done = True
            # Retire finished slots mid-flight — freed slots re-admit at
            # the top of the next iteration (continuous batching).
            for i, r in enumerate(active):
                if r is not None and r.done:
                    finished.append(r)
                    active[i] = None
            if not any(r is not None for r in active):
                continue
            if self.queue and any(r is None for r in active):
                continue            # admit (re-prefill) before decoding on
            logits, cache = self._decode(self.params, cache, toks)
            toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            self.stats["decode_steps"] += 1
            if self.qos is not None:
                self.qos.decode_event()
        if self.qos is not None:
            self.stats["qos"] = self.qos.summary()
        return finished
