"""End-to-end driver: data-parallel training with OCCL gradient sync
(paper Sec. 5.3 protocol) — a ~100M-param qwen3-family model for a few
hundred steps, with checkpoints, fault injection, and recovery.

Reduce steps/size via flags for a quick run:
    PYTHONPATH=src python examples/train_dp_occl.py --steps 12 --tiny
"""
import argparse
import pathlib
import sys
import tempfile
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import dataclasses
import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeCell
from repro.data.pipeline import SyntheticPipeline
from repro.fabric.ft import FTConfig, TrainController
from repro.train.occl_sync import OcclGradSync
from repro.train.state import init_state
from repro.train.step import (make_apply_step, make_grads_step,
                              make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--dp", type=int, default=2)
    ap.add_argument("--tiny", action="store_true")
    args = ap.parse_args()

    cfg = get_config("qwen3-0.6b")
    if args.tiny:
        cfg = cfg.reduced()
    else:
        # ~100M-param config that still fits CPU RAM comfortably
        cfg = dataclasses.replace(
            cfg.reduced(), d_model=512, n_layers=8, d_ff=2048,
            n_heads=8, n_kv_heads=4, d_head=64, vocab=32000)
    cell = ShapeCell("ex", 128, 4 * args.dp, "train")

    n = sum(int(np.prod(p.shape)) for p in
            jax.tree_util.tree_leaves(init_state(cfg).params))
    print(f"model: {cfg.name} ({n/1e6:.1f}M params), DP={args.dp}")

    # --- fault-tolerant single-process loop first (watchdog + ckpt) ----
    with tempfile.TemporaryDirectory() as ckdir:
        pipe = SyntheticPipeline(cfg, cell).start()
        ctrl = TrainController(
            FTConfig(ckpt_dir=ckdir, ckpt_period=25),
            jax.jit(make_train_step(cfg)), init_state(cfg), pipe,
            inject_failure_at=min(40, args.steps // 2) or None)
        logs = ctrl.run(min(args.steps, 60))
        pipe.stop()
        print(f"[ft loop] {len(logs)} steps, {ctrl.restarts} recovery, "
              f"loss {logs[0]['loss']:.3f} -> {logs[-1]['loss']:.3f}")

    # --- OCCL-synced DP loop --------------------------------------------
    states = [init_state(cfg) for _ in range(args.dp)]
    pipes = [SyntheticPipeline(cfg, cell, shard_id=r, n_shards=args.dp)
             for r in range(args.dp)]
    gfn = jax.jit(make_grads_step(cfg))
    afn = jax.jit(make_apply_step(cfg))
    sync = None
    t0 = time.time()
    steps = min(args.steps, 30)
    for step in range(steps):
        per_rank, losses = [], []
        for r in range(args.dp):
            loss, g = gfn(states[r], next(pipes[r]))
            per_rank.append(g)
            losses.append(float(loss))
        if sync is None:
            tmpl = jax.tree_util.tree_map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                per_rank[0])
            sync = OcclGradSync(tmpl, args.dp, bucket_elems=1 << 16,
                                slice_elems=1024)
        synced = sync.all_reduce(per_rank)
        states = [afn(states[r], synced[r]) for r in range(args.dp)]
        if step % 5 == 0:
            print(f"[occl dp] step {step:3d} loss {np.mean(losses):.4f}")
    dt = time.time() - t0
    st = sync.stats()
    print(f"[occl dp] {steps} steps in {dt:.1f}s "
          f"({steps * cell.global_batch / dt:.1f} samples/s); "
          f"buckets={len(sync.buckets)}, "
          f"daemon supersteps={int(st['supersteps'].max())}")
    print("OK")


if __name__ == "__main__":
    main()
