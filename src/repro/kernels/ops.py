"""jit'd dispatch layer over the Pallas kernels.

On TPU the kernels compile natively; on CPU (this container) they execute
via the Pallas interpreter, which validates the kernel bodies bit-for-bit
against the ref.py oracles.  ``use_kernels(False)`` falls back to the
oracles entirely (the scheduler's default fast path on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .chunk_combine import chunk_combine_pallas
from .fused_slice import fused_primitive_pallas

_INTERPRET = jax.default_backend() != "tpu"


def fused_primitive(payload: jnp.ndarray, local: jnp.ndarray,
                    op: jnp.ndarray, needs_recv: jnp.ndarray,
                    does_reduce: jnp.ndarray, reads_in: jnp.ndarray
                    ) -> jnp.ndarray:
    """Scheduler entry point: single [S] slice, traced flag scalars."""
    flags = jnp.stack([
        needs_recv.astype(jnp.int32), does_reduce.astype(jnp.int32),
        reads_in.astype(jnp.int32), op.astype(jnp.int32),
    ])[None, :]
    return fused_primitive_pallas(
        payload[None, :], local[None, :], flags, interpret=_INTERPRET)[0]


def fused_primitive_batch(payload, local, flags):
    return fused_primitive_pallas(payload, local, flags,
                                  interpret=_INTERPRET)


def chunk_combine(a, b, op: int = 0):
    return chunk_combine_pallas(a, b, op, interpret=_INTERPRET)


# ref aliases, exported for benchmarks and tests
fused_primitive_ref = ref.fused_primitive_ref
chunk_combine_ref = ref.chunk_combine_ref
