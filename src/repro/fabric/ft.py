"""Fault tolerance: checkpoint/restart training controller.

``TrainController`` wraps the train loop with the behaviors a 1000+-node
deployment needs:

* periodic async checkpoints (never blocks the step);
* a step watchdog (a step exceeding ``step_timeout_s`` marks the node
  suspect — on real fleets this triggers re-scheduling; here it raises);
* crash recovery: on any step failure the controller restores the last
  committed checkpoint (params, optimizer, data cursor) and resumes —
  losing at most ``ckpt_period`` steps;
* failure injection hooks for tests (``inject_failure_at``).

Straggler mitigation at the *collective* layer is the OCCL daemon's
voluntary-quit bound (core/daemon.py): a wedged peer cannot hold the
fabric — the daemon returns to the host, which can re-route or re-admit
work.  ``fabric/straggler.py`` adds the step-level detector.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Optional

import jax

from ..checkpoint.ckpt import AsyncCheckpointer, latest_step, restore
# StepTimeout moved to the unified error taxonomy (core/errors.py); the
# historic ``fabric.ft.StepTimeout`` name stays importable from here.
from ..core.errors import DeadlockTimeout, StepTimeout
from ..core.recorder import diagnose
from ..data.pipeline import SyntheticPipeline
from .straggler import StragglerDetector


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_period: int = 20
    keep: int = 2
    step_timeout_s: float = 300.0
    max_restarts: int = 3


class TrainController:
    def __init__(self, cfg: FTConfig, step_fn: Callable, state,
                 pipeline: SyntheticPipeline,
                 inject_failure_at: Optional[int] = None):
        self.cfg = cfg
        self.step_fn = step_fn
        self.state = state
        self.pipeline = pipeline
        self.ckpt = AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.keep)
        self.inject_failure_at = inject_failure_at
        self.restarts = 0
        self.metrics_log: list[dict] = []

    def _checkpoint(self, step: int):
        self.ckpt.save_async(step, self.state,
                             extras={"pipeline": self.pipeline.state_dict()})

    def _recover(self):
        self.restarts += 1
        if self.restarts > self.cfg.max_restarts:
            raise RuntimeError("restart budget exhausted")
        last = latest_step(self.cfg.ckpt_dir)
        if last is None:
            raise RuntimeError("no checkpoint to recover from")
        self.state, extras = restore(self.cfg.ckpt_dir, last, self.state)
        self.pipeline.load_state_dict(extras["pipeline"])
        return last

    def run(self, n_steps: int) -> list[dict]:
        self._checkpoint(int(self.state.step))   # step-0 baseline
        self.ckpt.wait()
        done = int(self.state.step)
        while done < n_steps:
            try:
                if (self.inject_failure_at is not None
                        and done == self.inject_failure_at):
                    self.inject_failure_at = None   # fire once
                    raise RuntimeError("injected node failure")
                batch = next(self.pipeline)
                t0 = time.time()
                self.state, metrics = self.step_fn(self.state, batch)
                jax.block_until_ready(metrics)
                dt = time.time() - t0
                if dt > self.cfg.step_timeout_s:
                    raise StepTimeout(f"step took {dt:.1f}s")
                metrics = {k: float(v) for k, v in metrics.items()}
                metrics.update(step=done, step_time_s=dt,
                               restarts=self.restarts)
                self.metrics_log.append(metrics)
                done += 1
                if done % self.cfg.ckpt_period == 0:
                    self._checkpoint(done)
            except (RuntimeError, StepTimeout):
                recovered = self._recover()
                done = recovered
        self.ckpt.wait()
        self._checkpoint(done)
        self.ckpt.wait()
        return self.metrics_log


class ReliabilityController:
    """Detection -> diagnosis -> eviction glue over one OcclRuntime.

    The reliability loop a fleet controller runs around the training
    step:

    1. **observe**: feed per-rank step times and the runtime's per-rank
       superstep/RTC stats into the :class:`StragglerDetector` (both
       channels — wall-clock alone misses a rank that is healthy
       host-side but wedging the fabric);
    2. **diagnose**: on a :class:`DeadlockTimeout` (or on demand) run
       ``recorder.diagnose`` and mark every named holder suspect;
    3. **heal**: evict every rank outside ``healthy_ranks()`` (highest
       rank first, so earlier evictions do not renumber later ones) and
       resume — ``evict()`` replays the wedged submissions, so surviving
       ranks' in-flight work completes on the shrunk fabric.  The
       eviction list is capped so at least ``min_survivors`` ranks stay
       (a detector that flags the whole fleet — e.g. diagnose naming
       every member of a stalled chain — must not tear the job down
       mid-heal); the capped remainder is reported in ``deferred``.
    """

    def __init__(self, runtime, detector: StragglerDetector | None = None,
                 min_survivors: int = 1, tenant_namer=None):
        self.runtime = runtime
        self.detector = detector or StragglerDetector(runtime.cfg.n_ranks)
        self.evicted: list[int] = []    # ranks as numbered at eviction time
        self.min_survivors = max(1, min_survivors)
        self.deferred: list[int] = []   # suspects kept alive by the cap
        # Optional coll_id -> tenant-label map (serving QoS: the fabric
        # is multi-tenant, and a diagnosis that names "collective 3"
        # without saying WHICH traffic class owns it sends the operator
        # back to the registration log).
        self.tenant_namer = tenant_namer

    @classmethod
    def for_serving(cls, qos, detector: StragglerDetector | None = None,
                    min_survivors: int = 1) -> "ReliabilityController":
        """Bind the reliability loop to a serving QoS fabric
        (:class:`~repro.serving.qos.ServingQos`): the decode tenant's
        rtc-latency feeds the same collective EWMA channel as training
        collectives, and diagnosis names stalled chains BY tenant — a
        wedged background burst is reported as BACKGROUND holding the
        lane instead of silently inflating decode p99."""
        return cls(qos.runtime, detector=detector,
                   min_survivors=min_survivors, tenant_namer=qos.class_of)

    def observe_step(self, step_times_s=None) -> None:
        """One observation window: optional per-rank wall-clock times
        (``{rank: seconds}``) plus the runtime's current collective
        stats.  On a serving fabric the stats include the decode
        tenant's per-rank rtc latency, so a rank dragging decode feeds
        the same EWMA channel as one dragging grad-sync."""
        if step_times_s:
            for r, t in step_times_s.items():
                self.detector.observe(r, t)
        self.detector.observe_collective_stats(self.runtime.stats())

    def diagnose_tenants(self) -> list[dict]:
        """Current stalled chains annotated with their tenant label
        (``tenant_namer``; None for unmapped collectives) — the
        serving-facing diagnosis surface."""
        out = []
        for s in diagnose(self.runtime).stalled:
            out.append({
                "coll_id": int(s.coll_id),
                "tenant": (self.tenant_namer(s.coll_id)
                           if self.tenant_namer else None),
                "holding_ranks": list(s.holding_ranks),
                "waiting_ranks": list(s.waiting_ranks),
                "reason": s.reason,
            })
        return out

    def heal(self, error: DeadlockTimeout | None = None) -> list[int]:
        """Mark diagnosed holders suspect, evict every unhealthy rank and
        resume.  Returns the evicted ranks (pre-eviction numbering).
        With no ``error``, diagnoses the runtime's current outstanding
        set directly (no-op when nothing is stalled).  Evictions are
        capped to keep ``min_survivors`` ranks; suspects spared by the
        cap land in ``self.deferred`` (with a warning) instead of
        raising :class:`~repro.core.errors.EvictionError` mid-loop."""
        diag = error.diagnosis if error is not None and \
            error.diagnosis is not None else diagnose(self.runtime)
        for r in diag.holders:
            self.detector.mark_suspect(r)
        healthy = set(self.detector.healthy_ranks())
        R = self.runtime.cfg.n_ranks
        bad = sorted((r for r in range(R) if r not in healthy),
                     reverse=True)
        # Floor: never evict past min_survivors ranks — a detector that
        # flags (almost) everyone would otherwise hit EvictionError
        # MID-loop with some evictions already applied.  Highest-numbered
        # suspects go first (stable renumbering); the rest are deferred,
        # not evicted, and reported for the controller's next window.
        max_evict = max(0, R - self.min_survivors)
        self.deferred = sorted(bad[max_evict:])
        bad = bad[:max_evict]
        if self.deferred:
            warnings.warn(
                f"heal(): {len(bad) + len(self.deferred)} of {R} ranks "
                f"flagged unhealthy; evicting {len(bad)} and keeping "
                f"suspect rank(s) {self.deferred} alive to preserve "
                f"{self.min_survivors} survivor(s)", stacklevel=2)
        for r in bad:
            self.runtime.evict(r)
        if bad:
            self.evicted.extend(bad)
            # Rank numbering changed; timing history no longer maps onto
            # rank ids — restart the detector for the shrunk fleet.
            self.detector = StragglerDetector(
                self.runtime.cfg.n_ranks, alpha=self.detector.alpha,
                threshold=self.detector.threshold)
        return bad
