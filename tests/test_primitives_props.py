"""Hypothesis property sweep: cross-rank program consistency of every
registered ring-program builder (the invariant the composite-collective
algorithm registry must preserve PER SUB-COLLECTIVE, core/algos.py).

For every kind x group size x root the per-rank primitive programs must be
mutually consistent along the ring:

* **flow matching** — the sequence of chunks rank m sends equals, in FIFO
  order, the sequence of chunks rank (m+1) % R receives (connectors are
  FIFO ring buffers, so a chunk mismatch would silently combine unrelated
  slices);
* **drain** — executing the programs dataflow-style with unbounded
  connectors terminates with every program complete and no dangling
  sends (a structural wedge here would deadlock the daemon regardless of
  scheduling);
* **flow conservation** — every chunk reaches its destination with
  exactly the right contribution set (all ranks for reductions, the
  originator for gathers/broadcast).

Skipped when hypothesis is absent (tier-1 containers);
``pip install -r requirements-dev.txt`` restores the sweep.
"""
import collections

import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.primitives import (_FLAGS, CollKind, Prim, build_program)


def _simulate(kind: CollKind, R: int, root: int):
    """Dataflow-execute the R per-rank programs over unbounded FIFO
    connectors, tracking each output chunk's contribution set (the set of
    ranks whose INPUT was combined into it)."""
    progs = [build_program(kind, m, R, root) for m in range(R)]
    pc = [0] * R
    fifo = [collections.deque() for _ in range(R)]  # edge m -> (m+1) % R
    out: list[dict] = [dict() for _ in range(R)]
    progress = True
    while progress:
        progress = False
        for m in range(R):
            while pc[m] < len(progs[m]):
                prim, k = progs[m][pc[m]]
                recv, send, _reduce, copy, reads = _FLAGS[Prim(prim)]
                src = (m - 1) % R
                if recv and not fifo[src]:
                    break                      # wait for the upstream send
                val: set = set()
                if recv:
                    wk, wv = fifo[src].popleft()
                    # Flow matching: the FIFO hands this rank exactly the
                    # chunk its program expects next.
                    assert wk == k, (
                        f"{kind.name} R={R} root={root}: rank {m} step "
                        f"{pc[m]} expects chunk {k}, wire has {wk}")
                    val |= wv
                if reads:
                    val.add(m)
                if copy:
                    out[m][k] = frozenset(val)
                if send:
                    fifo[m].append((k, frozenset(val)))
                pc[m] += 1
                progress = True
    assert all(pc[m] == len(progs[m]) for m in range(R)), (
        f"{kind.name} R={R} root={root}: programs wedge at {pc}")
    assert all(not f for f in fifo), "dangling sends after completion"
    return out


@settings(max_examples=120, deadline=None)
@given(data=st.data())
def test_flow_conservation(data):
    kind = data.draw(st.sampled_from(list(CollKind)), label="kind")
    R = data.draw(st.integers(1, 9), label="group_size")
    root = data.draw(st.integers(0, R - 1), label="root")
    out = _simulate(kind, R, root)
    everyone = frozenset(range(R))

    if R == 1:
        # Degenerate single-member group: local copy of the own input.
        assert out[0] == {0: frozenset({0})}
        return
    if kind == CollKind.ALL_REDUCE:
        for m in range(R):
            assert out[m] == {k: everyone for k in range(R)}
    elif kind == CollKind.ALL_GATHER:
        for m in range(R):
            assert out[m] == {k: frozenset({k}) for k in range(R)}
    elif kind == CollKind.REDUCE_SCATTER:
        for m in range(R):
            # Rank m finalizes exactly its own chunk, fully reduced.
            assert out[m] == {m: everyone}
    elif kind == CollKind.BROADCAST:
        for m in range(R):
            assert out[m] == {k: frozenset({root}) for k in range(R)}
    elif kind == CollKind.REDUCE:
        assert out[root] == {k: everyone for k in range(R)}
        for m in range(R):
            if m != root:
                assert out[m] == {}   # non-roots copy nothing


# ---------------------------------------------------------------------------
# composite-plan flow conservation (the algorithm zoo, core/algos.py)
# ---------------------------------------------------------------------------

def _eval_stage(stage, state):
    """Semantically evaluate one CompositePlan stage over per-rank logical
    contribution vectors.

    ``state[rank]`` is a list of frozensets of ``(origin_rank, elem)``
    atoms — the provenance of each logical element the rank currently
    holds — or None where the previous stage left the rank's buffer
    undefined (reduce non-roots).  Atoms carry the ORIGINAL input
    identity, so chunk-offset bugs anywhere in the chain (reduce-scatter
    ownership, all-gather placement, inter-ring chunk arithmetic) show up
    as misaligned atoms in the final state, not just wrong counts."""
    from repro.core.primitives import CollKind as K

    ns, P = stage.n_elems, stage.ring_size
    cl = -(-ns // P)
    rings = [stage.members[i:i + P]
             for i in range(0, len(stage.members), P)]
    new = dict(state)
    for ring in rings:
        assert len(ring) == P
        if stage.kind in (K.ALL_REDUCE, K.REDUCE):
            for r in ring:
                assert state[r] is not None and len(state[r]) == ns, (
                    f"{stage.kind.name}: rank {r} hands stage a "
                    f"{state[r] and len(state[r])}-elem buffer, wants {ns}")
            red = [frozenset().union(*(state[r][e] for r in ring))
                   for e in range(ns)]
            if stage.kind == K.ALL_REDUCE:
                for r in ring:
                    new[r] = list(red)
            else:
                for p, r in enumerate(ring):
                    new[r] = list(red) if p == stage.root else None
        elif stage.kind == K.REDUCE_SCATTER:
            for r in ring:
                assert state[r] is not None and len(state[r]) == ns
            for p, r in enumerate(ring):
                new[r] = [frozenset().union(
                              *(state[q][p * cl + j] for q in ring))
                          if p * cl + j < ns else frozenset()
                          for j in range(cl)]
        elif stage.kind == K.ALL_GATHER:
            for r in ring:
                assert state[r] is not None and len(state[r]) == cl
            full = [state[ring[e // cl]][e % cl] for e in range(ns)]
            for r in ring:
                new[r] = list(full)
        elif stage.kind == K.BROADCAST:
            src = ring[stage.root]
            assert state[src] is not None and len(state[src]) == ns
            for r in ring:
                new[r] = list(state[src])
        else:
            raise AssertionError(f"unexpected stage kind {stage.kind}")
    return new


@settings(max_examples=100, deadline=None)
@given(data=st.data())
def test_composite_plan_flow_conservation(data):
    """Every plan in the algorithm zoo, for every grid shape, root and
    ragged payload: chain edges agree on buffer lengths (the relink span
    contract) and the final state carries exactly the right contribution
    atoms at exactly the right logical positions."""
    from repro.core.algos import build_plan

    algo, kind = data.draw(st.sampled_from([
        ("two_level", CollKind.ALL_REDUCE),
        ("torus", CollKind.ALL_REDUCE),
        ("hybrid", CollKind.ALL_REDUCE),
        ("tree", CollKind.BROADCAST),
        ("tree", CollKind.REDUCE),
    ]), label="algo_kind")
    G = data.draw(st.integers(2, 4), label="G")
    N = data.draw(st.integers(2, 4), label="N")
    R = G * N
    root = data.draw(st.integers(0, R - 1), label="root")
    n = data.draw(st.integers(1, 64), label="n_elems")
    members = tuple(range(100, 100 + R))       # non-contiguous global ids
    plan = build_plan(algo, kind, members, (G, N), n, root)
    for stage in plan.stages:
        assert set(stage.members) <= set(members)
        assert len(stage.members) % stage.ring_size == 0
        assert len(set(stage.members)) == len(stage.members)
    state = {r: [frozenset({(r, e)}) for e in range(n)] for r in members}
    for stage in plan.stages:
        state = _eval_stage(stage, state)
    want_all = [frozenset((r, e) for r in members) for e in range(n)]
    if kind == CollKind.ALL_REDUCE:
        for r in members:
            assert state[r] == want_all, f"rank {r} mis-reduced ({algo})"
    elif kind == CollKind.BROADCAST:
        src = members[root]
        want = [frozenset({(src, e)}) for e in range(n)]
        for r in members:
            assert state[r] == want, f"rank {r} got non-root data"
    else:                                      # REDUCE: defined at root
        assert state[members[root]] == want_all


@settings(max_examples=60, deadline=None)
@given(data=st.data())
def test_send_recv_counts_balance(data):
    """Per ring edge, #sends == #recvs (no chunk is ever dropped on the
    wire) — the counting form of flow conservation."""
    from repro.core.primitives import PRIM_RECV, PRIM_SEND

    kind = data.draw(st.sampled_from(list(CollKind)), label="kind")
    R = data.draw(st.integers(2, 9), label="group_size")
    root = data.draw(st.integers(0, R - 1), label="root")
    progs = [build_program(kind, m, R, root) for m in range(R)]
    for m in range(R):
        sends = sum(int(PRIM_SEND[p]) for p, _ in progs[m])
        recvs = sum(int(PRIM_RECV[p]) for p, _ in progs[(m + 1) % R])
        assert sends == recvs, (kind, R, root, m)
