"""Paper Fig. 5: workload-agnostic overheads of the daemon.

(a) time composition: supersteps spent on I/O (SQE fetch) vs executing
    primitives, per collective execution in the daemon;
(b) overhead vs buffer size: the extra supersteps (scheduling, fetch,
    drain detection) are flat while payload supersteps grow — the
    workload-agnostic property the paper demonstrates.

On this CPU testbed the structural metric is SUPERSTEPS (the daemon's
clock); wall-time per launch is also reported.
"""
import numpy as np

from common import row, timeit
from repro.core import CollKind, OcclConfig, OcclRuntime


def run(sizes=(64, 256, 1024, 4096, 16384), R=8):
    out = []
    for n in sizes:
        cfg = OcclConfig(n_ranks=R, max_colls=2, max_comms=1,
                         slice_elems=256, conn_depth=8,
                         heap_elems=max(1 << 12, 8 * n),
                         superstep_budget=1 << 15)
        rt = OcclRuntime(cfg)
        comm = rt.communicator(list(range(R)))
        ar = rt.register(CollKind.ALL_REDUCE, comm, n_elems=n)
        x = np.ones(n, np.float32)

        def once():
            for r in range(R):
                rt.submit(r, ar, data=x)
            rt.drive()

        wall = timeit(once, iters=3, warmup=1)
        st = rt.stats()
        total_steps = int(st["supersteps"].max())
        work = int(st["slices_moved"].max(initial=0) // R)
        spec = rt.specs[ar]
        # protocol minimum: (2R-1 primitives) x slices x rounds + pipeline fill
        min_steps = (2 * R - 1) * spec.n_slices * spec.n_rounds + (2 * R - 2)
        launches = rt.launches
        overhead = total_steps / launches - min_steps / 1  # per launch
        out.append((n, wall, total_steps, min_steps, launches))
        row(f"overheads/allreduce_n{n}", wall * 1e6 / 4,
            f"supersteps_per_iter={total_steps/launches:.0f};"
            f"protocol_min={min_steps};launches={launches}")
    return out


if __name__ == "__main__":
    run()
