"""The tick contract (core/daemon.py): drive() and in-step ticks are the
SAME machine.

* ``drive()`` (one budget-bounded launch), ``drive(tick_k=1)`` (one
  superstep per host call) and ``drive(tick_k=7)`` (batched ticks) must
  produce BIT-IDENTICAL outputs and the IDENTICAL superstep/preempt
  trajectory for every collective kind — including device-chained
  composites and the ragged all-to-all.  The launch prologue + in-body
  budget check make the host-chosen ``k`` unobservable.
* The tick observability counters (state.py) must reconcile exactly:
  ``overlap_supersteps + barrier_supersteps == supersteps`` (every
  superstep runs inside some tick) and ``rtc_events`` matches
  ``stage_completions`` (every completion was latency-stamped).
* Deadlock freedom survives the move INSIDE a jitted step: conflicting
  chained submission orders that provably wedge the static baseline
  complete when driven entirely by in-step DeviceApi submits + bounded
  ticks (no host drive() at all).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CollKind, OcclConfig, OcclRuntime, OrderPolicy,
                        run_static_order)

R = 4
TRAJ_KEYS = ("supersteps", "preempts", "stage_completions", "completed",
             "launch_steps", "stall_slices", "slices_moved")


def _mixed_runtime():
    """One runtime exercising EVERY collective kind plus a chained
    two-level composite and a ragged a2a, submitted in conflicting
    per-rank orders."""
    cfg = OcclConfig(n_ranks=R, max_colls=12, max_comms=3, slice_elems=4,
                     conn_depth=4, heap_elems=1 << 15,
                     order_policy=OrderPolicy.FIFO,
                     superstep_budget=1 << 14, quit_threshold=64)
    rt = OcclRuntime(cfg)
    comm = rt.communicator(list(range(R)))
    rng = np.random.RandomState(11)
    specs = [
        (CollKind.ALL_REDUCE, dict(n_elems=24)),
        (CollKind.ALL_GATHER, dict(n_elems=16)),
        (CollKind.REDUCE_SCATTER, dict(n_elems=16)),
        (CollKind.BROADCAST, dict(n_elems=12, root=1)),
        (CollKind.REDUCE, dict(n_elems=12, root=2)),
        (CollKind.ALL_TO_ALL, dict(n_elems=16)),
        (CollKind.ALL_TO_ALL_RAGGED, dict(n_elems=12,
                                          chunk_sizes=(2, 1, 0, 3))),
        (CollKind.ALL_REDUCE, dict(n_elems=20, algo="two_level",
                                   hierarchy=(2, 2))),
    ]
    ids, kinds = [], []
    for kind, kw in specs:
        ids.append(rt.register(kind, comm, **kw))
        kinds.append(kind)
    for r in range(R):
        order = list(np.roll(np.arange(len(ids)), r))  # pairwise-conflicting
        for slot in order:
            cid, kind = ids[slot], kinds[slot]
            if kind == CollKind.ALL_GATHER:
                data = rng.randn(specs[slot][1]["n_elems"] // R)
            elif kind == CollKind.ALL_TO_ALL_RAGGED:
                data = rng.randn(sum(specs[slot][1]["chunk_sizes"]))
            elif kind == CollKind.BROADCAST:
                if r != specs[slot][1]["root"]:
                    rt.submit(r, cid)
                    continue
                data = rng.randn(specs[slot][1]["n_elems"])
            else:
                data = rng.randn(specs[slot][1]["n_elems"])
            rt.submit(r, cid, data=data.astype(np.float32))
    return rt, ids


def _run_mode(tick_k):
    rt, ids = _mixed_runtime()
    rt.drive(max_launches=8, tick_k=tick_k)
    outs = {(r, cid): np.asarray(rt.read_output(r, cid))
            for cid in ids for r in range(R)}
    st = rt.stats()
    traj = {k: np.asarray(st[k]).copy() for k in TRAJ_KEYS}
    return outs, traj, st


@pytest.fixture(scope="module")
def drive_baseline():
    return _run_mode(None)


@pytest.mark.parametrize("tick_k", [1, 7])
def test_tick_mode_bit_identical_to_drive(drive_baseline, tick_k):
    """Outputs AND trajectory: batching ticks must be unobservable."""
    outs0, traj0, _ = drive_baseline
    outs, traj, _ = _run_mode(tick_k)
    assert outs.keys() == outs0.keys()
    for key in outs0:
        np.testing.assert_array_equal(outs[key], outs0[key], err_msg=str(key))
    for k in TRAJ_KEYS:
        np.testing.assert_array_equal(traj[k], traj0[k], err_msg=k)


def test_counters_reconcile_with_stage_completions(drive_baseline):
    """overlap + barrier == supersteps; rtc_events == stage_completions
    (chain intermediates included); mean ready-to-complete latency is
    finite and positive wherever something completed."""
    _, _, st = drive_baseline
    np.testing.assert_array_equal(
        st["overlap_supersteps"] + st["barrier_supersteps"],
        st["supersteps"])
    np.testing.assert_array_equal(st["rtc_events"], st["stage_completions"])
    assert int(st["tick_calls"].max()) >= 1
    done = st["rtc_events"] > 0
    assert np.all(st["rtc_latency"][done] > 0)
    # drive() is all-barrier: nothing claimed to overlap host compute
    assert int(st["overlap_supersteps"].max()) == 0


def test_in_step_ticks_survive_conflicting_chained_orders():
    """Two device-chained two-level all-reduces, submitted in opposite
    per-rank orders ENTIRELY inside one jitted step (DeviceApi submits +
    bounded overlap ticks, then a drain) — the static baseline provably
    wedges on these orders; the tick-driven daemon completes them with
    correct sums."""
    orders = {0: [0, 1], 1: [1, 0], 2: [0, 1], 3: [1, 0]}
    members = {0: list(range(R)), 1: list(range(R))}
    static = run_static_order(orders, members)
    assert static.deadlocked and static.cycle

    cfg = OcclConfig(n_ranks=R, max_colls=8, max_comms=3, slice_elems=4,
                     conn_depth=3, heap_elems=1 << 14,
                     order_policy=OrderPolicy.FIFO,
                     superstep_budget=1 << 14, quit_threshold=64)
    rt = OcclRuntime(cfg)
    comm = rt.communicator(list(range(R)))
    ids = [rt.register(CollKind.ALL_REDUCE, comm, n_elems=16,
                       algo="two_level", hierarchy=(2, 2))
           for _ in range(2)]
    api = rt.device_api()
    rng = np.random.RandomState(3)
    xs = rng.randn(2, R, 16).astype(np.float32)

    @jax.jit
    def step(st, payloads):
        st = api.step_prologue(st)
        for r in range(R):
            for slot in orders[r]:
                st = api.submit(st, r, ids[slot], payloads[slot, r],
                                prio=slot)
                st, _ = api.tick(st, jnp.int32(3), barrier=False)
        st = api.drain(st)
        return st, jnp.stack([api.read_all(st, cid) for cid in ids])

    st, outs = step(rt.state, jnp.asarray(xs))
    rt.adopt_state(st)
    for slot in range(2):
        want = xs[slot].sum(axis=0)
        for r in range(R):
            np.testing.assert_allclose(np.asarray(outs[slot, r]), want,
                                       rtol=1e-4, atol=1e-5)
    # both chains logically completed on every rank, and some supersteps
    # genuinely ran hidden inside the in-step overlap ticks
    for cid in ids:
        assert np.all(np.asarray(api.completed(st, cid)) >= 1)
    stats = rt.stats()
    np.testing.assert_array_equal(
        stats["overlap_supersteps"] + stats["barrier_supersteps"],
        stats["supersteps"])
    assert int(stats["overlap_supersteps"].max()) > 0
