"""Paper Fig. 9 case study: context switches & task-queue lengths with and
without the stickiness adjustment scheme, under bursty adversarial
submission (the backward-pass burst of data-parallel training) and under
rank skew (one rank delays — where OCCL's dynamic scheduling wins over a
static order that would stall every rank)."""
import numpy as np

from common import row
from repro.core import CollKind, OcclConfig, OcclRuntime, OrderPolicy


def burst(stickiness: bool, skew_rank: int | None = None,
          R=4, C=8, size=256, demand: bool = False):
    cfg = OcclConfig(n_ranks=R, max_colls=C, max_comms=1, slice_elems=32,
                     conn_depth=4, heap_elems=1 << 15,
                     stickiness=stickiness, demand_steering=demand,
                     superstep_budget=1 << 15)
    rt = OcclRuntime(cfg)
    comm = rt.communicator(list(range(R)))
    ids = [rt.register(CollKind.ALL_REDUCE, comm, n_elems=size)
           for _ in range(C)]
    rng = np.random.RandomState(1)
    x = np.ones(size, np.float32)

    if skew_rank is None:
        for r in range(R):
            for i in rng.permutation(C):
                rt.submit(r, ids[i], data=x)
        rt.drive()
    else:
        # skewed: one rank submits late (the Fig. 9 GPU-2 scenario)
        for r in range(R):
            if r == skew_rank:
                continue
            for i in rng.permutation(C):
                rt.submit(r, ids[i], data=x)
        rt.launch_once()          # others run ahead, pile up, preempt
        for i in range(C):
            rt.submit(skew_rank, ids[i], data=x)
        rt.drive()
    st = rt.stats()
    return {
        "preempts": int(st["preempts"].sum()),
        "max_qlen": int(st["qlen_at_fetch"].max()),
        "supersteps": int(st["supersteps"].max()),
        "per_coll_preempts": st["preempts"].sum(0)[:8].tolist(),
    }


def run():
    out = {}
    for label, (stick, demand) in {
        "nostick": (False, False),
        "stickiness": (True, False),
        "demand": (False, True),
        "stickiness+demand": (True, True),
    }.items():
        r = burst(stick, demand=demand)
        s = burst(stick, skew_rank=2, demand=demand)
        out[label] = (r, s)
        row(f"gang/{label}", r["supersteps"],
            f"preempts={r['preempts']};max_qlen={r['max_qlen']};"
            f"skew_steps={s['supersteps']};skew_preempts={s['preempts']}")
    return out


if __name__ == "__main__":
    run()
