"""train_step / serve_step builders.

``make_train_step`` returns the canonical data/tensor-parallel training
step: loss -> grads (DP all-reduce inserted by SPMD) -> clip -> AdamW.
Gradient synchronization is the OCCL integration point: with
``grad_sync="xla"`` the reduction is the statically-sequenced XLA psum
(the paper's "statically sequenced NCCL" baseline); ``grad_sync="occl"``
routes bucketed gradients through the OCCL runtime between the backward
and optimizer phases (host-driven, see train/occl_sync.py).

Overlapped grad sync (the tick contract inside a training step)
----------------------------------------------------------------
``make_overlap_grads_step`` moves grad sync INSIDE the jitted step:
``custom_vjp`` identity boundaries wrap each gradient bucket's parameter
leaves, so their backward rules fire during the backward pass exactly
when that bucket's gradient cotangents materialize — each boundary
submits the bucket in-trace (core/device_api.py) and advances the daemon
by a bounded OVERLAP ``tick(state, k)`` (core/daemon.py docstring), so
all-reduce supersteps hide behind the remaining backward work instead of
trailing it as a barrier.  Mechanics worth knowing:

* The DaemonState rides the autodiff graph as a TOKEN: integer/bool
  state leaves cannot be cotangents (``float0``), so the state is
  bitcast losslessly to an all-float32 pytree (``encode_state``) and
  seeded as the token output's cotangent; each boundary decodes,
  submits+ticks, re-encodes.  The token THREADS the boundaries in
  bucket-major order, pinning the backward submission sequence.
* Everything stays pure: submission is a heap scatter + SQE append on
  the state, progress is ``tick`` — the step remains one XLA program,
  which is also where the measured win over host-driven drive() comes
  from (no per-phase host round trips).
* After the pullback, the step drains with BARRIER ticks (the only
  exposed communication when overlap worked) and reads the reduced
  buckets in-trace.  ``stats()`` splits the superstep clock into
  overlap vs barrier supersteps to make that visible.
* ``drive()`` remains the right entry point for host-driven workloads
  (registration-time payload staging, callbacks, DeadlockTimeout
  patience); the caller of an overlapped step must hand the final state
  back via ``runtime.adopt_state`` to keep host reconciliation
  consistent.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core.device_api import decode_state, encode_state, encoded_zeros
from ..models import build_model
from ..optim.adamw import AdamWConfig, adamw_update
from .state import TrainState


def make_train_step(cfg: ArchConfig,
                    opt: AdamWConfig = AdamWConfig()) -> Callable:
    model = build_model(cfg)

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        loss, grads = jax.value_and_grad(model.loss_fn)(state.params, batch)
        new_p, new_m, new_v, gnorm = adamw_update(
            opt, state.params, grads, state.m, state.v, state.step)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm}
        return TrainState(new_p, new_m, new_v, state.step + 1), metrics

    return train_step


def make_grads_step(cfg: ArchConfig) -> Callable:
    """Backward only — used by the OCCL-grad-sync integration, which
    synchronizes gradient buckets itself (train/occl_sync.py) and then
    applies make_apply_step."""
    model = build_model(cfg)

    def grads_step(state: TrainState, batch):
        loss, grads = jax.value_and_grad(model.loss_fn)(state.params, batch)
        return loss.astype(jnp.float32), grads

    return grads_step


def make_overlap_grads_step(cfg: ArchConfig, sync,
                            ticks_per_boundary: int = 4) -> Callable:
    """Backward pass with IN-STEP bucketized grad sync (module docstring).

    ``sync`` is an :class:`~repro.train.occl_sync.OcclGradSync`; the
    returned function

        ``step(st, per_rank_params, per_rank_batch)
            -> (st, losses[R], grads_list)``

    is pure and jit-able: ``st`` is the runtime's DaemonState, the grads
    come back averaged (bit-comparable to ``sync.all_reduce``), and the
    caller re-installs the final state with
    ``sync.occl.adopt_state(st)``.  ``ticks_per_boundary`` is the
    overlap budget spent after each bucket submission — supersteps that
    hide behind the remaining backward work.
    """
    model = build_model(cfg)
    api = sync.device_api()
    R = sync.n_ranks
    buckets = sync.buckets
    tmpl = sync.occl.state

    def _attach(rank: int, bidx: int):
        """Identity on (token, bucket_leaves) whose bwd submits the
        bucket's gradient and runs one overlap tick."""
        cid = buckets[bidx].coll_id

        @jax.custom_vjp
        def attach(token, leaves):
            return token, leaves

        def fwd(token, leaves):
            return (token, leaves), None

        def bwd(_, ct):
            dtoken, dleaves = ct
            st = decode_state(dtoken, tmpl)
            flat = jnp.concatenate(
                [jnp.ravel(d).astype(jnp.float32) for d in dleaves])
            st = api.submit(st, rank, cid, flat, prio=bidx)
            st, _ = api.tick(st, jnp.int32(ticks_per_boundary),
                             barrier=False)
            return encode_state(st), dleaves

        attach.defvjp(fwd, bwd)
        return attach

    def step(st, per_rank_params, per_rank_batch):
        st = api.step_prologue(st)

        def f(params_list, token):
            flats, defs = [], []
            for p in params_list:
                leaves, d = jax.tree_util.tree_flatten(p)
                flats.append(list(leaves))
                defs.append(d)
            # Token threading order pins the BACKWARD submission order
            # (the jaxpr transposes in reverse trace order): wrapping
            # bucket NB-1 .. 0 here makes backward submit bucket 0 —
            # the last layers' gradients, first ready in backward —
            # across all ranks, then bucket 1, etc., with overlap ticks
            # between every submission.
            for bidx in reversed(range(len(buckets))):
                b = buckets[bidx]
                for r in range(R):
                    bl = tuple(flats[r][i] for i in b.leaf_ids)
                    token, bl = _attach(r, bidx)(token, bl)
                    for i, leaf in zip(b.leaf_ids, bl):
                        flats[r][i] = leaf
            losses = [
                model.loss_fn(
                    jax.tree_util.tree_unflatten(defs[r], flats[r]),
                    per_rank_batch[r])
                for r in range(R)
            ]
            total = sum(l.astype(jnp.float32) for l in losses)
            return (total, token), jnp.stack(losses)

        (_, _), pull, losses = jax.vjp(
            f, list(per_rank_params), encoded_zeros(tmpl), has_aux=True)
        # Seed: d(total)=1 makes the bucket cotangents real gradients;
        # the token-output cotangent carries the REAL post-prologue state
        # into the boundary chain.
        _, dtoken = pull((jnp.float32(1.0), encode_state(st)))
        st = decode_state(dtoken, tmpl)
        st = api.drain(st)
        grads = []
        for r in range(R):
            flats_r = [
                api.read(st, r, b.coll_id).astype(jnp.float32) / R
                for b in buckets
            ]
            grads.append(sync.unflatten(flats_r))
        return st, losses, grads

    return step


def make_apply_step(cfg: ArchConfig,
                    opt: AdamWConfig = AdamWConfig()) -> Callable:
    def apply_step(state: TrainState, grads) -> TrainState:
        new_p, new_m, new_v, _ = adamw_update(
            opt, state.params, grads, state.m, state.v, state.step)
        return TrainState(new_p, new_m, new_v, state.step + 1)

    return apply_step


def make_prefill_step(cfg: ArchConfig) -> Callable:
    model = build_model(cfg)

    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step


def make_decode_step(cfg: ArchConfig) -> Callable:
    model = build_model(cfg)

    def serve_step(params, cache, tokens):
        logits, cache = model.decode_step(params, cache, tokens)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache

    return serve_step
