"""Scheduler/stickiness behaviors: two-phase blocking, preemptive priority,
Fig. 9 observability, voluntary quit + event-driven restart, head-of-line
resubmission."""
import numpy as np
import pytest

from repro.core import (CollKind, OcclConfig, OcclRuntime, OrderPolicy,
                        DeadlockTimeout)


def test_two_phase_blocking_nonpreemptive_while_runnable():
    """A runnable current collective is NOT preempted by priority alone
    (paper Sec. 3.2: priority affects queue order; preemption only fires
    on spin-threshold overrun)."""
    cfg = OcclConfig(n_ranks=2, max_colls=4, max_comms=1, slice_elems=4,
                     conn_depth=2, heap_elems=1 << 13,
                     order_policy=OrderPolicy.PRIORITY)
    rt = OcclRuntime(cfg)
    cm = rt.communicator([0, 1])
    lo = rt.register(CollKind.ALL_REDUCE, cm, n_elems=128)
    hi = rt.register(CollKind.ALL_REDUCE, cm, n_elems=8)
    order = []
    for r in range(2):
        rt.submit(r, lo, prio=0, data=np.ones(128, np.float32),
                  callback=lambda rk, c: order.append("lo"))
        rt.submit(r, hi, prio=5, data=np.ones(8, np.float32),
                  callback=lambda rk, c: order.append("hi"))
    rt.drive()
    assert order[0] == "lo"          # lo kept running (never stuck)


def test_priority_preempts_flag():
    cfg = OcclConfig(n_ranks=2, max_colls=4, max_comms=1, slice_elems=4,
                     conn_depth=2, heap_elems=1 << 13,
                     order_policy=OrderPolicy.PRIORITY,
                     priority_preempts=True)
    rt = OcclRuntime(cfg)
    cm = rt.communicator([0, 1])
    lo = rt.register(CollKind.ALL_REDUCE, cm, n_elems=128)
    hi = rt.register(CollKind.ALL_REDUCE, cm, n_elems=8)
    order = []
    for r in range(2):
        rt.submit(r, lo, prio=0, data=np.ones(128, np.float32),
                  callback=lambda rk, c: order.append("lo"))
        rt.submit(r, hi, prio=5, data=np.ones(8, np.float32),
                  callback=lambda rk, c: order.append("hi"))
    rt.drive()
    assert order[0] == "hi"          # hi overtook mid-flight
    assert rt.stats()["preempts"].sum() > 0
    np.testing.assert_allclose(rt.read_output(0, lo), 2 * np.ones(128),
                               rtol=1e-5)


def test_voluntary_quit_and_event_driven_restart():
    cfg = OcclConfig(n_ranks=2, max_colls=2, max_comms=1, slice_elems=4,
                     conn_depth=2, heap_elems=512, quit_threshold=8)
    rt = OcclRuntime(cfg)
    cm = rt.communicator([0, 1])
    ar = rt.register(CollKind.ALL_REDUCE, cm, n_elems=8)
    rt.submit(0, ar, data=np.ones(8, np.float32))
    assert rt.launch_once() == 0           # peer missing -> voluntary quit
    st = rt.stats()
    assert int(st["supersteps"].max()) < cfg.superstep_budget  # quit early
    rt.submit(1, ar, data=np.ones(8, np.float32))
    rt.drive()                              # restart completes it
    np.testing.assert_allclose(rt.read_output(1, ar), 2 * np.ones(8),
                               rtol=1e-5)
    assert rt.launches >= 2


def test_orphan_collective_times_out():
    cfg = OcclConfig(n_ranks=2, max_colls=2, max_comms=1, slice_elems=4,
                     conn_depth=2, heap_elems=512, quit_threshold=8,
                     superstep_budget=256)
    rt = OcclRuntime(cfg)
    cm = rt.communicator([0, 1])
    ar = rt.register(CollKind.ALL_REDUCE, cm, n_elems=8)
    rt.submit(0, ar, data=np.ones(8, np.float32))
    with pytest.raises(DeadlockTimeout):
        rt.drive(max_launches=3)


def test_repeat_submission_same_collective():
    """Head-of-line: resubmitting an in-flight collective waits, then runs
    with fresh buffers (iteration loop, monotonic connector counters)."""
    cfg = OcclConfig(n_ranks=2, max_colls=2, max_comms=1, slice_elems=4,
                     conn_depth=2, heap_elems=512)
    rt = OcclRuntime(cfg)
    cm = rt.communicator([0, 1])
    ar = rt.register(CollKind.ALL_REDUCE, cm, n_elems=8)
    for it in range(3):
        for r in range(2):
            rt.submit(r, ar, data=(it + 1) * np.ones(8, np.float32))
        rt.drive()
        np.testing.assert_allclose(
            rt.read_output(0, ar), 2 * (it + 1) * np.ones(8), rtol=1e-5)
    assert int(rt.stats()["completed"].max()) == 3


def test_fig9_observability():
    """Per-collective context-switch counts and queue lengths at fetch
    (the paper's Fig. 9 instrumentation) are exposed."""
    cfg = OcclConfig(n_ranks=4, max_colls=8, max_comms=1, slice_elems=4,
                     conn_depth=2, heap_elems=1 << 13)
    rt = OcclRuntime(cfg)
    cm = rt.communicator(list(range(4)))
    ids = [rt.register(CollKind.ALL_REDUCE, cm, n_elems=16)
           for _ in range(4)]
    rng = np.random.RandomState(0)
    for r in range(4):
        order = rng.permutation(4)
        for i in order:
            rt.submit(r, ids[i], data=np.ones(16, np.float32))
    rt.drive()
    st = rt.stats()
    assert st["preempts"].shape == (4, 8)
    assert st["qlen_at_fetch"].max() >= 1
    assert st["slices_moved"].sum() > 0


def test_stickiness_reduces_context_switches():
    """Fig. 9 ablation: with the stickiness scheme ON, adversarial-order
    workloads context-switch no more than with it OFF."""
    def run(stick):
        cfg = OcclConfig(n_ranks=4, max_colls=8, max_comms=1,
                         slice_elems=4, conn_depth=2, heap_elems=1 << 14,
                         stickiness=stick)
        rt = OcclRuntime(cfg)
        cm = rt.communicator(list(range(4)))
        ids = [rt.register(CollKind.ALL_REDUCE, cm, n_elems=64)
               for _ in range(6)]
        rng = np.random.RandomState(7)
        for r in range(4):
            for i in rng.permutation(6):
                rt.submit(r, ids[i], data=np.ones(64, np.float32))
        rt.drive()
        st = rt.stats()
        return int(st["preempts"].sum()), int(st["supersteps"].max())

    sw_on, steps_on = run(True)
    sw_off, steps_off = run(False)
    assert sw_on <= sw_off + 2            # not worse (usually far better)
