"""Step-level straggler detection.

Collective-layer straggler tolerance is intrinsic to OCCL (bounded
supersteps + voluntary quit: a slow rank only delays its own collectives,
which get preempted rather than wedging peers).  This module adds the
fleet-level detector with THREE signals feeding one exclusion list:

* wall-clock: per-rank step-time EWMAs (``observe``) flag ranks whose
  times exceed ``threshold`` x the fleet median;
* collective latency: ``observe_collective_stats`` ingests the runtime's
  per-rank ready-to-complete superstep counters (``rtc_latency`` /
  ``rtc_events`` from ``OcclRuntime.stats()``) — a rank whose mean RTC
  latency EWMA exceeds ``threshold`` x the median is dragging the fabric
  even when its host-side step times look normal, and a rank whose event
  counter stops advancing while the fleet's median does is wedged;
* explicit suspicion: ``mark_suspect`` pins a rank (the hang-diagnosis
  path — ``recorder.diagnose`` names the holder of a stalled chain and
  the controller marks it here before evicting).

``healthy_ranks()`` is the controller-facing output: every rank not
flagged by any signal; ``fabric.ft.ReliabilityController`` drives
``OcclRuntime.evict()`` from it.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class StragglerDetector:
    n_ranks: int
    alpha: float = 0.3          # EWMA factor
    threshold: float = 2.0      # x median -> straggler

    def __post_init__(self):
        self.ewma = np.zeros(self.n_ranks)
        self.seen = np.zeros(self.n_ranks, dtype=bool)
        # Collective-latency channel (superstep units, separate EWMA —
        # never mixed with the wall-clock seconds channel above).
        self.coll_ewma = np.zeros(self.n_ranks)
        self.coll_seen = np.zeros(self.n_ranks, dtype=bool)
        self.suspect = np.zeros(self.n_ranks, dtype=bool)
        # Cumulative-counter snapshots (stats() counters are monotonic;
        # deltas between observe calls are the per-window signal).
        self._last_lat = np.zeros(self.n_ranks)
        self._last_ev = np.zeros(self.n_ranks)

    def observe(self, rank: int, step_time_s: float):
        if not self.seen[rank]:
            self.ewma[rank] = step_time_s
            self.seen[rank] = True
        else:
            self.ewma[rank] = (self.alpha * step_time_s
                               + (1 - self.alpha) * self.ewma[rank])

    def observe_collective_stats(self, stats: dict):
        """Ingest ``OcclRuntime.stats()``: per-rank mean ready-to-complete
        latency over the window since the previous call feeds the
        collective EWMA; a rank completing NOTHING while the fleet's
        median completion count advances is marked suspect (wedged)."""
        lat = np.asarray(stats["rtc_latency"], dtype=float).sum(axis=1)
        ev = np.asarray(stats["rtc_events"], dtype=float).sum(axis=1)
        n = min(self.n_ranks, lat.shape[0])
        d_lat = lat[:n] - self._last_lat[:n]
        d_ev = ev[:n] - self._last_ev[:n]
        self._last_lat[:n] = lat[:n]
        self._last_ev[:n] = ev[:n]
        for r in range(n):
            if d_ev[r] > 0:
                mean = d_lat[r] / d_ev[r]
                if not self.coll_seen[r]:
                    self.coll_ewma[r] = mean
                    self.coll_seen[r] = True
                else:
                    self.coll_ewma[r] = (self.alpha * mean
                                         + (1 - self.alpha)
                                         * self.coll_ewma[r])
        if float(np.median(d_ev[:n])) > 0:
            for r in range(n):
                if d_ev[r] == 0:
                    self.suspect[r] = True

    def mark_suspect(self, rank: int):
        """Pin a rank as unhealthy regardless of its timing EWMAs — the
        hang-diagnosis path (``recorder.diagnose`` named it as holding a
        stalled chain)."""
        self.suspect[rank] = True

    def _over_median(self, ewma: np.ndarray, seen: np.ndarray) -> list[int]:
        if not seen.any():
            return []
        med = float(np.median(ewma[seen]))
        if med <= 0:
            return []
        return [r for r in range(self.n_ranks)
                if seen[r] and ewma[r] > self.threshold * med]

    def stragglers(self) -> list[int]:
        """Ranks flagged by ANY signal: wall-clock EWMA, collective RTC
        latency EWMA, or explicit suspicion."""
        bad = set(self._over_median(self.ewma, self.seen))
        bad |= set(self._over_median(self.coll_ewma, self.coll_seen))
        bad |= {r for r in range(self.n_ranks) if self.suspect[r]}
        return sorted(bad)

    def healthy_ranks(self) -> list[int]:
        bad = set(self.stragglers())
        return [r for r in range(self.n_ranks) if r not in bad]
