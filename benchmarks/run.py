"""Benchmark suite entrypoint: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (common.row).
  Fig. 5  -> bench_overheads       Fig. 6/7 -> bench_collectives
  Sec 5.2 -> bench_deadlock        Fig. 8/10 -> bench_training
  Fig. 9  -> bench_gang            Roofline  -> roofline (dry-run JSON)

``--quick`` runs a CI-sized smoke (small sizes, 1 iter) that still
rewrites BENCH_collectives.json — the burst sweep, the adversarial
contention sweep, the staging record, the mesh fast-path record and the
training overlap record — so the perf record stays reproducible from a
cold checkout.  Both modes end
with ``bench_collectives.validate_record()``: a stale or partial record
(e.g. a missing ``contention`` section) fails the run loudly instead of
silently passing; section writers replace the file atomically, so a
partial record can never be produced by an interrupted run.
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))


def main(quick: bool = False) -> None:
    print("name,us_per_call,derived")
    import bench_collectives
    if quick:
        bench_collectives.run(sizes=(64,), iters=1)
        bench_collectives.run_burst_sweep(bursts=(1, 8), n=8192, iters=1)
        # Full-size contention sweep even in --quick: the check_gates.py
        # B8 <= 0.5x B1 threshold is calibrated against the n=2048 record
        # (~3x fewer supersteps); the n=1024 smoke sits at ~0.49 — a 2%
        # margin any benign schedule shift would trip.
        bench_collectives.run_contention_sweep(bursts=(1, 8))
        # Staging engine vs the pre-PR bulk/scalar paths at the headline
        # 8-rank / 16k-elem point (CI smoke keeps the full workload: the
        # speedup is the acceptance-tracked number).
        bench_collectives.run_staging_bench(iters=10)
        bench_collectives.run_mesh_bench()
        # Composite layer: flat ring vs two-level chain at R=16 — the
        # full-size point (the hierarchy gate compares supersteps, which
        # are size-stable, so --quick keeps the acceptance workload).
        # iters=3 even in --quick: the skew gate compares WALL-CLOCK, and
        # best-of-1 timings jitter by ~20% — enough to flip near-ties.
        bench_collectives.run_hierarchy_bench(iters=3)
        # Algorithm zoo + cost-model calibration: the per-algorithm sweep
        # at the two crossover-straddling sizes, then the α-β-γ fit +
        # auto-pick record (check_gates asserts auto matches the measured
        # winners).  Full-size points even in --quick: the gates compare
        # measured winners, and smaller payloads move the crossover.
        # iters=3: the pick-vs-best wall tolerance is 1.15x, within
        # single-shot dispatch noise at the small payload.
        bench_collectives.run_algo_sweep(iters=3)
        # All-to-all: flat relay ring vs two-level chain at R=16, plus
        # the adversarial a2a x all-reduce contention scenario — the
        # alltoall supersteps gate compares structural counts, so the
        # full-size point stays in --quick too.
        bench_collectives.run_alltoall_bench(iters=3)
        import calibrate
        calibrate.main()
        # Training overlap record (tick contract): the dense grad-sync
        # and MoE barrier-vs-overlap points are REQUIRED sections — the
        # exposed-superstep gates compare structural counts, so the
        # full-size workload stays in --quick (iters only trims the
        # wall-clock side channel).
        import bench_training
        bench_training.run_training_bench(iters=1)
        # Reliability record: evict-vs-fresh supersteps are structural
        # (same replayed schedule), and the recorder-overhead point uses
        # best-of-N wall timing, so the CI smoke keeps the acceptance
        # workload and only trims iters.
        import bench_reliability
        bench_reliability.run_reliability_bench(iters=5)
        # Serving QoS replay: the p99 gate compares structural superstep
        # percentiles on a deterministic trace, so the CI smoke runs the
        # full acceptance workload (a few thousand 1-superstep ticks).
        import bench_serving
        bench_serving.run_serving_bench()
        # Fail LOUDLY on a stale/partial record: every section the gates
        # consume must have been (re)written by THIS run — a missing
        # ``contention`` key in a stale BENCH_collectives.json used to
        # slip through as a silent no-op.
        bench_collectives.validate_record()
        return
    import bench_overheads
    bench_overheads.run(sizes=(64, 1024, 4096))
    bench_collectives.run(sizes=(64, 4096), iters=2)
    # Machine-readable perf trajectory: supersteps/sec, slices/sec and
    # per-collective latency at burst_slices in {1, 4, 8}, plus the
    # adversarial contention stall/preempt record, written to
    # BENCH_collectives.json at the repo root.
    bench_collectives.run_burst_sweep(iters=2)
    bench_collectives.run_contention_sweep()
    bench_collectives.run_staging_bench(iters=20)
    bench_collectives.run_mesh_bench()
    bench_collectives.run_hierarchy_bench()
    bench_collectives.run_algo_sweep()
    bench_collectives.run_alltoall_bench()
    import calibrate
    calibrate.main()
    import bench_training
    bench_training.run_training_bench()
    import bench_reliability
    bench_reliability.run_reliability_bench()
    import bench_serving
    bench_serving.run_serving_bench()
    bench_collectives.validate_record()
    import bench_deadlock
    bench_deadlock.run(iters=2)
    bench_deadlock.run_a2a_chained(iters=2)
    import bench_gang
    bench_gang.run()
    bench_training.run()
    # roofline table (from cached dry-run artifacts, if present)
    import roofline
    rows = roofline.load()
    for d in rows:
        t = roofline.terms(d)
        print(f"roofline/{d['arch']}_{d['cell']},"
              f"{t['step_s']*1e6:.1f},"
              f"dom={t['dominant']};mfu={t['mfu']*100:.1f}%")


if __name__ == '__main__':
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: small sizes, 1 iteration per point")
    main(quick=ap.parse_args().quick)
