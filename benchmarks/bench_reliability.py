"""Reliability record: eviction efficiency + flight-recorder overhead.

Two sub-records under ``record["reliability"]`` (BENCH_collectives.json),
both gated by benchmarks/check_gates.py:

* **evict** — the ISSUE acceptance scenario: an R=8 grad-sync-shaped
  round wedges because one rank dies mid-step; ``runtime.evict(dead)``
  drains, rebuilds for R-1 and replays the survivors' staged
  submissions.  The record compares the post-evict cumulative
  supersteps against a FRESH R-1 runtime driving the identical
  survivor workload — eviction must complete the round in **no more
  supersteps** than the fresh runtime (the replay is the same schedule,
  so parity is the expected number; more means the rebuild is leaking
  work), and the outputs must be **bit-identical** (same op order ->
  same floats).
* **recorder** — flight-recorder overhead on the burst-sweep workload
  (bench_collectives.run_burst_sweep's shape): supersteps/sec with
  ``flight_recorder=True`` vs ``False``; the gate bounds
  ``overhead_frac`` at 5%.  Best-of-N wall timing on both sides — the
  recorder's cost is a handful of in-jit scatter ops per superstep, and
  min-of-N is the noise-robust estimator for a fixed workload.
"""
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

import bench_collectives as bc
from common import row
from repro.core import CollKind, OcclConfig, OcclRuntime

BENCH_JSON = bc.BENCH_JSON


# ---------------------------------------------------------------------------
# evict: shrink-vs-fresh supersteps + bit-equality
# ---------------------------------------------------------------------------
def _grad_round(R, C, n):
    cfg = OcclConfig(n_ranks=R, max_colls=C + 2, max_comms=1,
                     slice_elems=64, conn_depth=8,
                     heap_elems=1 << 17, superstep_budget=1 << 15)
    rt = OcclRuntime(cfg)
    comm = rt.communicator(range(R))
    hs = [rt.register(CollKind.ALL_REDUCE, comm, n_elems=n)
          for _ in range(C)]
    return rt, hs


def run_evict_bench(R=8, C=4, n=4096, dead=5):
    # Integer-valued f32 payloads keep the ring reduction exact, so the
    # bit-equality comparison is meaningful rather than vacuously tight.
    rng = np.random.RandomState(0)
    payload = {(r, c): rng.randint(0, 1 << 10, n).astype(np.float32)
               for r in range(R) for c in range(C)}

    rt, hs = _grad_round(R, C, n)
    # One healthy round first: the eviction happens MID-TRAINING, on a
    # runtime with history, not on a fresh build.
    for c, h in enumerate(hs):
        for r in range(R):
            h.submit(r, prio=c, data=payload[(r, c)])
    rt.drive()
    # The wedged round: rank `dead` never submits, every survivor's
    # submission is in flight when the eviction fires.
    for c, h in enumerate(hs):
        for r in range(R):
            if r != dead:
                h.submit(r, prio=c, data=payload[(r, c)])
    report = rt.evict(dead)
    evicted_steps = int(np.asarray(rt.stats()["supersteps"]).max())

    survivors = [r for r in range(R) if r != dead]
    fresh, fhs = _grad_round(R - 1, C, n)
    for c, h in enumerate(fhs):
        for new_r, old in enumerate(survivors):
            h.submit(new_r, prio=c, data=payload[(old, c)])
    fresh.drive()
    fresh_steps = int(np.asarray(fresh.stats()["supersteps"]).max())

    bit_equal = all(
        np.array_equal(np.asarray(hs[c].read(new_r)),
                       np.asarray(fhs[c].read(new_r)))
        for c in range(C) for new_r in range(R - 1))

    rec = {
        "config": {"n_ranks": R, "n_colls": C, "n_elems": n,
                   "evicted_rank": dead},
        "evicted_supersteps": evicted_steps,
        "fresh_supersteps": fresh_steps,
        "bit_equal": bool(bit_equal),
        "drain_launches": int(report["drain_launches"]),
        "replayed": int(report["replayed"]),
        "dropped": int(report["dropped"]),
    }
    row(f"reliability/evict_R{R}to{R - 1}", 0.0,
        f"evicted={evicted_steps};fresh={fresh_steps};"
        f"bit_equal={bit_equal}")
    return rec


# ---------------------------------------------------------------------------
# recorder: burst-sweep overhead on/off
# ---------------------------------------------------------------------------
def _burst_sps(flight_recorder, R=8, n=8192, burst=8, iters=10):
    """supersteps/sec on the burst-sweep all-reduce workload (same shape
    as bench_collectives.run_burst_sweep) with the recorder toggled."""
    cfg = OcclConfig(n_ranks=R, max_colls=2, max_comms=1,
                     slice_elems=bc.BURST_SLICE_ELEMS, conn_depth=32,
                     burst_slices=burst, heap_elems=1 << 18,
                     superstep_budget=1 << 15,
                     flight_recorder=flight_recorder)
    rt = OcclRuntime(cfg)
    cid = rt.register(CollKind.ALL_REDUCE, rt.communicator(range(R)),
                      n_elems=n)
    data = np.random.RandomState(0).rand(n).astype(np.float32)
    for r in range(R):
        rt.write_input(r, cid, data)

    def once():
        for r in range(R):
            rt.submit(r, cid)
        rt.drive()

    once()                                   # warmup (jit compile)
    s0 = rt.stats()
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        once()
        best = min(best, time.perf_counter() - t0)
    s1 = rt.stats()
    steps = (int(np.asarray(s1["supersteps"]).max())
             - int(np.asarray(s0["supersteps"]).max())) / iters
    return steps / best, steps


def run_recorder_bench(R=8, n=8192, burst=8, iters=10):
    sps_on, steps = _burst_sps(True, R=R, n=n, burst=burst, iters=iters)
    sps_off, _ = _burst_sps(False, R=R, n=n, burst=burst, iters=iters)
    overhead = max(0.0, (sps_off - sps_on) / sps_off)
    rec = {
        "config": {"n_ranks": R, "n_elems": n, "burst_slices": burst,
                   "iters": iters, "supersteps_per_iter": steps},
        "supersteps_per_sec_on": sps_on,
        "supersteps_per_sec_off": sps_off,
        "overhead_frac": overhead,
    }
    row(f"reliability/recorder_B{burst}", 0.0,
        f"sps_on={sps_on:.0f};sps_off={sps_off:.0f};"
        f"overhead={overhead * 100:.1f}%")
    return rec


def run_reliability_bench(iters=10, out_path=BENCH_JSON):
    record = {"reliability": {
        "evict": run_evict_bench(),
        "recorder": run_recorder_bench(iters=iters),
    }}
    doc = bc._read_record(out_path)
    doc.update(record)
    bc._write_record(out_path, doc)
    return record


if __name__ == "__main__":
    run_reliability_bench()
