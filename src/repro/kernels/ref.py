"""Pure-jnp oracles for the Pallas kernels (the ``ref.py`` contract).

These define the semantics; kernels must match them to within dtype
tolerance across the shape/dtype sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import jax.numpy as jnp


def _combine(op: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """op: 0=sum 1=max 2=min 3=prod.  bf16 inputs accumulate in f32."""
    at = a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a
    bt = b.astype(jnp.float32) if b.dtype == jnp.bfloat16 else b
    out = jnp.where(
        op == 0, at + bt,
        jnp.where(op == 1, jnp.maximum(at, bt),
                  jnp.where(op == 2, jnp.minimum(at, bt), at * bt)))
    return out.astype(a.dtype)


def fused_primitive_ref(payload: jnp.ndarray, local: jnp.ndarray,
                        flags: jnp.ndarray) -> jnp.ndarray:
    """Fused primitive value (paper Sec. 2.3 actions).

    payload, local: [B, S];  flags: [B, 4] i32 = (recv, reduce, reads_in, op).
    value = op(payload, local)         if reduce
          = payload                    elif recv
          = local                      elif reads_in
          = 0                          otherwise
    """
    recv = flags[:, 0:1] > 0
    reduce = flags[:, 1:2] > 0
    reads = flags[:, 2:3] > 0
    op = flags[:, 3:4]
    reduced = _combine(op, payload, local)
    return jnp.where(
        reduce, reduced,
        jnp.where(recv, payload,
                  jnp.where(reads, local, jnp.zeros_like(local))))


def chunk_combine_ref(a: jnp.ndarray, b: jnp.ndarray, op: int) -> jnp.ndarray:
    """Bulk recv-reduce over a whole chunk: elementwise combine of flat
    arrays with f32 accumulation for bf16 (the ring reduce workhorse)."""
    return _combine(jnp.int32(op), a, b)
