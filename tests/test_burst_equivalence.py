"""Slice-burst execution is a pure schedule transformation: outputs at
``burst_slices > 1`` are BIT-IDENTICAL to the seed single-slice semantics
(``burst_slices = 1``) for every collective kind, group size and order
policy, including the adversarial-order workloads that deadlock a
statically-sequenced baseline.

Each slice's value is the same pure function of the same operands in the
same order regardless of how many slices ride one superstep, so equality
is exact (assert_array_equal), not approximate.
"""
import numpy as np
import pytest

from repro.core import CollKind, OcclConfig, OcclRuntime, OrderPolicy

# These configs use shallow connectors ON PURPOSE (the credit-return
# equilibrium is part of the semantics under test, not a perf target).
pytestmark = pytest.mark.filterwarnings(
    "ignore::repro.core.runtime.ConnDepthWarning")

KINDS = [CollKind.ALL_REDUCE, CollKind.ALL_GATHER, CollKind.REDUCE_SCATTER,
         CollKind.BROADCAST, CollKind.REDUCE]
GROUP_SIZES = [1, 2, 4]
R = 4


def _run_all_kinds(policy: OrderPolicy, burst: int):
    """One runtime hosting every (kind, group_size) pair; adversarial
    per-rank submission orders.  Returns {(kind, gs): {rank: output}}."""
    cfg = OcclConfig(
        n_ranks=R, max_colls=16, max_comms=len(GROUP_SIZES), slice_elems=4,
        conn_depth=5, heap_elems=1 << 14, order_policy=policy,
        burst_slices=burst, superstep_budget=1 << 14)
    rt = OcclRuntime(cfg)
    comms = {gs: rt.communicator(list(range(gs))) for gs in GROUP_SIZES}
    rng = np.random.RandomState(7)
    ids, inputs = {}, {}
    for gs in GROUP_SIZES:
        for kind in KINDS:
            n_elems = int(rng.randint(1, 40))
            cid = rt.register(kind, comms[gs], n_elems=n_elems, root=0)
            ids[(kind, gs)] = cid
            if kind == CollKind.ALL_GATHER:
                chunk = -(-n_elems // gs)
                inputs[cid] = [rng.randn(chunk).astype(np.float32)
                               for _ in range(gs)]
            else:
                inputs[cid] = [rng.randn(n_elems).astype(np.float32)
                               for _ in range(gs)]
    order = list(ids.values())
    for r in range(R):
        rng_r = np.random.RandomState(100 + r)
        for cid in [order[i] for i in rng_r.permutation(len(order))]:
            kind, gs = next(k for k, v in ids.items() if v == cid)
            if r >= gs:
                continue
            if kind == CollKind.BROADCAST:
                if r == 0:
                    rt.write_input(r, cid, inputs[cid][0])
            else:
                rt.write_input(r, cid, inputs[cid][r])
            rt.submit(r, cid)
    rt.drive(max_launches=128)
    return {
        key: {r: rt.read_output(r, cid) for r in range(key[1])}
        for key, cid in ids.items()
    }


@pytest.mark.parametrize("policy", [OrderPolicy.FIFO, OrderPolicy.PRIORITY])
@pytest.mark.parametrize("burst", [4, 8])
def test_burst_outputs_bit_identical_to_single_slice(policy, burst):
    base = _run_all_kinds(policy, burst=1)
    got = _run_all_kinds(policy, burst=burst)
    for key in base:
        for r in base[key]:
            np.testing.assert_array_equal(
                base[key][r], got[key][r],
                err_msg=f"kind={key[0].name} gs={key[1]} rank={r} "
                        f"policy={policy.name} burst={burst}")


def test_pallas_burst_path_end_to_end():
    """use_pallas=True routes the whole [L*B, SLICE] superstep burst
    through one fused_primitive_batch call; outputs must match the
    jnp reference path exactly (both compute in f32)."""
    outs = {}
    for use_pallas in (False, True):
        cfg = OcclConfig(n_ranks=2, max_colls=4, max_comms=1, slice_elems=8,
                         conn_depth=6, burst_slices=4, heap_elems=1 << 13,
                         use_pallas=use_pallas, superstep_budget=1 << 13)
        rt = OcclRuntime(cfg)
        comm = rt.communicator([0, 1])
        cid = rt.register(CollKind.ALL_REDUCE, comm, n_elems=96)
        rng = np.random.RandomState(11)
        xs = [rng.randn(96).astype(np.float32) for _ in range(2)]
        for r in range(2):
            rt.submit(r, cid, data=xs[r])
        rt.drive()
        outs[use_pallas] = [rt.read_output(r, cid) for r in range(2)]
        for r in range(2):
            np.testing.assert_allclose(outs[use_pallas][r], sum(xs),
                                       rtol=1e-4)
    for r in range(2):
        np.testing.assert_array_equal(outs[False][r], outs[True][r])


def _run_adversarial(burst: int):
    """The Sec. 5.2 headline workload (examples/adversarial_orders.py):
    8 ranks submit 8 all-reduces in pairwise-different orders."""
    Radv, C = 8, 8
    rng = np.random.RandomState(42)
    orders = {r: list(rng.permutation(C)) for r in range(Radv)}
    cfg = OcclConfig(n_ranks=Radv, max_colls=C, max_comms=1, slice_elems=8,
                     conn_depth=4, burst_slices=burst, heap_elems=1 << 15,
                     superstep_budget=1 << 15)
    rt = OcclRuntime(cfg)
    world = rt.communicator(list(range(Radv)))
    sizes = [32 << (i % 3) for i in range(C)]
    ids = [rt.register(CollKind.ALL_REDUCE, world, n_elems=s) for s in sizes]
    data = {i: [rng.randn(sizes[i]).astype(np.float32) for _ in range(Radv)]
            for i in range(C)}
    for r in range(Radv):
        for slot in orders[r]:
            rt.submit(r, ids[slot], data=data[slot][r])
    rt.drive(max_launches=128)          # convergence == deadlock freedom
    return {i: {r: rt.read_output(r, ids[i]) for r in range(Radv)}
            for i in range(C)}


def test_burst_adversarial_orders_bit_identical():
    base = _run_adversarial(burst=1)
    got = _run_adversarial(burst=4)
    for i in base:
        for r in base[i]:
            np.testing.assert_array_equal(base[i][r], got[i][r],
                                          err_msg=f"coll={i} rank={r}")
