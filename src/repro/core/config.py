"""Configuration for the OCCL deadlock-free collective runtime.

All sizes are static (compiled into the daemon program), mirroring the
paper's registration-time preparation of collective contexts (Sec. 3.1.1).
"""
from __future__ import annotations

import dataclasses
import enum


class OrderPolicy(enum.IntEnum):
    """Order-adjusting policy of the stickiness scheme (paper Sec. 3.2)."""

    FIFO = 0      # empty the task queue ASAP; lazy SQ fetch; new at back
    PRIORITY = 1  # user priority first; eager SQ fetch; high-prio at front


class ReduceOp(enum.IntEnum):
    SUM = 0
    MAX = 1
    MIN = 2
    PROD = 3


@dataclasses.dataclass(frozen=True)
class OcclConfig:
    """Static configuration of one daemon instance.

    The daemon is compiled once per config (the analogue of launching the
    persistent daemon kernel with the max grid/block size, paper Sec. 4).
    """

    # --- geometry -------------------------------------------------------
    n_ranks: int = 8                # devices participating in the fabric
    max_colls: int = 16             # registered-collective slots (C)
    max_comms: int = 4              # communicator lanes (L); CUDA-block analogue
    slice_elems: int = 64           # elements per slice (preemption granule)
    conn_depth: int = 4             # ring-buffer slots per connector (K)
    burst_slices: int = 1           # max slices one lane moves per superstep
                                    # (B); the burst is credit-gated so the
                                    # deadlock-freedom capacity argument of
                                    # derive_slicing is unchanged, and a
                                    # collective stays preemptible between
                                    # bursts (slice granularity).  For
                                    # sustained B-slice throughput size
                                    # conn_depth >= ~3B (credit round trip;
                                    # see scheduler.py docstring)
    heap_elems: int = 1 << 16       # per-rank data heap (send/recv buffers)

    # --- SQ / CQ --------------------------------------------------------
    sq_len: int = 64                # submission-queue slots per rank
    cq_len: int = 64                # completion-queue slots per rank

    # --- scheduling / stickiness (paper Sec. 3.2) -----------------------
    order_policy: OrderPolicy = OrderPolicy.FIFO
    stickiness: bool = True         # master switch (Fig. 9 ablation)
    priority_preempts: bool = False  # P3/PACE-style: a strictly-higher-
                                    # priority queued collective preempts the
                                    # current one (paper Sec. 3.2 / Sec. 6:
                                    # a spin-threshold adjusting policy)
    demand_steering: bool = True    # beyond-paper gang policy: prefer
                                    # collectives whose recv connector has
                                    # data waiting (local evidence that ring
                                    # peers are executing them) — same
                                    # decentralized-information constraint
                                    # as the paper's spin-threshold scheme
                                    # but converges faster under adversarial
                                    # order skew (benchmarks/bench_gang.py)
    spin_base: int = 16             # initial threshold of queue-front coll
    spin_decr: int = 4              # threshold decrement per queue position
    spin_boost: int = 8             # boost to successors on primitive success
    spin_min: int = 1
    spin_max: int = 256

    # --- daemon lifecycle (paper Sec. 3.1.3) ----------------------------
    quit_threshold: int = 64        # voluntary quit after this many
                                    # no-progress supersteps
    superstep_budget: int = 4096    # hard bound per daemon launch

    # --- numerics / kernels ---------------------------------------------
    dtype: str = "float32"          # heap / wire dtype
    use_pallas: bool = False        # route slice math through Pallas kernels

    def __post_init__(self):
        assert self.n_ranks >= 1
        assert self.max_comms >= 1
        assert self.conn_depth >= 1
        assert self.slice_elems >= 1
        assert self.burst_slices >= 1
        assert self.spin_base >= self.spin_min
