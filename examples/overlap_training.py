"""Compute-communication overlap inside the jitted training step.

Two timelines for the SAME dense DP training step (backward + bucketized
gradient all-reduce), built on the tick contract (core/daemon.py):

1. **Barrier** — the backward runs to completion, then every gradient
   bucket's all-reduce supersteps execute in one exposed drain (the
   ``ticks_per_boundary=0`` degenerate of ``make_overlap_grads_step``,
   structurally the classic "backward, then sync" step).
2. **Overlapped** — ``custom_vjp`` boundaries submit each bucket the
   moment its cotangents materialize MID-BACKWARD and spend a bounded
   ``tick(state, k)`` budget advancing the daemon; those supersteps hide
   behind the remaining backward compute, and only the drain tail stays
   exposed on the critical path.

Both are ONE jitted XLA program; both produce bit-comparable gradients
(the daemon schedule is identical work, reordered against compute).  The
demo prints the superstep ledger — total / hidden / exposed — and an
ASCII timeline of where communication sat, then repeats the story for
the stream-sharded MoE layer (expert FFN starting on arrived dispatch
shards while later shard tails are still in flight).

    PYTHONPATH=src python examples/overlap_training.py
"""
import dataclasses
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeCell
from repro.data.pipeline import SyntheticPipeline
from repro.models import moe as MOE
from repro.train.occl_moe import OcclMoE, ep_forward_ref
from repro.train.occl_sync import OcclGradSync, static_all_reduce
from repro.train.state import init_state
from repro.train.step import make_grads_step, make_overlap_grads_step


def ledger(sync_or_moe, before, label):
    after = sync_or_moe.stats()
    total = int(np.max(after["supersteps"] - before["supersteps"]))
    hidden = int(np.max(after["overlap_supersteps"]
                        - before["overlap_supersteps"]))
    exposed = int(np.max(after["barrier_supersteps"]
                         - before["barrier_supersteps"]))
    bar = lambda n, ch: ch * max(0, round(40 * n / max(total, 1)))
    print(f"  {label:<10} supersteps={total:<5d} hidden={hidden:<5d} "
          f"exposed={exposed}")
    print(f"    compute  |{'#' * 40}|")
    print(f"    comm     |{bar(hidden, '~')}{bar(exposed, 'X')}|   "
          "(~ hidden behind compute, X exposed on the critical path)")
    return after


# --- act 1: dense grad sync under bandwidth-skew lanes -----------------
print("=== dense DP grad sync: barrier vs overlapped backward ===")
dp = 2
cfg = get_config("qwen3-0.6b").reduced()
cell = ShapeCell("t", 16, dp, "train")
states = [init_state(cfg) for _ in range(dp)]
batches = [SyntheticPipeline(cfg, cell, shard_id=r, n_shards=dp).batch_at(0)
           for r in range(dp)]
gfn = jax.jit(make_grads_step(cfg))
_, gshape = jax.eval_shape(gfn, states[0], batches[0])
sync = OcclGradSync(gshape, dp, bucket_elems=16384, slice_elems=512,
                    burst_slices=8, bandwidth_groups=2,
                    intra_burst_cap=8, inter_burst_cap=2)
print(f"{len(sync.buckets)} gradient buckets over {dp} ranks, "
      "skewed lanes (inter cap 2/8)")

params_list = [s.params for s in states]
snap = sync.stats()
for label, k in (("barrier", 0), ("overlapped", 8)):
    step = jax.jit(make_overlap_grads_step(cfg, sync, ticks_per_boundary=k))
    st, losses, grads = step(sync.occl.state, params_list, batches)
    sync.occl.adopt_state(st)
    snap = ledger(sync, snap, label)

# gradients are exact either way
want = static_all_reduce([gfn(states[r], batches[r])[1] for r in range(dp)])
for a, b in zip(jax.tree_util.tree_leaves(grads[0]),
                jax.tree_util.tree_leaves(want[0])):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=1e-4, atol=1e-6)
print("  gradients match the static all-reduce baseline\n")

# --- act 2: MoE dispatch-tail overlap ----------------------------------
print("=== expert-parallel MoE: full-layer barrier vs stream shards ===")
mcfg = dataclasses.replace(get_config("deepseek-moe-16b").reduced(),
                           capacity_factor=8.0)
params = MOE.init_moe_block(jax.random.PRNGKey(0), "t", mcfg, jnp.float32)
rng = np.random.RandomState(7)
R, Tl = 4, 8
cap = Tl * mcfg.top_k
xs = [jnp.asarray(rng.randn(Tl, mcfg.d_model) * 0.5, jnp.float32)
      for _ in range(R)]
moe = OcclMoE(mcfg, R, Tl, cap=cap, n_streams=4, overlap_ticks=8)
print(f"{mcfg.n_experts} experts over {R} ranks, capacity {cap} split "
      f"into {moe.n_streams} dispatch/combine streams")

snap = moe.stats()
ys_b = moe.forward(params, xs)            # host-driven, all-barrier
snap = ledger(moe, snap, "barrier")
ys_o = moe.forward_overlapped(params, xs)  # one jitted program
snap = ledger(moe, snap, "overlapped")

ref = ep_forward_ref(mcfg, params, xs, cap=cap)
for r in range(R):
    np.testing.assert_array_equal(np.asarray(ys_o[r]), np.asarray(ref[r]))
    np.testing.assert_array_equal(np.asarray(ys_b[r]), np.asarray(ref[r]))
print("  both paths BIT-IDENTICAL to the expert-parallel reference")
