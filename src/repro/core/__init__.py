"""OCCL core: the deadlock-free collective execution framework (DFCE).

The paper's primary contribution, adapted TPU-natively: collectives are
per-rank primitive sequences over connector ring buffers, executed by a
long-running daemon loop with decentralized preemption (spin thresholds)
and stickiness-driven emergent gang-scheduling.  See DESIGN.md.
"""
from .algos import (CompositePlan, SubCollective, default_hierarchy,
                    plan_two_level, select_algo)
from .config import OcclConfig, OrderPolicy, ReduceOp
from .primitives import CollKind, CollectiveSpec, Communicator, Prim
from .runtime import ConnDepthWarning, DeadlockTimeout, OcclRuntime
from .staging import StagingEngine
from .deadlock import run_static_order, consistent_order_exists

__all__ = [
    "OcclConfig", "OrderPolicy", "ReduceOp",
    "CollKind", "CollectiveSpec", "Communicator", "Prim",
    "OcclRuntime", "DeadlockTimeout", "ConnDepthWarning", "StagingEngine",
    "run_static_order", "consistent_order_exists",
    "CompositePlan", "SubCollective", "default_hierarchy",
    "plan_two_level", "select_algo",
]
