"""Paper Fig. 6/7: latency & algorithm bandwidth of the 5 collectives,
OCCL vs the statically-sequenced baseline — plus the slice-burst sweep
(``run_burst_sweep``) that records supersteps/sec, slices/sec and
per-collective latency for burst_slices in {1, 4, 8} into
BENCH_collectives.json (the repo's perf trajectory record).

Two metrics per (collective, size):
  * wall-clock per iteration on this host (CPU; both systems pay XLA
    dispatch, so the RELATIVE gap is the signal — paper Fig. 6);
  * protocol supersteps vs the pipeline-optimal minimum (the structural
    analogue of "core execution time", paper Fig. 7 — OCCL's long-running
    daemon reaches the minimum once gang convergence kicks in).

The static baseline is the same ring algorithm executed in a consistent
global order with no scheduling layer (direct jnp reduction) — the
"statically sequenced NCCL" of Sec. 5.
"""
import json
import os
import pathlib
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp

from common import row, timeit
from repro.core import CollKind, OcclConfig, OcclRuntime

BENCH_JSON = pathlib.Path(__file__).resolve().parents[1] / \
    "BENCH_collectives.json"
BURST_SLICE_ELEMS = 64      # slice width used by the burst sweep configs

KINDS = {
    "all_reduce": CollKind.ALL_REDUCE,
    "all_gather": CollKind.ALL_GATHER,
    "reduce_scatter": CollKind.REDUCE_SCATTER,
    "broadcast": CollKind.BROADCAST,
    "reduce": CollKind.REDUCE,
}


def _static_baseline(kind: CollKind, xs: list[np.ndarray], R: int):
    """Consistent-order direct execution (jit'd once)."""
    stack = jnp.stack([jnp.asarray(x) for x in xs])

    @jax.jit
    def run(stack):
        if kind == CollKind.ALL_REDUCE:
            return jnp.broadcast_to(stack.sum(0), stack.shape)
        if kind == CollKind.ALL_GATHER:
            return jnp.broadcast_to(stack.reshape(-1), (R, stack.size))
        if kind == CollKind.REDUCE_SCATTER:
            s = stack.sum(0)
            return s.reshape(R, -1)
        if kind == CollKind.BROADCAST:
            return jnp.broadcast_to(stack[0], stack.shape)
        return stack.sum(0)

    return run, stack


def run(sizes=(64, 1024, 16384, 262144), R=8, iters=3):
    results = []
    for name, kind in KINDS.items():
        for n in sizes:
            cfg = OcclConfig(n_ranks=R, max_colls=2, max_comms=1,
                             slice_elems=min(4096, max(64, n // 16)),
                             conn_depth=8,
                             heap_elems=max(1 << 13, 8 * n),
                             superstep_budget=1 << 15)
            rt = OcclRuntime(cfg)
            comm = rt.communicator(list(range(R)))
            cid = rt.register(kind, comm, n_elems=n)
            rng = np.random.RandomState(0)
            if kind == CollKind.ALL_GATHER:
                xs = [rng.randn(-(-n // R)).astype(np.float32)
                      for _ in range(R)]
            else:
                xs = [rng.randn(n).astype(np.float32) for _ in range(R)]

            def occl_once():
                for r in range(R):
                    if kind == CollKind.BROADCAST and r != 0:
                        rt.submit(r, cid)
                    else:
                        rt.submit(r, cid, data=xs[r if kind !=
                                  CollKind.BROADCAST else 0])
                rt.drive()

            t_occl = timeit(occl_once, iters=iters, warmup=1)
            st = rt.stats()
            steps_per_iter = int(st["supersteps"].max()) / rt.launches
            spec = rt.specs[cid]
            prims = {CollKind.ALL_REDUCE: 2 * R - 1}.get(kind, R)
            min_steps = (prims * spec.n_slices * spec.n_rounds
                         + 2 * (R - 1))

            static_fn, stack = _static_baseline(kind, xs, R)
            t_static = timeit(lambda: jax.block_until_ready(static_fn(stack)),
                              iters=iters, warmup=1)

            bytes_alg = 4 * n
            results.append((name, n, t_occl, t_static, steps_per_iter,
                            min_steps))
            row(f"collectives/{name}_n{n}", t_occl * 1e6,
                f"static_us={t_static*1e6:.1f};"
                f"steps={steps_per_iter:.0f};proto_min={min_steps};"
                f"algbw_model={bytes_alg/max(steps_per_iter,1):.0f}B/step")
    return results


def _bench_one_kind(kind: CollKind, burst: int, n: int, R: int,
                    conn_depth: int, iters: int) -> dict:
    """Latency/throughput of one collective at one burst width.

    Inputs are pre-written to the heap so the measurement is the daemon
    superstep loop (the optimized hot path), not host-side data staging.
    ``conn_depth`` must cover the burst bandwidth-delay product (~3B for
    the 3-superstep credit round trip) or the ring settles into the
    1-slice/step credit-return equilibrium — see scheduler.py.
    """
    cfg = OcclConfig(n_ranks=R, max_colls=2, max_comms=1,
                     slice_elems=BURST_SLICE_ELEMS,
                     conn_depth=conn_depth, burst_slices=burst,
                     heap_elems=1 << 18, superstep_budget=1 << 15)
    rt = OcclRuntime(cfg)
    comm = rt.communicator(list(range(R)))
    cid = rt.register(kind, comm, n_elems=n)
    rng = np.random.RandomState(0)
    for r in range(R):
        if kind == CollKind.ALL_GATHER:
            data = rng.randn(-(-n // R)).astype(np.float32)
        else:
            data = rng.randn(n).astype(np.float32)
        if kind == CollKind.BROADCAST and r != 0:
            continue
        rt.write_input(r, cid, data)

    def once():
        for r in range(R):
            rt.submit(r, cid)
        rt.drive()

    once()                                   # warmup: compile + converge
    s0 = rt.stats()
    t0 = time.perf_counter()
    for _ in range(iters):
        once()
    dt = (time.perf_counter() - t0) / iters
    s1 = rt.stats()
    slices = (int(s1["slices_moved"].sum())
              - int(s0["slices_moved"].sum())) / iters
    steps = (int(s1["supersteps"].max())
             - int(s0["supersteps"].max())) / iters
    return {
        "latency_s": dt,
        "supersteps": steps,
        "slices": slices,
        "supersteps_per_sec": steps / dt,
        "slices_per_sec": slices / dt,
    }


def run_burst_sweep(bursts=(1, 4, 8), n=65536, R=8, conn_depth=32,
                    iters=3, out_path=BENCH_JSON) -> dict:
    """The PR's perf record: the 5 collectives at each burst width,
    written to BENCH_collectives.json so future PRs can see regressions."""
    record = {
        "config": {"n_ranks": R, "n_elems": n,
                   "slice_elems": BURST_SLICE_ELEMS,
                   "conn_depth": conn_depth, "iters": iters,
                   "backend": "sim"},
        "bursts": {},
    }
    for burst in bursts:
        per_kind = {}
        for name, kind in KINDS.items():
            per_kind[name] = _bench_one_kind(
                kind, burst, n, R, conn_depth, iters)
            row(f"collectives/burst{burst}_{name}",
                per_kind[name]["latency_s"] * 1e6,
                f"slices_per_sec={per_kind[name]['slices_per_sec']:.0f};"
                f"supersteps_per_sec="
                f"{per_kind[name]['supersteps_per_sec']:.0f}")
        total_t = sum(k["latency_s"] for k in per_kind.values())
        total_slices = sum(k["slices"] for k in per_kind.values())
        total_steps = sum(k["supersteps"] for k in per_kind.values())
        record["bursts"][str(burst)] = {
            "per_collective": per_kind,
            "total": {
                "latency_s": total_t,
                "slices_per_sec": total_slices / total_t,
                "supersteps_per_sec": total_steps / total_t,
            },
        }
    b = record["bursts"]
    if "1" in b:
        base = b["1"]["total"]["slices_per_sec"]
        record["speedup_slices_per_sec_vs_burst1"] = {
            k: v["total"]["slices_per_sec"] / base for k, v in b.items()
        }
    # Merge-write: other sections (e.g. ``contention``) survive; the
    # replace is atomic so no reader ever sees a partial record.
    doc = _read_record(out_path)
    doc.update(record)
    _write_record(out_path, doc)
    print(f"# wrote {out_path}")
    return record


def _read_record(out_path: pathlib.Path) -> dict:
    """Existing perf record, or {} when absent.  A PRESENT-but-unparseable
    record fails LOUDLY: every writer replaces its section atomically
    (``_write_record``), so a corrupt file cannot be one of our
    interrupted runs — silently resetting it to {} would hide whatever
    produced it and let a partial record masquerade as a fresh baseline."""
    if not out_path.exists():
        return {}
    try:
        return json.loads(out_path.read_text())
    except ValueError as e:
        raise RuntimeError(
            f"{out_path} exists but is not valid JSON ({e}); bench writers "
            "replace sections atomically, so this was written by something "
            "else — inspect or delete it explicitly") from e


def _write_record(out_path: pathlib.Path, doc: dict) -> None:
    """Atomic section replace: serialize the WHOLE document to a temp file
    in the same directory, then ``os.replace`` it over the record.  A
    reader (or an interrupted run) can never observe a partially-written
    BENCH_collectives.json."""
    payload = json.dumps(doc, indent=2) + "\n"
    fd, tmp = tempfile.mkstemp(dir=str(out_path.parent),
                               prefix=out_path.name + ".", suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(payload)
        os.replace(tmp, out_path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


# Required shape of each section a full bench pass writes; consumed by
# ``validate_record`` (run.py fails loudly on partial/stale records) and
# by benchmarks/check_gates.py in CI.
RECORD_SECTIONS = {
    "bursts": (),                       # legacy top-level burst sweep
    "contention": ("bursts",),
    "staging": ("speedup_vs_legacy", "speedup_vs_legacy_scalar"),
    "mesh": ("ppermutes_per_superstep", "staged_flush"),
    "hierarchy": ("flat", "two_level", "superstep_ratio", "skew"),
    # Per-algorithm sweep (the algorithm zoo) + the auto-selection picks
    # benchmarks/calibrate.py records after fitting the cost model — a
    # record whose "algos" section lacks "auto" is a sweep that was never
    # calibrated, and validation fails it loudly.
    "algos": ("config", "sweep", "auto"),
    # All-to-all: flat relay ring vs the two-level composite at R=16
    # (supersteps gate), plus the adversarial a2a x all-reduce contention
    # scenario.  "auto" is appended by benchmarks/calibrate.py after the
    # fit, same contract as "algos".
    "alltoall": ("config", "flat", "two_level", "superstep_ratio",
                 "contention", "auto"),
    # End-to-end training overlap (the tick contract): dense grad-sync
    # and MoE step records, written by bench_training.run_training_bench
    # — barrier vs overlapped exposed-superstep counts and the modeled
    # tokens/sec the check_gates.py overlap gates compare.
    "training": ("config", "dense", "moe"),
    # Reliability: eviction shrink-vs-fresh supersteps and bit-equality,
    # plus flight-recorder burst-sweep overhead — written by
    # bench_reliability.run_reliability_bench, gated in check_gates.py
    # (evicted <= fresh supersteps; recorder overhead <= 5%).
    "reliability": ("evict", "recorder"),
    # Serving QoS traffic replay (bench_serving.run_serving_bench):
    # decode p50/p99 under adversarial background bursts with priority
    # preemption on vs off, gated in check_gates.py (on-p99 strictly
    # below off-p99; background degrades gracefully, no unbounded
    # starvation).
    "serving": ("config", "preempt_on", "preempt_off", "p99_ratio",
                "background_ratio"),
}


def validate_record(required=tuple(RECORD_SECTIONS),
                    out_path=BENCH_JSON) -> dict:
    """Fail LOUDLY when a required section is absent or partial — a stale
    or interrupted BENCH_collectives.json must not pass as a bench run
    (the pre-PR --quick path silently skipped contention validation when
    the key was missing)."""
    doc = _read_record(out_path)
    problems = []
    for section in required:
        if section not in doc:
            problems.append(f"missing section {section!r}")
            continue
        for key in RECORD_SECTIONS.get(section, ()):
            if key not in doc[section]:
                problems.append(f"section {section!r} lacks {key!r} "
                                "(partial record)")
    if "contention" in required and "contention" in doc:
        for burst, rec in doc["contention"].get("bursts", {}).items():
            for key in ("supersteps", "preempts", "stall_slices"):
                if key not in rec:
                    problems.append(
                        f"contention burst {burst} lacks {key!r}")
    if problems:
        raise RuntimeError(
            f"{out_path} failed validation: " + "; ".join(problems)
            + " — rerun `python benchmarks/run.py` (or --quick)")
    return doc


def _legacy_write_inputs_bulk(rt: OcclRuntime, writes: dict) -> None:
    """The PRE-PR bulk write path, preserved verbatim as the staging
    baseline: mirror the whole [R, H] heap through host memory, Python
    chunk loops per (rank, collective), full-heap re-upload.  Also keeps
    the old BUGS on purpose (pad tails not zeroed, no size assertions) —
    this is the cost model being displaced, not a supported API."""
    heap = np.array(rt._state.heap_in)              # full-heap host mirror
    for (rank, coll_id), data in writes.items():
        spec = rt.specs[coll_id]
        from repro.core.primitives import io_chunked as _ioc
        inc, _ = _ioc(CollKind(spec.kind))
        chunk_pad = spec.n_rounds * spec.n_slices * rt.cfg.slice_elems
        chunk_log = -(-spec.n_elems // spec.group_size)
        data = np.asarray(data).ravel()
        row = heap[rank]
        if inc:
            for k in range(spec.group_size):
                part = data[k * chunk_log:(k + 1) * chunk_log]
                off = spec.in_off + k * chunk_pad
                row[off:off + part.size] = part
        else:
            row[spec.in_off:spec.in_off + data.size] = data
    rt._state = rt._state._replace(
        heap_in=jnp.asarray(heap, rt._state.heap_in.dtype))


def _legacy_read_outputs_bulk(rt: OcclRuntime, reads: list) -> dict:
    """The pre-PR bulk read path: one full-heap device->host mirror plus
    Python un-pad loops (results were views/loop-copies of the mirror)."""
    heap = np.asarray(rt._state.heap_out)
    out = {}
    for rank, coll_id in reads:
        spec = rt.specs[coll_id]
        from repro.core.primitives import io_chunked as _ioc
        _, outc = _ioc(CollKind(spec.kind))
        chunk_pad = spec.n_rounds * spec.n_slices * rt.cfg.slice_elems
        chunk_log = -(-spec.n_elems // spec.group_size)
        row = heap[rank]
        if outc:
            o = np.zeros(spec.group_size * chunk_log, heap.dtype)
            for k in range(spec.group_size):
                src = spec.out_off + k * chunk_pad
                o[k * chunk_log:(k + 1) * chunk_log] = row[src:src + chunk_log]
            out[(rank, coll_id)] = o[:spec.n_elems]
        else:
            out[(rank, coll_id)] = row[spec.out_off:spec.out_off + chunk_log]
    return out


def _legacy_scalar_iter(rt: OcclRuntime, writes: dict) -> None:
    """The pre-PR SCALAR submit-time staging (what ``submit(data=...)``
    did before the staging queue): one ``.at[].set`` full-heap device
    round trip per (rank, collective) — the ~100 ms/iteration overhead
    recorded in ROADMAP."""
    for (rank, coll_id), data in writes.items():
        spec = rt.specs[coll_id]
        chunk_pad = spec.n_rounds * spec.n_slices * rt.cfg.slice_elems
        chunk_log = -(-spec.n_elems // spec.group_size)
        buf = np.zeros(spec.group_size * chunk_pad, data.dtype)
        for k in range(spec.group_size):
            part = data[k * chunk_log:(k + 1) * chunk_log]
            buf[k * chunk_pad:k * chunk_pad + part.size] = part
        heap = rt._state.heap_in
        heap = heap.at[rank, spec.in_off:spec.in_off + buf.size].set(
            jnp.asarray(buf, heap.dtype))
        rt._state = rt._state._replace(heap_in=heap)
    jax.block_until_ready(rt._state.heap_in)


def run_staging_bench(n=16384, R=8, n_buckets=8, iters=10,
                      out_path=BENCH_JSON) -> dict:
    """Per-iteration STAGING cost of a grad-sync-shaped step (write every
    rank's bucket payloads, read every rank's outputs; the daemon launch
    is excluded) — device-resident staging engine vs the pre-PR bulk path
    whose full-heap host mirrors dominated end-to-end time (~100 ms per
    8-rank iteration at 16k elems, ROADMAP).  Written to
    BENCH_collectives.json under ``staging``."""
    per_bucket = n // n_buckets

    def mk_runtime():
        cfg = OcclConfig(n_ranks=R, max_colls=max(8, n_buckets), max_comms=1,
                         slice_elems=256, conn_depth=8,
                         heap_elems=max(1 << 14, 16 * n),  # occl_sync-style 4x
                         superstep_budget=1 << 15)
        rt = OcclRuntime(cfg)
        comm = rt.communicator(list(range(R)))
        ids = [rt.register(CollKind.ALL_REDUCE, comm, n_elems=per_bucket)
               for _ in range(n_buckets)]
        return rt, ids

    # Two identical runtimes: the legacy path re-roots heap_in in a host
    # mirror every iteration, which would poison the staged path's
    # donation chain if they shared state.
    rt_l, ids = mk_runtime()
    rt_s, _ = mk_runtime()
    rng = np.random.RandomState(0)
    writes = {(r, cid): rng.randn(per_bucket).astype(np.float32)
              for cid in ids for r in range(R)}
    reads = list(writes)

    # One driven step each so heap_out holds real data for the read paths.
    for rt in (rt_l, rt_s):
        for cid in ids:
            for r in range(R):
                rt.submit(r, cid, data=writes[(r, cid)])
        rt.drive()

    def legacy_iter():
        _legacy_write_inputs_bulk(rt_l, writes)
        jax.block_until_ready(rt_l._state.heap_in)
        _legacy_read_outputs_bulk(rt_l, reads)

    def staged_iter():
        rt_s.write_inputs_bulk(writes)
        jax.block_until_ready(rt_s._state.heap_in)
        rt_s.read_outputs_bulk(reads)

    # Cross-check before timing: both paths must read back the same
    # logical outputs (the heaps hold identical converged steps).
    got_legacy = _legacy_read_outputs_bulk(rt_l, reads)
    got_staged = rt_s.read_outputs_bulk(reads)
    for k in reads:
        np.testing.assert_allclose(got_staged[k], got_legacy[k], rtol=1e-6)

    # Best-of-N per path, each in its own contiguous block (interleaving
    # would let the legacy path's full-heap sweeps evict the staged
    # path's cache-resident working set): the min is the steady-state
    # capability, robust to shared-container noise on CI hosts.
    def best_of(fn):
        fn()                                               # warm compile
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            fn()
            ts.append(time.perf_counter() - t0)
        return min(ts)

    t_legacy = best_of(legacy_iter)
    t_staged = best_of(staged_iter)

    # Scalar baseline is ~2 orders slower; a couple of iterations suffice.
    t0 = time.perf_counter()
    _legacy_scalar_iter(rt_l, writes)
    t_scalar = time.perf_counter() - t0
    t0 = time.perf_counter()
    _legacy_scalar_iter(rt_l, writes)
    t_scalar = min(t_scalar, time.perf_counter() - t0)

    record = {
        "config": {"n_ranks": R, "n_elems": n, "n_buckets": n_buckets,
                   "slice_elems": 256, "heap_elems": rt_s.cfg.heap_elems,
                   "iters": iters, "backend": "sim",
                   "workload": "grad-sync-shaped write+read, daemon excluded"},
        "legacy_scalar_write_s_per_iter": t_scalar,
        "legacy_bulk_s_per_iter": t_legacy,
        "staged_s_per_iter": t_staged,
        "speedup_vs_legacy": t_legacy / t_staged,
        "speedup_vs_legacy_scalar": t_scalar / t_staged,
    }
    row("collectives/staging_legacy_scalar_write", t_scalar * 1e6)
    row("collectives/staging_legacy_bulk", t_legacy * 1e6)
    row("collectives/staging_engine", t_staged * 1e6,
        f"speedup_vs_legacy={record['speedup_vs_legacy']:.1f}x;"
        f"vs_scalar={record['speedup_vs_legacy_scalar']:.0f}x")
    doc = _read_record(out_path)
    doc["staging"] = record
    _write_record(out_path, doc)
    print(f"# wrote {out_path} (staging)")
    return record


def build_contention_runtime(burst: int, n: int = 2048, R: int = 8,
                             C: int = 8, conn_depth: int = 32,
                             seed: int = 42,
                             slice_elems: int = BURST_SLICE_ELEMS,
                             **cfg_kw) -> OcclRuntime:
    """Adversarial contention: R ranks submit C all-reduces on ONE lane in
    pairwise-different orders (the Sec. 5.2 headline workload) — the
    regime where bursts historically amplified spin/preempt thrash.

    Everything is submitted but not yet driven; tier-1
    (tests/test_launch_epoch.py) reuses this builder so the regression
    test guards exactly the benchmarked regime.
    """
    rng = np.random.RandomState(seed)
    orders = {r: list(rng.permutation(C)) for r in range(R)}
    cfg = OcclConfig(n_ranks=R, max_colls=C, max_comms=1,
                     slice_elems=slice_elems, conn_depth=conn_depth,
                     burst_slices=burst, heap_elems=1 << 18,
                     superstep_budget=1 << 15, **cfg_kw)
    rt = OcclRuntime(cfg)
    world = rt.communicator(list(range(R)))
    ids = [rt.register(CollKind.ALL_REDUCE, world, n_elems=n)
           for _ in range(C)]
    for r in range(R):
        for slot in orders[r]:
            rt.submit(r, ids[slot],
                      data=rng.randn(n).astype(np.float32))
    return rt


def _contention_once(burst: int, n: int, R: int, C: int, conn_depth: int,
                     seed: int) -> dict:
    rt = build_contention_runtime(burst, n, R, C, conn_depth, seed)
    t0 = time.perf_counter()
    rt.drive(max_launches=128)
    dt = time.perf_counter() - t0
    st = rt.stats()
    steps = int(st["supersteps"].max())
    slices = int(st["slices_moved"].sum())
    return {
        "latency_s": dt,                       # includes compile (1 iter)
        "supersteps": steps,
        "preempts": int(st["preempts"].sum()),
        "stall_slices": int(st["stall_slices"].sum()),
        "slices": slices,
        "slices_per_superstep": slices / max(steps, 1),
        "launches": st["launches"],
    }


def run_contention_sweep(bursts=(1, 4, 8), n=2048, R=8, C=8, conn_depth=32,
                         seed=42, out_path=BENCH_JSON) -> dict:
    """Stall/preempt/throughput of the adversarial 8x8 all-reduce at each
    burst width — the burst-aware stall accounting record (spin advances
    by denied slices, so stalled lanes multiplex instead of spinning
    B-wide supersteps).  Merged into BENCH_collectives.json under
    ``contention``."""
    sweep = {}
    for burst in bursts:
        sweep[str(burst)] = _contention_once(burst, n, R, C, conn_depth,
                                             seed)
        s = sweep[str(burst)]
        row(f"collectives/contention_burst{burst}", s["latency_s"] * 1e6,
            f"supersteps={s['supersteps']};preempts={s['preempts']};"
            f"stall_slices={s['stall_slices']};"
            f"slices_per_superstep={s['slices_per_superstep']:.2f}")
    record = {
        "config": {"n_ranks": R, "n_colls": C, "n_elems": n,
                   "slice_elems": BURST_SLICE_ELEMS,
                   "conn_depth": conn_depth, "seed": seed,
                   "workload": "adversarial all-reduce, 1 lane"},
        "bursts": sweep,
    }
    if "1" in sweep:
        base = sweep["1"]["supersteps"]
        record["superstep_speedup_vs_burst1"] = {
            k: base / max(v["supersteps"], 1) for k, v in sweep.items()
        }
    doc = _read_record(out_path)
    doc["contention"] = record
    _write_record(out_path, doc)
    print(f"# wrote {out_path} (contention)")
    return record


def _algo_once(algo: str, kind: CollKind, hierarchy, R: int, n: int,
               burst: int, conn_depth: int, iters: int,
               bandwidth_groups: int = 0, inter_burst_cap: int = 0,
               max_comms: int = 3, root: int = 0) -> dict:
    """Supersteps + wall time of ONE algorithm lowering of ``kind`` at R
    ranks on the sim backend, optionally under the bandwidth-skew lane
    model (``bandwidth_groups``/``inter_burst_cap``).  One warm iteration
    converges gang scheduling and compiles; the measured iterations
    report the steady state.  The returned record carries the plan's
    cost-model features next to the measurement — the (X, y) pairs
    benchmarks/calibrate.py fits (α, β, γ) from."""
    from repro.core import plan_features

    cfg = OcclConfig(n_ranks=R, max_colls=8, max_comms=max_comms,
                     slice_elems=BURST_SLICE_ELEMS, conn_depth=conn_depth,
                     burst_slices=burst, heap_elems=1 << 18,
                     superstep_budget=1 << 15,
                     bandwidth_groups=bandwidth_groups,
                     inter_burst_cap=inter_burst_cap)
    rt = OcclRuntime(cfg)
    world = (rt.communicator(list(range(R))) if algo == "ring"
             else rt.logical_communicator(list(range(R))))
    cid = rt.register(kind, world, n_elems=n, algo=algo, root=root,
                      hierarchy=hierarchy)
    rng = np.random.RandomState(0)
    xs = [rng.randn(n).astype(np.float32) for _ in range(R)]
    want = (np.sum(xs, axis=0) if kind != CollKind.BROADCAST else xs[root])

    def once():
        rt.submit_all(cid, data={r: xs[r] for r in range(R)})
        rt.drive()

    once()                                   # warmup: compile + converge
    check_rank = root if kind in (CollKind.REDUCE, CollKind.BROADCAST) \
        else 0
    np.testing.assert_allclose(rt.read_output(check_rank, cid), want,
                               rtol=1e-4, atol=1e-4)
    s0 = rt.stats()
    # Best-of-N latency: the sim daemon's wall time at small payloads is
    # dominated by dispatch, and single-shot timings jitter by ~20% on
    # shared runners — the minimum is the standard noise-robust
    # microbenchmark statistic (supersteps are deterministic and
    # averaged).
    dt = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        once()
        dt = min(dt, time.perf_counter() - t0)
    s1 = rt.stats()
    steps = (int(s1["supersteps"].max()) - int(s0["supersteps"].max())) \
        / iters
    slices = (int(s1["slices_moved"].sum())
              - int(s0["slices_moved"].sum())) / iters
    feats = plan_features(cfg, kind, n, R, hierarchy, algo, root=root)
    return {"latency_s": dt, "supersteps": steps, "slices": slices,
            "features": {"supersteps": feats["supersteps"],
                         "bytes": feats["bytes"],
                         "stages": feats["stages"]}}


def _hierarchy_once(algo: str, hierarchy, R: int, n: int, burst: int,
                    conn_depth: int, iters: int, **skew) -> dict:
    """Back-compat shim: the original hierarchy measurement is the
    all-reduce case of ``_algo_once``."""
    return _algo_once(algo, CollKind.ALL_REDUCE, hierarchy, R, n, burst,
                      conn_depth, iters, **skew)


def run_hierarchy_bench(R=16, hierarchy=(4, 4), n=2048, burst=8,
                        conn_depth=24, iters=3, out_path=BENCH_JSON) -> dict:
    """Composite-layer perf record (``hierarchy`` key): the flat ring vs
    the device-chained two-level all-reduce at R=16 on the sim backend.

    With slice bursts the superstep count is latency-term dominated
    (2R - 1 = 31 ring steps vs N + (2G - 1) + N = 15 chain steps at
    (4, 4)), so the two-level chain must complete in FEWER supersteps —
    the check_gates.py hierarchy gate.  Wall time is recorded alongside
    for trajectory tracking (CPU-sim wall time includes XLA dispatch for
    the extra lanes, so supersteps are the structural signal).

    The ``skew`` subrecord re-measures both lowerings under the
    bandwidth-skew lane model (G islands, inter lanes capped): the flat
    ring's single lane crosses islands every hop, so here the two-level
    chain must win on WALL-CLOCK too (its bulk stages ride intra lanes
    at the full burst) — the wall-time gate of check_gates.py.
    """
    flat = _hierarchy_once("ring", None, R, n, burst, conn_depth, iters)
    two = _hierarchy_once("two_level", hierarchy, R, n, burst, conn_depth,
                          iters)
    skew_kw = dict(bandwidth_groups=hierarchy[0], inter_burst_cap=2)
    skew_n = n * 8        # skew penalties are bandwidth-term dominated
    # Deeper connectors for the bulk skew points: the two-level chain's
    # intra hops carry skew_n / N elements per rotation, and a ring
    # buffer shallower than that chunk throttles the chain on credit
    # stalls rather than the modeled lane bandwidth (both lowerings get
    # the same fabric).
    skew_depth = max(conn_depth, 64)
    skew_flat = _hierarchy_once("ring", None, R, skew_n, burst,
                                skew_depth, iters, **skew_kw)
    skew_two = _hierarchy_once("two_level", hierarchy, R, skew_n, burst,
                               skew_depth, iters, **skew_kw)
    record = {
        "config": {"n_ranks": R, "hierarchy": list(hierarchy), "n_elems": n,
                   "slice_elems": BURST_SLICE_ELEMS, "burst_slices": burst,
                   "conn_depth": conn_depth, "iters": iters,
                   "backend": "sim",
                   "workload": "all-reduce, flat ring vs two-level chain"},
        "flat": flat,
        "two_level": two,
        "superstep_ratio": two["supersteps"] / max(flat["supersteps"], 1),
        "skew": {
            "config": {"n_elems": skew_n, "conn_depth": skew_depth,
                       **skew_kw},
            "flat": skew_flat,
            "two_level": skew_two,
            "wall_ratio": skew_two["latency_s"]
                / max(skew_flat["latency_s"], 1e-12),
        },
    }
    row("collectives/hierarchy_flat_ring", flat["latency_s"] * 1e6,
        f"supersteps={flat['supersteps']:.0f}")
    row("collectives/hierarchy_two_level", two["latency_s"] * 1e6,
        f"supersteps={two['supersteps']:.0f};"
        f"ratio_vs_flat={record['superstep_ratio']:.2f}")
    row("collectives/hierarchy_skew_flat", skew_flat["latency_s"] * 1e6,
        f"supersteps={skew_flat['supersteps']:.0f}")
    row("collectives/hierarchy_skew_two_level",
        skew_two["latency_s"] * 1e6,
        f"supersteps={skew_two['supersteps']:.0f};"
        f"wall_ratio={record['skew']['wall_ratio']:.2f}")
    doc = _read_record(out_path)
    doc["hierarchy"] = record
    _write_record(out_path, doc)
    print(f"# wrote {out_path} (hierarchy)")
    return record


def run_algo_sweep(R=16, hierarchy=(4, 4), small_n=256, large_n=16384,
                   burst=8, conn_depth=64, iters=3,
                   out_path=BENCH_JSON) -> dict:
    """Algorithm-zoo sweep (``algos`` record section): measure EVERY
    registered lowering of all-reduce and broadcast at two payload sizes
    straddling the small/large crossover, under the bandwidth-skew lane
    model (hierarchy[0] islands, inter lanes capped at 2 slices/superstep
    — the regime where hierarchical plans earn their extra stages).

    Each measurement records wall-clock, supersteps and the plan's
    cost-model features; benchmarks/calibrate.py fits (α, β, γ) from
    exactly these samples and appends the fitted auto-selection picks
    under ``algos.auto`` (check_gates.py asserts the picks match the
    measured winners on both sides of the crossover).
    """
    from repro.core import AUTO_CANDIDATES

    skew_kw = dict(bandwidth_groups=hierarchy[0], inter_burst_cap=2)
    sweep: dict = {}
    for label, kind in [("all_reduce", CollKind.ALL_REDUCE),
                        ("broadcast", CollKind.BROADCAST)]:
        sweep[label] = {}
        for size_label, n in [("small", small_n), ("large", large_n)]:
            entry = {"n_elems": n}
            for algo in AUTO_CANDIDATES[kind]:
                hier = None if algo == "ring" else hierarchy
                entry[algo] = _algo_once(algo, kind, hier, R, n, burst,
                                         conn_depth, iters, **skew_kw)
                row(f"collectives/algos_{label}_{size_label}_{algo}",
                    entry[algo]["latency_s"] * 1e6,
                    f"supersteps={entry[algo]['supersteps']:.0f}")
            sweep[label][size_label] = entry
    record = {
        "config": {"n_ranks": R, "hierarchy": list(hierarchy),
                   "small_n": small_n, "large_n": large_n,
                   "slice_elems": BURST_SLICE_ELEMS, "burst_slices": burst,
                   "conn_depth": conn_depth, "iters": iters,
                   "backend": "sim", **skew_kw},
        "sweep": sweep,
    }
    doc = _read_record(out_path)
    # Replace the section wholesale, DROPPING any prior auto picks: they
    # were fitted against the previous sweep, and validate_record's
    # missing-"auto" failure is what forces benchmarks/calibrate.py to
    # re-fit against THIS sweep before the record passes as complete.
    doc["algos"] = record
    _write_record(out_path, doc)
    print(f"# wrote {out_path} (algos)")
    return record


def _a2a_once(algo: str, hierarchy, R: int, n: int, burst: int,
              conn_depth: int, iters: int, bandwidth_groups: int = 0,
              inter_burst_cap: int = 0) -> dict:
    """Supersteps + wall time of ONE all-to-all lowering, reference-
    checked (personalized exchange, not a reduction — ``_algo_once``'s
    sum oracle does not apply).  Same record shape as ``_algo_once`` so
    benchmarks/calibrate.py can rank the candidates with the fitted
    model."""
    from repro.core import plan_features

    cfg = OcclConfig(n_ranks=R, max_colls=8, max_comms=3,
                     slice_elems=BURST_SLICE_ELEMS, conn_depth=conn_depth,
                     burst_slices=burst, heap_elems=1 << 18,
                     superstep_budget=1 << 15,
                     bandwidth_groups=bandwidth_groups,
                     inter_burst_cap=inter_burst_cap)
    rt = OcclRuntime(cfg)
    world = (rt.communicator(list(range(R))) if algo == "ring"
             else rt.logical_communicator(list(range(R))))
    cid = rt.register(CollKind.ALL_TO_ALL, world, n_elems=n, algo=algo,
                      hierarchy=hierarchy)
    rng = np.random.RandomState(0)
    xs = [rng.randn(n).astype(np.float32) for _ in range(R)]
    c = n // R
    want0 = np.concatenate([xs[o][:c] for o in range(R)])

    def once():
        rt.submit_all(cid, data={r: xs[r] for r in range(R)})
        rt.drive()

    once()                                   # warmup: compile + converge
    np.testing.assert_array_equal(rt.read_output(0, cid), want0)
    s0 = rt.stats()
    dt = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        once()
        dt = min(dt, time.perf_counter() - t0)
    s1 = rt.stats()
    steps = (int(s1["supersteps"].max()) - int(s0["supersteps"].max())) \
        / iters
    feats = plan_features(cfg, CollKind.ALL_TO_ALL, n, R, hierarchy, algo)
    return {"latency_s": dt, "supersteps": steps,
            "features": {"supersteps": feats["supersteps"],
                         "bytes": feats["bytes"],
                         "stages": feats["stages"]}}


def _a2a_contention_once(R: int, n: int, burst: int, conn_depth: int,
                         iters: int) -> dict:
    """Adversarial a2a x all-reduce contention: a dispatch/combine-style
    all-to-all pair interleaved with an all-reduce, submitted in
    rank-dependent conflicting orders for which NO consistent static
    schedule exists (the MoE training shape).  The record proves the
    static baseline wedges and measures OCCL draining everything."""
    from repro.core import run_static_order

    orders = {r: list(np.random.RandomState(r).permutation(3))
              for r in range(R)}
    static = run_static_order(orders,
                              {c: list(range(R)) for c in range(3)})
    cfg = OcclConfig(n_ranks=R, max_colls=8, max_comms=1,
                     slice_elems=BURST_SLICE_ELEMS, conn_depth=conn_depth,
                     burst_slices=burst, heap_elems=1 << 18,
                     superstep_budget=1 << 15)
    rt = OcclRuntime(cfg)
    world = rt.communicator(list(range(R)))
    ids = [rt.register(CollKind.ALL_TO_ALL, world, n_elems=n),
           rt.register(CollKind.ALL_TO_ALL, world, n_elems=n),
           rt.register(CollKind.ALL_REDUCE, world, n_elems=n)]
    rng = np.random.RandomState(1)
    xs = {c: [rng.randn(n).astype(np.float32) for _ in range(R)]
          for c in range(3)}

    def once():
        for r in range(R):
            for c in orders[r]:
                rt.submit(r, ids[c], data=xs[c][r])
        rt.drive()

    once()
    c_ = n // R
    for cid, c in ((ids[0], 0), (ids[1], 1)):
        np.testing.assert_array_equal(
            rt.read_output(0, cid),
            np.concatenate([xs[c][o][:c_] for o in range(R)]))
    np.testing.assert_allclose(rt.read_output(0, ids[2]),
                               np.sum(xs[2], axis=0), rtol=1e-4, atol=1e-4)
    s0 = rt.stats()
    dt = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        once()
        dt = min(dt, time.perf_counter() - t0)
    s1 = rt.stats()
    steps = (int(s1["supersteps"].max()) - int(s0["supersteps"].max())) \
        / iters
    return {"static_deadlocks": bool(static.deadlocked),
            "static_cycle": list(static.cycle or []),
            "latency_s": dt, "supersteps": steps,
            "n_collectives": 3}


def run_alltoall_bench(R=16, hierarchy=(4, 4), n=4096, burst=8,
                       conn_depth=64, iters=3,
                       out_path=BENCH_JSON) -> dict:
    """All-to-all perf record (``alltoall`` section): the flat relay
    ring vs the two-level composite at R=16 under the bandwidth-skew
    lane model (the algo-sweep regime), plus the adversarial
    a2a x all-reduce contention scenario.

    The flat ring's program is O(R^2) — ``1 + (R-1)(R+2)/2`` steps, the
    relay hops included — while the two-level chain runs two short
    exchanges of ``1 + (N-1)(N+2)/2`` and ``1 + (G-1)(G+2)/2`` steps, so
    at R=16/(4,4) the chain must land in strictly fewer supersteps (the
    check_gates.py alltoall gate; 136 vs 20 program steps before
    slicing).  benchmarks/calibrate.py appends the fitted cost model's
    pick under ``auto`` — the gate asserts it lands on the measured
    winner.
    """
    skew_kw = dict(bandwidth_groups=hierarchy[0], inter_burst_cap=2)
    flat = _a2a_once("ring", None, R, n, burst, conn_depth, iters,
                     **skew_kw)
    two = _a2a_once("two_level", hierarchy, R, n, burst, conn_depth,
                    iters, **skew_kw)
    contention = _a2a_contention_once(8, 2048, burst, max(conn_depth, 32),
                                      iters)
    record = {
        "config": {"n_ranks": R, "hierarchy": list(hierarchy),
                   "n_elems": n, "slice_elems": BURST_SLICE_ELEMS,
                   "burst_slices": burst, "conn_depth": conn_depth,
                   "iters": iters, "backend": "sim", **skew_kw,
                   "workload": "all-to-all, flat relay ring vs "
                               "two-level chain + adversarial contention"},
        "flat": flat,
        "two_level": two,
        "superstep_ratio": two["supersteps"] / max(flat["supersteps"], 1),
        "contention": contention,
    }
    row("collectives/alltoall_flat_ring", flat["latency_s"] * 1e6,
        f"supersteps={flat['supersteps']:.0f}")
    row("collectives/alltoall_two_level", two["latency_s"] * 1e6,
        f"supersteps={two['supersteps']:.0f};"
        f"ratio_vs_flat={record['superstep_ratio']:.2f}")
    row("collectives/alltoall_contention", contention["latency_s"] * 1e6,
        f"supersteps={contention['supersteps']:.0f};"
        f"static_deadlocks={contention['static_deadlocks']}")
    doc = _read_record(out_path)
    # Replace wholesale, dropping any stale auto pick (same re-fit
    # forcing contract as the "algos" section).
    doc["alltoall"] = record
    _write_record(out_path, doc)
    print(f"# wrote {out_path} (alltoall)")
    return record


def run_mesh_bench(R=8, n=16384, n_buckets=8, out_path=BENCH_JSON) -> dict:
    """Mesh-backend fast-path record, written under the ``mesh`` key:

    * ``ppermutes_per_superstep`` — ppermute ops per ``_mesh_exchange``
      superstep, counted in the traced jaxpr per heap dtype (packed 16-bit
      must match 32-bit at 2; the unpacked escape hatch pays 3).  The
      count is ring-size independent, so it needs no multi-device flags —
      CI asserts it on every run via benchmarks/check_gates.py, and the
      8-device mesh job executes the same code path for real.
    * ``staged_flush`` — bytes one grad-sync-shaped staged flush ships
      (payload bytes; on the mesh backend placed per device) vs the full
      ``[R, heap]`` mirror the pre-PR sim-style path gathered/moved.
    """
    from repro.core.daemon import count_exchange_ppermutes
    from repro.core import OcclConfig as _Cfg

    ppermutes = {}
    for label, dtype, packed in [
        ("float32", "float32", True),
        ("bfloat16_packed", "bfloat16", True),
        ("bfloat16_unpacked", "bfloat16", False),
        ("float16_packed", "float16", True),
    ]:
        cfg = _Cfg(n_ranks=R, max_comms=1, slice_elems=BURST_SLICE_ELEMS,
                   burst_slices=4, packed_16bit=packed, dtype=dtype)
        ppermutes[label] = count_exchange_ppermutes(cfg)
        row(f"collectives/mesh_ppermutes_{label}", 0.0,
            f"ppermutes_per_superstep={ppermutes[label]}")

    # Staged-flush bytes: all-ranks staged submits, one prologue flush.
    per_bucket = n // n_buckets
    cfg = OcclConfig(n_ranks=R, max_colls=max(8, n_buckets), max_comms=1,
                     slice_elems=256, conn_depth=8,
                     heap_elems=max(1 << 14, 16 * n),
                     superstep_budget=1 << 15)
    rt = OcclRuntime(cfg)
    comm = rt.communicator(list(range(R)))
    ids = [rt.register(CollKind.ALL_REDUCE, comm, n_elems=per_bucket)
           for _ in range(n_buckets)]
    rng = np.random.RandomState(0)
    for cid in ids:
        for r in range(R):
            rt.submit(r, cid, data=rng.randn(per_bucket).astype(np.float32))
    rt.launch_once()
    st = rt.stats()
    itemsize = jnp.dtype(cfg.dtype).itemsize
    from repro.core.state import heap_scratch_elems
    full_heap = R * (cfg.heap_elems + heap_scratch_elems(cfg)) * itemsize
    flush = {
        "payload_bytes": int(st["staging_flush_bytes"]),
        "full_heap_mirror_bytes": int(full_heap),
        "gather_bytes_avoided_ratio":
            full_heap / max(int(st["staging_flush_bytes"]), 1),
        "flush_writes": int(st["staging_flush_writes"]),
        "sharded_flushes": int(st["staging_sharded_flushes"]),
        "backend": "sim" if rt.mesh is None else "mesh",
    }
    row("collectives/mesh_staged_flush", 0.0,
        f"payload_bytes={flush['payload_bytes']};"
        f"full_heap_mirror_bytes={flush['full_heap_mirror_bytes']}")

    # Each sub-record carries ITS OWN measurement config: the ppermute
    # counts and the flush bytes are produced by different runtimes, and
    # full_heap_mirror_bytes depends on the flush config's scratch pad.
    record = {
        "ppermutes_per_superstep": ppermutes,
        "ppermutes_config": {"n_ranks": R, "burst_slices": 4,
                             "slice_elems": BURST_SLICE_ELEMS},
        "staged_flush": flush,
        "staged_flush_config": {"n_ranks": R, "n_elems": n,
                                "n_buckets": n_buckets, "slice_elems": 256,
                                "conn_depth": 8, "burst_slices": 1,
                                "heap_elems": cfg.heap_elems},
    }
    doc = _read_record(out_path)
    doc["mesh"] = record
    _write_record(out_path, doc)
    print(f"# wrote {out_path} (mesh)")
    return record


if __name__ == "__main__":
    run()
    run_burst_sweep()
    run_contention_sweep()
    run_staging_bench()
    run_mesh_bench()
    validate_record()
