"""Shared benchmark utilities."""
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np


def timeit(fn, iters=5, warmup=1):
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def row(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")
