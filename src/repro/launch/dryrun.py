import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
# (No `from __future__` here for that reason — py3.13 needs no annotations
# import anyway.)
# NOTE on XLA cost_analysis: while-loop bodies are counted ONCE (not x
# trip count).  The deliverable compile therefore uses the rolled scan
# (production HLO, honest memory analysis), and roofline FLOPs/bytes/
# collective-traffic are obtained from two small-L *unrolled* lowerings,
# extrapolated linearly over the (homogeneous) layer stack:
#     F_L = F(1) + (L - 1) * (F(2) - F(1))
# which is exact for scanned stacks and validated against a full-unroll
# build in EXPERIMENTS.md (qwen3 train_4k: <1% error).

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (arch x shape-cell), lower + compile the train/prefill/serve
step from ShapeDtypeStructs on the production mesh — 16x16 single-pod and
2x16x16 multi-pod — and record memory_analysis / cost_analysis plus the
collective-traffic breakdown parsed from the compiled HLO.  Results land
in benchmarks/dryrun_results/*.json for the roofline harness.

Usage:
    python -m repro.launch.dryrun --arch llama3-8b --cell train_4k
    python -m repro.launch.dryrun --arch llama3-8b --cell train_4k --multi-pod
    python -m repro.launch.dryrun --all            # every cell, both meshes
"""
import argparse
import json
import pathlib
import re
import subprocess
import sys
import time

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / \
    "benchmarks" / "dryrun_results"

# `%name = <shape> <op>(...)`: capture the shape expression then the op.
_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9\[\],{}: /#()]*?\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_stats(hlo: str) -> dict:
    """Sum result bytes of every collective op in the compiled HLO, by kind.

    The result shape of an op sits between `=` and the op name:
    ``%x = bf16[16,2048]{1,0} all-reduce(%y), ...``.  ``-start/-done``
    pairs are counted once (on the -start).  NOTE: ops inside while-loop
    bodies appear once; the dry-run unrolls the layer scan so per-layer
    collectives are correctly multiplied."""
    out: dict[str, dict] = {}
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        b = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(m.group(1)))
        if b == 0:
            continue
        rec = out.setdefault(kind, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += b
    return out


def n_params(tree) -> int:
    import jax
    import numpy as np
    return int(sum(np.prod(l.shape)
                   for l in jax.tree_util.tree_leaves(tree)))


def active_params(cfg, params) -> tuple[int, int]:
    """(N_matmul_total, N_matmul_active): matrix params (ndim>=2, no embed),
    with routed-expert stacks scaled by top_k/E for the active count."""
    import jax
    import numpy as np
    total = active = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        names = [getattr(p, "key", None) for p in path]
        if leaf.ndim < 2 or names[-1] == "embed":
            continue
        n = int(np.prod(leaf.shape))
        total += n
        if cfg.n_experts and "moe" in names and names[-1] in ("wg", "wu", "wd"):
            active += n * cfg.top_k // cfg.n_experts
        else:
            active += n
    return total, active


def _lower_cell(cfg, cell, mesh):
    """Lower the cell's step on the mesh; returns (lowered, model_tokens,
    flops_per_param)."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..models import build_model, input_specs
    from ..parallel.sharding import batch_pspecs, data_axes, param_shardings
    from ..train.state import abstract_state, state_shardings
    from ..train.step import (make_decode_step, make_prefill_step,
                              make_train_step)

    import contextlib

    def mesh_ctx():
        # ambient mesh so P-only with_sharding_constraint resolves
        # (jax.sharding.use_mesh was renamed set_mesh in jax 0.8)
        try:
            return jax.sharding.use_mesh(mesh)
        except AttributeError:
            return jax.sharding.set_mesh(mesh)
    specs = input_specs(cfg, cell)
    bspecs = batch_pspecs(mesh, specs)
    to_sh = lambda tree: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))

    if cell.kind == "train":
        st = abstract_state(cfg)
        st_sh = state_shardings(mesh, cfg, st)
        step = make_train_step(cfg)
        with mesh_ctx():
            lowered = jax.jit(
                step, in_shardings=(st_sh, to_sh(bspecs)),
                out_shardings=(st_sh, None),
            ).lower(st, specs)
        return lowered, cell.global_batch * cell.seq_len, 6
    model = build_model(cfg)
    pspecs = jax.eval_shape(lambda: model.init(0))
    p_sh = param_shardings(mesh, pspecs)
    if cell.kind == "prefill":
        step = make_prefill_step(cfg)
        with mesh_ctx():
            lowered = jax.jit(
                step, in_shardings=(p_sh, to_sh(bspecs)),
            ).lower(pspecs, specs)
        return lowered, cell.global_batch * cell.seq_len, 2
    step = make_decode_step(cfg)
    tok_spec = P(data_axes(mesh)) if cell.global_batch > 1 else P(None)
    tok_sh = NamedSharding(mesh, tok_spec)
    with mesh_ctx():
        lowered = jax.jit(
            step,
            in_shardings=(p_sh, to_sh(bspecs["cache"]), tok_sh),
            out_shardings=(tok_sh, to_sh(bspecs["cache"])),
        ).lower(pspecs, specs["cache"], specs["tokens"])
    return lowered, cell.global_batch, 2


def _cost_of(compiled) -> dict:
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    colls = collective_stats(hlo)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collectives": colls,
        "coll_bytes": float(sum(c["bytes"] for c in colls.values())),
    }


def _quad_extrap(ls, ys, L):
    """Quadratic (Lagrange) fit through 3 (l, y) points, evaluated at L.

    Per-layer HLO cost creeps superlinearly with depth (XLA's
    rematerialization grows under memory pressure); a quadratic fit
    matches full-unroll ground truth to ~0.1% (EXPERIMENTS.md)."""
    (x0, x1, x2), (y0, y1, y2) = ls, ys
    t0 = y0 * (L - x1) * (L - x2) / ((x0 - x1) * (x0 - x2))
    t1 = y1 * (L - x0) * (L - x2) / ((x1 - x0) * (x1 - x2))
    t2 = y2 * (L - x0) * (L - x1) / ((x2 - x0) * (x2 - x1))
    return max(0.0, t0 + t1 + t2)


def _roofline_probe(cfg, cell, mesh, unroll_layers: tuple[int, int, int]):
    """Three small-L UNROLLED lowerings -> quadratic extrapolation."""
    import dataclasses as dc
    os.environ["REPRO_SCAN_UNROLL"] = "1"
    try:
        ls = list(unroll_layers)
        probes = {}
        for l in ls:
            sub = {"n_layers": l}
            if cfg.enc_layers:
                sub["enc_layers"] = l
            c1 = dc.replace(cfg, **sub)
            lowered, _, _ = _lower_cell(c1, cell, mesh)
            probes[l] = _cost_of(lowered.compile())
        L = cfg.n_layers
        out = {}
        for fld in ("flops", "bytes", "coll_bytes"):
            out[fld] = _quad_extrap(ls, [probes[l][fld] for l in ls], L)
        kinds = set().union(*(probes[l]["collectives"].keys() for l in ls))
        colls = {}
        for k in kinds:
            bs = [probes[l]["collectives"].get(k, {}).get("bytes", 0)
                  for l in ls]
            ns = [probes[l]["collectives"].get(k, {}).get("count", 0)
                  for l in ls]
            colls[k] = {"bytes": _quad_extrap(ls, bs, L),
                        "count": _quad_extrap(ls, ns, L)}
        out["collectives"] = colls
        out["probe_layers"] = ls
        return out
    finally:
        os.environ["REPRO_SCAN_UNROLL"] = "0"


def run_cell(arch: str, cell_name: str, multi_pod: bool,
             out_path: pathlib.Path | None = None,
             with_roofline: bool = True, full_unroll: bool = False) -> dict:
    import jax

    from ..configs import get_config
    from ..configs.base import SHAPES
    from ..models import build_model
    from ..train.state import abstract_state
    from .mesh import make_production_mesh

    cfg = get_config(arch)
    cell = SHAPES[cell_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"

    # ---- deliverable: production (rolled-scan) compile -------------------
    os.environ["REPRO_SCAN_UNROLL"] = "1" if full_unroll else "0"
    t0 = time.time()
    lowered, model_tokens, flops_per_param = _lower_cell(cfg, cell, mesh)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    rolled_cost = _cost_of(compiled)

    def _mem_attr(name):
        try:
            return int(getattr(mem, name))
        except Exception:
            return None

    if cell.kind == "train":
        ptree = abstract_state(cfg).params
    else:
        ptree = jax.eval_shape(lambda: build_model(cfg).init(0))
    n_total, n_active = active_params(cfg, ptree)
    model_flops = flops_per_param * n_active * model_tokens

    result = {
        "arch": arch, "cell": cell_name, "mesh": mesh_name,
        "n_devices": 512 if multi_pod else 256,
        "kind": cell.kind,
        "seq_len": cell.seq_len, "global_batch": cell.global_batch,
        "compile_ok": True,
        "full_unroll": full_unroll,
        "rolled": rolled_cost,
        "mem_argument_bytes": _mem_attr("argument_size_in_bytes"),
        "mem_output_bytes": _mem_attr("output_size_in_bytes"),
        "mem_temp_bytes": _mem_attr("temp_size_in_bytes"),
        "n_params_matmul": n_total,
        "n_params_active": n_active,
        "model_flops_global": float(model_flops),
        "model_tokens": model_tokens,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "hlo_bytes": len(compiled.as_text()),
    }

    # ---- roofline probe: small-L unrolled extrapolation (single-pod) -----
    if with_roofline and not multi_pod and not full_unroll:
        if cfg.family == "hybrid":
            p = cfg.shared_attn_period
            probe = _roofline_probe(cfg, cell, mesh, (p, 2 * p, 3 * p))
        else:
            probe = _roofline_probe(cfg, cell, mesh, (1, 2, 4))
        result["roofline"] = probe
    elif full_unroll:
        result["roofline"] = dict(rolled_cost,
                                  coll_bytes=rolled_cost["coll_bytes"],
                                  probe_layers="full")

    print(json.dumps(result, indent=1))
    print("memory_analysis:", mem)
    if out_path:
        out_path.parent.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(result, indent=1))
    return result


def _cell_path(arch, cell, multi_pod):
    mesh = "2x16x16" if multi_pod else "16x16"
    return RESULTS_DIR / f"{arch}__{cell}__{mesh}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--cell")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--full-unroll", action="store_true",
                    help="ground-truth unrolled build (slow; hillclimb cells)")
    ap.add_argument("--no-roofline", action="store_true")
    args = ap.parse_args()

    if args.all:
        from ..configs import all_cells
        todo = [(a, c, mp) for a, c in all_cells() for mp in (False, True)]
        failed = []
        for arch, cell, mp in todo:
            path = _cell_path(arch, cell, mp)
            if path.exists() and not args.force:
                print(f"skip (cached): {path.name}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--cell", cell]
            if mp:
                cmd.append("--multi-pod")
            print(f"=== {arch} {cell} {'2x16x16' if mp else '16x16'} ===",
                  flush=True)
            r = subprocess.run(cmd, cwd=str(RESULTS_DIR.parents[1]))
            if r.returncode != 0:
                failed.append((arch, cell, mp))
        if failed:
            print("FAILED cells:", failed)
            sys.exit(1)
        print("ALL CELLS PASSED")
        return

    out = _cell_path(args.arch, args.cell, args.multi_pod)
    run_cell(args.arch, args.cell, args.multi_pod, out,
             with_roofline=not args.no_roofline,
             full_unroll=args.full_unroll)


if __name__ == "__main__":
    main()
