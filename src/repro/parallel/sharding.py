"""Sharding rules: parameter and activation PartitionSpecs.

Axes of the production mesh (launch/mesh.py):
    pod   — multi-pod data parallelism (2-way in the 512-chip dry-run)
    data  — in-pod data parallelism (16-way); also the ZeRO-1 shard axis
    model — tensor/expert parallelism (16-way)

Parameter rules are name+shape based (Megatron-style):
    column-parallel (out-dim on "model"): wq wk wv wg wu w1 z_proj x_proj
        dt_proj shared_wg shared_wu lm_head head vis_proj patch_proj
    row-parallel (in-dim on "model"):     wo wd w2 out_proj shared_wd
    vocab-parallel:                       embed (dim 0)
    expert-parallel (dim E on "model"):   moe wg/wu/wd
    head-parallel small vectors:          A_log D_skip dt_bias gate_norm
                                          conv_x_* (SSM d_inner shards)
    replicated:                           norms, biases, router, B/C proj
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# name -> (rule) where rule picks the sharded dim
_COL = {"wq", "wk", "wv", "wg", "wu", "w1", "z_proj", "x_proj", "dt_proj",
        "shared_wg", "shared_wu", "lm_head", "head", "vis_proj",
        "patch_proj"}
_ROW = {"wo", "wd", "w2", "out_proj", "shared_wd"}
_VEC_MODEL = {"A_log", "D_skip", "dt_bias", "gate_norm", "conv_x_w",
              "conv_x_b"}
_REPL = {"norm", "norm_w", "norm_b", "q_norm", "k_norm", "b1", "b2",
         "router", "B_proj", "C_proj", "conv_B_w", "conv_B_b", "conv_C_w",
         "conv_C_b", "final_norm", "enc_norm", "pos_embed", "proj"}


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _spec_for(path: tuple, leaf) -> P:
    names = [getattr(p, "key", getattr(p, "name", None)) for p in path]
    name = names[-1]
    in_moe = any(n in ("moe",) for n in names)
    nd = leaf.ndim

    if name == "embed":
        return P(*(["model"] + [None] * (nd - 1)))
    if in_moe and name in ("wg", "wu", "wd"):
        # [L, E, D, F] or [E, D, F]: shard E (dim -3) over model
        spec = [None] * nd
        spec[nd - 3] = "model"
        return P(*spec)
    if name in _COL:
        spec = [None] * nd
        spec[nd - 1] = "model"
        return P(*spec)
    if name in _ROW:
        spec = [None] * nd
        spec[nd - 2] = "model"
        return P(*spec)
    if name in _VEC_MODEL:
        spec = [None] * nd
        spec[nd - 1] = "model"
        return P(*spec)
    return P()  # replicated (norms, biases, router, B/C projections)


def param_pspecs(params) -> Any:
    """PartitionSpec pytree matching a parameter pytree."""
    return jax.tree_util.tree_map_with_path(_spec_for, params)


def param_shardings(mesh: Mesh, params) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_pspecs(params))


# ----------------------------------------------------------------------
# activation / batch specs
# ----------------------------------------------------------------------
def batch_pspecs(mesh: Mesh, specs: dict, *, seq_axis_for_cache=True) -> dict:
    """PartitionSpecs for an input_specs() dict (train/prefill/decode)."""
    da = data_axes(mesh)
    out = {}
    for key, val in specs.items():
        if key == "cache":
            out[key] = {k: _cache_spec(mesh, k, v) for k, v in val.items()}
        else:
            out[key] = _batch_spec(da, key, val)
    return out


def _batch_spec(da, key, v):
    if v.ndim == 0 or v.shape[0] == 1:
        # batch=1 cells (long_500k): parallelism lives in the sequence /
        # state dims; the batch dim is replicated.
        return P(*([None] * v.ndim))
    return P(da, *([None] * (v.ndim - 1)))


def _cache_spec(mesh, key, v):
    """Decode-cache shardings.  Batch over data axes; the long sequence
    dimension of KV caches over "model" (sequence-parallel cache); SSM
    states over heads ("model").  batch=1 long-context cells shard the
    sequence over data+model instead (DESIGN.md Sec. 5)."""
    da = data_axes(mesh)
    if v.ndim == 0:
        return P()
    if key in ("k", "v", "shared_k", "shared_v"):
        # [L, B, S, kv, dh]
        B = v.shape[1]
        if B == 1:
            return P(None, None, da + ("model",), None, None)
        return P(None, da, "model", None, None)
    if key == "state":        # [L, B, H, N, P]
        B = v.shape[1]
        return P(None, None if B == 1 else da, "model", None, None)
    if key in ("conv_x",):    # [L, B, K-1, d_inner]
        B = v.shape[1]
        return P(None, None if B == 1 else da, None, "model")
    if key in ("conv_B", "conv_C"):
        B = v.shape[1]
        return P(None, None if B == 1 else da, None, None)
    if key == "enc_out":      # [B, Sf, D]
        return P(da, None, None)
    return P()


def logical_out_shardings(mesh: Mesh, tree_spec) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_spec)
