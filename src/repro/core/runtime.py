"""OcclRuntime: the public host API of the deadlock-free collective library.

Mirrors the paper's integration contract (Sec. 4): register communicators
and collectives once, then ``submit`` from any rank in ANY order with an
optional completion callback; the runtime launches the daemon event-driven
and guarantees every submitted collective completes (assuming every member
rank eventually submits it — the same contract NCCL imposes, minus the
ordering requirement).

The runtime also exposes the observability used in the paper's Fig. 9 case
study: per-collective preemption (context-switch) counts and task-queue
lengths at fetch time.
"""
from __future__ import annotations

import collections
import dataclasses
import warnings
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .config import OcclConfig, ReduceOp
from .daemon import build_sim_daemon
from .primitives import (
    CollKind,
    CollectiveSpec,
    Communicator,
    derive_slicing,
    io_chunked,
)
from .sqcq import SQE, HostQueues
from .state import DaemonState, init_state
from .tables import StaticTables, build_tables


class RegistrationClosed(RuntimeError):
    pass


class DeadlockTimeout(RuntimeError):
    """drive() saw ``max_launches`` consecutive launches with NO progress
    (no completions reconciled and no slices moved) while work was still
    outstanding.

    With OCCL this means some member rank never submitted a matching
    collective (an application bug), NOT an ordering deadlock — inconsistent
    orders are handled by preemption.  Launches that make progress do not
    consume the budget: a long-lived workload may relaunch the daemon an
    unbounded number of times (the superstep budget is per launch)."""


class ConnDepthWarning(UserWarning):
    """conn_depth is too shallow to sustain the configured slice burst."""


class OcclRuntime:
    def __init__(self, cfg: OcclConfig, mesh=None, mesh_axis: str = "rank"):
        """mesh=None: sim backend (vmapped ranks on one device).
        mesh: a jax Mesh whose ``mesh_axis`` has cfg.n_ranks devices —
        the shard_map backend (ppermute connector fabric)."""
        self.cfg = cfg
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        self.comms: list[Communicator] = []
        self.specs: list[CollectiveSpec] = []
        self._heap_ptr = 0
        self._tables: Optional[StaticTables] = None
        self._daemon = None
        self._state: Optional[DaemonState] = None
        self.queues = HostQueues(cfg)
        self.launches = 0
        # Per-launch bookkeeping (relaunch observability): one record per
        # launch_once with the device epoch, the supersteps the launch ran,
        # the slices it moved and the completions it reconciled.  Bounded:
        # a long-lived runtime relaunches indefinitely, so only the most
        # recent window is kept (aggregates live in the device counters).
        self.launch_history: collections.deque = collections.deque(
            maxlen=1024)

    # ------------------------------------------------------------------
    # registration (paper Sec. 3.1.1)
    # ------------------------------------------------------------------
    def communicator(self, members: Sequence[int]) -> Communicator:
        if self._tables is not None:
            raise RegistrationClosed("register communicators before first launch")
        comm = Communicator(
            comm_id=len(self.comms), members=tuple(members),
            lane=len(self.comms))
        assert comm.lane < self.cfg.max_comms, "raise cfg.max_comms"
        self.comms.append(comm)
        return comm

    def _alloc(self, elems: int) -> int:
        off = self._heap_ptr
        self._heap_ptr += elems
        assert self._heap_ptr <= self.cfg.heap_elems, "raise cfg.heap_elems"
        return off

    def register(self, kind: CollKind, comm: Communicator, n_elems: int,
                 op: ReduceOp = ReduceOp.SUM, root: int = 0) -> int:
        """Register a collective; returns its unique id (paper Sec. 3.1.1)."""
        if self._tables is not None:
            raise RegistrationClosed("register collectives before first launch")
        cid = len(self.specs)
        assert cid < self.cfg.max_colls, "raise cfg.max_colls"
        ns, rounds = derive_slicing(
            n_elems, comm.size, self.cfg.slice_elems, self.cfg.conn_depth)
        chunk = rounds * ns * self.cfg.slice_elems
        padded = comm.size * chunk
        inc, outc = io_chunked(kind)
        in_off = self._alloc(padded if inc else chunk)
        out_off = self._alloc(padded if outc else chunk)
        spec = CollectiveSpec(
            coll_id=cid, kind=kind, comm=comm, n_elems=n_elems, op=int(op),
            root=root, in_off=in_off, out_off=out_off, n_slices=ns,
            n_rounds=rounds)
        self.specs.append(spec)
        return cid

    # ------------------------------------------------------------------
    # lazy build (first launch closes registration)
    # ------------------------------------------------------------------
    def _ensure_built(self):
        if self._tables is None:
            if (self.cfg.burst_slices > 1
                    and self.cfg.conn_depth < 3 * self.cfg.burst_slices):
                warnings.warn(
                    f"conn_depth={self.cfg.conn_depth} < 3 * burst_slices="
                    f"{3 * self.cfg.burst_slices}: the connector cannot "
                    "cover the burst credit round trip, so sustained "
                    "throughput relaxes to the 1-slice/superstep "
                    "equilibrium (no faster than burst_slices=1).  Set "
                    "conn_depth >= 3 * burst_slices or auto_conn_depth=True.",
                    ConnDepthWarning, stacklevel=3)
            self._tables = build_tables(self.cfg, self.comms, self.specs)
            if self.mesh is None:
                self._daemon = build_sim_daemon(self.cfg, self._tables)
            else:
                from .daemon import build_shardmap_daemon
                self._daemon = build_shardmap_daemon(
                    self.cfg, self._tables, self.mesh, self.mesh_axis)
            self._state = init_state(self.cfg, per_rank=True)

    @property
    def state(self) -> DaemonState:
        self._ensure_built()
        return self._state

    # ------------------------------------------------------------------
    # data movement (send/recv buffers live in the per-rank heap)
    # ------------------------------------------------------------------
    def _spec(self, coll_id: int) -> CollectiveSpec:
        return self.specs[coll_id]

    def _chunk_layout(self, spec: CollectiveSpec):
        sl = self.cfg.slice_elems
        chunk_pad = spec.n_rounds * spec.n_slices * sl
        chunk_log = -(-spec.n_elems // spec.group_size)  # ceil
        return chunk_pad, chunk_log

    def write_input(self, rank: int, coll_id: int, data: np.ndarray) -> None:
        """Place logical input data into the rank's heap (padded layout)."""
        self._ensure_built()
        spec = self._spec(coll_id)
        inc, _ = io_chunked(CollKind(spec.kind))
        chunk_pad, chunk_log = self._chunk_layout(spec)
        data = np.asarray(data).ravel()
        if inc:
            assert data.size == spec.n_elems
            buf = np.zeros(spec.group_size * chunk_pad, data.dtype)
            for k in range(spec.group_size):
                part = data[k * chunk_log:(k + 1) * chunk_log]
                buf[k * chunk_pad:k * chunk_pad + part.size] = part
        else:  # all-gather: input is the rank's own chunk
            assert data.size == chunk_log, (data.size, chunk_log)
            buf = np.zeros(chunk_pad, data.dtype)
            buf[:chunk_log] = data
        heap = self._state.heap_in
        heap = heap.at[rank, spec.in_off:spec.in_off + buf.size].set(
            jnp.asarray(buf, heap.dtype))
        self._state = self._state._replace(heap_in=heap)

    def write_inputs_bulk(self, writes: dict) -> None:
        """Batch heap writes: {(rank, coll_id): logical data} in ONE
        host->device transfer (the per-step fast path for grad sync)."""
        self._ensure_built()
        heap = np.array(self._state.heap_in)  # mutable host copy
        for (rank, coll_id), data in writes.items():
            spec = self._spec(coll_id)
            inc, _ = io_chunked(CollKind(spec.kind))
            chunk_pad, chunk_log = self._chunk_layout(spec)
            data = np.asarray(data).ravel()
            row = heap[rank]
            if inc:
                for k in range(spec.group_size):
                    part = data[k * chunk_log:(k + 1) * chunk_log]
                    off = spec.in_off + k * chunk_pad
                    row[off:off + part.size] = part
            else:
                row[spec.in_off:spec.in_off + data.size] = data
        self._state = self._state._replace(
            heap_in=jnp.asarray(heap, self._state.heap_in.dtype))

    def read_outputs_bulk(self, reads: list) -> dict:
        """Batch heap reads: [(rank, coll_id), ...] with ONE device->host
        transfer.  Returns {(rank, coll_id): logical output}."""
        self._ensure_built()
        heap = np.asarray(self._state.heap_out)
        out = {}
        for rank, coll_id in reads:
            spec = self._spec(coll_id)
            _, outc = io_chunked(CollKind(spec.kind))
            chunk_pad, chunk_log = self._chunk_layout(spec)
            row = heap[rank]
            if outc:
                o = np.zeros(spec.group_size * chunk_log, heap.dtype)
                for k in range(spec.group_size):
                    src = spec.out_off + k * chunk_pad
                    o[k * chunk_log:(k + 1) * chunk_log] = \
                        row[src:src + chunk_log]
                out[(rank, coll_id)] = o[:spec.n_elems]
            else:
                out[(rank, coll_id)] = \
                    row[spec.out_off:spec.out_off + chunk_log]
        return out

    def read_output(self, rank: int, coll_id: int) -> np.ndarray:
        """Gather logical output data from the rank's heap (un-pad)."""
        self._ensure_built()
        spec = self._spec(coll_id)
        _, outc = io_chunked(CollKind(spec.kind))
        chunk_pad, chunk_log = self._chunk_layout(spec)
        heap = np.asarray(self._state.heap_out[rank])
        if outc:
            out = np.zeros(spec.group_size * chunk_log, heap.dtype)
            for k in range(spec.group_size):
                src = spec.out_off + k * chunk_pad
                out[k * chunk_log:(k + 1) * chunk_log] = \
                    heap[src:src + chunk_log]
            return out[:spec.n_elems]
        return heap[spec.out_off:spec.out_off + chunk_log]

    # ------------------------------------------------------------------
    # submission + event-driven execution (paper Sec. 3.1.2 / 3.1.3)
    # ------------------------------------------------------------------
    def submit(self, rank: int, coll_id: int, prio: int = 0,
               data: Optional[np.ndarray] = None,
               callback: Optional[Callable[[int, int], None]] = None) -> None:
        self._ensure_built()
        if data is not None:
            self.write_input(rank, coll_id, data)
        self.queues.submit(rank, SQE(coll_id=coll_id, prio=prio,
                                     callback=callback))

    def submit_all(self, coll_id: int, prio: int = 0) -> None:
        spec = self._spec(coll_id)
        for r in spec.comm.members:
            self.submit(r, coll_id, prio=prio)

    def launch_once(self) -> int:
        """One daemon launch; returns #CQEs drained (may be 0)."""
        self._ensure_built()
        prev_slices = int(np.asarray(self._state.slices_moved).sum())
        st = self.queues.pack_sq(self._state)
        st = self._daemon(st)
        st = jax.block_until_ready(st)
        self.launches += 1
        self._state = st
        fired = self.queues.reconcile(st)
        self.launch_history.append({
            "epoch": int(np.asarray(st.epoch).max()),
            "launch_steps": int(np.asarray(st.launch_steps).max()),
            "slices_moved": int(np.asarray(st.slices_moved).sum())
                            - prev_slices,
            "completions": fired,
        })
        return fired

    def drive(self, max_launches: int = 64) -> None:
        """Event-driven daemon restarting: run while #CQE < #SQE (Sec. 3.1.3).

        ``max_launches`` bounds CONSECUTIVE launches without progress (no
        completions reconciled and no slices moved), not total launches: a
        workload whose span exceeds ``superstep_budget`` legitimately needs
        many launches, and each one that advances work resets the patience.
        """
        idle = 0
        while self.queues.outstanding() != 0:
            self.launch_once()
            rec = self.launch_history[-1]
            if rec["completions"] == 0 and rec["slices_moved"] == 0:
                idle += 1
            else:
                idle = 0
            if idle >= max_launches:
                raise DeadlockTimeout(
                    f"{self.queues.outstanding()} collectives outstanding "
                    f"after {idle} consecutive daemon launches without "
                    f"progress ({self.launches} total) — a member rank "
                    f"never submitted a matching collective")

    # ------------------------------------------------------------------
    # observability (paper Fig. 9)
    # ------------------------------------------------------------------
    def stats(self) -> dict:
        self._ensure_built()
        st = self._state
        return {
            "preempts": np.asarray(st.preempts),          # [R, C]
            "stall_slices": np.asarray(st.stall_slices),  # [R, C] — burst
                                                          # slices denied by
                                                          # the credit gate
            "qlen_at_fetch": np.asarray(st.qlen_at_fetch),
            "completed": np.asarray(st.completed),
            "supersteps": np.asarray(st.supersteps),      # cumulative epoch
                                                          # clock (never
                                                          # reset)
            "launch_steps": np.asarray(st.launch_steps),  # last launch only
            "epoch": np.asarray(st.epoch),                # device launch
                                                          # counter
            "slices_moved": np.asarray(st.slices_moved),
            "cq_count": np.asarray(st.cq_count),          # [R] — may exceed
                                                          # cq_len (ring CQ)
            "burst_slices": self.cfg.burst_slices,
            "launches": self.launches,
            "launch_history": list(self.launch_history),
        }
