"""Hypothesis property sweep for the staging engine: the bulk heap-I/O
path (``write_inputs_bulk`` -> drive -> ``read_outputs_bulk``) is
observationally identical to the scalar path (``write_input`` -> drive ->
``read_output``) for every CollKind, arbitrary (odd) sizes that exercise
padding, and repeated steps over a reused heap.

Skipped entirely when hypothesis is not installed (tier-1 containers);
``pip install -r requirements-dev.txt`` restores the sweep.  The
deterministic fallback lives in test_staging.py.
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import CollKind, OcclConfig, OcclRuntime

KINDS = list(CollKind)


def _ragged_sizes(n, R):
    """Per-distance live counts with real capacity drops at odd n."""
    cl = -(-n // R)
    return tuple(max(0, cl - 2 * d) for d in range(R))


def _norm_coll(kind, n, R):
    """(n_elems, chunk_sizes, logical payload size) honoring the a2a
    registration contracts (exactly-divisible totals; explicit ragged
    per-distance sizes) for an arbitrary drawn n."""
    if kind == CollKind.ALL_TO_ALL:
        ne = max(R, n - n % R)
        return ne, None, ne
    if kind == CollKind.ALL_TO_ALL_RAGGED:
        sizes = _ragged_sizes(n, R)
        return n, sizes, sum(sizes)
    return n, None, n


def _payload_n(kind, n, R):
    if kind == CollKind.ALL_GATHER:
        return -(-n // R)
    return _norm_coll(kind, n, R)[2]


def _mk_runtime(R, colls):
    cfg = OcclConfig(n_ranks=R, max_colls=max(2, len(colls)), max_comms=1,
                     slice_elems=8, conn_depth=4, heap_elems=1 << 14)
    rt = OcclRuntime(cfg)
    comm = rt.communicator(list(range(R)))
    ids = []
    for kind, n, root in colls:
        ne, cs, _ = _norm_coll(kind, n, R)
        ids.append(rt.register(kind, comm, n_elems=ne, root=root,
                               chunk_sizes=cs))
    return rt, ids


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_bulk_path_equals_scalar_path(data):
    R = data.draw(st.integers(2, 4), label="ranks")
    n_coll = data.draw(st.integers(1, 3), label="n_coll")
    colls = []
    for i in range(n_coll):
        kind = data.draw(st.sampled_from(KINDS), label=f"kind{i}")
        n = data.draw(st.integers(1, 60), label=f"n{i}")
        root = data.draw(st.integers(0, R - 1), label=f"root{i}")
        colls.append((kind, n, root))
    steps = data.draw(st.integers(1, 3), label="steps")
    seed = data.draw(st.integers(0, 1000), label="seed")

    rt_s, ids_s = _mk_runtime(R, colls)
    rt_b, ids_b = _mk_runtime(R, colls)
    rng = np.random.RandomState(seed)

    for _ in range(steps):                 # reused heap across steps
        writes = {}
        for (kind, n, root), cs, cb in zip(colls, ids_s, ids_b):
            pn = _payload_n(kind, n, R)
            xs = [rng.randn(pn).astype(np.float32) for _ in range(R)]
            for r in range(R):
                d = xs[root] if kind == CollKind.BROADCAST else xs[r]
                rt_s.write_input(r, cs, d)
                rt_s.submit(r, cs)
                writes[(r, cb)] = d
                rt_b.submit(r, cb)
        rt_b.write_inputs_bulk(writes)
        rt_s.drive()
        rt_b.drive()

        bulk = rt_b.read_outputs_bulk(
            [(r, cb) for cb in ids_b for r in range(R)])
        for cs, cb in zip(ids_s, ids_b):
            for r in range(R):
                np.testing.assert_array_equal(
                    bulk[(r, cb)], rt_s.read_output(r, cs))

    # Bulk heap contents end bit-identical to the scalar path's, pads
    # included (the stale-padding invariant).
    np.testing.assert_array_equal(np.asarray(rt_b.state.heap_in),
                                  np.asarray(rt_s.state.heap_in))


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_staged_submit_equals_explicit_write(data):
    """submit(data=...) staging + prologue flush lands exactly where an
    explicit pre-write would (same heap, same outputs)."""
    R = data.draw(st.integers(2, 4), label="ranks")
    kind = data.draw(st.sampled_from(KINDS), label="kind")
    n = data.draw(st.integers(1, 48), label="n")
    seed = data.draw(st.integers(0, 1000), label="seed")

    rng = np.random.RandomState(seed)
    xs = [rng.randn(_payload_n(kind, n, R)).astype(np.float32)
          for _ in range(R)]

    outs = []
    for staged in (True, False):
        rt, (cid,) = _mk_runtime(R, [(kind, n, 0)])
        for r in range(R):
            d = xs[0] if kind == CollKind.BROADCAST else xs[r]
            if staged:
                rt.submit(r, cid, data=d)
            else:
                rt.write_input(r, cid, d)
                rt.submit(r, cid)
        rt.drive()
        outs.append([rt.read_output(r, cid) for r in range(R)])
    for a, b in zip(*outs):
        np.testing.assert_array_equal(a, b)
