"""Unit tests: primitive programs (paper Sec. 2.3) and slicing invariants."""
import numpy as np
import pytest

from repro.core.primitives import (CollKind, Prim, PRIM_RECV, PRIM_SEND,
                                   build_program, derive_slicing,
                                   io_chunked, program_len)


@pytest.mark.parametrize("kind", list(CollKind))
@pytest.mark.parametrize("R", [2, 3, 4, 8])
def test_program_lengths(kind, R):
    for m in range(R):
        prog = build_program(kind, m, R)
        assert len(prog) == program_len(kind, R)


@pytest.mark.parametrize("R", [2, 3, 4, 8])
def test_allreduce_transfer_counts(R):
    """Ring all-reduce: every rank sends and receives exactly 2(R-1)
    chunks (the bandwidth-optimality invariant)."""
    for m in range(R):
        prog = build_program(CollKind.ALL_REDUCE, m, R)
        sends = sum(PRIM_SEND[p] for p, _ in prog)
        recvs = sum(PRIM_RECV[p] for p, _ in prog)
        assert sends == 2 * (R - 1)
        assert recvs == 2 * (R - 1)


@pytest.mark.parametrize("R", [2, 3, 4, 8])
def test_allreduce_chunk_coverage(R):
    """Each rank's copy-steps cover all R chunks exactly once."""
    from repro.core.primitives import PRIM_COPY
    for m in range(R):
        prog = build_program(CollKind.ALL_REDUCE, m, R)
        copies = sorted(c for p, c in prog if PRIM_COPY[p])
        assert copies == list(range(R))


@pytest.mark.parametrize("R", [2, 4, 8])
def test_reduce_scatter_final_chunk(R):
    """Rank m finalizes chunk m (recvReduceCopy last)."""
    for m in range(R):
        prog = build_program(CollKind.REDUCE_SCATTER, m, R)
        prim, chunk = prog[-1]
        assert prim == Prim.RECV_REDUCE_COPY
        assert chunk == m


@pytest.mark.parametrize("R", [2, 3, 5])
@pytest.mark.parametrize("root", [0, 1])
def test_broadcast_roles(R, root):
    progs = [build_program(CollKind.BROADCAST, m, R, root) for m in range(R)]
    # root only sends; the last-in-chain rank only receives
    assert all(p == Prim.COPY_SEND for p, _ in progs[root])
    last = (root - 1) % R
    assert all(p == Prim.RECV for p, _ in progs[last])


def test_slicing_caps_rounds():
    """Per-round slices <= conn_depth - 1 (the wedge-freedom invariant)."""
    for n in [1, 5, 64, 1000, 12345]:
        for R in [2, 4, 8]:
            for K in [2, 4, 8]:
                per, rounds = derive_slicing(n, R, 16, K)
                assert per <= K - 1
                assert per * rounds * 16 * R >= n
