"""Pallas TPU kernel: bulk chunk combine (the ring-reduce workhorse).

``recvReduceSend`` over a whole chunk at bandwidth: elementwise combine of
two flat buffers with f32 accumulation for bf16 wire payloads.  Used by the
bulk static-path collectives (grad-bucket ring reduce) where whole chunks
move per superstep rather than single slices.

Grid: 1-D over tiles of TILE elements; each instance streams one VMEM tile
of ``a`` and ``b`` and writes one tile of the result — HBM traffic is
exactly 2 reads + 1 write per element (roofline-optimal for this op).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 1024


def _kernel(a_ref, b_ref, o_ref, *, op: int):
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    if op == 0:
        v = a + b
    elif op == 1:
        v = jnp.maximum(a, b)
    elif op == 2:
        v = jnp.minimum(a, b)
    else:
        v = a * b
    o_ref[...] = v.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("op", "interpret"))
def chunk_combine_pallas(a: jnp.ndarray, b: jnp.ndarray, op: int = 0, *,
                         interpret: bool = True) -> jnp.ndarray:
    """Elementwise combine of flat [T] buffers (T padded to TILE)."""
    (T,) = a.shape
    pad = (-T) % TILE
    if pad:
        a = jnp.pad(a, (0, pad))
        b = jnp.pad(b, (0, pad))
    n = (T + pad) // TILE
    out = pl.pallas_call(
        functools.partial(_kernel, op=op),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((T + pad,), a.dtype),
        interpret=interpret,
    )(a, b)
    return out[:T]
