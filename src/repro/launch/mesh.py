"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod ("data", "model"); 2 pods adds the "pod" axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int | None = None):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    model = model or (2 if n % 2 == 0 and n > 1 else 1)
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
