"""In-trace submission and progress: the device-side OCCL API.

The host API (:class:`~repro.core.runtime.OcclRuntime`) submits SQEs from
Python and drives the daemon between jitted programs.  This module is the
same contract INSIDE a traced step: pure functions over
:class:`~repro.core.state.DaemonState` that write payloads into the heap,
append SQEs, advance the daemon by bounded ticks
(:func:`~repro.core.daemon.build_sim_tick`) and gather results — all
traceable under ``jit``/``lax.while_loop``/``custom_vjp``, which is what
lets gradient buckets be submitted mid-backward and MoE expert compute
start while the dispatch all-to-all tail is still in flight.

Conventions (sim backend; state leaves carry the leading [R] rank axis):

* :meth:`DeviceApi.step_prologue` opens a step: it resets the SQ/CQ
  cursors (the in-trace analogue of ``HostQueues.pack_sq``) and runs the
  daemon launch prologue.  Call it ONCE per step — mid-step relaunches
  after a voluntary quit reuse ``launch_prologue`` only (resetting
  ``sq_read`` would re-fetch already-consumed SQEs).
* :meth:`DeviceApi.submit` writes the padded heap span (pads zero-filled)
  and appends an SQE at ``sq_size`` — so per-step submissions per rank
  must fit ``cfg.sq_len`` (size the config accordingly; overflow drops
  the SQE and poisons nothing).
* :meth:`DeviceApi.tick` auto-relaunches (prologue) when the fabric went
  not-live with work still pending — the in-trace analogue of drive()'s
  event-driven restart.
* ``custom_vjp`` boundaries cannot carry integer/bool pytrees as
  cotangents (they get ``float0`` tangents); :func:`encode_state` /
  :func:`decode_state` bitcast the whole state to/from an all-``float32``
  pytree LOSSLESSLY so a DaemonState can ride a gradient token.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .daemon import (
    TickFlags,
    _drained,
    build_sim_tick,
    launch_prologue,
)
from .state import DaemonState


# ---------------------------------------------------------------------------
# Lossless state <-> float32 encoding (custom_vjp token threading)
# ---------------------------------------------------------------------------
def encode_state(st: DaemonState) -> DaemonState:
    """Bitcast every leaf to ``float32`` (losslessly; same pytree shape).

    i32 and bool (via i32) leaves are bit-pattern casts; 16-bit float
    heaps widen exactly.  The result is a valid cotangent pytree for a
    ``custom_vjp`` whose primal output is a same-structure float token.
    """
    def enc(a):
        if a.dtype == jnp.bool_:
            return jax.lax.bitcast_convert_type(
                a.astype(jnp.int32), jnp.float32)
        if a.dtype == jnp.int32:
            return jax.lax.bitcast_convert_type(a, jnp.float32)
        if a.dtype in (jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float16)):
            return a.astype(jnp.float32)          # exact widening
        assert a.dtype == jnp.float32, a.dtype
        return a

    return jax.tree_util.tree_map(enc, st)


def decode_state(enc, like: DaemonState) -> DaemonState:
    """Inverse of :func:`encode_state`; ``like`` supplies target dtypes."""
    def dec(a, ref):
        if ref.dtype == jnp.bool_:
            return jax.lax.bitcast_convert_type(
                a, jnp.int32).astype(jnp.bool_)
        if ref.dtype == jnp.int32:
            return jax.lax.bitcast_convert_type(a, jnp.int32)
        if ref.dtype != jnp.float32:
            return a.astype(ref.dtype)            # exact narrowing back
        return a

    return jax.tree_util.tree_map(dec, enc, like)


def encoded_zeros(like: DaemonState) -> DaemonState:
    """An all-zero encoded token with the structure encode_state returns
    (the primal token a custom_vjp forward emits)."""
    return jax.tree_util.tree_map(
        lambda a: jnp.zeros(a.shape, jnp.float32), like)


class DeviceApi:
    """Pure in-trace submission/tick/read API over a built sim runtime.

    Construct it AFTER registration closed (first launch or an explicit
    ``runtime.state`` touch); it snapshots the runtime's static tables,
    chain routing and heap layout.  All methods are pure state -> state
    functions safe inside ``jit``; none touch the runtime.  After the
    step completes on device, hand the final state back to the host with
    ``runtime.adopt_state(st)`` so host-side reconciliation stays
    consistent.
    """

    def __init__(self, rt):
        rt._ensure_built()
        if rt.mesh is not None:
            raise NotImplementedError(
                "DeviceApi targets the sim backend ([R, ...] state on one "
                "device); mesh-backend in-step ticks go through "
                "runtime.tick_fn() + shard_map composition")
        self.cfg = rt.cfg
        self._rt = rt
        # Elastic-shrink staleness stamp: runtime.evict() bumps the
        # runtime generation and drops its DeviceApi cache; an api object
        # the USER kept across the shrink still points at the old tables/
        # heap layout, so its step entrypoint refuses to trace.
        self._generation = rt._generation
        self._t = rt._tables
        self._specs = list(rt.specs)
        self._entry_of = {h: dict(m) for h, m in rt._entry_of.items()}
        self._rank_tail = {h: dict(m) for h, m in rt._rank_tail.items()}
        self._tail_of = dict(rt._tail_of)
        self._tick = build_sim_tick(self.cfg, self._t, barrier=False)
        self._tick_barrier = build_sim_tick(self.cfg, self._t, barrier=True)

    @property
    def stale(self) -> bool:
        """True once ``runtime.evict()`` rebuilt past this snapshot."""
        return self._generation != self._rt._generation

    def _check_current(self) -> None:
        if self.stale:
            from .errors import EvictionError
            raise EvictionError(
                f"DeviceApi snapshot of generation {self._generation} is "
                f"stale: the runtime is at generation "
                f"{self._rt._generation} after evict() — fetch a fresh "
                "runtime.device_api()")

    # -- routing helpers ---------------------------------------------------
    def _out_cid(self, coll_id: int) -> int:
        return self._tail_of.get(coll_id, coll_id)

    def out_elems(self, coll_id: int) -> int:
        return int(self._t.out_log[self._out_cid(coll_id)])

    def in_elems(self, coll_id: int) -> int:
        return int(self._t.in_log[coll_id])

    # -- step boundary -----------------------------------------------------
    def step_prologue(self, st: DaemonState) -> DaemonState:
        """Open a step: clear the SQ/CQ (every cursor and entry — the
        in-trace ``pack_sq``) and run the daemon launch prologue.  ONCE
        per step; see module docstring."""
        self._check_current()   # trace-time guard; no-op on traced values
        st = st._replace(
            sq_coll=jnp.full_like(st.sq_coll, -1),
            sq_prio=jnp.zeros_like(st.sq_prio),
            sq_in=jnp.full_like(st.sq_in, -1),
            sq_out=jnp.full_like(st.sq_out, -1),
            sq_size=jnp.zeros_like(st.sq_size),
            sq_read=jnp.zeros_like(st.sq_read),
            cq_coll=jnp.full_like(st.cq_coll, -1),
            cq_count=jnp.zeros_like(st.cq_count),
        )
        return launch_prologue(st)

    # -- submission --------------------------------------------------------
    def submit(self, st: DaemonState, rank: int, coll_id: int,
               data: jnp.ndarray, prio: int = 0) -> DaemonState:
        """Stage ``data`` ([in_log[coll_id]], traced) into rank's padded
        input span and append one SQE (registered buffer offsets; chain
        submissions are routed to the rank's entry stage exactly like the
        host path).  ``rank``/``coll_id``/``prio`` are static ints."""
        t, spec = self._t, self._specs[coll_id]
        span = int(t.in_span[coll_id])
        vals = jnp.zeros((span,), st.heap_in.dtype)
        vals = vals.at[jnp.asarray(t.stage_in_map[coll_id])].set(
            data.astype(st.heap_in.dtype))
        lo = spec.in_off
        st = st._replace(
            heap_in=st.heap_in.at[rank, lo:lo + span].set(vals))
        entry = self._entry_of.get(coll_id, {}).get(rank, coll_id)
        idx = st.sq_size[rank]
        ok = idx < self.cfg.sq_len
        slot = jnp.minimum(idx, self.cfg.sq_len - 1)
        put = lambda a, v: a.at[rank, slot].set(jnp.where(ok, v, a[rank, slot]))
        return st._replace(
            sq_coll=put(st.sq_coll, entry),
            sq_prio=put(st.sq_prio, prio),
            sq_in=put(st.sq_in, -1),
            sq_out=put(st.sq_out, -1),
            sq_size=st.sq_size.at[rank].add(ok.astype(jnp.int32)),
        )

    def submit_all(self, st: DaemonState, coll_id: int, data: jnp.ndarray,
                   prio: int = 0) -> DaemonState:
        """``data`` is [R, in_log[coll_id]]; one submit per member rank."""
        members = self._specs[coll_id].comm.members
        for r in members:
            st = self.submit(st, r, coll_id, data[r], prio=prio)
        return st

    # -- results -----------------------------------------------------------
    def read(self, st: DaemonState, rank: int, coll_id: int) -> jnp.ndarray:
        """Gather rank's logical output ([out_log], traced, heap dtype);
        composite ids read their chain tail's region."""
        tcid = self._out_cid(coll_id)
        lo = self._specs[tcid].out_off
        return st.heap_out[rank, lo + jnp.asarray(self._t.stage_out_map[tcid])]

    def read_all(self, st: DaemonState, coll_id: int) -> jnp.ndarray:
        tcid = self._out_cid(coll_id)
        lo = self._specs[tcid].out_off
        idx = lo + jnp.asarray(self._t.stage_out_map[tcid])
        return st.heap_out[:, idx]

    def completed(self, st: DaemonState, coll_id: int) -> jnp.ndarray:
        """[R] cumulative logical completions of ``coll_id`` (its chain
        tail) — the gating signal for already-arrived-granule compute."""
        return st.completed[:, self._out_cid(coll_id)]

    # -- progress ----------------------------------------------------------
    def _relaunch_if_stalled(self, st: DaemonState) -> DaemonState:
        """Mid-step event-driven restart: when the fabric went not-live
        (drain/quit/budget) but work is pending, run the launch prologue
        — and ONLY the prologue; SQ cursors must survive."""
        need = ~st.global_live[0] & ~jnp.all(jax.vmap(_drained)(st))
        return jax.lax.cond(need, launch_prologue, lambda s: s, st)

    def tick(self, st: DaemonState, k,
             barrier: bool = False) -> tuple[DaemonState, TickFlags]:
        """Advance up to ``k`` supersteps (auto-relaunching first if the
        previous tick ended the launch with work still pending).
        ``barrier`` is the static accounting tag: True when the caller
        blocks on this tick, False when it hides behind compute."""
        st = self._relaunch_if_stalled(st)
        fn = self._tick_barrier if barrier else self._tick
        return fn(st, k)

    def tick_until(self, st: DaemonState, done_fn: Callable, chunk: int = 8,
                   max_iters: int = 1024,
                   barrier: bool = False) -> DaemonState:
        """Tick in ``chunk``-superstep slices until ``done_fn(state)`` (a
        traced [] bool) holds or ``max_iters`` slices elapse (bounded so a
        missing peer submission cannot hang the jitted step)."""
        def cond(carry):
            st, it = carry
            return ~done_fn(st) & (it < max_iters)

        def body(carry):
            st, it = carry
            st, _ = self.tick(st, jnp.int32(chunk), barrier=barrier)
            return st, it + jnp.int32(1)

        st, _ = jax.lax.while_loop(cond, body, (st, jnp.int32(0)))
        return st

    def drain(self, st: DaemonState, chunk: int = 16,
              max_iters: int = 1024) -> DaemonState:
        """Barrier-tick until every rank's submitted work completed — the
        step's only EXPOSED communication when overlap worked."""
        return self.tick_until(
            st, lambda s: jnp.all(jax.vmap(_drained)(s)),
            chunk=chunk, max_iters=max_iters, barrier=True)
