"""Serving launcher.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b \
        --requests 8 --max-new 16

Reduced configs run end-to-end on this host; full configs are validated
via the decode/prefill dry-run cells (launch/dryrun.py) and deploy with
the same jitted prefill/serve_step on a real mesh.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    args = ap.parse_args()

    from ..configs import get_config
    from ..serving.engine import Request, ServingEngine

    cfg = get_config(args.arch).reduced()
    eng = ServingEngine(cfg, batch_size=args.batch,
                        prompt_len=args.prompt_len,
                        max_len=args.prompt_len + args.max_new + 8)
    rng = np.random.RandomState(0)
    for i in range(args.requests):
        eng.submit(Request(
            rid=i, prompt=rng.randint(0, cfg.vocab,
                                      size=rng.randint(4, args.prompt_len)),
            max_new_tokens=args.max_new))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    print(f"{args.arch}: {len(done)} requests, "
          f"{eng.stats['tokens']} tokens in {dt:.2f}s "
          f"({eng.stats['tokens']/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
