"""OCCL-based gradient synchronization (the paper's DNN-training use).

Gradients are flattened into size-bounded BUCKETS (paper Sec. 5.3.1: 161
all-reduces for ResNet50, one per parameter tensor group).  Each bucket is
registered once as an OCCL all-reduce on the DP communicator; every step
the ranks submit their buckets **in backward order with rising priority**
(the Priority-based Ordering policy of Sec. 3.2 — later gradients are
needed first by the optimizer of the next layer-ordered pass, so they
overlap with remaining backward compute), and the daemon gang-schedules
them decentrally.

Ranks here are the simulated DP workers of the sim backend (one device,
vmapped) — the same scheduler core drives the shard_map mesh backend on a
real fleet.  The "static" comparator (statically-sequenced NCCL of the
paper's Sec. 5) is plain jnp summation in a fixed bucket order.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core import CollKind, OcclConfig, OcclRuntime, OrderPolicy


@dataclasses.dataclass
class Bucket:
    coll_id: int
    leaf_ids: list[int]
    sizes: list[int]
    total: int


class OcclGradSync:
    """compress_wire: bf16 gradient payloads on the connector fabric
    (half the wire bytes; accumulation stays f32 on-host via the heap
    dtype) — the gradient-compression option of DESIGN.md §6."""

    def __init__(self, grads_template, n_ranks: int,
                 bucket_elems: int = 4096, slice_elems: int = 256,
                 priority_preempts: bool = False,
                 compress_wire: bool = False,
                 hierarchy: tuple | None = None,
                 burst_slices: int = 1,
                 bandwidth_groups: int = 0,
                 intra_burst_cap: int = 0,
                 inter_burst_cap: int = 0):
        """``hierarchy=(G, N)`` routes every bucket through the composite
        two-level all-reduce (intra-group reduce-scatter -> inter-group
        all-reduce -> intra-group all-gather over the G x N rank grid,
        chained on device) instead of the flat ring — the node-aware
        topology of real fleets, where N is the intra-node (fast-domain)
        size.  Requires G * N == n_ranks.

        ``burst_slices``/``bandwidth_groups``/``intra_burst_cap``/
        ``inter_burst_cap`` forward the bandwidth-skew lane model
        (config.py) into the grad-sync runtime — the setting the overlap
        perf gate measures under (skewed lanes need ``burst_slices > 1``
        for the caps to differentiate intra/inter traffic)."""
        leaves = jax.tree_util.tree_leaves(grads_template)
        self.treedef = jax.tree_util.tree_structure(grads_template)
        self.shapes = [l.shape for l in leaves]
        self.dtypes = [l.dtype for l in leaves]
        self.n_ranks = n_ranks

        # --- bucketize leaves in reverse (backward) order ----------------
        buckets: list[Bucket] = []
        cur_ids: list[int] = []
        cur_sizes: list[int] = []
        cur_total = 0
        for i in reversed(range(len(leaves))):
            n = int(np.prod(leaves[i].shape))
            if cur_total + n > bucket_elems and cur_ids:
                buckets.append(Bucket(-1, cur_ids, cur_sizes, cur_total))
                cur_ids, cur_sizes, cur_total = [], [], 0
            cur_ids.append(i)
            cur_sizes.append(n)
            cur_total += n
        if cur_ids:
            buckets.append(Bucket(-1, cur_ids, cur_sizes, cur_total))
        self.buckets = buckets

        heap = sum(2 * b.total + 64 * len(buckets) for b in buckets)
        self.compress_wire = compress_wire
        self.hierarchy = hierarchy
        if hierarchy is not None:
            G, N = hierarchy
            assert G * N == n_ranks, (
                f"hierarchy {hierarchy} does not tile {n_ranks} ranks")
        # A two-level bucket is a 3-stage chain: 3 collective slots per
        # bucket, two lanes (all buckets share the derived intra and inter
        # partitions; the logical group claims NO lane of its own), and
        # intermediate heap regions (~2x per side).
        n_colls = len(buckets) * (3 if hierarchy is not None else 1)
        self.occl = OcclRuntime(OcclConfig(
            n_ranks=n_ranks,
            max_colls=max(8, n_colls),
            max_comms=2 if hierarchy is not None else 1,
            slice_elems=slice_elems,
            conn_depth=max(8, 3 * burst_slices),
            burst_slices=burst_slices,
            heap_elems=max(1 << 14, 4 * heap)
                       * (2 if hierarchy is not None else 1),
            # In-step submission appends one SQE per bucket per rank into
            # the device SQ (no host pack_sq between them) — the SQ must
            # hold a whole step's buckets.
            sq_len=max(64, len(buckets) + 4),
            order_policy=OrderPolicy.PRIORITY,
            priority_preempts=priority_preempts,
            superstep_budget=1 << 16,
            dtype="bfloat16" if compress_wire else "float32",
            bandwidth_groups=bandwidth_groups,
            intra_burst_cap=intra_burst_cap,
            inter_burst_cap=inter_burst_cap,
        ))
        comm = (self.occl.communicator(list(range(n_ranks)))
                if hierarchy is None
                else self.occl.logical_communicator(list(range(n_ranks))))
        for b in buckets:
            b.coll_id = self.occl.register(
                CollKind.ALL_REDUCE, comm, n_elems=b.total,
                algo="ring" if hierarchy is None else "two_level",
                hierarchy=hierarchy)

    # ------------------------------------------------------------------
    def _pack(self, grads, bucket: Bucket) -> np.ndarray:
        leaves = jax.tree_util.tree_leaves(grads)
        parts = [np.asarray(leaves[i], np.float32).ravel()
                 for i in bucket.leaf_ids]
        out = np.concatenate(parts)
        if self.compress_wire:
            out = np.asarray(jnp.asarray(out, jnp.bfloat16))
        return out

    # -- overlap-mode helpers (train/step.py custom_vjp boundaries) -------
    def device_api(self):
        """The runtime's in-trace submission/tick API (core/device_api.py)
        bound to this sync's bucket registrations."""
        return self.occl.device_api()

    def unflatten(self, flats_by_bucket: Sequence) -> object:
        """Rebuild one rank's gradient pytree from per-bucket flat traced
        arrays (already averaged), in bucket-index order."""
        leaves = [None] * len(self.shapes)
        for b, flat in zip(self.buckets, flats_by_bucket):
            off = 0
            for i, n in zip(b.leaf_ids, b.sizes):
                leaves[i] = flat[off:off + n].reshape(
                    self.shapes[i]).astype(self.dtypes[i])
                off += n
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    def all_reduce(self, per_rank_grads: Sequence) -> list:
        """Average gradients across ranks via OCCL collectives.

        per_rank_grads: list of grad pytrees (one per DP rank, any
        submission order is fine — the runtime is deadlock-free)."""
        assert len(per_rank_grads) == self.n_ranks
        for prio, b in enumerate(self.buckets):
            for r in range(self.n_ranks):
                # Payloads are STAGED host-side and flushed to the device
                # in one batched scatter by the first launch prologue —
                # one staging transfer per step (runtime._flush_staged).
                self.occl.submit(r, b.coll_id, prio=prio,
                                 data=self._pack(per_rank_grads[r], b))
        self.occl.drive()
        reads = self.occl.read_outputs_bulk(
            [(r, b.coll_id) for r in range(self.n_ranks)
             for b in self.buckets])

        outs = []
        for r in range(self.n_ranks):
            leaves = [None] * len(self.shapes)
            for b in self.buckets:
                # read_outputs_bulk returns owned copies, so the average
                # can be taken in place without corrupting sibling reads.
                flat = np.asarray(reads[(r, b.coll_id)], np.float32)
                flat /= self.n_ranks
                off = 0
                for i, n in zip(b.leaf_ids, b.sizes):
                    leaves[i] = jnp.asarray(
                        flat[off:off + n].reshape(self.shapes[i]),
                        self.dtypes[i])
                    off += n
            outs.append(jax.tree_util.tree_unflatten(self.treedef, leaves))
        return outs

    def evict(self, rank: int) -> dict:
        """Elastically drop one DP worker: delegates to
        ``OcclRuntime.evict`` (drain -> rebuild for R-1 -> replay) and
        shrinks this sync's own rank count.  Bucket registrations survive
        via their :class:`~repro.core.handles.CollectiveHandle`\\ s —
        ``all_reduce`` keeps working unchanged on the smaller fleet, and
        a mid-flight eviction replays the surviving ranks' staged bucket
        payloads.  A two-level hierarchy that no longer tiles the shrunk
        fleet falls back to the auto-derived grid (evict()'s replay
        rule), so ``self.hierarchy`` is cleared when it stops tiling."""
        report = self.occl.evict(rank)
        self.n_ranks = self.occl.cfg.n_ranks
        if self.hierarchy is not None:
            G, N = self.hierarchy
            if G * N != self.n_ranks:
                self.hierarchy = None
        return report

    def stats(self):
        return self.occl.stats()


def static_all_reduce(per_rank_grads: Sequence) -> list:
    """The statically-sequenced baseline: fixed-order averaging."""
    n = len(per_rank_grads)
    avg = jax.tree_util.tree_map(
        lambda *xs: sum(x.astype(jnp.float32) for x in xs) / n,
        *per_rank_grads)
    return [jax.tree_util.tree_map(
        lambda a, t: a.astype(t.dtype), avg, per_rank_grads[0])
        for _ in range(n)]
