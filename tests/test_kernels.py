"""Pallas kernels vs ref.py oracles: shape/dtype sweeps in interpret mode.

Hypothesis property tests on the fused-primitive semantics live in
test_kernels_props.py (skipped when hypothesis is absent)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.chunk_combine import chunk_combine_pallas
from repro.kernels.fused_slice import fused_primitive_pallas


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S", [(1, 8), (1, 64), (3, 512), (2, 1024),
                                 (4, 96)])
def test_fused_primitive_sweep(dtype, B, S):
    rng = np.random.RandomState(B * 1000 + S)
    p = jnp.asarray(rng.randn(B, S), dtype)
    l = jnp.asarray(rng.randn(B, S), dtype)
    f = jnp.asarray(rng.randint(0, 2, (B, 4)), jnp.int32)
    f = f.at[:, 3].set(jnp.asarray(rng.randint(0, 4, (B,)), jnp.int32))
    got = fused_primitive_pallas(p, l, f, interpret=True)
    want = ops.fused_primitive_ref(p, l, f)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T", [8, 1000, 1024, 4096, 5000])
@pytest.mark.parametrize("op", [0, 1, 2, 3])
def test_chunk_combine_sweep(dtype, T, op):
    rng = np.random.RandomState(T + op)
    a = jnp.asarray(rng.randn(T), dtype)
    b = jnp.asarray(rng.randn(T), dtype)
    got = chunk_combine_pallas(a, b, op, interpret=True)
    want = ops.chunk_combine_ref(a, b, op)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=1e-6)
