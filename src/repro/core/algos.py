"""Collective algorithm registry + the composite-collective IR.

Two layers live here:

* **Algorithm registry** — the per-kind ring program builders that used to
  be inlined in :func:`repro.core.primitives.build_program` are registered
  under ``("ring", kind)`` keys, so alternative single-communicator
  algorithms (tree, bucket, ...) can be added without touching the
  builder dispatch.  ``build_ring_program`` is the registry-backed
  entrypoint; ``primitives.build_program`` delegates here.

* **CompositePlan IR** — a logical collective over a ``G x N`` rank grid
  lowered into a CHAIN of ring sub-collectives over derived
  sub-communicators.  The canonical plan is the two-level all-reduce of
  "The Big Send-off" (PAPERS.md): intra-group reduce-scatter -> inter-group
  all-reduce over chunk owners -> intra-group all-gather, which replaces
  the flat ring's ``2R - 1`` latency steps with ``N + (2G - 1) + N``.
  Each stage is an ordinary registered collective; the chain edges become
  the registration-time successor tables that let the daemon advance a
  chain ON DEVICE (scheduler.lanes_step enqueues the successor SQE in the
  same superstep its predecessor completes).

Chained sub-collectives are exactly the inter-collective dependencies the
source paper warns about (circular collective dependency, Sec. 1): stage
k+1 on one rank waits for stage k on OTHER ranks.  The OCCL scheduler's
preemption keeps composed chains deadlock-free the same way it keeps
independently submitted collectives deadlock-free — the deadlock-freedom
property sweep covers chains submitted in conflicting orders.

Plan registry (the algorithm zoo)
---------------------------------
Multi-stage lowerings are registered in ``PLAN_BUILDERS`` under
``(algo_name, kind)`` keys; :func:`build_plan` is the dispatch.  Shipped
plans over a ``G x N`` grid (root at grid position ``(g0, m0) =
divmod(root, N)``):

* ``two_level`` ALL_REDUCE — intra reduce-scatter -> inter all-reduce over
  chunk owners -> intra all-gather (latency ``2N + 2G - 1``).
* ``torus`` ALL_REDUCE — the 2D-torus decomposition: intra reduce-scatter
  -> inter reduce-scatter -> inter all-gather -> intra all-gather
  (``2N + 2G``; the inter traffic is a further factor G smaller than
  two_level's, which wins under inter-lane bandwidth skew).
* ``hybrid`` ALL_REDUCE — pipelined ring+tree: intra REDUCE to the group
  leaders -> leader-ring all-reduce over the FULL payload -> intra
  BROADCAST (latency ``N + (2G - 1) + N`` but no payload split: strong at
  latency-bound sizes, weak when inter bandwidth is scarce).
* ``tree`` BROADCAST / REDUCE — leader-ring hop + intra hop (latency
  ``G + N`` vs the flat ring's ``R``).

Stages may cover only a SUBSET of the logical members (tree/hybrid inter
stages run on the G group leaders): the tables layer derives per-rank
chain successor/tail maps, the runtime redirects each rank's submission
to its first participating stage, and a rank's logical CQE fires at its
LAST participating stage.

Adding an algorithm: write ``plan_<name>(members, hierarchy, n_elems,
root)`` returning a CompositePlan whose adjacent stages satisfy
``out_log(stage k) == in_log(stage k+1)`` (the chain-relink handshake,
asserted at registration), register it with ``@register_plan(name,
kind)``, and list it in :data:`AUTO_CANDIDATES` so ``algo="auto"`` can
pick it.  The hypothesis sweep in tests/test_primitives_props.py
validates any registered plan structurally (flow conservation across
stages, every grid x root).

Calibration workflow (``algo="auto"``)
--------------------------------------
``select_algo("auto", ...)`` ranks the registered candidate plans with
the measured α-β-γ cost model of :mod:`repro.core.costmodel`:
``benchmarks/bench_collectives.py run_algo_sweep`` measures every
candidate's wall-clock into the ``algos`` section of
BENCH_collectives.json, ``benchmarks/calibrate.py`` fits (α, β, γ) to
those samples and persists them to BENCH_calibration.json, and
registration-time ``select_algo`` loads the fit to pick the plan with
the lowest PREDICTED WALL-CLOCK — not superstep count — for the
submitted payload size, topology and bandwidth skew.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence

import numpy as np

from .primitives import CollKind, Prim

# ---------------------------------------------------------------------------
# algorithm registry (single-communicator program builders)
# ---------------------------------------------------------------------------

# (algo_name, kind) -> builder(member_idx, group_size, root_idx) -> program.
ALGO_BUILDERS: dict = {}


def register_algo(algo: str, kind: CollKind):
    """Decorator: register a per-rank program builder for (algo, kind)."""

    def deco(fn: Callable[[int, int, int], list]):
        ALGO_BUILDERS[(algo, kind)] = fn
        return fn

    return deco


@register_algo("ring", CollKind.ALL_REDUCE)
def _ring_all_reduce(m: int, R: int, root: int) -> list:
    # Phase 1 (reduce-scatter): chunk c starts at rank c; at step s rank r
    # handles chunk (r - s) mod R; partial completes at step R-1.
    prog = [(Prim.SEND, m)]
    for s in range(1, R - 1):
        prog.append((Prim.RECV_REDUCE_SEND, (m - s) % R))
    prog.append((Prim.RECV_REDUCE_COPY_SEND, (m - (R - 1)) % R))
    # Phase 2 (all-gather): fully-reduced chunks circulate once more.
    for s in range(R, 2 * R - 2):
        prog.append((Prim.RECV_COPY_SEND, (m - s) % R))
    prog.append((Prim.RECV, (m + 2) % R))
    return prog


@register_algo("ring", CollKind.ALL_GATHER)
def _ring_all_gather(m: int, R: int, root: int) -> list:
    prog = [(Prim.COPY_SEND, m)]
    for s in range(1, R - 1):
        prog.append((Prim.RECV_COPY_SEND, (m - s) % R))
    prog.append((Prim.RECV, (m + 1) % R))
    return prog


@register_algo("ring", CollKind.REDUCE_SCATTER)
def _ring_reduce_scatter(m: int, R: int, root: int) -> list:
    # Chunk c finalizes at rank c after R-1 hops, so it starts at c+1.
    prog = [(Prim.SEND, (m - 1) % R)]
    for s in range(1, R - 1):
        prog.append((Prim.RECV_REDUCE_SEND, (m - s - 1) % R))
    prog.append((Prim.RECV_REDUCE_COPY, m))
    return prog


@register_algo("ring", CollKind.BROADCAST)
def _ring_broadcast(m: int, R: int, root: int) -> list:
    d = (m - root) % R
    prog = []
    for k in range(R):  # pipeline the R chunks down the chain
        if d == 0:
            prog.append((Prim.COPY_SEND, k))
        elif d == R - 1:
            prog.append((Prim.RECV, k))
        else:
            prog.append((Prim.RECV_COPY_SEND, k))
    return prog


@register_algo("ring", CollKind.REDUCE)
def _ring_reduce(m: int, R: int, root: int) -> list:
    # R >= 2 here: single-member groups early-return a COPY in
    # build_ring_program, so the chain roles below are total.
    d = (m - root) % R
    prog = []
    for k in range(R):
        if d == 1:
            prog.append((Prim.SEND, k))
        elif d == 0:
            prog.append((Prim.RECV_REDUCE_COPY, k))
        else:
            prog.append((Prim.RECV_REDUCE_SEND, k))
    return prog


@register_algo("ring", CollKind.ALL_TO_ALL)
def _ring_all_to_all(m: int, R: int, root: int) -> list:
    # Personalized exchange over the ring, ABSOLUTE (member-indexed)
    # chunks: input chunk d is the payload FOR member d, output chunk o
    # is the payload FROM member o.  Phase s in 1..R-1 walks every
    # (origin -> origin + s) pair s hops down the ring: the origin SENDs
    # its input chunk for member (m + s); each intermediate forwards the
    # in-flight chunk with the heap-inert RECV_SEND; the destination's
    # final RECV lands it in output chunk (m - s) — the chunk operand of
    # a step indexes whichever buffer side the primitive touches (SEND:
    # input, RECV: output, RECV_SEND: neither — the id is kept at the
    # forwarded chunk's destination purely for trace readability).
    # FIFO-safe: within phase s, rank m pushes wire chunks destined to
    # (m+s), (m+s-1), ..., (m+1) in that order, which is exactly the
    # order its successor's relay/RECV steps consume them.
    prog = [(Prim.COPY, m)]
    for s in range(1, R):
        prog.append((Prim.SEND, (m + s) % R))
        for t in range(1, s):
            prog.append((Prim.RECV_SEND, (m + s - t) % R))
        prog.append((Prim.RECV, (m - s) % R))
    return prog


@register_algo("ring", CollKind.ALL_TO_ALL_RAGGED)
def _ring_all_to_all_ragged(m: int, R: int, root: int) -> list:
    # Capacity-dropped variant with DISTANCE-indexed chunks: input chunk
    # s holds the (<= chunk capacity) live payload for member (m + s),
    # output chunk s the payload from member (m - s).  Distance keying
    # makes the program AND the ragged stage maps rank-independent —
    # every rank's chunk s carries chunk_sizes[s] live elements, so one
    # per-collective stage map (tables.py) serves all ranks, which a
    # destination- or origin-indexed ragged layout cannot do.
    prog = [(Prim.COPY, 0)]
    for s in range(1, R):
        prog.append((Prim.SEND, s))
        for t in range(1, s):
            prog.append((Prim.RECV_SEND, s))
        prog.append((Prim.RECV, s))
    return prog


def build_ring_program(
    kind: CollKind, member_idx: int, group_size: int, root_idx: int = 0,
    algo: str = "ring",
) -> list:
    """Per-rank primitive sequence ``[(prim, chunk_idx), ...]`` from the
    algorithm registry.  Ring algorithm, Simple protocol (paper Sec. 5)."""
    if group_size == 1:
        # Degenerate single-member group: a local copy (broadcast/reduce/
        # all_* all collapse to in -> out).
        return [(Prim.COPY, 0)]
    try:
        builder = ALGO_BUILDERS[(algo, CollKind(kind))]
    except (KeyError, ValueError):
        known = sorted({f"({a}, {CollKind(k).name})"
                        for a, k in ALGO_BUILDERS})
        raise ValueError(
            f"no registered program builder for algo={algo!r}, "
            f"kind={kind!r}; registered: {known}") from None
    return builder(member_idx, group_size, root_idx)


# ---------------------------------------------------------------------------
# composite plans (multi-communicator chained sub-collectives)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SubCollective:
    """One stage of a composite plan: an ordinary ring collective over a
    PARTITIONED sub-communicator (disjoint rings sharing one lane)."""

    kind: CollKind
    members: tuple          # flat rank tuple; consecutive ``ring_size``
                            # runs are the disjoint rings of this stage
    ring_size: int
    n_elems: int            # logical element count of this stage
    root: int = 0
    # Stage-input permutation (CollectiveSpec.in_perm): position of each
    # chain-logical element j inside THIS stage's input layout.  The
    # chain relink composes it with the predecessor's output map, which
    # is how the two-level a2a gets its inter-stage granule transposes
    # for free (no shuffle stage, no extra heap traffic).
    in_perm: tuple = ()


@dataclasses.dataclass(frozen=True)
class CompositePlan:
    """A logical collective lowered to a chain of sub-collectives.

    ``stages[k+1]`` consumes ``stages[k]``'s logical output; the tables
    layer turns each edge into a registration-time heap relink map and a
    ``next_coll`` successor entry, so the daemon advances the whole chain
    on device.  Logical I/O addresses only the endpoints: payloads stage
    into ``stages[0]``'s input region, results read from ``stages[-1]``'s
    output region.
    """

    kind: CollKind          # the logical collective the chain implements
    n_elems: int
    hierarchy: tuple        # (G groups, N ranks per group)
    stages: tuple           # tuple[SubCollective, ...]


def default_hierarchy(R: int) -> tuple:
    """(G, N) with G * N == R and N the largest divisor <= sqrt(R) —
    the most square grid, which minimizes the two-level latency term
    N + (2G - 1) + N.  Primes fall back to (R, 1)."""
    best = 1
    for n in range(2, int(math.isqrt(R)) + 1):
        if R % n == 0:
            best = n
    return (R // best, best)


def _grid(members: Sequence[int], hierarchy: tuple) -> list:
    """Row-major ``G x N`` grid of the member ranks; validates tiling."""
    G, N = hierarchy
    R = len(members)
    if G * N != R:
        raise ValueError(f"hierarchy {hierarchy} does not tile the "
                         f"{R}-member communicator (G * N != {R})")
    members = tuple(members)
    return [members[g * N:(g + 1) * N] for g in range(G)]


def plan_two_level(kind: CollKind, members: Sequence[int],
                   hierarchy: tuple, n_elems: int) -> CompositePlan:
    """Lower a logical all-reduce over a ``G x N`` rank grid into the
    two-level chain (The Big Send-off, PAPERS.md):

      1. intra-group REDUCE_SCATTER over each group's N-ring: member m of
         group g ends up owning chunk m of the group-local sum;
      2. inter-group ALL_REDUCE over the G chunk owners of each position m
         (one G-ring per chunk position): chunk m becomes globally reduced
         everywhere;
      3. intra-group ALL_GATHER over the N-rings: every rank reassembles
         the full globally-reduced payload.

    ``members`` is the logical communicator's ring order, reshaped
    row-major: group g = members[g*N : (g+1)*N].
    """
    if kind != CollKind.ALL_REDUCE:
        raise ValueError(
            f"two_level lowering is defined for ALL_REDUCE only, got "
            f"{CollKind(kind)!r} (register other kinds with algo='ring')")
    G, N = hierarchy
    groups = _grid(members, hierarchy)
    # Inter-group rings: position m's chunk owners across all groups.
    owners = [tuple(groups[g][m] for g in range(G)) for m in range(N)]
    intra = tuple(r for grp in groups for r in grp)          # == members
    inter = tuple(r for ring in owners for r in ring)
    chunk = -(-n_elems // N)                                 # ceil
    return CompositePlan(
        kind=kind, n_elems=n_elems, hierarchy=(G, N),
        stages=(
            SubCollective(CollKind.REDUCE_SCATTER, intra, N, n_elems),
            SubCollective(CollKind.ALL_REDUCE, inter, G, chunk),
            SubCollective(CollKind.ALL_GATHER, intra, N, n_elems),
        ))


def plan_torus(kind: CollKind, members: Sequence[int], hierarchy: tuple,
               n_elems: int, root: int = 0) -> CompositePlan:
    """2D-torus all-reduce: replace two_level's inter ALL_REDUCE with an
    inter REDUCE_SCATTER + ALL_GATHER pair.  One more latency step
    (``2N + 2G`` vs ``2N + 2G - 1``) but each inter primitive step moves
    a chunk a further factor G smaller — the right trade when the
    inter-group lane is bandwidth-starved (cfg.bandwidth_groups skew).

    Chain-edge exactness: stage logical sizes compose as
    ``n -> cl1 = ceil(n/N) -> cl2 = ceil(cl1/G) -> cl1 -> n`` using the
    SAME ceil at producer and consumer, so every edge's
    ``out_log == in_log`` holds for ragged payloads too."""
    if kind != CollKind.ALL_REDUCE:
        raise ValueError(
            f"torus lowering is defined for ALL_REDUCE only, got "
            f"{CollKind(kind)!r}")
    G, N = hierarchy
    groups = _grid(members, hierarchy)
    owners = [tuple(groups[g][m] for g in range(G)) for m in range(N)]
    intra = tuple(r for grp in groups for r in grp)
    inter = tuple(r for ring in owners for r in ring)
    cl1 = -(-n_elems // N)                                   # ceil
    return CompositePlan(
        kind=kind, n_elems=n_elems, hierarchy=(G, N),
        stages=(
            SubCollective(CollKind.REDUCE_SCATTER, intra, N, n_elems),
            SubCollective(CollKind.REDUCE_SCATTER, inter, G, cl1),
            SubCollective(CollKind.ALL_GATHER, inter, G, cl1),
            SubCollective(CollKind.ALL_GATHER, intra, N, n_elems),
        ))


def plan_hybrid(kind: CollKind, members: Sequence[int], hierarchy: tuple,
                n_elems: int, root: int = 0) -> CompositePlan:
    """Pipelined ring+tree all-reduce: intra REDUCE to each group's
    leader (grid column ``m0``), leader-ring ALL_REDUCE over the FULL
    payload, intra BROADCAST back out.  Latency ``N + (2G - 1) + N``
    with no payload split across stages — competitive at latency-bound
    sizes, deliberately bandwidth-hungry on the inter lane (the cost
    model learns to avoid it when skew makes that lane scarce).

    Non-leader ranks participate only in stages 0 and 2: their chains
    skip the leader ring (per-rank successor maps, tables layer)."""
    if kind != CollKind.ALL_REDUCE:
        raise ValueError(
            f"hybrid lowering is defined for ALL_REDUCE only, got "
            f"{CollKind(kind)!r}")
    G, N = hierarchy
    g0, m0 = divmod(root, N)
    groups = _grid(members, hierarchy)
    leaders = tuple(groups[g][m0] for g in range(G))
    intra = tuple(r for grp in groups for r in grp)
    return CompositePlan(
        kind=kind, n_elems=n_elems, hierarchy=(G, N),
        stages=(
            SubCollective(CollKind.REDUCE, intra, N, n_elems, root=m0),
            SubCollective(CollKind.ALL_REDUCE, leaders, G, n_elems),
            SubCollective(CollKind.BROADCAST, intra, N, n_elems, root=m0),
        ))


def plan_two_level_alltoall(kind: CollKind, members: Sequence[int],
                            hierarchy: tuple, n_elems: int,
                            root: int = 0) -> CompositePlan:
    """Hierarchical all-to-all over a ``G x N`` rank grid: an intra-group
    exchange that gathers, per rank, everything its group-column peers
    hold for the OTHER groups, then an inter-group exchange across the
    grid columns that delivers it — ISSUE's gather -> leader exchange ->
    scatter collapsed into two full-membership stages (every rank is its
    own leader for its slice, so no scatter stage and no G-fold leader
    bottleneck; supersteps drop from the flat ring's ``1 + (R-1)(R+2)/2``
    to the two stages' ``a2a_len(N) + a2a_len(G)``).

    Correctness hinges on the two ``in_perm`` granule transposes: with
    per-pair granule size ``c = n / R`` (n must divide; ``algo="auto"``
    silently drops this plan otherwise, explicit registration raises),
    writing a destination as ``(g', j1)`` and the rank as ``(g, i)``:

      * stage A (intra, one N-ring per group, ring index ``i``) must
        exchange on the DESTINATION COLUMN ``j1``, so its in_perm maps
        the user granule ``d = g'·N + j1`` to stage position
        ``j1·G + g'`` — after the exchange, rank (g, i) holds, for every
        origin column j1 of its own group, the payloads of rank (g, j1)
        for all of column i's ranks, granule order ``o1·G + g'``.
      * stage B (inter, one G-ring per grid column, ring index ``g``)
        exchanges on the destination GROUP, so its in_perm transposes
        ``o1·G + g'`` to stage position ``g'·N + o1``.

    The final output granule ``o2·N + o1`` of rank (g, i) is then the
    payload from global rank ``o2·N + o1`` — the exact absolute
    origin-major layout the flat ring produces, which is what lets
    ``algo="auto"`` swap the two freely and the bench compare them on
    identical submits."""
    if kind != CollKind.ALL_TO_ALL:
        raise ValueError(
            f"two_level all-to-all lowering is defined for ALL_TO_ALL "
            f"only, got {CollKind(kind)!r} (the ragged variant is "
            f"flat-ring only: per-distance sizes do not survive the "
            f"granule transposes)")
    G, N = hierarchy
    groups = _grid(members, hierarchy)
    R = G * N
    if n_elems % R != 0:
        raise ValueError(
            f"two_level all-to-all needs n_elems divisible by the group "
            f"size for exact granule transposes (n_elems={n_elems}, "
            f"R={R}); use algo='ring' for ragged totals")
    c = n_elems // R
    intra = tuple(r for grp in groups for r in grp)          # row-major
    inter = tuple(groups[g][i] for i in range(N) for g in range(G))
    j = np.arange(n_elems, dtype=np.int64)
    u, d = j % c, j // c
    gq, j1 = divmod(d, N)
    perm_a = (j1 * G + gq) * c + u
    o1, gq2 = divmod(j // c, G)
    perm_b = (gq2 * N + o1) * c + (j % c)
    return CompositePlan(
        kind=kind, n_elems=n_elems, hierarchy=(G, N),
        stages=(
            SubCollective(CollKind.ALL_TO_ALL, intra, N, n_elems,
                          in_perm=tuple(map(int, perm_a))),
            SubCollective(CollKind.ALL_TO_ALL, inter, G, n_elems,
                          in_perm=tuple(map(int, perm_b))),
        ))


def plan_tree_broadcast(kind: CollKind, members: Sequence[int],
                        hierarchy: tuple, n_elems: int, root: int = 0
                        ) -> CompositePlan:
    """Tree broadcast over the grid: root's payload hops the leader ring
    (grid column ``m0`` of the root), then every group's leader fans out
    over its intra ring — ``G + N`` latency steps vs the flat ring's
    ``R``.  Non-leader ranks participate only in the intra stage."""
    if kind != CollKind.BROADCAST:
        raise ValueError(
            f"tree broadcast lowering got {CollKind(kind)!r}")
    G, N = hierarchy
    g0, m0 = divmod(root, N)
    groups = _grid(members, hierarchy)
    leaders = tuple(groups[g][m0] for g in range(G))
    intra = tuple(r for grp in groups for r in grp)
    return CompositePlan(
        kind=kind, n_elems=n_elems, hierarchy=(G, N),
        stages=(
            SubCollective(CollKind.BROADCAST, leaders, G, n_elems,
                          root=g0),
            SubCollective(CollKind.BROADCAST, intra, N, n_elems,
                          root=m0),
        ))


def plan_tree_reduce(kind: CollKind, members: Sequence[int],
                     hierarchy: tuple, n_elems: int, root: int = 0
                     ) -> CompositePlan:
    """Tree reduce: mirror of the tree broadcast — every group reduces
    onto its leader (the root's grid column ``m0``), then the leader
    ring reduces onto the root's group leader, i.e. the root itself."""
    if kind != CollKind.REDUCE:
        raise ValueError(f"tree reduce lowering got {CollKind(kind)!r}")
    G, N = hierarchy
    g0, m0 = divmod(root, N)
    groups = _grid(members, hierarchy)
    leaders = tuple(groups[g][m0] for g in range(G))
    intra = tuple(r for grp in groups for r in grp)
    return CompositePlan(
        kind=kind, n_elems=n_elems, hierarchy=(G, N),
        stages=(
            SubCollective(CollKind.REDUCE, intra, N, n_elems, root=m0),
            SubCollective(CollKind.REDUCE, leaders, G, n_elems, root=g0),
        ))


# (algo_name, kind) -> plan builder(members, hierarchy, n_elems, root).
PLAN_BUILDERS: dict = {
    ("two_level", CollKind.ALL_REDUCE):
        lambda members, hier, n, root=0: plan_two_level(
            CollKind.ALL_REDUCE, members, hier, n),
    ("torus", CollKind.ALL_REDUCE):
        lambda members, hier, n, root=0: plan_torus(
            CollKind.ALL_REDUCE, members, hier, n, root),
    ("hybrid", CollKind.ALL_REDUCE):
        lambda members, hier, n, root=0: plan_hybrid(
            CollKind.ALL_REDUCE, members, hier, n, root),
    ("tree", CollKind.BROADCAST):
        lambda members, hier, n, root=0: plan_tree_broadcast(
            CollKind.BROADCAST, members, hier, n, root),
    ("tree", CollKind.REDUCE):
        lambda members, hier, n, root=0: plan_tree_reduce(
            CollKind.REDUCE, members, hier, n, root),
    ("two_level", CollKind.ALL_TO_ALL):
        lambda members, hier, n, root=0: plan_two_level_alltoall(
            CollKind.ALL_TO_ALL, members, hier, n),
}


def register_plan(algo: str, kind: CollKind):
    """Decorator: register a composite plan builder for (algo, kind)."""

    def deco(fn):
        PLAN_BUILDERS[(algo, CollKind(kind))] = fn
        return fn

    return deco


# Candidate plans ``algo="auto"`` ranks per kind (flat ring is always a
# candidate; plans needing a non-degenerate grid are filtered at select
# time).  Order breaks cost ties: earlier wins.
AUTO_CANDIDATES: dict = {
    CollKind.ALL_REDUCE: ("ring", "two_level", "torus", "hybrid"),
    CollKind.BROADCAST: ("ring", "tree"),
    CollKind.REDUCE: ("ring", "tree"),
    CollKind.ALL_GATHER: ("ring",),
    CollKind.REDUCE_SCATTER: ("ring",),
    CollKind.ALL_TO_ALL: ("ring", "two_level"),
    CollKind.ALL_TO_ALL_RAGGED: ("ring",),
}


def build_plan(algo: str, kind: CollKind, members: Sequence[int],
               hierarchy: tuple, n_elems: int, root: int = 0
               ) -> CompositePlan:
    """Dispatch a composite lowering from the plan registry."""
    try:
        builder = PLAN_BUILDERS[(algo, CollKind(kind))]
    except KeyError:
        raise ValueError(
            f"no registered composite plan for algo={algo!r}, "
            f"kind={CollKind(kind)!r} (registered: "
            f"{sorted(set(a for a, _ in PLAN_BUILDERS))})")
    return builder(tuple(members), tuple(hierarchy), n_elems, root)


def select_algo(algo: str, kind: CollKind, n_elems: int, group_size: int,
                hierarchy: Optional[tuple] = None, cfg=None,
                model=None) -> str:
    """Resolve ``"auto"`` to the concrete algorithm with the lowest
    PREDICTED WALL-CLOCK under the measured α-β-γ cost model
    (:mod:`repro.core.costmodel`).

    Explicit algorithm names pass through unchanged.  ``"auto"`` ranks
    the :data:`AUTO_CANDIDATES` of the kind: per candidate the model
    predicts ``α·supersteps + β·bytes_on_wire + γ·n_stages`` from the
    plan's stage structure, the config's slicing geometry and the
    bandwidth-skew lane caps; (α, β, γ) come from ``model`` (default:
    the persisted BENCH_calibration.json fit of benchmarks/calibrate.py,
    falling back to conservative defaults when absent).  Composite
    candidates are dropped when the grid is degenerate (G or N == 1 —
    prime groups) — a lone flat ring short-circuits without consulting
    the model, so flat-only workloads never touch the calibration file.
    """
    if algo != "auto":
        return algo
    if hierarchy is not None:
        G, N = hierarchy
        # A caller-provided grid that does not tile the group is a bug,
        # not a selection hint: silently downgrading to the flat ring
        # would hide the typo (the explicit composite path raises the
        # same error via _grid).
        if G * N != group_size:
            raise ValueError(
                f"hierarchy {hierarchy} does not tile the "
                f"{group_size}-member communicator (G * N != {group_size})")
    else:
        G, N = default_hierarchy(group_size)
    try:
        pool = AUTO_CANDIDATES[CollKind(kind)]
    except (KeyError, ValueError):
        known = sorted(CollKind(k).name for k in AUTO_CANDIDATES)
        raise ValueError(
            f"algo='auto' has no candidate set for collective kind "
            f"{kind!r}; registered kinds: {known}") from None
    candidates = [
        a for a in pool
        if a == "ring" or (G > 1 and N > 1
                           and (a, CollKind(kind)) in PLAN_BUILDERS)
    ]
    if len(candidates) == 1:
        return candidates[0]
    from .costmodel import CostModel, plan_features

    if model is None:
        model = CostModel.load()
    costs = {}
    for a in candidates:
        try:
            costs[a] = model.predict(
                plan_features(cfg, kind, n_elems, group_size, (G, N), a))
        except ValueError:
            # Plan not constructible for this payload/grid (e.g. the
            # two-level a2a's exact-divisibility requirement): drop the
            # candidate rather than fail selection — the flat ring is
            # always constructible.
            continue
    return min((a for a in candidates if a in costs),
               key=lambda a: costs[a])
