"""Unit tests: primitive programs (paper Sec. 2.3) and slicing invariants."""
import numpy as np
import pytest

from repro.core.primitives import (CollKind, Prim, PRIM_RECV, PRIM_SEND,
                                   build_program, derive_slicing,
                                   io_chunked, program_len)


@pytest.mark.parametrize("kind", list(CollKind))
@pytest.mark.parametrize("R", [2, 3, 4, 8])
def test_program_lengths(kind, R):
    for m in range(R):
        prog = build_program(kind, m, R)
        assert len(prog) == program_len(kind, R)


@pytest.mark.parametrize("R", [2, 3, 4, 8])
def test_allreduce_transfer_counts(R):
    """Ring all-reduce: every rank sends and receives exactly 2(R-1)
    chunks (the bandwidth-optimality invariant)."""
    for m in range(R):
        prog = build_program(CollKind.ALL_REDUCE, m, R)
        sends = sum(PRIM_SEND[p] for p, _ in prog)
        recvs = sum(PRIM_RECV[p] for p, _ in prog)
        assert sends == 2 * (R - 1)
        assert recvs == 2 * (R - 1)


@pytest.mark.parametrize("R", [2, 3, 4, 8])
def test_allreduce_chunk_coverage(R):
    """Each rank's copy-steps cover all R chunks exactly once."""
    from repro.core.primitives import PRIM_COPY
    for m in range(R):
        prog = build_program(CollKind.ALL_REDUCE, m, R)
        copies = sorted(c for p, c in prog if PRIM_COPY[p])
        assert copies == list(range(R))


@pytest.mark.parametrize("R", [2, 4, 8])
def test_reduce_scatter_final_chunk(R):
    """Rank m finalizes chunk m (recvReduceCopy last)."""
    for m in range(R):
        prog = build_program(CollKind.REDUCE_SCATTER, m, R)
        prim, chunk = prog[-1]
        assert prim == Prim.RECV_REDUCE_COPY
        assert chunk == m


@pytest.mark.parametrize("R", [2, 3, 5])
@pytest.mark.parametrize("root", [0, 1])
def test_broadcast_roles(R, root):
    progs = [build_program(CollKind.BROADCAST, m, R, root) for m in range(R)]
    # root only sends; the last-in-chain rank only receives
    assert all(p == Prim.COPY_SEND for p, _ in progs[root])
    last = (root - 1) % R
    assert all(p == Prim.RECV for p, _ in progs[last])


@pytest.mark.parametrize("R", [2, 3, 4, 7])
def test_broadcast_nonzero_roots(R):
    """Every root placement: exactly one all-COPY_SEND rank (the root),
    exactly one all-RECV rank (its ring predecessor), everyone else
    relays — and chunk ids stay the pipeline order on every rank."""
    for root in range(R):
        progs = [build_program(CollKind.BROADCAST, m, R, root)
                 for m in range(R)]
        roles = ["send" if all(p == Prim.COPY_SEND for p, _ in pr)
                 else "recv" if all(p == Prim.RECV for p, _ in pr)
                 else "relay" for pr in progs]
        assert roles.count("send") == 1 and roles.index("send") == root
        assert roles.count("recv") == 1
        assert roles.index("recv") == (root - 1) % R
        for pr in progs:
            assert [c for _, c in pr] == list(range(R))


@pytest.mark.parametrize("R", [2, 3, 4, 7])
def test_reduce_nonzero_roots(R):
    """REDUCE chain roles for every root: the root's ring successor only
    SENDs (chain start), the root only RECV_REDUCE_COPYs (chain end),
    intermediates RECV_REDUCE_SEND.  Regression for the unreachable
    ``R == 1`` guard that used to sit in the d == 1 branch: single-member
    groups early-return a COPY, so the distance-1 role must be pure SEND
    for every R >= 2 and every root."""
    for root in range(R):
        progs = [build_program(CollKind.REDUCE, m, R, root)
                 for m in range(R)]
        for m, pr in enumerate(progs):
            d = (m - root) % R
            if d == 1:
                want = Prim.SEND
            elif d == 0:
                want = Prim.RECV_REDUCE_COPY
            else:
                want = Prim.RECV_REDUCE_SEND
            assert all(p == want for p, _ in pr), (m, root, pr)
            assert [c for _, c in pr] == list(range(R))


def test_single_member_groups_collapse_to_copy():
    """R == 1 degenerates to one local COPY for every kind and root —
    the early return that makes the in-branch R == 1 guard unreachable."""
    for kind in CollKind:
        assert build_program(kind, 0, 1, 0) == [(Prim.COPY, 0)]


def test_slicing_caps_rounds():
    """Per-round slices <= conn_depth - 1 (the wedge-freedom invariant)."""
    for n in [1, 5, 64, 1000, 12345]:
        for R in [2, 4, 8]:
            for K in [2, 4, 8]:
                per, rounds = derive_slicing(n, R, 16, K)
                assert per <= K - 1
                assert per * rounds * 16 * R >= n
