"""The paper's headline demo (Sec. 5.2): a workload that DEADLOCKS every
statically-sequenced collective library completes under OCCL.

8 ranks submit 8 all-reduces in pairwise-different orders, 3 iterations.
First we prove the baseline deadlocks (wait-for-graph cycle), then OCCL
runs it to completion, reporting the preemption counts that did the work.

    PYTHONPATH=src python examples/adversarial_orders.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import (CollKind, OcclConfig, OcclRuntime,
                        run_static_order)

R, C, ITERS = 8, 8, 3
rng = np.random.RandomState(42)
orders = {r: list(rng.permutation(C)) for r in range(R)}

# --- 1. the statically-sequenced baseline deadlocks --------------------
static = run_static_order(orders, {c: list(range(R)) for c in range(C)})
print("static single-FIFO-queue execution:",
      "DEADLOCK" if static.deadlocked else "ok")
print("  completed before wedging:", static.completed)
print("  wait-for cycle over ranks:", static.cycle)
assert static.deadlocked

# --- 2. OCCL completes the same workload -------------------------------
cfg = OcclConfig(n_ranks=R, max_colls=C, max_comms=1, slice_elems=64,
                 conn_depth=4, heap_elems=1 << 16,
                 superstep_budget=1 << 15)
rt = OcclRuntime(cfg)
world = rt.communicator(list(range(R)))
sizes = [64 << (i % 5) for i in range(C)]
ids = [rt.register(CollKind.ALL_REDUCE, world, n_elems=s) for s in sizes]

for it in range(ITERS):
    data = {i: [rng.randn(sizes[i]).astype(np.float32) for _ in range(R)]
            for i in range(C)}
    for r in range(R):
        for slot in orders[r]:
            rt.submit(r, ids[slot], data=data[slot][r])
    rt.drive()
    for i in range(C):
        want = sum(data[i])
        for r in range(R):
            np.testing.assert_allclose(rt.read_output(r, ids[i]), want,
                                       rtol=1e-4, atol=1e-5)
    print(f"iteration {it}: all {C} collectives correct on all {R} ranks")

st = rt.stats()
print(f"\nOCCL: {int(st['completed'].sum())} collective executions, "
      f"{int(st['preempts'].sum())} preemptions (context switches), "
      f"{rt.launches} daemon launches")
print("OK — the deadlock-prone workload is just a workload now.")
