"""Model zoo: the 10 assigned architectures + ViT (the paper's model)."""
from .transformer import Model, build_model
from .io import input_specs, make_concrete, train_specs, decode_specs

__all__ = ["Model", "build_model", "input_specs", "make_concrete",
           "train_specs", "decode_specs"]
