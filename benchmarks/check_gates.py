"""CI perf gates over BENCH_collectives.json (called from ci.yml).

Replaces the inline workflow heredoc with a versioned, testable script.
Gates (thresholds deliberately looser than local best-of-N numbers —
shared CI runners are noisy; the gate catches REGRESSIONS, not jitter):

* **staging** — the device-resident staging engine must stay >= 3x the
  pre-PR bulk path (local best-of-N shows >= 5x; see ROADMAP "Device-
  resident staging").
* **contention** — burst-aware stall accounting must keep the adversarial
  8x8 all-reduce at B=8 at no more than 0.5x the supersteps of B=1 (the
  PR-2 record shows ~3x fewer; parity was the pre-PR failure mode).
* **mesh pack** — packed 16-bit heaps must ride exactly 2 ppermutes per
  ``_mesh_exchange`` superstep, same as 32-bit (3 means the packing
  regressed to the separate header/payload exchange).
* **hierarchy** — the composite two-level all-reduce at R=16 must
  complete in FEWER supersteps than the flat ring (the chain's latency
  term is N + (2G - 1) + N = 15 steps vs the ring's 2R - 1 = 31; parity
  or worse means the device-side chain advance regressed to host round
  trips or the stages stopped overlapping their slice bursts).

A missing or partial record FAILS (validate_record): a stale
BENCH_collectives.json silently skipping a gate was the failure mode
that motivated this script.

Usage: ``python benchmarks/check_gates.py [path/to/BENCH_collectives.json]``
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))


def check(doc: dict) -> list[str]:
    """Returns a list of human-readable gate failures (empty == pass)."""
    failures = []

    s = doc["staging"]
    speedup = s["speedup_vs_legacy"]
    print(f"staging speedup vs legacy bulk: {speedup:.1f}x "
          f"(vs scalar: {s['speedup_vs_legacy_scalar']:.0f}x)")
    if speedup < 3.0:
        failures.append(
            f"staging engine regressed: {speedup:.2f}x vs legacy bulk "
            "(gate: >= 3x)")

    c = doc["contention"]["bursts"]
    if "1" not in c or "8" not in c:
        failures.append(
            f"contention sweep lacks bursts 1 and 8 (got {sorted(c)}) — "
            "rerun benchmarks/run.py")
    else:
        b1, b8 = c["1"]["supersteps"], c["8"]["supersteps"]
        ratio = b8 / max(b1, 1)
        print(f"contention supersteps: B=1 {b1}, B=8 {b8} "
              f"(ratio {ratio:.2f})")
        if ratio > 0.5:
            failures.append(
                f"burst-aware stall accounting regressed: B=8 ran "
                f"{ratio:.2f}x the supersteps of B=1 (gate: <= 0.5x)")

    pp = doc["mesh"]["ppermutes_per_superstep"]
    print(f"mesh ppermutes/superstep: {pp}")
    for key in ("float32", "bfloat16_packed", "float16_packed"):
        if pp.get(key) != 2:
            failures.append(
                f"mesh exchange {key} pays {pp.get(key)} ppermutes per "
                "superstep (gate: exactly 2 — packed 16-bit must match "
                "32-bit)")
    if pp.get("bfloat16_unpacked") != 3:
        failures.append(
            "unpacked-bf16 baseline no longer pays 3 ppermutes "
            f"(got {pp.get('bfloat16_unpacked')}) — the escape-hatch "
            "baseline the packed path is measured against has drifted")

    h = doc["hierarchy"]
    flat_steps = h["flat"]["supersteps"]
    two_steps = h["two_level"]["supersteps"]
    print(f"hierarchy supersteps at R={h['config']['n_ranks']}: "
          f"flat {flat_steps:.0f}, two_level {two_steps:.0f} "
          f"(ratio {h['superstep_ratio']:.2f})")
    if not two_steps < flat_steps:
        failures.append(
            f"two-level all-reduce regressed: {two_steps:.0f} supersteps "
            f"vs flat ring's {flat_steps:.0f} (gate: strictly fewer)")
    return failures


def main(argv: list[str]) -> int:
    import bench_collectives

    path = (pathlib.Path(argv[1]) if len(argv) > 1
            else bench_collectives.BENCH_JSON)
    doc = bench_collectives.validate_record(
        required=("staging", "contention", "mesh", "hierarchy"),
        out_path=path)
    failures = check(doc)
    for f in failures:
        print(f"GATE FAILED: {f}", file=sys.stderr)
    if not failures:
        print("all perf gates passed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
