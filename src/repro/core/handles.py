"""Collective handles: the object-capability face of ``register()``.

``OcclRuntime.register`` returns a :class:`CollectiveHandle` — an ``int``
subclass, so every pre-existing call site that threads the bare
``coll_id`` through ``submit``/``read_output``/dict keys keeps working
unchanged — that additionally owns the collective's operations
(``submit``/``submit_all``/``write``/``read``/``stats``) and, crucially,
survives **re-registration after an elastic shrink**: ``evict(rank)``
rebuilds every registration for R-1 ranks and the handle transparently
re-resolves to its post-shrink collective id via its registration-log
index.  Raw ints cannot do that — they go stale the moment the id space
is rebuilt — which is why eviction forced this API.

Plain-int call paths remain accepted everywhere as thin deprecated
shims (``runtime._resolve_cid``); they are only guaranteed against the
CURRENT registration generation.
"""
from __future__ import annotations


class CollectiveHandle(int):
    """An ``int``-compatible capability for one registered collective.

    The integer value is the collective id at REGISTRATION time; method
    calls and post-shrink uses resolve through the runtime's
    registration log instead, so the handle follows the collective
    across ``evict()`` rebuilds.
    """

    def __new__(cls, cid: int, runtime, reg_index: int):
        h = super().__new__(cls, cid)
        h._runtime = runtime
        h.reg_index = int(reg_index)
        return h

    def __repr__(self):
        return f"CollectiveHandle({int(self)}, reg_index={self.reg_index})"

    # NamedTuple/int semantics: hashing and equality stay value-based so
    # handles keep working as dict keys mixed with plain ints.

    @property
    def coll_id(self) -> int:
        """Current (post-shrink) collective id; raises if evicted away."""
        return self._runtime._current_cid(self.reg_index)

    @property
    def alive(self) -> bool:
        """False once a shrink dissolved this registration (e.g. every
        surviving member was evicted or the registration could not be
        rebuilt for the smaller group)."""
        try:
            self._runtime._current_cid(self.reg_index)
            return True
        except Exception:
            return False

    # -- owned operations (delegate to the runtime) ---------------------
    def submit(self, rank: int, prio: int = 0, data=None, callback=None,
               in_off: int = -1, out_off: int = -1):
        return self._runtime.submit(rank, self, prio=prio, data=data,
                                    callback=callback, in_off=in_off,
                                    out_off=out_off)

    def submit_all(self, prio: int = 0, data=None, callback=None):
        return self._runtime.submit_all(self, prio=prio, data=data,
                                        callback=callback)

    def write(self, rank: int, data, in_off: int = -1):
        return self._runtime.write_input(rank, self, data, in_off=in_off)

    def read(self, rank: int, out_off: int = -1):
        return self._runtime.read_output(rank, self, out_off=out_off)

    def stats(self) -> dict:
        return self._runtime.collective_stats(self)
