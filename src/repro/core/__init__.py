"""OCCL core: the deadlock-free collective execution framework (DFCE).

The paper's primary contribution, adapted TPU-natively: collectives are
per-rank primitive sequences over connector ring buffers, executed by a
long-running daemon loop with decentralized preemption (spin thresholds)
and stickiness-driven emergent gang-scheduling.  See DESIGN.md.
"""
from .algos import (AUTO_CANDIDATES, PLAN_BUILDERS, CompositePlan,
                    SubCollective, build_plan, default_hierarchy,
                    plan_hybrid, plan_torus, plan_tree_broadcast,
                    plan_tree_reduce, plan_two_level,
                    plan_two_level_alltoall, register_plan, select_algo)
from .config import OcclConfig, OrderPolicy, ReduceOp
from .costmodel import CostModel, fit, plan_features
from .daemon import (TickFlags, build_mesh_tick, build_shardmap_tick,
                     build_sim_tick, launch_prologue)
from .device_api import DeviceApi, decode_state, encode_state, encoded_zeros
from .errors import (ConnDepthWarning, DeadlockTimeout, EvictionError,
                     RegistrationClosed, StepTimeout)
from .handles import CollectiveHandle
from .recorder import (EVENT_NAMES, Diagnosis, FlightEvent, StalledChain,
                       diagnose, events)
from .primitives import CollKind, CollectiveSpec, Communicator, Prim
from .runtime import OcclRuntime
from .staging import StagingEngine
from .deadlock import run_static_order, consistent_order_exists

__all__ = [
    "OcclConfig", "OrderPolicy", "ReduceOp",
    "CollKind", "CollectiveSpec", "Communicator", "Prim",
    "OcclRuntime", "DeadlockTimeout", "ConnDepthWarning", "StagingEngine",
    "EvictionError", "RegistrationClosed", "StepTimeout",
    "CollectiveHandle",
    "FlightEvent", "StalledChain", "Diagnosis", "EVENT_NAMES",
    "events", "diagnose",
    "TickFlags", "launch_prologue", "build_sim_tick", "build_mesh_tick",
    "build_shardmap_tick", "DeviceApi", "encode_state", "decode_state",
    "encoded_zeros",
    "run_static_order", "consistent_order_exists",
    "CompositePlan", "SubCollective", "default_hierarchy",
    "plan_two_level", "plan_torus", "plan_hybrid",
    "plan_tree_broadcast", "plan_tree_reduce", "plan_two_level_alltoall",
    "PLAN_BUILDERS", "AUTO_CANDIDATES", "register_plan", "build_plan",
    "select_algo", "CostModel", "plan_features", "fit",
]
