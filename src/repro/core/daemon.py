"""The daemon loop: a long-running jitted superstep loop (paper Sec. 3.1).

Two interchangeable backends share the identical per-rank scheduler core:

* **sim** — all ranks live on one device; per-rank state carries a leading
  rank axis and the superstep is ``vmap``-ed; the connector fabric is a
  gather along the communicator ring permutation.  Used by unit/property
  tests and the collective microbenchmarks.

* **mesh** — ranks are devices of a mesh axis under ``shard_map``; the
  fabric is a pair of ``lax.ppermute`` s (forward slice + reverse credit)
  per lane per superstep.  The communication schedule is *static* — which
  collective's slice rides the wire is the dynamic, per-device scheduler
  decision.  Deadlock at the transport layer is therefore structurally
  impossible; the scheduler provides liveness (preemption) and performance
  (stickiness/gang convergence).

The loop terminates on: all work drained, the voluntary-quit threshold
(consecutive fabric-wide no-progress supersteps, Sec. 3.1.3), or the hard
superstep budget.  The host relaunches it event-driven while completions
lag submissions.

Launch prologue (both backends): the per-launch clock ``launch_steps`` and
the no-progress counter are zeroed, the launch counter ``epoch`` advances,
and active task-queue arrivals are rebased onto the fresh launch clock
(scheduler.rebase_arrivals).  The superstep budget bounds ``launch_steps``
— a PER-LAUNCH quantity — so the quit/relaunch cycle can repeat forever;
the cumulative ``supersteps`` epoch clock is observability-only.

The tick contract (compute-communication overlap)
-------------------------------------------------
``tick(state, k)`` is the unit of daemon progress: a PURE, jit-composable
function advancing up to ``k`` supersteps of the exact loop body above and
returning ``(state, TickFlags)``.  It is callable from *inside* a traced
training step — the mailbox fields of :class:`DaemonState` persist
in-flight wire messages across tick boundaries, so suspending after any
superstep and resuming later is exactly the voluntary-quit/relaunch cycle
the paper already requires, at a finer grain.  The contract:

* **Purity.**  ``tick`` closes over static tables only; all dynamic state
  threads through the ``DaemonState`` argument.  No host callbacks, no
  side effects — safe under ``jit``, ``lax.while_loop`` and ``custom_vjp``
  backward passes.
* **Batching invariance.**  ``tick(st, a)`` then ``tick(st, b)`` is
  bit-identical to ``tick(st, a + b)`` (the mailbox load/store round trip
  at the boundary is the identity), so a host ``drive()`` launch and any
  in-step tick batching produce the SAME superstep/preemption trajectory.
* **drive() is a thin wrapper.**  A daemon launch IS
  ``launch_prologue`` + ``tick(superstep_budget + 1)``; the host loop
  only packs SQEs and reconciles CQEs around it.  ``drive()`` remains the
  right entry point for host-driven workloads (registration-time payload
  staging, callbacks, DeadlockTimeout patience); in-step submission uses
  :mod:`repro.core.device_api`.
* **Accounting.**  Each tick stamps its supersteps into
  ``overlap_steps`` or ``barrier_steps`` by its static ``barrier`` flag —
  barrier ticks are supersteps the step is *blocked* on (drive()/drain),
  overlap ticks hide behind compute — and ``overlap + barrier ==
  supersteps`` always.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import OcclConfig
from .scheduler import (
    LocalTables,
    Mailbox,
    SharedTables,
    chain_relink_fired,
    rank_superstep,
    rebase_arrivals,
)
from .state import DaemonState
from .tables import StaticTables


def shared_tables(t: StaticTables) -> SharedTables:
    return SharedTables(
        registered=jnp.asarray(t.registered),
        kind=jnp.asarray(t.kind),
        op=jnp.asarray(t.op),
        lane=jnp.asarray(t.lane),
        n_steps=jnp.asarray(t.n_steps),
        n_slices=jnp.asarray(t.n_slices),
        n_rounds=jnp.asarray(t.n_rounds),
        in_chunked=jnp.asarray(t.in_chunked),
        out_chunked=jnp.asarray(t.out_chunked),
        base_in_off=jnp.asarray(t.base_in_off),
        base_out_off=jnp.asarray(t.base_out_off),
        next_coll=jnp.asarray(t.next_coll),
        chain_tail=jnp.asarray(t.chain_tail),
        chain_prio_inherit=jnp.asarray(t.chain_prio_inherit),
        chain_mask=jnp.asarray(t.chain_mask),
        chain_src=jnp.asarray(t.chain_src),
        chain_dst=jnp.asarray(t.chain_dst),
        lane_caps=jnp.asarray(t.lane_caps),
    )


def local_tables(t: StaticTables) -> LocalTables:
    """Per-rank tables with leading rank axis (sim) — slice [r] for mesh."""
    return LocalTables(
        member=jnp.asarray(t.member),
        prog_kind=jnp.asarray(t.prog_kind),
        prog_chunk=jnp.asarray(t.prog_chunk),
        chain_next=jnp.asarray(t.chain_next),
        chain_tail_r=jnp.asarray(t.chain_tail_r),
    )


def _sim_exchange(fwd_src, rev_src, outbox: Mailbox) -> Mailbox:
    """Deliver per-lane messages along each communicator ring (sim backend).

    ``outbox`` fields have shape [R, L, ...]; the message arriving at rank
    r on lane l was sent by ``fwd_src[l, r]`` (resp. ``rev_src``).  One
    batched gather over the (rank, lane) grid per field — no Python lane
    loop in the compiled superstep.
    """
    L = fwd_src.shape[0]
    lanes = jnp.arange(L)

    def pick(field, src):  # field: [R, L, ...] -> gathered [R, L, ...]
        return field[src.T, lanes[None, :]]

    return Mailbox(
        fwd_count=pick(outbox.fwd_count, fwd_src),
        fwd_coll=pick(outbox.fwd_coll, fwd_src),
        fwd_payload=pick(outbox.fwd_payload, fwd_src),
        rev_count=pick(outbox.rev_count, rev_src),
        rev_coll=pick(outbox.rev_coll, rev_src),
    )


def _pack16_to_i32(pay: jnp.ndarray, pad: int) -> jnp.ndarray:
    """Bitcast PAIRS of adjacent 16-bit payload elements into i32 lanes.

    ``pay`` is [G, W] of a 2-byte dtype; an odd W is zero-padded by ``pad``
    (0 or 1) so every element has a pair partner.  Returns [G, (W+pad)//2]
    i32 — exact bits, concatenable with the i32 (coll, count) header.
    """
    if pad:
        pay = jnp.concatenate(
            [pay, jnp.zeros((pay.shape[0], pad), pay.dtype)], axis=1)
    return jax.lax.bitcast_convert_type(
        pay.reshape(pay.shape[0], -1, 2), jnp.int32)


def _unpack16_from_i32(packed: jnp.ndarray, dtype, width: int) -> jnp.ndarray:
    """Inverse of :func:`_pack16_to_i32`: [G, P] i32 -> [G, width] 16-bit
    (the pad element, if any, is sliced off)."""
    pairs = jax.lax.bitcast_convert_type(packed, dtype)   # [G, P, 2]
    return pairs.reshape(pairs.shape[0], -1)[:, :width]


def _mesh_exchange(t: StaticTables, outbox: Mailbox, axis_name: str) -> Mailbox:
    """Deliver messages over the device fabric (mesh backend).

    Lanes whose communicators share a ring permutation are FUSED: their
    stacked traffic rides one ppermute pair per direction — the forward
    direction packs (coll, count) headers and the [B, SL] payload burst of
    every fused lane into a single i32 buffer (exact bitcast for 32-bit
    heap dtypes; for 16-bit dtypes adjacent payload-element PAIRS are
    bitcast into i32 lanes per the registration-time
    ``lane_group_pack16`` pairing metadata, odd lane zero-padded), the
    reverse direction is one i32 credit-header ppermute.  With one
    communicator ring (the common case) the whole superstep costs exactly
    two ppermutes for BOTH 32-bit and 16-bit heaps, vs five per lane in
    the unfused scheme; ``cfg.packed_16bit=False`` (tables built without
    pairing metadata) restores the separate header/payload ppermutes for
    16-bit dtypes (three per superstep).
    """
    L, B, SL = outbox.fwd_payload.shape
    dt = outbox.fwd_payload.dtype
    fuse_payload = dt.itemsize == 4
    pack16 = t.lane_group_pack16 if dt.itemsize == 2 else None

    fwd_count = jnp.zeros_like(outbox.fwd_count)
    fwd_coll = jnp.zeros_like(outbox.fwd_coll)
    fwd_payload = jnp.zeros_like(outbox.fwd_payload)
    rev_count = jnp.zeros_like(outbox.rev_count)
    rev_coll = jnp.zeros_like(outbox.rev_coll)

    for gi, (group_lanes, fwd_pairs, rev_pairs) in enumerate(t.lane_groups):
        g = jnp.asarray(group_lanes)
        hdr = jnp.stack([outbox.fwd_coll[g], outbox.fwd_count[g]], axis=1)
        pay = outbox.fwd_payload[g].reshape(len(group_lanes), B * SL)
        if fuse_payload:
            # Single fwd ppermute: header ++ bitcast payload, all lanes.
            packed = jnp.concatenate(
                [hdr, jax.lax.bitcast_convert_type(pay, jnp.int32)
                 if dt != jnp.int32 else pay], axis=1)
            moved = jax.lax.ppermute(packed, axis_name, perm=fwd_pairs)
            got_hdr, got_pay = moved[:, :2], moved[:, 2:]
            if dt != jnp.int32:
                got_pay = jax.lax.bitcast_convert_type(got_pay, dt)
        elif pack16 is not None:
            # Packed 16-bit: element pairs ride i32 lanes alongside the
            # header in the SAME single fwd ppermute.
            cols, pad = pack16[gi]
            packed = jnp.concatenate([hdr, _pack16_to_i32(pay, pad)], axis=1)
            moved = jax.lax.ppermute(packed, axis_name, perm=fwd_pairs)
            got_hdr = moved[:, :2]
            got_pay = _unpack16_from_i32(moved[:, 2:2 + cols], dt, B * SL)
        else:
            got_hdr = jax.lax.ppermute(hdr, axis_name, perm=fwd_pairs)
            got_pay = jax.lax.ppermute(pay, axis_name, perm=fwd_pairs)
        fwd_coll = fwd_coll.at[g].set(got_hdr[:, 0])
        fwd_count = fwd_count.at[g].set(got_hdr[:, 1])
        fwd_payload = fwd_payload.at[g].set(
            got_pay.astype(dt).reshape(len(group_lanes), B, SL))

        rhdr = jnp.stack([outbox.rev_coll[g], outbox.rev_count[g]], axis=1)
        rgot = jax.lax.ppermute(rhdr, axis_name, perm=rev_pairs)
        rev_coll = rev_coll.at[g].set(rgot[:, 0])
        rev_count = rev_count.at[g].set(rgot[:, 1])

    return Mailbox(
        fwd_count=fwd_count, fwd_coll=fwd_coll, fwd_payload=fwd_payload,
        rev_count=rev_count, rev_coll=rev_coll,
    )


def _drained(st: DaemonState) -> jnp.ndarray:
    """All submitted work complete on this rank (reductions over [C])."""
    return ((st.sq_read >= st.sq_size)
            & ~jnp.any(st.tq_active)
            & ~jnp.any(st.inflight))


class TickFlags(NamedTuple):
    """Progress report of one ``tick(state, k)`` call.

    ``steps`` is how many supersteps actually ran (< k when the launch
    went not-live first); ``live`` is the fabric-wide continue flag after
    the tick (False: drained, voluntary quit, or budget — re-run
    ``launch_prologue`` before ticking again); ``drained`` is True when
    every rank's submitted work is complete."""

    steps: jnp.ndarray    # [] i32
    live: jnp.ndarray     # [] bool
    drained: jnp.ndarray  # [] bool


def launch_prologue(st: DaemonState) -> DaemonState:
    """Pure launch prologue (both backends; shape-generic over the leading
    rank axis): fresh launch clock + epoch tick + bounded queue-age rebase
    (see module docstring).  Does NOT touch SQ/CQ cursors — those belong
    to the submission boundary (sqcq.HostQueues.pack_sq host-side,
    device_api.device_prologue in-trace)."""
    st = st._replace(
        global_live=jnp.ones_like(st.global_live),
        no_prog=jnp.zeros_like(st.no_prog),
        launch_steps=jnp.zeros_like(st.launch_steps),
        epoch=st.epoch + 1,
    )
    return rebase_arrivals(st)


def _tick_accounting(st: DaemonState, steps: jnp.ndarray,
                     barrier: bool) -> DaemonState:
    """Stamp one tick's supersteps into the barrier/overlap split."""
    if barrier:
        return st._replace(tick_calls=st.tick_calls + 1,
                           barrier_steps=st.barrier_steps + steps)
    return st._replace(tick_calls=st.tick_calls + 1,
                       overlap_steps=st.overlap_steps + steps)


def _relink_edges(t: StaticTables) -> tuple:
    """Static per-edge relink descriptors for the sim daemon.

    Each chain edge c -> next_coll[c] rewrites the successor's contiguous
    input span ``heap_in[dst_lo : dst_lo + span]`` from a build-time-known
    gather of ``heap_out`` (tables._build_chain_links).  Because every
    offset is static, the sim daemon can apply the hand-off as a cheap
    static-slice + ``where``-select per superstep — no dynamic scatter, no
    cond over the heap.  When the source map is itself one contiguous run
    (the common chunk hand-off), the gather degrades to a static slice.

    Returns a hashable tuple of
    ``(c, dst_lo, span, ('slice', src_lo, n) | ('gather', idx_bytes))``
    entries (part of the jit-cache key alongside the config).
    """
    edges = []
    C = t.chain_dst.shape[0]
    for c in range(C):
        dst = t.chain_dst[c]
        valid = dst < (1 << 30)
        if not valid.any():
            continue
        span = int(valid.sum())
        dst_lo = int(dst[0])
        src = t.chain_src[c, :span]
        live = src >= 0
        n = int(live.sum())
        contiguous = (n > 0 and bool(live[:n].all())
                      and np.array_equal(src[:n],
                                         src[0] + np.arange(n, dtype=src.dtype)))
        if contiguous:
            desc = ("slice", int(src[0]), n)
        else:
            desc = ("gather", src.tobytes())
        edges.append((c, dst_lo, span, desc))
    return tuple(edges)


# One compiled daemon per (OcclConfig, relink edges) (tables are
# ARGUMENTS, so different registrations / test instances with the same
# config share the binary; the static chain-edge descriptors are part of
# the key because they shape the in-body relink slices).
_SIM_JIT_CACHE: dict = {}


def _edge_plan(edges: tuple) -> list:
    """Unpack the static relink-edge descriptors (trace-time constants)."""
    plan = []
    for c, dst_lo, span, desc in edges:
        if desc[0] == "slice":
            plan.append((c, dst_lo, span, desc[1], desc[2], None))
        else:
            idx = np.frombuffer(desc[1], dtype=np.int32).copy()
            plan.append((c, dst_lo, span, None, None,
                         (jnp.asarray(np.maximum(idx, 0)),
                          jnp.asarray(idx >= 0))))
    return plan


def _sim_body_fn(cfg: OcclConfig, edges: tuple) -> Callable:
    """ONE sim superstep: vmapped scheduler + deferred relink + fabric
    exchange + liveness consensus.  Shared verbatim by ``tick`` and the
    host daemon — the single definition is what makes tick-mode
    trajectories bit-identical to drive()-mode."""
    edge_plan = _edge_plan(edges)

    def vstep(sh, lt, st, inbox):
        return jax.vmap(
            functools.partial(rank_superstep, cfg, sh, defer_relink=True),
            in_axes=(0, 0, 0), out_axes=(0, 0))(lt, st, inbox)

    def body(sh, lt, fwd_src, rev_src, st, inbox):
        prev_sc = st.stage_completions
        st, outbox = vstep(sh, lt, st, inbox)
        # Deferred chain relink, applied in-body from purely STATIC
        # slices: under the per-rank vmap a cond predicate is batched
        # (lowers to a select paying the O(M) hand-off gather every
        # superstep), and a scalar-predicate cond touching the heap
        # in this hot body costs a full heap copy per superstep (XLA
        # loses carry aliasing at the loop back-edge).  Instead each
        # chain edge rewrites the successor's contiguous input span
        # with a static-slice + ``where``-select keyed on "did this
        # rank complete the predecessor this superstep" — a few KB of
        # vectorized traffic per superstep, no scatter, no cond.
        if edge_plan:
            fired = jax.vmap(chain_relink_fired,
                             in_axes=(None, 0, 0, 0))(
                sh, lt, prev_sc, st.stage_completions)
            heap_in, heap_out = st.heap_in, st.heap_out
            for c, dst_lo, span, src_lo, n, gather in edge_plan:
                if gather is None:
                    vals = heap_out[:, src_lo:src_lo + n]
                    if n < span:            # zero-filled pad tail
                        vals = jnp.concatenate(
                            [vals, jnp.zeros((vals.shape[0],
                                              span - n), vals.dtype)],
                            axis=1)
                else:
                    idx, live = gather
                    vals = jnp.where(live[None, :],
                                     heap_out[:, idx], 0)
                cur = heap_in[:, dst_lo:dst_lo + span]
                new = jnp.where(fired[:, c][:, None],
                                vals.astype(cur.dtype), cur)
                heap_in = heap_in.at[:, dst_lo:dst_lo + span].set(new)
            st = st._replace(heap_in=heap_in)
        inbox = _sim_exchange(fwd_src, rev_src, outbox)
        all_drained = jnp.all(jax.vmap(_drained)(st))
        quit_now = jnp.min(st.no_prog) >= cfg.quit_threshold
        over_budget = st.launch_steps[0] >= cfg.superstep_budget
        live = ~(all_drained | quit_now | over_budget)
        st = st._replace(
            global_live=jnp.broadcast_to(live, st.global_live.shape))
        return st, inbox

    return body


def _sim_tick_fn(cfg: OcclConfig, edges: tuple, barrier: bool) -> Callable:
    """tick(sh, lt, fwd_src, rev_src, st, k) -> (st, TickFlags), sim."""
    superstep = _sim_body_fn(cfg, edges)

    def tick(sh, lt, fwd_src, rev_src, st, k):
        def cond(carry):
            st, _, i = carry
            return st.global_live[0] & (i < k)

        def body(carry):
            st, inbox, i = carry
            st, inbox = superstep(sh, lt, fwd_src, rev_src, st, inbox)
            return st, inbox, i + jnp.int32(1)

        st, inbox, i = jax.lax.while_loop(
            cond, body, (st, _load_mailbox(st), jnp.int32(0)))
        st = _tick_accounting(_store_mailbox(st, inbox), i, barrier)
        flags = TickFlags(steps=i, live=st.global_live[0],
                          drained=jnp.all(jax.vmap(_drained)(st)))
        return st, flags

    return tick


def _sim_daemon_jit(cfg: OcclConfig, edges: tuple = ()) -> Callable:
    key = (cfg, edges)
    if key in _SIM_JIT_CACHE:
        return _SIM_JIT_CACHE[key]

    tick = _sim_tick_fn(cfg, edges, barrier=True)

    @jax.jit
    def daemon(sh: SharedTables, lt: LocalTables, fwd_src, rev_src,
               st: DaemonState) -> DaemonState:
        # A launch IS prologue + one barrier tick.  k = budget + 1 never
        # binds — the in-body budget check flips ``global_live`` first —
        # so the trajectory is bit-identical to the pre-tick unbounded
        # while loop.
        st, _ = tick(sh, lt, fwd_src, rev_src, launch_prologue(st),
                     jnp.int32(cfg.superstep_budget + 1))
        return st

    _SIM_JIT_CACHE[key] = daemon
    return daemon


def build_sim_tick(cfg: OcclConfig, t: StaticTables,
                   barrier: bool = False) -> Callable:
    """Traceable ``tick(state, k) -> (state, TickFlags)``, sim backend
    (state leaves carry the leading [R] rank axis).

    NOT jitted: compose it inside a jitted training step (see
    :mod:`repro.core.device_api`) or wrap in ``jax.jit`` for host use.
    ``barrier`` is a STATIC accounting tag — True means the caller is
    blocked on this tick (drive()/drain), False means the tick is hidden
    behind compute; it does not change scheduling."""
    sh = shared_tables(t)
    lt = local_tables(t)
    fwd_src = jnp.asarray(t.fwd_src)
    rev_src = jnp.asarray(t.rev_src)
    fn = _sim_tick_fn(cfg, _relink_edges(t), barrier)
    return lambda st, k: fn(sh, lt, fwd_src, rev_src, st, k)


def _load_mailbox(st: DaemonState) -> Mailbox:
    """Re-inject messages that were on the wire at the last daemon exit."""
    return Mailbox(
        fwd_count=st.mb_fwd_count, fwd_coll=st.mb_fwd_coll,
        fwd_payload=st.mb_fwd_payload,
        rev_count=st.mb_rev_count, rev_coll=st.mb_rev_coll)


def _store_mailbox(st: DaemonState, inbox: Mailbox) -> DaemonState:
    return st._replace(
        mb_fwd_count=inbox.fwd_count, mb_fwd_coll=inbox.fwd_coll,
        mb_fwd_payload=inbox.fwd_payload,
        mb_rev_count=inbox.rev_count, mb_rev_coll=inbox.rev_coll)


def build_sim_daemon(cfg: OcclConfig, t: StaticTables) -> Callable:
    """Daemon for the sim backend: state [R,...] -> state."""
    sh = shared_tables(t)
    lt = local_tables(t)
    fwd_src = jnp.asarray(t.fwd_src)
    rev_src = jnp.asarray(t.rev_src)
    fn = _sim_daemon_jit(cfg, _relink_edges(t))
    return lambda st: fn(sh, lt, fwd_src, rev_src, st)


def build_shardmap_daemon(cfg: OcclConfig, t: StaticTables, mesh,
                          axis_name: str = "rank") -> Callable:
    """jit daemon over a real device mesh: state leaves are [R, ...]
    sharded along ``axis_name``; each device runs the per-rank scheduler
    and the connector fabric is a ppermute pair per lane per superstep."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    mesh_daemon = build_mesh_daemon(cfg, t, axis_name)

    def per_dev(st_slice: DaemonState) -> DaemonState:
        st1 = jax.tree_util.tree_map(lambda a: a[0], st_slice)
        st1 = mesh_daemon(st1)
        return jax.tree_util.tree_map(lambda a: a[None], st1)

    inner = shard_map(per_dev, mesh=mesh, in_specs=P(axis_name),
                      out_specs=P(axis_name), check_rep=False)

    @jax.jit
    def daemon(st: DaemonState) -> DaemonState:
        return inner(st)

    return daemon


def _count_primitive(jaxpr, name: str) -> int:
    """Recursively count occurrences of primitive ``name`` in a jaxpr
    (descends into call/scan/shard_map sub-jaxprs via eqn params)."""
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            n += 1
        for p in eqn.params.values():
            for sub in (p if isinstance(p, (list, tuple)) else [p]):
                inner = getattr(sub, "jaxpr", sub)
                if hasattr(inner, "eqns"):
                    n += _count_primitive(inner, name)
    return n


def count_exchange_ppermutes(cfg: OcclConfig, n_comms: int = 1) -> int:
    """Trace one ``_mesh_exchange`` superstep and count its ppermute ops.

    The fusion structure depends only on the heap dtype, the packing
    metadata and the lane grouping — not on the ring size — so the trace
    runs on a single-device mesh (always available; tier-1 and the mesh
    perf record both use this without multi-device XLA flags).
    """
    import dataclasses as _dc

    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from .primitives import Communicator
    from .tables import build_tables

    cfg1 = _dc.replace(cfg, n_ranks=1, max_comms=max(cfg.max_comms, n_comms))
    comms = [Communicator(comm_id=i, members=(0,), lane=i)
             for i in range(n_comms)]
    t = build_tables(cfg1, comms, [])
    L, B, SL = cfg1.max_comms, cfg1.burst_slices, cfg1.slice_elems
    dt = jnp.dtype(cfg1.dtype)
    outbox = Mailbox(
        fwd_count=jnp.zeros((1, L), jnp.int32),
        fwd_coll=jnp.zeros((1, L), jnp.int32),
        fwd_payload=jnp.zeros((1, L, B, SL), dt),
        rev_count=jnp.zeros((1, L), jnp.int32),
        rev_coll=jnp.zeros((1, L), jnp.int32),
    )
    mesh = jax.make_mesh((1,), ("rank",))

    def per_dev(ob: Mailbox) -> Mailbox:
        ob1 = jax.tree_util.tree_map(lambda a: a[0], ob)
        out = _mesh_exchange(t, ob1, "rank")
        return jax.tree_util.tree_map(lambda a: a[None], out)

    fn = shard_map(per_dev, mesh=mesh, in_specs=P("rank"),
                   out_specs=P("rank"), check_rep=False)
    closed = jax.make_jaxpr(fn)(outbox)
    return _count_primitive(closed.jaxpr, "ppermute")


def build_mesh_tick(cfg: OcclConfig, t: StaticTables, axis_name: str,
                    rank_of_device: np.ndarray | None = None,
                    barrier: bool = False) -> Callable:
    """Per-device ``tick(state, k) -> (state, TickFlags)`` for use inside
    ``shard_map``.

    ``rank_of_device`` maps the device's linear index along ``axis_name`` to
    its OCCL rank (identity by default).  The returned callable takes and
    returns the per-device DaemonState (no leading rank axis); static
    tables are indexed by the device's rank via ``lax.axis_index``.  The
    flags are replicated across devices by construction: ``live`` is the
    fabric consensus computed inside the body, ``steps`` follows the
    uniform loop cond, and ``drained`` is an explicit all_gather.
    """
    sh = shared_tables(t)
    lt_all = local_tables(t)  # leading rank axis; gathered per device
    if rank_of_device is None:
        rank_of_device = np.arange(cfg.n_ranks)
    rod = jnp.asarray(rank_of_device, jnp.int32)

    def tick(st: DaemonState, k) -> tuple[DaemonState, TickFlags]:
        dev = jax.lax.axis_index(axis_name)
        rank = rod[dev]
        lt = jax.tree_util.tree_map(lambda a: a[rank], lt_all)

        def cond(carry):
            st, _, i = carry
            return st.global_live & (i < k)

        def body(carry):
            st, inbox, i = carry
            st, outbox = rank_superstep(cfg, sh, lt, st, inbox,
                                        cond_relink=cfg.cond_chain_relink)
            inbox = _mesh_exchange(t, outbox, axis_name)
            # Fabric-wide consensus on liveness (computed in the body so the
            # cond stays collective-free).
            drained = jnp.all(
                jax.lax.all_gather(_drained(st), axis_name))
            stuck = jnp.all(
                jax.lax.all_gather(st.no_prog >= cfg.quit_threshold,
                                   axis_name))
            over = st.launch_steps >= cfg.superstep_budget
            st = st._replace(global_live=~(drained | stuck | over))
            return st, inbox, i + jnp.int32(1)

        st, inbox, i = jax.lax.while_loop(
            cond, body, (st, _load_mailbox(st), jnp.int32(0)))
        st = _tick_accounting(_store_mailbox(st, inbox), i, barrier)
        flags = TickFlags(
            steps=i, live=st.global_live,
            drained=jnp.all(jax.lax.all_gather(_drained(st), axis_name)))
        return st, flags

    return tick


def build_mesh_daemon(cfg: OcclConfig, t: StaticTables, axis_name: str,
                      rank_of_device: np.ndarray | None = None) -> Callable:
    """Per-device daemon body for use inside ``shard_map``: a launch is
    ``launch_prologue`` + one barrier tick (k = budget + 1 never binds —
    the in-body budget check flips ``global_live`` first)."""
    tick = build_mesh_tick(cfg, t, axis_name, rank_of_device, barrier=True)

    def daemon(st: DaemonState) -> DaemonState:
        st, _ = tick(launch_prologue(st),
                     jnp.int32(cfg.superstep_budget + 1))
        return st

    return daemon


def build_shardmap_tick(cfg: OcclConfig, t: StaticTables, mesh,
                        axis_name: str = "rank",
                        rank_of_device: np.ndarray | None = None,
                        barrier: bool = False) -> Callable:
    """Traceable ``tick(state, k) -> (state, TickFlags)`` over a real
    device mesh: state leaves are [R, ...] sharded along ``axis_name``,
    ``k`` and the returned flags are replicated.  NOT jitted — compose it
    inside a jitted step or wrap in ``jax.jit`` for host use."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    mesh_tick = build_mesh_tick(cfg, t, axis_name, rank_of_device,
                                barrier=barrier)

    def per_dev(st_slice: DaemonState, k):
        st1 = jax.tree_util.tree_map(lambda a: a[0], st_slice)
        st1, flags = mesh_tick(st1, k)
        return jax.tree_util.tree_map(lambda a: a[None], st1), flags

    return shard_map(per_dev, mesh=mesh, in_specs=(P(axis_name), P()),
                     out_specs=(P(axis_name), P()), check_rep=False)
