"""Serve a small model with continuous batching, then show the QoS win:
decode collectives sharing one OCCL fabric with an adversarial
background tenant, with preemption ON vs OFF.

Part 1 runs the engine standalone (prefill + step-locked decode over
recycled batch slots).  Part 2 attaches a :class:`ServingQos` fabric:
every decode step issues a tensor-parallel all-reduce while a background
tenant keeps grad-sync bursts at its admission cap — with preemption the
decode op cuts the burst mid-transfer at slice granularity; without it,
decode waits the whole transfer out.  The before/after p99 (in fabric
supersteps) is the number the serving bench gates on.

    PYTHONPATH=src python examples/serve_batched.py --arch llama3-8b
"""
import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.configs import get_config
from repro.serving.engine import Request, ServingEngine
from repro.serving.qos import ServingQos


def _reqs(n, vocab, max_new, rng):
    return [Request(rid=i,
                    prompt=rng.randint(0, vocab, size=rng.randint(4, 16)),
                    max_new_tokens=max_new) for i in range(n)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()

    # --- 1. engine alone: continuous batching over recycled slots ------
    eng = ServingEngine(cfg, batch_size=4, prompt_len=16)
    rng = np.random.RandomState(0)
    for r in _reqs(args.requests, cfg.vocab, args.max_new, rng):
        eng.submit(r)
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    for r in done[:4]:
        print(f"req {r.rid}: {len(r.out_tokens)} tokens -> "
              f"{r.out_tokens[:8]}...")
    tok = eng.stats["tokens"]
    assert tok == sum(len(r.out_tokens) for r in done)
    print(f"{len(done)} requests, {tok} tokens in {dt:.2f}s "
          f"({tok/dt:.1f} tok/s, {eng.stats['prefills']} prefills, "
          f"{eng.stats['decode_steps']} decode steps)")

    # --- 2. QoS before/after: decode p99 vs an adversarial tenant ------
    def contended_run(preemption):
        qos = ServingQos(n_ranks=4, decode_elems=256, prefill_elems=1024,
                         background_elems=4096, background_buckets=2,
                         preemption=preemption, prio_aging_quantum=8)
        e = ServingEngine(cfg, batch_size=4, prompt_len=16, qos=qos)
        for r in _reqs(args.requests, cfg.vocab, args.max_new,
                       np.random.RandomState(0)):
            e.submit(r)
        e.run()                 # decode_event pumps the background tenant
        qos.drain()             # bounded starvation: bursts all land
        return e.stats["qos"], qos

    off, qos_off = contended_run(False)
    on, qos_on = contended_run(True)
    print("decode p99 vs adversarial background "
          "(fabric supersteps per collective):")
    print(f"  preemption OFF: p50 {off['decode']['p50']:.0f}  "
          f"p99 {off['decode']['p99']:.0f}")
    print(f"  preemption ON : p50 {on['decode']['p50']:.0f}  "
          f"p99 {on['decode']['p99']:.0f}")
    for label, q in (("off", qos_off), ("on", qos_on)):
        bg = q.tenants[list(q.tenants)[0]]
        print(f"  background ({label}): {bg.completed}/{bg.submitted} "
              "bursts completed after drain (degrades, not starves)")
    assert on["decode"]["p99"] < off["decode"]["p99"]

    # --- 3. the mechanism itself: a decode submit landing MID-burst ----
    # The engine drives the fabric event-wise, so priority ORDERING
    # already wins above; here a burst is mid-transfer on a live daemon
    # when decode arrives, and the slice-granular preempt counter shows
    # the cut.
    qos = ServingQos(n_ranks=4, decode_elems=256, background_elems=4096,
                     preemption=True)
    qos.submit_background()
    qos.advance(2)              # burst holds the lane mid-superstep
    lat = qos.wait(qos.submit_decode())
    qos.drain()
    print(f"mid-burst decode: {lat} supersteps, "
          f"preempts {qos.summary()['preempts']}")
    assert qos.summary()["preempts"] > 0
    print("OK")


if __name__ == "__main__":
    main()
