"""Per-arch smoke tests (reduced configs) + numerical model properties:
blockwise==full attention, SSD chunked==naive recurrence, MoE dispatch==
dense oracle, prefill/decode==train forward consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Heavyweight model sweeps: excluded from tier-1 (`pytest -q`); run with `pytest -m ""`.
pytestmark = pytest.mark.slow

from repro.configs import get_config, _REGISTRY
from repro.configs.base import ShapeCell
from repro.models import build_model, input_specs, make_concrete
from repro.models.attention import attention, blockwise_attention


CELL_T = ShapeCell("t", 32, 2, "train")
CELL_P = ShapeCell("p", 32, 2, "prefill")


@pytest.mark.parametrize("arch", sorted(_REGISTRY))
def test_arch_smoke(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init(0)
    batch = make_concrete(input_specs(cfg, CELL_T), 1, vocab=cfg.vocab)
    loss, grads = jax.jit(jax.value_and_grad(m.loss_fn))(params, batch)
    assert np.isfinite(float(loss))
    for leaf in jax.tree_util.tree_leaves(grads):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("arch", ["llama3-8b", "mamba2-2.7b", "zamba2-1.2b",
                                  "seamless-m4t-large-v2", "deepseek-moe-16b"])
def test_arch_prefill_decode(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init(0)
    pb = make_concrete(input_specs(cfg, CELL_P), 2, vocab=cfg.vocab)
    logits, cache = jax.jit(m.prefill)(params, pb)
    assert np.isfinite(np.asarray(logits)).all()
    toks = jnp.zeros((2,), jnp.int32)
    for _ in range(3):
        logits, cache = jax.jit(m.decode_step)(params, cache, toks)
        toks = jnp.argmax(logits, -1).astype(jnp.int32)
        assert np.isfinite(np.asarray(logits)).all()


def test_blockwise_equals_full_attention():
    import repro.models.attention as A
    rng = np.random.RandomState(0)
    B, S, Hq, Hkv, dh = 2, 4096, 4, 2, 16
    q = jnp.asarray(rng.randn(B, S, Hq, dh), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, Hkv, dh), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, Hkv, dh), jnp.float32)
    for mode, win, pre in [("causal", 0, 0), ("bidir", 0, 0),
                           ("causal", 64, 0), ("prefix", 0, 7)]:
        full = attention(q, k, v, mode=mode, window=win, prefix_len=pre)
        blk = blockwise_attention(q, k, v, mode=mode, window=win,
                                  prefix_len=pre)
        np.testing.assert_allclose(np.asarray(full), np.asarray(blk),
                                   rtol=2e-4, atol=2e-5)


def test_ssd_matches_naive_recurrence():
    """Chunked SSD == step-by-step linear recurrence oracle."""
    from repro.models import ssm as S
    cfg = get_config("mamba2-2.7b").reduced()
    cfg = dataclasses.replace(cfg, ssm_chunk=4)
    m = build_model(cfg)
    params0 = jax.tree_util.tree_map(
        lambda a: a[0], m.init(0)["layers"])     # first layer's params
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(2, 16, cfg.d_model) * 0.3, jnp.float32)

    y_chunked, (state, convs) = S.ssd_forward(cfg, params0, x)

    # oracle: token-by-token decode steps
    H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    GN = cfg.ssm_groups * N
    K1 = cfg.ssm_dconv - 1
    st = (jnp.zeros((2, H, N, P), jnp.float32),
          (jnp.zeros((2, K1, cfg.d_inner)), jnp.zeros((2, K1, GN)),
           jnp.zeros((2, K1, GN))))
    ys = []
    for t in range(16):
        y_t, st = S.ssd_decode_step(cfg, params0, x[:, t:t + 1], st)
        ys.append(y_t)
    y_naive = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_naive),
                               rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(st[0]),
                               rtol=2e-3, atol=2e-4)


def test_moe_dispatch_matches_dense_oracle():
    from repro.models import moe as M
    cfg = get_config("deepseek-moe-16b").reduced()
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops
    root = jax.random.PRNGKey(0)
    p = M.init_moe_block(root, "t", cfg, jnp.float32)
    rng = np.random.RandomState(2)
    x = jnp.asarray(rng.randn(2, 8, cfg.d_model) * 0.5, jnp.float32)
    y, aux = M.moe_forward(cfg, p, x)
    y_ref = M.moe_forward_dense_ref(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-5)
    assert np.isfinite(float(aux))


def test_prefill_decode_consistency_with_train():
    """The decode path must produce the same next-token logits as the
    training forward at the same position."""
    cfg = get_config("llama3-8b").reduced()
    m = build_model(cfg)
    params = m.init(0)
    rng = np.random.RandomState(3)
    S = 16
    toks = jnp.asarray(rng.randint(0, cfg.vocab, (2, S)), jnp.int32)

    # train-style forward logits at position S-1 given tokens[:, :S]
    batch = {"tokens": toks, "targets": toks}
    # reuse prefill for ground truth at S, then decode one step and compare
    # against prefill of S+1 tokens.
    logits_p, cache = jax.jit(
        lambda p, b: m.prefill(p, b, pad_to=S))(
        params, {"tokens": toks[:, :S - 1]})
    logits_d, cache = jax.jit(m.decode_step)(params, cache, toks[:, S - 1])
    logits_full, _ = jax.jit(m.prefill)(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(logits_d),
                               np.asarray(logits_full[:, 0]),
                               rtol=2e-3, atol=2e-3)


def test_grid_cells_and_skips():
    from repro.configs import all_cells, ASSIGNED_ARCHS
    cells = all_cells()
    assert len(ASSIGNED_ARCHS) == 10
    # 10 archs x 4 shapes = 40 potential; 7 long_500k skips documented
    archs_with_500k = {a for a, c in cells if c == "long_500k"}
    assert archs_with_500k == {"mamba2-2.7b", "zamba2-1.2b",
                               "h2o-danube-3-4b"}
    assert len(cells) == 33
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for cell, reason in cfg.skip_cells:
            assert reason  # every skip carries its justification


def test_causal_rec_matches_blockwise():
    """Recursive-halving causal attention (the beyond-paper flop saver)
    is numerically identical to masked blockwise attention."""
    from repro.models.attention import (blockwise_attention,
                                        causal_rec_attention)
    rng = np.random.RandomState(5)
    B, S, Hq, Hkv, dh = 1, 4096, 4, 2, 16
    q = jnp.asarray(rng.randn(B, S, Hq, dh), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, Hkv, dh), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, Hkv, dh), jnp.float32)
    want = blockwise_attention(q, k, v, mode="causal")
    for levels in (1, 2, 3):
        got = causal_rec_attention(q, k, v, levels)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-5)


def test_moe_combine_scatter_matches_gather(monkeypatch):
    from repro.models import moe as M
    cfg = get_config("deepseek-moe-16b").reduced()
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    root = jax.random.PRNGKey(0)
    p = M.init_moe_block(root, "t", cfg, jnp.float32)
    rng = np.random.RandomState(4)
    x = jnp.asarray(rng.randn(2, 8, cfg.d_model) * 0.5, jnp.float32)
    y_g, _ = M.moe_forward(cfg, p, x)
    monkeypatch.setenv("REPRO_MOE_COMBINE", "scatter")
    y_s, _ = M.moe_forward(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y_g), np.asarray(y_s),
                               rtol=2e-4, atol=2e-5)
