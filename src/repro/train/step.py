"""train_step / serve_step builders.

``make_train_step`` returns the canonical data/tensor-parallel training
step: loss -> grads (DP all-reduce inserted by SPMD) -> clip -> AdamW.
Gradient synchronization is the OCCL integration point: with
``grad_sync="xla"`` the reduction is the statically-sequenced XLA psum
(the paper's "statically sequenced NCCL" baseline); ``grad_sync="occl"``
routes bucketed gradients through the OCCL runtime between the backward
and optimizer phases (host-driven, see train/occl_sync.py).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import build_model
from ..optim.adamw import AdamWConfig, adamw_update
from .state import TrainState


def make_train_step(cfg: ArchConfig,
                    opt: AdamWConfig = AdamWConfig()) -> Callable:
    model = build_model(cfg)

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        loss, grads = jax.value_and_grad(model.loss_fn)(state.params, batch)
        new_p, new_m, new_v, gnorm = adamw_update(
            opt, state.params, grads, state.m, state.v, state.step)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm}
        return TrainState(new_p, new_m, new_v, state.step + 1), metrics

    return train_step


def make_grads_step(cfg: ArchConfig) -> Callable:
    """Backward only — used by the OCCL-grad-sync integration, which
    synchronizes gradient buckets itself (train/occl_sync.py) and then
    applies make_apply_step."""
    model = build_model(cfg)

    def grads_step(state: TrainState, batch):
        loss, grads = jax.value_and_grad(model.loss_fn)(state.params, batch)
        return loss.astype(jnp.float32), grads

    return grads_step


def make_apply_step(cfg: ArchConfig,
                    opt: AdamWConfig = AdamWConfig()) -> Callable:
    def apply_step(state: TrainState, grads) -> TrainState:
        new_p, new_m, new_v, _ = adamw_update(
            opt, state.params, grads, state.m, state.v, state.step)
        return TrainState(new_p, new_m, new_v, state.step + 1)

    return apply_step


def make_prefill_step(cfg: ArchConfig) -> Callable:
    model = build_model(cfg)

    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step


def make_decode_step(cfg: ArchConfig) -> Callable:
    model = build_model(cfg)

    def serve_step(params, cache, tokens):
        logits, cache = model.decode_step(params, cache, tokens)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache

    return serve_step
