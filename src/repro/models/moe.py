"""Fine-grained MoE with shared experts (DeepSeekMoE / Kimi-K2 style).

Sort-based capacity dispatch (MaxText-style, no [T, E] one-hots):
tokens' (token, expert) assignments are sorted by expert id; each expert
gathers its first ``capacity`` slots; overflow tokens are dropped (weighted
combine renormalizes).  This keeps peak memory at E*cap*D = T*k*cf*D —
inherent to top-k — and maps onto expert parallelism: expert-major
intermediates are sharded over the "model" axis (an all-to-all at dispatch
and combine, inserted by SPMD from the sharding constraints).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .layers import ninit


def _ep(x, spec):
    """Expert-parallel sharding constraint (REPRO_MOE_EP=1; needs an
    ambient mesh — jax.sharding.use_mesh — else it is a no-op).  §Perf:
    without it GSPMD all-gathers the full token array into every
    expert shard."""
    if os.environ.get("REPRO_MOE_EP") != "1":
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def init_moe_block(root, path, cfg, dtype):
    D, E, Fe = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    Fs = cfg.n_shared_experts * Fe
    p = {
        "router": ninit(root, f"{path}/router", (D, E), 0.02, jnp.float32),
        "wg": ninit(root, f"{path}/wg", (E, D, Fe), 0.02, dtype),
        "wu": ninit(root, f"{path}/wu", (E, D, Fe), 0.02, dtype),
        "wd": ninit(root, f"{path}/wd", (E, Fe, D),
                    0.02 / np.sqrt(2 * cfg.n_layers), dtype),
    }
    if Fs:
        p.update(
            shared_wg=ninit(root, f"{path}/swg", (D, Fs), 0.02, dtype),
            shared_wu=ninit(root, f"{path}/swu", (D, Fs), 0.02, dtype),
            shared_wd=ninit(root, f"{path}/swd", (Fs, D),
                            0.02 / np.sqrt(2 * cfg.n_layers), dtype),
        )
    return p


def moe_forward(cfg, params, x, *, ep_constraint=None):
    """x: [B, S, D] -> [B, S, D] (+ aux load-balance loss).

    ep_constraint: optional fn(array, spec) applying
    with_sharding_constraint for expert-parallel layouts.
    """
    B, S, D = x.shape
    E, k, Fe = cfg.n_experts, cfg.top_k, cfg.d_ff_expert
    T = B * S
    xt = x.reshape(T, D)

    logits = (xt.astype(jnp.float32) @ params["router"])        # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)                        # [T, k]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.bincount(topi.reshape(-1), length=E).astype(jnp.float32) / (T * k)
    aux = E * jnp.sum(me * ce)

    cap = int(np.ceil(T * k / E * cfg.capacity_factor))
    cap = max(4, int(-(-cap // 4) * 4))

    # --- sort-based dispatch -------------------------------------------
    flat_e = topi.reshape(-1)                                   # [T*k]
    order = jnp.argsort(flat_e)                                 # stable
    sorted_e = flat_e[order]
    sorted_tok = order // k
    sorted_w = topv.reshape(-1)[order]

    starts = jnp.searchsorted(sorted_e, jnp.arange(E))          # [E]
    slot = starts[:, None] + jnp.arange(cap)[None, :]           # [E, cap]
    slot_c = jnp.clip(slot, 0, T * k - 1)
    valid = (sorted_e[slot_c] == jnp.arange(E)[:, None]) & (slot < T * k)
    tok_idx = jnp.where(valid, sorted_tok[slot_c], 0)           # [E, cap]
    w = jnp.where(valid, sorted_w[slot_c], 0.0)                 # [E, cap]

    # experts over "model" (EP); capacity slots optionally over "data"
    # (REPRO_MOE_CAP_SHARD=1 splits expert work 256 ways but makes GSPMD
    # reshard the dispatch gathers — measured trade-off in §Perf).
    cap_axes = ("data",) if os.environ.get("REPRO_MOE_CAP_SHARD") == "1" \
        else (None,)
    spec2 = P("model", *cap_axes)
    spec3 = P("model", *cap_axes, None)
    tok_idx = _ep(tok_idx, spec2)
    w = _ep(w, spec2)
    xe = xt[tok_idx]                                            # [E, cap, D]
    xe = _ep(xe, spec3)
    h = jnp.einsum("ecd,edf->ecf", xe, params["wg"])
    u = jnp.einsum("ecd,edf->ecf", xe, params["wu"])
    h = _ep(jax.nn.silu(h) * u, spec3)
    ye = jnp.einsum("ecf,efd->ecd", h, params["wd"])            # [E, cap, D]
    ye = _ep(ye, spec3)

    # --- weighted combine ------------------------------------------------
    if os.environ.get("REPRO_MOE_COMBINE", "gather") == "scatter":
        # scatter-add back to token space
        yt = jnp.zeros((T, D), ye.dtype)
        yt = yt.at[tok_idx.reshape(-1)].add(
            (ye * w[..., None].astype(ye.dtype)).reshape(-1, D))
    else:
        # gather via the inverse permutation: every (token, j) assignment
        # reads its expert slot: sorted position q -> slot (e, q-starts[e])
        inv = jnp.argsort(order)                                # [T*k]
        e_of = flat_e                                           # [T*k]
        slot_of = inv - starts[e_of]                            # [T*k]
        in_cap = slot_of < cap
        flat_idx = jnp.where(
            in_cap, e_of * cap + jnp.clip(slot_of, 0, cap - 1), 0)
        yg = ye.reshape(E * cap, D)[flat_idx]                   # [T*k, D]
        wg_ = jnp.where(in_cap, topv.reshape(-1), 0.0)
        yt = jnp.sum((yg * wg_[:, None].astype(ye.dtype)).reshape(T, k, D),
                     axis=1)

    if "shared_wg" in params:
        h = jax.nn.silu(xt @ params["shared_wg"]) * (xt @ params["shared_wu"])
        yt = yt + h @ params["shared_wd"]
    return yt.reshape(B, S, D), aux


def moe_forward_dense_ref(cfg, params, x):
    """O(T*E) oracle: every expert on every token, weighted by router
    (with the same top-k mask).  For correctness tests on tiny configs."""
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)
    logits = xt.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, cfg.top_k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    gates = jnp.zeros_like(probs)
    gates = jax.vmap(lambda g, i, v: g.at[i].set(v))(gates, topi, topv)
    h = jnp.einsum("td,edf->tef", xt, params["wg"])
    u = jnp.einsum("td,edf->tef", xt, params["wu"])
    ye = jnp.einsum("tef,efd->ted", jax.nn.silu(h) * u, params["wd"])
    yt = jnp.einsum("te,ted->td", gates.astype(ye.dtype), ye)
    if "shared_wg" in params:
        hs = jax.nn.silu(xt @ params["shared_wg"]) * (xt @ params["shared_wu"])
        yt = yt + hs @ params["shared_wd"]
    return yt.reshape(B, S, D)
