"""Hang -> diagnose -> evict -> resume: the reliability loop end to end.

An 8-rank grad-sync round wedges because rank 5 dies mid-step.  The
drive times out with a :class:`DeadlockTimeout` that carries the flight
recorder's export and a diagnosis naming the holder; ``evict(5)`` drains
the fabric, rebuilds every communicator and registration for 7 ranks,
replays the survivors' staged submissions and finishes the round in ONE
relaunch — bit-identical to a fresh 7-rank runtime driving the same
workload, which this script verifies at the end.

    PYTHONPATH=src python examples/elastic_shrink.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import CollKind, DeadlockTimeout, OcclConfig, OcclRuntime
from repro.core.recorder import EVENT_NAMES, events

R, C, N = 8, 4, 1024
DEAD = 5


def build(n_ranks):
    cfg = OcclConfig(n_ranks=n_ranks, max_colls=C + 2, max_comms=1,
                     slice_elems=64, conn_depth=8, heap_elems=1 << 16,
                     superstep_budget=1 << 15)
    rt = OcclRuntime(cfg)
    comm = rt.communicator(range(n_ranks))
    # register() returns CollectiveHandles: they survive the shrink by
    # re-resolving through the registration log, so the SAME handle
    # objects keep working after evict() rebuilds the id space.
    handles = [rt.register(CollKind.ALL_REDUCE, comm, n_elems=N)
               for _ in range(C)]
    return rt, handles


# Integer-valued payloads make the ring reduction exact, so the final
# comparison against the fresh 7-rank runtime can demand bit-equality.
rng = np.random.RandomState(0)
payload = {(r, c): rng.randint(0, 1 << 10, N).astype(np.float32)
           for r in range(R) for c in range(C)}

rt, hs = build(R)

# --- 1. the wedged round: rank 5 dies, everyone else submits ----------
for c, h in enumerate(hs):
    for r in range(R):
        if r != DEAD:
            h.submit(r, prio=c, data=payload[(r, c)])
try:
    rt.drive(max_launches=4)
    raise SystemExit("expected a DeadlockTimeout")
except DeadlockTimeout as e:
    print("drive() timed out, as expected:")
    print(f"  {e}\n")
    # The exception carries the flight recorder's export: the newest
    # per-collective events of the wedged rank's peers show the fabric
    # stalled waiting, not computing.
    tail = events(e.flight_record, rank=0)[-3:]
    print("  rank 0 recorder tail:",
          ", ".join(f"{EVENT_NAMES[ev.kind]}(coll={ev.coll})"
                    for ev in tail))
    assert DEAD in e.diagnosis.holders

# --- 2. evict the dead rank and resume --------------------------------
report = rt.evict(DEAD)
print(f"\nevict({DEAD}): now R={report['n_ranks']}, replayed "
      f"{report['replayed']} staged submissions, dropped "
      f"{report['dropped']} from the dead rank "
      f"(generation {report['generation']})")
steps = int(np.asarray(rt.stats()["supersteps"]).max())
print(f"survivors' round completed in {steps} supersteps after rebuild")

# --- 3. verify bit-equality against a fresh 7-rank runtime ------------
survivors = [r for r in range(R) if r != DEAD]
fresh, fhs = build(R - 1)
for c, h in enumerate(fhs):
    for new_r, old in enumerate(survivors):
        h.submit(new_r, prio=c, data=payload[(old, c)])
fresh.drive()
for c in range(C):
    for new_r in range(R - 1):
        np.testing.assert_array_equal(np.asarray(hs[c].read(new_r)),
                                      np.asarray(fhs[c].read(new_r)))
fresh_steps = int(np.asarray(fresh.stats()["supersteps"]).max())
print(f"\nOK — bit-identical to a fresh {R - 1}-rank runtime "
      f"({steps} vs {fresh_steps} supersteps).")
