"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal
[arXiv:2308.11596].  The modality frontend is a STUB per spec:
``input_specs()`` provides precomputed frame embeddings [B, frames, D].
Both encoder and decoder have 24 layers; decode shapes run against the
decoder with cross-attention to stub encoder memory."""
from .base import ArchConfig, _FULL_ATTN_500K_SKIP

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_head=64,
    d_ff=8192, vocab=256206,
    enc_layers=24, enc_frames=1024,
    skip_cells=(_FULL_ATTN_500K_SKIP,),
)
