"""Measured α-β-γ (latency-bandwidth-stage) cost model for ``algo="auto"``.

The element-count threshold the composite layer shipped with could only
encode ONE crossover, hand-picked per machine.  What actually flips the
winning algorithm is measured wall-clock (the MPI-vs-NCCL broadcast study,
PAPERS.md: the winner changes with payload size; The Big Send-off:
topology-aware decompositions win only when the interconnect is the
bottleneck), so selection is a fitted linear model over three structural
features any CompositePlan exposes at registration time:

``predicted_wall = α · supersteps + β · bytes_on_wire + γ · n_stages``

* **supersteps** — Σ over stages of ``program_len · rounds ·
  ceil(slices_per_step / lane_cap)``: the latency term, aware of the
  per-lane burst caps the bandwidth-skew knob (cfg.bandwidth_groups)
  imposes, so a flat ring whose single lane crosses island boundaries is
  charged the slow inter cap on EVERY step while a hierarchical plan pays
  it only on its inter stages.
* **bytes_on_wire** — Σ over stages of per-rank payload bytes forwarded
  per lane (``program_len · rounds · slices · slice_elems · itemsize``):
  the bandwidth term; ring all-reduce is bandwidth-optimal, so this is
  what protects it at large payloads on uniform fabrics.
* **n_stages** — the per-stage overhead term: a chained registration pays
  fixed costs per stage hand-off (successor enqueue, relink scatter,
  extra program dispatch) that dominate small payloads; γ is what makes
  ``auto`` keep the flat ring below the measured crossover.

Both terms are a2a-aware for free: ``program_len`` of the ring
all-to-all counts its ``1 + (R-1)(R+2)/2`` steps INCLUDING the
RECV_SEND relay hops, so the flat ring is charged the O(R²) forwarding
it really does, while the hierarchical ``two_level`` a2a pays only its
two short intra/inter exchanges — which is exactly the structure that
lets ``auto`` rank flat vs hierarchical a2a without any kind-specific
feature code.

(α, β, γ) are CALIBRATED PER BACKEND from the measured BENCH history:
``benchmarks/calibrate.py`` fits a rank-aware non-negative least squares
over the ``algos`` sweep samples of BENCH_collectives.json (each sample
records these features next to its measured wall-clock; support sets
that invert a measured same-config ordering lose to ones that preserve
it — see :func:`fit`) and persists the fit to
``BENCH_calibration.json`` beside it; :meth:`CostModel.load` is what
registration-time ``select_algo("auto")`` consults.  With no calibration
file the conservative :meth:`CostModel.default` is used (α = 1 superstep
unit, β = 0, γ = 24 superstep-equivalents per stage — composite plans
must win by a clear superstep margin before auto leaves the flat ring).
"""
from __future__ import annotations

import dataclasses
import json
import os
from pathlib import Path
from typing import Optional, Sequence

import numpy as np

from .primitives import CollKind, derive_slicing, program_len

# Default location: beside BENCH_collectives.json at the repo root
# (costmodel.py lives at src/repro/core/).  REPRO_CALIBRATION overrides
# (tests / alternate machines).
CALIBRATION_JSON = Path(__file__).resolve().parents[3] / "BENCH_calibration.json"


def _calibration_path(path=None) -> Path:
    if path is not None:
        return Path(path)
    env = os.environ.get("REPRO_CALIBRATION")
    return Path(env) if env else CALIBRATION_JSON


@dataclasses.dataclass(frozen=True)
class CostModel:
    """A fitted (α, β, γ) triple; ``source`` records provenance."""

    alpha: float = 1.0          # per superstep
    beta: float = 0.0           # per wire byte
    gamma: float = 24.0         # per chain stage
    source: str = "default"

    def predict(self, features: dict) -> float:
        """Predicted wall-clock (model units) of one plan's features."""
        return (self.alpha * features["supersteps"]
                + self.beta * features["bytes"]
                + self.gamma * features["stages"])

    @classmethod
    def default(cls) -> "CostModel":
        return cls()

    @classmethod
    def load(cls, path=None, backend: str = "sim") -> "CostModel":
        """Load the persisted per-backend fit; default() when absent or
        unreadable (auto selection must never fail on a fresh checkout)."""
        p = _calibration_path(path)
        try:
            with open(p) as f:
                rec = json.load(f)
            fit = rec["backends"][backend]
            return cls(alpha=float(fit["alpha"]), beta=float(fit["beta"]),
                       gamma=float(fit["gamma"]), source=str(p))
        except (OSError, KeyError, ValueError, TypeError):
            return cls.default()

    def save(self, path=None, backend: str = "sim",
             extra: Optional[dict] = None) -> Path:
        """Merge-persist this fit under ``backends[backend]``."""
        p = _calibration_path(path)
        rec = {}
        try:
            with open(p) as f:
                rec = json.load(f)
        except (OSError, ValueError):
            pass
        rec.setdefault("backends", {})[backend] = {
            "alpha": self.alpha, "beta": self.beta, "gamma": self.gamma,
            **(extra or {}),
        }
        tmp = p.with_suffix(".tmp")
        with open(tmp, "w") as f:
            json.dump(rec, f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, p)
        return p


# ---------------------------------------------------------------------------
# structural features of a plan under a config
# ---------------------------------------------------------------------------

def _ring_is_inter(ring: Sequence[int], n_ranks: int,
                   bandwidth_groups: int) -> bool:
    """True when any hop of the ring (wrap included) crosses a bandwidth
    island (cfg.bandwidth_groups equal blocks of consecutive ranks)."""
    if bandwidth_groups <= 1 or n_ranks % bandwidth_groups != 0:
        return False
    isl = n_ranks // bandwidth_groups
    return any(ring[i] // isl != ring[(i + 1) % len(ring)] // isl
               for i in range(len(ring)))


def _lane_cap_for(rings: list, cfg) -> int:
    """Burst cap of the lane the given rings would run on (tables.py
    computes the authoritative per-lane value; this mirrors it for
    prediction)."""
    B = cfg.burst_slices
    inter = any(_ring_is_inter(r, cfg.n_ranks, cfg.bandwidth_groups)
                for r in rings)
    cap = cfg.inter_burst_cap if inter else cfg.intra_burst_cap
    return max(1, min(B, cap)) if cap > 0 else B


def _stage_features(kind: CollKind, ring_size: int, n_elems: int,
                    rings: list, cfg) -> tuple[float, float]:
    """(supersteps, wire bytes) of one ring stage under ``cfg``."""
    import jax.numpy as jnp

    ns, rounds = derive_slicing(n_elems, ring_size, cfg.slice_elems,
                                cfg.conn_depth)
    P = program_len(CollKind(kind), ring_size)
    cap = _lane_cap_for(rings, cfg)
    supersteps = P * rounds * (-(-ns // cap))               # ceil
    bytes_ = (P * rounds * ns * cfg.slice_elems
              * jnp.dtype(cfg.dtype).itemsize)
    return float(supersteps), float(bytes_)


def plan_features(cfg, kind: CollKind, n_elems: int, group_size: int,
                  hierarchy: Optional[tuple], algo: str,
                  root: int = 0) -> dict:
    """Structural cost features of ``algo`` for this payload/topology:
    ``{"supersteps", "bytes", "stages"}`` — the model's regressors.

    The members are taken as ranks ``0..group_size-1`` in ring order (the
    bandwidth-island assignment is positional, so predicted lane classes
    match the tables-layer ``lane_caps`` of any same-shaped registration).
    """
    from .algos import build_plan, default_hierarchy

    if cfg is None:
        from .config import OcclConfig

        cfg = OcclConfig(n_ranks=max(group_size, 1))
    members = tuple(range(group_size))
    if algo == "ring":
        rings = [members]
        s, b = _stage_features(kind, group_size, n_elems, rings, cfg)
        return {"supersteps": s, "bytes": b, "stages": 1.0, "algo": algo}
    hier = (tuple(hierarchy) if hierarchy is not None
            else default_hierarchy(group_size))
    plan = build_plan(algo, kind, members, hier, n_elems, root)
    supersteps = bytes_ = 0.0
    for stage in plan.stages:
        rings = [stage.members[i:i + stage.ring_size]
                 for i in range(0, len(stage.members), stage.ring_size)]
        s, b = _stage_features(stage.kind, stage.ring_size, stage.n_elems,
                               rings, cfg)
        supersteps += s
        bytes_ += b
    return {"supersteps": supersteps, "bytes": bytes_,
            "stages": float(len(plan.stages)), "algo": algo}


# ---------------------------------------------------------------------------
# fitting (benchmarks/calibrate.py drives this)
# ---------------------------------------------------------------------------

def _rank_violations(pred: np.ndarray, y: np.ndarray,
                     groups: Sequence[Sequence[int]]) -> int:
    """Ordered pairs within a group whose measured order the prediction
    gets wrong (sample i measurably faster than j, predicted >= j)."""
    viol = 0
    for idx in groups:
        for a in idx:
            for b in idx:
                if y[a] < y[b] and pred[a] >= pred[b]:
                    viol += 1
    return viol


def fit(samples: Sequence[dict]) -> CostModel:
    """Rank-aware non-negative least squares of measured wall-clock on
    the three features, weighted by 1/wall (each sample contributes its
    RELATIVE error, so microsecond-scale and second-scale samples count
    equally).

    ``samples``: dicts with ``supersteps``, ``bytes``, ``stages`` and the
    measured ``wall`` (seconds); an optional ``tag``
    (``"<kind>/<size>/<algo>"``) groups samples that competed on the SAME
    payload/topology.  Non-negativity matters: a negative fitted
    coefficient (possible with few, collinear samples) would let auto
    rank a plan BETTER for moving more bytes.  With only three regressors
    the exact active-set search over the 8 sign patterns is cheap and
    deterministic.

    Candidate support sets are ranked by (pairwise ranking violations
    within each tag group, THEN weighted squared error).  The model's
    only job is selection — picking the measured winner per config —
    and with few collinear samples the globally error-minimal plane can
    invert a close small-payload ordering that a slightly-worse-error
    support set preserves.  Minimizing rank violations first keeps the
    calibrated ``auto`` on the measured winner; the error term breaks
    ties among equally-consistent fits.
    """
    pts = [s for s in samples if s.get("wall", 0) > 0]
    if len(pts) < 3:
        raise ValueError(
            f"need >= 3 measured samples to fit (got {len(pts)}); run "
            "benchmarks/bench_collectives.py run_algo_sweep first")
    X = np.array([[s["supersteps"], s["bytes"], s["stages"]]
                  for s in pts], float)
    y = np.array([s["wall"] for s in pts], float)
    # Samples sharing a "<kind>/<size>" tag prefix competed on one
    # config; untagged samples form no pairs (ranking-neutral).
    by_cfg: dict = {}
    for i, s in enumerate(pts):
        tag = s.get("tag")
        if tag:
            by_cfg.setdefault(tag.rsplit("/", 1)[0], []).append(i)
    groups = [idx for idx in by_cfg.values() if len(idx) > 1]
    w = 1.0 / y
    Xw, yw = X * w[:, None], y * w
    best, best_key = None, (np.inf, np.inf)
    for mask in range(1, 8):                     # non-empty support sets
        cols = [j for j in range(3) if mask & (1 << j)]
        coef, *_ = np.linalg.lstsq(Xw[:, cols], yw, rcond=None)
        if (coef < 0).any():
            continue
        full = np.zeros(3)
        full[cols] = coef
        err = float(((Xw @ full - yw) ** 2).sum())
        key = (_rank_violations(X @ full, y, groups), err)
        if key < best_key:
            best, best_key = full, key
    assert best is not None, "all-zero fit is always feasible"
    return CostModel(alpha=float(best[0]), beta=float(best[1]),
                     gamma=float(best[2]), source=f"fit[{len(pts)}]")
