"""Model zoo: decoder LMs (dense / MoE / SSM / hybrid), enc-dec, VLM, ViT.

One functional ``Model`` API per architecture family:
    init(seed) -> params                        (layer-stacked for lax.scan)
    loss_fn(params, batch) -> scalar            (train_step payload)
    prefill(params, batch) -> (last_logits, cache)
    decode_step(params, cache, tokens, pos) -> (logits, cache)

Layers are stacked on a leading [L] axis and driven by ``lax.scan`` so HLO
size (and 1-core compile time for the 512-device dry-run) stays bounded.
Remat policy per config: full / dots / none.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from . import attention as A
from . import moe as M
from . import ssm as S
from .layers import (cross_entropy, geglu, gelu_mlp, key_for, layer_norm,
                     ninit, rms_norm, rope, swiglu, u_scan)

VOCAB_PAD = 256   # Megatron-style: pad vocab to a multiple of the mesh
                  # (16 model x 16 data) so embed/lm_head shard evenly.


def padded_vocab(v: int) -> int:
    return -(-v // VOCAB_PAD) * VOCAB_PAD


def mask_vocab_logits(logits, vocab: int):
    """-inf the padded tail so it never wins CE/argmax."""
    if logits.shape[-1] == vocab:
        return logits
    keep = jnp.arange(logits.shape[-1]) < vocab
    return jnp.where(keep, logits, jnp.float32(-1e30))


# ======================================================================
# blocks
# ======================================================================
def init_attn(root, path, cfg: ArchConfig, dtype, d_model=None):
    D = d_model or cfg.d_model
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    p = {
        "norm": jnp.zeros((D,), dtype),
        "wq": ninit(root, f"{path}/wq", (D, H * dh), 0.02, dtype),
        "wk": ninit(root, f"{path}/wk", (D, KV * dh), 0.02, dtype),
        "wv": ninit(root, f"{path}/wv", (D, KV * dh), 0.02, dtype),
        "wo": ninit(root, f"{path}/wo", (H * dh, D),
                    0.02 / np.sqrt(2 * max(cfg.n_layers, 1)), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((dh,), dtype)
        p["k_norm"] = jnp.zeros((dh,), dtype)
    return p


def _qkv(cfg, p, x, kv_x=None, *, positions=None, rope_on=True):
    B, Sq = x.shape[:2]
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    kv_x = x if kv_x is None else kv_x
    Skv = kv_x.shape[1]
    q = (x @ p["wq"]).reshape(B, Sq, H, dh)
    k = (kv_x @ p["wk"]).reshape(B, Skv, KV, dh)
    v = (kv_x @ p["wv"]).reshape(B, Skv, KV, dh)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if rope_on:
        qpos = positions if positions is not None else jnp.arange(Sq)
        kpos = jnp.arange(Skv)
        q = rope(q, jnp.broadcast_to(qpos, (B, Sq)), cfg.rope_theta)
        k = rope(k, jnp.broadcast_to(kpos, (B, Skv)), cfg.rope_theta)
    return q, k, v


def attn_train(cfg, p, x, *, mode="causal", prefix_len=0, kv_x=None,
               rope_on=True):
    h = rms_norm(x, p["norm"])
    # cross-attention: kv_x (encoder memory) is already normalized
    q, k, v = _qkv(cfg, p, h, kv_x=kv_x, rope_on=rope_on)
    y = A.full_or_blockwise(q, k, v, mode=mode, window=cfg.swa_window,
                            prefix_len=prefix_len)
    B, Sq = x.shape[:2]
    return x + y.reshape(B, Sq, -1) @ p["wo"]


def attn_prefill(cfg, p, x, *, mode="causal", prefix_len=0):
    """Like attn_train but also returns (k, v) for the cache."""
    h = rms_norm(x, p["norm"])
    q, k, v = _qkv(cfg, p, h)
    y = A.full_or_blockwise(q, k, v, mode=mode, window=cfg.swa_window,
                            prefix_len=prefix_len)
    B, Sq = x.shape[:2]
    return x + y.reshape(B, Sq, -1) @ p["wo"], (k, v)


def attn_decode(cfg, p, x, kc, vc, pos, *, rope_on=True):
    """x: [B,1,D]; kc/vc: [B,Smax,KV,dh]; pos: scalar i32."""
    B = x.shape[0]
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    h = rms_norm(x, p["norm"])
    q = (h @ p["wq"]).reshape(B, 1, H, dh)
    k = (h @ p["wk"]).reshape(B, 1, KV, dh)
    v = (h @ p["wv"]).reshape(B, 1, KV, dh)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if rope_on:
        ppos = jnp.broadcast_to(pos, (B, 1))
        q = rope(q, ppos, cfg.rope_theta)
        k = rope(k, ppos, cfg.rope_theta)
    wpos = jnp.minimum(pos, kc.shape[1] - 1)
    kc = jax.lax.dynamic_update_slice(kc, k, (0, wpos, 0, 0))
    vc = jax.lax.dynamic_update_slice(vc, v, (0, wpos, 0, 0))
    y = A.decode_attention(q, kc, vc, pos, window=cfg.swa_window)
    return x + y.reshape(B, 1, -1) @ p["wo"], kc, vc


def init_mlp(root, path, cfg, dtype, kind="swiglu", d_model=None, d_ff=None):
    D = d_model or cfg.d_model
    F = d_ff or cfg.d_ff
    if kind == "gelu":   # ViT-style with biases + LayerNorm
        return {
            "norm_w": jnp.ones((D,), dtype), "norm_b": jnp.zeros((D,), dtype),
            "w1": ninit(root, f"{path}/w1", (D, F), 0.02, dtype),
            "b1": jnp.zeros((F,), dtype),
            "w2": ninit(root, f"{path}/w2", (F, D),
                        0.02 / np.sqrt(2 * cfg.n_layers), dtype),
            "b2": jnp.zeros((D,), dtype),
        }
    return {
        "norm": jnp.zeros((D,), dtype),
        "wg": ninit(root, f"{path}/wg", (D, F), 0.02, dtype),
        "wu": ninit(root, f"{path}/wu", (D, F), 0.02, dtype),
        "wd": ninit(root, f"{path}/wd", (F, D),
                    0.02 / np.sqrt(2 * max(cfg.n_layers, 1)), dtype),
    }


def mlp_apply(p, x, kind="swiglu"):
    if kind == "gelu":
        h = layer_norm(x, p["norm_w"], p["norm_b"])
        return x + gelu_mlp(h, p["w1"], p["b1"], p["w2"], p["b2"])
    h = rms_norm(x, p["norm"])
    fn = geglu if kind == "geglu" else swiglu
    return x + fn(h, p["wg"], p["wu"], p["wd"])


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # full


# ======================================================================
# the Model API
# ======================================================================
@dataclasses.dataclass
class Model:
    cfg: ArchConfig

    # ------------------------------------------------------------------
    def init(self, seed: int = 0):
        cfg = self.cfg
        root = jax.random.PRNGKey(seed)
        dt = jnp.dtype(cfg.param_dtype)
        fam = cfg.family
        p: dict[str, Any] = {}

        def stack(fn):
            """Init per-layer params and stack on a leading [L] axis."""
            leaves = [fn(i) for i in range(cfg.n_layers)]
            return jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *leaves)

        if fam in ("dense", "moe"):
            p["embed"] = ninit(root, "embed", (padded_vocab(cfg.vocab), cfg.d_model), 0.02, dt)
            if fam == "dense":
                p["layers"] = stack(lambda i: {
                    "attn": init_attn(root, f"l{i}/attn", cfg, dt),
                    "mlp": init_mlp(root, f"l{i}/mlp", cfg, dt),
                })
            else:
                p["layers"] = stack(lambda i: {
                    "attn": init_attn(root, f"l{i}/attn", cfg, dt),
                    "moe": M.init_moe_block(root, f"l{i}/moe", cfg, dt),
                })
            p["final_norm"] = jnp.zeros((cfg.d_model,), dt)
            p["lm_head"] = ninit(root, "lm_head",
                                 (cfg.d_model, padded_vocab(cfg.vocab)),
                                 0.02, dt)
        elif fam == "ssm":
            p["embed"] = ninit(root, "embed", (padded_vocab(cfg.vocab), cfg.d_model), 0.02, dt)
            p["layers"] = stack(
                lambda i: S.init_ssm_block(root, f"l{i}", cfg, dt))
            p["final_norm"] = jnp.zeros((cfg.d_model,), dt)
            p["lm_head"] = ninit(root, "lm_head",
                                 (cfg.d_model, padded_vocab(cfg.vocab)),
                                 0.02, dt)
        elif fam == "hybrid":
            p["embed"] = ninit(root, "embed", (padded_vocab(cfg.vocab), cfg.d_model), 0.02, dt)
            p["layers"] = stack(
                lambda i: S.init_ssm_block(root, f"l{i}", cfg, dt))
            p["shared"] = {
                "proj": ninit(root, "shared/proj",
                              (2 * cfg.d_model, cfg.d_model), 0.02, dt),
                "attn": init_attn(root, "shared/attn", cfg, dt),
                "mlp": init_mlp(root, "shared/mlp", cfg, dt),
            }
            p["final_norm"] = jnp.zeros((cfg.d_model,), dt)
            p["lm_head"] = ninit(root, "lm_head",
                                 (cfg.d_model, padded_vocab(cfg.vocab)),
                                 0.02, dt)
        elif fam == "encdec":
            p["enc_layers"] = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *[
                    {"attn": init_attn(root, f"e{i}/attn", cfg, dt),
                     "mlp": init_mlp(root, f"e{i}/mlp", cfg, dt)}
                    for i in range(cfg.enc_layers)])
            p["enc_norm"] = jnp.zeros((cfg.d_model,), dt)
            p["embed"] = ninit(root, "embed", (padded_vocab(cfg.vocab), cfg.d_model), 0.02, dt)
            p["layers"] = stack(lambda i: {
                "attn": init_attn(root, f"d{i}/attn", cfg, dt),
                "cross": init_attn(root, f"d{i}/cross", cfg, dt),
                "mlp": init_mlp(root, f"d{i}/mlp", cfg, dt),
            })
            p["final_norm"] = jnp.zeros((cfg.d_model,), dt)
            p["lm_head"] = ninit(root, "lm_head",
                                 (cfg.d_model, padded_vocab(cfg.vocab)),
                                 0.02, dt)
        elif fam == "vlm":
            p["embed"] = ninit(root, "embed", (padded_vocab(cfg.vocab), cfg.d_model), 0.02, dt)
            p["vis_proj"] = ninit(root, "vis_proj",
                                  (cfg.d_model, cfg.d_model), 0.02, dt)
            p["layers"] = stack(lambda i: {
                "attn": init_attn(root, f"l{i}/attn", cfg, dt),
                "mlp": init_mlp(root, f"l{i}/mlp", cfg, dt),
            })
            p["final_norm"] = jnp.zeros((cfg.d_model,), dt)
            p["lm_head"] = ninit(root, "lm_head",
                                 (cfg.d_model, padded_vocab(cfg.vocab)),
                                 0.02, dt)
        elif fam == "vit":
            p["pos_embed"] = ninit(root, "pos", (cfg.vis_tokens, cfg.d_model),
                                   0.02, dt)
            p["patch_proj"] = ninit(root, "patch_proj",
                                    (cfg.d_model, cfg.d_model), 0.02, dt)
            p["layers"] = stack(lambda i: {
                "attn": init_attn(root, f"l{i}/attn", cfg, dt),
                "mlp": init_mlp(root, f"l{i}/mlp", cfg, dt, kind="gelu"),
            })
            p["final_norm"] = jnp.zeros((cfg.d_model,), dt)
            p["head"] = ninit(root, "head", (cfg.d_model, padded_vocab(cfg.vocab)), 0.02, dt)
        else:  # pragma: no cover
            raise ValueError(fam)
        return p

    # ------------------------------------------------------------------
    # decoder trunk (shared by train / prefill / decode)
    # ------------------------------------------------------------------
    def _mlp_kind(self):
        return "geglu" if self.cfg.family == "vlm" else "swiglu"

    def _trunk_train(self, params, x, *, mode="causal", prefix_len=0,
                     enc_out=None):
        cfg = self.cfg
        fam = cfg.family
        kind = self._mlp_kind()
        aux0 = jnp.zeros((), jnp.float32)
        if fam == "hybrid":
            return self._hybrid_train(params, x)

        if fam in ("dense", "vlm"):
            def body(carry, lp):
                h, aux = carry
                h = attn_train(cfg, lp["attn"], h, mode=mode,
                               prefix_len=prefix_len)
                h = mlp_apply(lp["mlp"], h, kind)
                return (h, aux), None
        elif fam == "moe":
            def body(carry, lp):
                h, aux = carry
                h = attn_train(cfg, lp["attn"], h, mode=mode)
                y, a = M.moe_forward(cfg, lp["moe"], h)
                return (h + y, aux + a), None
        elif fam == "ssm":
            def body(carry, lp):
                h, aux = carry
                h, _ = S.ssd_forward(cfg, lp, h)
                return (h, aux), None
        elif fam == "encdec":
            def body(carry, lp):
                h, aux = carry
                h = attn_train(cfg, lp["attn"], h, mode="causal")
                h = attn_train(cfg, lp["cross"], h, mode="bidir",
                               kv_x=enc_out, rope_on=False)
                h = mlp_apply(lp["mlp"], h, kind)
                return (h, aux), None
        else:
            raise ValueError(fam)

        body = _remat(body, cfg.remat)
        (x, aux), _ = u_scan(body, (x, aux0), params["layers"])
        return x, aux

    def _hybrid_train(self, params, x):
        cfg = self.cfg
        x0 = x
        period = cfg.shared_attn_period
        shared = params["shared"]
        aux0 = jnp.zeros((), jnp.float32)

        def body(carry, inp):
            h, aux = carry
            lp, idx = inp
            h, _ = S.ssd_forward(cfg, lp, h)

            def with_shared(h):
                z = jnp.concatenate([h, x0], axis=-1) @ shared["proj"]
                z = attn_train(cfg, shared["attn"], z, mode="causal")
                z = mlp_apply(shared["mlp"], z, "swiglu")
                return h + z

            h = jax.lax.cond((idx + 1) % period == 0, with_shared,
                             lambda h: h, h)
            return (h, aux), None

        body = _remat(body, cfg.remat)
        (x, aux), _ = u_scan(
            body, (x, aux0),
            (params["layers"], jnp.arange(cfg.n_layers)))
        return x, aux

    # ------------------------------------------------------------------
    # train loss
    # ------------------------------------------------------------------
    def loss_fn(self, params, batch) -> jnp.ndarray:
        cfg = self.cfg
        cdt = jnp.dtype(cfg.compute_dtype)
        params = jax.tree_util.tree_map(lambda a: a.astype(cdt), params)
        fam = cfg.family

        if fam == "vit":
            x = batch["patches"].astype(cdt) @ params["patch_proj"]
            x = x + params["pos_embed"][None]

            def body(h, lp):
                hh = rms_norm(h, lp["attn"]["norm"])
                q, k, v = _qkv(cfg, lp["attn"], hh, rope_on=False)
                y = A.attention(q, k, v, mode="bidir")
                h = h + y.reshape(h.shape[0], h.shape[1], -1) @ lp["attn"]["wo"]
                h = mlp_apply(lp["mlp"], h, "gelu")
                return h, None

            x, _ = u_scan(_remat(body, cfg.remat), x, params["layers"])
            x = rms_norm(x, params["final_norm"]).mean(axis=1)
            logits = mask_vocab_logits(
                (x @ params["head"]).astype(jnp.float32), cfg.vocab)
            onehot = jax.nn.one_hot(batch["labels"], logits.shape[-1])
            return -jnp.mean(
                jnp.sum(jax.nn.log_softmax(logits) * onehot, axis=-1))

        prefix_len = 0
        mode = "causal"
        enc_out = None
        if fam == "encdec":
            enc = batch["frames"].astype(cdt)

            def ebody(h, lp):
                h = attn_train(cfg, lp["attn"], h, mode="bidir",
                               rope_on=True)
                h = mlp_apply(lp["mlp"], h, "swiglu")
                return h, None

            enc, _ = u_scan(_remat(ebody, cfg.remat), enc,
                                  params["enc_layers"])
            enc_out = rms_norm(enc, params["enc_norm"])

        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        if fam == "vlm":
            vis = batch["patches"].astype(cdt) @ params["vis_proj"]
            x = jnp.concatenate([vis, x], axis=1)
            prefix_len = cfg.vis_tokens
            mode = "prefix"

        x, aux = self._trunk_train(params, x, mode=mode,
                                   prefix_len=prefix_len, enc_out=enc_out)
        x = rms_norm(x, params["final_norm"])
        if fam == "vlm":   # strip image positions from the loss
            x = x[:, cfg.vis_tokens:]
        logits = mask_vocab_logits(
            (x @ params["lm_head"]).astype(jnp.float32), cfg.vocab)
        loss = cross_entropy(logits, batch["targets"])
        return loss + 0.01 * aux

    # ------------------------------------------------------------------
    # serving: prefill + decode
    # ------------------------------------------------------------------
    def prefill(self, params, batch, pad_to: int | None = None):
        """pad_to: total cache capacity (prompt + expected decode
        steps); without it the first decode step would have no free slot
        and would overwrite the last cached position."""
        cfg = self.cfg
        cdt = jnp.dtype(cfg.compute_dtype)
        params = jax.tree_util.tree_map(lambda a: a.astype(cdt), params)
        fam = cfg.family

        enc_out = None
        if fam == "encdec":
            enc = batch["frames"].astype(cdt)

            def ebody(h, lp):
                h = attn_train(cfg, lp["attn"], h, mode="bidir")
                h = mlp_apply(lp["mlp"], h, "swiglu")
                return h, None

            enc, _ = u_scan(ebody, enc, params["enc_layers"])
            enc_out = rms_norm(enc, params["enc_norm"])

        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        prefix_len, mode = 0, "causal"
        if fam == "vlm":
            vis = batch["patches"].astype(cdt) @ params["vis_proj"]
            x = jnp.concatenate([vis, x], axis=1)
            prefix_len, mode = cfg.vis_tokens, "prefix"

        kind = self._mlp_kind()
        if fam in ("dense", "vlm", "moe", "encdec"):
            def body(h, lp):
                h, (k, v) = attn_prefill(cfg, lp["attn"], h, mode=mode,
                                         prefix_len=prefix_len)
                if fam == "encdec":
                    h = attn_train(cfg, lp["cross"], h, mode="bidir",
                                   kv_x=enc_out, rope_on=False)
                if fam == "moe":
                    y, _ = M.moe_forward(cfg, lp["moe"], h)
                    h = h + y
                else:
                    h = mlp_apply(lp["mlp"], h, kind)
                return h, (k, v)

            x, (kc, vc) = u_scan(body, x, params["layers"])
            if pad_to is not None and pad_to > kc.shape[2]:
                pads = [(0, 0), (0, 0), (0, pad_to - kc.shape[2]),
                        (0, 0), (0, 0)]
                kc = jnp.pad(kc, pads)
                vc = jnp.pad(vc, pads)
            cache = {"k": kc, "v": vc,
                     "pos": jnp.asarray(x.shape[1], jnp.int32)}
            if fam == "encdec":
                cache["enc_out"] = enc_out
        elif fam in ("ssm", "hybrid"):
            def body(h, lp):
                h, (st, cv) = S.ssd_forward(cfg, lp, h)
                return h, (st, cv)

            x0 = x
            if fam == "hybrid":
                # python-loop prefill for the shared block boundaries
                states, convs = [], []
                shared_kv = []
                shared = params["shared"]
                for i in range(cfg.n_layers):
                    lp = jax.tree_util.tree_map(lambda a: a[i],
                                                params["layers"])
                    x, (st, cv) = S.ssd_forward(cfg, lp, x)
                    states.append(st)
                    convs.append(cv)
                    if (i + 1) % cfg.shared_attn_period == 0:
                        z = jnp.concatenate([x, x0], axis=-1) @ shared["proj"]
                        z, (k, v) = attn_prefill(cfg, shared["attn"], z)
                        z = mlp_apply(shared["mlp"], z, "swiglu")
                        x = x + z
                        shared_kv.append((k, v))
                cvx, cvB, cvC = (jnp.stack([c[i] for c in convs])
                                 for i in range(3))
                sk = jnp.stack([k for k, _ in shared_kv])
                sv = jnp.stack([v for _, v in shared_kv])
                if pad_to is not None and pad_to > sk.shape[2]:
                    pads = [(0, 0), (0, 0), (0, pad_to - sk.shape[2]),
                            (0, 0), (0, 0)]
                    sk = jnp.pad(sk, pads)
                    sv = jnp.pad(sv, pads)
                cache = {
                    "state": jnp.stack(states),
                    "conv_x": cvx, "conv_B": cvB, "conv_C": cvC,
                    "shared_k": sk,
                    "shared_v": sv,
                    "pos": jnp.asarray(x.shape[1], jnp.int32),
                }
            else:
                x, (st, (cvx, cvB, cvC)) = u_scan(
                    body, x, params["layers"])
                cache = {"state": st, "conv_x": cvx, "conv_B": cvB,
                         "conv_C": cvC,
                         "pos": jnp.asarray(x.shape[1], jnp.int32)}
        else:
            raise ValueError(fam)

        x = rms_norm(x, params["final_norm"])
        logits = mask_vocab_logits(
            (x[:, -1:] @ params["lm_head"]).astype(jnp.float32), cfg.vocab)
        return logits, cache

    def decode_step(self, params, cache, tokens):
        """tokens: [B] int32 — one decode step; returns (logits [B,V], cache)."""
        cfg = self.cfg
        cdt = jnp.dtype(cfg.compute_dtype)
        params = jax.tree_util.tree_map(lambda a: a.astype(cdt), params)
        fam = cfg.family
        pos = cache["pos"]
        x = jnp.take(params["embed"], tokens[:, None], axis=0)

        kind = self._mlp_kind()
        if fam in ("dense", "vlm", "moe", "encdec"):
            def body(h, lp_kv):
                lp, kc, vc = lp_kv
                h, kc, vc = attn_decode(cfg, lp["attn"], h, kc, vc, pos)
                if fam == "encdec":
                    h = attn_train(cfg, lp["cross"], h, mode="bidir",
                                   kv_x=cache["enc_out"], rope_on=False)
                if fam == "moe":
                    y, _ = M.moe_forward(cfg, lp["moe"], h)
                    h = h + y
                else:
                    h = mlp_apply(lp["mlp"], h, kind)
                return h, (kc, vc)

            x, (kc, vc) = u_scan(
                body, x, (params["layers"], cache["k"], cache["v"]))
            cache = dict(cache, k=kc, v=vc, pos=pos + 1)
        elif fam == "ssm":
            def body(h, lp_st):
                lp, st, cv3 = lp_st
                h, (st, cv3) = S.ssd_decode_step(cfg, lp, h, (st, cv3))
                return h, (st, cv3)

            x, (st, (cvx, cvB, cvC)) = u_scan(
                body, x,
                (params["layers"], cache["state"],
                 (cache["conv_x"], cache["conv_B"], cache["conv_C"])))
            cache = dict(cache, state=st, conv_x=cvx, conv_B=cvB,
                         conv_C=cvC, pos=pos + 1)
        elif fam == "hybrid":
            shared = params["shared"]
            x0 = x
            states = cache["state"]
            convs = (cache["conv_x"], cache["conv_B"], cache["conv_C"])
            sk, sv = cache["shared_k"], cache["shared_v"]
            new_states, new_convs = [], []
            new_sk, new_sv = [], []
            inv = 0
            for i in range(cfg.n_layers):
                lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
                cv3 = tuple(c[i] for c in convs)
                x, (st, cv3) = S.ssd_decode_step(
                    cfg, lp, x, (states[i], cv3))
                new_states.append(st)
                new_convs.append(cv3)
                if (i + 1) % cfg.shared_attn_period == 0:
                    z = jnp.concatenate([x, x0], axis=-1) @ shared["proj"]
                    z, kc, vc = attn_decode(cfg, shared["attn"], z,
                                            sk[inv], sv[inv], pos)
                    z = mlp_apply(shared["mlp"], z, "swiglu")
                    x = x + z
                    new_sk.append(kc)
                    new_sv.append(vc)
                    inv += 1
            cvx, cvB, cvC = (jnp.stack([c[i] for c in new_convs])
                             for i in range(3))
            cache = dict(cache, state=jnp.stack(new_states),
                         conv_x=cvx, conv_B=cvB, conv_C=cvC,
                         shared_k=jnp.stack(new_sk),
                         shared_v=jnp.stack(new_sv), pos=pos + 1)
        else:
            raise ValueError(fam)

        x = rms_norm(x, params["final_norm"])
        logits = mask_vocab_logits(
            (x[:, 0] @ params["lm_head"]).astype(jnp.float32), cfg.vocab)
        return logits, cache


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
