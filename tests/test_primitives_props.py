"""Hypothesis property sweep: cross-rank program consistency of every
registered ring-program builder (the invariant the composite-collective
algorithm registry must preserve PER SUB-COLLECTIVE, core/algos.py).

For every kind x group size x root the per-rank primitive programs must be
mutually consistent along the ring:

* **flow matching** — the sequence of chunks rank m sends equals, in FIFO
  order, the sequence of chunks rank (m+1) % R receives (connectors are
  FIFO ring buffers, so a chunk mismatch would silently combine unrelated
  slices);
* **drain** — executing the programs dataflow-style with unbounded
  connectors terminates with every program complete and no dangling
  sends (a structural wedge here would deadlock the daemon regardless of
  scheduling);
* **flow conservation** — every chunk reaches its destination with
  exactly the right contribution set (all ranks for reductions, the
  originator for gathers/broadcast).

Skipped when hypothesis is absent (tier-1 containers);
``pip install -r requirements-dev.txt`` restores the sweep.
"""
import collections

import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.primitives import (_FLAGS, CollKind, Prim, build_program)


def _simulate(kind: CollKind, R: int, root: int):
    """Dataflow-execute the R per-rank programs over unbounded FIFO
    connectors, tracking each output chunk's contribution set (the set of
    ranks whose INPUT was combined into it)."""
    progs = [build_program(kind, m, R, root) for m in range(R)]
    pc = [0] * R
    fifo = [collections.deque() for _ in range(R)]  # edge m -> (m+1) % R
    out: list[dict] = [dict() for _ in range(R)]
    progress = True
    while progress:
        progress = False
        for m in range(R):
            while pc[m] < len(progs[m]):
                prim, k = progs[m][pc[m]]
                recv, send, _reduce, copy, reads = _FLAGS[Prim(prim)]
                src = (m - 1) % R
                if recv and not fifo[src]:
                    break                      # wait for the upstream send
                val: set = set()
                if recv:
                    wk, wv = fifo[src].popleft()
                    # Flow matching: the FIFO hands this rank exactly the
                    # chunk its program expects next.
                    assert wk == k, (
                        f"{kind.name} R={R} root={root}: rank {m} step "
                        f"{pc[m]} expects chunk {k}, wire has {wk}")
                    val |= wv
                if reads:
                    val.add(m)
                if copy:
                    out[m][k] = frozenset(val)
                if send:
                    fifo[m].append((k, frozenset(val)))
                pc[m] += 1
                progress = True
    assert all(pc[m] == len(progs[m]) for m in range(R)), (
        f"{kind.name} R={R} root={root}: programs wedge at {pc}")
    assert all(not f for f in fifo), "dangling sends after completion"
    return out


@settings(max_examples=120, deadline=None)
@given(data=st.data())
def test_flow_conservation(data):
    kind = data.draw(st.sampled_from(list(CollKind)), label="kind")
    R = data.draw(st.integers(1, 9), label="group_size")
    root = data.draw(st.integers(0, R - 1), label="root")
    out = _simulate(kind, R, root)
    everyone = frozenset(range(R))

    if R == 1:
        # Degenerate single-member group: local copy of the own input.
        assert out[0] == {0: frozenset({0})}
        return
    if kind == CollKind.ALL_REDUCE:
        for m in range(R):
            assert out[m] == {k: everyone for k in range(R)}
    elif kind == CollKind.ALL_GATHER:
        for m in range(R):
            assert out[m] == {k: frozenset({k}) for k in range(R)}
    elif kind == CollKind.REDUCE_SCATTER:
        for m in range(R):
            # Rank m finalizes exactly its own chunk, fully reduced.
            assert out[m] == {m: everyone}
    elif kind == CollKind.BROADCAST:
        for m in range(R):
            assert out[m] == {k: frozenset({root}) for k in range(R)}
    elif kind == CollKind.REDUCE:
        assert out[root] == {k: everyone for k in range(R)}
        for m in range(R):
            if m != root:
                assert out[m] == {}   # non-roots copy nothing


@settings(max_examples=60, deadline=None)
@given(data=st.data())
def test_send_recv_counts_balance(data):
    """Per ring edge, #sends == #recvs (no chunk is ever dropped on the
    wire) — the counting form of flow conservation."""
    from repro.core.primitives import PRIM_RECV, PRIM_SEND

    kind = data.draw(st.sampled_from(list(CollKind)), label="kind")
    R = data.draw(st.integers(2, 9), label="group_size")
    root = data.draw(st.integers(0, R - 1), label="root")
    progs = [build_program(kind, m, R, root) for m in range(R)]
    for m in range(R):
        sends = sum(int(PRIM_SEND[p]) for p, _ in progs[m])
        recvs = sum(int(PRIM_RECV[p]) for p, _ in progs[(m + 1) % R])
        assert sends == recvs, (kind, R, root, m)
