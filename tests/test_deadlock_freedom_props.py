"""Hypothesis property sweeps for deadlock freedom (paper Sec. 5.2).

Skipped entirely when hypothesis is not installed (tier-1 containers);
``pip install -r requirements-dev.txt`` restores the property coverage.
The shared scenario driver lives in test_deadlock_freedom.py.
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import CollKind, OrderPolicy, run_static_order

from test_deadlock_freedom import KINDS, _run_occl, _run_occl_chained


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_any_order_completes_correctly(data):
    R = data.draw(st.integers(2, 5), label="ranks")
    n_coll = data.draw(st.integers(1, 4), label="n_coll")
    colls = []
    for i in range(n_coll):
        kind = data.draw(st.sampled_from(KINDS), label=f"kind{i}")
        n_elems = data.draw(st.integers(1, 40), label=f"n{i}")
        root = data.draw(st.integers(0, R - 1), label=f"root{i}")
        colls.append((kind, n_elems, root))
    orders = [data.draw(st.permutations(range(n_coll)), label=f"order{r}")
              for r in range(R)]
    policy = data.draw(st.sampled_from(
        [OrderPolicy.FIFO, OrderPolicy.PRIORITY]), label="policy")
    stick = data.draw(st.booleans(), label="stickiness")
    burst = data.draw(st.sampled_from([1, 2, 4]), label="burst")
    seed = data.draw(st.integers(0, 1000), label="seed")

    rt, ids, inputs, roots = _run_occl(R, colls, orders, policy, stick, seed,
                                       burst_slices=burst)

    for slot, cid in enumerate(ids):
        kind, n_elems, root = colls[slot]
        if kind == CollKind.ALL_REDUCE:
            want = sum(inputs[cid])
            for r in range(R):
                np.testing.assert_allclose(
                    rt.read_output(r, cid), want, rtol=1e-4, atol=1e-6)
        elif kind == CollKind.ALL_GATHER:
            chunk = -(-n_elems // R)
            want = np.concatenate(inputs[cid])[:n_elems]
            for r in range(R):
                np.testing.assert_allclose(
                    rt.read_output(r, cid), want, rtol=1e-4, atol=1e-6)
        elif kind == CollKind.REDUCE_SCATTER:
            chunk = -(-n_elems // R)
            full = sum(np.pad(x, (0, chunk * R - n_elems))
                       for x in inputs[cid])
            for r in range(R):
                np.testing.assert_allclose(
                    rt.read_output(r, cid), full[r * chunk:(r + 1) * chunk],
                    rtol=1e-4, atol=1e-6)
        elif kind == CollKind.BROADCAST:
            for r in range(R):
                np.testing.assert_allclose(
                    rt.read_output(r, cid), inputs[cid][0], rtol=1e-4, atol=1e-6)
        elif kind == CollKind.REDUCE:
            want = sum(inputs[cid])
            np.testing.assert_allclose(
                rt.read_output(root, cid), want, rtol=1e-4, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_occl_survives_static_deadlocks(data):
    """Order sets that deadlock the single-FIFO-queue baseline still
    complete under OCCL (the paper's stress scenario, Sec. 5.2)."""
    R = data.draw(st.integers(2, 4))
    n_coll = data.draw(st.integers(2, 4))
    orders = {r: list(data.draw(st.permutations(range(n_coll))))
              for r in range(R)}
    members_of = {c: list(range(R)) for c in range(n_coll)}
    static = run_static_order(orders, members_of)
    colls = [(CollKind.ALL_REDUCE, 8, 0) for _ in range(n_coll)]
    rt, ids, inputs, _ = _run_occl(
        R, colls, [orders[r] for r in range(R)],
        OrderPolicy.FIFO, True, seed=1)
    for cid in ids:
        want = sum(inputs[cid])
        np.testing.assert_allclose(rt.read_output(0, cid), want, rtol=1e-4, atol=1e-6)
    if static.deadlocked:
        assert static.cycle is not None or static.blocked_at


@settings(max_examples=10, deadline=None)
@given(data=st.data())
def test_chained_conflicting_orders_complete(data):
    """Composite tentpole acceptance: CHAINED sub-collectives (two-level
    all-reduces whose stages share the derived intra/inter lanes and are
    enqueued on device) submitted in conflicting orders across lanes.
    Every order set that deadlocks the StaticOrderExecutor baseline must
    complete under OCCL with correct sums — the chain edges are exactly
    the inter-collective dependencies the paper's Sec. 1 warns about."""
    R, hierarchy = data.draw(st.sampled_from(
        [(4, (2, 2)), (8, (2, 4)), (8, (4, 2))]), label="grid")
    n_chained = data.draw(st.integers(1, 3), label="n_chained")
    n_flat = data.draw(st.integers(0, 2), label="n_flat")
    n_coll = n_chained + n_flat
    orders = {r: list(data.draw(st.permutations(range(n_coll)),
                                label=f"order{r}"))
              for r in range(R)}
    policy = data.draw(st.sampled_from(
        [OrderPolicy.FIFO, OrderPolicy.PRIORITY]), label="policy")
    seed = data.draw(st.integers(0, 1000), label="seed")

    # The baseline sees the LOGICAL submission orders (a chain is one
    # collective to the application).
    static = run_static_order(orders,
                              {c: list(range(R)) for c in range(n_coll)})
    rt, ids, inputs = _run_occl_chained(
        R, hierarchy, n_chained, n_flat,
        [orders[r] for r in range(R)], seed, policy)
    for cid in ids:
        want = sum(inputs[cid])
        for r in range(R):
            np.testing.assert_allclose(rt.read_output(r, cid), want,
                                       rtol=1e-4, atol=1e-5)
    if static.deadlocked:
        assert static.cycle is not None or static.blocked_at
