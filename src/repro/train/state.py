"""Train state pytree + sharding specs."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from ..models import build_model
from ..optim.adamw import init_moments, zero1_pspecs
from ..parallel.sharding import param_pspecs


class TrainState(NamedTuple):
    params: Any
    m: Any
    v: Any
    step: jnp.ndarray      # [] i32


def init_state(cfg: ArchConfig, seed: int = 0) -> TrainState:
    model = build_model(cfg)
    params = model.init(seed)
    m, v = init_moments(params, cfg.moment_dtype)
    return TrainState(params, m, v, jnp.zeros((), jnp.int32))


def abstract_state(cfg: ArchConfig) -> TrainState:
    """ShapeDtypeStruct state (no allocation) — dry-run input."""
    return jax.eval_shape(lambda: init_state(cfg))


def state_pspecs(cfg: ArchConfig, state: TrainState,
                 data_size: int = 16) -> TrainState:
    ps = param_pspecs(state.params)
    if cfg.zero1:
        mom = zero1_pspecs(ps, state.params, data_size)
    else:
        mom = ps
    return TrainState(params=ps, m=mom, v=mom, step=P())


def state_shardings(mesh: Mesh, cfg: ArchConfig,
                    state: TrainState) -> TrainState:
    data_size = 1
    for name, size in zip(mesh.axis_names, mesh.devices.shape):
        if name == "data":
            data_size = size
    specs = state_pspecs(cfg, state, data_size)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
