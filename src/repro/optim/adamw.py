"""Sharded AdamW with global-norm clipping, warmup-cosine schedule, and
ZeRO-1 moment partitioning (moments sharded over the "data" axis on top of
the tensor-parallel param sharding).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos).astype(jnp.float32)


def init_moments(params, moment_dtype: str):
    dt = jnp.dtype(moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return (jax.tree_util.tree_map(zeros, params),
            jax.tree_util.tree_map(zeros, params))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree_util.tree_leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, m, v, step):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - cfg.beta1 ** t
    bc2 = 1.0 - cfg.beta2 ** t

    def upd(p, g, mi, vi):
        gf = g.astype(jnp.float32) * scale
        mn = cfg.beta1 * mi.astype(jnp.float32) + (1 - cfg.beta1) * gf
        vn = cfg.beta2 * vi.astype(jnp.float32) + (1 - cfg.beta2) * gf * gf
        upd = (mn / bc1) / (jnp.sqrt(vn / bc2) + cfg.eps)
        pf = p.astype(jnp.float32)
        pn = pf - lr * (upd + cfg.weight_decay * pf)
        return pn.astype(p.dtype), mn.astype(mi.dtype), vn.astype(vi.dtype)

    out = jax.tree_util.tree_map(upd, params, grads, m, v)
    new_p = jax.tree_util.tree_map(lambda o: o[0], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda o: o[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda o: o[2], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return new_p, new_m, new_v, gnorm


def zero1_pspecs(param_pspecs, params, data_size: int = 16):
    """ZeRO-1: additionally shard each moment leaf's first large,
    still-replicated, divisible dim over the "data" axis."""
    def z(spec: P, p):
        dims = list(spec) + [None] * (p.ndim - len(spec))
        for i, d in enumerate(dims):
            if d is None and p.shape[i] % data_size == 0 and p.shape[i] >= data_size:
                dims[i] = "data"
                return P(*dims)
        return P(*dims)

    return jax.tree_util.tree_map(z, param_pspecs, params)
