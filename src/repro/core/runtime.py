"""OcclRuntime: the public host API of the deadlock-free collective library.

Mirrors the paper's integration contract (Sec. 4): register communicators
and collectives once, then ``submit`` from any rank in ANY order with an
optional completion callback; the runtime launches the daemon event-driven
and guarantees every submitted collective completes (assuming every member
rank eventually submits it — the same contract NCCL imposes, minus the
ordering requirement).

The runtime also exposes the observability used in the paper's Fig. 9 case
study: per-collective preemption (context-switch) counts and task-queue
lengths at fetch time.

Heap I/O is device-resident (staging.StagingEngine): the padded chunk
layout of every collective is precomputed at registration
(tables.build_tables), so ``write_input``/``write_inputs_bulk`` are one
host->device transfer of concatenated logical payloads plus one fused
scatter into ``heap_in`` (pad positions zero-filled in the same scatter),
and ``read_output``/``read_outputs_bulk`` are the mirror gather out of
``heap_out`` returning owned copies.  ``submit(..., data=...)`` does NOT
touch the device at call time: the payload is enqueued host-side
(HostQueues.stage) and the whole batch is flushed in the ``launch_once``
prologue — one staging transfer per daemon launch, so per-step grad-sync
cost scales with payload bytes instead of Python-loop iterations.  Per-SQE
dynamic buffer offsets (``in_off``/``out_off``) are honored end to end:
the staging engine adds the override to its relative index maps, and the
daemon applies the same override at SQE fetch.
"""
from __future__ import annotations

import collections
import dataclasses
import warnings
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .algos import build_plan, default_hierarchy, select_algo
from .config import OcclConfig, ReduceOp
from .daemon import (build_shardmap_tick, build_sim_daemon, build_sim_tick,
                     launch_prologue)
# Error taxonomy lives in core/errors.py; the historic names stay
# importable from this module (deprecated shim).
from .errors import (ConnDepthWarning, DeadlockTimeout, EvictionError,
                     RegistrationClosed)
from .handles import CollectiveHandle
from .primitives import (
    CollKind,
    CollectiveSpec,
    Communicator,
    derive_slicing,
    io_chunked,
)
from . import recorder as _recorder
from .sqcq import SQE, HostQueues
from .staging import StagingEngine
from .state import DaemonState, init_state
from .tables import StaticTables, build_tables


class OcclRuntime:
    def __init__(self, cfg: OcclConfig, mesh=None, mesh_axis: str = "rank",
                 cost_model=None):
        """mesh=None: sim backend (vmapped ranks on one device).
        mesh: a jax Mesh whose ``mesh_axis`` has cfg.n_ranks devices —
        the shard_map backend (ppermute connector fabric).
        cost_model: a costmodel.CostModel used by ``algo="auto"``
        registration; None loads the persisted calibration lazily
        (BENCH_calibration.json / REPRO_CALIBRATION)."""
        self.cfg = cfg
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        self._cost_model = cost_model
        self.comms: list[Communicator] = []
        self.specs: list[CollectiveSpec] = []
        # Composite-collective bookkeeping: a logical collective registered
        # with a multi-stage algorithm is a CHAIN of specs; the returned id
        # is the HEAD (the logical input endpoint), `_tail_of` maps it to
        # the tail (the logical output endpoint read_output addresses) and
        # `_chain_of` to the full stage list (per-stage stats).  Derived
        # sub-communicators are cached by their partition signature so
        # multiple composite collectives over the same grid share lanes.
        self._tail_of: dict[int, int] = {}
        self._chain_of: dict[int, list[int]] = {}
        self._derived_comms: dict = {}
        # Partial-membership chains (tree / hybrid plans): a rank that is
        # not a member of every stage SUBMITS at its first participating
        # stage (`_entry_of[head][rank]`) and COMPLETES at its last
        # (`_rank_tail[head][rank]`) — the daemon's per-rank chain maps
        # (tables.chain_next / chain_tail_r) advance it stage-to-stage in
        # between.  `_logical_members` keeps the logical group of each
        # composite head (the head SPEC's comm is only stage 0's derived
        # sub-communicator); `_algo_of` records the lowered algorithm per
        # logical collective for stats()/auto observability.
        self._entry_of: dict[int, dict[int, int]] = {}
        self._rank_tail: dict[int, dict[int, int]] = {}
        self._logical_members: dict[int, tuple] = {}
        self._algo_of: dict[int, str] = {}
        # Separate allocation arenas for input and output buffers: in_off
        # indexes heap_in and out_off indexes heap_out — two DIFFERENT
        # arrays — so a shared pointer only interleaved dead holes into
        # both address spaces.  Independent pointers pack each heap's live
        # regions contiguously (the staging engine coalesces adjacent
        # regions into single stacked device ops) and double the usable
        # capacity per cfg.heap_elems.
        self._in_ptr = 0
        self._out_ptr = 0
        self._tables: Optional[StaticTables] = None
        self._staging: Optional[StagingEngine] = None
        self._daemon = None
        self._tick_fns: dict = {}       # barrier flag -> jitted tick
        self._prologue_jit = None
        self._device_api = None
        self._state: Optional[DaemonState] = None
        self.queues = HostQueues(cfg)
        self.launches = 0
        # Per-launch bookkeeping (relaunch observability): one record per
        # launch_once with the device epoch, the supersteps the launch ran,
        # the slices it moved and the completions it reconciled.  Bounded:
        # a long-lived runtime relaunches indefinitely, so only the most
        # recent window is kept (aggregates live in the device counters).
        self.launch_history: collections.deque = collections.deque(
            maxlen=1024)
        # --- elastic-shrink bookkeeping (evict(); handles.py) -----------
        # The registration LOG is the durable description of the topology:
        # an ordered replay script of communicator() and register() calls
        # (with their ORIGINAL arguments) that evict() re-executes against
        # the shrunk rank set.  `_log_cids` maps each register() call's
        # log index to its CURRENT head collective id (None once a shrink
        # dissolved it) — the indirection CollectiveHandle resolves
        # through, which is what lets handles survive re-registration.
        self._reg_log: list[dict] = []
        self._log_cids: list[Optional[int]] = []
        self._head_to_reg: dict[int, int] = {}
        self._replaying = False
        self._generation = 0        # bumped by evict(); staleness guard
        # Outstanding-submission ledger: submit() appends one record per
        # SQE (popped by an always-attached accounting callback when the
        # completion reconciles) so evict() can replay staged-but-
        # unlaunched work, and diagnose() can name the collective each
        # waiting rank is blocked on.  `_submit_counts` is cumulative —
        # the lagging-submitter signal of recorder.diagnose().
        self._outstanding: dict = collections.defaultdict(collections.deque)
        self._submit_counts: dict = {}
        self._sub_seq = 0
        self.evictions: list[int] = []  # evict() history (ranks as passed)

    # ------------------------------------------------------------------
    # registration (paper Sec. 3.1.1)
    # ------------------------------------------------------------------
    def communicator(self, members: Sequence[int]) -> Communicator:
        if self._tables is not None:
            raise RegistrationClosed("register communicators before first launch")
        comm = Communicator(
            comm_id=len(self.comms), members=tuple(members),
            lane=len(self.comms))
        assert comm.lane < self.cfg.max_comms, "raise cfg.max_comms"
        self.comms.append(comm)
        if not self._replaying:
            # Log the creation ORDER (lane assignment is order-dependent)
            # so evict()'s replay reproduces the same lane layout.
            self._reg_log.append({"what": "comm", "comm_id": comm.comm_id,
                                  "members": comm.members})
        return comm

    def logical_communicator(self, members: Sequence[int]) -> Communicator:
        """A communicator DESCRIPTOR for composite registration: names the
        member grid without claiming a daemon lane.  Composite chains run
        entirely on their derived sub-communicator lanes, so a logical
        group that only ever registers multi-stage algorithms would waste
        a traced-every-superstep lane on a ring no collective uses (the
        grad-sync hierarchy mode saves one max_comms slot this way).
        Flat (``algo="ring"``) registration on it is rejected."""
        return Communicator(comm_id=-1, members=tuple(members), lane=-1)

    def _alloc_in(self, elems: int) -> int:
        off = self._in_ptr
        self._in_ptr += elems
        assert self._in_ptr <= self.cfg.heap_elems, "raise cfg.heap_elems"
        return off

    def _alloc_out(self, elems: int) -> int:
        off = self._out_ptr
        self._out_ptr += elems
        assert self._out_ptr <= self.cfg.heap_elems, "raise cfg.heap_elems"
        return off

    def register(self, kind: CollKind, comm: Communicator, n_elems: int,
                 op: ReduceOp = ReduceOp.SUM, root: int = 0,
                 algo: Optional[str] = None,
                 hierarchy: Optional[tuple] = None,
                 inherit_prio: bool = True,
                 chunk_sizes: Optional[Sequence[int]] = None
                 ) -> CollectiveHandle:
        """Register a collective; returns its :class:`CollectiveHandle`
        (paper Sec. 3.1.1).

        The handle IS the collective id (an ``int`` subclass, so every
        bare-``coll_id`` call path keeps working), owns the collective's
        operations (``submit``/``submit_all``/``write``/``read``/
        ``stats``) and — unlike a raw int — survives re-registration
        after an elastic shrink (``evict()``): it re-resolves through the
        registration log to its post-shrink id.

        ``algo`` selects the lowering (default ``cfg.algo``): ``"ring"``
        is the flat single-communicator ring; the composite plans
        (algos.PLAN_BUILDERS — ``"two_level"``/``"torus"``/``"hybrid"``
        for ALL_REDUCE, ``"tree"`` for BROADCAST/REDUCE) lower the
        collective over a ``G x N`` rank grid (``hierarchy``; the most
        square factorization when omitted) into a device-chained stage
        sequence; ``"auto"`` ranks the registered candidates with the
        measured α-β-γ cost model (core/costmodel.py — the calibration
        persisted by benchmarks/calibrate.py, or the runtime's injected
        ``cost_model``).  For a chain the returned id is the logical
        handle: submit/stage payloads against it, read results from it
        (the runtime routes reads to the chain tail), and its CQ callback
        fires ONCE when the whole chain completes on the callback's rank.
        ``inherit_prio`` lets device-enqueued successor stages inherit the
        submission's live priority (the chain competes as one unit).

        ``chunk_sizes`` (ALL_TO_ALL_RAGGED only) gives the per-DISTANCE
        live element counts of the capacity-dropped exchange: member m's
        chunk s carries ``chunk_sizes[s]`` elements for member (m+s) mod
        R; the rest of each chunk's capacity is padding staged as zeros
        and never read back.  Logical I/O sizes become
        ``sum(chunk_sizes)`` on both sides.
        """
        head = self._register_impl(kind, comm, n_elems, op=op, root=root,
                                   algo=algo, hierarchy=hierarchy,
                                   inherit_prio=inherit_prio,
                                   chunk_sizes=chunk_sizes)
        reg_index = len(self._log_cids)
        self._reg_log.append({
            "what": "register", "reg_index": reg_index,
            "comm_id": comm.comm_id, "members": tuple(comm.members),
            "kind": kind, "n_elems": int(n_elems), "op": op,
            "root": int(root), "algo": algo,
            "hierarchy": tuple(hierarchy) if hierarchy is not None else None,
            "inherit_prio": bool(inherit_prio),
            "chunk_sizes": (tuple(int(z) for z in chunk_sizes)
                            if chunk_sizes is not None else None),
        })
        self._log_cids.append(head)
        self._head_to_reg[head] = reg_index
        return CollectiveHandle(head, self, reg_index)

    def _register_impl(self, kind: CollKind, comm: Communicator,
                       n_elems: int, op: ReduceOp = ReduceOp.SUM,
                       root: int = 0, algo: Optional[str] = None,
                       hierarchy: Optional[tuple] = None,
                       inherit_prio: bool = True,
                       chunk_sizes: Optional[Sequence[int]] = None) -> int:
        """The registration body (shared by register() and evict()'s
        replay); returns the raw head collective id."""
        if self._tables is not None:
            raise RegistrationClosed("register collectives before first launch")
        if chunk_sizes is not None and CollKind(kind) is not \
                CollKind.ALL_TO_ALL_RAGGED:
            raise ValueError(
                f"chunk_sizes is only meaningful for ALL_TO_ALL_RAGGED, "
                f"got kind={CollKind(kind)!r}")
        algo = select_algo(self.cfg.algo if algo is None else algo,
                           kind, n_elems, len(comm.members),
                           hierarchy=hierarchy, cfg=self.cfg,
                           model=self._cost_model)
        if algo == "ring":
            return self._register_ring(kind, comm, n_elems, op, root,
                                       chunk_sizes=chunk_sizes or ())
        if chunk_sizes is not None:
            raise ValueError(
                f"algo={algo!r} cannot lower a ragged all-to-all: "
                "per-distance sizes do not survive the composite granule "
                "transposes — register ALL_TO_ALL_RAGGED with algo='ring'")
        return self._register_composite(algo, kind, comm, n_elems, op,
                                        root, hierarchy, inherit_prio)

    def _register_ring(self, kind: CollKind, comm: Communicator,
                       n_elems: int, op: ReduceOp = ReduceOp.SUM,
                       root: int = 0, next_coll: int = -1,
                       chain_stage: int = 0,
                       inherit_prio: bool = True,
                       in_perm: Sequence[int] = (),
                       chunk_sizes: Sequence[int] = ()) -> int:
        cid = len(self.specs)
        assert cid < self.cfg.max_colls, "raise cfg.max_colls"
        if comm.lane < 0:
            raise ValueError(
                "flat (ring) registration needs a lane-bound communicator "
                "from runtime.communicator(); logical_communicator() "
                "descriptors only support composite algorithms")
        ns, rounds = derive_slicing(
            n_elems, comm.size, self.cfg.slice_elems, self.cfg.conn_depth)
        chunk = rounds * ns * self.cfg.slice_elems
        padded = comm.size * chunk
        if (CollKind(kind) is CollKind.ALL_TO_ALL
                and n_elems % comm.size != 0):
            # A personalized exchange needs one equal granule per pair:
            # with a ragged tail granule the input clips by DESTINATION
            # and the output by ORIGIN, so the two layouts cannot carry
            # the same elements (data would be silently truncated).
            raise ValueError(
                f"ALL_TO_ALL needs n_elems divisible by the ring size "
                f"(n_elems={n_elems}, ring={comm.size}); register "
                f"ALL_TO_ALL_RAGGED with per-distance chunk_sizes for "
                f"uneven payloads")
        inc, outc = io_chunked(kind)
        in_off = self._alloc_in(padded if inc else chunk)
        out_off = self._alloc_out(padded if outc else chunk)
        if chunk_sizes:
            # Loud registration-time validation: the ragged capacities
            # must tile the padded chunk layout exactly (one count per
            # ring member, each within the chunk's logical capacity,
            # at least one live element overall) — tables.py re-asserts,
            # but a user-facing misregistration should name the rule.
            cl = -(-n_elems // comm.size)
            sizes = tuple(int(z) for z in chunk_sizes)
            if (len(sizes) != comm.size
                    or any(z < 0 or z > cl for z in sizes)
                    or sum(sizes) < 1):
                raise ValueError(
                    f"chunk_sizes must be {comm.size} per-distance counts "
                    f"in [0, {cl}] (chunk capacity for n_elems={n_elems}) "
                    f"with at least one live element, got {sizes}")
        spec = CollectiveSpec(
            coll_id=cid, kind=kind, comm=comm, n_elems=n_elems, op=int(op),
            root=root, in_off=in_off, out_off=out_off, n_slices=ns,
            n_rounds=rounds, next_coll=next_coll, chain_stage=chain_stage,
            inherit_prio=inherit_prio, in_perm=tuple(in_perm),
            chunk_sizes=tuple(int(z) for z in chunk_sizes))
        self.specs.append(spec)
        return cid

    def _register_composite(self, algo: str, kind: CollKind,
                            comm: Communicator, n_elems: int, op: ReduceOp,
                            root: int, hierarchy: Optional[tuple],
                            inherit_prio: bool) -> int:
        """Lower ``algo`` to its stage chain (algos.build_plan) and
        register the stages back-to-back with successor links.  Derived
        heap regions for the chain intermediates come from the same split
        in/out arenas as flat collectives; lane budgets are validated as
        each derived sub-communicator partition claims a lane, and each
        stage's ``derive_slicing`` enforces the per-round connector cap
        for the widest stage's ring.

        Tree/hybrid plans have PARTIAL-membership stages (leader-only
        rings): per-rank entry/tail maps are recorded here so submit()
        can route each rank's SQE to its first participating stage and
        key its completion on its last — on device, tables.chain_next /
        chain_tail_r advance each rank through exactly its own stages."""
        if comm.ring_size is not None and comm.ring_size != len(comm.members):
            raise ValueError(f"{algo} lowering expects a flat logical "
                             "communicator, not an already-partitioned one")
        hier = (tuple(hierarchy) if hierarchy is not None
                else default_hierarchy(len(comm.members)))
        plan = build_plan(algo, kind, comm.members, hier, n_elems, root)
        head = len(self.specs)
        n_stages = len(plan.stages)
        assert head + n_stages <= self.cfg.max_colls, (
            f"composite registration needs {n_stages} collective slots; "
            "raise cfg.max_colls")
        for k, stage in enumerate(plan.stages):
            sub = self._derived_communicator(stage.members, stage.ring_size)
            self._register_ring(
                stage.kind, sub, stage.n_elems, op=op, root=stage.root,
                next_coll=(head + k + 1 if k + 1 < n_stages else -1),
                chain_stage=k, inherit_prio=inherit_prio,
                in_perm=stage.in_perm)
        tail = head + n_stages - 1
        self._tail_of[head] = tail
        self._chain_of[head] = list(range(head, tail + 1))
        self._logical_members[head] = tuple(comm.members)
        self._algo_of[head] = algo
        entry: dict[int, int] = {}
        rtail: dict[int, int] = {}
        for r in comm.members:
            mine = [head + k for k, stage in enumerate(plan.stages)
                    if r in stage.members]
            assert mine, (f"{algo} plan leaves rank {r} out of every "
                          "stage — logical members must all participate")
            if mine[0] != head:
                entry[r] = mine[0]
            if mine[-1] != tail:
                rtail[r] = mine[-1]
        if entry:
            self._entry_of[head] = entry
        if rtail:
            self._rank_tail[head] = rtail
        return head

    def _derived_communicator(self, members, ring_size: int) -> Communicator:
        """Sub-communicator for one composite stage: ``members`` tiled into
        disjoint ``ring_size`` rings sharing ONE lane.  Cached by partition
        signature so composite collectives over the same grid share lanes
        (e.g. every two-level bucket of a grad sync uses the same intra
        and inter lanes)."""
        key = (tuple(members), int(ring_size))
        cached = self._derived_comms.get(key)
        if cached is not None:
            return cached
        lane = len(self.comms)
        if lane >= self.cfg.max_comms:
            raise ValueError(
                f"composite stage needs daemon lane {lane} but "
                f"cfg.max_comms={self.cfg.max_comms}; each derived "
                "sub-communicator partition occupies one lane — raise "
                "max_comms")
        comm = Communicator(comm_id=lane, members=tuple(members),
                            lane=lane, ring_size=int(ring_size))
        self.comms.append(comm)
        self._derived_comms[key] = comm
        return comm

    # ------------------------------------------------------------------
    # lazy build (first launch closes registration)
    # ------------------------------------------------------------------
    def _ensure_built(self):
        if self._tables is None:
            if (self.cfg.burst_slices > 1
                    and self.cfg.conn_depth < 3 * self.cfg.burst_slices):
                warnings.warn(
                    f"conn_depth={self.cfg.conn_depth} < 3 * burst_slices="
                    f"{3 * self.cfg.burst_slices}: the connector cannot "
                    "cover the burst credit round trip, so sustained "
                    "throughput relaxes to the 1-slice/superstep "
                    "equilibrium (no faster than burst_slices=1).  Set "
                    "conn_depth >= 3 * burst_slices or auto_conn_depth=True.",
                    ConnDepthWarning, stacklevel=3)
            self._tables = build_tables(self.cfg, self.comms, self.specs)
            sharding = None
            if self.mesh is None:
                self._daemon = build_sim_daemon(self.cfg, self._tables)
            else:
                from jax.sharding import NamedSharding
                from jax.sharding import PartitionSpec as P

                from .daemon import build_shardmap_daemon
                # The [R, ...] state sharding: rank axis on the mesh axis.
                # Plumbed into the staging engine (per-device flush
                # placements skip the sim-style gathered commit) and into
                # init_state (state is born sharded).
                sharding = NamedSharding(self.mesh, P(self.mesh_axis))
                self._daemon = build_shardmap_daemon(
                    self.cfg, self._tables, self.mesh, self.mesh_axis)
            self._staging = StagingEngine(self.cfg, self._tables,
                                          sharding=sharding)
            self._state = init_state(self.cfg, per_rank=True,
                                     sharding=sharding)

    @property
    def state(self) -> DaemonState:
        self._ensure_built()
        return self._state

    # ------------------------------------------------------------------
    # tick surface (compute-communication overlap; daemon.py docstring)
    # ------------------------------------------------------------------
    def tick_fn(self, barrier: bool = True) -> Callable:
        """The backend's jitted ``tick(state, k) -> (state, TickFlags)``
        over the full [R, ...] state (sharded on the mesh backend).
        ``barrier`` is the static accounting tag (see daemon.TickFlags).
        Host-driven tick launches (``launch_once(tick_k=...)``) use the
        barrier variant; in-step overlap composes the raw builders via
        :meth:`device_api` instead."""
        self._ensure_built()
        fn = self._tick_fns.get(bool(barrier))
        if fn is None:
            if self.mesh is None:
                raw = build_sim_tick(self.cfg, self._tables, barrier=barrier)
            else:
                raw = build_shardmap_tick(self.cfg, self._tables, self.mesh,
                                          self.mesh_axis, barrier=barrier)
            fn = jax.jit(raw)
            self._tick_fns[bool(barrier)] = fn
        return fn

    def device_api(self):
        """The in-trace submission/tick/read API bound to this runtime's
        registrations (sim backend; core/device_api.py)."""
        if self._device_api is None:
            from .device_api import DeviceApi
            self._device_api = DeviceApi(self)
        return self._device_api

    def adopt_state(self, st: DaemonState) -> None:
        """Install a state produced by in-trace ticks (device_api) as the
        runtime's current state, syncing the host completion snapshot so
        a later ``reconcile`` does not re-fire device-side completions."""
        self._ensure_built()
        self._state = jax.block_until_ready(st)
        self.queues._completed_seen = np.asarray(
            st.completed, dtype=np.int64).copy()

    # ------------------------------------------------------------------
    # data movement (send/recv buffers live in the per-rank heap)
    # ------------------------------------------------------------------
    def _spec(self, coll_id: int) -> CollectiveSpec:
        return self.specs[coll_id]

    def _current_cid(self, reg_index: int) -> int:
        """Registration-log index -> CURRENT head collective id."""
        cid = self._log_cids[reg_index]
        if cid is None:
            raise EvictionError(
                f"registration {reg_index} did not survive the last "
                "shrink (its group dissolved or could not be rebuilt)")
        return cid

    def _resolve_cid(self, coll_id) -> int:
        """Public-API id resolution: a :class:`CollectiveHandle` follows
        the registration log across shrinks; a plain int is the thin
        DEPRECATED shim — accepted verbatim, valid only against the
        current registration generation."""
        if isinstance(coll_id, CollectiveHandle) and \
                coll_id._runtime is self:
            return self._current_cid(coll_id.reg_index)
        return int(coll_id)

    def _resolve_off(self, coll_id: int, off: Optional[int], default: int,
                     span: int, name: str) -> int:
        """Default (None / -1 sentinel) or per-SQE-override base offset;
        overrides are bounds-checked and negatives other than the -1
        sentinel are rejected (an underflowed offset silently landing on
        the registered default is the silent-ignore bug class this layer
        exists to close)."""
        if off is None or off == -1:
            return default
        if off < 0 or off + span > self.cfg.heap_elems:
            raise ValueError(
                f"collective {coll_id}: {name} override {off} + padded "
                f"span {span} outside [0, heap_elems={self.cfg.heap_elems})")
        return off

    def _resolve_in_off(self, coll_id: int, off: Optional[int]) -> int:
        return self._resolve_off(coll_id, off, self._spec(coll_id).in_off,
                                 int(self._tables.in_span[coll_id]),
                                 "in_off")

    def _out_cid(self, coll_id: int) -> int:
        """Logical OUTPUT endpoint: the chain tail for composite
        collectives, the collective itself otherwise."""
        return self._tail_of.get(coll_id, coll_id)

    def _resolve_out_off(self, coll_id: int, off: Optional[int]) -> int:
        # Offsets resolve against the chain TAIL — the logical output
        # endpoint a per-SQE override addresses (runtime + daemon agree:
        # fetch_sqe applies the override at chain_tail[c]).
        tcid = self._out_cid(coll_id)
        return self._resolve_off(coll_id, off, self._spec(tcid).out_off,
                                 int(self._tables.out_span[tcid]),
                                 "out_off")

    def write_input(self, rank: int, coll_id: int, data: np.ndarray,
                    in_off: Optional[int] = None) -> None:
        """Place logical input data into the rank's heap (padded layout,
        pad positions zero-filled).  Supersedes any payload staged at the
        same buffer by an earlier ``submit(..., data=...)``."""
        self._ensure_built()
        coll_id = self._resolve_cid(coll_id)
        off = self._resolve_in_off(coll_id, in_off)
        self.queues.staged.pop((rank, coll_id, off), None)
        self._state = self._staging.write(
            self._state, [(rank, coll_id, data, off)])

    def write_inputs_bulk(self, writes: dict) -> None:
        """Batch heap writes: ``{(rank, coll_id): data}`` in ONE
        host->device transfer + one fused scatter.  To override the
        registered offset, pass the value as an ``(ndarray, in_off)``
        pair — the payload must be an ``np.ndarray`` in that form, so a
        plain tuple/list of numbers is always treated as data."""
        self._ensure_built()
        specs = self.specs
        staged = self.queues.staged
        items = []
        for (rank, coll_id), v in writes.items():
            coll_id = self._resolve_cid(coll_id)
            if (isinstance(v, tuple) and len(v) == 2
                    and isinstance(v[0], np.ndarray)
                    and isinstance(v[1], (int, np.integer))):
                data, off = v[0], self._resolve_in_off(coll_id, v[1])
            else:                       # registered default: pre-validated
                data, off = v, specs[coll_id].in_off
            if staged:
                staged.pop((rank, coll_id, off), None)
            items.append((rank, coll_id, data, off))
        self._state = self._staging.write(self._state, items)

    def read_outputs_bulk(self, reads: list) -> dict:
        """Batch heap reads: ``[(rank, coll_id), ...]`` (or ``(rank,
        coll_id, out_off)``) with ONE fused gather + device->host transfer.
        Returns ``{(rank, coll_id): logical output}`` as owned copies.
        Composite collectives read from their chain TAIL's output region
        but stay keyed by the logical (head) id the caller passed."""
        self._ensure_built()
        specs = self.specs
        # Identical repeats dedup (pre-PR dict semantics); only CONFLICTING
        # offsets for one (rank, coll_id) are ambiguous — the result dict
        # could hold just one of them — and must be rejected.
        resolved: dict = {}
        orig_of: dict = {}
        for e in reads:
            cid = self._resolve_cid(e[1])
            tcid = self._out_cid(cid)
            off = (self._resolve_out_off(cid, e[2]) if len(e) > 2
                   else specs[tcid].out_off)
            prev = resolved.setdefault((e[0], tcid), off)
            if prev != off:
                raise ValueError(
                    f"conflicting out_off reads for (rank={e[0]}, "
                    f"coll={e[1]}): {prev} vs {off}; read each "
                    "dynamic-offset result with its own read_output call")
            orig_of.setdefault((e[0], tcid), []).append((e[0], e[1]))
        keys = [(r, c, off) for (r, c), off in resolved.items()]
        got = self._staging.read(self._state, keys)
        out: dict = {}
        for (r, tcid), v in got.items():
            for i, okey in enumerate(dict.fromkeys(orig_of[(r, tcid)])):
                # Every result stays an OWNED array even when a head and
                # its tail were both requested (aliased reads get copies).
                out[okey] = v if i == 0 else v.copy()
        return out

    def read_output(self, rank: int, coll_id: int,
                    out_off: Optional[int] = None) -> np.ndarray:
        """Gather logical output data from the rank's heap (un-pad);
        returns an owned copy (callers may mutate it in place).  For a
        composite collective this reads the chain tail's output region —
        the logical endpoint of the chain."""
        self._ensure_built()
        coll_id = self._resolve_cid(coll_id)
        tcid = self._out_cid(coll_id)
        return self._staging.read(
            self._state,
            [(rank, tcid, self._resolve_out_off(coll_id, out_off))]
        )[(rank, tcid)]

    # ------------------------------------------------------------------
    # submission + event-driven execution (paper Sec. 3.1.2 / 3.1.3)
    # ------------------------------------------------------------------
    def submit(self, rank: int, coll_id: int, prio: int = 0,
               data: Optional[np.ndarray] = None,
               callback: Optional[Callable[[int, int], None]] = None,
               in_off: int = -1, out_off: int = -1) -> None:
        """Enqueue one SQE.  A payload passed via ``data`` is STAGED
        host-side and flushed to the device in the next ``launch_once``
        prologue (one batched transfer per launch), not written at call
        time.  ``in_off``/``out_off`` override the registered heap offsets
        for this submission (-1 keeps the defaults); the override is
        honored both by the daemon (SQE fetch) and by the staged write.

        For a composite (chained) collective the id is the logical
        handle: the payload stages into the chain HEAD's input region,
        ``out_off`` overrides the chain TAIL's output region, and the
        callback fires once — when this rank's last participating stage
        completes — with the logical id the caller submitted.  On a
        partial-membership chain (tree/hybrid plans) the SQE itself is
        routed to the rank's ENTRY stage: a rank skipping the head would
        otherwise fetch a stage it is not a member of and stall the
        chain forever."""
        self._ensure_built()
        in_off_arg, out_off_arg = in_off, out_off
        coll_id = self._resolve_cid(coll_id)
        in_off = self._resolve_in_off(coll_id, in_off)
        out_off = self._resolve_out_off(coll_id, out_off)
        if data is not None:
            # snapshot() validates and COPIES: the flush happens at the
            # next launch prologue, and the pre-PR immediate-write
            # semantics captured the value at call time — a caller
            # reusing its buffer between submit and drive must not leak
            # the mutation in.
            self.queues.stage(rank, coll_id,
                              self._staging.snapshot(coll_id, data), in_off)
        entry = self._entry_of.get(coll_id, {}).get(rank, coll_id)
        # This rank's completion endpoint (CQE source stage): its last
        # participating stage — the logical tail except on chains that
        # drop the rank early (e.g. tree-reduce non-leaders).
        tcid = self._rank_tail.get(coll_id, {}).get(
            rank, self._out_cid(coll_id))
        cb = callback
        if callback is not None and tcid != coll_id:
            # CQEs of a chain are emitted by the rank's tail stage;
            # surface the LOGICAL id to the user callback.
            def cb(r, _c, _cb=callback, _lc=coll_id):
                _cb(r, _lc)
        # Outstanding-submission ledger (evict() replay + diagnose()):
        # one record per SQE, popped by the accounting callback when the
        # completion reconciles.  Payloads are NOT duplicated here —
        # evict() recovers them from the staging queue or the device heap.
        key = (rank, coll_id)
        self._outstanding[key].append({
            "seq": self._sub_seq, "rank": rank, "cid": coll_id,
            "reg_index": self._head_to_reg.get(coll_id),
            "prio": prio, "callback": callback,
            "in_off_arg": in_off_arg, "out_off_arg": out_off_arg,
            "in_off": in_off, "out_off": out_off,
            "had_data": data is not None,
        })
        self._sub_seq += 1
        self._submit_counts[key] = self._submit_counts.get(key, 0) + 1

        def _acct(r, c, _key=key, _user=cb):
            dq = self._outstanding.get(_key)
            if dq:
                dq.popleft()
            if _user is not None:
                _user(r, c)

        # A non-head entry stage never reads the logical input (broadcast
        # non-roots), so the head-resolved in_off override must not leak
        # into its fetch — the entry keeps its registered default.
        sqe_in = in_off if entry == coll_id else -1
        self.queues.submit(rank, SQE(coll_id=entry, prio=prio,
                                     in_off=sqe_in, out_off=out_off,
                                     callback=_acct),
                           cb_coll=tcid)

    def submit_all(self, coll_id: int, prio=0, data=None, callback=None,
                   in_off=-1, out_off=-1) -> None:
        """Submit one collective on every member rank.

        Every argument is forwarded to :meth:`submit` and may be either a
        single value applied to all ranks or a per-rank ``{rank: value}``
        mapping (missing ranks take the default) — so a caller can hand
        per-rank priorities, payloads, completion callbacks and dynamic
        buffer offsets without falling back to a hand-rolled submit loop.
        """
        coll_id = self._resolve_cid(coll_id)
        members = self._logical_members.get(
            coll_id, self._spec(coll_id).comm.members)

        def pick(v, r, default):
            return v.get(r, default) if isinstance(v, dict) else v

        for r in members:
            self.submit(r, coll_id,
                        prio=pick(prio, r, 0),
                        data=pick(data, r, None),
                        callback=pick(callback, r, None),
                        in_off=pick(in_off, r, -1),
                        out_off=pick(out_off, r, -1))

    def _flush_staged(self) -> None:
        """Launch prologue: drain the submit-time staging queue into the
        device heap — one batched scatter for every payload submitted
        since the previous launch."""
        staged = self.queues.take_staged()
        if staged:
            self._state = self._staging.write(self._state, staged,
                                              owned=True)

    def launch_once(self, tick_k: Optional[int] = None) -> int:
        """One daemon launch; returns #CQEs drained (may be 0).

        ``tick_k`` switches to the host-driven TICK path: the launch is
        the jitted prologue plus repeated ``tick(tick_k)`` calls until the
        fabric goes not-live.  Batching invariance (daemon.py docstring)
        makes the trajectory bit-identical to the one-shot daemon for any
        ``tick_k >= 1`` — the tick/drive equivalence tests exercise this.
        """
        self._ensure_built()
        self._flush_staged()
        prev_slices = int(np.asarray(self._state.slices_moved).sum())
        st = self.queues.pack_sq(self._state)
        if tick_k is None:
            st = self._daemon(st)
        else:
            if self._prologue_jit is None:
                self._prologue_jit = jax.jit(launch_prologue)
            tick = self.tick_fn(barrier=True)
            st = self._prologue_jit(st)
            while True:
                st, flags = tick(st, jnp.int32(tick_k))
                if not bool(jax.device_get(flags.live)):
                    break
        st = jax.block_until_ready(st)
        self.launches += 1
        self._state = st
        fired = self.queues.reconcile(st)
        self.launch_history.append({
            "epoch": int(np.asarray(st.epoch).max()),
            "launch_steps": int(np.asarray(st.launch_steps).max()),
            "slices_moved": int(np.asarray(st.slices_moved).sum())
                            - prev_slices,
            "completions": fired,
        })
        return fired

    def drive(self, max_launches: int = 64,
              tick_k: Optional[int] = None) -> None:
        """Event-driven daemon restarting: run while #CQE < #SQE (Sec. 3.1.3).

        ``max_launches`` bounds CONSECUTIVE launches without progress (no
        completions reconciled and no slices moved), not total launches: a
        workload whose span exceeds ``superstep_budget`` legitimately needs
        many launches, and each one that advances work resets the patience.
        ``tick_k`` routes every launch through the host-driven tick path
        (see :meth:`launch_once`).
        """
        idle = 0
        while self.queues.outstanding() != 0:
            self.launch_once(tick_k=tick_k)
            rec = self.launch_history[-1]
            if rec["completions"] == 0 and rec["slices_moved"] == 0:
                idle += 1
            else:
                idle = 0
            if idle >= max_launches:
                raise self._deadlock_error(
                    f"{self.queues.outstanding()} collectives outstanding "
                    f"after {idle} consecutive daemon launches without "
                    f"progress ({self.launches} total) — a member rank "
                    f"never submitted a matching collective")

    def _deadlock_error(self, msg: str) -> DeadlockTimeout:
        """Build the enriched :class:`DeadlockTimeout`: the flight-recorder
        export plus a host-side diagnosis naming the rank(s) holding each
        stalled collective ride on the exception (satellite 2)."""
        export = self.export_flight_record()
        diag = None
        try:
            diag = _recorder.diagnose(self)
            if diag is not None and diag.stalled:
                msg = msg + "\n" + str(diag)
        except Exception:  # diagnosis is best-effort — never mask the hang
            pass
        return DeadlockTimeout(msg, flight_record=export, diagnosis=diag)

    # ------------------------------------------------------------------
    # elastic shrink (evict one rank, rebuild for R-1, replay, resume)
    # ------------------------------------------------------------------
    def _drain_completable(self, max_idle: int = 2,
                           max_total: int = 64) -> int:
        """Run the daemon until every COMPLETABLE in-flight chain has
        drained: launches repeat while they make progress (completions or
        slices moved) and stop after ``max_idle`` idle launches — work
        still outstanding then is wedged (typically on the rank about to
        be evicted) and becomes evict()'s replay set.  Never raises on
        the wedged remainder; returns the number of launches run."""
        n = idle = 0
        while self.queues.outstanding() and n < max_total and \
                idle < max_idle:
            self.launch_once()
            n += 1
            rec = self.launch_history[-1]
            if rec["completions"] == 0 and rec["slices_moved"] == 0:
                idle += 1
            else:
                idle = 0
        return n

    def evict(self, rank: int, relaunch: bool = True) -> dict:
        """Elastically shrink the fabric by one rank (the tentpole API).

        Lifecycle (drain -> rebuild -> replay):

        1. **Drain**: run the daemon until every completable in-flight
           chain finishes; what remains outstanding is wedged (usually on
           the evicted rank).  Payloads of the wedged submissions are
           recovered host-side — from the submit-time staging queue if
           not yet flushed, else gathered straight out of the old device
           ``heap_in`` through the registration's logical index map.
        2. **Rebuild**: reset every derived structure (communicators,
           specs, chain tables, heap arenas, staging engine, daemon
           program, host queues, device state) and REPLAY the
           registration log against the shrunk rank set — surviving
           members renumber ``m -> m - (m > rank)``.  Each registration
           keeps its log index, so existing :class:`CollectiveHandle`\\ s
           re-resolve transparently; a registration whose group
           dissolves, whose root rank died (BROADCAST/REDUCE), or whose
           per-peer chunk layout cannot tile the smaller ring (flat and
           ragged ALL_TO_ALL) resolves to "gone" and its handle raises
           :class:`EvictionError` on use.  The rewritten log (members
           AND root) is renumbered post-shrink, so evictions compose.
        3. **Replay**: re-submit every surviving wedged submission in
           original submission order with its recovered payload and
           original arguments, then (``relaunch=True``) ``drive()`` once
           — the single relaunch after which the fabric runs normally.

        The rebuilt runtime is indistinguishable from a FRESH runtime
        constructed at R-1 with the same registration script: scheduler
        state starts clean, so post-evict supersteps and collective
        outputs are bit-identical to the fresh baseline (asserted by
        tests/test_reliability.py and gated in CI).

        Caveats: device ``heap_out`` contents do not survive the rebuild
        — read results BEFORE evicting (completed-but-unread outputs are
        dropped); the evicted rank's own outstanding submissions die
        with it; registration stays closed (the log replays, new
        registrations are still rejected).  Sim backend only.
        """
        if self.mesh is not None:
            raise NotImplementedError(
                "evict() is sim-backend only: shrinking a jax device mesh "
                "needs a new Mesh over the surviving devices — rebuild the "
                "runtime on the shrunk mesh with the same registration "
                "script instead")
        R = self.cfg.n_ranks
        if not 0 <= rank < R:
            raise EvictionError(f"rank {rank} outside [0, {R})")
        if R <= 1:
            raise EvictionError("cannot shrink below 1 rank")
        self._ensure_built()
        # --- 1. drain ---------------------------------------------------
        drain_launches = self._drain_completable()
        old_state = self._state
        old_tables = self._tables
        old_staged = dict(self.queues.staged)
        heap_in = None  # fetched lazily (one device->host transfer)
        records = sorted(
            (rec for dq in self._outstanding.values() for rec in dq),
            key=lambda d: d["seq"])
        replay = []
        dropped = []
        for rec in records:
            if rec["rank"] == rank:
                dropped.append(rec)
                continue
            if rec["reg_index"] is None:
                raise EvictionError(
                    f"outstanding submission of collective {rec['cid']} on "
                    f"rank {rec['rank']} was made against a raw non-head "
                    "stage id — it cannot be re-resolved after a shrink "
                    "(submit logical collective handles/ids only)")
            data = None
            n_log = int(old_tables.in_log[rec["cid"]])
            if n_log > 0:
                key = (rec["rank"], rec["cid"], rec["in_off"])
                if key in old_staged:
                    data = np.asarray(old_staged[key])
                else:
                    if heap_in is None:
                        heap_in = np.asarray(old_state.heap_in)
                    data = heap_in[rec["rank"], rec["in_off"]
                                   + old_tables.stage_in_map[rec["cid"]]]
            replay.append((rec, data))
        # --- 2. rebuild for R-1 -----------------------------------------
        dead = rank
        remap = {m: m - (m > dead) for m in range(R)}
        old_log = self._reg_log
        old_cids = list(self._log_cids)
        self.cfg = dataclasses.replace(self.cfg, n_ranks=R - 1)
        self.comms = []
        self.specs = []
        self._tail_of = {}
        self._chain_of = {}
        self._derived_comms = {}
        self._entry_of = {}
        self._rank_tail = {}
        self._logical_members = {}
        self._algo_of = {}
        self._in_ptr = 0
        self._out_ptr = 0
        self._tables = None
        self._staging = None
        self._daemon = None
        self._tick_fns = {}
        self._prologue_jit = None
        self._device_api = None
        self._state = None
        self.queues = HostQueues(self.cfg)
        self._outstanding = collections.defaultdict(collections.deque)
        self._submit_counts = {}
        self._generation += 1
        self.evictions.append(rank)
        new_log: list[dict] = []
        new_log_cids: list[Optional[int]] = []
        self._head_to_reg = {}
        # The log's comm_id fields are SYMBOLIC join keys between comm and
        # register entries (stable across shrinks); the rebuilt
        # Communicator objects get fresh lane-ordered ids of their own.
        comm_map: dict = {}
        self._replaying = True
        try:
            for entry in old_log:
                members = tuple(remap[m] for m in entry["members"]
                                if m != dead)
                if entry["what"] == "comm":
                    new_log.append(dict(entry, members=members))
                    comm_map[entry["comm_id"]] = (
                        self.communicator(members) if members else None)
                    continue
                # register entry: keep its _log_cids POSITION even when it
                # dissolves — handle reg_index stability depends on it.
                reg_index = len(new_log_cids)
                was_alive = old_cids[reg_index] is not None
                rooted = CollKind(entry["kind"]) in (
                    CollKind.BROADCAST, CollKind.REDUCE)
                # The rewritten log is in POST-shrink numbering: the root
                # must be remapped alongside the members (a stale root
                # would be misread against the NEXT evict's dead rank /
                # remap).  A rooted entry whose root is gone keeps the
                # tombstone -1 so it stays dissolved across later evicts.
                root = entry["root"]
                root_gone = rooted and (root < 0 or root == dead)
                new_root = -1 if root_gone else \
                    (remap[root] if rooted else 0)
                new_entry = dict(entry, members=members, root=new_root)
                new_log.append(new_entry)
                head = None
                comm = None
                if members:
                    if entry["comm_id"] == -1:
                        comm = self.logical_communicator(members)
                    else:
                        comm = comm_map.get(entry["comm_id"])
                if comm is not None:
                    hier = entry["hierarchy"]
                    if hier is not None and \
                            int(np.prod(hier)) != len(members):
                        hier = None  # re-derive for the smaller group
                    sizes = entry["chunk_sizes"]
                    if sizes is not None and len(sizes) != len(members):
                        # Per-distance ragged capacities are defined over
                        # the ORIGINAL ring size; they cannot be remapped
                        # onto a smaller ring — dissolve loudly.
                        if was_alive:
                            warnings.warn(
                                f"registration {reg_index} "
                                "(ALL_TO_ALL_RAGGED) dissolved by evict(): "
                                f"chunk_sizes has {len(sizes)} per-distance "
                                f"counts but the shrunk group has "
                                f"{len(members)} members", stacklevel=2)
                        comm = None
                    elif CollKind(entry["kind"]) is CollKind.ALL_TO_ALL:
                        # The flat all-to-all's I/O is R equal per-peer
                        # chunks of n_elems/R: any payload (staged,
                        # in-heap, or application-side) laid out for the
                        # original ring scrambles on a smaller one (chunk
                        # size changes, the dead rank's chunk has no
                        # destination) — dissolve like the ragged variant.
                        if was_alive:
                            warnings.warn(
                                f"registration {reg_index} (ALL_TO_ALL) "
                                "dissolved by evict(): its per-peer chunk "
                                "layout is defined over the original ring "
                                "size and cannot be re-tiled for "
                                f"{len(members)} members", stacklevel=2)
                        comm = None
                    if comm is not None and root_gone:
                        # The semantic endpoint (broadcast source / reduce
                        # destination) is gone; silently re-rooting would
                        # change the collective's meaning.
                        if was_alive:
                            warnings.warn(
                                f"registration {reg_index} "
                                f"({CollKind(entry['kind']).name}) "
                                f"dissolved by evict(): its root rank "
                                f"{dead} was evicted", stacklevel=2)
                        comm = None
                    if comm is not None:
                        head = self._register_impl(
                            entry["kind"], comm, entry["n_elems"],
                            op=entry["op"],
                            root=(new_root if rooted else 0),
                            algo=entry["algo"], hierarchy=hier,
                            inherit_prio=entry["inherit_prio"],
                            chunk_sizes=sizes)
                        self._head_to_reg[head] = reg_index
                new_log_cids.append(head)
        finally:
            self._replaying = False
        self._reg_log = new_log
        self._log_cids = new_log_cids
        # --- 3. replay surviving wedged submissions ---------------------
        replayed = 0
        for rec, data in replay:
            new_cid = self._log_cids[rec["reg_index"]]
            if new_cid is None:
                warnings.warn(
                    f"dropping outstanding submission of dissolved "
                    f"registration {rec['reg_index']} on old rank "
                    f"{rec['rank']} (its completion callback will never "
                    "fire)", stacklevel=2)
                continue
            self.submit(remap[rec["rank"]], new_cid, prio=rec["prio"],
                        data=data, callback=rec["callback"],
                        in_off=rec["in_off_arg"],
                        out_off=rec["out_off_arg"])
            replayed += 1
        if relaunch and self.queues.outstanding():
            self.drive()
        return {
            "evicted_rank": rank,
            "n_ranks": self.cfg.n_ranks,
            "generation": self._generation,
            "drain_launches": drain_launches,
            "replayed": replayed,
            "dropped": len(dropped),
            "dissolved": [i for i, c in enumerate(self._log_cids)
                          if c is None],
        }

    # ------------------------------------------------------------------
    # observability (paper Fig. 9)
    # ------------------------------------------------------------------
    def export_flight_record(self) -> dict:
        """Numpy export of the on-device flight-recorder ring (+ wrap-proof
        per-kind counters); decode with :func:`repro.core.recorder.events`.
        Included in :meth:`stats` and attached to every
        :class:`~repro.core.errors.DeadlockTimeout` this runtime raises."""
        self._ensure_built()
        return _recorder.export_record(self._state, self.cfg)

    def collective_stats(self, coll_id) -> dict:
        """Per-collective observability slice (the :class:`CollectiveHandle`
        ``stats()`` surface): the logical head's chain stages and the
        scheduler counters restricted to those stage columns."""
        self._ensure_built()
        cid = self._resolve_cid(coll_id)
        stages = list(self._chain_of.get(cid, [cid]))
        st = self._state
        cols = np.asarray(stages, dtype=np.int64)
        rtc_ev = np.asarray(st.rtc_events)[:, cols]
        rtc_lat = np.asarray(st.rtc_latency)[:, cols]
        with np.errstate(divide="ignore", invalid="ignore"):
            rtc_mean = np.where(rtc_ev > 0, rtc_lat / np.maximum(rtc_ev, 1),
                                0.0)
        return {
            "coll_id": cid,
            "algo": self._algo_of.get(cid, "ring"),
            "members": tuple(self._logical_members.get(
                cid, self._spec(cid).comm.members)),
            "stages": stages,                      # chain stage ids
            "completed": np.asarray(st.completed)[:, cols],        # [R, S]
            "stage_completions":
                np.asarray(st.stage_completions)[:, cols],         # [R, S]
            "preempts": np.asarray(st.preempts)[:, cols],          # [R, S]
            "stall_slices": np.asarray(st.stall_slices)[:, cols],  # [R, S]
            "rtc_events": rtc_ev,                                  # [R, S]
            "rtc_latency": rtc_lat,                                # [R, S]
            "rtc_mean_latency": rtc_mean,                          # [R, S]
            "outstanding": {
                r: len(dq) for (r, c), dq in self._outstanding.items()
                if c == cid and dq
            },
        }

    def stats(self) -> dict:
        self._ensure_built()
        st = self._state
        return {
            "preempts": np.asarray(st.preempts),          # [R, C]
            "stall_slices": np.asarray(st.stall_slices),  # [R, C] — burst
                                                          # slices denied by
                                                          # the credit gate
            "qlen_at_fetch": np.asarray(st.qlen_at_fetch),
            "completed": np.asarray(st.completed),    # LOGICAL completions
                                                      # (chain tails only)
            # Per-stage completions, chain intermediates included: for a
            # composite collective, stage_completions[:, head..tail] counts
            # each sub-collective's executions — `chains` maps each logical
            # head id to its stage ids so callers can index the matrix.
            "stage_completions": np.asarray(st.stage_completions),
            "chains": dict(self._chain_of),
            # Lowered algorithm per logical collective (composite heads
            # only; flat registrations are implicitly "ring") and the
            # per-lane burst caps the bandwidth-skew model assigned —
            # what auto-selection observability and the algos bench read.
            "algos": dict(self._algo_of),
            "lane_caps": np.asarray(self._tables.lane_caps),
            "supersteps": np.asarray(st.supersteps),      # cumulative epoch
                                                          # clock (never
                                                          # reset)
            "launch_steps": np.asarray(st.launch_steps),  # last launch only
            "epoch": np.asarray(st.epoch),                # device launch
                                                          # counter
            "slices_moved": np.asarray(st.slices_moved),
            # Tick/overlap observability (state.py): tick invocations and
            # the barrier/overlap split of the superstep clock — overlap
            # supersteps ran hidden behind step compute, barrier
            # supersteps are exposed (drive()/drain); their sum equals
            # ``supersteps`` because every superstep runs inside some
            # tick.  ``rtc_latency[r, c] / rtc_events[r, c]`` is the mean
            # ready-to-complete latency of collective c on rank r
            # (supersteps from queue entry to completion); rtc_events
            # reconciles with stage_completions.
            "tick_calls": np.asarray(st.tick_calls),            # [R]
            "overlap_supersteps": np.asarray(st.overlap_steps),  # [R]
            "barrier_supersteps": np.asarray(st.barrier_steps),  # [R]
            "rtc_latency": np.asarray(st.rtc_latency),          # [R, C]
            "rtc_events": np.asarray(st.rtc_events),            # [R, C]
            "cq_count": np.asarray(st.cq_count),          # [R] — may exceed
                                                          # cq_len (ring CQ)
            "burst_slices": self.cfg.burst_slices,
            "launches": self.launches,
            "launch_history": list(self.launch_history),
            # Staging-flush accounting (mesh fast path observability):
            # payload bytes shipped by StagingEngine.write and how many of
            # those writes took the per-device sharded placement path.
            "staging_flush_writes": self._staging.flush_writes,
            "staging_flush_bytes": self._staging.flush_bytes,
            "staging_sharded_flushes": self._staging.sharded_flushes,
            # Flight-recorder export (core/recorder.py): per-rank event
            # ring + wrap-proof per-kind cumulative counters.  Decode with
            # ``recorder.events``; ``recorder.diagnose(runtime)`` names
            # the rank holding each stalled chain on a hang.
            "flight_recorder": _recorder.export_record(st, self.cfg),
        }
