"""Pallas kernels vs ref.py oracles: shape/dtype sweeps in interpret mode,
plus hypothesis property tests on the fused-primitive semantics."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels.chunk_combine import chunk_combine_pallas
from repro.kernels.fused_slice import fused_primitive_pallas


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S", [(1, 8), (1, 64), (3, 512), (2, 1024),
                                 (4, 96)])
def test_fused_primitive_sweep(dtype, B, S):
    rng = np.random.RandomState(B * 1000 + S)
    p = jnp.asarray(rng.randn(B, S), dtype)
    l = jnp.asarray(rng.randn(B, S), dtype)
    f = jnp.asarray(rng.randint(0, 2, (B, 4)), jnp.int32)
    f = f.at[:, 3].set(jnp.asarray(rng.randint(0, 4, (B,)), jnp.int32))
    got = fused_primitive_pallas(p, l, f, interpret=True)
    want = ops.fused_primitive_ref(p, l, f)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T", [8, 1000, 1024, 4096, 5000])
@pytest.mark.parametrize("op", [0, 1, 2, 3])
def test_chunk_combine_sweep(dtype, T, op):
    rng = np.random.RandomState(T + op)
    a = jnp.asarray(rng.randn(T), dtype)
    b = jnp.asarray(rng.randn(T), dtype)
    got = chunk_combine_pallas(a, b, op, interpret=True)
    want = ops.chunk_combine_ref(a, b, op)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=1e-6)


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_fused_primitive_props(data):
    """Semantics: reduce==op(payload,local); recv-only==payload;
    reads-only==local; neither==0."""
    S = data.draw(st.sampled_from([8, 32, 128]))
    rng = np.random.RandomState(data.draw(st.integers(0, 999)))
    p = jnp.asarray(rng.randn(1, S), jnp.float32)
    l = jnp.asarray(rng.randn(1, S), jnp.float32)
    recv = data.draw(st.integers(0, 1))
    red = data.draw(st.integers(0, 1))
    reads = data.draw(st.integers(0, 1))
    op = data.draw(st.integers(0, 3))
    f = jnp.asarray([[recv, red, reads, op]], jnp.int32)
    got = np.asarray(fused_primitive_pallas(p, l, f, interpret=True))[0]
    pn, ln = np.asarray(p)[0], np.asarray(l)[0]
    if red:
        want = {0: pn + ln, 1: np.maximum(pn, ln),
                2: np.minimum(pn, ln), 3: pn * ln}[op]
    elif recv:
        want = pn
    elif reads:
        want = ln
    else:
        want = np.zeros(S, np.float32)
    np.testing.assert_allclose(got, want, rtol=1e-6)
