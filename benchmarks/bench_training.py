"""Paper Fig. 8/10: DNN training throughput, OCCL vs statically-sequenced
gradient synchronization.

ViT (the paper's Sec. 5.3.2 model) + qwen3 (LM), reduced configs, DP=4
simulated ranks on this host.  Throughput = samples/sec.  The OCCL path
submits per-bucket all-reduces in backward order with priorities (the
overlap policy); the static path sums in a fixed global order.  Per the
paper, OCCL should be within single-digit % of static under uniform
ranks (its win appears under runtime skew, which bench_gang.py shows).
"""
import time

import jax
import numpy as np

from common import row
from repro.configs import get_config
from repro.configs.base import ShapeCell
from repro.data.pipeline import SyntheticPipeline
from repro.train.occl_sync import OcclGradSync, static_all_reduce
from repro.train.state import init_state
from repro.train.step import make_apply_step, make_grads_step


def run_arch(arch: str, steps=6, dp=4, batch=8, seq=32):
    cfg = get_config(arch).reduced()
    cell = ShapeCell("b", seq, batch, "train")
    gfn = jax.jit(make_grads_step(cfg))
    afn = jax.jit(make_apply_step(cfg))

    def loop(kind):
        states = [init_state(cfg) for _ in range(dp)]
        pipes = [SyntheticPipeline(cfg, cell, shard_id=r, n_shards=dp)
                 for r in range(dp)]
        sync = None
        # warmup (compile)
        for r in range(dp):
            gfn(states[r], pipes[r].batch_at(0))
        t0 = time.perf_counter()
        for step in range(steps):
            pr = []
            for r in range(dp):
                _, g = gfn(states[r], next(pipes[r]))
                pr.append(g)
            if kind == "occl":
                nonlocal_sync = sync
                if nonlocal_sync is None:
                    tmpl = jax.tree_util.tree_map(
                        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                        pr[0])
                    sync = OcclGradSync(tmpl, dp, bucket_elems=16384,
                                        slice_elems=512)
                synced = sync.all_reduce(pr)
            else:
                synced = static_all_reduce(pr)
            states = [afn(states[r], synced[r]) for r in range(dp)]
        jax.block_until_ready(states[0].params)
        dt = time.perf_counter() - t0
        return steps * batch / dt, sync

    tput_static, _ = loop("static")
    tput_occl, sync = loop("occl")
    overhead = (tput_static - tput_occl) / tput_static * 100
    st = sync.stats() if sync else {}
    row(f"training/{arch}_dp{dp}", 1e6 / max(tput_occl, 1e-9),
        f"occl_tput={tput_occl:.1f}sps;static_tput={tput_static:.1f}sps;"
        f"overhead={overhead:.1f}%;buckets={len(sync.buckets)}")
    return tput_occl, tput_static


def run():
    out = {}
    for arch in ("vit-base", "qwen3-0.6b"):
        out[arch] = run_arch(arch)
    return out


if __name__ == "__main__":
    run()
