"""OCCL gradient synchronization == the statically-sequenced baseline,
numerically, while tolerating per-rank submission-order skew."""
import pytest

# Heavyweight training-sync integration: excluded from tier-1; run with `pytest -m ""`.
pytestmark = pytest.mark.slow
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeCell
from repro.data.pipeline import SyntheticPipeline
from repro.train.occl_sync import OcclGradSync, static_all_reduce
from repro.train.state import init_state
from repro.train.step import (make_apply_step, make_grads_step,
                              make_overlap_grads_step)


def _grads(dp=2):
    cfg = get_config("qwen3-0.6b").reduced()
    cell = ShapeCell("t", 16, dp, "train")
    states = [init_state(cfg) for _ in range(dp)]
    pipes = [SyntheticPipeline(cfg, cell, shard_id=r, n_shards=dp)
             for r in range(dp)]
    gfn = jax.jit(make_grads_step(cfg))
    return cfg, [gfn(states[r], next(pipes[r]))[1] for r in range(dp)]


def test_occl_sync_matches_static():
    cfg, per_rank = _grads(dp=2)
    tmpl = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), per_rank[0])
    sync = OcclGradSync(tmpl, n_ranks=2, bucket_elems=2048)
    got = sync.all_reduce(per_rank)
    want = static_all_reduce(per_rank)
    for r in range(2):
        for a, b in zip(jax.tree_util.tree_leaves(got[r]),
                        jax.tree_util.tree_leaves(want[r])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-4, atol=1e-6)
    st = sync.stats()
    assert int(st["completed"].sum()) == 2 * len(sync.buckets)


def test_occl_sync_bucket_priority_order():
    """Buckets are registered in backward order and submitted with rising
    priority — the paper's overlap policy."""
    cfg, per_rank = _grads(dp=2)
    tmpl = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), per_rank[0])
    sync = OcclGradSync(tmpl, n_ranks=2, bucket_elems=1024)
    assert len(sync.buckets) >= 2
    n_leaves = len(jax.tree_util.tree_leaves(tmpl))
    # first bucket holds the LAST leaves (backward order)
    assert max(sync.buckets[0].leaf_ids) == n_leaves - 1
    covered = sorted(i for b in sync.buckets for i in b.leaf_ids)
    assert covered == list(range(n_leaves))


def test_occl_sync_two_level_hierarchy():
    """hierarchy=(G, N) routes every bucket through the composite
    two-level all-reduce chain; results match the static baseline and the
    chain/stage counters show every bucket ran as a 3-stage chain."""
    cfg, per_rank = _grads(dp=4)
    tmpl = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), per_rank[0])
    sync = OcclGradSync(tmpl, n_ranks=4, bucket_elems=2048,
                        hierarchy=(2, 2))
    got = sync.all_reduce(per_rank)
    want = static_all_reduce(per_rank)
    for r in range(4):
        for a, b in zip(jax.tree_util.tree_leaves(got[r]),
                        jax.tree_util.tree_leaves(want[r])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-4, atol=1e-6)
    st = sync.stats()
    chains = st["chains"]
    assert len(chains) == len(sync.buckets)
    for b in sync.buckets:
        stages = chains[b.coll_id]
        assert len(stages) == 3
        assert (st["stage_completions"][:, stages] == 1).all()
        assert (st["completed"][:, stages[-1]] == 1).all()


def test_occl_sync_compressed_wire():
    """bf16 wire payloads: half the connector bytes, grads within bf16
    tolerance of the exact f32 reduction."""
    import jax
    cfg, per_rank = _grads(dp=2)
    tmpl = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), per_rank[0])
    sync = OcclGradSync(tmpl, n_ranks=2, bucket_elems=2048,
                        compress_wire=True)
    got = sync.all_reduce(per_rank)
    want = static_all_reduce(per_rank)
    for a, b in zip(jax.tree_util.tree_leaves(got[0]),
                    jax.tree_util.tree_leaves(want[0])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=1e-3)


def test_overlap_grads_step_matches_static():
    """The in-step overlapped backward (custom_vjp boundaries submitting
    buckets mid-backward + hidden ticks, train/step.py) returns the SAME
    averaged gradients as the static baseline, and the tick counters
    show real overlap: supersteps hidden behind backward compute, with
    the barrier drain only exposing the tail."""
    dp = 2
    cfg = get_config("qwen3-0.6b").reduced()
    cell = ShapeCell("t", 16, dp, "train")
    states = [init_state(cfg) for _ in range(dp)]
    batches = [SyntheticPipeline(cfg, cell, shard_id=r,
                                 n_shards=dp).batch_at(0)
               for r in range(dp)]
    gfn = jax.jit(make_grads_step(cfg))
    per_rank = [gfn(states[r], batches[r])[1] for r in range(dp)]
    _, gshape = jax.eval_shape(gfn, states[0], batches[0])
    sync = OcclGradSync(gshape, n_ranks=dp, bucket_elems=16384,
                        slice_elems=512)
    step = jax.jit(make_overlap_grads_step(cfg, sync,
                                           ticks_per_boundary=4))
    s0 = sync.stats()
    st, losses, got = step(sync.occl.state,
                           [s.params for s in states], batches)
    sync.occl.adopt_state(st)
    s1 = sync.stats()
    want = static_all_reduce(per_rank)
    for r in range(dp):
        for a, b in zip(jax.tree_util.tree_leaves(got[r]),
                        jax.tree_util.tree_leaves(want[r])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-4, atol=1e-6)
    hidden = int(np.max(s1["overlap_supersteps"]
                        - s0["overlap_supersteps"]))
    exposed = int(np.max(s1["barrier_supersteps"]
                         - s0["barrier_supersteps"]))
    total = int(np.max(s1["supersteps"] - s0["supersteps"]))
    assert hidden > 0                 # boundaries really hid supersteps
    assert hidden + exposed == total  # every superstep inside some tick
    # every bucket logically completed exactly once on every rank
    assert int((s1["completed"] - s0["completed"]).sum()) \
        == dp * len(sync.buckets)
