"""Quickstart: the OCCL public API in 40 lines.

Register a communicator + collectives ONCE, then submit from any rank in
ANY order — no cross-rank ordering discipline needed.  Completion arrives
via callbacks (the CQ poller), exactly the integration contract of paper
Sec. 4.

    PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import CollKind, OcclConfig, OcclRuntime

R = 4
cfg = OcclConfig(n_ranks=R, max_colls=4, max_comms=1,
                 slice_elems=64, conn_depth=4, heap_elems=1 << 14)
rt = OcclRuntime(cfg)
world = rt.communicator(list(range(R)))

grads = rt.register(CollKind.ALL_REDUCE, world, n_elems=1024)
acts = rt.register(CollKind.ALL_GATHER, world, n_elems=512)

rng = np.random.RandomState(0)
g = [rng.randn(1024).astype(np.float32) for _ in range(R)]
a = [rng.randn(128).astype(np.float32) for _ in range(R)]

done = []
for r in range(R):
    # each rank picks its own order — rank parity inverts it (this would
    # deadlock a single-FIFO-queue library, Fig. 1a)
    order = [(grads, g[r]), (acts, a[r])]
    if r % 2:
        order.reverse()
    for cid, data in order:
        rt.submit(r, cid, data=data,
                  callback=lambda rank, c: done.append((rank, c)))

rt.drive()   # event-driven daemon launches until every CQE has landed

np.testing.assert_allclose(rt.read_output(0, grads), sum(g), rtol=1e-5)
np.testing.assert_allclose(rt.read_output(3, acts),
                           np.concatenate(a), rtol=1e-5)
st = rt.stats()
print(f"completed {len(done)} collective executions on {R} ranks "
      f"in {int(st['supersteps'].max())} supersteps "
      f"({int(st['preempts'].sum())} preemptions; orders were adversarial)")
print("OK")
