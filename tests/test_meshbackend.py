"""Mesh-backend (shard_map + ppermute fabric) integration tests.

Run in a SUBPROCESS with 8 virtual host devices so the main test process
keeps seeing 1 device (per spec)."""
import pytest

# Heavyweight mesh-backend subprocess tests: excluded from tier-1; run with `pytest -m ""`.
pytestmark = pytest.mark.slow
import json
import pathlib
import subprocess
import sys
import textwrap

ROOT = pathlib.Path(__file__).resolve().parents[1]

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "@SRC@")
    import numpy as np, jax
    from repro.core import OcclConfig, CollKind, OcclRuntime

    mesh = jax.make_mesh((8,), ("rank",))
    cfg = OcclConfig(n_ranks=8, max_colls=8, max_comms=2, slice_elems=8,
                     conn_depth=3, heap_elems=1 << 13)
    rt = OcclRuntime(cfg, mesh=mesh)
    world = rt.communicator(list(range(8)))
    evens = rt.communicator([0, 2, 4, 6])
    a = rt.register(CollKind.ALL_REDUCE, world, n_elems=96)
    b = rt.register(CollKind.REDUCE_SCATTER, world, n_elems=64)
    c = rt.register(CollKind.ALL_REDUCE, evens, n_elems=24)
    rng = np.random.RandomState(0)
    xa = [rng.randn(96).astype(np.float32) for _ in range(8)]
    xb = [rng.randn(64).astype(np.float32) for _ in range(8)]
    xc = {r: rng.randn(24).astype(np.float32) for r in evens.members}

    # adversarial per-rank orders across ALL collectives
    for r in range(8):
        rt.write_input(r, a, xa[r]); rt.write_input(r, b, xb[r])
        order = [a, b] if r % 2 == 0 else [b, a]
        if r in evens.members:
            rt.write_input(r, c, xc[r])
            order.insert(r % 3 % 2, c)
        for cid in order:
            rt.submit(r, cid)
    rt.drive()
    for r in range(8):
        np.testing.assert_allclose(rt.read_output(r, a), sum(xa), rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(
            rt.read_output(r, b), sum(xb)[r*8:(r+1)*8], rtol=1e-4, atol=1e-6)
    for r in evens.members:
        np.testing.assert_allclose(
            rt.read_output(r, c), sum(xc.values()), rtol=1e-4, atol=1e-6)
    st = rt.stats()
    print("MESH_OK", int(st["supersteps"].max()), int(st["preempts"].sum()))
""").replace("@SRC@", str(ROOT / "src"))


def test_mesh_backend_adversarial_orders():
    r = subprocess.run([sys.executable, "-c", _SCRIPT],
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MESH_OK" in r.stdout


_BURST_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "@SRC@")
    import numpy as np, jax
    from repro.core import OcclConfig, CollKind, OcclRuntime

    # burst_slices > 1 through the mesh fabric: exercises the fused
    # per-ring-group ppermute pair with [L, B, SL] payload packing
    # (i32 header+payload bitcast for the float32 heap) on two lanes.
    mesh = jax.make_mesh((8,), ("rank",))
    cfg = OcclConfig(n_ranks=8, max_colls=8, max_comms=2, slice_elems=8,
                     conn_depth=8, burst_slices=4, heap_elems=1 << 13)
    rt = OcclRuntime(cfg, mesh=mesh)
    world = rt.communicator(list(range(8)))
    evens = rt.communicator([0, 2, 4, 6])
    a = rt.register(CollKind.ALL_REDUCE, world, n_elems=96)
    c = rt.register(CollKind.ALL_GATHER, evens, n_elems=32)
    rng = np.random.RandomState(0)
    xa = [rng.randn(96).astype(np.float32) for _ in range(8)]
    xc = {r: rng.randn(8).astype(np.float32) for r in evens.members}
    for r in range(8):
        rt.write_input(r, a, xa[r])
        if r in evens.members:
            rt.write_input(r, c, xc[r]); rt.submit(r, c)
        rt.submit(r, a)
    rt.drive()
    for r in range(8):
        np.testing.assert_allclose(rt.read_output(r, a), sum(xa),
                                   rtol=1e-4, atol=1e-6)
    want = np.concatenate([xc[r] for r in evens.members])
    for r in evens.members:
        np.testing.assert_allclose(rt.read_output(r, c), want,
                                   rtol=1e-4, atol=1e-6)

    # 16-bit heap dtype: the PACKED exchange executes (element pairs
    # bitcast into i32 lanes ride the fused header++payload ppermute),
    # and the all-ranks staged submits take the sharded flush placement.
    cfg16 = OcclConfig(n_ranks=8, max_colls=2, max_comms=1, slice_elems=8,
                       conn_depth=6, burst_slices=4, dtype="bfloat16",
                       heap_elems=1 << 12)
    rt16 = OcclRuntime(cfg16, mesh=mesh)
    world16 = rt16.communicator(list(range(8)))
    g = rt16.register(CollKind.ALL_GATHER, world16, n_elems=64)
    xg = [rng.randn(8).astype(np.float32) for _ in range(8)]
    for r in range(8):
        rt16.submit(r, g, data=xg[r])
    rt16.drive()
    wg = np.concatenate(xg)
    for r in range(8):
        np.testing.assert_allclose(
            np.asarray(rt16.read_output(r, g), np.float32), wg,
            rtol=2e-2, atol=2e-2)
    st16 = rt16.stats()
    assert st16["staging_sharded_flushes"] >= 1, st16
    print("MESH_BURST_OK")
""").replace("@SRC@", str(ROOT / "src"))


def test_mesh_backend_burst_slices():
    r = subprocess.run([sys.executable, "-c", _BURST_SCRIPT],
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MESH_BURST_OK" in r.stdout


_CHAINED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "@SRC@")
    import numpy as np, jax
    from repro.core import OcclConfig, CollKind, OcclRuntime

    # Composite two-level all-reduces on the REAL shard_map fabric: the
    # chain (intra reduce-scatter -> inter all-reduce -> intra all-gather)
    # advances on device across the ppermute connector exchanges, two
    # chains share the derived intra/inter lanes, and the ranks submit
    # them in conflicting orders (the chained-collective deadlock
    # scenario on the mesh backend).
    mesh = jax.make_mesh((8,), ("rank",))
    cfg = OcclConfig(n_ranks=8, max_colls=8, max_comms=3, slice_elems=8,
                     conn_depth=4, heap_elems=1 << 13,
                     superstep_budget=1 << 14)
    rt = OcclRuntime(cfg, mesh=mesh)
    world = rt.communicator(list(range(8)))
    a = rt.register(CollKind.ALL_REDUCE, world, n_elems=96,
                    algo="two_level", hierarchy=(2, 4))
    b = rt.register(CollKind.ALL_REDUCE, world, n_elems=56,
                    algo="two_level", hierarchy=(2, 4))
    rng = np.random.RandomState(0)
    xa = [rng.randn(96).astype(np.float32) for _ in range(8)]
    xb = [rng.randn(56).astype(np.float32) for _ in range(8)]
    for r in range(8):
        order = [(a, xa), (b, xb)] if r % 2 == 0 else [(b, xb), (a, xa)]
        for cid, xs in order:
            rt.submit(r, cid, data=xs[r])
    rt.drive(max_launches=128)
    for r in range(8):
        np.testing.assert_allclose(rt.read_output(r, a), sum(xa),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(rt.read_output(r, b), sum(xb),
                                   rtol=1e-4, atol=1e-5)
    st = rt.stats()
    chain = st["chains"][a]
    assert (st["stage_completions"][:, chain] >= 1).all(), st["chains"]
    assert (st["completed"][:, chain[-1]] == 1).all()
    print("MESH_CHAIN_OK", int(st["supersteps"].max()),
          int(st["preempts"].sum()))
""").replace("@SRC@", str(ROOT / "src"))


def test_mesh_backend_chained_two_level():
    r = subprocess.run([sys.executable, "-c", _CHAINED_SCRIPT],
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MESH_CHAIN_OK" in r.stdout


_ELASTIC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, tempfile
    sys.path.insert(0, "@SRC@")
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.train.state import init_state, state_shardings
    from repro.checkpoint.ckpt import save, restore

    cfg = get_config("qwen3-0.6b").reduced()
    state = init_state(cfg)
    with tempfile.TemporaryDirectory() as d:
        # save from an 8-device (4 data x 2 model) mesh
        mesh8 = jax.make_mesh((4, 2), ("data", "model"))
        sh8 = state_shardings(mesh8, cfg, state)
        st8 = jax.tree_util.tree_map(
            lambda a, s: jax.device_put(a, s), state, sh8)
        save(d, 0, st8)
        # restore onto a DIFFERENT 2x2 mesh (elastic downscale)
        mesh4 = jax.make_mesh((2, 2), ("data", "model"))
        sh4 = state_shardings(mesh4, cfg, state)
        got, _ = restore(d, 0, state, shardings=sh4)
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        shard_count = len(jax.tree_util.tree_leaves(got)[1].sharding.device_set)
    print("ELASTIC_OK", shard_count)
""").replace("@SRC@", str(ROOT / "src"))


def test_elastic_checkpoint_reshard():
    r = subprocess.run([sys.executable, "-c", _ELASTIC],
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "ELASTIC_OK" in r.stdout
