"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060].  d_ff=0: no MLP blocks; 64L of Mamba2 mixers.
long_500k runs (linear-time decode with O(1) state)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0, d_head=0,
    d_ff=0, vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_groups=8,
)
