"""Serving launcher.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b \
        --requests 8 --max-new 16

With ``--qos`` the engine's prefill/decode collectives run as staged
OCCL submits on a shared fabric alongside an adversarial background
tenant (grad-sync bursts at the admission cap); decode preempts the
bursts mid-superstep unless ``--no-preempt`` selects the FIFO baseline.
The run then prints the per-class latency digest (supersteps).

Reduced configs run end-to-end on this host; full configs are validated
via the decode/prefill dry-run cells (launch/dryrun.py) and deploy with
the same jitted prefill/serve_step on a real mesh.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--qos", action="store_true",
                    help="share an OCCL fabric with a background tenant")
    ap.add_argument("--no-preempt", action="store_true",
                    help="QoS baseline: FIFO, no priority preemption")
    ap.add_argument("--tp-ranks", type=int, default=4,
                    help="fabric size for the QoS collectives")
    args = ap.parse_args()

    from ..configs import get_config
    from ..serving.engine import Request, ServingEngine

    qos = None
    if args.qos:
        from ..serving.qos import ServingQos
        qos = ServingQos(n_ranks=args.tp_ranks,
                         preemption=not args.no_preempt,
                         prio_aging_quantum=8)

    cfg = get_config(args.arch).reduced()
    eng = ServingEngine(cfg, batch_size=args.batch,
                        prompt_len=args.prompt_len,
                        max_len=args.prompt_len + args.max_new + 8,
                        qos=qos)
    rng = np.random.RandomState(0)
    for i in range(args.requests):
        eng.submit(Request(
            rid=i, prompt=rng.randint(0, cfg.vocab,
                                      size=rng.randint(4, args.prompt_len)),
            max_new_tokens=args.max_new))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    print(f"{args.arch}: {len(done)} requests, "
          f"{eng.stats['tokens']} tokens in {dt:.2f}s "
          f"({eng.stats['tokens']/dt:.1f} tok/s)")
    if qos is not None:
        qos.drain()             # bounded starvation: bursts all land
        q = qos.summary()       # post-drain digest
        print(f"qos (preemption={'off' if args.no_preempt else 'on'}): "
              f"decode p50 {q['decode']['p50']:.0f} / "
              f"p99 {q['decode']['p99']:.0f} supersteps, "
              f"prefill p99 {q['prefill']['p99']:.0f}, "
              f"background completed {q['background']['completed']}"
              f"/{q['background']['submitted']}, "
              f"preempts {q['preempts']}")


if __name__ == "__main__":
    main()
