"""Daemon state: dynamic contexts, connectors, task queue, SQ/CQ mirrors.

Every field is a fixed-shape array so the daemon compiles to one XLA program
(the analogue of the long-running daemon kernel, paper Sec. 3.1).  In the
sim backend each array carries a leading ``n_ranks`` axis and the superstep
is vmapped; in the mesh backend the same arrays are per-device inside
``shard_map``.

Connector representation (paper Fig. 3, Sec. 2.3): the connector between
ring-neighbors ``r -> next(r)`` is a lock-free ring buffer of ``K`` slice
slots.  The *writer* owns the committed-write counter ``head`` and a lagging
mirror of the reader's ``tail`` (credits); the *reader* owns ``tail``, a
lagging mirror of ``head`` and the payload slots.  Committed writes stay
visible to the peer even if the writing collective is preempted — the
visibility property that makes decentralized preemption safe.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from .config import OcclConfig
from .recorder import N_EVENT_KINDS


def heap_scratch_elems(cfg: OcclConfig) -> int:
    """Physical heap padding past the allocatable region: the scheduler's
    per-lane [B*SLICE] burst windows (read and read-modify-write) must
    never clamp-shift at the top of the heap.  Logical offsets handed out
    by the runtime — and every staging-engine index — stay < heap_elems;
    only the daemon's windowed slices may graze the scratch tail."""
    return cfg.burst_slices * cfg.slice_elems


class DaemonState(NamedTuple):
    # --- data heap (send/recv buffers; addresses = heap offsets) --------
    # heap_in is written exclusively through staging.StagingEngine (fused
    # index-map scatters; donated on accelerator backends), heap_out by
    # the daemon's burst windows and read back via the engine's fused
    # gather — no host-side heap mirrors anywhere on the bulk I/O path.
    heap_in: jnp.ndarray       # [H]
    heap_out: jnp.ndarray      # [H]

    # --- connectors (per collective; dedicated, paper Sec. 5.1) ---------
    head: jnp.ndarray          # [C] i32 — my committed writes (send side)
    tail_mirror: jnp.ndarray   # [C] i32 — reader's consumed count (lagging)
    head_mirror: jnp.ndarray   # [C] i32 — upstream's commits (lagging)
    tail: jnp.ndarray          # [C] i32 — my consumed count (recv side)
    payload: jnp.ndarray       # [C, K, SLICE] — recv-connector slots

    # --- task queue + dynamic contexts (paper Sec. 3.1.1) ---------------
    tq_active: jnp.ndarray     # [C] bool — in my task queue
    arrival: jnp.ndarray       # [C] i32 — queue-order key (FIFO / rotate)
    prio: jnp.ndarray          # [C] i32 — user priority (SQE)
    cur: jnp.ndarray           # [L] i32 — executing collective per lane (-1)
    ctx_step: jnp.ndarray      # [C] i32 — primitive index
    ctx_slice: jnp.ndarray     # [C] i32 — slice index inside the chunk
    ctx_round: jnp.ndarray     # [C] i32 — primitive-sequence repetition
    spin: jnp.ndarray          # [C] i32 — current primitive's spin count
    boost: jnp.ndarray        # [C] i32 — stickiness boost (success bonus)
    in_off: jnp.ndarray        # [C] i32 — live buffer addresses (SQE-set)
    out_off: jnp.ndarray       # [C] i32

    # --- SQ / CQ (paper Sec. 3.1.2) --------------------------------------
    sq_coll: jnp.ndarray       # [SQL] i32
    sq_prio: jnp.ndarray       # [SQL] i32
    sq_in: jnp.ndarray         # [SQL] i32 (-1 = keep registered default)
    sq_out: jnp.ndarray        # [SQL] i32
    sq_size: jnp.ndarray       # [] i32 — valid SQEs
    sq_read: jnp.ndarray       # [] i32 — daemon cursor
    cq_coll: jnp.ndarray       # [CQL] i32
    cq_count: jnp.ndarray      # [] i32
    inflight: jnp.ndarray      # [C] bool — submitted, not yet completed

    # --- in-flight connector messages (survive daemon relaunch) ---------
    # A credit/slice-burst emitted on the fabric's last superstep has not
    # been applied yet; dropping it would permanently wedge the connector
    # counters.  The mailbox is therefore part of the persistent state.
    # Counts (not bools): one message carries up to ``burst_slices`` slices.
    mb_fwd_count: jnp.ndarray   # [L] i32
    mb_fwd_coll: jnp.ndarray    # [L] i32
    mb_fwd_payload: jnp.ndarray # [L, B, SLICE]
    mb_rev_count: jnp.ndarray   # [L] i32
    mb_rev_coll: jnp.ndarray    # [L] i32

    # --- counters / lifecycle --------------------------------------------
    # Launch-epoch clock: ``supersteps`` is the cumulative epoch clock
    # (never reset; observability only), ``launch_steps`` is the per-launch
    # clock (zeroed in the daemon prologue) that the superstep budget and
    # the task-queue arrival keys are measured against, and ``epoch``
    # counts daemon launches.  Only the launch clock feeds scheduling
    # decisions, so no decision ever depends on how long the runtime has
    # been alive.
    completed: jnp.ndarray     # [C] i32 — LOGICAL completions (chain tails
                               #   and flat collectives; repeat submissions
                               #   accumulate) — drives host reconciliation
    stage_completions: jnp.ndarray  # [C] i32 — per-stage completions,
                               #   counting chain intermediates too (chain
                               #   observability; == completed when no
                               #   composite collectives are registered)
    preempts: jnp.ndarray      # [C] i32 — context switches (Fig. 9)
    stall_slices: jnp.ndarray  # [C] i32 — burst slices denied by credit
                               #   gating, counting partial denials (stall
                               #   accounting; spin advances by these units
                               #   on zero-progress supersteps)
    qlen_at_fetch: jnp.ndarray # [C] i32 — task-queue length at SQE fetch (Fig. 9)
    supersteps: jnp.ndarray    # [] i32 — cumulative epoch clock
    launch_steps: jnp.ndarray  # [] i32 — per-launch clock (budget domain)
    epoch: jnp.ndarray         # [] i32 — daemon launch counter
    no_prog: jnp.ndarray       # [] i32 — consecutive no-progress supersteps
    made_prog_prev: jnp.ndarray  # [] bool — lazy-fetch gate input
    slices_moved: jnp.ndarray  # [] i32 — work counter (bandwidth accounting)
    global_live: jnp.ndarray   # [] bool — fabric-wide continue flag

    # --- tick/overlap observability (compute-communication overlap) ------
    # ``tick()`` is the unit of daemon progress since the tickable-daemon
    # refactor: drive()'s launches and in-step overlap ticks both run the
    # same loop, tagged by a static barrier/overlap bit.  The invariant
    # ``overlap_steps + barrier_steps == supersteps`` holds because EVERY
    # superstep executes inside some tick.  Ready-to-complete latency is
    # measured on the cumulative ``supersteps`` clock: ``fetch_step[c]``
    # stamps when c entered the task queue (SQE fetch or device-enqueued
    # chain successor) and completion accumulates the delta into
    # ``rtc_latency``; ``rtc_events`` counts the completions accounted
    # (== stage_completions, asserted by tier-1 tests).
    fetch_step: jnp.ndarray    # [C] i32 — supersteps stamp at queue entry
    rtc_latency: jnp.ndarray   # [C] i32 — cumulative ready-to-complete
                               #   supersteps (sum over completions)
    rtc_events: jnp.ndarray    # [C] i32 — completions the latency counter
                               #   accounted (reconciles stage_completions)
    tick_calls: jnp.ndarray    # [] i32 — tick() invocations
    overlap_steps: jnp.ndarray # [] i32 — supersteps run by overlap ticks
                               #   (interleaved with compute in a step)
    barrier_steps: jnp.ndarray # [] i32 — supersteps run by barrier ticks
                               #   (drive()/drain: compute is blocked)

    # --- flight recorder (core/recorder.py; cfg.flight_recorder) ---------
    # Fixed-size per-rank ring of scheduling events stamped with the
    # cumulative epoch clock; ``fr_count`` is the total appended (ring
    # index = count % recorder_len) and ``fr_kinds`` keeps wrap-proof
    # per-kind cumulative counters that reconcile with the scheduler's
    # own counters (see recorder.py).  All i32 — they ride the f32
    # bitcast of device_api.encode_state unchanged.
    fr_kind: jnp.ndarray       # [FR] i32 — event kind (-1 = empty slot)
    fr_coll: jnp.ndarray       # [FR] i32 — stage/collective id
    fr_step: jnp.ndarray       # [FR] i32 — epoch-clock stamp
    fr_count: jnp.ndarray      # [] i32 — events appended (monotonic)
    fr_kinds: jnp.ndarray      # [N_EVENT_KINDS] i32 — cumulative per kind


def init_state(cfg: OcclConfig, per_rank: bool = True,
               sharding=None) -> DaemonState:
    """Fresh state; leading rank axis added when ``per_rank``.

    ``sharding`` (mesh backend) is a ``NamedSharding`` placing the leading
    rank axis on the mesh's rank axis: every [R, ...] leaf is device_put
    per shard at creation, so the state is device-resident and sharded
    BEFORE the first daemon launch or staging flush — no full-array
    single-device hop on first use."""
    C, K, L = cfg.max_colls, cfg.conn_depth, cfg.max_comms
    B = cfg.burst_slices
    SQL, CQL, H, SL = cfg.sq_len, cfg.cq_len, cfg.heap_elems, cfg.slice_elems
    dt = jnp.dtype(cfg.dtype)

    def z(shape, dtype=jnp.int32, fill=0):
        a = jnp.full(shape, fill, dtype)
        return a

    pad = heap_scratch_elems(cfg)
    s = DaemonState(
        heap_in=z((H + pad,), dt),
        heap_out=z((H + pad,), dt),
        head=z((C,)), tail_mirror=z((C,)), head_mirror=z((C,)), tail=z((C,)),
        payload=z((C, K, SL), dt),
        tq_active=z((C,), jnp.bool_, False),
        arrival=z((C,)),
        prio=z((C,)),
        cur=z((L,), jnp.int32, -1),
        ctx_step=z((C,)), ctx_slice=z((C,)), ctx_round=z((C,)),
        spin=z((C,)), boost=z((C,)),
        in_off=z((C,)), out_off=z((C,)),
        sq_coll=z((SQL,), jnp.int32, -1), sq_prio=z((SQL,)),
        sq_in=z((SQL,), jnp.int32, -1), sq_out=z((SQL,), jnp.int32, -1),
        sq_size=z(()), sq_read=z(()),
        cq_coll=z((CQL,), jnp.int32, -1), cq_count=z(()),
        inflight=z((C,), jnp.bool_, False),
        mb_fwd_count=z((L,)),
        mb_fwd_coll=z((L,)),
        mb_fwd_payload=z((L, B, SL), dt),
        mb_rev_count=z((L,)),
        mb_rev_coll=z((L,)),
        completed=z((C,)), stage_completions=z((C,)),
        preempts=z((C,)), stall_slices=z((C,)),
        qlen_at_fetch=z((C,)),
        supersteps=z(()), launch_steps=z(()), epoch=z(()), no_prog=z(()),
        made_prog_prev=z((), jnp.bool_, False),
        slices_moved=z(()),
        global_live=z((), jnp.bool_, True),
        fetch_step=z((C,)), rtc_latency=z((C,)), rtc_events=z((C,)),
        tick_calls=z(()), overlap_steps=z(()), barrier_steps=z(()),
        fr_kind=z((cfg.recorder_len,), jnp.int32, -1),
        fr_coll=z((cfg.recorder_len,), jnp.int32, -1),
        fr_step=z((cfg.recorder_len,)),
        fr_count=z(()),
        fr_kinds=z((N_EVENT_KINDS,)),
    )
    if per_rank:
        s = s._replace(
            **{
                f: jnp.broadcast_to(v, (cfg.n_ranks,) + v.shape).copy()
                for f, v in s._asdict().items()
            }
        )
        if sharding is not None:
            import jax

            s = jax.tree_util.tree_map(
                lambda a: jax.device_put(a, sharding), s)
    return s
