"""Mamba2 / SSD (state-space duality) mixer [arXiv:2405.21060].

Chunked dual form: within chunks of length Q the recurrence is evaluated
as masked attention-like matmuls (MXU-friendly); across chunks a small
scan carries the [H, N, P] state.  Decode is the O(1) recurrent step.

Layout: d_inner = expand * d_model; H = d_inner / headdim heads of dim P;
B/C have G groups shared by H/G heads (GQA-like); state size N.

Projections are SPLIT (z, x, B, C, dt) rather than fused as in the
reference CUDA implementation: tensor parallelism shards z/x/dt on heads
(d_inner) and replicates the small B/C projections — a fused projection
would cut shard boundaries through the z|x|B|C|dt split points.
(Hardware adaptation note in DESIGN.md.)
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as PSpec

from .layers import ninit, rms_norm, u_scan


def _hs(x, spec):
    """Head-sharding constraint for the intra-chunk SSD tensors
    (REPRO_SSM_SHARD_HEADS=1; no-op without an ambient mesh).  §Perf: the
    [B,nc,Q,Q,H] decay/score tensors otherwise replicate on the model
    axis and dominate per-device memory."""
    if os.environ.get("REPRO_SSM_SHARD_HEADS") != "1":
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def init_ssm_block(root, path, cfg, dtype):
    D, din = cfg.d_model, cfg.d_inner
    H, P, G, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_groups, cfg.ssm_state
    GN = G * N
    K = cfg.ssm_dconv
    return {
        "norm": jnp.zeros((D,), dtype),
        "z_proj": ninit(root, f"{path}/z", (D, din), 0.02, dtype),
        "x_proj": ninit(root, f"{path}/x", (D, din), 0.02, dtype),
        "B_proj": ninit(root, f"{path}/B", (D, GN), 0.02, dtype),
        "C_proj": ninit(root, f"{path}/C", (D, GN), 0.02, dtype),
        "dt_proj": ninit(root, f"{path}/dt", (D, H), 0.02, dtype),
        "conv_x_w": ninit(root, f"{path}/cx", (K, din), 0.2, dtype),
        "conv_x_b": jnp.zeros((din,), dtype),
        "conv_B_w": ninit(root, f"{path}/cB", (K, GN), 0.2, dtype),
        "conv_B_b": jnp.zeros((GN,), dtype),
        "conv_C_w": ninit(root, f"{path}/cC", (K, GN), 0.2, dtype),
        "conv_C_b": jnp.zeros((GN,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dtype),
        "D_skip": jnp.ones((H,), dtype),
        "dt_bias": jnp.full((H,), np.log(np.e - 1), dtype),
        "gate_norm": jnp.zeros((din,), dtype),
        "out_proj": ninit(root, f"{path}/out", (din, D),
                          0.02 / np.sqrt(2 * cfg.n_layers), dtype),
    }


def _causal_conv(u, w, b, state=None):
    """Depthwise causal conv1d + silu over [B, S, C] (d_conv taps).

    state: trailing (d_conv - 1) inputs from the previous call (decode).
    Returns (activated output, new state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros_like(u[:, : K - 1])
    else:
        pad = state.astype(u.dtype)
    up = jnp.concatenate([pad, u], axis=1)
    out = sum(up[:, i:i + u.shape[1]] * w[i] for i in range(K))
    new_state = up[:, -(K - 1):]
    return jax.nn.silu(out + b), new_state


def _project(cfg, params, x):
    h = rms_norm(x, params["norm"])
    z = h @ params["z_proj"]
    xr = h @ params["x_proj"]
    Br = h @ params["B_proj"]
    Cr = h @ params["C_proj"]
    dt = h @ params["dt_proj"]
    return z, xr, Br, Cr, dt


def ssd_forward(cfg, params, x):
    """Train/prefill path.  x: [B, S, D] -> (x', (ssm_state, conv_states))."""
    B_, S, D = x.shape
    H, P, G, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_groups, cfg.ssm_state
    Q = min(cfg.ssm_chunk, S)
    assert S % Q == 0, "pad sequence to a multiple of ssm_chunk"
    nc = S // Q

    z, xr, Br, Cr, dt = _project(cfg, params, x)
    xr, cvx = _causal_conv(xr, params["conv_x_w"], params["conv_x_b"])
    Br, cvB = _causal_conv(Br, params["conv_B_w"], params["conv_B_b"])
    Cr, cvC = _causal_conv(Cr, params["conv_C_w"], params["conv_C_b"])

    xin = xr.reshape(B_, S, H, P)
    Bmat = Br.reshape(B_, S, G, N)
    Cmat = Cr.reshape(B_, S, G, N)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # [B,S,H]
    A = -jnp.exp(params["A_log"].astype(jnp.float32))              # [H]

    # chunked SSD — reshape to [B, nc, Q, ...]
    xc = xin.reshape(B_, nc, Q, H, P).astype(jnp.float32)
    Bc = Bmat.reshape(B_, nc, Q, G, N).astype(jnp.float32)
    Cc = Cmat.reshape(B_, nc, Q, G, N).astype(jnp.float32)
    dtc = dt.reshape(B_, nc, Q, H)
    rep = H // G

    dA = dtc * A                                          # [B,nc,Q,H]
    cum = jnp.cumsum(dA, axis=2)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # [B,nc,Qq,Qk,H]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    # mask BEFORE exp: i<j entries are positive and overflow; masking after
    # leaks NaN through the backward pass (0 * inf).
    seg = jnp.where(causal[None, None, :, :, None], seg, -jnp.inf)
    L = _hs(jnp.exp(seg), PSpec(None, None, None, None, "model"))

    # intra-chunk: y_i += sum_j (C_i . B_j) L_ij dt_j x_j
    Bh = jnp.repeat(Bc, rep, axis=3)                      # [B,nc,Q,H,N]
    Ch = jnp.repeat(Cc, rep, axis=3)
    cb = _hs(jnp.einsum("bcqhn,bckhn->bcqkh", Ch, Bh),
             PSpec(None, None, None, None, "model"))
    w = _hs(cb * L * dtc[:, :, None, :, :],
            PSpec(None, None, None, None, "model"))
    y = jnp.einsum("bcqkh,bckhp->bcqhp", w, xc)

    # chunk states: S_c = sum_j exp(cum_Q - cum_j) dt_j B_j x_j^T
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)          # [B,nc,Q,H]
    sb = Bh * (dtc * decay_out)[..., None]                # [B,nc,Q,H,N]
    chunk_state = jnp.einsum("bcqhn,bcqhp->bchnp", sb, xc)

    # inter-chunk scan: state_{c+1} = exp(sum dA_c) state_c + S_c
    chunk_decay = jnp.exp(cum[:, :, -1, :])               # [B,nc,H]

    def scan_fn(state, inp):
        dec, s_c = inp
        new = state * dec[:, :, None, None] + s_c
        return new, state  # emit state ENTERING the chunk

    final_state, states_in = u_scan(
        scan_fn,
        jnp.zeros((B_, H, N, P), jnp.float32),
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(chunk_state, 1, 0)))
    states_in = jnp.moveaxis(states_in, 0, 1)             # [B,nc,H,N,P]

    # inter-chunk contribution: y_i += C_i . (exp(cum_i) * state_in)
    y = y + jnp.einsum("bcqhn,bchnp->bcqhp",
                       Ch * jnp.exp(cum)[..., None], states_in)

    y = y.reshape(B_, S, H, P)
    y = y + params["D_skip"].astype(jnp.float32)[None, None, :, None] \
        * xc.reshape(B_, S, H, P)
    y = y.reshape(B_, S, cfg.d_inner)
    y = rms_norm(y.astype(x.dtype), params["gate_norm"]) * jax.nn.silu(z)
    out = y @ params["out_proj"]
    return x + out, (final_state, (cvx, cvB, cvC))


def ssd_decode_step(cfg, params, x, state):
    """O(1) recurrent step.  x: [B, 1, D]; state = (ssm [B,H,N,P] f32,
    (conv_x, conv_B, conv_C) trailing inputs)."""
    ssm_state, (cvx, cvB, cvC) = state
    B_ = x.shape[0]
    H, P, G, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_groups, cfg.ssm_state

    z, xr, Br, Cr, dt = _project(cfg, params, x)
    xr, cvx = _causal_conv(xr, params["conv_x_w"], params["conv_x_b"], cvx)
    Br, cvB = _causal_conv(Br, params["conv_B_w"], params["conv_B_b"], cvB)
    Cr, cvC = _causal_conv(Cr, params["conv_C_w"], params["conv_C_b"], cvC)

    xin = xr.reshape(B_, H, P)
    Bv = Br.reshape(B_, G, N)
    Cv = Cr.reshape(B_, G, N)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))[:, 0]  # [B,H]
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    rep = H // G
    Bh = jnp.repeat(Bv, rep, axis=1).astype(jnp.float32)  # [B,H,N]
    Ch = jnp.repeat(Cv, rep, axis=1).astype(jnp.float32)

    decay = jnp.exp(dt * A)                               # [B,H]
    xf = xin.astype(jnp.float32)
    ssm_state = (ssm_state * decay[:, :, None, None]
                 + jnp.einsum("bhn,bhp->bhnp", Bh * dt[..., None], xf))
    y = jnp.einsum("bhn,bhnp->bhp", Ch, ssm_state)
    y = y + params["D_skip"].astype(jnp.float32)[None, :, None] * xf
    y = y.reshape(B_, 1, cfg.d_inner)
    y = rms_norm(y.astype(x.dtype), params["gate_norm"]) * jax.nn.silu(z)
    return x + y @ params["out_proj"], (ssm_state, (cvx, cvB, cvC))
