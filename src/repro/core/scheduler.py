"""Per-rank daemon superstep: the core of the DFCE-framework (paper Sec. 3.1).

One superstep, per rank:
  A. apply arriving connector messages (slice commits + credits);
  B. maybe fetch one SQE (order policy controls eagerness, Sec. 3.2);
  C. per lane: select the current collective (two-phase blocking), gate one
     slice move of its current primitive on connector state, execute or
     spin/preempt (spin thresholds + stickiness, Sec. 3.2);
  D. bookkeeping for voluntary quit (Sec. 3.1.3).

Everything is branch-free fixed-shape array code so the loop compiles into
a single long-running XLA program — the daemon-kernel analogue.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import OcclConfig, OrderPolicy, ReduceOp
from . import primitives as P
from .primitives import Prim
from .state import DaemonState

# Queue-key stride between priority classes (arrival stays below this).
_BIG = jnp.int32(1 << 20)

# Primitive action-flag lookups as device arrays (indexable by tracers).
PRIM_RECV = jnp.asarray(P.PRIM_RECV)
PRIM_SEND = jnp.asarray(P.PRIM_SEND)
PRIM_REDUCE = jnp.asarray(P.PRIM_REDUCE)
PRIM_COPY = jnp.asarray(P.PRIM_COPY)
PRIM_READS_IN = jnp.asarray(P.PRIM_READS_IN)


class SharedTables(NamedTuple):
    """Rank-independent static context (vmap in_axes=None)."""

    registered: jnp.ndarray   # [C] bool
    kind: jnp.ndarray         # [C]
    op: jnp.ndarray           # [C]
    lane: jnp.ndarray         # [C]
    n_steps: jnp.ndarray      # [C]
    n_slices: jnp.ndarray     # [C]
    n_rounds: jnp.ndarray     # [C]
    in_chunked: jnp.ndarray   # [C]
    out_chunked: jnp.ndarray  # [C]
    base_in_off: jnp.ndarray  # [C]
    base_out_off: jnp.ndarray # [C]


class LocalTables(NamedTuple):
    """Per-rank static context (vmap in_axes=0)."""

    member: jnp.ndarray       # [C] bool
    prog_kind: jnp.ndarray    # [C, S]
    prog_chunk: jnp.ndarray   # [C, S]


class Mailbox(NamedTuple):
    """Per-lane connector traffic for one superstep (fwd data + rev credit)."""

    fwd_valid: jnp.ndarray    # [L] bool
    fwd_coll: jnp.ndarray     # [L] i32
    fwd_payload: jnp.ndarray  # [L, SLICE]
    rev_valid: jnp.ndarray    # [L] bool
    rev_coll: jnp.ndarray     # [L] i32


def empty_mailbox(cfg: OcclConfig) -> Mailbox:
    L, SL = cfg.max_comms, cfg.slice_elems
    return Mailbox(
        fwd_valid=jnp.zeros((L,), jnp.bool_),
        fwd_coll=jnp.zeros((L,), jnp.int32),
        fwd_payload=jnp.zeros((L, SL), jnp.dtype(cfg.dtype)),
        rev_valid=jnp.zeros((L,), jnp.bool_),
        rev_coll=jnp.zeros((L,), jnp.int32),
    )


def _combine(op: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Apply the collective's reduction (static-context ``op``)."""
    return jax.lax.switch(
        jnp.clip(op, 0, 3),
        [
            lambda x, y: x + y,
            jnp.maximum,
            jnp.minimum,
            lambda x, y: x * y,
        ],
        a,
        b,
    )


def _queue_keys(cfg, st, shared, local, lane):
    """Ascending queue-order key per collective for this lane (front = min)."""
    eligible = st.tq_active & local.member & (shared.lane == lane)
    key = st.arrival
    if cfg.demand_steering:
        # Data already waiting in the recv connector => ring peers are on
        # this collective; steering toward it is the fastest decentralized
        # gang-convergence signal available (beyond-paper policy).
        demand = (st.tail < st.head_mirror).astype(jnp.int32)
        key = key - demand * (jnp.int32(1) << 18)
    if cfg.order_policy == OrderPolicy.PRIORITY:
        # Higher priority first; FIFO (+demand) within equal priority.
        key = (-st.prio) * _BIG + key
    key = jnp.where(eligible, key, jnp.iinfo(jnp.int32).max)
    return eligible, key


def _positions(eligible, key):
    """Task-queue position of each eligible collective (0 = front)."""
    pos = jnp.sum(
        (key[None, :] < key[:, None])
        | ((key[None, :] == key[:, None])
           & (jnp.arange(key.shape[0])[None, :] < jnp.arange(key.shape[0])[:, None])),
        axis=1,
    ).astype(jnp.int32)
    return jnp.where(eligible, pos, jnp.int32(0))


def _thresholds(cfg, st, eligible, pos):
    """Effective spin thresholds (stickiness scheme, Sec. 3.2)."""
    if cfg.stickiness:
        base = cfg.spin_base - pos * cfg.spin_decr + st.boost
    else:
        base = jnp.full_like(pos, cfg.spin_base)
    return jnp.clip(base, cfg.spin_min, cfg.spin_max)


def apply_inbox(cfg: OcclConfig, st: DaemonState, inbox: Mailbox) -> DaemonState:
    """Phase A: commit arriving slices into the recv-connector mirror and
    arriving credits into the send-side tail mirror."""
    K = cfg.conn_depth
    head_mirror, tail_mirror, payload = st.head_mirror, st.tail_mirror, st.payload
    for lane in range(cfg.max_comms):
        c = inbox.fwd_coll[lane]
        v = inbox.fwd_valid[lane]
        slot = head_mirror[c] % K
        payload = payload.at[c, slot].set(
            jnp.where(v, inbox.fwd_payload[lane], payload[c, slot])
        )
        head_mirror = head_mirror.at[c].add(jnp.where(v, 1, 0))
        rc = inbox.rev_coll[lane]
        rv = inbox.rev_valid[lane]
        tail_mirror = tail_mirror.at[rc].add(jnp.where(rv, 1, 0))
    return st._replace(
        head_mirror=head_mirror, tail_mirror=tail_mirror, payload=payload
    )


def fetch_sqe(cfg: OcclConfig, st: DaemonState, shared: SharedTables,
              local: LocalTables) -> tuple[DaemonState, jnp.ndarray]:
    """Phase B: pop at most one SQE into the task queue (paper Sec. 3.1.2).

    FIFO policy fetches lazily (queue empty or stuck); PRIORITY fetches
    eagerly every superstep (paper: "checking the SQ more frequently").
    """
    has_sqe = st.sq_read < st.sq_size
    if cfg.order_policy == OrderPolicy.PRIORITY:
        want = has_sqe
    else:
        stuck_or_empty = (~st.made_prog_prev) | (~jnp.any(st.tq_active))
        want = has_sqe & stuck_or_empty
    slot = jnp.clip(st.sq_read, 0, cfg.sq_len - 1)
    c = st.sq_coll[slot]
    # Head-of-line wait: a re-submission of an in-flight collective waits
    # (the runtime never has two executions of one collective concurrently).
    ok = want & (c >= 0) & ~st.inflight[c] & local.member[c] & shared.registered[c]
    qlen = jnp.sum(st.tq_active).astype(jnp.int32)
    one = jnp.where(ok, 1, 0)
    st = st._replace(
        tq_active=st.tq_active.at[c].set(jnp.where(ok, True, st.tq_active[c])),
        inflight=st.inflight.at[c].set(jnp.where(ok, True, st.inflight[c])),
        arrival=st.arrival.at[c].set(
            jnp.where(ok, st.supersteps, st.arrival[c])),
        prio=st.prio.at[c].set(jnp.where(
            ok, jnp.clip(st.sq_prio[slot], -512, 512), st.prio[c])),
        in_off=st.in_off.at[c].set(jnp.where(
            ok,
            jnp.where(st.sq_in[slot] >= 0, st.sq_in[slot], shared.base_in_off[c]),
            st.in_off[c])),
        out_off=st.out_off.at[c].set(jnp.where(
            ok,
            jnp.where(st.sq_out[slot] >= 0, st.sq_out[slot], shared.base_out_off[c]),
            st.out_off[c])),
        ctx_step=st.ctx_step.at[c].set(jnp.where(ok, 0, st.ctx_step[c])),
        ctx_slice=st.ctx_slice.at[c].set(jnp.where(ok, 0, st.ctx_slice[c])),
        ctx_round=st.ctx_round.at[c].set(jnp.where(ok, 0, st.ctx_round[c])),
        spin=st.spin.at[c].set(jnp.where(ok, 0, st.spin[c])),
        boost=st.boost.at[c].set(jnp.where(ok, 0, st.boost[c])),
        qlen_at_fetch=st.qlen_at_fetch.at[c].set(
            jnp.where(ok, qlen, st.qlen_at_fetch[c])),
        sq_read=st.sq_read + one,
    )
    return st, ok


def lane_step(cfg: OcclConfig, st: DaemonState, shared: SharedTables,
              local: LocalTables, lane: int
              ) -> tuple[DaemonState, jnp.ndarray, jnp.ndarray, jnp.ndarray,
                         jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Phase C for one lane: two-phase-blocking selection + one slice move.

    Returns (state, moved, fwd_valid, fwd_coll, fwd_payload, rev_valid,
    rev_coll).
    """
    K, SL = cfg.conn_depth, cfg.slice_elems
    C = cfg.max_colls

    eligible, key = _queue_keys(cfg, st, shared, local, lane)
    pos = _positions(eligible, key)
    thr = _thresholds(cfg, st, eligible, pos)

    cur = st.cur[lane]
    cur_ok = (cur >= 0) & eligible[jnp.clip(cur, 0, C - 1)]
    cur_c = jnp.clip(cur, 0, C - 1)
    overspun = cur_ok & (st.spin[cur_c] > thr[cur_c])
    if cfg.priority_preempts:
        higher = jnp.any(eligible & (st.prio > st.prio[cur_c]))
        overspun = overspun | (cur_ok & higher)

    # Preempt: context switch — dynamic context stays in the context buffer
    # (it already lives in ctx_* arrays: the lazy-saving optimization of
    # Sec. 4 is structural here), rotate to the back of the queue.
    st = st._replace(
        preempts=st.preempts.at[cur_c].add(jnp.where(overspun, 1, 0)),
        arrival=st.arrival.at[cur_c].set(
            jnp.where(overspun, st.supersteps + 1, st.arrival[cur_c])),
        spin=st.spin.at[cur_c].set(jnp.where(overspun, 0, st.spin[cur_c])),
        boost=st.boost.at[cur_c].set(jnp.where(overspun, 0, st.boost[cur_c])),
    )
    keep = cur_ok & ~overspun

    # Queue front after a possible rotation.
    eligible, key = _queue_keys(cfg, st, shared, local, lane)
    front = jnp.argmin(key).astype(jnp.int32)
    any_eligible = jnp.any(eligible)
    cand = jnp.where(keep, cur, jnp.where(any_eligible, front, -1))
    c = jnp.clip(cand, 0, C - 1)
    valid = cand >= 0

    # --- gate one slice move of the current primitive --------------------
    step = jnp.clip(st.ctx_step[c], 0, local.prog_kind.shape[1] - 1)
    prim = local.prog_kind[c, step]
    chunk = local.prog_chunk[c, step]
    sl = st.ctx_slice[c]
    needs_recv = PRIM_RECV[prim] > 0
    needs_send = PRIM_SEND[prim] > 0
    does_reduce = PRIM_REDUCE[prim] > 0
    does_copy = PRIM_COPY[prim] > 0
    reads_in = PRIM_READS_IN[prim] > 0

    can_recv = st.tail[c] < st.head_mirror[c]
    can_send = (st.head[c] - st.tail_mirror[c]) < K
    gate = valid & (prim != Prim.NULL) & \
        (~needs_recv | can_recv) & (~needs_send | can_send)

    # --- execute the fused actions (paper Fig. 3) ------------------------
    recv_val = st.payload[c, st.tail[c] % K]
    nsl = shared.n_slices[c]
    rnd = st.ctx_round[c]
    chunk_stride = shared.n_rounds[c] * nsl * SL   # padded chunk extent
    within = (rnd * nsl + sl) * SL                 # (round, slice) offset
    in_base = (st.in_off[c]
               + jnp.where(shared.in_chunked[c] > 0, chunk, 0) * chunk_stride
               + within)
    out_base = (st.out_off[c]
                + jnp.where(shared.out_chunked[c] > 0, chunk, 0) * chunk_stride
                + within)
    in_val = jax.lax.dynamic_slice(st.heap_in, (in_base,), (SL,))
    if cfg.use_pallas:
        from ..kernels import ops as kops
        value = kops.fused_primitive(
            recv_val, in_val, shared.op[c],
            needs_recv, does_reduce, reads_in)
    else:
        reduced = _combine(shared.op[c], recv_val, in_val)
        value = jnp.where(
            does_reduce, reduced,
            jnp.where(needs_recv, recv_val,
                      jnp.where(reads_in, in_val, jnp.zeros_like(in_val))))

    write_out = gate & does_copy
    new_heap_out = jax.lax.dynamic_update_slice(
        st.heap_out, value.astype(st.heap_out.dtype), (out_base,))
    heap_out = jax.lax.select(write_out, new_heap_out, st.heap_out)

    did_recv = gate & needs_recv
    did_send = gate & needs_send

    # --- advance the dynamic context (round, primitive, slice) -----------
    nslices = shared.n_slices[c]
    new_slice = sl + 1
    step_done = gate & (new_slice >= nslices)
    seq_done = step_done & (st.ctx_step[c] + 1 >= shared.n_steps[c])
    next_step = jnp.where(
        seq_done, 0,
        jnp.where(step_done, st.ctx_step[c] + 1, st.ctx_step[c]))
    next_slice = jnp.where(gate, jnp.where(step_done, 0, new_slice), sl)
    next_round = jnp.where(seq_done, rnd + 1, rnd)
    coll_done = seq_done & (next_round >= shared.n_rounds[c])

    st = st._replace(
        heap_out=heap_out,
        tail=st.tail.at[c].add(jnp.where(did_recv, 1, 0)),
        head=st.head.at[c].add(jnp.where(did_send, 1, 0)),
        ctx_step=st.ctx_step.at[c].set(jnp.where(gate, next_step, st.ctx_step[c])),
        ctx_slice=st.ctx_slice.at[c].set(next_slice),
        ctx_round=st.ctx_round.at[c].set(next_round),
        spin=st.spin.at[c].set(
            jnp.where(gate, 0, jnp.where(valid, st.spin[c] + 1, st.spin[c]))),
        # Stickiness: a successful primitive boosts its successors' spin
        # thresholds (gang-convergence pressure, Sec. 3.2).
        boost=st.boost.at[c].add(
            jnp.where(step_done & ~coll_done & jnp.bool_(cfg.stickiness),
                      cfg.spin_boost, 0)),
        slices_moved=st.slices_moved + jnp.where(gate, 1, 0),
    )

    # --- completion: write the CQE (paper Sec. 3.1.2) ---------------------
    cq_slot = jnp.clip(st.cq_count, 0, cfg.cq_len - 1)
    st = st._replace(
        tq_active=st.tq_active.at[c].set(
            jnp.where(coll_done, False, st.tq_active[c])),
        inflight=st.inflight.at[c].set(
            jnp.where(coll_done, False, st.inflight[c])),
        completed=st.completed.at[c].add(jnp.where(coll_done, 1, 0)),
        cq_coll=st.cq_coll.at[cq_slot].set(
            jnp.where(coll_done, c, st.cq_coll[cq_slot])),
        cq_count=st.cq_count + jnp.where(coll_done, 1, 0),
        cur=st.cur.at[lane].set(jnp.where(coll_done | ~valid, -1, cand)),
    )

    fwd_payload = value.astype(st.payload.dtype)
    return st, gate, did_send, c, fwd_payload, did_recv, c


def rank_superstep(cfg: OcclConfig, shared: SharedTables, local: LocalTables,
                   st: DaemonState, inbox: Mailbox
                   ) -> tuple[DaemonState, Mailbox]:
    """One full superstep for one rank."""
    st = apply_inbox(cfg, st, inbox)
    st, fetched = fetch_sqe(cfg, st, shared, local)

    L, SL = cfg.max_comms, cfg.slice_elems
    fwd_valid, fwd_coll, rev_valid, rev_coll = [], [], [], []
    fwd_payload = []
    moved_any = jnp.bool_(False)
    for lane in range(L):
        st, moved, fv, fc, fp, rv, rc = lane_step(cfg, st, shared, local, lane)
        moved_any = moved_any | moved
        fwd_valid.append(fv)
        fwd_coll.append(fc)
        fwd_payload.append(fp)
        rev_valid.append(rv)
        rev_coll.append(rc)

    progress = moved_any | fetched
    st = st._replace(
        supersteps=st.supersteps + 1,
        no_prog=jnp.where(progress, 0, st.no_prog + 1),
        made_prog_prev=moved_any,
    )
    outbox = Mailbox(
        fwd_valid=jnp.stack(fwd_valid),
        fwd_coll=jnp.stack(fwd_coll),
        fwd_payload=jnp.stack(fwd_payload),
        rev_valid=jnp.stack(rev_valid),
        rev_coll=jnp.stack(rev_coll),
    )
    return st, outbox
