"""Reliability tentpole: collective handles, elastic shrink, detection.

Covers the redesigned handle API (``register()`` -> CollectiveHandle,
int shims intact), the unified error taxonomy, ``evict()``'s
drain -> rebuild -> replay lifecycle — including the acceptance scenario:
killing one rank mid-training at R=8 shrinks to R=7 in one relaunch with
grad-sync results bit-identical to a fresh 7-rank runtime — and the
straggler-detector -> diagnose -> evict e2e loop.
"""
import warnings

import numpy as np
import pytest

from repro.core import (CollKind, CollectiveHandle, OcclConfig, OcclRuntime)
from repro.core import errors as core_errors
from repro.core.errors import DeadlockTimeout, EvictionError
from repro.fabric.ft import ReliabilityController, StepTimeout
from repro.fabric.straggler import StragglerDetector


def _cfg(R, **kw):
    kw.setdefault("max_colls", 12)
    kw.setdefault("max_comms", 4)
    kw.setdefault("slice_elems", 8)
    kw.setdefault("heap_elems", 1 << 13)
    return OcclConfig(n_ranks=R, **kw)


def _payloads(R, n, seed=0):
    # Integer-valued floats: reductions are EXACT in f32 regardless of
    # ring order, so bit-equality assertions stay meaningful.
    rng = np.random.RandomState(seed)
    return {r: rng.randint(0, 1 << 10, n).astype(np.float32)
            for r in range(R)}


# ---------------------------------------------------------------------------
# satellite 1: the handle API (+ int shims)
# ---------------------------------------------------------------------------
def test_register_returns_int_compatible_handle():
    R = 4
    rt = OcclRuntime(_cfg(R))
    h = rt.register(CollKind.ALL_REDUCE, rt.communicator(range(R)),
                    n_elems=32)
    assert isinstance(h, CollectiveHandle) and isinstance(h, int)
    assert h == 0 and h.reg_index == 0 and h.alive
    data = _payloads(R, 32)
    for r in range(R):
        h.submit(r, data=data[r])
    rt.drive()
    ref = sum(data.values())
    for r in range(R):
        np.testing.assert_array_equal(h.read(r), ref)
    cs = h.stats()
    assert cs["coll_id"] == 0 and cs["stages"] == [0]
    assert int(cs["completed"].sum()) == R


def test_int_coll_id_paths_still_work():
    """The deprecated thin shim: every boundary accepts the bare int."""
    R = 4
    rt = OcclRuntime(_cfg(R))
    h = rt.register(CollKind.ALL_REDUCE, rt.communicator(range(R)),
                    n_elems=16)
    cid = int(h)          # strip the handle
    data = _payloads(R, 16)
    for r in range(R):
        rt.write_input(r, cid, data[r])
        rt.submit(r, cid)
    rt.drive()
    ref = sum(data.values())
    np.testing.assert_array_equal(rt.read_output(2, cid), ref)
    got = rt.read_outputs_bulk([(r, cid) for r in range(R)])
    np.testing.assert_array_equal(got[(0, cid)], ref)


def test_write_read_via_handle_methods():
    R = 2
    rt = OcclRuntime(_cfg(R, max_comms=1))
    h = rt.register(CollKind.ALL_REDUCE, rt.communicator(range(R)),
                    n_elems=16)
    data = _payloads(R, 16)
    for r in range(R):
        h.write(r, data[r])
        h.submit(r)
    rt.drive()
    np.testing.assert_array_equal(h.read(1), data[0] + data[1])


def test_submit_all_on_handle():
    R = 4
    rt = OcclRuntime(_cfg(R))
    h = rt.register(CollKind.ALL_REDUCE, rt.communicator(range(R)),
                    n_elems=16)
    data = _payloads(R, 16)
    fired = []
    h.submit_all(data=data, callback=lambda r, c: fired.append((r, c)))
    rt.drive()
    np.testing.assert_array_equal(h.read(0), sum(data.values()))
    assert sorted(fired) == [(r, 0) for r in range(R)]


# ---------------------------------------------------------------------------
# satellite 2: unified error taxonomy
# ---------------------------------------------------------------------------
def test_error_taxonomy_identity():
    """Historic import paths resolve to the SAME classes as core.errors."""
    from repro.core.runtime import (ConnDepthWarning, DeadlockTimeout as D,
                                    RegistrationClosed)
    assert D is core_errors.DeadlockTimeout
    assert RegistrationClosed is core_errors.RegistrationClosed
    assert ConnDepthWarning is core_errors.ConnDepthWarning
    assert StepTimeout is core_errors.StepTimeout


def test_deadlock_timeout_carries_flight_record():
    R = 2
    rt = OcclRuntime(_cfg(R, max_comms=1))
    h = rt.register(CollKind.ALL_REDUCE, rt.communicator(range(R)),
                    n_elems=16)
    h.submit(0, data=np.ones(16, np.float32))   # rank 1 never submits
    with pytest.raises(DeadlockTimeout) as ei:
        rt.drive(max_launches=3)
    e = ei.value
    assert e.flight_record is not None and e.flight_record["enabled"]
    assert e.diagnosis is not None and e.diagnosis.holders == [1]


def test_registration_closed_after_first_launch():
    R = 2
    rt = OcclRuntime(_cfg(R, max_comms=2))
    comm = rt.communicator(range(R))
    h = rt.register(CollKind.ALL_REDUCE, comm, n_elems=16)
    h.submit_all(data=_payloads(R, 16))
    rt.drive()
    with pytest.raises(core_errors.RegistrationClosed):
        rt.register(CollKind.ALL_REDUCE, comm, n_elems=16)


# ---------------------------------------------------------------------------
# tentpole: elastic shrink
# ---------------------------------------------------------------------------
def test_evict_flat_bit_equal_to_fresh():
    """Kill rank 1 mid-flight at R=4; the shrunk runtime's outputs and
    superstep count are bit-identical to a fresh 3-rank runtime driving
    the same submissions."""
    R, n = 4, 32
    data = _payloads(R, n)
    rt = OcclRuntime(_cfg(R))
    h = rt.register(CollKind.ALL_REDUCE, rt.communicator(range(R)),
                    n_elems=n)
    for r in range(R):          # round 1 completes everywhere
        h.submit(r, data=data[r])
    rt.drive()
    for r in (0, 2, 3):         # round 2 wedges: rank 1 is dead
        h.submit(r, data=data[r])
    report = rt.evict(1)
    assert report["n_ranks"] == 3 and report["replayed"] == 3
    assert h.alive and h.coll_id == 0

    fresh = OcclRuntime(_cfg(3))
    hf = fresh.register(CollKind.ALL_REDUCE, fresh.communicator(range(3)),
                        n_elems=n)
    for i, old in enumerate((0, 2, 3)):
        hf.submit(i, data=data[old])
    fresh.drive()
    for new_r in range(3):
        np.testing.assert_array_equal(h.read(new_r), hf.read(new_r))
    assert (int(np.asarray(rt.state.supersteps).max())
            == int(np.asarray(fresh.state.supersteps).max()))


def test_evict_two_level_r8_to_r7():
    """The acceptance scenario: two-level composite grad-sync bucket at
    R=8, rank 5 dies mid-round, one evict -> R=7 (prime: the replay
    re-derives hierarchy (7, 1), whose single-member groups degenerate
    cleanly), results bit-identical to a fresh 7-rank runtime."""
    R, n = 8, 64
    data = _payloads(R, n)
    rt = OcclRuntime(_cfg(R))
    h = rt.register(CollKind.ALL_REDUCE,
                    rt.logical_communicator(range(R)),
                    n_elems=n, algo="two_level", hierarchy=(2, 4))
    for r in range(R):
        h.submit(r, data=data[r])
    rt.drive()
    launches_before = rt.launches
    for r in range(R):
        if r != 5:
            h.submit(r, data=data[r])
    report = rt.evict(5)
    assert report["n_ranks"] == 7 and report["replayed"] == 7
    assert h.alive
    assert rt.stats()["algos"][h.coll_id] == "two_level"

    fresh = OcclRuntime(_cfg(7))
    hf = fresh.register(CollKind.ALL_REDUCE,
                        fresh.logical_communicator(range(7)),
                        n_elems=n, algo="two_level")
    survivors = [r for r in range(R) if r != 5]
    for i, old in enumerate(survivors):
        hf.submit(i, data=data[old])
    fresh.drive()
    for new_r in range(7):
        np.testing.assert_array_equal(h.read(new_r), hf.read(new_r))
    # One-relaunch resume: the post-evict drive needs no more launches
    # than the fresh runtime's initial drive.
    assert (rt.launches - launches_before - report["drain_launches"]
            <= fresh.launches)
    assert (int(np.asarray(rt.state.supersteps).max())
            == int(np.asarray(fresh.state.supersteps).max()))


def test_evict_replays_staged_but_unlaunched():
    """Submissions staged AFTER the last launch (payload still host-side)
    are replayed from the staging queue, not the heap."""
    R, n = 4, 16
    data = _payloads(R, n)
    rt = OcclRuntime(_cfg(R))
    h = rt.register(CollKind.ALL_REDUCE, rt.communicator(range(R)),
                    n_elems=n)
    rt.state                    # build WITHOUT launching (nothing flushed)
    for r in (0, 2, 3):
        h.submit(r, data=data[r])
    report = rt.evict(1)
    assert report["replayed"] == 3 and report["drain_launches"] >= 1
    ref = data[0] + data[2] + data[3]
    for new_r in range(3):
        np.testing.assert_array_equal(h.read(new_r), ref)


def test_evict_drops_dead_ranks_submissions_and_callbacks():
    """Ranks 0, 1 and 3 submit but rank 2 never does, so the collective
    wedges WITH the dead rank 3's submission in flight.  Evicting 3
    drops its record, replays the survivors', and the late rank's
    submission after the shrink completes the collective — firing the
    replayed callbacks with post-shrink rank ids."""
    R, n = 4, 16
    data = _payloads(R, n)
    rt = OcclRuntime(_cfg(R))
    h = rt.register(CollKind.ALL_REDUCE, rt.communicator(range(R)),
                    n_elems=n)
    fired = []
    for r in (0, 1, 3):
        h.submit(r, data=data[r],
                 callback=lambda rr, cc: fired.append(rr))
    report = rt.evict(3, relaunch=False)
    assert report["dropped"] == 1 and report["replayed"] == 2
    h.submit(2, data=data[2])           # the late rank finally submits
    rt.drive()
    assert sorted(fired) == [0, 1]      # replayed callbacks, new rank ids
    np.testing.assert_array_equal(h.read(0), data[0] + data[1] + data[2])


def test_evicted_registration_raises():
    """A broadcast rooted at the evicted rank dissolves; its handle goes
    dead while sibling registrations survive."""
    R, n = 4, 16
    rt = OcclRuntime(_cfg(R))
    comm = rt.communicator(range(R))
    h_ar = rt.register(CollKind.ALL_REDUCE, comm, n_elems=n)
    h_bc = rt.register(CollKind.BROADCAST, comm, n_elems=n, root=2)
    data = _payloads(R, n)
    h_ar.submit_all(data=data)
    rt.drive()
    with pytest.warns(UserWarning, match="dissolved"):
        report = rt.evict(2)
    assert h_ar.alive and not h_bc.alive
    assert report["dissolved"] == [1]
    with pytest.raises(EvictionError):
        h_bc.submit(0, data=data[0])
    with pytest.raises(EvictionError):
        _ = h_bc.coll_id


def test_device_api_goes_stale_after_evict():
    R, n = 4, 16
    rt = OcclRuntime(_cfg(R))
    h = rt.register(CollKind.ALL_REDUCE, rt.communicator(range(R)),
                    n_elems=n)
    h.submit_all(data=_payloads(R, n))
    rt.drive()
    api = rt.device_api()
    assert not api.stale
    rt.evict(0)
    assert api.stale
    with pytest.raises(EvictionError):
        api.step_prologue(rt.state)
    api2 = rt.device_api()      # fresh snapshot binds the shrunk tables
    assert not api2.stale and api2 is not api


def test_double_evict():
    """Two successive shrinks (R=5 -> 3): handles keep resolving."""
    R, n = 5, 20
    data = _payloads(R, n)
    rt = OcclRuntime(_cfg(R))
    h = rt.register(CollKind.ALL_REDUCE, rt.communicator(range(R)),
                    n_elems=n)
    h.submit_all(data=data)
    rt.drive()
    rt.evict(1)
    rt.evict(2)                 # old rank 3 in the original numbering
    assert rt.cfg.n_ranks == 3 and h.alive
    survivors = [0, 2, 4]       # 1 evicted, then new-rank-2 (= old 3)
    h.submit_all(data={i: data[r] for i, r in enumerate(survivors)})
    rt.drive()
    np.testing.assert_array_equal(h.read(0),
                                  sum(data[r] for r in survivors))


def test_double_evict_rooted_broadcast_remaps_root():
    """The registration log rewrites its root in POST-shrink numbering:
    two consecutive evictions of a rooted collective must keep the handle
    resolving (regression: the second evict used to KeyError on the
    stale pre-shrink root) and broadcast from the renumbered source."""
    R, n = 5, 16
    data = _payloads(R, n)
    rt = OcclRuntime(_cfg(R))
    comm = rt.communicator(range(R))
    h_bc = rt.register(CollKind.BROADCAST, comm, n_elems=n, root=3)
    h_bc.submit_all(data=data)
    rt.drive()
    rt.evict(1)                 # root old-3 renumbers to 2
    rt.evict(0)                 # ... and to 1; neither kills it
    assert rt.cfg.n_ranks == 3 and h_bc.alive
    # New numbering: new0=old2, new1=old3 (the root), new2=old4.
    h_bc.submit_all(data={i: data[r] for i, r in enumerate((2, 3, 4))})
    rt.drive()
    for new_r in range(3):
        np.testing.assert_array_equal(h_bc.read(new_r), data[3])


def test_second_evict_dissolves_renumbered_root():
    """Evicting the root under its POST-shrink id must dissolve the
    rooted registration (the stale pre-shrink root numbering used to
    make the dissolve check miss it)."""
    R, n = 4, 16
    data = _payloads(R, n)
    rt = OcclRuntime(_cfg(R))
    comm = rt.communicator(range(R))
    h_ar = rt.register(CollKind.ALL_REDUCE, comm, n_elems=n)
    h_bc = rt.register(CollKind.BROADCAST, comm, n_elems=n, root=3)
    h_ar.submit_all(data=data)
    rt.drive()
    rt.evict(0)                 # root old-3 renumbers to 2
    assert h_bc.alive
    with pytest.warns(UserWarning, match="BROADCAST.*dissolved"):
        rt.evict(2)             # kills old rank 3 — the actual root
    assert h_ar.alive and not h_bc.alive
    with pytest.raises(EvictionError):
        h_bc.submit(0, data=data[0])


def test_dissolved_root_stays_dissolved_across_evicts():
    """A rooted registration dissolved by one evict is tombstoned: a
    later evict neither resurrects it nor re-warns about it."""
    R, n = 5, 16
    data = _payloads(R, n)
    rt = OcclRuntime(_cfg(R))
    comm = rt.communicator(range(R))
    h_ar = rt.register(CollKind.ALL_REDUCE, comm, n_elems=n)
    h_bc = rt.register(CollKind.BROADCAST, comm, n_elems=n, root=2)
    h_ar.submit_all(data=data)
    rt.drive()
    with pytest.warns(UserWarning, match="dissolved"):
        rt.evict(2)
    assert not h_bc.alive
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        rt.evict(0)
    assert not [w for w in caught if "dissolved" in str(w.message)]
    assert rt.cfg.n_ranks == 3 and h_ar.alive and not h_bc.alive
    h_ar.submit_all(data={i: data[r] for i, r in enumerate((1, 3, 4))})
    rt.drive()
    np.testing.assert_array_equal(h_ar.read(0),
                                  data[1] + data[3] + data[4])


def test_evict_dissolves_flat_alltoall():
    """ALL_TO_ALL payloads are R equal per-peer chunks: a pre-shrink
    layout scrambles on a smaller ring even when n_elems stays divisible,
    so evict() dissolves the registration (like the ragged variant) and
    drops its wedged replays instead of silently re-chunking them."""
    R, n = 4, 12                # 12 divides by 4 AND by 3 — the silent case
    data = _payloads(R, n)
    rt = OcclRuntime(_cfg(R))
    comm = rt.communicator(range(R))
    h_ar = rt.register(CollKind.ALL_REDUCE, comm, n_elems=n)
    h_a2a = rt.register(CollKind.ALL_TO_ALL, comm, n_elems=n)
    h_ar.submit_all(data=data)
    rt.drive()
    for r in (0, 2, 3):         # wedged round: rank 1 is dead
        h_a2a.submit(r, data=data[r])
    with pytest.warns(UserWarning, match="ALL_TO_ALL.*dissolved"):
        report = rt.evict(1)
    assert h_ar.alive and not h_a2a.alive
    assert report["dissolved"] == [1] and report["replayed"] == 0
    with pytest.raises(EvictionError):
        h_a2a.submit(0, data=data[0])


# ---------------------------------------------------------------------------
# satellite 3: detection -> diagnosis -> eviction e2e
# ---------------------------------------------------------------------------
def test_straggler_detector_collective_stats_channel():
    det = StragglerDetector(4)
    stats = {"rtc_latency": np.zeros((4, 2)), "rtc_events": np.zeros((4, 2))}
    det.observe_collective_stats(stats)          # baseline snapshot
    # Window 2: ranks 0-2 complete cheaply, rank 3 completes nothing
    # while the fleet median advances -> suspect.
    stats = {"rtc_latency": np.array([[4., 0], [4, 0], [4, 0], [0, 0]]),
             "rtc_events": np.array([[2., 0], [2, 0], [2, 0], [0, 0]])}
    det.observe_collective_stats(stats)
    assert det.suspect[3] and not det.suspect[:3].any()
    assert det.healthy_ranks() == [0, 1, 2]
    # A rank completing with far-above-median latency is flagged too.
    det2 = StragglerDetector(4)
    det2.observe_collective_stats(
        {"rtc_latency": np.array([[4.], [4.], [4.], [40.]]),
         "rtc_events": np.array([[2.], [2.], [2.], [2.]])})
    assert det2.stragglers() == [3]


def test_reliability_controller_e2e():
    """Kill rank 2 at R=6; the controller turns the DeadlockTimeout into
    a diagnosis, marks the holder suspect, evicts it via healthy_ranks()
    and the replay completes on R=5."""
    R, n = 6, 24
    data = _payloads(R, n)
    rt = OcclRuntime(_cfg(R))
    h = rt.register(CollKind.ALL_REDUCE, rt.communicator(range(R)),
                    n_elems=n)
    ctl = ReliabilityController(rt)
    for r in range(R):
        if r != 2:
            h.submit(r, data=data[r])
    try:
        rt.drive(max_launches=3)
        raise AssertionError("expected DeadlockTimeout")
    except DeadlockTimeout as e:
        ctl.observe_step({r: 0.01 for r in range(R) if r != 2})
        evicted = ctl.heal(e)
    assert evicted == [2] and rt.cfg.n_ranks == 5
    assert ctl.detector.n_ranks == 5            # detector rebuilt
    ref = sum(v for r, v in data.items() if r != 2)
    for new_r in range(5):
        np.testing.assert_array_equal(h.read(new_r), ref)


def test_heal_caps_evictions_to_keep_survivors():
    """A detector that flags the WHOLE fleet (e.g. diagnose naming every
    member of a stalled chain) must not tear the job down mid-heal:
    heal() caps the eviction list at min_survivors and defers the rest
    instead of raising EvictionError with some evictions applied."""
    R, n = 4, 16
    data = _payloads(R, n)
    rt = OcclRuntime(_cfg(R))
    h = rt.register(CollKind.ALL_REDUCE, rt.communicator(range(R)),
                    n_elems=n)
    h.submit_all(data=data)
    rt.drive()
    ctl = ReliabilityController(rt, min_survivors=2)
    for r in range(R):
        ctl.detector.mark_suspect(r)
    with pytest.warns(UserWarning, match="keeping suspect"):
        evicted = ctl.heal()
    assert evicted == [3, 2] and ctl.deferred == [0, 1]
    assert rt.cfg.n_ranks == 2 and h.alive
    h.submit_all(data={0: data[0], 1: data[1]})
    rt.drive()
    np.testing.assert_array_equal(h.read(0), data[0] + data[1])


# ---------------------------------------------------------------------------
# grad-sync integration (acceptance: mid-training eviction at R=8)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_grad_sync_evict_mid_training():
    import jax

    from repro.train.occl_sync import OcclGradSync

    R = 8
    rng = np.random.RandomState(7)
    tmpl = {"w": jax.ShapeDtypeStruct((40,), np.float32),
            "b": jax.ShapeDtypeStruct((8,), np.float32)}
    grads = [{"w": rng.rand(40).astype(np.float32),
              "b": rng.rand(8).astype(np.float32)} for _ in range(R)]
    sync = OcclGradSync(tmpl, n_ranks=R, bucket_elems=32, slice_elems=8)
    got = sync.all_reduce(grads)                 # step 1: full fleet
    ref = sum(np.asarray(g["w"]) for g in grads) / R
    np.testing.assert_allclose(np.asarray(got[0]["w"]), ref, rtol=1e-5)

    # rank 5 dies between steps; evict and keep training at R=7
    report = sync.evict(5)
    assert report["n_ranks"] == 7 and sync.n_ranks == 7
    survivors = [g for i, g in enumerate(grads) if i != 5]
    got7 = sync.all_reduce(survivors)

    # bit-identical to a FRESH 7-rank sync over the same grads
    fresh = OcclGradSync(tmpl, n_ranks=7, bucket_elems=32, slice_elems=8)
    want7 = fresh.all_reduce(survivors)
    for r in range(7):
        for k in ("w", "b"):
            np.testing.assert_array_equal(np.asarray(got7[r][k]),
                                          np.asarray(want7[r][k]))
    # ... and no more supersteps than the fresh baseline spent.
    evicted_steps = int(np.asarray(sync.stats()["supersteps"]).max())
    fresh_steps = int(np.asarray(fresh.stats()["supersteps"]).max())
    assert evicted_steps - fresh_steps <= fresh_steps  # pre-evict step 1
