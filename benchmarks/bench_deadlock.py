"""Paper Sec. 5.2 deadlock stress: 8 ranks x 8 all-reduces with pairwise
different submission orders, iterated — OCCL completes everything while
the statically-sequenced baseline provably deadlocks (wait-for cycle).

``run_a2a_chained`` is the expert-parallel MoE variant: chained
dispatch/combine ALL-TO-ALL pairs submitted in conflicting per-rank
orders (two MoE layers' exchanges interleaving across ranks) — the
personalized payloads make misrouting visible, and the same wait-for
cycle wedges the static executor."""
import numpy as np

from common import row, timeit
from repro.core import (CollKind, OcclConfig, OcclRuntime,
                        run_static_order)


def run(R=8, C=8, iters=3, sizes=None):
    sizes = sizes or [64 * (2 ** (i % 5)) for i in range(C)]
    cfg = OcclConfig(n_ranks=R, max_colls=C, max_comms=1, slice_elems=64,
                     conn_depth=4, heap_elems=1 << 16,
                     superstep_budget=1 << 15)
    rt = OcclRuntime(cfg)
    comm = rt.communicator(list(range(R)))
    ids = [rt.register(CollKind.ALL_REDUCE, comm, n_elems=s) for s in sizes]
    rng = np.random.RandomState(0)
    orders = {r: list(rng.permutation(C)) for r in range(R)}

    static = run_static_order(orders, {i: list(range(R)) for i in range(C)})
    assert static.deadlocked, "stress orders should wedge the baseline"

    data = {i: [rng.randn(sizes[i]).astype(np.float32) for _ in range(R)]
            for i in range(C)}

    def one_iter():
        for r in range(R):
            for slot in orders[r]:
                rt.submit(r, ids[slot], data=data[slot][r])
        rt.drive()

    t = timeit(one_iter, iters=iters, warmup=1)
    for i in range(C):
        want = sum(data[i])
        for r in range(R):
            np.testing.assert_allclose(rt.read_output(r, ids[i]), want,
                                       rtol=1e-4, atol=1e-5)
    st = rt.stats()
    row("deadlock/stress_8x8", t * 1e6,
        f"static_deadlock_cycle={static.cycle};"
        f"preempts={int(st['preempts'].sum())};"
        f"completed={int(st['completed'].sum())}")
    return st


def run_a2a_chained(R=8, C=4, n=1024, iters=3):
    """C chained all-to-alls (two MoE layers' dispatch+combine pairs)
    in conflicting per-rank submission orders: the static single-queue
    executor wedges, OCCL drains all of them with every personalized
    granule landing reference-exact."""
    cfg = OcclConfig(n_ranks=R, max_colls=C, max_comms=1, slice_elems=64,
                     conn_depth=8, heap_elems=1 << 17,
                     superstep_budget=1 << 15)
    rt = OcclRuntime(cfg)
    comm = rt.communicator(list(range(R)))
    ids = [rt.register(CollKind.ALL_TO_ALL, comm, n_elems=n)
           for _ in range(C)]
    rng = np.random.RandomState(7)
    orders = {r: list(rng.permutation(C)) for r in range(R)}
    static = run_static_order(orders, {i: list(range(R)) for i in range(C)})
    assert static.deadlocked, "chained a2a orders should wedge the baseline"

    data = {i: [rng.randn(n).astype(np.float32) for _ in range(R)]
            for i in range(C)}

    def one_iter():
        for r in range(R):
            for slot in orders[r]:
                rt.submit(r, ids[slot], data=data[slot][r])
        rt.drive()

    t = timeit(one_iter, iters=iters, warmup=1)
    c = n // R
    for i in range(C):
        for m in range(R):
            want = np.concatenate([data[i][o][m * c:(m + 1) * c]
                                   for o in range(R)])
            np.testing.assert_array_equal(rt.read_output(m, ids[i]), want)
    st = rt.stats()
    row("deadlock/a2a_chained_8x4", t * 1e6,
        f"static_deadlock_cycle={static.cycle};"
        f"preempts={int(st['preempts'].sum())};"
        f"completed={int(st['completed'].sum())}")
    return st


if __name__ == "__main__":
    run()
    run_a2a_chained()
