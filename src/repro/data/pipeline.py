"""Deterministic synthetic data pipeline.

Production-shaped: shard-aware (each DP rank draws only its shard),
checkpointable (the cursor is just the step number — restore = seek),
background prefetch (a thread keeps ``prefetch`` batches ready), and
deterministic across restarts/elastic resharding (batch content depends
only on (seed, step, global position), never on worker count).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np

from ..configs.base import ArchConfig, ShapeCell


class SyntheticPipeline:
    def __init__(self, cfg: ArchConfig, cell: ShapeCell, *, seed: int = 0,
                 shard_id: int = 0, n_shards: int = 1, prefetch: int = 2):
        assert cell.global_batch % n_shards == 0
        self.cfg, self.cell, self.seed = cfg, cell, seed
        self.shard_id, self.n_shards = shard_id, n_shards
        self.local_batch = cell.global_batch // n_shards
        self.step = 0
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- deterministic batch synthesis -----------------------------------
    def batch_at(self, step: int) -> dict:
        cfg, cell = self.cfg, self.cell
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + step) % (2**31 - 1))
        # draw the GLOBAL batch then slice our shard: elasticity-safe
        B, S = cell.global_batch, cell.seq_len
        lo = self.shard_id * self.local_batch
        hi = lo + self.local_batch
        out = {}
        if cfg.family == "vit":
            pat = rng.randn(B, cfg.vis_tokens, cfg.d_model).astype(np.float32)
            lab = rng.randint(0, cfg.vocab, size=(B,)).astype(np.int32)
            return {"patches": pat[lo:hi], "labels": lab[lo:hi]}
        text = S
        if cfg.family == "vlm":
            text = S - cfg.vis_tokens
            out["patches"] = rng.randn(
                B, cfg.vis_tokens, cfg.d_model).astype(np.float32)[lo:hi]
        if cfg.family == "encdec":
            out["frames"] = rng.randn(
                B, cfg.enc_frames, cfg.d_model).astype(np.float32)[lo:hi]
        toks = rng.randint(0, cfg.vocab, size=(B, text + 1)).astype(np.int32)
        out["tokens"] = toks[lo:hi, :-1]
        out["targets"] = toks[lo:hi, 1:]
        return out

    # -- iterator + prefetch ----------------------------------------------
    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            try:
                self._q.put(self.batch_at(step), timeout=0.1)
                step += 1
            except queue.Full:
                continue

    def start(self):
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2)
            self._thread = None
        while not self._q.empty():
            self._q.get_nowait()

    def __next__(self) -> dict:
        if self._thread is not None:
            b = self._q.get()
        else:
            b = self.batch_at(self.step)
        self.step += 1
        return b

    def __iter__(self) -> Iterator[dict]:
        return self

    # -- checkpointing ------------------------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, d: dict):
        was_running = self._thread is not None
        self.stop()
        self.step = int(d["step"])
        self.seed = int(d["seed"])
        if was_running:
            self.start()
