"""Serving engine: batched prefill + decode, slot recycling, determinism."""
import pytest

# Heavyweight serving integration: excluded from tier-1; run with `pytest -m ""`.
pytestmark = pytest.mark.slow
import numpy as np

from repro.configs import get_config
from repro.serving.engine import Request, ServingEngine


def _reqs(n, vocab, seed=0, max_new=5):
    rng = np.random.RandomState(seed)
    return [Request(rid=i,
                    prompt=rng.randint(0, vocab, size=rng.randint(3, 12)),
                    max_new_tokens=max_new) for i in range(n)]


def test_engine_drains_queue_multiple_batches():
    cfg = get_config("qwen3-0.6b").reduced()
    eng = ServingEngine(cfg, batch_size=3, prompt_len=12, max_len=24)
    for r in _reqs(7, cfg.vocab):
        eng.submit(r)
    done = eng.run()
    assert len(done) == 7
    assert all(len(r.out_tokens) == 5 for r in done)
    # Uniform max_new: slots free together, so continuous batching still
    # admits in ceil(7/3) cohorts.
    assert eng.stats["prefills"] == 3
    assert eng.stats["tokens"] == sum(len(r.out_tokens) for r in done)


def test_engine_recycles_slots_mid_flight():
    """Heterogeneous decode lengths: the long request must NOT hold the
    short ones' slots hostage — freed slots re-admit from the queue
    while the long request keeps decoding (continuous batching), and the
    token counter reconciles exactly with the emitted tokens."""
    cfg = get_config("qwen3-0.6b").reduced()
    eng = ServingEngine(cfg, batch_size=2, prompt_len=12, max_len=24)
    reqs = _reqs(4, cfg.vocab, seed=1)
    for r, n in zip(reqs, [8, 2, 2, 2]):
        r.max_new_tokens = n
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 4
    assert sorted(len(r.out_tokens) for r in done) == [2, 2, 2, 8]
    # The rid=0 long request finishes LAST: the short ones were admitted
    # into its partner slot mid-flight and retired before it.
    assert done[-1].rid == 0
    # A fixed-cohort engine would need ceil(4/2)=2 prefills but run the
    # long request alone for its tail; mid-flight recycling instead
    # re-prefills on each admission event (3 here: {0,1}, {0,2}, {0,3}).
    assert eng.stats["admissions"] == 4
    assert eng.stats["prefills"] == 3
    assert eng.stats["tokens"] == sum(len(r.out_tokens) for r in done)


def test_engine_token_stats_reconcile_with_zero_token_requests():
    """Degenerate admissions (max_new_tokens=0) retire at admission and
    contribute zero tokens; the invariant still holds exactly."""
    cfg = get_config("qwen3-0.6b").reduced()
    eng = ServingEngine(cfg, batch_size=2, prompt_len=12, max_len=24)
    reqs = _reqs(3, cfg.vocab, seed=2)
    reqs[1].max_new_tokens = 0
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 3
    assert eng.stats["tokens"] == sum(len(r.out_tokens) for r in done)
    assert next(r for r in done if r.rid == 1).out_tokens == []
    assert {r.rid for r in done} == {0, 1, 2}


def test_engine_with_qos_fabric():
    """Engine + shared QoS fabric: every prefill issues the all-gather
    and every decode step the TP all-reduce, with an adversarial
    background tenant pumping bursts — decode preempts them, and the
    engine's stats gain the per-class latency digest."""
    from repro.serving.qos import ServingQos, TrafficClass

    cfg = get_config("qwen3-0.6b").reduced()
    qos = ServingQos(n_ranks=2, decode_elems=64, prefill_elems=128,
                     background_elems=1024, background_buckets=1,
                     preemption=True)
    eng = ServingEngine(cfg, batch_size=2, prompt_len=12, max_len=24,
                        qos=qos)
    for r in _reqs(3, cfg.vocab, max_new=3):
        eng.submit(r)
    done = eng.run()
    assert len(done) == 3
    q = eng.stats["qos"]
    # One collective per event, reconciled exactly.
    assert q["decode"]["completed"] == eng.stats["decode_steps"]
    assert q["prefill"]["completed"] == eng.stats["prefills"]
    qos.drain()     # background bursts pumped mid-run must all land
    bg = qos.tenants[TrafficClass.BACKGROUND]
    assert bg.submitted > 0 and bg.completed == bg.submitted


def test_engine_deterministic():
    cfg = get_config("llama3-8b").reduced()

    def run():
        eng = ServingEngine(cfg, batch_size=2, prompt_len=8, max_len=16)
        for r in _reqs(2, cfg.vocab, seed=3):
            eng.submit(r)
        return [r.out_tokens for r in eng.run()]

    assert run() == run()
