"""Batched serving engine: continuous prefill + decode over a request queue.

CPU/testbed-scale engine with the production control flow: requests are
admitted into fixed batch slots, prefilled (padded to the bucket), then
decoded step-locked as a batch; finished slots are recycled for waiting
requests.  The decode step is the same jitted ``serve_step`` the dry-run
lowers at 32k/500k scale.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, ShapeCell
from ..models import build_model, input_specs, make_concrete


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # [S] int32
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ArchConfig, *, batch_size: int = 4,
                 prompt_len: int = 32, max_len: int = 96, seed: int = 0):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = self.model.init(seed)
        self.B, self.S, self.max_len = batch_size, prompt_len, max_len
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, pad_to=self.max_len))
        self._decode = jax.jit(self.model.decode_step)
        self.queue: list[Request] = []
        self.stats = {"prefills": 0, "decode_steps": 0, "tokens": 0}

    def submit(self, req: Request):
        self.queue.append(req)

    def _batchify(self, reqs: list[Request]) -> dict:
        toks = np.zeros((self.B, self.S), np.int32)
        for i, r in enumerate(reqs):
            toks[i, :len(r.prompt)] = r.prompt[:self.S]
        batch = {"tokens": jnp.asarray(toks)}
        cfg = self.cfg
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (self.B, cfg.vis_tokens, cfg.d_model), cfg.compute_dtype)
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (self.B, cfg.enc_frames, cfg.d_model), cfg.compute_dtype)
        return batch

    def run(self) -> list[Request]:
        """Drain the queue; returns completed requests."""
        finished: list[Request] = []
        while self.queue:
            reqs = [self.queue.pop(0) for _ in
                    range(min(self.B, len(self.queue)))]
            while len(reqs) < self.B:       # pad the batch
                reqs.append(Request(rid=-1, prompt=np.zeros(1, np.int32),
                                    max_new_tokens=0, done=True))
            batch = self._batchify(reqs)
            logits, cache = self._prefill(self.params, batch)
            self.stats["prefills"] += 1
            toks = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            steps = max((r.max_new_tokens for r in reqs), default=0)
            for _ in range(steps):
                for i, r in enumerate(reqs):
                    if not r.done:
                        r.out_tokens.append(int(toks[i]))
                        if len(r.out_tokens) >= r.max_new_tokens:
                            r.done = True
                logits, cache = self._decode(self.params, cache, toks)
                toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                self.stats["decode_steps"] += 1
                self.stats["tokens"] += sum(1 for r in reqs if not r.done)
                if all(r.done for r in reqs):
                    break
            finished.extend(r for r in reqs if r.rid >= 0)
        return finished
