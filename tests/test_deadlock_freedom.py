"""THE property of the paper (hypothesis): any per-rank submission order
of any mix of collectives completes deadlock-free under OCCL with correct
results — including order-sets that provably deadlock the statically
sequenced baseline (Fig. 1a)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CollKind, OcclConfig, OcclRuntime, OrderPolicy,
                        run_static_order)

KINDS = [CollKind.ALL_REDUCE, CollKind.ALL_GATHER, CollKind.REDUCE_SCATTER,
         CollKind.BROADCAST, CollKind.REDUCE]


def _run_occl(R, colls, orders, policy, stickiness, seed):
    cfg = OcclConfig(
        n_ranks=R, max_colls=max(4, len(colls)), max_comms=1,
        slice_elems=4, conn_depth=3, heap_elems=1 << 13,
        order_policy=policy, stickiness=stickiness,
        superstep_budget=1 << 14, quit_threshold=64)
    rt = OcclRuntime(cfg)
    comm = rt.communicator(list(range(R)))
    rng = np.random.RandomState(seed)
    ids, inputs, roots = [], {}, {}
    for kind, n_elems, root in colls:
        cid = rt.register(kind, comm, n_elems=n_elems, root=root)
        ids.append(cid)
        roots[cid] = root
        if kind == CollKind.ALL_GATHER:
            chunk = -(-n_elems // R)
            inputs[cid] = [rng.randn(chunk).astype(np.float32)
                           for _ in range(R)]
        else:
            inputs[cid] = [rng.randn(n_elems).astype(np.float32)
                           for _ in range(R)]
    for r in range(R):
        for slot in orders[r]:
            cid = ids[slot]
            kind = colls[slot][0]
            if kind == CollKind.BROADCAST:
                if r == comm.members[roots[cid]]:
                    rt.write_input(r, cid, inputs[cid][0])
            else:
                rt.write_input(r, cid, inputs[cid][r])
            rt.submit(r, cid)
    rt.drive(max_launches=128)
    return rt, ids, inputs, roots


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_any_order_completes_correctly(data):
    R = data.draw(st.integers(2, 5), label="ranks")
    n_coll = data.draw(st.integers(1, 4), label="n_coll")
    colls = []
    for i in range(n_coll):
        kind = data.draw(st.sampled_from(KINDS), label=f"kind{i}")
        n_elems = data.draw(st.integers(1, 40), label=f"n{i}")
        root = data.draw(st.integers(0, R - 1), label=f"root{i}")
        colls.append((kind, n_elems, root))
    orders = [data.draw(st.permutations(range(n_coll)), label=f"order{r}")
              for r in range(R)]
    policy = data.draw(st.sampled_from(
        [OrderPolicy.FIFO, OrderPolicy.PRIORITY]), label="policy")
    stick = data.draw(st.booleans(), label="stickiness")
    seed = data.draw(st.integers(0, 1000), label="seed")

    rt, ids, inputs, roots = _run_occl(R, colls, orders, policy, stick, seed)

    for slot, cid in enumerate(ids):
        kind, n_elems, root = colls[slot]
        if kind == CollKind.ALL_REDUCE:
            want = sum(inputs[cid])
            for r in range(R):
                np.testing.assert_allclose(
                    rt.read_output(r, cid), want, rtol=1e-4, atol=1e-6)
        elif kind == CollKind.ALL_GATHER:
            chunk = -(-n_elems // R)
            want = np.concatenate(inputs[cid])[:n_elems]
            for r in range(R):
                np.testing.assert_allclose(
                    rt.read_output(r, cid), want, rtol=1e-4, atol=1e-6)
        elif kind == CollKind.REDUCE_SCATTER:
            chunk = -(-n_elems // R)
            full = sum(np.pad(x, (0, chunk * R - n_elems))
                       for x in inputs[cid])
            for r in range(R):
                np.testing.assert_allclose(
                    rt.read_output(r, cid), full[r * chunk:(r + 1) * chunk],
                    rtol=1e-4, atol=1e-6)
        elif kind == CollKind.BROADCAST:
            for r in range(R):
                np.testing.assert_allclose(
                    rt.read_output(r, cid), inputs[cid][0], rtol=1e-4, atol=1e-6)
        elif kind == CollKind.REDUCE:
            want = sum(inputs[cid])
            np.testing.assert_allclose(
                rt.read_output(root, cid), want, rtol=1e-4, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_occl_survives_static_deadlocks(data):
    """Order sets that deadlock the single-FIFO-queue baseline still
    complete under OCCL (the paper's stress scenario, Sec. 5.2)."""
    R = data.draw(st.integers(2, 4))
    n_coll = data.draw(st.integers(2, 4))
    orders = {r: list(data.draw(st.permutations(range(n_coll))))
              for r in range(R)}
    members_of = {c: list(range(R)) for c in range(n_coll)}
    static = run_static_order(orders, members_of)
    colls = [(CollKind.ALL_REDUCE, 8, 0) for _ in range(n_coll)]
    rt, ids, inputs, _ = _run_occl(
        R, colls, [orders[r] for r in range(R)],
        OrderPolicy.FIFO, True, seed=1)
    for cid in ids:
        want = sum(inputs[cid])
        np.testing.assert_allclose(rt.read_output(0, cid), want, rtol=1e-4, atol=1e-6)
    if static.deadlocked:
        assert static.cycle is not None or static.blocked_at


def test_pairwise_opposite_orders_deadlock_baseline_not_occl():
    """The canonical Fig. 1(a) two-collective inversion."""
    orders = {0: [0, 1], 1: [1, 0]}
    members = {0: [0, 1], 1: [0, 1]}
    res = run_static_order(orders, members)
    assert res.deadlocked and res.cycle

    colls = [(CollKind.ALL_REDUCE, 12, 0), (CollKind.ALL_REDUCE, 12, 0)]
    rt, ids, inputs, _ = _run_occl(
        2, colls, [[0, 1], [1, 0]], OrderPolicy.FIFO, True, seed=2)
    for cid in ids:
        np.testing.assert_allclose(
            rt.read_output(0, cid), sum(inputs[cid]), rtol=1e-4, atol=1e-6)
    assert rt.stats()["preempts"].sum() > 0   # preemption did the work
