"""Serving engine: batched prefill + decode, slot recycling, determinism."""
import pytest

# Heavyweight serving integration: excluded from tier-1; run with `pytest -m ""`.
pytestmark = pytest.mark.slow
import numpy as np

from repro.configs import get_config
from repro.serving.engine import Request, ServingEngine


def _reqs(n, vocab, seed=0):
    rng = np.random.RandomState(seed)
    return [Request(rid=i,
                    prompt=rng.randint(0, vocab, size=rng.randint(3, 12)),
                    max_new_tokens=5) for i in range(n)]


def test_engine_drains_queue_multiple_batches():
    cfg = get_config("qwen3-0.6b").reduced()
    eng = ServingEngine(cfg, batch_size=3, prompt_len=12, max_len=24)
    for r in _reqs(7, cfg.vocab):
        eng.submit(r)
    done = eng.run()
    assert len(done) == 7
    assert all(len(r.out_tokens) == 5 for r in done)
    assert eng.stats["prefills"] == 3          # ceil(7/3) batches


def test_engine_deterministic():
    cfg = get_config("llama3-8b").reduced()

    def run():
        eng = ServingEngine(cfg, batch_size=2, prompt_len=8, max_len=16)
        for r in _reqs(2, cfg.vocab, seed=3):
            eng.submit(r)
        return [r.out_tokens for r in eng.run()]

    assert run() == run()
