"""Device-resident staging engine: the bulk heap-I/O fast path.

Host-side submit-time staging used to dominate end-to-end cost (~100 ms
per 8-rank iteration at 16k elems, ROADMAP): every ``write_input`` was a
Python chunk loop plus a full-heap device round trip, and the "bulk"
variants still mirrored the whole ``[R, H]`` heap through host memory in
both directions.  This module replaces that with the registration-time
index maps of :class:`repro.core.tables.StaticTables` (``stage_in_map`` /
``stage_out_map``) and per-write-set compiled staging plans:

* **write**: ONE host->device transfer of the concatenated logical
  payloads; the pack transform into the padded chunk layout runs
  on-device (a fused gather + mask when any write has pad positions —
  pads are zero-filled as part of the same op, so stale heap data can
  never leak into the padded slices of chunked collectives); the packed
  segments then land in ``heap_in`` via buffer-donated device updates —
  in place on backends that implement donation (CPU/GPU/TPU in current
  jaxlibs), never a host heap mirror.
* **read**: the mirror path out of ``heap_out``: device segment slices
  fused into one buffer, ONE device->host transfer, and a vectorized
  un-pad.  Results are owned writable copies (never views aliasing the
  heap snapshot), so callers may mutate them freely.

Plans — the compiled program plus its device-resident index arrays — are
cached by the (rank, collective, base-offset) signature of the write/read
set, so a steady-state training step (identical buckets every iteration)
compiles once and thereafter only ships payload values.  At plan-build
time adjacent heap regions are COALESCED: the runtime's split in/out
allocation arenas pack registered buffers contiguously, so a grad-sync
step that stages every bucket collapses to a single stacked ``[R, W]``
``dynamic_update_slice`` (write) / ``dynamic_slice`` (read) instead of
one op per (rank, collective).  Cost therefore scales with payload BYTES,
not with heap size or Python chunk-loop iterations.

Index maps are relative to each collective's base heap offset; per-SQE
dynamic buffer offsets (paper Sec. 3.1.2) are honored by adding the
override as a scalar at plan-build time.  Writes in one batch touching
overlapping regions (possible only via offset overrides) apply in
(rank, offset)-sorted order, not submission order.

Donation caveat: each write invalidates the PREVIOUS ``heap_in`` buffer.
The runtime immediately replaces its state, so this is only observable
to callers that squirrel away a stale ``DaemonState`` and poke its
``heap_in`` after a later write — don't.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import OcclConfig
from .state import DaemonState
from .tables import StaticTables

# On the CPU backend ``np.asarray`` of a device array is a ZERO-COPY view
# (host memory IS device memory), so the read path needs no jit dispatch
# at all: un-pad directly out of the view with the precomputed maps and
# hand back owned copies.  Accelerator backends keep the compiled
# segment-gather plan (one fused device slice, one D2H transfer).
# Probed LAZILY on first read: importing this module must not initialize
# the jax backend (that would freeze platform selection before user code
# can call jax.config.update), and by first read the backend in use is
# the one the heaps actually live on.
@functools.lru_cache(maxsize=None)
def _host_is_device() -> bool:
    return jax.default_backend() == "cpu"


def _merge_segments(segs):
    """Coalesce (rank, off, span) runs that are adjacent in the heap.
    ``segs`` must be (rank, off)-sorted; returns the merged list."""
    merged = []
    for rank, off, span in segs:
        if merged and merged[-1][0] == rank \
                and merged[-1][1] + merged[-1][2] == off:
            r, o, s = merged[-1]
            merged[-1] = (r, o, s + span)
        else:
            merged.append((rank, off, span))
    return merged


def _stacked(merged) -> Optional[tuple]:
    """(r0, off, span) if the merged segments form one dense rank-range
    block — identical column window on consecutive ranks — which executes
    as a single 2D slice/update; None otherwise."""
    if not merged:
        return None
    offs = {(o, s) for _, o, s in merged}
    ranks = [r for r, _, _ in merged]
    if len(offs) == 1 and ranks == list(range(ranks[0],
                                               ranks[0] + len(ranks))):
        _, off, span = merged[0]
        return ranks[0], off, span
    return None


@dataclasses.dataclass
class _WritePlan:
    fn: Callable             # (heap, vals, gather_src, mask) -> heap
    gather_src: jnp.ndarray  # device-resident, uploaded once per plan
    mask: jnp.ndarray
    # Sharded fast path (mesh backend): when the write set is one dense
    # full-rank stacked block, the packed payload is placed PER DEVICE via
    # jax.device_put with the heap's NamedSharding and the update runs
    # shard-locally — no [R, ...] gather, no cross-device payload
    # broadcast.  None when the engine has no sharding or the set is not
    # a full-rank block (the general plan stays correct on any backend).
    sharded_fn: Optional[Callable] = None   # (heap, block [R, span]) -> heap
    src_np: Optional[np.ndarray] = None     # host copies for host-side pack
    mask_np: Optional[np.ndarray] = None
    identity: bool = False


@dataclasses.dataclass
class _ReadPlan:
    fn: Callable             # heap -> packed padded segments [S]
    # (rank, coll_id, base) -> (packed position, logical size, unpad map
    # or None for the pad-free identity layout)
    slot_by_key: dict


class StagingEngine:
    """Pack/unpack between logical user payloads and the padded heap
    layout, via precomputed index maps and per-signature compiled plans."""

    def __init__(self, cfg: OcclConfig, tables: StaticTables,
                 sharding=None):
        self.cfg = cfg
        self.t = tables
        # Host-side payloads are cast to the HEAP dtype before upload, so
        # the transfer ships heap-width bytes (half for bfloat16 wire
        # compression) and non-float32 heaps never round-trip through
        # float32 (ml_dtypes supplies the numpy bfloat16).
        self._dtype = np.dtype(jnp.zeros((), cfg.dtype).dtype)
        self._write_plans: dict = {}
        self._read_plans: dict = {}
        # Mesh backend: the [R, ...] heap's NamedSharding (leading axis on
        # the mesh's rank axis).  Full-rank stacked writes then stage via
        # per-device jax.device_put placements instead of the sim-style
        # single-device payload commit (see _WritePlan.sharded_fn).
        self.sharding = sharding
        # Flush observability (BENCH_collectives.json "mesh" section):
        # payload bytes shipped by write() vs what a full [R, heap] mirror
        # would move, and how many writes took the sharded placement path.
        self.flush_writes = 0
        self.flush_bytes = 0
        self.sharded_flushes = 0

    # -- writes ----------------------------------------------------------
    def _write_plan(self, sig) -> _WritePlan:
        """``sig`` is the (rank, base)-SORTED (rank, coll_id, base) tuple,
        so every caller-order permutation of one write set hits one plan
        (one compile, one LRU slot)."""
        plan = self._write_plans.pop(sig, None)
        if plan is not None:
            self._write_plans[sig] = plan    # touch: LRU re-insert
            return plan
        t = self.t
        segs, src, mask = [], [], []
        logical = 0
        for rank, cid, base in sig:
            span = int(t.in_span[cid])
            m = t.stage_in_map[cid]
            s = np.zeros(span, np.int32)
            s[m] = logical + np.arange(m.size, dtype=np.int32)
            ok = np.zeros(span, bool)
            ok[m] = True
            src.append(s)
            mask.append(ok)
            segs.append((int(rank), int(base), span))
            logical += m.size
        src = np.concatenate(src)
        mask = np.concatenate(mask)
        # Pad-free layouts in sorted order: logical order IS packed order.
        identity = bool(mask.all()) and bool(
            (src == np.arange(src.size, dtype=np.int32)).all())
        merged = _merge_segments(segs)
        stack = _stacked(merged)

        @functools.partial(jax.jit, donate_argnums=(0,))
        def fn(heap, vals, gather_src, ok):
            packed = vals if identity else jnp.where(ok, vals[gather_src], 0)
            packed = packed.astype(heap.dtype)
            if stack is not None:
                r0, off, span = stack
                block = packed.reshape(-1, span)
                heap = jax.lax.dynamic_update_slice(heap, block, (r0, off))
            else:
                o = 0
                for rank, off, span in merged:
                    heap = jax.lax.dynamic_update_slice(
                        heap, packed[o:o + span][None, :], (rank, off))
                    o += span
            return heap

        # Sharded fast path: a dense block covering EVERY rank with one
        # identical column window (the grad-sync / all-ranks-submit shape)
        # updates shard-locally after per-device payload placement.
        sharded_fn = None
        if (self.sharding is not None and stack is not None
                and stack[0] == 0 and len(merged) == self.cfg.n_ranks):
            s_off = stack[1]

            @functools.partial(jax.jit, donate_argnums=(0,))
            def sharded_fn(heap, block):
                return jax.lax.dynamic_update_slice(heap, block, (0, s_off))

        plan = _WritePlan(fn=fn, gather_src=jnp.asarray(src),
                          mask=jnp.asarray(mask), sharded_fn=sharded_fn,
                          src_np=src, mask_np=mask, identity=identity)
        if len(self._write_plans) > 64:    # evict least-recently-used
            self._write_plans.pop(next(iter(self._write_plans)))
        self._write_plans[sig] = plan
        return plan

    def snapshot(self, coll_id: int, data) -> np.ndarray:
        """Validate one logical payload and return an OWNED heap-dtype
        copy — the single definition of the payload invariant, shared by
        the write path and the runtime's submit-time staging (which must
        capture the value at call time, not at flush time).  The copy
        also keeps caller memory out of the (async) jit below."""
        data = np.ravel(data)
        want = int(self.t.in_log[coll_id])
        if data.size != want:
            # ValueError, not assert: a silently-undersized payload would
            # gather clamped tail garbage into the heap under python -O.
            raise ValueError(
                f"collective {coll_id} input: got {data.size} elems, "
                f"registered logical size is {want}")
        return np.array(data, dtype=self._dtype)   # np.array always copies

    def write(self, state: DaemonState, items,
              owned: bool = False) -> DaemonState:
        """items: iterable of ``(rank, coll_id, data, base_in_off)``.
        Logical payloads land at their padded positions, pads are zeroed,
        in one transfer + one donated in-place scatter program.
        ``owned=True`` (the staged-submit flush, whose payloads were
        already snapshotted at submit time) skips the defensive
        anti-aliasing copy on the per-step hot path."""
        items = list(items)
        if not items:
            return state
        datas = [data if owned else self.snapshot(cid, data)
                 for _, cid, data, _ in items]
        # Stable (rank, base) sort: the plan cache is permutation-
        # independent, and duplicate-region writes keep caller order
        # (last write wins) among themselves.
        order = sorted(range(len(items)),
                       key=lambda i: (items[i][0], items[i][3]))
        plan = self._write_plan(
            tuple((items[i][0], items[i][1], items[i][3]) for i in order))
        vals = [datas[i] for i in order]
        vals = vals[0] if len(vals) == 1 else np.concatenate(vals)
        self.flush_writes += 1
        self.flush_bytes += vals.nbytes
        if plan.sharded_fn is not None:
            # Mesh fast path: pack host-side with the same precomputed
            # maps, then device_put the [R, span] block with the heap's
            # NamedSharding — each device receives ONLY its own rank's
            # rows, and the donated update runs shard-locally (the
            # sim-style path would commit the whole payload to one device
            # and let SPMD re-distribute it).
            packed = vals if plan.identity else vals[plan.src_np]
            if not plan.identity:
                packed[~plan.mask_np] = packed.dtype.type(0)
            block = jax.device_put(
                packed.reshape(self.cfg.n_ranks, -1), self.sharding)
            heap = plan.sharded_fn(state.heap_in, block)
            self.sharded_flushes += 1
            return state._replace(heap_in=heap)
        # vals is passed as numpy in the HEAP dtype: the jit commits it
        # inside the one dispatch (zero-copy on CPU; one heap-width H2D
        # transfer on accelerators).
        heap = plan.fn(state.heap_in, vals, plan.gather_src, plan.mask)
        return state._replace(heap_in=heap)

    # -- reads -----------------------------------------------------------
    def _read_plan(self, sig) -> _ReadPlan:
        """``sig`` is the (rank, base)-SORTED (rank, coll_id, base) tuple
        (permutation-independent plan cache, like writes)."""
        plan = self._read_plans.pop(sig, None)
        if plan is not None:
            self._read_plans[sig] = plan     # touch: LRU re-insert
            return plan
        t = self.t
        segs, slot_by_key = [], {}
        pos = 0
        for rank, cid, base in sig:
            span = int(t.out_span[cid])
            segs.append((int(rank), int(base), span))
            m = t.stage_out_map[cid]
            identity = bool(
                (m == np.arange(m.size, dtype=np.int32)).all())
            slot_by_key[(rank, cid, base)] = (
                pos, m.size, None if identity else m)
            pos += span
        merged = _merge_segments(segs)
        stack = _stacked(merged)

        @jax.jit
        def fn(heap):
            if stack is not None:
                r0, off, span = stack
                n_rows = len(merged)
                return jax.lax.dynamic_slice(
                    heap, (r0, off), (n_rows, span)).ravel()
            return jnp.concatenate([
                jax.lax.dynamic_slice(heap, (rank, off), (1, span)).ravel()
                for rank, off, span in merged])

        plan = _ReadPlan(fn=fn, slot_by_key=slot_by_key)
        if len(self._read_plans) > 64:     # evict least-recently-used
            self._read_plans.pop(next(iter(self._read_plans)))
        self._read_plans[sig] = plan
        return plan

    def read(self, state: DaemonState, keys) -> dict:
        """keys: iterable of ``(rank, coll_id, base_out_off)``.  Returns
        ``{(rank, coll_id): logical output}`` as owned writable arrays."""
        keys = list(keys)
        if not keys:
            return {}
        if _host_is_device():
            return self._read_host(state, keys)
        plan = self._read_plan(
            tuple(sorted(keys, key=lambda k: (k[0], k[2]))))
        packed = np.asarray(plan.fn(state.heap_out))
        out = {}
        for rank, cid, base in keys:
            pos, n, unpad = plan.slot_by_key[(rank, cid, base)]
            if unpad is None:
                out[(rank, cid)] = packed[pos:pos + n].copy()
            else:
                out[(rank, cid)] = packed[pos + unpad]
        return out

    def _read_host(self, state: DaemonState, keys) -> dict:
        """CPU fast path: un-pad straight out of the zero-copy heap view —
        no jit dispatch, no transfer; per-key copies stay owned."""
        t = self.t
        heap = np.asarray(state.heap_out)
        out = {}
        for rank, cid, base in keys:
            m = t.stage_out_map[cid]
            row = heap[rank]
            if m.size == int(t.out_span[cid]):      # pad-free: identity map
                out[(rank, cid)] = row[base:base + m.size].copy()
            else:
                out[(rank, cid)] = row[base + m]    # fancy-index: owned
        return out
