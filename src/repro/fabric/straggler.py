"""Step-level straggler detection.

Collective-layer straggler tolerance is intrinsic to OCCL (bounded
supersteps + voluntary quit: a slow rank only delays its own collectives,
which get preempted rather than wedging peers).  This module adds the
fleet-level detector: per-rank step-time EWMAs flag ranks whose times
exceed ``threshold`` x the fleet median, feeding the controller's
re-scheduling decision (on this testbed: a report + an exclusion list).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class StragglerDetector:
    n_ranks: int
    alpha: float = 0.3          # EWMA factor
    threshold: float = 2.0      # x median -> straggler

    def __post_init__(self):
        self.ewma = np.zeros(self.n_ranks)
        self.seen = np.zeros(self.n_ranks, dtype=bool)

    def observe(self, rank: int, step_time_s: float):
        if not self.seen[rank]:
            self.ewma[rank] = step_time_s
            self.seen[rank] = True
        else:
            self.ewma[rank] = (self.alpha * step_time_s
                               + (1 - self.alpha) * self.ewma[rank])

    def stragglers(self) -> list[int]:
        if not self.seen.any():
            return []
        med = float(np.median(self.ewma[self.seen]))
        if med <= 0:
            return []
        return [r for r in range(self.n_ranks)
                if self.seen[r] and self.ewma[r] > self.threshold * med]

    def healthy_ranks(self) -> list[int]:
        bad = set(self.stragglers())
        return [r for r in range(self.n_ranks) if r not in bad]
