"""Expert-parallel MoE dispatch through OCCL all-to-all.

Three acts:

1. **Flat relay ring vs two-level chain** — the same personalized
   exchange over a 4x4 rank grid registered both ways.  The flat ring
   pays the O(R^2) relay program (1 + (R-1)(R+2)/2 = 136 primitive steps
   at R=16: every granule rides the ring to its destination through
   RECV_SEND relay hops), while the two-level lowering runs two short
   full-membership exchanges (intra-island, then inter-island over
   transposed granules) for ~20 steps — and lands the IDENTICAL output
   layout, element-exact.

2. **MoE dispatch/combine** — a reduced DeepSeek-MoE block runs expert-
   parallel: each rank owns a contiguous expert shard, tokens are routed
   top-k, packed into uniform per-(source, expert) capacity bins, and
   both the dispatch and combine exchanges ride staged OCCL all-to-all
   submits.  The transport is bit-preserving in float32, so the OCCL
   path must match the direct-indexing reference BITWISE — including
   under real capacity drops, where overflow slots travel as zeros.

3. **The adversarial chained-order scenario** — two MoE layers' worth of
   dispatch/combine exchanges submitted in conflicting per-rank orders.
   The static single-FIFO-queue baseline deadlocks on this order set
   (wait-for cycle); OCCL's preemption drains all of them with every
   personalized granule landing reference-exact.

    PYTHONPATH=src python examples/moe_alltoall.py
"""
import dataclasses
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import (CollKind, OcclConfig, OcclRuntime,
                        run_static_order)
from repro.core.primitives import program_len

R, HIER, N_ELEMS = 16, (4, 4), 2048
rng = np.random.RandomState(42)


def make_runtime():
    cfg = OcclConfig(n_ranks=R, max_colls=8, max_comms=4, slice_elems=64,
                     conn_depth=32, burst_slices=8, heap_elems=1 << 18,
                     superstep_budget=1 << 15)
    rt = OcclRuntime(cfg)
    return rt, rt.communicator(list(range(R)))


def drive_once(rt, cid, xs):
    for r in range(R):
        rt.submit(r, cid, data=xs[r])
    s0 = int(np.asarray(rt.stats()["supersteps"]).max())
    rt.drive()
    return int(np.asarray(rt.stats()["supersteps"]).max()) - s0


# --- 1. flat relay ring vs two-level chain -----------------------------
xs = [np.asarray(rng.randn(N_ELEMS), np.float32) for _ in range(R)]
c = N_ELEMS // R
want = {m: np.concatenate([xs[o][m * c:(m + 1) * c] for o in range(R)])
        for m in range(R)}
steps = {}
for algo in ("ring", "two_level"):
    rt, world = make_runtime()
    cid = rt.register(CollKind.ALL_TO_ALL, world, n_elems=N_ELEMS,
                      algo=algo, hierarchy=HIER)
    drive_once(rt, cid, xs)                    # warmup: compile + converge
    steps[algo] = drive_once(rt, cid, xs)
    for m in range(R):
        np.testing.assert_array_equal(rt.read_output(m, cid), want[m])
print(f"all-to-all at R={R}: flat relay-ring program is "
      f"{program_len(CollKind.ALL_TO_ALL, R)} primitive steps, "
      f"supersteps flat {steps['ring']} vs two-level "
      f"{steps['two_level']} ({steps['ring'] / steps['two_level']:.1f}x "
      "fewer), outputs element-exact either way")
assert steps["two_level"] < steps["ring"]

# --- 2. expert-parallel MoE dispatch/combine through OCCL --------------
import jax                                     # noqa: E402
import jax.numpy as jnp                        # noqa: E402

from repro.configs import get_config           # noqa: E402
from repro.models import moe as M              # noqa: E402
from repro.train.occl_moe import OcclMoE, ep_forward_ref  # noqa: E402

mcfg = get_config("deepseek-moe-16b").reduced()
mcfg = dataclasses.replace(mcfg, capacity_factor=8.0)
params = M.init_moe_block(jax.random.PRNGKey(0), "t", mcfg, jnp.float32)
EP, TL = 4, 8
toks = [jnp.asarray(rng.randn(TL, mcfg.d_model) * 0.5, jnp.float32)
        for _ in range(EP)]
for cap, label in [(TL * mcfg.top_k, "no-drop"), (4, "capacity-dropped")]:
    moe = OcclMoE(mcfg, EP, TL, cap=cap)
    ys = moe.forward(params, toks)
    ref = ep_forward_ref(mcfg, params, toks, cap=cap)
    for r in range(EP):
        np.testing.assert_array_equal(np.asarray(ys[r]),
                                      np.asarray(ref[r]))
    print(f"MoE {label} (E={mcfg.n_experts}, top_k={mcfg.top_k}, "
          f"cap={cap}): OCCL dispatch+combine BITWISE == reference "
          f"on all {EP} ranks")

# --- 3. adversarial chained dispatch/combine orders --------------------
C = 4                                          # two layers x (disp, comb)
orders = {r: list(np.random.RandomState(r).permutation(C))
          for r in range(R)}
static = run_static_order(orders, {i: list(range(R)) for i in range(C)})
print("static single-FIFO-queue baseline on the conflicting orders:",
      "DEADLOCK" if static.deadlocked else "ok",
      f"(wait-for cycle over ranks {static.cycle})")
assert static.deadlocked

rt, world = make_runtime()
ids = [rt.register(CollKind.ALL_TO_ALL, world, n_elems=512)
       for _ in range(C)]
data = {i: [np.asarray(rng.randn(512), np.float32) for _ in range(R)]
        for i in range(C)}
for r in range(R):
    for slot in orders[r]:
        rt.submit(r, ids[slot], data=data[slot][r])
rt.drive(max_launches=256)
cc = 512 // R
for i in range(C):
    for m in range(R):
        w = np.concatenate([data[i][o][m * cc:(m + 1) * cc]
                            for o in range(R)])
        np.testing.assert_array_equal(rt.read_output(m, ids[i]), w)
st = rt.stats()
print(f"OCCL: all {C} chained exchanges complete under conflicting "
      f"orders — {int(st['preempts'].sum())} preemptions, "
      f"{rt.launches} daemon launches, every granule reference-exact")
print("OK — expert-parallel dispatch stays deadlock-free even when "
      "layers' exchanges interleave across ranks.")
