"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --reduced --steps 50 --batch 8 --seq 64 [--resume] [--occl-sync]

Runs the fault-tolerant train loop (fabric/ft.py) on the host mesh with
the synthetic pipeline; full configs train the same way on a real fleet
(the dry-run proves the production-mesh lowering).  ``--occl-sync``
routes DP gradient buckets through the OCCL runtime (paper integration)
with simulated DP ranks.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-period", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--occl-sync", action="store_true")
    ap.add_argument("--dp", type=int, default=2,
                    help="simulated DP ranks for --occl-sync")
    args = ap.parse_args()

    from ..configs import get_config
    from ..configs.base import ShapeCell
    from ..data.pipeline import SyntheticPipeline
    from ..fabric.ft import FTConfig, TrainController
    from ..checkpoint.ckpt import latest_step, restore
    from ..train.state import init_state
    from ..train.step import make_train_step

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cell = ShapeCell("cli", args.seq, args.batch, "train")

    state = init_state(cfg)
    n = sum(int(np.prod(p.shape))
            for p in jax.tree_util.tree_leaves(state.params))
    print(f"arch={cfg.name} params={n:,}")

    if args.occl_sync:
        run_occl_dp(cfg, cell, args)
        return

    pipe = SyntheticPipeline(cfg, cell).start()
    step_fn = jax.jit(make_train_step(cfg))
    ft = FTConfig(ckpt_dir=args.ckpt_dir, ckpt_period=args.ckpt_period)
    ctrl = TrainController(ft, step_fn, state, pipe)
    if args.resume:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            ctrl.state, extras = restore(args.ckpt_dir, last, state)
            pipe.load_state_dict(extras["pipeline"])
            print(f"resumed from step {last}")
    logs = ctrl.run(args.steps)
    pipe.stop()
    for m in logs[-5:]:
        print(f"step {m['step']:4d} loss {m['loss']:.4f} "
              f"{m['step_time_s']*1e3:7.1f} ms")


def run_occl_dp(cfg, cell, args):
    """Simulated DP training with OCCL gradient sync (paper Sec. 5.3)."""
    from ..data.pipeline import SyntheticPipeline
    from ..train.occl_sync import OcclGradSync
    from ..train.state import init_state
    from ..train.step import make_apply_step, make_grads_step

    dp = args.dp
    assert cell.global_batch % dp == 0
    states = [init_state(cfg) for _ in range(dp)]   # identical seeds
    pipes = [SyntheticPipeline(cfg, cell, shard_id=r, n_shards=dp)
             for r in range(dp)]
    grads_fn = jax.jit(make_grads_step(cfg))
    apply_fn = jax.jit(make_apply_step(cfg))
    gtmpl = jax.eval_shape(lambda: states[0].params)
    sync = OcclGradSync(gtmpl, dp)

    for step in range(args.steps):
        t0 = time.time()
        per_rank = []
        losses = []
        for r in range(dp):
            loss, g = grads_fn(states[r], next(pipes[r]))
            per_rank.append(g)
            losses.append(float(loss))
        synced = sync.all_reduce(per_rank)
        states = [apply_fn(states[r], synced[r]) for r in range(dp)]
        print(f"step {step:3d} loss {np.mean(losses):.4f} "
              f"{(time.time()-t0)*1e3:7.1f} ms "
              f"(occl launches={sync.occl.launches})")
    st = sync.stats()
    print("occl grad-sync: supersteps", int(st["supersteps"].max()),
          "preempts", int(st["preempts"].sum()))


if __name__ == "__main__":
    main()
